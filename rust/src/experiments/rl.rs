//! RL-side drivers: agent training (the `rap train-agent` command),
//! Fig 9 (seed robustness), Fig 10 (α/β sensitivity), Fig 11 (overhead).

use anyhow::Result;

use super::common::{agent_path, banner, setup};
use crate::agent::dqn::{DqnAgent, DqnConfig, EpisodeLog};
use crate::agent::env::{EnvConfig, PruneEnv};
use crate::gsi::{CalibratedEvaluator, GsiEngine};
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::runtime::SyntheticEvaluator;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Workload/budget distribution the controller is trained against
/// (heterogeneous request mixes + fluctuating budgets — paper Alg 2).
pub fn training_sampler(max_seq: usize)
    -> impl FnMut(&mut Rng) -> (Workload, f64) {
    move |rng: &mut Rng| {
        let batch = [4usize, 8, 16][rng.below(3)];
        let seqlen = [max_seq / 2, max_seq][rng.below(2)];
        let budget = 0.55 + 0.35 * rng.f64();
        (Workload::new(batch, seqlen), budget)
    }
}

/// Train the DQN controller against the real model (memoized GSI reward)
/// and save it next to the model's artifacts. Returns the episode log.
pub fn train_agent(model: &str, episodes: usize, seed: u64)
                   -> Result<Vec<EpisodeLog>> {
    banner(&format!(
        "Training RAP controller ({model}, {episodes} episodes, seed \
         {seed})"));
    let s = setup(model)?;
    let max_seq = s.rt.meta().max_seq;
    let corpus = s.corpus;
    let mut ev = CalibratedEvaluator::new(s.rt, &corpus, 1, 128)?;
    let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
    let mut rng = Rng::new(seed);
    let cfg = DqnConfig { episodes, ..DqnConfig::default() };
    let mut agent =
        DqnAgent::new(env.state_dim(), env.n_actions(), cfg, &mut rng);
    #[allow(clippy::disallowed_methods)]
    // lint:allow(wall-clock): reports real training wall time to the
    // operator; nothing downstream consumes it
    let t0 = std::time::Instant::now();
    let logs = agent.train(&mut env, training_sampler(max_seq), seed)?;
    let secs = t0.elapsed().as_secs_f64();
    let path = agent_path(model);
    if let Some(dir) = path.parent() {
        // the sim fallback runs without an artifacts tree on disk
        std::fs::create_dir_all(dir)?;
    }
    agent.save(&path)?;
    println!("trained in {secs:.1}s  ({} Q-network parameters), saved to \
              {}", agent.n_params(), path.display());
    let log_json = Json::Arr(logs.iter().map(|l| Json::object(vec![
        ("episode", Json::Num(l.episode as f64)),
        ("reward", Json::Num(l.reward)),
        ("steps", Json::Num(l.steps as f64)),
        ("fit", Json::Bool(l.fit)),
    ])).collect());
    std::fs::write(agent_path(model).with_extension("log.json"),
                   log_json.pretty())?;
    print_curve(&logs, 10);
    Ok(logs)
}

fn print_curve(logs: &[EpisodeLog], chunks: usize) {
    let n = logs.len().max(1);
    let step = (n / chunks).max(1);
    println!("  reward curve (chunk means):");
    for c in logs.chunks(step) {
        let avg: f64 =
            c.iter().map(|l| l.reward).sum::<f64>() / c.len() as f64;
        let fit = c.iter().filter(|l| l.fit).count();
        println!("    ep {:>4}  reward {:>8.4}  fit {}/{}",
                 c[0].episode, avg, fit, c.len());
    }
}

/// Fig 9: reward curves across independent seeds. Seeds share the GSI
/// memo through one environment, so later seeds are much cheaper.
pub fn fig9(model: &str, episodes: usize) -> Result<()> {
    banner(&format!("Figure 9 — RL reward across seeds ({model})"));
    let s = setup(model)?;
    let max_seq = s.rt.meta().max_seq;
    let corpus = s.corpus;
    let mut ev = CalibratedEvaluator::new(s.rt, &corpus, 1, 128)?;
    let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
    let mut finals = Vec::new();
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let cfg = DqnConfig { episodes, ..DqnConfig::default() };
        let mut agent = DqnAgent::new(env.state_dim(), env.n_actions(),
                                      cfg, &mut rng);
        let logs =
            agent.train(&mut env, training_sampler(max_seq), seed)?;
        println!("\nseed {seed}:");
        print_curve(&logs, 8);
        let tail: f64 = logs[logs.len().saturating_sub(10)..]
            .iter()
            .map(|l| l.reward)
            .sum::<f64>() / 10.0;
        finals.push(tail);
    }
    let mean = crate::util::stats::mean(&finals);
    let spread = finals.iter().fold(0.0f64, |a, &x| a.max((x - mean)
        .abs()));
    println!("\nfinal-reward mean {mean:.4}, max seed deviation \
              {spread:.4}");
    println!("shape check: all seeds converge into a narrow band \
              (paper Fig 9).");
    Ok(())
}

/// Fit an additive surrogate of the real model's block damage from
/// one-shot GSI scores — used for the (α, β) sweep where 25 full
/// trainings against PJRT would be disproportionate (documented in
/// DESIGN.md §6).
pub fn fit_surrogate(model: &str) -> Result<SyntheticEvaluator> {
    let s = setup(model)?;
    let meta = s.rt.meta().clone();
    let corpus = s.corpus;
    let mut ev = CalibratedEvaluator::new(s.rt, &corpus, 1, 128)?;
    let mut gsi = GsiEngine::new(&mut ev);
    let full = PruneMask::full(&meta);
    let base = gsi.nll(&full)?;
    let imp = gsi.importance(&full)?;
    Ok(SyntheticEvaluator::new(meta, base, imp, 0.5))
}

/// Fig 10: reward landscape over the (α, β) penalty factors.
pub fn fig10(model: &str, episodes: usize) -> Result<()> {
    banner(&format!("Figure 10 — α/β sensitivity ({model}, additive \
                     surrogate)"));
    let surrogate = fit_surrogate(model)?;
    let alphas = [0.2f64, 0.4, 0.6, 0.8, 1.0];
    let betas = [0.1f64, 0.2, 0.3, 0.4, 0.5];
    println!("rows: α, cols: β — mean last-10-episode reward");
    print!("{:>6}", "");
    for b in betas {
        print!(" {b:>8.1}");
    }
    println!();
    let mut best = (f64::MIN, 0.0, 0.0);
    for a in alphas {
        print!("{a:>6.1}");
        for b in betas {
            let mut ev = surrogate_clone(&surrogate);
            let mut env = PruneEnv::new(&mut ev, EnvConfig {
                alpha: a, beta: b });
            let mut rng = Rng::new(17);
            let cfg = DqnConfig { episodes, hidden: 64,
                                  ..DqnConfig::default() };
            let mut agent = DqnAgent::new(env.state_dim(),
                                          env.n_actions(), cfg, &mut rng);
            let max_seq = env.mem.meta().max_seq;
            let logs =
                agent.train(&mut env, training_sampler(max_seq), 17)?;
            let tail: f64 = logs[logs.len().saturating_sub(10)..]
                .iter()
                .map(|l| l.reward)
                .sum::<f64>() / 10.0;
            if tail > best.0 {
                best = (tail, a, b);
            }
            print!(" {tail:>8.3}");
        }
        println!();
    }
    println!("\nbest ridge at α={:.1}, β={:.1} (paper adopts α=1.0, \
              β=0.3 — large α, moderate β)", best.1, best.2);
    Ok(())
}

fn surrogate_clone(s: &SyntheticEvaluator) -> SyntheticEvaluator {
    SyntheticEvaluator::new(s.meta.clone(), s.base_nll, s.damage.clone(),
                            s.layer_synergy)
}

/// Fig 11: controller overhead vs the LLM (params, memory, latency).
pub fn fig11(model: &str) -> Result<()> {
    banner(&format!("Figure 11 — RL-agent overhead analysis ({model})"));
    let mut s = setup(model)?;
    let meta = s.rt.meta().clone();
    let mask = PruneMask::full(&meta);

    // model side: one batched "inference" = prefill 128 + 64 decode steps
    // at batch 8 (the paper's seqlen-2048/batch-8 analog at our scale).
    let calib = s.calib_tokens()?;
    let mut env_rng = Rng::new(5);
    let prompt: Vec<i32> = (0..128)
        .map(|_| env_rng.below(meta.vocab) as i32)
        .collect();
    #[allow(clippy::disallowed_methods)]
    // lint:allow(wall-clock): Table-6-style overhead figures measure
    // real host time by design
    let t0 = std::time::Instant::now();
    let (_, k1, v1) = s.rt.prefill(128, &prompt, &mask)?;
    let mut k = vec![0.0f32; s.rt.cache_elems(8)];
    let mut v = vec![0.0f32; s.rt.cache_elems(8)];
    let per = k1.len();
    for b in 0..8 {
        // replicate the prefilled sequence into every batch slot
        let lper = per / meta.n_layers;
        for l in 0..meta.n_layers {
            let dst = (l * 8 + b) * lper;
            k[dst..dst + lper]
                .copy_from_slice(&k1[l * lper..(l + 1) * lper]);
            v[dst..dst + lper]
                .copy_from_slice(&v1[l * lper..(l + 1) * lper]);
        }
    }
    let mut toks = vec![1i32; 8];
    for step in 0..64 {
        let pos: Vec<i32> = vec![128 + step as i32; 8];
        let lg = s.rt.decode(8, &toks, &pos, &mut k, &mut v, &mask)?;
        for (b, t) in toks.iter_mut().enumerate() {
            *t = argmax(&lg[b * meta.vocab..(b + 1) * meta.vocab]) as i32;
        }
    }
    let infer_secs = t0.elapsed().as_secs_f64();

    // controller side: one full policy decision (GSI warm after first)
    let corpus = s.corpus;
    let mut ev = CalibratedEvaluator { rt: s.rt, tokens: calib, batch: 1,
                                       seqlen: 128 };
    let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
    let mut rng = Rng::new(3);
    let cfg = DqnConfig { episodes: 0, ..DqnConfig::default() };
    let agent = DqnAgent::new(env.state_dim(), env.n_actions(), cfg,
                              &mut rng);
    let w = Workload::new(8, meta.max_seq);
    #[allow(clippy::disallowed_methods)]
    // lint:allow(wall-clock): cold/warm decision latency is the
    // measured quantity (paper Table 6)
    let t1 = std::time::Instant::now();
    let _mask = crate::agent::online_prune(&agent, &mut env, w, 0.8)?;
    let cold = t1.elapsed().as_secs_f64();
    #[allow(clippy::disallowed_methods)]
    // lint:allow(wall-clock): warm-path half of the same measurement
    let t2 = std::time::Instant::now();
    let _mask = crate::agent::online_prune(&agent, &mut env, w, 0.8)?;
    let warm = t2.elapsed().as_secs_f64();

    let model_params = meta.total_params();
    let agent_params = agent.n_params();
    let mem = MemoryModel::new(&meta);
    let model_bytes =
        mem.peak_bytes(&mask, Workload::new(8, meta.max_seq));
    let agent_bytes = agent_params * 4;
    let _ = corpus;

    println!("  {:<28} {:>14} {:>14}", "", "LLM", "RL agent");
    println!("  {:<28} {:>14} {:>14}", "parameters",
             fmt_big(model_params), fmt_big(agent_params));
    println!("  {:<28} {:>13.1}M {:>13.3}M", "peak memory (MiB)",
             model_bytes as f64 / 1e6, agent_bytes as f64 / 1e6);
    println!("  {:<28} {:>13.2}s {:>13.3}s",
             "latency (batch-8 inference / policy step, cold)",
             infer_secs, cold);
    println!("  {:<28} {:>14} {:>12.4}s", "policy step (warm memo)", "",
             warm);
    println!("\n  parameter reduction factor: {:.0}×",
             model_params as f64 / agent_params as f64);
    println!("  warm controller overhead: {:.2}% of one batched \
              inference", warm / infer_secs * 100.0);
    println!("\nshape check: paper reports 3.7e5× parameter reduction and \
              <1% latency overhead (0.5s vs 52.73s).");
    Ok(())
}

fn fmt_big(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut b = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[b] {
            b = i;
        }
    }
    b
}

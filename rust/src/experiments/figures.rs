//! Figure drivers 2–6 and 12: workload statistics, memory regimes, block
//! sensitivity, dynamic-memory OOM traces, one-shot vs GSI.

use anyhow::Result;

use super::common::{banner, setup};
use crate::corpus::Split;
use crate::mask::PruneMask;
use crate::memory::{gib, mib, MemoryModel, Workload};
use crate::model_meta::{BlockId, ModelMeta};
use crate::util::stats::Histogram;
use crate::workload::{TraceConfig, TraceGenerator};

/// Fig 2: distribution + daily variation of the conversational workload.
pub fn fig2(seed: u64) -> Result<()> {
    banner("Figure 2 — workload distribution and daily variation \
            (Azure-like trace)");
    let mut gen = TraceGenerator::new(TraceConfig::default(), seed);
    let reqs = gen.generate_day();
    println!("requests in one simulated day: {}", reqs.len());

    println!("\n(a) prompt-length distribution");
    let mut h = Histogram::new(0.0, 130.0, 13);
    for r in &reqs {
        h.add(r.prompt_len as f64);
    }
    print!("{}", h.ascii(40));

    println!("\n(b) hourly arrival rate (requests per 1/24 day)");
    let day = gen.cfg.day_secs;
    let mut hourly = vec![0usize; 24];
    for r in &reqs {
        let hr = ((r.arrival / day) * 24.0) as usize;
        hourly[hr.min(23)] += 1;
    }
    let max = *hourly.iter().max().unwrap_or(&1) as f64;
    for (hr, &c) in hourly.iter().enumerate() {
        let bar = "#".repeat((c as f64 / max * 40.0) as usize);
        println!("  h{hr:02} |{bar:<40}| {c}");
    }
    println!("\n(c) generation-length stats");
    let lens: Vec<f64> = reqs.iter().map(|r| r.gen_len as f64).collect();
    println!("  mean {:.1}  p50 {:.1}  p95 {:.1}",
             crate::util::stats::mean(&lens),
             crate::util::stats::percentile(&lens, 50.0),
             crate::util::stats::percentile(&lens, 95.0));
    Ok(())
}

fn breakdown_row(mem: &MemoryModel, mask: &PruneMask, w: Workload,
                 unit_gib: bool) {
    let b = mem.breakdown(mask, w);
    let total = b.total() as f64;
    let fmt = |x: usize| if unit_gib { gib(x) } else { mib(x) };
    println!(
        "  bs={:<3} len={:<5} | FFN {:>8.2} ({:>4.1}%)  MHA {:>8.2} \
         ({:>4.1}%)  KV {:>8.2} ({:>4.1}%)  total {:>8.2} {}",
        w.batch, w.seqlen, fmt(b.ffn_param_bytes),
        b.ffn_param_bytes as f64 / total * 100.0, fmt(b.mha_param_bytes),
        b.mha_param_bytes as f64 / total * 100.0, fmt(b.kv_bytes),
        b.kv_bytes as f64 / total * 100.0, fmt(b.total()),
        if unit_gib { "GiB" } else { "MiB" });
}

/// Fig 3: memory footprint shares across batch/seqlen — the
/// parameter-dominated → KV-dominated transition.
pub fn fig3() -> Result<()> {
    banner("Figure 3 — dynamic memory footprint across batch sizes and \
            sequence lengths");
    println!("\n(a) paper-scale shape: Llama2-7B (analytic, f32)");
    let llama = ModelMeta::llama2_7b();
    let mem = MemoryModel::new(&llama);
    let mask = PruneMask::full(&llama);
    for &(bs, len) in &[(1usize, 128usize), (1, 512), (4, 1024), (8, 2048),
                        (16, 4096)] {
        breakdown_row(&mem, &mask, Workload::new(bs, len), true);
    }
    println!("\n(b) this repo's substitute: rap-small (measured manifest)");
    let s = setup("rap-small")?;
    let mask = PruneMask::full(s.rt.meta());
    for &(bs, len) in &[(1usize, 32usize), (2, 64), (4, 128), (8, 256),
                        (16, 256)] {
        breakdown_row(&s.mem, &mask, Workload::new(bs, len), false);
    }
    println!("\nshape check: small workloads parameter-dominated, large \
              KV-dominated (paper Fig 3).");
    Ok(())
}

/// Fig 4 / Fig 12: per-block sensitivity (remove one MHA/FFN) across
/// sequence lengths.
pub fn fig4(model: &str) -> Result<()> {
    banner(&format!(
        "Figure 4/12 — block sensitivity vs sequence length ({model})"));
    let mut s = setup(model)?;
    let meta = s.rt.meta().clone();
    let full = PruneMask::full(&meta);
    for &t in &[64usize, 128, 256] {
        // the sim backend scores any shape; PJRT needs a compiled bucket
        if t > meta.max_seq
            || !(s.rt.is_sim()
                 || s.rt.meta().has_entry(&format!("score_b4_t{t}")))
        {
            continue;
        }
        let tokens = s.corpus.batches(Split::Wiki, 4, t, 1, 0)?.remove(0);
        let dense = s.rt.mean_nll(4, t, &tokens, &full)?;
        println!("\nseq len {t}: dense PPL {:.2}", dense.exp());
        println!("  {:<6} {:>10} {:>10}", "layer", "ΔPPL(MHA)",
                 "ΔPPL(FFN)");
        for l in 0..meta.n_layers {
            let m1 = full.with_block_dropped(BlockId::Mha(l));
            let m2 = full.with_block_dropped(BlockId::Ffn(l));
            let p1 = s.rt.mean_nll(4, t, &tokens, &m1)?.exp();
            let p2 = s.rt.mean_nll(4, t, &tokens, &m2)?.exp();
            println!("  {:<6} {:>10.2} {:>10.2}", l, p1 - dense.exp(),
                     p2 - dense.exp());
        }
    }
    println!("\nshape check: per-layer impact is heterogeneous and varies \
              with sequence length (paper Takeaway 2).");
    Ok(())
}

/// Fig 5: dynamic memory allocation trace with OOM events under a static
/// dense deployment vs RAP.
pub fn fig5(seed: u64, secs: f64) -> Result<()> {
    fig5_with(seed, secs, 1, None, None)
}

/// As [`fig5`], with the CLI's tenancy decoration (`serve --tenants n
/// --slo secs`): the same trace spread across `tenants` synthetic
/// tenants, every request carrying a relative completion SLO of `slo`
/// seconds. The report then includes the per-tenant sections (deadline
/// hit-rates, per-tenant TTFT tails). `trace_out` attaches a flight
/// recorder to the RAP engine and writes its Chrome-trace JSON there.
pub fn fig5_with(seed: u64, secs: f64, tenants: usize,
                 slo: Option<f64>, trace_out: Option<&str>)
                 -> Result<()> {
    use crate::server::controller::{Controller, Policy};
    use crate::server::engine::{Engine, EngineConfig};
    use crate::server::memmon::{MemMonConfig, MemoryMonitor};
    use crate::telemetry::{Bus, Recorder};
    use crate::util::json::Json;

    banner("Figure 5 — dynamic memory trace with co-running interference");
    for (label, adaptive) in [("static-dense", false), ("RAP", true)] {
        let s = setup("rap-small")?;
        let calib = s.calib_tokens()?;
        // Capacity: 1.35× the dense parameter bytes — enough for the dense
        // model plus a moderate KV working set, but co-running apps
        // (~30% chunks) push it under water, as in the paper's Fig 5.
        let param_bytes =
            s.mem.param_bytes(&PruneMask::full(s.rt.meta()));
        let capacity = (param_bytes as f64 * 1.35) as usize;
        let monitor = MemoryMonitor::new(MemMonConfig {
            app_rate: 0.1,
            mean_hold_secs: 25.0,
            size_mu: (capacity as f64 * 0.30).ln(),
            ..MemMonConfig::for_capacity(capacity)
        }, seed);
        let policy = if adaptive {
            Policy::GsiGreedy
        } else {
            Policy::Static(PruneMask::full(s.rt.meta()))
        };
        let controller = Controller::new(policy, s.mem.clone(), calib, 128);
        let mut engine = Engine::new(s.rt, monitor, controller,
                                     EngineConfig {
                                         max_sim_secs: secs,
                                         ..EngineConfig::default()
                                     });
        // flight-record the RAP run (the one whose decisions are worth
        // auditing) when the CLI asked for a trace file
        let recorder = if adaptive && trace_out.is_some() {
            let rec = std::rc::Rc::new(std::cell::RefCell::new(
                Recorder::default()));
            engine.bus = Bus::attached(&rec, Some(0));
            Some(rec)
        } else {
            None
        };
        let mut gen = TraceGenerator::new(TraceConfig {
            base_rate: 1.2,
            ..TraceConfig::default()
        }, seed + 1);
        let reqs = gen.generate(0.0, secs);
        let n_req = reqs.len();
        // the one ingress path: trace → SubmitRequests (decorated with
        // tenants/SLO when the CLI asked for them)
        let subs = crate::api::decorate_trace(reqs, tenants, slo);
        let report = engine.run_requests(subs)?;
        println!("\n[{label}] {} requests over {:.0}s sim", n_req, secs);
        println!("  t(s)    used(MiB)  avail(MiB)");
        for sample in engine.metrics.mem_trace.iter().step_by(4) {
            let bar_used = (mib(sample.used) / 4.0) as usize;
            println!("  {:>6.1} {:>9.1} {:>10.1} |{}", sample.t,
                     mib(sample.used), mib(sample.available),
                     "#".repeat(bar_used.min(60)));
        }
        println!("  OOM events: {}   absorbed spikes: {}   evictions: \
                  {}   rejections: {}   completed: {}   mask switches: \
                  {}",
                 report.oom_events, report.absorbed_spikes,
                 report.evictions, report.rejected, report.completed,
                 report.mask_switches);
        report.print_tenants();
        if let (Some(path), Some(rec)) = (trace_out, recorder) {
            let r = rec.borrow();
            let trace = crate::telemetry::trace::chrome_trace(
                &r.events, &r.dumps, engine.sim_time(),
                vec![("source", Json::Str("rap serve".to_string())),
                     ("seed", Json::Num(seed as f64))]);
            std::fs::write(path, trace.pretty())?;
            println!("  trace written to {path}");
        }
    }
    println!("\nshape check: static deployment accumulates OOM events when \
              interference spikes; RAP absorbs them by shrinking the \
              model (the absorbed-spikes column) instead.");
    Ok(())
}

/// Fig 6: per-block PPL under one-shot vs GSI orderings.
pub fn fig6(model: &str, n_remove: usize) -> Result<()> {
    use crate::gsi::{CalibratedEvaluator, GsiEngine};

    banner(&format!(
        "Figure 6 — one-shot vs greedy-sequential importance ({model})"));
    let s = setup(model)?;
    let meta = s.rt.meta().clone();
    let corpus = s.corpus;
    let mut ev = CalibratedEvaluator::new(s.rt, &corpus, 4, 128)?;
    let mut gsi = GsiEngine::new(&mut ev);
    let full = PruneMask::full(&meta);

    let one_shot = gsi.one_shot_order(&full)?;
    println!("\none-shot ranking (first {n_remove} removals, static \
              scores):");
    let mut os_mask = full.clone();
    for (b, d) in one_shot.iter().take(n_remove) {
        os_mask.drop_block(*b);
        println!("  remove {:<6} static ΔNLL {:+.4}", b.to_string(), d);
    }
    let os_nll = gsi.nll(&os_mask)?;

    let mut count = 0usize;
    let res = gsi.greedy(&full, |_| {
        count += 1;
        count > n_remove
    })?;
    println!("\nGSI ranking (recalibrated after every removal):");
    for (b, nll) in res.order.iter().zip(&res.nll_after) {
        println!("  remove {:<6} PPL after {:.2}", b.to_string(),
                 nll.exp());
    }
    let gsi_nll = *res.nll_after.last().unwrap();
    println!("\nafter {n_remove} removals: one-shot PPL {:.2} vs GSI PPL \
              {:.2}  (dense {:.2})",
             os_nll.exp(), gsi_nll.exp(), res.base_nll.exp());
    println!("model evaluations spent (memoized): {}", gsi.memo_len());
    println!("\nshape check: GSI ≤ one-shot (paper Fig 6 / Table 2 shows \
              one-shot inflating PPL).");
    Ok(())
}

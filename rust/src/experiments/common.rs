//! Shared setup for the experiment drivers.

use anyhow::Result;

use crate::corpus::{Corpus, Split};
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::runtime::{ProbeStats, Runtime};

/// The unified-budget workload Table 1/2/3 accounts against. Chosen so
/// the dense peak is KV-dominated (like the paper's batch=16 / 4k-token
/// Llama setting scaled to our substitute): batch 16 × max_seq.
pub fn budget_workload(rt: &Runtime) -> Workload {
    Workload::new(16, rt.meta().max_seq)
}

/// Perplexity-eval batch count (4×128 windows each).
pub const PPL_BATCHES: usize = 6;
/// MCQ questions per task for table runs.
pub const MCQ_QUESTIONS: usize = 24;

pub struct Setup {
    pub rt: Runtime,
    pub corpus: Corpus,
    pub mem: MemoryModel,
}

pub fn setup(model: &str) -> Result<Setup> {
    let root = crate::artifacts_dir();
    let rt = Runtime::load(&root, model)?;
    let corpus = Corpus::load(&root.join("corpus"))?;
    let mem = MemoryModel::new(rt.meta());
    Ok(Setup { rt, corpus, mem })
}

impl Setup {
    /// Probe stats on a dense model over an alpaca-sim batch (the
    /// baselines' importance source).
    pub fn dense_probe(&mut self) -> Result<ProbeStats> {
        let (_, pb, pt) = self.rt.probe_entry()?;
        let tokens = self
            .corpus
            .batches(Split::Alpaca, pb, pt, 1, 0)?
            .remove(0);
        let mask = PruneMask::full(self.rt.meta());
        self.rt.probe(&tokens, &mask)
    }

    /// Calibration tokens for GSI (b=1, t=128 — the cheap bucket).
    pub fn calib_tokens(&self) -> Result<Vec<i32>> {
        Ok(self.corpus.batches(Split::Alpaca, 1, 128, 1, 0)?.remove(0))
    }
}

/// Where a trained agent lives for `model`.
pub fn agent_path(model: &str) -> std::path::PathBuf {
    crate::artifacts_dir().join(model).join("agent.bin")
}

/// Section header for experiment output.
pub fn banner(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}

//! Shared setup for the experiment drivers.
//!
//! `setup` prefers the AOT artifacts (real PJRT execution); when they
//! are absent it falls back to the deterministic sim backend with an
//! in-process regenerated corpus, so every experiment, example, and CI
//! job runs artifact-free.

use anyhow::{bail, Result};

use crate::corpus::{Corpus, Split};
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::model_meta::ModelMeta;
use crate::runtime::{ProbeStats, Runtime};

/// The unified-budget workload Table 1/2/3 accounts against. Chosen so
/// the dense peak is KV-dominated (like the paper's batch=16 / 4k-token
/// Llama setting scaled to our substitute): batch 16 × max_seq.
pub fn budget_workload(rt: &Runtime) -> Workload {
    Workload::new(16, rt.meta().max_seq)
}

/// Perplexity-eval batch count (4×128 windows each).
pub const PPL_BATCHES: usize = 6;
/// MCQ questions per task for table runs.
pub const MCQ_QUESTIONS: usize = 24;

pub struct Setup {
    pub rt: Runtime,
    pub corpus: Corpus,
    pub mem: MemoryModel,
}

pub fn setup(model: &str) -> Result<Setup> {
    let root = crate::artifacts_dir();
    let loaded = Runtime::load(&root, model).and_then(|rt| {
        let corpus = Corpus::load(&root.join("corpus"))?;
        Ok((rt, corpus))
    });
    match loaded {
        Ok((rt, corpus)) => {
            let mem = MemoryModel::new(rt.meta());
            Ok(Setup { rt, corpus, mem })
        }
        Err(e) => {
            eprintln!("note: AOT artifacts unavailable ({e}); running \
                       '{model}' on the deterministic sim backend");
            sim_setup(model, 42)
        }
    }
}

/// Artifact-free setup: the sim runtime plus a corpus regenerated
/// in-process from the same Markov+copy family the AOT path trains on.
/// Deterministic per (model, seed).
pub fn sim_setup(model: &str, seed: u64) -> Result<Setup> {
    let meta = sim_meta_for(model)?;
    let rt = Runtime::synthetic(meta, seed);
    let corpus = Corpus::synthetic(rt.meta().vocab, seed);
    let mem = MemoryModel::new(rt.meta());
    Ok(Setup { rt, corpus, mem })
}

/// Shape table mirroring python/compile/model.py's CONFIGS — the sim
/// fallback serves the same model geometry the AOT path compiles.
fn sim_meta_for(model: &str) -> Result<ModelMeta> {
    Ok(match model {
        "rap-small" => ModelMeta::synthetic("rap-small", 12, 256, 8, 8,
                                            1024, 512, 256),
        "qwen-sim" => ModelMeta::synthetic("qwen-sim", 8, 256, 8, 2,
                                           768, 512, 256),
        "rap-tiny" => ModelMeta::synthetic("rap-tiny", 3, 64, 4, 2,
                                           128, 64, 64),
        other => bail!("no sim shape for model '{other}' (expected \
                        rap-small | qwen-sim | rap-tiny)"),
    })
}

impl Setup {
    /// Probe stats on a dense model over an alpaca-sim batch (the
    /// baselines' importance source).
    pub fn dense_probe(&mut self) -> Result<ProbeStats> {
        let (_, pb, pt) = self.rt.probe_entry()?;
        let tokens = self
            .corpus
            .batches(Split::Alpaca, pb, pt, 1, 0)?
            .remove(0);
        let mask = PruneMask::full(self.rt.meta());
        self.rt.probe(&tokens, &mask)
    }

    /// Calibration tokens for GSI (b=1, t=128 — the cheap bucket).
    pub fn calib_tokens(&self) -> Result<Vec<i32>> {
        Ok(self.corpus.batches(Split::Alpaca, 1, 128, 1, 0)?.remove(0))
    }
}

/// Where a trained agent lives for `model`.
pub fn agent_path(model: &str) -> std::path::PathBuf {
    crate::artifacts_dir().join(model).join("agent.bin")
}

/// Section header for experiment output.
pub fn banner(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}

//! Table drivers: Table 1 (main results), Table 2 (ablations), Table 3
//! (Qwen-sim generalization), Table 4 (weight-prune ratios) — all under
//! the paper's unified-memory-budget protocol.

use anyhow::Result;

use super::common::{agent_path, banner, budget_workload, setup,
                    MCQ_QUESTIONS, PPL_BATCHES};
use crate::agent::dqn::{DqnAgent, DqnConfig};
use crate::agent::env::{EnvConfig, PruneEnv};
use crate::evalharness::{full_eval, EvalRow};
use crate::gsi::{CalibratedEvaluator, GsiEngine};
use crate::mask::PruneMask;
use crate::pruning::{build_mask, build_mask_eval, PruneContext, Scheme};

pub struct TableRow {
    pub scheme: String,
    pub eval: EvalRow,
    pub param_ratio_pruned: f64,
}

/// Evaluate one (model, budget) block of Table 1: every scheme under the
/// same absolute byte budget. Returns rows for Table 4 reuse.
pub fn run_budget_block(model: &str, budget_frac: f64, seed: u64,
                        questions: usize, ppl_batches: usize)
                        -> Result<Vec<TableRow>> {
    let mut s = setup(model)?;
    let meta = s.rt.meta().clone();
    let w = budget_workload(&s.rt);
    let budget_bytes = s.mem.budget_bytes(w, budget_frac);
    let probe = s.dense_probe()?;

    // 1. decide all masks first (so eval order can't bias anything)
    let mut masks: Vec<(String, PruneMask)> = Vec::new();
    {
        let ctx = PruneContext { mem: &s.mem, probe: &probe, workload: w,
                                 budget_bytes, seed };
        masks.push(("Dense".into(), PruneMask::full(&meta)));
        for scheme in Scheme::baselines() {
            masks.push((scheme.name().into(), build_mask(scheme, &ctx)?));
        }
        masks.push((Scheme::RandomDrop.name().into(),
                    build_mask(Scheme::RandomDrop, &ctx)?));
    }
    // evaluator-driven schemes share one memoized GSI engine
    {
        let corpus_ref = &s.corpus;
        let mut ev = CalibratedEvaluator::new(s.rt, corpus_ref, 4, 128)?;
        let mut gsi = GsiEngine::new(&mut ev);
        let ctx = PruneContext { mem: &s.mem, probe: &probe, workload: w,
                                 budget_bytes, seed };
        masks.push((Scheme::OneShot.name().into(),
                    build_mask_eval(Scheme::OneShot, &ctx, &mut gsi)?));
        // RAP: trained agent if available, else GSI-greedy (same
        // machinery the agent is trained around).
        let rap_mask = if agent_path(model).exists() {
            let agent = DqnAgent::load(&agent_path(model),
                                       DqnConfig::default())?;
            let mut env = PruneEnv::with_memo(&mut ev,
                                              EnvConfig::default(),
                                              Default::default());
            crate::agent::online_prune(&agent, &mut env, w, budget_frac)?
        } else {
            build_mask_eval(Scheme::RapGreedy, &ctx, &mut gsi)?
        };
        masks.push(("RAP".into(), rap_mask));
        s.rt = ev.rt; // hand the runtime back
    }

    // 2. evaluate
    let mut rows = Vec::new();
    for (name, mask) in masks {
        let peak = s.mem.peak_bytes(&mask, w);
        let fits = peak <= budget_bytes || name == "Dense";
        let eval = full_eval(&mut s.rt, &s.corpus, &mask, &name,
                             ppl_batches, questions, seed)?;
        rows.push(TableRow {
            scheme: if fits { name } else { format!("{name} (!fit)") },
            eval,
            param_ratio_pruned: 1.0 - mask.param_fraction(&meta),
        });
    }
    Ok(rows)
}

/// Table 1 (and Table 3 when called with qwen-sim): zero-shot performance
/// of pruned vs dense under 80% / 60% unified budgets.
pub fn table1(model: &str, seed: u64, quick: bool) -> Result<Vec<(f64,
    Vec<TableRow>)>> {
    banner(&format!(
        "Table 1/3 — zero-shot performance under memory budgets ({model})"));
    let (q, p) = if quick { (8, 2) } else { (MCQ_QUESTIONS, PPL_BATCHES) };
    let mut out = Vec::new();
    for &budget in &[0.8f64, 0.6] {
        println!("\n--- budget {:.0}% of dense peak (params + KV) ---",
                 budget * 100.0);
        println!("{}", EvalRow::header());
        let rows = run_budget_block(model, budget, seed, q, p)?;
        for r in &rows {
            let mut e = r.eval.clone();
            e.scheme = r.scheme.clone();
            println!("{}", e.row());
        }
        out.push((budget, rows));
    }
    println!("\nshape check: RAP keeps the lowest PPL drift and highest \
              avg accuracy at both budgets; FFN-Skip collapses under the \
              KV-dominated budget (paper Table 1).");
    Ok(out)
}

/// Table 2 / Fig 8: ablation study — RAP⁻GSI (one-shot scores) and
/// RAP⁻RL (random drop) vs full RAP.
pub fn table2(model: &str, seed: u64, quick: bool) -> Result<()> {
    banner(&format!("Table 2 / Figure 8 — ablations ({model})"));
    let (q, p) = if quick { (8, 2) } else { (MCQ_QUESTIONS, PPL_BATCHES) };
    for &budget in &[0.8f64, 0.6] {
        println!("\n--- budget {:.0}% ---", budget * 100.0);
        println!("{}", EvalRow::header());
        let rows = run_budget_block(model, budget, seed, q, p)?;
        for r in rows {
            let keep = r.scheme.contains("RAP") || r.scheme == "Dense";
            if keep {
                let mut e = r.eval.clone();
                e.scheme = match r.scheme.as_str() {
                    "Random-Drop (RAP-RL)" => "RAP -RL (random)".into(),
                    "One-Shot (RAP-GSI)" => "RAP -GSI (one-shot)".into(),
                    other => other.into(),
                };
                println!("{}", e.row());
            }
        }
    }
    println!("\nshape check: full RAP < RAP-GSI < RAP-RL in PPL (paper \
              Table 2: 11.8 < 42.0 < 313.5 at 80%).");
    Ok(())
}

/// Table 4: weight-prune ratio each scheme needed to meet the budget.
pub fn table4(seed: u64) -> Result<()> {
    banner("Table 4 — weight-pruning ratio required to meet each \
            memory budget");
    println!("{:<22} {:>14} {:>14} {:>14} {:>14}", "Scheme",
             "rap-small 80%", "rap-small 60%", "qwen-sim 80%",
             "qwen-sim 60%");
    let mut cols: Vec<Vec<(String, f64)>> = Vec::new();
    for model in ["rap-small", "qwen-sim"] {
        for &budget in &[0.8f64, 0.6] {
            let rows = run_budget_block(model, budget, seed, 0, 1)?;
            cols.push(rows
                .into_iter()
                .map(|r| (r.scheme, r.param_ratio_pruned))
                .collect());
        }
    }
    for i in 0..cols[0].len() {
        print!("{:<22}", cols[0][i].0);
        for col in &cols {
            print!(" {:>13.1}%", col[i].1 * 100.0);
        }
        println!();
    }
    println!("\nshape check: RAP meets the budget with the *least* weight \
              pruning (paper Table 4: ~24% vs 35–75% for baselines) \
              because it also prunes KV-heavy MHA blocks.");
    Ok(())
}

/// Run every budget block once and print Tables 1, 2, 3 and 4 from the
/// shared results (avoids recomputing the expensive eval blocks).
pub fn all_tables(seed: u64, quick: bool) -> Result<()> {
    let (q, p) = if quick { (8, 2) } else { (MCQ_QUESTIONS, PPL_BATCHES) };
    let mut blocks: Vec<(String, f64, Vec<TableRow>)> = Vec::new();
    for model in ["rap-small", "qwen-sim"] {
        for &budget in &[0.8f64, 0.6] {
            eprintln!("[tables] computing {model} @ {budget}...");
            let rows = run_budget_block(model, budget, seed, q, p)?;
            blocks.push((model.to_string(), budget, rows));
        }
    }
    for model in ["rap-small", "qwen-sim"] {
        banner(&format!(
            "Table {} — zero-shot performance under memory budgets ({model})",
            if model == "rap-small" { "1" } else { "3" }));
        for (m, budget, rows) in &blocks {
            if m != model {
                continue;
            }
            println!("\n--- budget {:.0}% of dense peak (params + KV) ---",
                     budget * 100.0);
            println!("{}", EvalRow::header());
            for r in rows {
                let mut e = r.eval.clone();
                e.scheme = r.scheme.clone();
                println!("{}", e.row());
            }
        }
    }
    banner("Table 2 / Figure 8 — ablations (rap-small)");
    for (m, budget, rows) in &blocks {
        if m != "rap-small" {
            continue;
        }
        println!("\n--- budget {:.0}% ---", budget * 100.0);
        println!("{}", EvalRow::header());
        for r in rows {
            if r.scheme.contains("RAP") || r.scheme == "Dense" {
                let mut e = r.eval.clone();
                e.scheme = match r.scheme.as_str() {
                    "Random-Drop (RAP-RL)" => "RAP -RL (random)".into(),
                    "One-Shot (RAP-GSI)" => "RAP -GSI (one-shot)".into(),
                    other => other.into(),
                };
                println!("{}", e.row());
            }
        }
    }
    banner("Table 4 — weight-pruning ratio required per budget");
    println!("{:<22} {:>14} {:>14} {:>14} {:>14}", "Scheme",
             "rap-small 80%", "rap-small 60%", "qwen-sim 80%",
             "qwen-sim 60%");
    for i in 0..blocks[0].2.len() {
        print!("{:<22}", blocks[0].2[i].scheme);
        for (_, _, rows) in &blocks {
            print!(" {:>13.1}%", rows[i].param_ratio_pruned * 100.0);
        }
        println!();
    }
    Ok(())
}

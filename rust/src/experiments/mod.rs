//! Experiment drivers — one per paper table/figure (DESIGN.md §5 maps
//! each to its source). All are runnable via `rap experiment <id>`.

pub mod bench;
pub mod common;
pub mod figures;
pub mod fleet;
pub mod rl;
pub mod tables;

//! `rap bench fleet` — the serving-stack throughput benchmark: replay
//! the seeded tenant-storm and chaos-storm scenario traces and record
//! sim-side requests/sec, wall-clock, and peak RSS, each with telemetry
//! off and on (the observer-cost surface CI watches).
//!
//! Unlike every report/trace JSON in the repo, `BENCH_fleet.json`
//! deliberately carries wall-clock numbers — it *measures* the host, so
//! its bytes are not expected to be seed-deterministic. Sim-side
//! figures (requests, completions, sim seconds, rps) still are.

use std::time::Instant;

use anyhow::Result;

use super::common::banner;
use crate::coordinator::fleet::{chaos_storm_fleet, chaos_storm_trace,
                                tenant_storm_fleet, tenant_storm_trace};
use crate::coordinator::router::RouterPolicy;
use crate::util::json::Json;

/// Peak resident set size in bytes, from `/proc/self/status` `VmHWM`.
/// 0 when the file is unavailable (non-Linux hosts).
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest.trim().trim_end_matches("kB").trim();
            return kb.parse::<u64>().unwrap_or(0) * 1024;
        }
    }
    0
}

struct BenchRow {
    scenario: &'static str,
    telemetry: bool,
    requests: usize,
    completed: usize,
    sim_secs: f64,
    sim_rps: f64,
    wall_secs: f64,
    audit_events: f64,
}

fn bench_one(scenario: &'static str, telemetry: bool, seed: u64)
             -> Result<BenchRow> {
    let (mut fleet, reqs) = match scenario {
        "tenant-storm" => {
            (tenant_storm_fleet(seed, RouterPolicy::TenantFair),
             tenant_storm_trace(seed))
        }
        _ => (chaos_storm_fleet(seed, true), chaos_storm_trace(seed)),
    };
    if telemetry {
        fleet.enable_telemetry();
        fleet.enable_metrics_sampling(1.0);
    }
    let requests = reqs.len();
    let t0 = Instant::now();
    let report = fleet.run_requests(reqs)?;
    let wall_secs = t0.elapsed().as_secs_f64();
    // audit-stream size comes back out of the exported trace, so the
    // benchmark also exercises the export path end to end
    let audit_events = fleet
        .trace_json()
        .and_then(|t| t.get("metadata").ok()?.get("events").ok()?
                       .num().ok())
        .unwrap_or(0.0);
    Ok(BenchRow {
        scenario,
        telemetry,
        requests,
        completed: report.completed,
        sim_secs: report.sim_secs,
        sim_rps: report.throughput_rps,
        wall_secs,
        audit_events,
    })
}

/// `rap bench fleet [--json path]`: both storm scenarios, telemetry off
/// then on, written to `BENCH_fleet.json` (or `--json <path>`).
pub fn bench_fleet(seed: u64, json_path: Option<&str>) -> Result<()> {
    banner(&format!(
        "Bench — fleet serving throughput, telemetry off vs on \
         (seed {seed})"));
    println!("{:<14} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10} {:>8}",
             "scenario", "telemetry", "requests", "completed",
             "sim secs", "sim req/s", "wall secs", "events");
    let mut rows = Vec::new();
    for scenario in ["tenant-storm", "chaos-storm"] {
        for telemetry in [false, true] {
            let row = bench_one(scenario, telemetry, seed)?;
            println!("{:<14} {:>9} {:>9} {:>9} {:>9.1} {:>10.2} \
                      {:>10.3} {:>8}",
                     row.scenario, if row.telemetry { "on" } else
                     { "off" },
                     row.requests, row.completed, row.sim_secs,
                     row.sim_rps, row.wall_secs, row.audit_events);
            rows.push(row);
        }
    }
    let peak_rss = peak_rss_bytes();
    println!("peak RSS: {:.1} MiB", peak_rss as f64 / (1024.0 * 1024.0));
    let json = Json::object(vec![
        ("seed", Json::Num(seed as f64)),
        ("peak_rss_bytes", Json::Num(peak_rss as f64)),
        ("runs", Json::Arr(rows.iter().map(|r| {
            Json::object(vec![
                ("scenario", Json::Str(r.scenario.to_string())),
                ("telemetry", Json::Bool(r.telemetry)),
                ("requests", Json::Num(r.requests as f64)),
                ("completed", Json::Num(r.completed as f64)),
                ("sim_secs", Json::Num(r.sim_secs)),
                ("sim_rps", Json::Num(r.sim_rps)),
                ("wall_secs", Json::Num(r.wall_secs)),
                ("audit_events", Json::Num(r.audit_events)),
            ])
        }).collect())),
    ]);
    let path = json_path.unwrap_or("BENCH_fleet.json");
    std::fs::write(path, json.pretty())?;
    println!("bench JSON written to {path}");
    Ok(())
}

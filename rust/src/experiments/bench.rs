//! `rap bench fleet` — the serving-stack throughput benchmark: replay
//! the seeded tenant-storm and chaos-storm scenario traces and record
//! sim-side requests/sec, wall-clock, and peak RSS, each with telemetry
//! off and on (the observer-cost surface CI watches).
//!
//! `rap bench fleet --scale` is the second surface: the replica-count
//! scaling trajectory (event-driven + sampled routing vs the lockstep
//! full-scan baseline on a generated 1M-request tenant storm), written
//! to `BENCH_scale.json` and ratio-gated in CI.
//!
//! Unlike every report/trace JSON in the repo, the `BENCH_*.json`
//! files deliberately carry wall-clock numbers — they *measure* the
//! host, so their bytes are not expected to be seed-deterministic.
//! Sim-side figures (requests, completions, sim seconds) still are —
//! the committed `rust/BENCH_fleet.json` baseline pins exactly those
//! (wall-clock and observer fields zeroed), and CI diffs every fresh
//! run's sim-side figures against it.

use std::time::Instant;

use anyhow::Result;

use super::common::banner;
use crate::api::SubmitRequest;
use crate::coordinator::fleet::{chaos_storm_fleet, chaos_storm_trace,
                                tenant_storm_fleet, tenant_storm_trace,
                                Fleet, FleetConfig};
use crate::coordinator::replica::{build_sim_replica, ReplicaSpec};
use crate::coordinator::router::{Router, RouterPolicy};
use crate::model_meta::ModelMeta;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Peak resident set size in bytes, from `/proc/self/status` `VmHWM`.
/// 0 when the file is unavailable (non-Linux hosts).
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest.trim().trim_end_matches("kB").trim();
            return kb.parse::<u64>().unwrap_or(0) * 1024;
        }
    }
    0
}

struct BenchRow {
    scenario: &'static str,
    telemetry: bool,
    requests: usize,
    completed: usize,
    sim_secs: f64,
    sim_rps: f64,
    wall_secs: f64,
    audit_events: f64,
}

fn bench_one(scenario: &'static str, telemetry: bool, seed: u64)
             -> Result<BenchRow> {
    let (mut fleet, reqs) = match scenario {
        "tenant-storm" => {
            (tenant_storm_fleet(seed, RouterPolicy::TenantFair),
             tenant_storm_trace(seed))
        }
        _ => (chaos_storm_fleet(seed, true), chaos_storm_trace(seed)),
    };
    if telemetry {
        fleet.enable_telemetry();
        fleet.enable_metrics_sampling(1.0);
    }
    let requests = reqs.len();
    #[allow(clippy::disallowed_methods)]
    // lint:allow(wall-clock): the benchmark's whole point is host
    // wall time; it is the one deliberate wall-clock artifact
    let t0 = Instant::now();
    let report = fleet.run_requests(reqs)?;
    let wall_secs = t0.elapsed().as_secs_f64();
    // audit-stream size comes back out of the exported trace, so the
    // benchmark also exercises the export path end to end
    let audit_events = fleet
        .trace_json()
        .and_then(|t| t.get("metadata").ok()?.get("events").ok()?
                       .num().ok())
        .unwrap_or(0.0);
    Ok(BenchRow {
        scenario,
        telemetry,
        requests,
        completed: report.completed,
        sim_secs: report.sim_secs,
        sim_rps: report.throughput_rps,
        wall_secs,
        audit_events,
    })
}

/// `rap bench fleet [--json path]`: both storm scenarios, telemetry off
/// then on, written to `BENCH_fleet.json` (or `--json <path>`).
pub fn bench_fleet(seed: u64, json_path: Option<&str>) -> Result<()> {
    banner(&format!(
        "Bench — fleet serving throughput, telemetry off vs on \
         (seed {seed})"));
    println!("{:<14} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10} {:>8}",
             "scenario", "telemetry", "requests", "completed",
             "sim secs", "sim req/s", "wall secs", "events");
    let mut rows = Vec::new();
    for scenario in ["tenant-storm", "chaos-storm"] {
        for telemetry in [false, true] {
            let row = bench_one(scenario, telemetry, seed)?;
            println!("{:<14} {:>9} {:>9} {:>9} {:>9.1} {:>10.2} \
                      {:>10.3} {:>8}",
                     row.scenario, if row.telemetry { "on" } else
                     { "off" },
                     row.requests, row.completed, row.sim_secs,
                     row.sim_rps, row.wall_secs, row.audit_events);
            rows.push(row);
        }
    }
    let peak_rss = peak_rss_bytes();
    println!("peak RSS: {:.1} MiB", peak_rss as f64 / (1024.0 * 1024.0));
    let json = Json::object(vec![
        ("seed", Json::Num(seed as f64)),
        ("peak_rss_bytes", Json::Num(peak_rss as f64)),
        ("runs", Json::Arr(rows.iter().map(|r| {
            Json::object(vec![
                ("scenario", Json::Str(r.scenario.to_string())),
                ("telemetry", Json::Bool(r.telemetry)),
                ("requests", Json::Num(r.requests as f64)),
                ("completed", Json::Num(r.completed as f64)),
                ("sim_secs", Json::Num(r.sim_secs)),
                ("sim_rps", Json::Num(r.sim_rps)),
                ("wall_secs", Json::Num(r.wall_secs)),
                ("audit_events", Json::Num(r.audit_events)),
            ])
        }).collect())),
    ]);
    let path = json_path.unwrap_or("BENCH_fleet.json");
    std::fs::write(path, json.pretty())?;
    println!("bench JSON written to {path}");
    Ok(())
}

// ---- replica-count scaling sweep (ISSUE 8) ----------------------------

/// Requests in the event-driven storm at each sweep point.
const SCALE_STORM_REQS: usize = 1_000_000;
/// The lockstep baseline replays a truncated prefix of the same storm:
/// a full-roster sweep per tick at 1024 replicas would take hours on
/// a million requests, and requests/sec is wall-normalized anyway, so
/// a shorter run measures the same per-request cost.
const SCALE_LOCKSTEP_REQS: usize = 20_000;
/// Tenants in the generated storm.
const SCALE_TENANTS: usize = 8;
/// Offered load per replica (req/s) — fixed per replica so every
/// sweep point runs at the same utilization and the sweep isolates
/// coordination cost, not queueing collapse.
const SCALE_RATE_PER_REPLICA: f64 = 0.5;

/// A tenant-storm trace sized for the scaling sweep: `n_requests`
/// arrivals spread over `SCALE_TENANTS` tenants at
/// `SCALE_RATE_PER_REPLICA × n_replicas` req/s, with prompts and
/// generations kept tiny so wall-clock measures the *fleet's*
/// coordination cost rather than token simulation.
fn scale_storm_trace(seed: u64, n_requests: usize, n_replicas: usize)
                     -> Vec<SubmitRequest> {
    let rate = SCALE_RATE_PER_REPLICA * n_replicas as f64;
    let mut rng = Rng::new(seed ^ 0x5CA1_E5ED);
    let mut at = 0.0;
    (0..n_requests)
        .map(|i| {
            at += rng.f64() * 2.0 / rate;
            let tenant = format!("t{}", rng.below(SCALE_TENANTS));
            SubmitRequest::new(8 + rng.below(9), 1 + rng.below(4))
                .with_id(i as u64)
                .with_arrival(at)
                .with_tenant(&tenant)
        })
        .collect()
}

/// A homogeneous `n`-replica fleet on a deliberately tiny model —
/// drain/respawn, interference, and mask motion all off, so the sweep
/// isolates the coordination layer under test.
fn scale_fleet(n: usize, seed: u64, event_driven: bool) -> Fleet {
    let meta =
        ModelMeta::synthetic("scale-sim", 2, 32, 4, 2, 64, 64, 64);
    let spec = ReplicaSpec {
        app_rate: 0.0,   // no interference
        adaptive: false, // static dense: no controller churn
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        oom_threshold: usize::MAX, // no drain/respawn
        max_sim_secs: 1e12,        // never truncate the storm
        event_driven,
        sample_d: event_driven.then_some(2),
        ..FleetConfig::default()
    };
    let replicas = (0..n)
        .map(|i| build_sim_replica(i, &meta, &spec, seed))
        .collect();
    Fleet::new(replicas, Router::new(RouterPolicy::RapAware, n), cfg)
}

struct ScaleRow {
    replicas: usize,
    mode: &'static str,
    requests: usize,
    completed: usize,
    sim_secs: f64,
    wall_secs: f64,
    /// Wall-normalized throughput: requests replayed per wall second.
    rps: f64,
    /// `VmHWM` after this run — cumulative across the process, so
    /// rows are meaningful read in sweep order (ascending N).
    peak_rss_bytes: u64,
}

/// `rap bench fleet --scale [--points 4,64,256,1024] [--json path]`:
/// the replica-count scaling trajectory. Each point replays the
/// generated tenant storm twice — event-driven + sampled routing on
/// the full `SCALE_STORM_REQS`, then the lockstep full-scan baseline
/// on a truncated prefix — and records wall-normalized requests/sec.
/// CI asserts event/lockstep ≥ 10× at N=1024 from `BENCH_scale.json`.
pub fn bench_scale(seed: u64, json_path: Option<&str>,
                   points: &[usize]) -> Result<()> {
    banner(&format!(
        "Bench — fleet scaling sweep, event-driven vs lockstep \
         (seed {seed})"));
    println!("{:<9} {:>9} {:>9} {:>9} {:>10} {:>10} {:>11} {:>9}",
             "replicas", "mode", "requests", "completed", "sim secs",
             "wall secs", "req/s", "rss MiB");
    let mut rows = Vec::new();
    for &n in points {
        for (mode, n_req) in [("lockstep", SCALE_LOCKSTEP_REQS),
                              ("event", SCALE_STORM_REQS)] {
            let event = mode == "event";
            let reqs = scale_storm_trace(seed, n_req, n);
            let mut fleet = scale_fleet(n, seed, event);
            #[allow(clippy::disallowed_methods)]
            // lint:allow(wall-clock): scaling sweep reports host
            // throughput — wall time is the measured quantity
            let t0 = Instant::now();
            let report = fleet.run_requests(reqs)?;
            let wall_secs = t0.elapsed().as_secs_f64();
            let rps = n_req as f64 / wall_secs.max(1e-9);
            let row = ScaleRow {
                replicas: n,
                mode,
                requests: n_req,
                completed: report.completed,
                sim_secs: report.sim_secs,
                wall_secs,
                rps,
                peak_rss_bytes: peak_rss_bytes(),
            };
            println!("{:<9} {:>9} {:>9} {:>9} {:>10.1} {:>10.3} \
                      {:>11.0} {:>9.1}",
                     row.replicas, row.mode, row.requests,
                     row.completed, row.sim_secs, row.wall_secs,
                     row.rps,
                     row.peak_rss_bytes as f64 / (1024.0 * 1024.0));
            rows.push(row);
        }
    }
    let json = Json::object(vec![
        ("seed", Json::Num(seed as f64)),
        ("runs", Json::Arr(rows.iter().map(|r| {
            Json::object(vec![
                ("replicas", Json::Num(r.replicas as f64)),
                ("mode", Json::Str(r.mode.to_string())),
                ("requests", Json::Num(r.requests as f64)),
                ("completed", Json::Num(r.completed as f64)),
                ("sim_secs", Json::Num(r.sim_secs)),
                ("wall_secs", Json::Num(r.wall_secs)),
                ("rps", Json::Num(r.rps)),
                ("peak_rss_bytes", Json::Num(r.peak_rss_bytes as f64)),
            ])
        }).collect())),
    ]);
    let path = json_path.unwrap_or("BENCH_scale.json");
    std::fs::write(path, json.pretty())?;
    println!("scale bench JSON written to {path}");
    Ok(())
}

//! Fleet experiment: the four routing policies head-to-head on one
//! seeded trace across heterogeneous replicas. Runs entirely on the sim
//! runtime backend — no artifacts required.

use anyhow::Result;

use super::common::banner;
use crate::coordinator::fleet::{default_fleet_trace, default_sim_fleet,
                                elastic_demo_fleet, elastic_demo_trace};
use crate::coordinator::metrics::{zero_nan, FleetReport};
use crate::coordinator::router::RouterPolicy;

/// `rap experiment fleet`: replay the same trace under every routing
/// policy and tabulate completions, memory casualties, and tail latency.
pub fn fleet_compare(seed: u64, secs: f64, replicas: usize) -> Result<()> {
    banner(&format!(
        "Fleet — routing policies across {replicas} heterogeneous \
         replicas ({secs:.0}s trace, seed {seed})"));
    let reqs = default_fleet_trace(seed, secs);
    println!("trace: {} requests\n", reqs.len());
    println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>9} {:>9} {:>9}",
             "router", "completed", "rejected", "dropped", "OOMs",
             "respawn", "p50 lat", "p99 lat", "p99 ttft");
    for policy in RouterPolicy::ALL {
        let mut fleet = default_sim_fleet(replicas, seed, policy);
        fleet.cfg.max_sim_secs = secs + 3600.0; // arrivals + drain window
        let r = fleet.run_trace(reqs.clone())?;
        println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>8.3}s \
                  {:>8.3}s {:>8.3}s",
                 policy.name(), r.completed, r.rejected, r.dropped,
                 r.oom_events, r.respawns, zero_nan(r.p50_latency),
                 zero_nan(r.p99_latency), zero_nan(r.p99_ttft));
    }
    println!("\nshape check: memory-aware routing (kv-headroom, \
              rap-aware) cuts OOM events vs round-robin on the same \
              trace; rap-aware additionally weighs each replica's mask \
              quality and the request's KV cost under that mask.");
    Ok(())
}

fn elastic_row(label: &str, r: &FleetReport) {
    println!("{:<22} {:>9} {:>8} {:>8} {:>6} {:>6} {:>7} {:>8} {:>9}",
             label, r.completed, r.rejected, r.evictions, r.oom_events,
             r.spawns, r.retires, r.migrations,
             format!("{:.3}s", zero_nan(r.p99_ttft)));
}

/// `rap experiment fleet --elastic`: the ISSUE-3 acceptance surface.
/// One seeded burst-storm trace against periodic interference walls,
/// served twice by otherwise-identical fleets: the fixed-size
/// drain/respawn baseline, and the elastic fleet (autoscaling +
/// cross-replica migration). The elastic fleet must lose fewer
/// sequences to OOM evictions and hold a lower p99 TTFT — the same
/// inequality `tests/elastic_fleet.rs` asserts. The scenario's shape
/// (2 replicas, 120 s, wall schedule) is fixed so the comparison stays
/// reproducible; only the seed varies.
pub fn fleet_elastic(seed: u64) -> Result<()> {
    banner(&format!(
        "Fleet — fixed drain/respawn vs autoscale+migration on one \
         burst-storm trace (seed {seed})"));
    let reqs = elastic_demo_trace(seed);
    println!("trace: {} requests over {:.0}s, 4 interference walls on \
              replica 0 (fixed scenario — only --seed varies it)\n",
             reqs.len(),
             crate::coordinator::fleet::ELASTIC_DEMO_SECS);
    println!("{:<22} {:>9} {:>8} {:>8} {:>6} {:>6} {:>7} {:>8} {:>9}",
             "fleet", "completed", "rejected", "evicted", "OOMs",
             "spawns", "retires", "migrated", "p99 ttft");
    let mut fixed = elastic_demo_fleet(seed, false);
    let fr = fixed.run_trace(reqs.clone())?;
    elastic_row("fixed drain/respawn", &fr);
    let mut elastic = elastic_demo_fleet(seed, true);
    let er = elastic.run_trace(reqs)?;
    elastic_row("autoscale+migrate", &er);
    println!("\nshape check: migration turns every eviction the walls \
              would force into a live transfer (evicted column → 0, \
              migrated column > 0), and the autoscaler's burst capacity \
              pulls the TTFT tail down.");
    if er.evictions < fr.evictions && er.p99_ttft < fr.p99_ttft {
        println!("verdict: elastic fleet wins on both axes \
                  (evictions {} vs {}, p99 ttft {:.3}s vs {:.3}s).",
                 er.evictions, fr.evictions, er.p99_ttft, fr.p99_ttft);
    } else {
        println!("verdict: UNEXPECTED — elastic fleet did not win on \
                  both axes (evictions {} vs {}, p99 ttft {:.3}s vs \
                  {:.3}s).",
                 er.evictions, fr.evictions, er.p99_ttft, fr.p99_ttft);
    }
    Ok(())
}

//! Fleet experiment: the four routing policies head-to-head on one
//! seeded trace across heterogeneous replicas. Runs entirely on the sim
//! runtime backend — no artifacts required.

use anyhow::Result;

use super::common::banner;
use crate::coordinator::fleet::{default_fleet_trace, default_sim_fleet};
use crate::coordinator::metrics::zero_nan;
use crate::coordinator::router::RouterPolicy;

/// `rap experiment fleet`: replay the same trace under every routing
/// policy and tabulate completions, memory casualties, and tail latency.
pub fn fleet_compare(seed: u64, secs: f64, replicas: usize) -> Result<()> {
    banner(&format!(
        "Fleet — routing policies across {replicas} heterogeneous \
         replicas ({secs:.0}s trace, seed {seed})"));
    let reqs = default_fleet_trace(seed, secs);
    println!("trace: {} requests\n", reqs.len());
    println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>9} {:>9} {:>9}",
             "router", "completed", "rejected", "dropped", "OOMs",
             "respawn", "p50 lat", "p99 lat", "p99 ttft");
    for policy in RouterPolicy::ALL {
        let mut fleet = default_sim_fleet(replicas, seed, policy);
        fleet.cfg.max_sim_secs = secs + 3600.0; // arrivals + drain window
        let r = fleet.run_trace(reqs.clone())?;
        println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>8.3}s \
                  {:>8.3}s {:>8.3}s",
                 policy.name(), r.completed, r.rejected, r.dropped,
                 r.oom_events, r.respawns, zero_nan(r.p50_latency),
                 zero_nan(r.p99_latency), zero_nan(r.p99_ttft));
    }
    println!("\nshape check: memory-aware routing (kv-headroom, \
              rap-aware) cuts OOM events vs round-robin on the same \
              trace; rap-aware additionally weighs each replica's mask \
              quality and the request's KV cost under that mask.");
    Ok(())
}

//! Fleet experiment: the four routing policies head-to-head on one
//! seeded trace across heterogeneous replicas. Runs entirely on the sim
//! runtime backend — no artifacts required.

use anyhow::Result;

use super::common::banner;
use crate::coordinator::fleet::{absorbable_spike_fleet,
                                absorbable_spike_trace,
                                chaos_storm_fleet, chaos_storm_trace,
                                default_fleet_trace, default_sim_fleet,
                                elastic_demo_fleet, elastic_demo_trace,
                                longctx_storm_fleet, longctx_storm_trace,
                                tenant_storm_fcfs_trace,
                                tenant_storm_fleet, tenant_storm_trace,
                                CHAOS_STORM_SECS, LONGCTX_STORM_SECS,
                                TENANT_STORM_SECS,
                                TENANT_STORM_SLO_SECS};
use crate::coordinator::metrics::{zero_nan, FleetReport,
                                  FleetTenantReport};
use crate::coordinator::router::RouterPolicy;
use crate::corpus::Corpus;
use crate::evalharness::mcq;
use crate::server::controller::default_kv_floor;
use crate::server::kv::KvPolicy;
use crate::util::json::Json;

/// `rap experiment fleet`: replay the same trace under every routing
/// policy and tabulate completions, memory casualties, and tail latency.
pub fn fleet_compare(seed: u64, secs: f64, replicas: usize) -> Result<()> {
    banner(&format!(
        "Fleet — routing policies across {replicas} heterogeneous \
         replicas ({secs:.0}s trace, seed {seed})"));
    let reqs = default_fleet_trace(seed, secs);
    println!("trace: {} requests\n", reqs.len());
    println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>9} {:>9} {:>9}",
             "router", "completed", "rejected", "dropped", "OOMs",
             "respawn", "p50 lat", "p99 lat", "p99 ttft");
    for policy in RouterPolicy::ALL {
        let mut fleet = default_sim_fleet(replicas, seed, policy);
        fleet.cfg.max_sim_secs = secs + 3600.0; // arrivals + drain window
        let r = fleet.run_trace(reqs.clone())?;
        println!("{:<18} {:>9} {:>8} {:>8} {:>6} {:>7} {:>8.3}s \
                  {:>8.3}s {:>8.3}s",
                 policy.name(), r.completed, r.rejected, r.dropped,
                 r.oom_events, r.respawns, zero_nan(r.p50_latency),
                 zero_nan(r.p99_latency), zero_nan(r.p99_ttft));
    }
    println!("\nshape check: memory-aware routing (kv-headroom, \
              rap-aware) cuts OOM events vs round-robin on the same \
              trace; rap-aware additionally weighs each replica's mask \
              quality and the request's KV cost under that mask.");
    Ok(())
}

fn elastic_row(label: &str, r: &FleetReport) {
    println!("{:<22} {:>9} {:>8} {:>8} {:>6} {:>8} {:>6} {:>7} {:>8} \
              {:>9}",
             label, r.completed, r.rejected, r.evictions, r.oom_events,
             r.absorbed_spikes, r.spawns, r.retires, r.migrations,
             format!("{:.3}s", zero_nan(r.p99_ttft)));
}

fn elastic_header() {
    println!("{:<22} {:>9} {:>8} {:>8} {:>6} {:>8} {:>6} {:>7} {:>8} \
              {:>9}",
             "fleet", "completed", "rejected", "evicted", "OOMs",
             "absorbed", "spawns", "retires", "migrated", "p99 ttft");
}

/// `rap experiment fleet --elastic`: the ISSUE-3 acceptance surface.
/// One seeded burst-storm trace against periodic interference walls,
/// served twice by otherwise-identical fleets: the fixed-size
/// drain/respawn baseline, and the elastic fleet (autoscaling +
/// cross-replica migration). The elastic fleet must lose fewer
/// sequences to OOM evictions and hold a lower p99 TTFT — the same
/// inequality `tests/elastic_fleet.rs` asserts. The scenario's shape
/// (2 replicas, 120 s, wall schedule) is fixed so the comparison stays
/// reproducible; only the seed varies.
pub fn fleet_elastic(seed: u64) -> Result<()> {
    banner(&format!(
        "Fleet — fixed drain/respawn vs autoscale+migration on one \
         burst-storm trace (seed {seed})"));
    let reqs = elastic_demo_trace(seed);
    println!("trace: {} requests over {:.0}s, 4 interference walls on \
              replica 0 (fixed scenario — only --seed varies it)\n",
             reqs.len(),
             crate::coordinator::fleet::ELASTIC_DEMO_SECS);
    elastic_header();
    let mut fixed = elastic_demo_fleet(seed, false);
    let fr = fixed.run_trace(reqs.clone())?;
    elastic_row("fixed drain/respawn", &fr);
    let mut elastic = elastic_demo_fleet(seed, true);
    let er = elastic.run_trace(reqs)?;
    elastic_row("autoscale+migrate", &er);
    println!("\nshape check: migration turns every eviction the walls \
              would force into a live transfer (evicted column → 0, \
              migrated column > 0), and the autoscaler's burst capacity \
              pulls the TTFT tail down.");
    if er.evictions < fr.evictions && er.p99_ttft < fr.p99_ttft {
        println!("verdict: elastic fleet wins on both axes \
                  (evictions {} vs {}, p99 ttft {:.3}s vs {:.3}s).",
                 er.evictions, fr.evictions, er.p99_ttft, fr.p99_ttft);
    } else {
        println!("verdict: UNEXPECTED — elastic fleet did not win on \
                  both axes (evictions {} vs {}, p99 ttft {:.3}s vs \
                  {:.3}s).",
                 er.evictions, fr.evictions, er.p99_ttft, fr.p99_ttft);
    }
    Ok(())
}

/// `rap experiment fleet --absorbable`: the ISSUE-4 acceptance surface.
/// One seeded trace whose interference spikes are fully absorbable by
/// mask-shrinking, served twice by otherwise-identical elastic fleets:
/// once under the legacy current-mask accounting (every spike looks
/// like an OOM → phantom queue rebalancing, migrations, and OOM-driven
/// spawns) and once under mask-elastic accounting (the memory outlook
/// absorbs every spike). The mask-elastic fleet must perform strictly
/// fewer migrations AND spawns at an equal-or-better p99 TTFT — with
/// this scenario's wall, exactly zero of each. The scenario shape
/// (2 replicas, a 20 s arrival window, one 12 s wall) is fixed; only
/// the seed varies.
pub fn fleet_absorbable(seed: u64) -> Result<()> {
    banner(&format!(
        "Fleet — current-mask vs mask-elastic accounting on absorbable \
         interference spikes (seed {seed})"));
    let reqs = absorbable_spike_trace(seed);
    println!("trace: {} requests over {:.0}s, then one absorbable wall \
              on replica 0 (fixed scenario — only --seed varies it)\n",
             reqs.len(),
             crate::coordinator::fleet::ABSORBABLE_SPIKE_SECS);
    elastic_header();
    let mut phantom = absorbable_spike_fleet(seed, false);
    let pr = phantom.run_trace(reqs.clone())?;
    elastic_row("current-mask", &pr);
    let mut elastic = absorbable_spike_fleet(seed, true);
    let er = elastic.run_trace(reqs)?;
    elastic_row("mask-elastic", &er);
    println!("\nshape check: every wall fits between the min-viable and \
              the current footprint, so the mask-elastic fleet absorbs \
              them all (absorbed column > 0) while the current-mask \
              fleet reroutes queues and spawns replicas for nothing.");
    println!("absorbable-spike: mask-elastic migrations={} spawns={} \
              ooms={} absorbed={}",
             er.migrations, er.spawns, er.oom_events,
             er.absorbed_spikes);
    if er.migrations < pr.migrations && er.spawns < pr.spawns
        && er.p99_ttft <= pr.p99_ttft
    {
        println!("verdict: mask-elastic accounting wins (migrations {} \
                  vs {}, spawns {} vs {}, p99 ttft {:.3}s vs {:.3}s).",
                 er.migrations, pr.migrations, er.spawns, pr.spawns,
                 er.p99_ttft, pr.p99_ttft);
    } else {
        println!("verdict: UNEXPECTED — mask-elastic accounting did not \
                  win (migrations {} vs {}, spawns {} vs {}, p99 ttft \
                  {:.3}s vs {:.3}s).",
                 er.migrations, pr.migrations, er.spawns, pr.spawns,
                 er.p99_ttft, pr.p99_ttft);
    }
    Ok(())
}

fn longctx_row(label: &str, r: &FleetReport) {
    println!("{:<14} {:>9} {:>8} {:>6} {:>8} {:>10} {:>11} {:>6} \
              {:>8} {:>9}",
             label, r.completed, r.evictions, r.oom_events,
             r.absorbed_spikes, r.compressed_spikes,
             format!("{:.1} KiB",
                     r.kv_bytes_reclaimed as f64 / 1024.0),
             r.spawns, r.migrations,
             format!("{:.3}s", zero_nan(r.p99_ttft)));
}

/// Questions per MCQ task in the quality block — enough for a stable
/// per-seed accuracy, small enough to keep the experiment instant.
const LONGCTX_MCQ_QUESTIONS: usize = 40;
/// Corpus seed for the MCQ block (matches the evalharness tests).
const LONGCTX_MCQ_CORPUS_SEED: u64 = 7;

/// `rap experiment fleet --longctx`: the PR-9 acceptance surface.
/// One seeded long-context storm against a mid-storm interference wall
/// sized into the joint-only band: deep enough that the controller's
/// min-viable *mask* alone cannot hold the closed cohort's decode
/// growth, shallow enough that the same mask plus KV compression to
/// the floor policy can. Served twice by otherwise-identical elastic
/// fleets: `kv_elastic = false` (mask-only, the pre-PR-9 lattice) and
/// `kv_elastic = true` (the joint (mask × KV policy) lattice). The
/// joint fleet must absorb the wall in place — zero migrations, zero
/// spawns, zero OOMs, compression engaged — at an equal-or-better p99
/// TTFT, while the mask-only fleet true-OOMs into shed work and
/// OOM-driven spawns. The same inequalities `tests/longctx_fleet.rs`
/// asserts.
///
/// The quality side of the trade: an MCQ block scores every
/// evalharness task under the dense policy and under the compression
/// floor with the *oracle* scorer (the true Markov chain conditioned
/// on retained context positions — see `evalharness::mcq`), including
/// the one task whose context genuinely exceeds the floor's token cap.
/// Floor accuracy must sit within `MCQ_EPSILON` of dense on every
/// task.
///
/// `report_out` writes the full acceptance report (both fleet reports
/// + the MCQ block) as JSON — deterministic per seed, byte for byte.
pub fn fleet_longctx(seed: u64, report_out: Option<&str>) -> Result<()> {
    banner(&format!(
        "Fleet — mask-only vs joint (mask × KV policy) elasticity on a \
         long-context storm (seed {seed})"));
    let reqs = longctx_storm_trace(seed);
    println!("trace: {} requests over {:.0}s, one mid-storm wall on \
              replica 0 sized into the joint-only band (fixed \
              scenario — only --seed varies it)\n",
             reqs.len(), LONGCTX_STORM_SECS);
    println!("{:<14} {:>9} {:>8} {:>6} {:>8} {:>10} {:>11} {:>6} \
              {:>8} {:>9}",
             "fleet", "completed", "evicted", "OOMs", "absorbed",
             "compressed", "reclaimed", "spawns", "migrated",
             "p99 ttft");
    let mut mask_only = longctx_storm_fleet(seed, false);
    let mr = mask_only.run_trace(reqs.clone())?;
    longctx_row("mask-only", &mr);
    let mut joint = longctx_storm_fleet(seed, true);
    let jr = joint.run_trace(reqs)?;
    longctx_row("joint", &jr);

    // -- quality block: dense vs compression-floor accuracy, oracle-
    //    scored over retained context positions
    let corpus = Corpus::synthetic(64, LONGCTX_MCQ_CORPUS_SEED);
    let floor = default_kv_floor();
    let mut tasks = mcq::all_tasks();
    tasks.push(mcq::longctx_task());
    println!("\nMCQ accuracy under KV compression (oracle scorer, \
              {LONGCTX_MCQ_QUESTIONS} questions/task):");
    println!("{:<16} {:>8} {:>8} {:>8} {:>8}",
             "task", "chance", "dense", "floor", "delta");
    let mut mcq_rows = Vec::new();
    let mut max_delta = 0.0f64;
    for task in &tasks {
        let dense = mcq::policy_accuracy(&corpus, task, KvPolicy::Dense,
                                         LONGCTX_MCQ_QUESTIONS, seed);
        let comp = mcq::policy_accuracy(&corpus, task, floor,
                                        LONGCTX_MCQ_QUESTIONS, seed);
        let delta = (dense - comp).abs();
        max_delta = max_delta.max(delta);
        println!("{:<16} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
                 task.name, mcq::chance(task), dense, comp, delta);
        mcq_rows.push(Json::object(vec![
            ("task", Json::Str(task.name.to_string())),
            ("chance", Json::Num(mcq::chance(task))),
            ("dense", Json::Num(dense)),
            ("floor", Json::Num(comp)),
            ("delta", Json::Num(delta)),
        ]));
    }

    println!("\nshape check: the wall lands where the min-viable mask \
              plus the cohort's decode growth no longer fits — the \
              mask-only fleet true-OOMs into shed work and OOM-driven \
              spawns, while the joint fleet compresses resident caches \
              to the floor and absorbs in place; the oracle shows the \
              floor costs within {:.2} accuracy on every task.",
             mcq::MCQ_EPSILON);
    println!("longctx-storm: joint migrations={} spawns={} ooms={} \
              compressed={} reclaimed={} vs mask-only ooms={} \
              spawns={} migrations={}; mcq max |dense - floor| = \
              {:.3}",
             jr.migrations, jr.spawns, jr.oom_events,
             jr.compressed_spikes, jr.kv_bytes_reclaimed,
             mr.oom_events, mr.spawns, mr.migrations, max_delta);
    let joint_wins = jr.migrations == 0 && jr.spawns == 0
        && jr.oom_events == 0 && jr.compressed_spikes > 0
        && jr.p99_ttft <= mr.p99_ttft
        && mr.oom_events + mr.spawns + mr.migrations >= 1
        && max_delta <= mcq::MCQ_EPSILON;
    if joint_wins {
        println!("verdict: joint elasticity wins (absorbed in place \
                  with 0 migrations / 0 spawns / 0 OOMs at p99 ttft \
                  {:.3}s vs {:.3}s, quality within epsilon).",
                 jr.p99_ttft, mr.p99_ttft);
    } else {
        println!("verdict: UNEXPECTED — joint elasticity did not win \
                  (joint ooms={} spawns={} migrations={} \
                  compressed={}, p99 ttft {:.3}s vs {:.3}s, mcq max \
                  delta {:.3}).",
                 jr.oom_events, jr.spawns, jr.migrations,
                 jr.compressed_spikes, jr.p99_ttft, mr.p99_ttft,
                 max_delta);
    }
    if let Some(path) = report_out {
        let report = Json::object(vec![
            ("scenario", Json::Str("longctx-storm".to_string())),
            ("seed", Json::Num(seed as f64)),
            ("mask_only", mr.to_json()),
            ("joint", jr.to_json()),
            ("mcq", Json::object(vec![
                ("questions_per_task",
                 Json::Num(LONGCTX_MCQ_QUESTIONS as f64)),
                ("corpus_seed",
                 Json::Num(LONGCTX_MCQ_CORPUS_SEED as f64)),
                ("epsilon", Json::Num(mcq::MCQ_EPSILON)),
                ("max_delta", Json::Num(max_delta)),
                ("tasks", Json::Arr(mcq_rows)),
            ])),
            ("joint_wins", Json::Bool(joint_wins)),
        ]);
        std::fs::write(path, report.pretty())?;
        println!("acceptance report written to {path}");
    }
    Ok(())
}

fn tenant_row(label: &str, t: &FleetTenantReport) {
    let hit = if t.counts.deadline_total > 0 {
        format!("{:>7.1}%", 100.0 * t.deadline_hit_rate())
    } else {
        "      —".to_string()
    };
    let quota = if t.quota_bytes.is_some() {
        format!("{:>7.1}%", 100.0 * t.quota_utilization())
    } else {
        "      —".to_string()
    };
    println!("{:<26} {:>9} {:>6} {:>7} {:>7} {:>9} {} {}",
             label, t.counts.submitted, t.counts.finished,
             t.counts.deadline_missed, t.counts.rejected,
             format!("{:.3}s", zero_nan(t.p99_ttft)), hit, quota);
}

/// Find one tenant's section of a fleet report.
fn tenant_section<'a>(r: &'a FleetReport, name: &str)
                      -> &'a FleetTenantReport {
    r.tenants
        .iter()
        .find(|t| t.tenant == name)
        .expect("tenant missing from report")
}

fn chaos_row(label: &str, r: &FleetReport) {
    let lat = tenant_section(r, "latency");
    println!("{:<18} {:>9} {:>7} {:>5} {:>9} {:>8} {:>6} {:>7} {:>9} \
              {:>7.1}%",
             label, r.completed, r.rejected, r.chaos.seq_lost,
             r.chaos.seq_restored, r.chaos.checkpoints_taken, r.spawns,
             r.chaos.transfer_retries,
             format!("{:.3}s", zero_nan(r.p99_ttft)),
             100.0 * lat.deadline_hit_rate())
}

/// Arrivals still non-terminal when the run drained — must be zero, or
/// the recovery path leaked a request.
fn nonterminal(r: &FleetReport) -> u64 {
    (r.total_requests).saturating_sub(
        r.completed as u64 + r.rejected + r.cancelled
            + r.deadline_missed + r.dropped)
}

/// `rap experiment fleet --chaos`: the ISSUE-6 acceptance surface.
/// One seeded two-tenant storm served twice by otherwise-identical
/// fleets while the same fault plan tears pieces out of them — the
/// interconnect degrades and partitions, one replica crashes mid-flood,
/// another is spot-reclaimed with a grace window. The only difference
/// between the two fleets is periodic KV checkpointing: with it, the
/// crash restores checkpointed sequences onto peers; without it, every
/// in-flight sequence on the crashed replica is lost and must restart
/// from scratch. The checkpointed fleet must lose strictly fewer
/// sequences AND hold a strictly better latency-tenant deadline
/// hit-rate — the same inequality `tests/chaos_fleet.rs` asserts. The
/// scenario shape (3 replicas, 40 s window, the fault schedule) is
/// fixed; only the seed varies.
/// `trace_out` flight-records the checkpointed fleet (the run whose
/// recovery decisions are worth auditing) and writes Chrome-trace JSON.
pub fn fleet_chaos(seed: u64, trace_out: Option<&str>) -> Result<()> {
    banner(&format!(
        "Fleet — checkpointed vs checkpoint-free recovery under one \
         seeded fault plan (seed {seed})"));
    let reqs = chaos_storm_trace(seed);
    println!("trace: {} requests over {:.0}s; fault plan: 3x link \
              degrade [10,20)s, crash replica 1 @14s, partition \
              [16,19)s, reclaim replica 2 @24s (5s grace) — fixed \
              scenario, only --seed varies it\n",
             reqs.len(), CHAOS_STORM_SECS);
    println!("{:<18} {:>9} {:>7} {:>5} {:>9} {:>8} {:>6} {:>7} {:>9} \
              {:>8}",
             "fleet", "completed", "reject", "lost", "restored",
             "ckpts", "spawns", "retries", "p99 ttft", "hit");
    let mut plain = chaos_storm_fleet(seed, false);
    let pr = plain.run_requests(reqs.clone())?;
    chaos_row("checkpoint-free", &pr);
    let mut ckpt = chaos_storm_fleet(seed, true);
    if trace_out.is_some() {
        ckpt.enable_telemetry();
    }
    let cr = ckpt.run_requests(reqs)?;
    chaos_row("checkpointed", &cr);
    if let (Some(path), Some(trace)) = (trace_out, ckpt.trace_json()) {
        std::fs::write(path, trace.pretty())?;
        println!("trace written to {path}");
    }
    let p_lat = tenant_section(&pr, "latency");
    let c_lat = tenant_section(&cr, "latency");
    println!("\nshape check: both fleets eat the same crash, but the \
              checkpointed one restores the crashed replica's \
              checkpointed sequences onto peers — where they re-enter \
              admission and resume mid-decode — instead of restarting \
              them from the prompt: fewer sequences lost, and the \
              latency tenant's deadline hit-rate holds up through the \
              fault window.");
    println!("chaos-storm: ckpt lost={} restored={} hit_rate={:.3} \
              nonterminal={} vs plain lost={} hit_rate={:.3} \
              nonterminal={}",
             cr.chaos.seq_lost, cr.chaos.seq_restored,
             c_lat.deadline_hit_rate(), nonterminal(&cr),
             pr.chaos.seq_lost, p_lat.deadline_hit_rate(),
             nonterminal(&pr));
    if cr.chaos.seq_lost < pr.chaos.seq_lost
        && c_lat.deadline_hit_rate() > p_lat.deadline_hit_rate()
        && nonterminal(&cr) == 0
        && nonterminal(&pr) == 0
    {
        println!("verdict: checkpointing wins (lost {} vs {}, \
                  hit-rate {:.1}% vs {:.1}%, every request terminal).",
                 cr.chaos.seq_lost, pr.chaos.seq_lost,
                 100.0 * c_lat.deadline_hit_rate(),
                 100.0 * p_lat.deadline_hit_rate());
    } else {
        println!("verdict: UNEXPECTED — checkpointing did not strictly \
                  win (lost {} vs {}, hit-rate {:.1}% vs {:.1}%, \
                  nonterminal {} / {}).",
                 cr.chaos.seq_lost, pr.chaos.seq_lost,
                 100.0 * c_lat.deadline_hit_rate(),
                 100.0 * p_lat.deadline_hit_rate(),
                 nonterminal(&cr), nonterminal(&pr));
    }
    Ok(())
}

/// `rap experiment fleet --tenants`: the ISSUE-5 acceptance surface.
/// One seeded two-tenant storm — a noisy tenant flooding low-priority
/// long decodes over a latency-sensitive tenant's steady SLO-carrying
/// stream — served twice by otherwise-identical fleets: once behind the
/// FCFS baseline (round-robin dispatch on arrival) and once behind the
/// tenant-fair router (per-tenant KV quotas, deficit-first dispatch,
/// RAP-aware placement within a tenant). Tenant-fair must hold the
/// latency tenant's p99 TTFT *and* deadline hit-rate strictly better
/// than FCFS while the noisy tenant's peak quota utilization stays ≤
/// 100% — the same inequality `tests/tenant_fleet.rs` asserts. The
/// scenario shape (2 replicas, 40 s window, one 20 s flood) is fixed;
/// only the seed varies.
pub fn fleet_tenants(seed: u64) -> Result<()> {
    banner(&format!(
        "Fleet — FCFS vs tenant-fair ingress on a two-tenant storm \
         (seed {seed})"));
    let reqs = tenant_storm_trace(seed);
    let latency_n =
        reqs.iter().filter(|r| r.tenant.as_ref() == "latency").count();
    println!("trace: {} requests over {:.0}s ({} latency-tenant with a \
              {:.1}s completion SLO, {} noisy-tenant long decodes) — \
              fixed scenario, only --seed varies it\n",
             reqs.len(), TENANT_STORM_SECS, latency_n,
             TENANT_STORM_SLO_SECS, reqs.len() - latency_n);
    println!("{:<26} {:>9} {:>6} {:>7} {:>7} {:>9} {:>8} {:>8}",
             "fleet / tenant", "submitted", "done", "missed", "reject",
             "p99 ttft", "hit", "quota");
    // the baseline is the legacy front door: round-robin dispatch,
    // FCFS queues (priorities flattened), deadlines measured only
    let mut fcfs = tenant_storm_fleet(seed, RouterPolicy::RoundRobin);
    let fr = fcfs.run_requests(tenant_storm_fcfs_trace(seed))?;
    tenant_row("fcfs / latency", tenant_section(&fr, "latency"));
    tenant_row("fcfs / noisy", tenant_section(&fr, "noisy"));
    let mut fair = tenant_storm_fleet(seed, RouterPolicy::TenantFair);
    let tr = fair.run_requests(reqs)?;
    tenant_row("tenant-fair / latency", tenant_section(&tr, "latency"));
    tenant_row("tenant-fair / noisy", tenant_section(&tr, "noisy"));
    let f_lat = tenant_section(&fr, "latency");
    let t_lat = tenant_section(&tr, "latency");
    let t_noisy = tenant_section(&tr, "noisy");
    println!("\nshape check: the quota holds the noisy flood at the \
              front door, so the latency tenant's requests stop \
              queueing behind long decodes — its TTFT tail and deadline \
              hit-rate must both improve strictly, and the noisy \
              tenant must stay within its KV quota.");
    println!("tenant-storm: tenant-fair latency p99_ttft={:.3}s \
              hit_rate={:.3} vs fcfs p99_ttft={:.3}s hit_rate={:.3} \
              noisy_quota_util={:.3}",
             t_lat.p99_ttft, t_lat.deadline_hit_rate(), f_lat.p99_ttft,
             f_lat.deadline_hit_rate(), t_noisy.quota_utilization());
    if t_lat.p99_ttft < f_lat.p99_ttft
        && t_lat.deadline_hit_rate() > f_lat.deadline_hit_rate()
        && t_noisy.quota_utilization() <= 1.0
    {
        println!("verdict: tenant-fair ingress wins (p99 ttft {:.3}s \
                  vs {:.3}s, hit-rate {:.1}% vs {:.1}%, noisy quota \
                  peak {:.1}%).",
                 t_lat.p99_ttft, f_lat.p99_ttft,
                 100.0 * t_lat.deadline_hit_rate(),
                 100.0 * f_lat.deadline_hit_rate(),
                 100.0 * t_noisy.quota_utilization());
    } else {
        println!("verdict: UNEXPECTED — tenant-fair did not strictly \
                  win (p99 ttft {:.3}s vs {:.3}s, hit-rate {:.1}% vs \
                  {:.1}%, noisy quota peak {:.1}%).",
                 t_lat.p99_ttft, f_lat.p99_ttft,
                 100.0 * t_lat.deadline_hit_rate(),
                 100.0 * f_lat.deadline_hit_rate(),
                 100.0 * t_noisy.quota_utilization());
    }
    Ok(())
}

//! PJRT runtime: loads the AOT artifacts and executes them from Rust.
//!
//! This is the only module that touches the `xla` crate. It follows the
//! /opt/xla-example/load_hlo pattern: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! Performance notes (§Perf):
//!   * weights are uploaded to the device ONCE as `PjRtBuffer`s and reused
//!     by every call via `execute_b` — without this every score/decode call
//!     would re-copy ~50 MB of parameters;
//!   * executables are compiled lazily per entry and cached;
//!   * PJRT (through this wrapper) returns one tuple buffer per execution,
//!     so multi-output results round-trip the host; KV caches therefore
//!     live host-side between decode steps (measured in EXPERIMENTS.md
//!     §Perf).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::mask::PruneMask;
use crate::model_meta::{DType, EntrySpec, ModelMeta};

/// A host-side input tensor handed to `Runtime::execute`.
pub enum HostArr<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostArr<'_> {
    fn len(&self) -> usize {
        match self {
            HostArr::F32(v) => v.len(),
            HostArr::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostArr::F32(_) => DType::F32,
            HostArr::I32(_) => DType::I32,
        }
    }
}

/// Per-entry execution statistics (drives the §Perf analysis + Fig 11).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Block-redundancy statistics from the `probe` entry (consumed by the
/// baseline pruners).
#[derive(Clone, Debug)]
pub struct ProbeStats {
    /// cos(x, x + attn(x)) per layer — high = MHA block redundant.
    pub attn_cos: Vec<f32>,
    /// cos(x, x + ffn(x)) per layer — high = FFN block redundant.
    pub ffn_cos: Vec<f32>,
    /// mean per-head output norm [L, H] — low = head prunable.
    pub head_norm: Vec<f32>,
    /// mean per-channel activation magnitude [L, F] — low = channel prunable.
    pub chan_norm: Vec<f32>,
}

pub struct Runtime {
    client: PjRtClient,
    meta: ModelMeta,
    /// Device-resident weight buffers, `param_specs` order.
    weights: Vec<PjRtBuffer>,
    exes: HashMap<String, PjRtLoadedExecutable>,
    stats: HashMap<String, ExecStats>,
}

impl Runtime {
    /// Load weights + manifest for `model` under `artifacts_root` and
    /// create a CPU PJRT client. Entries compile lazily on first use.
    pub fn load(artifacts_root: &Path, model: &str) -> Result<Runtime> {
        let meta = ModelMeta::load(&artifacts_root.join(model))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let bytes = std::fs::read(meta.dir.join("weights.bin"))
            .context("reading weights.bin")?;
        let mut weights = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let end = p.offset + p.nbytes;
            if end > bytes.len() {
                bail!("weights.bin too short for {}", p.name);
            }
            let data = f32_slice(&bytes[p.offset..end])?;
            weights.push(
                client
                    .buffer_from_host_buffer(&data, &p.shape, None)
                    .map_err(|e| anyhow::anyhow!(
                        "uploading {}: {e:?}", p.name))?,
            );
        }
        Ok(Runtime { client, meta, weights, exes: HashMap::new(),
                     stats: HashMap::new() })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }

    /// Total wall-clock spent inside PJRT executions.
    pub fn total_exec_secs(&self) -> f64 {
        self.stats.values().map(|s| s.total_secs).sum()
    }

    fn ensure_compiled(&mut self, entry: &str) -> Result<()> {
        if self.exes.contains_key(entry) {
            return Ok(());
        }
        let spec = self.meta.entry(entry)?.clone();
        let path = self.meta.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}",
                                         path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {entry}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.entry(entry.to_string()).or_default().compile_secs += dt;
        self.exes.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of entries (the serving engine does this at
    /// startup so the hot path never hits the compiler).
    pub fn warmup(&mut self, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.ensure_compiled(e)?;
        }
        Ok(())
    }

    /// Execute `entry` with the given runtime inputs (weights are
    /// prepended automatically). Returns the output tuple elements.
    pub fn execute(&mut self, entry: &str, inputs: &[HostArr])
                   -> Result<Vec<Literal>> {
        self.ensure_compiled(entry)?;
        let spec = self.meta.entry(entry)?.clone();
        validate_inputs(&spec, inputs)?;

        // Upload runtime inputs as device buffers.
        let mut owned: Vec<PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (i, inp) in inputs.iter().enumerate() {
            let shape = &spec.inputs[i].shape;
            let buf = match inp {
                HostArr::F32(v) => {
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
                HostArr::I32(v) => {
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
            }
            .map_err(|e| anyhow::anyhow!(
                "uploading input {} of {entry}: {e:?}",
                spec.inputs[i].name))?;
            owned.push(buf);
        }
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.extend(owned.iter());

        let exe = self.exes.get(entry).unwrap();
        let t0 = Instant::now();
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("executing {entry}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {entry} result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {entry}: {e:?}"))?;
        let st = self.stats.entry(entry.to_string()).or_default();
        st.calls += 1;
        st.total_secs += t0.elapsed().as_secs_f64();
        if parts.len() != spec.outputs.len() {
            bail!("{entry}: expected {} outputs, got {}",
                  spec.outputs.len(), parts.len());
        }
        Ok(parts)
    }

    // ---- typed entry points -------------------------------------------

    /// Masked-NLL scoring: returns (per_seq_nll, per_seq_cnt).
    pub fn score(&mut self, batch: usize, seqlen: usize, tokens: &[i32],
                 loss_mask: &[f32], mask: &PruneMask)
                 -> Result<(Vec<f32>, Vec<f32>)> {
        let entry = format!("score_b{batch}_t{seqlen}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(loss_mask),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok((lit_f32(&parts[0])?, lit_f32(&parts[1])?))
    }

    /// Mean NLL over a token batch with an all-ones loss mask — the
    /// perplexity primitive (exp of this is PPL).
    pub fn mean_nll(&mut self, batch: usize, seqlen: usize, tokens: &[i32],
                    mask: &PruneMask) -> Result<f64> {
        let ones = vec![1.0f32; batch * seqlen];
        let (nll, cnt) = self.score(batch, seqlen, tokens, &ones, mask)?;
        let total: f64 = nll.iter().map(|&x| x as f64).sum();
        let n: f64 = cnt.iter().map(|&x| x as f64).sum();
        Ok(total / n.max(1.0))
    }

    /// The compiled probe entry (models probe at min(128, max_seq)).
    pub fn probe_entry(&self) -> Result<(String, usize, usize)> {
        let e = self
            .meta
            .entries
            .iter()
            .find(|e| e.name.starts_with("probe_"))
            .ok_or_else(|| anyhow::anyhow!("no probe entry compiled"))?;
        let shape = &e.inputs[0].shape; // tokens [B, T]
        Ok((e.name.clone(), shape[0], shape[1]))
    }

    /// Block-redundancy probe (batch/seqlen from the compiled bucket —
    /// see `probe_entry`).
    pub fn probe(&mut self, tokens: &[i32], mask: &PruneMask)
                 -> Result<ProbeStats> {
        let (entry, _, _) = self.probe_entry()?;
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok(ProbeStats {
            attn_cos: lit_f32(&parts[0])?,
            ffn_cos: lit_f32(&parts[1])?,
            head_norm: lit_f32(&parts[2])?,
            chan_norm: lit_f32(&parts[3])?,
        })
    }

    /// Prompt pass for one sequence; returns (last-token logits, k, v)
    /// where k/v are `[L, 1, Hkv, S, Dh]` flattened host tensors.
    pub fn prefill(&mut self, seqlen: usize, tokens: &[i32],
                   mask: &PruneMask)
                   -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let entry = format!("prefill_t{seqlen}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok((lit_f32(&parts[0])?, lit_f32(&parts[1])?, lit_f32(&parts[2])?))
    }

    /// One decode step for a batch; caches are `[L, B, Hkv, S, Dh]`
    /// flattened and are replaced with the updated versions in place.
    pub fn decode(&mut self, batch: usize, tokens: &[i32], pos: &[i32],
                  k_cache: &mut Vec<f32>, v_cache: &mut Vec<f32>,
                  mask: &PruneMask) -> Result<Vec<f32>> {
        let entry = format!("decode_b{batch}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::I32(pos),
            HostArr::F32(k_cache),
            HostArr::F32(v_cache),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        let logits = lit_f32(&parts[0])?;
        *k_cache = lit_f32(&parts[1])?;
        *v_cache = lit_f32(&parts[2])?;
        Ok(logits)
    }

    /// Flattened element count of a decode cache for batch `b`.
    pub fn cache_elems(&self, batch: usize) -> usize {
        let m = &self.meta;
        m.n_layers * batch * m.n_kv_heads * m.max_seq * m.head_dim()
    }
}

fn validate_inputs(spec: &EntrySpec, inputs: &[HostArr]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{}: expected {} inputs, got {}", spec.name,
              spec.inputs.len(), inputs.len());
    }
    for (i, inp) in inputs.iter().enumerate() {
        let want = &spec.inputs[i];
        if inp.len() != want.elems() {
            bail!("{}: input '{}' has {} elements, wanted {} {:?}",
                  spec.name, want.name, inp.len(), want.elems(), want.shape);
        }
        if inp.dtype() != want.dtype {
            bail!("{}: input '{}' dtype mismatch", spec.name, want.name);
        }
    }
    Ok(())
}

/// Literal → Vec<f32>.
pub fn lit_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))
}

/// Decode little-endian bytes as f32 values.
fn f32_slice(raw: &[u8]) -> Result<Vec<f32>> {
    if raw.len() % 4 != 0 {
        bail!("byte length {} not divisible by 4", raw.len());
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Abstracts "evaluate the model's NLL under a mask" so that GSI, the RL
/// environment and the eval harness can run against either the real PJRT
/// runtime or a synthetic evaluator in unit tests.
pub trait NllEvaluator {
    fn meta(&self) -> &ModelMeta;
    /// Mean NLL of the calibration batch under `mask`.
    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64>;
}

/// Synthetic evaluator with controllable per-block damage — lets unit
/// tests exercise GSI/DQN logic without PJRT artifacts.
pub struct SyntheticEvaluator {
    pub meta: ModelMeta,
    pub base_nll: f64,
    /// Damage added per dropped block (index = BlockId::index).
    pub damage: Vec<f64>,
    /// Pairwise interaction added when both blocks of a layer are gone.
    pub layer_synergy: f64,
    pub evals: u64,
}

impl SyntheticEvaluator {
    pub fn new(meta: ModelMeta, base_nll: f64, damage: Vec<f64>,
               layer_synergy: f64) -> Self {
        assert_eq!(damage.len(), meta.n_blocks());
        SyntheticEvaluator { meta, base_nll, damage, layer_synergy,
                             evals: 0 }
    }
}

impl NllEvaluator for SyntheticEvaluator {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64> {
        self.evals += 1;
        let mut nll = self.base_nll;
        for b in mask.dropped_blocks() {
            nll += self.damage[b.index(self.meta.n_layers)];
        }
        for l in 0..self.meta.n_layers {
            if mask.block_dropped(crate::model_meta::BlockId::Mha(l))
                && mask.block_dropped(crate::model_meta::BlockId::Ffn(l))
            {
                nll += self.layer_synergy;
            }
        }
        Ok(nll)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::{BlockId, TensorSpec};

    fn synth() -> SyntheticEvaluator {
        let meta = ModelMeta::synthetic("t", 3, 64, 4, 2, 96, 128, 64);
        let damage = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        SyntheticEvaluator::new(meta, 2.0, damage, 1.0)
    }

    #[test]
    fn synthetic_evaluator_is_additive() {
        let mut ev = synth();
        let full = PruneMask::full(&ev.meta.clone());
        assert_eq!(ev.eval_nll(&full).unwrap(), 2.0);
        let m = full.with_block_dropped(BlockId::Mha(1));
        assert!((ev.eval_nll(&m).unwrap() - 2.2).abs() < 1e-12);
        let m2 = m.with_block_dropped(BlockId::Ffn(1));
        // 2.0 + 0.2 + 0.5 + synergy 1.0
        assert!((ev.eval_nll(&m2).unwrap() - 3.7).abs() < 1e-12);
        assert_eq!(ev.evals, 3);
    }

    #[test]
    fn host_arr_validation() {
        let spec = EntrySpec {
            name: "e".into(),
            file: "e.hlo.txt".into(),
            inputs: vec![TensorSpec {
                name: "x".into(),
                shape: vec![2, 3],
                dtype: DType::F32,
            }],
            outputs: vec![],
        };
        let ok = [HostArr::F32(&[0.0; 6])];
        assert!(validate_inputs(&spec, &ok).is_ok());
        let short = [HostArr::F32(&[0.0; 5])];
        assert!(validate_inputs(&spec, &short).is_err());
        let wrong_ty = [HostArr::I32(&[0; 6])];
        assert!(validate_inputs(&spec, &wrong_ty).is_err());
        assert!(validate_inputs(&spec, &[]).is_err());
    }

    #[test]
    fn f32_slice_roundtrip() {
        let xs = [1.5f32, -2.25, 0.0, f32::MAX];
        let bytes: Vec<u8> =
            xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(f32_slice(&bytes).unwrap(), xs);
        assert!(f32_slice(&bytes[..5]).is_err());
    }
}

//! Model runtime: a single `Runtime` facade over two interchangeable
//! backends —
//!
//!   * [`pjrt`]: loads the AOT artifacts and executes the compiled HLO
//!     through the `xla` bindings (the measured path; requires artifacts
//!     and a real PJRT build);
//!   * [`sim`]: a deterministic, artifact-free stand-in with an analytic
//!     cost model (the path CI, unit tests, and the fleet coordinator
//!     run on — see `sim.rs` for exactly what it does and does not
//!     model).
//!
//! Everything downstream (engine, controller, GSI, experiments) talks to
//! `Runtime`'s typed entry points and cannot tell the backends apart,
//! except through [`Runtime::last_cost`]: the sim backend reports the
//! modeled duration of each call there, and the serving engine advances
//! its simulated clock by that instead of wall time.

use std::collections::HashMap;

use anyhow::{bail, Result};
use xla::Literal;

pub mod pjrt;
pub mod sim;

pub use sim::{FaultEvent, FaultPlan};

use crate::mask::PruneMask;
use crate::model_meta::{DType, EntrySpec, ModelMeta};

/// A host-side input tensor handed to `Runtime::execute`.
pub enum HostArr<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostArr<'_> {
    fn len(&self) -> usize {
        match self {
            HostArr::F32(v) => v.len(),
            HostArr::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            HostArr::F32(_) => DType::F32,
            HostArr::I32(_) => DType::I32,
        }
    }
}

/// Per-entry execution statistics (drives the §Perf analysis + Fig 11).
/// For the sim backend, `total_secs` accumulates *modeled* seconds.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Block-redundancy statistics from the `probe` entry (consumed by the
/// baseline pruners).
#[derive(Clone, Debug)]
pub struct ProbeStats {
    /// cos(x, x + attn(x)) per layer — high = MHA block redundant.
    pub attn_cos: Vec<f32>,
    /// cos(x, x + ffn(x)) per layer — high = FFN block redundant.
    pub ffn_cos: Vec<f32>,
    /// mean per-head output norm [L, H] — low = head prunable.
    pub head_norm: Vec<f32>,
    /// mean per-channel activation magnitude [L, F] — low = channel prunable.
    pub chan_norm: Vec<f32>,
}

enum Backend {
    Pjrt(pjrt::PjrtRuntime),
    Sim(sim::SimRuntime),
}

pub struct Runtime {
    backend: Backend,
    stats: HashMap<String, ExecStats>,
    /// Modeled duration of the most recent typed call (sim backend only).
    last_cost: Option<f64>,
}

impl Runtime {
    /// Load weights + manifest for `model` under `artifacts_root` on the
    /// PJRT backend. Entries compile lazily on first use.
    pub fn load(artifacts_root: &std::path::Path, model: &str)
                -> Result<Runtime> {
        Ok(Runtime {
            backend: Backend::Pjrt(pjrt::PjrtRuntime::load(artifacts_root,
                                                           model)?),
            stats: HashMap::new(),
            last_cost: None,
        })
    }

    /// An artifact-free runtime on the sim backend (deterministic per
    /// seed). Used by unit tests and the fleet coordinator.
    pub fn synthetic(meta: ModelMeta, seed: u64) -> Runtime {
        Runtime::synthetic_with(meta, seed, sim::SimConfig::default())
    }

    /// Sim backend with explicit device characteristics (heterogeneous
    /// fleet replicas get different throughputs).
    pub fn synthetic_with(meta: ModelMeta, seed: u64, cfg: sim::SimConfig)
                          -> Runtime {
        Runtime {
            backend: Backend::Sim(sim::SimRuntime::new(meta, seed, cfg)),
            stats: HashMap::new(),
            last_cost: None,
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self.backend, Backend::Sim(_))
    }

    pub fn meta(&self) -> &ModelMeta {
        match &self.backend {
            Backend::Pjrt(p) => &p.meta,
            Backend::Sim(s) => &s.meta,
        }
    }

    pub fn stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }

    /// Total wall-clock (PJRT) or modeled (sim) seconds spent executing.
    pub fn total_exec_secs(&self) -> f64 {
        self.stats.values().map(|s| s.total_secs).sum()
    }

    /// Modeled duration of the most recent typed call. `Some` on the sim
    /// backend; `None` on PJRT (callers should fall back to measured wall
    /// time — see `engine::Engine`).
    pub fn last_cost(&self) -> Option<f64> {
        self.last_cost
    }

    fn note_sim(&mut self, entry: String, cost: f64) {
        let st = self.stats.entry(entry).or_default();
        st.calls += 1;
        st.total_secs += cost;
        self.last_cost = Some(cost);
    }

    /// Pre-compile a set of entries (the serving engine does this at
    /// startup so the hot path never hits the compiler). No-op on sim.
    pub fn warmup(&mut self, entries: &[&str]) -> Result<()> {
        if let Backend::Pjrt(p) = &mut self.backend {
            for e in entries {
                let cs = p.ensure_compiled(e)?;
                self.stats.entry((*e).to_string()).or_default()
                    .compile_secs += cs;
            }
        }
        Ok(())
    }

    /// Execute a raw PJRT entry with the given runtime inputs (weights
    /// are prepended automatically). Returns the output tuple elements.
    /// PJRT backend only — the sim backend has no compiled entries.
    pub fn execute(&mut self, entry: &str, inputs: &[HostArr])
                   -> Result<Vec<Literal>> {
        match &mut self.backend {
            Backend::Pjrt(p) => {
                let (parts, exec_secs, compile_secs) =
                    p.execute(entry, inputs)?;
                let st = self.stats.entry(entry.to_string()).or_default();
                st.calls += 1;
                st.total_secs += exec_secs;
                st.compile_secs += compile_secs;
                self.last_cost = None;
                Ok(parts)
            }
            Backend::Sim(_) => {
                bail!("raw entry execution ('{entry}') requires the PJRT \
                       backend")
            }
        }
    }

    // ---- typed entry points -------------------------------------------

    /// Masked-NLL scoring: returns (per_seq_nll, per_seq_cnt).
    pub fn score(&mut self, batch: usize, seqlen: usize, tokens: &[i32],
                 loss_mask: &[f32], mask: &PruneMask)
                 -> Result<(Vec<f32>, Vec<f32>)> {
        if tokens.len() != batch * seqlen
            || loss_mask.len() != batch * seqlen
        {
            bail!("score: tokens/loss_mask must be batch*seqlen = {}",
                  batch * seqlen);
        }
        let sim_out = match &mut self.backend {
            Backend::Sim(s) => Some(s.score(batch, seqlen, loss_mask, mask)),
            Backend::Pjrt(_) => None,
        };
        if let Some((nll, cnt, cost)) = sim_out {
            self.note_sim(format!("sim_score_b{batch}"), cost);
            return Ok((nll, cnt));
        }
        let entry = format!("score_b{batch}_t{seqlen}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(loss_mask),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok((lit_f32(&parts[0])?, lit_f32(&parts[1])?))
    }

    /// Mean NLL over a token batch with an all-ones loss mask — the
    /// perplexity primitive (exp of this is PPL).
    pub fn mean_nll(&mut self, batch: usize, seqlen: usize, tokens: &[i32],
                    mask: &PruneMask) -> Result<f64> {
        let ones = vec![1.0f32; batch * seqlen];
        let (nll, cnt) = self.score(batch, seqlen, tokens, &ones, mask)?;
        let total: f64 = nll.iter().map(|&x| x as f64).sum();
        let n: f64 = cnt.iter().map(|&x| x as f64).sum();
        Ok(total / n.max(1.0))
    }

    /// The compiled probe entry (models probe at min(128, max_seq)). On
    /// the sim backend a synthetic descriptor is returned.
    pub fn probe_entry(&self) -> Result<(String, usize, usize)> {
        match &self.backend {
            Backend::Sim(s) => {
                Ok(("sim_probe".to_string(), 4, s.meta.max_seq.min(128)))
            }
            Backend::Pjrt(p) => {
                let e = p
                    .meta
                    .entries
                    .iter()
                    .find(|e| e.name.starts_with("probe_"))
                    .ok_or_else(|| {
                        anyhow::anyhow!("no probe entry compiled")
                    })?;
                let shape = &e.inputs[0].shape; // tokens [B, T]
                Ok((e.name.clone(), shape[0], shape[1]))
            }
        }
    }

    /// Block-redundancy probe (batch/seqlen from the compiled bucket —
    /// see `probe_entry`).
    pub fn probe(&mut self, tokens: &[i32], mask: &PruneMask)
                 -> Result<ProbeStats> {
        let sim_out = match &mut self.backend {
            Backend::Sim(s) => Some(s.probe(mask)),
            Backend::Pjrt(_) => None,
        };
        if let Some((attn_cos, ffn_cos, head_norm, chan_norm, cost)) =
            sim_out
        {
            self.note_sim("sim_probe".to_string(), cost);
            return Ok(ProbeStats { attn_cos, ffn_cos, head_norm,
                                   chan_norm });
        }
        let (entry, _, _) = self.probe_entry()?;
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok(ProbeStats {
            attn_cos: lit_f32(&parts[0])?,
            ffn_cos: lit_f32(&parts[1])?,
            head_norm: lit_f32(&parts[2])?,
            chan_norm: lit_f32(&parts[3])?,
        })
    }

    /// Prompt pass for one sequence; returns (last-token logits, k, v)
    /// where k/v are `[L, 1, Hkv, S, Dh]` flattened host tensors.
    pub fn prefill(&mut self, seqlen: usize, tokens: &[i32],
                   mask: &PruneMask)
                   -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let sim_out = match &mut self.backend {
            Backend::Sim(s) => Some(s.prefill(seqlen, tokens, mask)),
            Backend::Pjrt(_) => None,
        };
        if let Some((logits, k, v, cost)) = sim_out {
            self.note_sim(format!("sim_prefill_t{seqlen}"), cost);
            return Ok((logits, k, v));
        }
        let entry = format!("prefill_t{seqlen}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        Ok((lit_f32(&parts[0])?, lit_f32(&parts[1])?, lit_f32(&parts[2])?))
    }

    /// One decode step for a batch; caches are `[L, B, Hkv, S, Dh]`
    /// flattened and are replaced with the updated versions in place.
    pub fn decode(&mut self, batch: usize, tokens: &[i32], pos: &[i32],
                  k_cache: &mut Vec<f32>, v_cache: &mut Vec<f32>,
                  mask: &PruneMask) -> Result<Vec<f32>> {
        let sim_out = match &mut self.backend {
            Backend::Sim(s) => {
                if tokens.len() != batch || pos.len() != batch {
                    bail!("decode: tokens/pos must have batch = {batch} \
                           entries");
                }
                Some(s.decode(batch, tokens, pos, mask))
            }
            Backend::Pjrt(_) => None,
        };
        if let Some((logits, cost)) = sim_out {
            // Sim caches are contentless: leave k_cache/v_cache as-is.
            self.note_sim(format!("sim_decode_b{batch}"), cost);
            return Ok(logits);
        }
        let entry = format!("decode_b{batch}");
        let parts = self.execute(&entry, &[
            HostArr::I32(tokens),
            HostArr::I32(pos),
            HostArr::F32(k_cache),
            HostArr::F32(v_cache),
            HostArr::F32(&mask.head_gate),
            HostArr::F32(&mask.ffn_gate),
        ])?;
        let logits = lit_f32(&parts[0])?;
        *k_cache = lit_f32(&parts[1])?;
        *v_cache = lit_f32(&parts[2])?;
        Ok(logits)
    }

    /// Modeled duration of migrating `bytes` of sequence state to a
    /// peer replica. The sim backend prices it with its interconnect
    /// model; PJRT has no modeled interconnect, so a default-configured
    /// model is used there — fleet logic stays backend-agnostic.
    pub fn transfer_cost(&self, bytes: usize) -> f64 {
        match &self.backend {
            Backend::Sim(s) => s.transfer_cost(bytes),
            Backend::Pjrt(_) => {
                let d = sim::SimConfig::default();
                d.migration_latency_secs
                    + bytes as f64 / d.link_bytes_per_sec
            }
        }
    }

    /// Streaming variant of [`Runtime::transfer_cost`]: bytes-only
    /// pricing with no per-transfer setup latency, for checkpoint
    /// deltas that ride an always-open replication stream.
    pub fn stream_cost(&self, bytes: usize) -> f64 {
        match &self.backend {
            Backend::Sim(s) => s.stream_cost(bytes),
            Backend::Pjrt(_) => {
                bytes as f64 / sim::SimConfig::default().link_bytes_per_sec
            }
        }
    }

    /// Flattened element count of a decode cache for batch `b`.
    pub fn cache_elems(&self, batch: usize) -> usize {
        let m = self.meta();
        m.n_layers * batch * m.n_kv_heads * m.max_seq * m.head_dim()
    }
}

pub(crate) fn validate_inputs(spec: &EntrySpec, inputs: &[HostArr])
                              -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{}: expected {} inputs, got {}", spec.name,
              spec.inputs.len(), inputs.len());
    }
    for (i, inp) in inputs.iter().enumerate() {
        let want = &spec.inputs[i];
        if inp.len() != want.elems() {
            bail!("{}: input '{}' has {} elements, wanted {} {:?}",
                  spec.name, want.name, inp.len(), want.elems(), want.shape);
        }
        if inp.dtype() != want.dtype {
            bail!("{}: input '{}' dtype mismatch", spec.name, want.name);
        }
    }
    Ok(())
}

/// Literal → Vec<f32>.
pub fn lit_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))
}

/// Abstracts "evaluate the model's NLL under a mask" so that GSI, the RL
/// environment and the eval harness can run against either the real PJRT
/// runtime or a synthetic evaluator in unit tests.
pub trait NllEvaluator {
    fn meta(&self) -> &ModelMeta;
    /// Mean NLL of the calibration batch under `mask`.
    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64>;
}

/// Synthetic evaluator with controllable per-block damage — lets unit
/// tests exercise GSI/DQN logic without PJRT artifacts.
pub struct SyntheticEvaluator {
    pub meta: ModelMeta,
    pub base_nll: f64,
    /// Damage added per dropped block (index = BlockId::index).
    pub damage: Vec<f64>,
    /// Pairwise interaction added when both blocks of a layer are gone.
    pub layer_synergy: f64,
    pub evals: u64,
}

impl SyntheticEvaluator {
    pub fn new(meta: ModelMeta, base_nll: f64, damage: Vec<f64>,
               layer_synergy: f64) -> Self {
        assert_eq!(damage.len(), meta.n_blocks());
        SyntheticEvaluator { meta, base_nll, damage, layer_synergy,
                             evals: 0 }
    }
}

impl NllEvaluator for SyntheticEvaluator {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64> {
        self.evals += 1;
        let mut nll = self.base_nll;
        for b in mask.dropped_blocks() {
            nll += self.damage[b.index(self.meta.n_layers)];
        }
        for l in 0..self.meta.n_layers {
            if mask.block_dropped(crate::model_meta::BlockId::Mha(l))
                && mask.block_dropped(crate::model_meta::BlockId::Ffn(l))
            {
                nll += self.layer_synergy;
            }
        }
        Ok(nll)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::{BlockId, TensorSpec};

    fn synth() -> SyntheticEvaluator {
        let meta = ModelMeta::synthetic("t", 3, 64, 4, 2, 96, 128, 64);
        let damage = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        SyntheticEvaluator::new(meta, 2.0, damage, 1.0)
    }

    #[test]
    fn synthetic_evaluator_is_additive() {
        let mut ev = synth();
        let full = PruneMask::full(&ev.meta.clone());
        assert_eq!(ev.eval_nll(&full).unwrap(), 2.0);
        let m = full.with_block_dropped(BlockId::Mha(1));
        assert!((ev.eval_nll(&m).unwrap() - 2.2).abs() < 1e-12);
        let m2 = m.with_block_dropped(BlockId::Ffn(1));
        // 2.0 + 0.2 + 0.5 + synergy 1.0
        assert!((ev.eval_nll(&m2).unwrap() - 3.7).abs() < 1e-12);
        assert_eq!(ev.evals, 3);
    }

    #[test]
    fn host_arr_validation() {
        let spec = EntrySpec {
            name: "e".into(),
            file: "e.hlo.txt".into(),
            inputs: vec![TensorSpec {
                name: "x".into(),
                shape: vec![2, 3],
                dtype: DType::F32,
            }],
            outputs: vec![],
        };
        let ok = [HostArr::F32(&[0.0; 6])];
        assert!(validate_inputs(&spec, &ok).is_ok());
        let short = [HostArr::F32(&[0.0; 5])];
        assert!(validate_inputs(&spec, &short).is_err());
        let wrong_ty = [HostArr::I32(&[0; 6])];
        assert!(validate_inputs(&spec, &wrong_ty).is_err());
        assert!(validate_inputs(&spec, &[]).is_err());
    }

    // ---- sim-backend facade behavior ----------------------------------

    fn sim_rt() -> Runtime {
        Runtime::synthetic(
            ModelMeta::synthetic("s", 4, 128, 8, 4, 512, 512, 256), 42)
    }

    #[test]
    fn sim_runtime_scores_and_reports_cost() {
        let mut rt = sim_rt();
        assert!(rt.is_sim());
        let full = PruneMask::full(&rt.meta().clone());
        let tokens = vec![0i32; 128];
        let dense = rt.mean_nll(1, 128, &tokens, &full).unwrap();
        assert!(rt.last_cost().unwrap() > 0.0);
        let pruned = full.with_block_dropped(BlockId::Ffn(2));
        let worse = rt.mean_nll(1, 128, &tokens, &pruned).unwrap();
        assert!(worse > dense);
        assert!(rt.total_exec_secs() > 0.0);
    }

    #[test]
    fn sim_runtime_prefill_decode_shapes() {
        let mut rt = sim_rt();
        let meta = rt.meta().clone();
        let full = PruneMask::full(&meta);
        let tokens = vec![1i32; 32];
        let (logits, k, v) = rt.prefill(32, &tokens, &full).unwrap();
        assert_eq!(logits.len(), meta.vocab);
        assert_eq!(k.len(), rt.cache_elems(1));
        assert_eq!(v.len(), rt.cache_elems(1));
        let mut k = vec![0.0; rt.cache_elems(2)];
        let mut v = vec![0.0; rt.cache_elems(2)];
        let lg = rt.decode(2, &[3, 4], &[9, 9], &mut k, &mut v, &full)
            .unwrap();
        assert_eq!(lg.len(), 2 * meta.vocab);
        // identical inputs → identical logits (determinism)
        let lg2 = rt.decode(2, &[3, 4], &[9, 9], &mut k, &mut v, &full)
            .unwrap();
        assert_eq!(lg, lg2);
    }

    #[test]
    fn sim_runtime_rejects_raw_execute() {
        let mut rt = sim_rt();
        assert!(rt.execute("score_b1_t128", &[]).is_err());
        assert!(rt.warmup(&["anything"]).is_ok()); // warmup is a no-op
    }
}

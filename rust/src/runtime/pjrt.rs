//! PJRT backend: loads the AOT artifacts and executes them through the
//! `xla` bindings. This is the only file in the crate that touches `xla`.
//! It follows the load_hlo pattern: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! Performance notes (§Perf):
//!   * weights are uploaded to the device ONCE as `PjRtBuffer`s and reused
//!     by every call via `execute_b` — without this every score/decode call
//!     would re-copy ~50 MB of parameters;
//!   * executables are compiled lazily per entry and cached;
//!   * PJRT (through this wrapper) returns one tuple buffer per execution,
//!     so multi-output results round-trip the host; KV caches therefore
//!     live host-side between decode steps (measured in EXPERIMENTS.md
//!     §Perf).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::HostArr;
use crate::model_meta::ModelMeta;

pub struct PjrtRuntime {
    pub meta: ModelMeta,
    client: PjRtClient,
    /// Device-resident weight buffers, `param_specs` order.
    weights: Vec<PjRtBuffer>,
    exes: HashMap<String, PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Load weights + manifest for `model` under `artifacts_root` and
    /// create a CPU PJRT client. Entries compile lazily on first use.
    pub fn load(artifacts_root: &Path, model: &str) -> Result<PjrtRuntime> {
        let meta = ModelMeta::load(&artifacts_root.join(model))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let bytes = std::fs::read(meta.dir.join("weights.bin"))
            .context("reading weights.bin")?;
        let mut weights = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let end = p.offset + p.nbytes;
            if end > bytes.len() {
                bail!("weights.bin too short for {}", p.name);
            }
            let data = f32_slice(&bytes[p.offset..end])?;
            weights.push(
                client
                    .buffer_from_host_buffer(&data, &p.shape, None)
                    .map_err(|e| anyhow::anyhow!(
                        "uploading {}: {e:?}", p.name))?,
            );
        }
        Ok(PjrtRuntime { client, meta, weights, exes: HashMap::new() })
    }

    /// Compile `entry` if needed; returns the compile seconds spent
    /// (0.0 when already cached).
    pub fn ensure_compiled(&mut self, entry: &str) -> Result<f64> {
        if self.exes.contains_key(entry) {
            return Ok(0.0);
        }
        let spec = self.meta.entry(entry)?.clone();
        let path = self.meta.dir.join(&spec.file);
        #[allow(clippy::disallowed_methods)]
        // lint:allow(wall-clock): real device compile time IS the
        // measurement here; PJRT never runs under the sim clock
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}",
                                         path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {entry}: {e:?}"))?;
        self.exes.insert(entry.to_string(), exe);
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Execute `entry` with the given runtime inputs (weights are
    /// prepended automatically). Returns the output tuple elements plus
    /// (exec_secs, compile_secs).
    pub fn execute(&mut self, entry: &str, inputs: &[HostArr])
                   -> Result<(Vec<Literal>, f64, f64)> {
        let compile_secs = self.ensure_compiled(entry)?;
        let spec = self.meta.entry(entry)?.clone();
        super::validate_inputs(&spec, inputs)?;

        // Upload runtime inputs as device buffers.
        let mut owned: Vec<PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (i, inp) in inputs.iter().enumerate() {
            let shape = &spec.inputs[i].shape;
            let buf = match inp {
                HostArr::F32(v) => {
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
                HostArr::I32(v) => {
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
            }
            .map_err(|e| anyhow::anyhow!(
                "uploading input {} of {entry}: {e:?}",
                spec.inputs[i].name))?;
            owned.push(buf);
        }
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.extend(owned.iter());

        let exe = self.exes.get(entry).unwrap();
        #[allow(clippy::disallowed_methods)]
        // lint:allow(wall-clock): real device execute time IS the
        // measurement here; PJRT never runs under the sim clock
        let t0 = Instant::now();
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("executing {entry}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {entry} result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {entry}: {e:?}"))?;
        let exec_secs = t0.elapsed().as_secs_f64();
        if parts.len() != spec.outputs.len() {
            bail!("{entry}: expected {} outputs, got {}",
                  spec.outputs.len(), parts.len());
        }
        Ok((parts, exec_secs, compile_secs))
    }
}

/// Decode little-endian bytes as f32 values.
fn f32_slice(raw: &[u8]) -> Result<Vec<f32>> {
    if raw.len() % 4 != 0 {
        bail!("byte length {} not divisible by 4", raw.len());
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_slice_roundtrip() {
        let xs = [1.5f32, -2.25, 0.0, f32::MAX];
        let bytes: Vec<u8> =
            xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(f32_slice(&bytes).unwrap(), xs);
        assert!(f32_slice(&bytes[..5]).is_err());
    }
}

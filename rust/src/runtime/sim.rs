//! Simulation backend: a deterministic, artifact-free stand-in for the
//! PJRT executables with an *analytic cost model*, so serving-layer and
//! fleet experiments run anywhere (CI, the offline image, unit tests)
//! with realistic relative timings.
//!
//! What it models, and what it does not:
//!   * **NLL under a mask** — additive per-block damage plus a per-layer
//!     synergy when both blocks of a layer are gone (same family as
//!     `SyntheticEvaluator`, but seeded per instance so replicas can
//!     disagree about block importance). GSI and the DQN controller run
//!     unmodified against it.
//!   * **Step cost** — every call reports a virtual duration derived from
//!     active parameters × tokens ÷ device throughput, so a pruned mask
//!     really is proportionally faster and a slow replica really is
//!     slower. The serving engine advances its simulated clock by this
//!     cost instead of the (meaningless) wall time of the stub math.
//!   * **Logits** — a deterministic one-hot spike derived from hashing
//!     the inputs: enough for the engine's argmax sampling to be
//!     reproducible, with no pretense of being a language model.

use crate::mask::PruneMask;
use crate::model_meta::{BlockId, ModelMeta};
use crate::util::rng::Rng;

/// Modeled device characteristics for one sim instance. Heterogeneous
/// fleet replicas get different `flops_per_sec`.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Modeled sustained throughput (FLOP/s) of the device.
    pub flops_per_sec: f64,
    /// Fixed per-call launch overhead (seconds).
    pub base_overhead_secs: f64,
    /// NLL of the dense model on the synthetic calibration stream.
    pub base_nll: f64,
    /// Extra NLL when both blocks of one layer are dropped.
    pub layer_synergy: f64,
    /// Modeled cross-replica interconnect bandwidth (bytes/s) — prices
    /// in-flight sequence migration between fleet replicas.
    pub link_bytes_per_sec: f64,
    /// Fixed per-migration latency (seconds): connection setup plus the
    /// destination's cache registration.
    pub migration_latency_secs: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            flops_per_sec: 2.0e9,
            base_overhead_secs: 2.0e-4,
            base_nll: 2.0,
            layer_synergy: 0.75,
            link_bytes_per_sec: 4.0e9,
            migration_latency_secs: 0.02,
        }
    }
}

/// One scheduled fault in a [`FaultPlan`]. Times are shared-clock sim
/// seconds; replica indices refer to the coordinator's replica vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// Replica `replica` dies at `at`: every resident KV cache, queued
    /// request, and parked state on it is destroyed.
    Crash { at: f64, replica: usize },
    /// Interconnect degradation over `[from, until)`: transfers started
    /// inside the window take `factor`× their modeled duration.
    Degrade { from: f64, until: f64, factor: f64 },
    /// Full interconnect partition over `[from, until)`: no transfer
    /// can complete inside the window — deliveries retry with bounded
    /// backoff and eventually fail back to a local requeue.
    Partition { from: f64, until: f64 },
    /// Spot-capacity reclaim at `at`: the replica gets `grace_secs` to
    /// drain through the Park/migrate path, then is forcibly killed if
    /// work remains.
    Reclaim { at: f64, replica: usize, grace_secs: f64 },
    /// Device-memory pressure cliff over `[from, until)`: co-running
    /// interference suddenly holds `frac` of the device capacity
    /// (drives `Sys_avail(t)` through the monitor's walls mechanism).
    Pressure { from: f64, until: f64, frac: f64 },
}

impl FaultEvent {
    /// When the event first takes effect (the plan sorts by this).
    pub fn start(&self) -> f64 {
        match *self {
            FaultEvent::Crash { at, .. } => at,
            FaultEvent::Degrade { from, .. } => from,
            FaultEvent::Partition { from, .. } => from,
            FaultEvent::Reclaim { at, .. } => at,
            FaultEvent::Pressure { from, .. } => from,
        }
    }

    /// Audit label for the telemetry event stream, e.g.
    /// `crash replica 1 at 14s`.
    pub fn describe(&self) -> String {
        match *self {
            FaultEvent::Crash { at, replica } => {
                format!("crash replica {replica} at {at}s")
            }
            FaultEvent::Degrade { from, until, factor } => {
                format!("degrade link x{factor} over [{from}s, \
                         {until}s)")
            }
            FaultEvent::Partition { from, until } => {
                format!("partition link over [{from}s, {until}s)")
            }
            FaultEvent::Reclaim { at, replica, grace_secs } => {
                format!("reclaim replica {replica} at {at}s (grace \
                         {grace_secs}s)")
            }
            FaultEvent::Pressure { from, until, frac } => {
                format!("pressure {frac} of capacity over [{from}s, \
                         {until}s)")
            }
        }
    }
}

/// A seeded, deterministic schedule of failure events for one run. The
/// plan is data, not behavior: the fleet coordinator applies crash and
/// reclaim events as its clock passes them, and consults
/// [`FaultPlan::link_factor`] when pricing or delivering transfers.
/// Engine-level tests feed the pressure events straight into a
/// [`MemoryMonitor`](crate::server::memmon::MemoryMonitor) via
/// `MemoryMonitor::with_faults` — no fleet required.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Events sorted by start time (ties keep insertion order).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by(|a, b| a.start().total_cmp(&b.start()));
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// A seeded storm sized to `horizon` seconds over `replicas`
    /// replicas: one crash mid-run, one degradation window, one full
    /// partition, and (fleets of 2+) a spot reclaim of a different
    /// replica with a few seconds of grace. Deterministic per seed.
    pub fn seeded(seed: u64, horizon: f64, replicas: usize) -> FaultPlan {
        if replicas == 0 || horizon <= 0.0 {
            return FaultPlan::default();
        }
        let mut rng = Rng::new(seed ^ 0xFA_17_BAD);
        let victim = rng.below(replicas);
        let mut events = vec![FaultEvent::Crash {
            at: horizon * (0.25 + 0.2 * rng.f64()),
            replica: victim,
        }];
        let dg_from = horizon * (0.15 + 0.15 * rng.f64());
        events.push(FaultEvent::Degrade {
            from: dg_from,
            until: dg_from + horizon * (0.15 + 0.1 * rng.f64()),
            factor: 2.0 + 4.0 * rng.f64(),
        });
        let pt_from = horizon * (0.35 + 0.15 * rng.f64());
        events.push(FaultEvent::Partition {
            from: pt_from,
            until: pt_from + horizon * (0.05 + 0.08 * rng.f64()),
        });
        if replicas > 1 {
            let other =
                (victim + 1 + rng.below(replicas - 1)) % replicas;
            events.push(FaultEvent::Reclaim {
                at: horizon * (0.55 + 0.15 * rng.f64()),
                replica: other,
                grace_secs: 3.0 + 4.0 * rng.f64(),
            });
        }
        FaultPlan::new(events)
    }

    /// State of the interconnect at `t`: `None` while a partition
    /// window covers `t` (nothing can be delivered), otherwise the
    /// product of all active degradation factors (1.0 on a healthy
    /// link) to scale a transfer's duration by.
    pub fn link_factor(&self, t: f64) -> Option<f64> {
        let mut factor = 1.0;
        for ev in &self.events {
            match *ev {
                FaultEvent::Partition { from, until }
                    if t >= from && t < until =>
                {
                    return None;
                }
                FaultEvent::Degrade { from, until, factor: f }
                    if t >= from && t < until =>
                {
                    factor *= f;
                }
                _ => {}
            }
        }
        Some(factor)
    }

    /// The plan's pressure cliffs as `(start, end, bytes)` interference
    /// spans against a device of `capacity` bytes — the
    /// `MemoryMonitor::with_spans` wire format.
    pub fn pressure_spans(&self, capacity: usize)
                          -> Vec<(f64, f64, usize)> {
        self.events
            .iter()
            .filter_map(|ev| match *ev {
                FaultEvent::Pressure { from, until, frac } => {
                    Some((from, until,
                          (capacity as f64 * frac) as usize))
                }
                _ => None,
            })
            .collect()
    }
}

pub struct SimRuntime {
    pub meta: ModelMeta,
    pub cfg: SimConfig,
    /// NLL damage per dropped block (index = `BlockId::index`).
    damage: Vec<f64>,
}

impl SimRuntime {
    pub fn new(meta: ModelMeta, seed: u64, cfg: SimConfig) -> SimRuntime {
        let mut rng = Rng::new(seed ^ 0x51D_BAD_CAFE);
        let damage = (0..meta.n_blocks())
            .map(|_| {
                let r = rng.f64();
                0.02 + 0.4 * r * r
            })
            .collect();
        SimRuntime { meta, cfg, damage }
    }

    /// Active (unpruned) parameter count under `mask`.
    fn active_params(&self, mask: &PruneMask) -> f64 {
        mask.param_fraction(&self.meta) * self.meta.total_params() as f64
    }

    /// Virtual duration of a forward over `batch` sequences × `tokens`
    /// tokens each: 2 FLOPs per active parameter per token.
    pub fn cost(&self, mask: &PruneMask, batch: usize, tokens: usize) -> f64 {
        self.cfg.base_overhead_secs
            + 2.0 * self.active_params(mask) * (batch * tokens) as f64
                / self.cfg.flops_per_sec
    }

    /// Virtual duration of moving `bytes` of sequence state to a peer
    /// replica over the modeled interconnect (fleet migration).
    pub fn transfer_cost(&self, bytes: usize) -> f64 {
        self.cfg.migration_latency_secs
            + bytes as f64 / self.cfg.link_bytes_per_sec
    }

    /// Virtual duration of streaming `bytes` over the interconnect
    /// with no per-transfer setup latency: periodic checkpoint deltas
    /// ride an always-open replication stream, so only the bytes are
    /// charged (discrete migrations pay `transfer_cost`).
    pub fn stream_cost(&self, bytes: usize) -> f64 {
        bytes as f64 / self.cfg.link_bytes_per_sec
    }

    /// Modeled mean NLL under `mask` (additive damage + layer synergy).
    pub fn nll(&self, mask: &PruneMask) -> f64 {
        let mut nll = self.cfg.base_nll;
        for b in mask.dropped_blocks() {
            nll += self.damage[b.index(self.meta.n_layers)];
        }
        for l in 0..self.meta.n_layers {
            if mask.block_dropped(BlockId::Mha(l))
                && mask.block_dropped(BlockId::Ffn(l))
            {
                nll += self.cfg.layer_synergy;
            }
        }
        nll
    }

    /// Per-sequence (nll_sum, token_count) pair per the score entry's
    /// contract: `mean_nll` recovers exactly `self.nll(mask)`.
    pub fn score(&self, batch: usize, seqlen: usize, loss_mask: &[f32],
                 mask: &PruneMask) -> (Vec<f32>, Vec<f32>, f64) {
        let n = batch * seqlen;
        let nll = self.nll(mask) as f32;
        let mut cnt = vec![0.0f32; batch];
        for (i, &m) in loss_mask.iter().take(n).enumerate() {
            cnt[i / seqlen] += m;
        }
        let per_seq: Vec<f32> = cnt.iter().map(|c| nll * c).collect();
        (per_seq, cnt, self.cost(mask, batch, seqlen))
    }

    /// Prompt pass: one-hot logits + zeroed per-sequence caches of the
    /// exact shapes the KV manager expects.
    pub fn prefill(&self, seqlen: usize, tokens: &[i32], mask: &PruneMask)
                   -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let m = &self.meta;
        let elems = m.n_layers * m.n_kv_heads * m.max_seq * m.head_dim();
        let mut h = fnv(0x9E3779B9);
        for &t in tokens.iter().take(8) {
            h = fnv(h ^ t as u64);
        }
        let logits = spike(m.vocab, 1, h ^ mask.key());
        (logits, vec![0.0; elems], vec![0.0; elems],
         self.cost(mask, 1, seqlen))
    }

    /// One decode step: one-hot logits per batch row; caches untouched
    /// (the zeroed contents carry no information worth updating).
    pub fn decode(&self, batch: usize, tokens: &[i32], pos: &[i32],
                  mask: &PruneMask) -> (Vec<f32>, f64) {
        let mut h = fnv(0xB10C ^ mask.key());
        for (&t, &p) in tokens.iter().zip(pos) {
            h = fnv(h ^ t as u64 ^ ((p as u64) << 32));
        }
        (spike(self.meta.vocab, batch, h), self.cost(mask, batch, 1))
    }

    /// Block-redundancy probe derived from the damage vector: low-damage
    /// blocks look redundant (high cosine), matching what the baseline
    /// pruners expect to consume.
    pub fn probe(&self, mask: &PruneMask) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let m = &self.meta;
        let red = |d: f64| (1.0 - d / 0.45).clamp(0.05, 0.98) as f32;
        let attn_cos: Vec<f32> = (0..m.n_layers)
            .map(|l| red(self.damage[BlockId::Mha(l).index(m.n_layers)]))
            .collect();
        let ffn_cos: Vec<f32> = (0..m.n_layers)
            .map(|l| red(self.damage[BlockId::Ffn(l).index(m.n_layers)]))
            .collect();
        let head_norm: Vec<f32> = (0..m.n_layers * m.n_heads)
            .map(|i| 0.5 + 0.5 * ((fnv(i as u64) >> 11) as f64
                / (1u64 << 53) as f64) as f32)
            .collect();
        let chan_norm: Vec<f32> = (0..m.n_layers * m.d_ff)
            .map(|i| 0.5 + 0.5 * ((fnv(0xFF ^ i as u64) >> 11) as f64
                / (1u64 << 53) as f64) as f32)
            .collect();
        let cost = self.cost(mask, 4, self.meta.max_seq.min(128));
        (attn_cos, ffn_cos, head_norm, chan_norm, cost)
    }
}

/// One-hot logits per row, spike position hashed from `salt` + row.
fn spike(vocab: usize, rows: usize, salt: u64) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * vocab];
    for r in 0..rows {
        let idx = (fnv(salt ^ r as u64) % vocab as u64) as usize;
        out[r * vocab + idx] = 1.0;
    }
    out
}

fn fnv(x: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for i in 0..8 {
        h ^= (x >> (8 * i)) & 0xFF;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimRuntime {
        let meta = ModelMeta::synthetic("s", 4, 128, 8, 4, 512, 512, 256);
        SimRuntime::new(meta, 42, SimConfig::default())
    }

    #[test]
    fn nll_grows_when_blocks_drop() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let dense = s.nll(&full);
        for b in s.meta.all_blocks() {
            assert!(s.nll(&full.with_block_dropped(b)) > dense, "{b}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let meta = ModelMeta::synthetic("s", 4, 128, 8, 4, 512, 512, 256);
        let a = SimRuntime::new(meta.clone(), 7, SimConfig::default());
        let b = SimRuntime::new(meta.clone(), 7, SimConfig::default());
        let c = SimRuntime::new(meta, 8, SimConfig::default());
        let full = PruneMask::full(&a.meta);
        let m = full.with_block_dropped(BlockId::Ffn(1));
        assert_eq!(a.nll(&m), b.nll(&m));
        assert_ne!(a.nll(&m), c.nll(&m));
    }

    #[test]
    fn pruned_masks_are_cheaper_and_slow_devices_slower() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let pruned = full.with_block_dropped(BlockId::Ffn(0));
        assert!(s.cost(&pruned, 8, 64) < s.cost(&full, 8, 64));
        let meta = s.meta.clone();
        let slow = SimRuntime::new(meta, 42, SimConfig {
            flops_per_sec: 1.0e9, ..SimConfig::default()
        });
        assert!(slow.cost(&full, 8, 64) > s.cost(&full, 8, 64));
    }

    #[test]
    fn transfer_cost_scales_with_payload() {
        let s = sim();
        let small = s.transfer_cost(1 << 10);
        let big = s.transfer_cost(1 << 26);
        assert!(small >= s.cfg.migration_latency_secs);
        assert!(big > small, "more bytes must cost more: {small} vs {big}");
        // an empty payload still pays the fixed latency
        assert_eq!(s.transfer_cost(0), s.cfg.migration_latency_secs);
    }

    #[test]
    fn fault_plan_is_sorted_and_deterministic_per_seed() {
        let a = FaultPlan::seeded(11, 40.0, 3);
        let b = FaultPlan::seeded(11, 40.0, 3);
        let c = FaultPlan::seeded(12, 40.0, 3);
        assert_eq!(a.events, b.events);
        assert_ne!(a.events, c.events);
        assert!(a.events.windows(2)
                 .all(|w| w[0].start() <= w[1].start()));
        // a 2+-replica storm reclaims a replica other than the crashed
        let crash = a.events.iter().find_map(|e| match *e {
            FaultEvent::Crash { replica, .. } => Some(replica),
            _ => None,
        });
        let reclaim = a.events.iter().find_map(|e| match *e {
            FaultEvent::Reclaim { replica, .. } => Some(replica),
            _ => None,
        });
        assert!(crash.is_some() && reclaim.is_some());
        assert_ne!(crash, reclaim);
        assert!(FaultPlan::seeded(1, 40.0, 0).is_empty());
    }

    #[test]
    fn link_factor_models_partition_and_degradation() {
        let plan = FaultPlan::new(vec![
            FaultEvent::Degrade { from: 5.0, until: 15.0, factor: 3.0 },
            FaultEvent::Degrade { from: 10.0, until: 20.0, factor: 2.0 },
            FaultEvent::Partition { from: 12.0, until: 14.0 },
        ]);
        assert_eq!(plan.link_factor(0.0), Some(1.0));
        assert_eq!(plan.link_factor(6.0), Some(3.0));
        assert_eq!(plan.link_factor(11.0), Some(6.0)); // both stack
        assert_eq!(plan.link_factor(13.0), None); // partitioned
        assert_eq!(plan.link_factor(14.0), Some(6.0)); // heals
        assert_eq!(plan.link_factor(25.0), Some(1.0));
    }

    #[test]
    fn pressure_spans_scale_to_capacity() {
        let plan = FaultPlan::new(vec![
            FaultEvent::Pressure { from: 2.0, until: 8.0, frac: 0.5 },
            FaultEvent::Crash { at: 3.0, replica: 0 },
        ]);
        assert_eq!(plan.pressure_spans(1000), vec![(2.0, 8.0, 500)]);
    }

    #[test]
    fn score_recovers_model_nll() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let (b, t) = (2, 16);
        let ones = vec![1.0f32; b * t];
        let (nll, cnt, cost) = s.score(b, t, &ones, &full);
        let mean = nll.iter().map(|&x| x as f64).sum::<f64>()
            / cnt.iter().map(|&x| x as f64).sum::<f64>();
        assert!((mean - s.nll(&full)).abs() < 1e-5);
        assert!(cost > 0.0);
    }

    #[test]
    fn shapes_match_contract() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let (logits, k, v, _) = s.prefill(32, &[1, 2, 3], &full);
        assert_eq!(logits.len(), s.meta.vocab);
        let elems = s.meta.n_layers * s.meta.n_kv_heads * s.meta.max_seq
            * s.meta.head_dim();
        assert_eq!(k.len(), elems);
        assert_eq!(v.len(), elems);
        let (lg, _) = s.decode(4, &[1, 2, 3, 4], &[5, 5, 5, 5], &full);
        assert_eq!(lg.len(), 4 * s.meta.vocab);
        // exactly one spike per row
        for r in 0..4 {
            let row = &lg[r * s.meta.vocab..(r + 1) * s.meta.vocab];
            assert_eq!(row.iter().filter(|&&x| x != 0.0).count(), 1);
        }
    }
}

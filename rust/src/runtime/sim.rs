//! Simulation backend: a deterministic, artifact-free stand-in for the
//! PJRT executables with an *analytic cost model*, so serving-layer and
//! fleet experiments run anywhere (CI, the offline image, unit tests)
//! with realistic relative timings.
//!
//! What it models, and what it does not:
//!   * **NLL under a mask** — additive per-block damage plus a per-layer
//!     synergy when both blocks of a layer are gone (same family as
//!     `SyntheticEvaluator`, but seeded per instance so replicas can
//!     disagree about block importance). GSI and the DQN controller run
//!     unmodified against it.
//!   * **Step cost** — every call reports a virtual duration derived from
//!     active parameters × tokens ÷ device throughput, so a pruned mask
//!     really is proportionally faster and a slow replica really is
//!     slower. The serving engine advances its simulated clock by this
//!     cost instead of the (meaningless) wall time of the stub math.
//!   * **Logits** — a deterministic one-hot spike derived from hashing
//!     the inputs: enough for the engine's argmax sampling to be
//!     reproducible, with no pretense of being a language model.

use crate::mask::PruneMask;
use crate::model_meta::{BlockId, ModelMeta};
use crate::util::rng::Rng;

/// Modeled device characteristics for one sim instance. Heterogeneous
/// fleet replicas get different `flops_per_sec`.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Modeled sustained throughput (FLOP/s) of the device.
    pub flops_per_sec: f64,
    /// Fixed per-call launch overhead (seconds).
    pub base_overhead_secs: f64,
    /// NLL of the dense model on the synthetic calibration stream.
    pub base_nll: f64,
    /// Extra NLL when both blocks of one layer are dropped.
    pub layer_synergy: f64,
    /// Modeled cross-replica interconnect bandwidth (bytes/s) — prices
    /// in-flight sequence migration between fleet replicas.
    pub link_bytes_per_sec: f64,
    /// Fixed per-migration latency (seconds): connection setup plus the
    /// destination's cache registration.
    pub migration_latency_secs: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            flops_per_sec: 2.0e9,
            base_overhead_secs: 2.0e-4,
            base_nll: 2.0,
            layer_synergy: 0.75,
            link_bytes_per_sec: 4.0e9,
            migration_latency_secs: 0.02,
        }
    }
}

pub struct SimRuntime {
    pub meta: ModelMeta,
    pub cfg: SimConfig,
    /// NLL damage per dropped block (index = `BlockId::index`).
    damage: Vec<f64>,
}

impl SimRuntime {
    pub fn new(meta: ModelMeta, seed: u64, cfg: SimConfig) -> SimRuntime {
        let mut rng = Rng::new(seed ^ 0x51D_BAD_CAFE);
        let damage = (0..meta.n_blocks())
            .map(|_| {
                let r = rng.f64();
                0.02 + 0.4 * r * r
            })
            .collect();
        SimRuntime { meta, cfg, damage }
    }

    /// Active (unpruned) parameter count under `mask`.
    fn active_params(&self, mask: &PruneMask) -> f64 {
        mask.param_fraction(&self.meta) * self.meta.total_params() as f64
    }

    /// Virtual duration of a forward over `batch` sequences × `tokens`
    /// tokens each: 2 FLOPs per active parameter per token.
    pub fn cost(&self, mask: &PruneMask, batch: usize, tokens: usize) -> f64 {
        self.cfg.base_overhead_secs
            + 2.0 * self.active_params(mask) * (batch * tokens) as f64
                / self.cfg.flops_per_sec
    }

    /// Virtual duration of moving `bytes` of sequence state to a peer
    /// replica over the modeled interconnect (fleet migration).
    pub fn transfer_cost(&self, bytes: usize) -> f64 {
        self.cfg.migration_latency_secs
            + bytes as f64 / self.cfg.link_bytes_per_sec
    }

    /// Modeled mean NLL under `mask` (additive damage + layer synergy).
    pub fn nll(&self, mask: &PruneMask) -> f64 {
        let mut nll = self.cfg.base_nll;
        for b in mask.dropped_blocks() {
            nll += self.damage[b.index(self.meta.n_layers)];
        }
        for l in 0..self.meta.n_layers {
            if mask.block_dropped(BlockId::Mha(l))
                && mask.block_dropped(BlockId::Ffn(l))
            {
                nll += self.cfg.layer_synergy;
            }
        }
        nll
    }

    /// Per-sequence (nll_sum, token_count) pair per the score entry's
    /// contract: `mean_nll` recovers exactly `self.nll(mask)`.
    pub fn score(&self, batch: usize, seqlen: usize, loss_mask: &[f32],
                 mask: &PruneMask) -> (Vec<f32>, Vec<f32>, f64) {
        let n = batch * seqlen;
        let nll = self.nll(mask) as f32;
        let mut cnt = vec![0.0f32; batch];
        for (i, &m) in loss_mask.iter().take(n).enumerate() {
            cnt[i / seqlen] += m;
        }
        let per_seq: Vec<f32> = cnt.iter().map(|c| nll * c).collect();
        (per_seq, cnt, self.cost(mask, batch, seqlen))
    }

    /// Prompt pass: one-hot logits + zeroed per-sequence caches of the
    /// exact shapes the KV manager expects.
    pub fn prefill(&self, seqlen: usize, tokens: &[i32], mask: &PruneMask)
                   -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let m = &self.meta;
        let elems = m.n_layers * m.n_kv_heads * m.max_seq * m.head_dim();
        let mut h = fnv(0x9E3779B9);
        for &t in tokens.iter().take(8) {
            h = fnv(h ^ t as u64);
        }
        let logits = spike(m.vocab, 1, h ^ mask.key());
        (logits, vec![0.0; elems], vec![0.0; elems],
         self.cost(mask, 1, seqlen))
    }

    /// One decode step: one-hot logits per batch row; caches untouched
    /// (the zeroed contents carry no information worth updating).
    pub fn decode(&self, batch: usize, tokens: &[i32], pos: &[i32],
                  mask: &PruneMask) -> (Vec<f32>, f64) {
        let mut h = fnv(0xB10C ^ mask.key());
        for (&t, &p) in tokens.iter().zip(pos) {
            h = fnv(h ^ t as u64 ^ ((p as u64) << 32));
        }
        (spike(self.meta.vocab, batch, h), self.cost(mask, batch, 1))
    }

    /// Block-redundancy probe derived from the damage vector: low-damage
    /// blocks look redundant (high cosine), matching what the baseline
    /// pruners expect to consume.
    pub fn probe(&self, mask: &PruneMask) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let m = &self.meta;
        let red = |d: f64| (1.0 - d / 0.45).clamp(0.05, 0.98) as f32;
        let attn_cos: Vec<f32> = (0..m.n_layers)
            .map(|l| red(self.damage[BlockId::Mha(l).index(m.n_layers)]))
            .collect();
        let ffn_cos: Vec<f32> = (0..m.n_layers)
            .map(|l| red(self.damage[BlockId::Ffn(l).index(m.n_layers)]))
            .collect();
        let head_norm: Vec<f32> = (0..m.n_layers * m.n_heads)
            .map(|i| 0.5 + 0.5 * ((fnv(i as u64) >> 11) as f64
                / (1u64 << 53) as f64) as f32)
            .collect();
        let chan_norm: Vec<f32> = (0..m.n_layers * m.d_ff)
            .map(|i| 0.5 + 0.5 * ((fnv(0xFF ^ i as u64) >> 11) as f64
                / (1u64 << 53) as f64) as f32)
            .collect();
        let cost = self.cost(mask, 4, self.meta.max_seq.min(128));
        (attn_cos, ffn_cos, head_norm, chan_norm, cost)
    }
}

/// One-hot logits per row, spike position hashed from `salt` + row.
fn spike(vocab: usize, rows: usize, salt: u64) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * vocab];
    for r in 0..rows {
        let idx = (fnv(salt ^ r as u64) % vocab as u64) as usize;
        out[r * vocab + idx] = 1.0;
    }
    out
}

fn fnv(x: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for i in 0..8 {
        h ^= (x >> (8 * i)) & 0xFF;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimRuntime {
        let meta = ModelMeta::synthetic("s", 4, 128, 8, 4, 512, 512, 256);
        SimRuntime::new(meta, 42, SimConfig::default())
    }

    #[test]
    fn nll_grows_when_blocks_drop() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let dense = s.nll(&full);
        for b in s.meta.all_blocks() {
            assert!(s.nll(&full.with_block_dropped(b)) > dense, "{b}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let meta = ModelMeta::synthetic("s", 4, 128, 8, 4, 512, 512, 256);
        let a = SimRuntime::new(meta.clone(), 7, SimConfig::default());
        let b = SimRuntime::new(meta.clone(), 7, SimConfig::default());
        let c = SimRuntime::new(meta, 8, SimConfig::default());
        let full = PruneMask::full(&a.meta);
        let m = full.with_block_dropped(BlockId::Ffn(1));
        assert_eq!(a.nll(&m), b.nll(&m));
        assert_ne!(a.nll(&m), c.nll(&m));
    }

    #[test]
    fn pruned_masks_are_cheaper_and_slow_devices_slower() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let pruned = full.with_block_dropped(BlockId::Ffn(0));
        assert!(s.cost(&pruned, 8, 64) < s.cost(&full, 8, 64));
        let meta = s.meta.clone();
        let slow = SimRuntime::new(meta, 42, SimConfig {
            flops_per_sec: 1.0e9, ..SimConfig::default()
        });
        assert!(slow.cost(&full, 8, 64) > s.cost(&full, 8, 64));
    }

    #[test]
    fn transfer_cost_scales_with_payload() {
        let s = sim();
        let small = s.transfer_cost(1 << 10);
        let big = s.transfer_cost(1 << 26);
        assert!(small >= s.cfg.migration_latency_secs);
        assert!(big > small, "more bytes must cost more: {small} vs {big}");
        // an empty payload still pays the fixed latency
        assert_eq!(s.transfer_cost(0), s.cfg.migration_latency_secs);
    }

    #[test]
    fn score_recovers_model_nll() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let (b, t) = (2, 16);
        let ones = vec![1.0f32; b * t];
        let (nll, cnt, cost) = s.score(b, t, &ones, &full);
        let mean = nll.iter().map(|&x| x as f64).sum::<f64>()
            / cnt.iter().map(|&x| x as f64).sum::<f64>();
        assert!((mean - s.nll(&full)).abs() < 1e-5);
        assert!(cost > 0.0);
    }

    #[test]
    fn shapes_match_contract() {
        let s = sim();
        let full = PruneMask::full(&s.meta);
        let (logits, k, v, _) = s.prefill(32, &[1, 2, 3], &full);
        assert_eq!(logits.len(), s.meta.vocab);
        let elems = s.meta.n_layers * s.meta.n_kv_heads * s.meta.max_seq
            * s.meta.head_dim();
        assert_eq!(k.len(), elems);
        assert_eq!(v.len(), elems);
        let (lg, _) = s.decode(4, &[1, 2, 3, 4], &[5, 5, 5, 5], &full);
        assert_eq!(lg.len(), 4 * s.meta.vocab);
        // exactly one spike per row
        for r in 0..4 {
            let row = &lg[r * s.meta.vocab..(r + 1) * s.meta.vocab];
            assert_eq!(row.iter().filter(|&&x| x != 0.0).count(), 1);
        }
    }
}

//! One fleet replica: a serving engine with its own memory monitor and
//! RAP controller, plus the lifecycle and pressure bookkeeping the
//! coordinator manages (`Serving` → `Draining` → `Respawning`, or →
//! `Retired` when the autoscaler sheds capacity; autoscaler spawns may
//! enter through `Warming` when the fleet charges a warm-up cost).
//!
//! A replica never owns a run loop — the fleet advances every replica to
//! the shared clock via [`Replica::step_to`], which delegates to the
//! engine's externally-steppable `step_to` API.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::api::{SubmitRequest, Tenant};
use crate::mask::PruneMask;
use crate::memory::MemoryModel;
use crate::model_meta::ModelMeta;
use crate::runtime::sim::SimConfig;
use crate::runtime::Runtime;
use crate::server::controller::{Controller, Policy};
use crate::server::engine::{Engine, EngineConfig};
use crate::server::memmon::{MemMonConfig, MemoryMonitor};
use crate::telemetry::registry::series;
use crate::telemetry::Registry;

/// Replica lifecycle, driven by the fleet's maintenance pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReplicaState {
    /// Accepting routed requests.
    Serving,
    /// Freshly spawned, loading weights / warming caches until the
    /// given sim time (`FleetConfig::warmup_secs`): part of the working
    /// set but not yet routable. Becomes `Serving` when the cool-down
    /// elapses.
    Warming { until: f64 },
    /// Excluded from routing; finishing outstanding work. Ends in
    /// `Respawning` (pressure drain) or `Retired` (autoscale-down,
    /// flagged by `Replica::retiring`).
    Draining,
    /// Offline until the given sim time (restart cool-down), then back
    /// to `Serving` with a cleared pressure history.
    Respawning { until: f64 },
    /// Removed from the fleet by the autoscaler. Stays in the roster
    /// (ids are never reused, reports keep its history) but is never
    /// routed to, stepped into work, or respawned.
    Retired,
    /// Killed by an injected fault (crash, or a spot reclaim whose
    /// grace expired with work still resident). Like `Retired` it stays
    /// in the roster for reports but leaves the working set — unlike a
    /// drain it never comes back, and everything resident at the moment
    /// of death was destroyed (see `Engine::crash_dump`).
    Failed,
}

impl ReplicaState {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaState::Serving => "serving",
            ReplicaState::Warming { .. } => "warming",
            ReplicaState::Draining => "draining",
            ReplicaState::Respawning { .. } => "respawning",
            ReplicaState::Retired => "retired",
            ReplicaState::Failed => "failed",
        }
    }
}

pub struct Replica {
    pub id: usize,
    pub engine: Engine,
    pub state: ReplicaState,
    /// Requests the router has dispatched here.
    pub routed: u64,
    /// Completed drain → respawn cycles.
    pub respawns: u64,
    /// Draining toward `Retired` (autoscale-down) rather than respawn.
    pub retiring: bool,
    /// In-flight sequences this replica shipped out (migration source).
    pub migrations_out: u64,
    /// Sequences delivered here from a pressured peer.
    pub migrations_in: u64,
    /// Injected failures that killed this replica (crash events plus
    /// expired spot-reclaim graces).
    pub crashes: u64,
    /// Checkpointed sequences restored onto this replica after a peer
    /// crashed.
    pub restored_in: u64,
    /// When the autoscaler spawned this replica (`None` for the
    /// original fleet).
    pub spawned_at: Option<f64>,
    /// When the first request was routed here (warm-up regression
    /// surface: for a spawned replica this is ≥ spawned_at +
    /// warmup_secs).
    pub first_routed_at: Option<f64>,
    /// Engine OOM counter at the last harvest (the marks themselves
    /// live in the telemetry [`Registry`], keyed by replica id).
    oom_seen: u64,
    /// Engine absorbed-spike counter at the last harvest.
    absorbed_seen: u64,
    /// Scan cursor into `engine.metrics.completed`: records behind the
    /// cursor have already been harvested into the registry's TTFT
    /// series.
    signal_cursor: usize,
    /// A respawn cool-down elapsed; the next harvest clears this
    /// replica's OOM series so it restarts with a clean history.
    oom_reset_pending: bool,
}

impl Replica {
    pub fn new(id: usize, engine: Engine) -> Replica {
        Replica {
            id,
            engine,
            state: ReplicaState::Serving,
            routed: 0,
            respawns: 0,
            retiring: false,
            migrations_out: 0,
            migrations_in: 0,
            crashes: 0,
            restored_in: 0,
            spawned_at: None,
            first_routed_at: None,
            oom_seen: 0,
            absorbed_seen: 0,
            signal_cursor: 0,
            oom_reset_pending: false,
        }
    }

    /// Eligible to receive routed requests.
    pub fn accepting(&self) -> bool {
        matches!(self.state, ReplicaState::Serving)
    }

    /// Part of the fleet's working set (anything but `Retired` or
    /// `Failed`) — a crashed replica holds no work and contributes no
    /// signals, and excluding it from the autoscaler's returning-count
    /// is what lets a replacement spawn through `max_replicas`.
    pub fn live(&self) -> bool {
        !matches!(self.state,
                  ReplicaState::Retired | ReplicaState::Failed)
    }

    pub fn outstanding(&self) -> usize {
        self.engine.outstanding()
    }

    /// Add this replica's queued + in-flight requests to a per-tenant
    /// tally (the autoscaler's per-tenant outstanding signal and the
    /// tenant-fair router's usage accounting read this).
    pub fn outstanding_by_tenant(&self,
                                 acc: &mut BTreeMap<Tenant, usize>) {
        for r in self.engine.batcher.waiting.iter() {
            *acc.entry(r.tenant.clone()).or_insert(0) += 1;
        }
        for s in self.engine.batcher.active.iter() {
            *acc.entry(s.req.tenant.clone()).or_insert(0) += 1;
        }
    }

    /// `Sys_avail(t)` minus the replica's current footprint: the KV
    /// bytes this replica could take on right now *without moving its
    /// mask*.
    pub fn kv_headroom(&self, t: f64) -> usize {
        self.engine
            .monitor
            .available_at(t)
            .saturating_sub(self.engine.bytes_used())
    }

    /// `Sys_avail(t)` minus the replica's *min-viable* footprint: the
    /// bytes this replica could take on if its controller shrank the
    /// mask as far as allowed (see `server::outlook::MemoryOutlook`).
    /// Placement decisions (routing, migration targets) score this, so
    /// a replica mid-shrink doesn't look full. Equals `kv_headroom` for
    /// static deployments or with mask-elastic accounting disabled.
    pub fn elastic_headroom(&self, t: f64) -> usize {
        self.engine
            .outlook()
            .elastic_headroom(self.engine.monitor.available_at(t))
    }

    /// Quality of the currently-deployed mask: fraction of parameters
    /// retained (1.0 = dense). The RAP-aware router prefers sending work
    /// where the model is least damaged.
    pub fn mask_utility(&self) -> f64 {
        self.engine.mask.param_fraction(self.engine.rt.meta())
    }

    /// Route a request here at sim time `t` (the fleet calls this only
    /// on `accepting()` replicas).
    pub fn submit(&mut self, req: SubmitRequest, t: f64) {
        self.routed += 1;
        if self.first_routed_at.is_none() {
            self.first_routed_at = Some(t);
        }
        self.engine.submit(req);
    }

    /// Advance to the shared clock; completes a pending respawn or
    /// warm-up whose cool-down has elapsed. The fleet follows every
    /// step with a [`Replica::harvest`] so the pressure signals the
    /// step produced land in the telemetry registry.
    pub fn step_to(&mut self, t: f64) -> Result<()> {
        match self.state {
            ReplicaState::Respawning { until } if t >= until => {
                self.state = ReplicaState::Serving;
                self.oom_reset_pending = true;
            }
            ReplicaState::Warming { until } if t >= until => {
                self.state = ReplicaState::Serving;
            }
            _ => {}
        }
        self.engine.step_to(t)
    }

    /// Harvest the engine-side deltas since the last call into the
    /// shared registry: OOM events and absorbed spikes become timestamped
    /// marks on this replica's series (the autoscaler's pressure
    /// windows), completed requests contribute `(finished_at, ttft)`
    /// points to the TTFT window plus observations on the exported
    /// latency histograms. A respawn that completed since the last
    /// harvest clears the OOM series first — a restarted replica begins
    /// with a clean pressure history.
    pub fn harvest(&mut self, t: f64, reg: &mut Registry) {
        if self.oom_reset_pending {
            reg.clear(series::OOM, self.id);
            self.oom_reset_pending = false;
        }
        let total = self.engine.metrics.oom_events;
        for _ in self.oom_seen..total {
            reg.mark(series::OOM, self.id, t);
        }
        self.oom_seen = total;
        let absorbed = self.engine.metrics.absorbed_spikes;
        for _ in self.absorbed_seen..absorbed {
            reg.mark(series::ABSORBED, self.id, t);
        }
        self.absorbed_seen = absorbed;
        // keep the absorbed window from growing without bound (marks
        // only matter inside the autoscaler's signal window; 120 s
        // comfortably covers every configured window)
        reg.trim(series::ABSORBED, self.id, t - 120.0);
        // the completed log is appended in finished_at order, so the
        // cursor makes this amortized O(new completions)
        let completed = &self.engine.metrics.completed;
        for rec in &completed[self.signal_cursor..] {
            reg.record(series::TTFT, self.id, rec.finished_at,
                       rec.ttft());
            reg.observe("rap_ttft_seconds", rec.ttft());
            reg.observe("rap_latency_seconds", rec.latency());
        }
        self.signal_cursor = completed.len();
        reg.trim(series::TTFT, self.id, t - 120.0);
    }

    /// When this replica next needs to be stepped, for the fleet's
    /// event-driven scheduler (`FleetConfig::event_driven`):
    ///
    ///   * `NEG_INFINITY` — always due. Any replica with resident work
    ///     (queued, active, or parked sequences) must be stepped to
    ///     every fleet barrier: the engine's blocked-tick clamp and the
    ///     harvest timestamps its steps produce are barrier-sensitive,
    ///     so skipping a busy replica would move seeded reports. A
    ///     `Draining` replica is also always due — the maintenance pass
    ///     has to observe the drain completing to retire or respawn it.
    ///   * a finite time — due at that barrier: a `Warming` or
    ///     `Respawning` replica flips back to `Serving` inside
    ///     [`Replica::step_to`], so someone must step it once its
    ///     cool-down elapses.
    ///   * `INFINITY` — never due. An idle `Serving` replica's
    ///     `step_to` is a pure clock jump (no work, no signals), and
    ///     `Retired`/`Failed` replicas left the working set; skipping
    ///     them is observationally free.
    pub fn next_event_at(&self) -> f64 {
        if !self.engine.idle() || self.engine.parked_len() > 0 {
            return f64::NEG_INFINITY;
        }
        match self.state {
            ReplicaState::Draining => f64::NEG_INFINITY,
            ReplicaState::Warming { until }
            | ReplicaState::Respawning { until } => until,
            ReplicaState::Serving
            | ReplicaState::Retired
            | ReplicaState::Failed => f64::INFINITY,
        }
    }
}

/// Blueprint for one simulated replica: heterogeneous capacity,
/// interference profile, and device speed.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaSpec {
    /// Device capacity as a multiple of the dense model's parameter
    /// bytes (≥ ~1.2 so the dense model fits an idle device).
    pub capacity_mult: f64,
    /// Co-running app arrivals per second.
    pub app_rate: f64,
    /// Mean interference hold duration (seconds).
    pub mean_hold_secs: f64,
    /// Median interference chunk as a fraction of capacity.
    pub chunk_frac: f64,
    /// Modeled device throughput (FLOP/s).
    pub flops_per_sec: f64,
    /// RAP controller (`GsiGreedy`) vs a static dense deployment.
    pub adaptive: bool,
}

impl ReplicaSpec {
    /// A repeating palette of four distinct device personalities: roomy
    /// and calm, tight and noisy, fast and calm, small and thrashing.
    pub fn heterogeneous(i: usize) -> ReplicaSpec {
        const MULT: [f64; 4] = [2.5, 1.35, 3.0, 1.2];
        const RATE: [f64; 4] = [0.04, 0.12, 0.02, 0.18];
        const HOLD: [f64; 4] = [30.0, 20.0, 45.0, 15.0];
        const CHUNK: [f64; 4] = [0.18, 0.30, 0.12, 0.35];
        const FLOPS: [f64; 4] = [2.0e9, 1.2e9, 3.0e9, 1.6e9];
        let k = i % 4;
        ReplicaSpec {
            capacity_mult: MULT[k],
            app_rate: RATE[k],
            mean_hold_secs: HOLD[k],
            chunk_frac: CHUNK[k],
            flops_per_sec: FLOPS[k],
            adaptive: true,
        }
    }
}

/// Build a sim-backed replica from a spec. Deterministic per
/// (`seed`, `id`): the runtime's block-importance profile and the
/// interference schedule both derive from them.
pub fn build_sim_replica(id: usize, meta: &ModelMeta, spec: &ReplicaSpec,
                         seed: u64) -> Replica {
    let sim_cfg = SimConfig {
        flops_per_sec: spec.flops_per_sec,
        ..SimConfig::default()
    };
    let rt = Runtime::synthetic_with(
        meta.clone(), seed.wrapping_add(0x9E37 * (id as u64 + 1)), sim_cfg);
    let mem = MemoryModel::new(rt.meta());
    let dense_params = mem.param_bytes(&PruneMask::full(rt.meta()));
    let capacity = (dense_params as f64 * spec.capacity_mult) as usize;
    let monitor = MemoryMonitor::new(
        MemMonConfig {
            app_rate: spec.app_rate,
            mean_hold_secs: spec.mean_hold_secs,
            size_mu: (capacity as f64 * spec.chunk_frac).max(1.0).ln(),
            ..MemMonConfig::for_capacity(capacity)
        },
        seed.wrapping_add(1000 + id as u64),
    );
    let policy = if spec.adaptive {
        Policy::GsiGreedy
    } else {
        Policy::Static(PruneMask::full(rt.meta()))
    };
    // The sim backend's NLL model ignores token content, so a zeroed
    // calibration batch is sufficient.
    let controller = Controller::new(policy, mem, vec![0i32; 128], 128)
        .with_calib_bucket(1, 128);
    Replica::new(id, Engine::new(rt, monitor, controller,
                                 EngineConfig::default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("r", 4, 128, 8, 4, 512, 512, 256)
    }

    #[test]
    fn lifecycle_and_pressure_window() {
        let mut reg = Registry::new();
        let mut r = build_sim_replica(0, &meta(),
                                      &ReplicaSpec::heterogeneous(0), 5);
        assert!(r.accepting());
        r.state = ReplicaState::Respawning { until: 10.0 };
        assert!(!r.accepting());
        r.step_to(5.0).unwrap();
        r.harvest(5.0, &mut reg);
        assert!(matches!(r.state, ReplicaState::Respawning { .. }));
        // marks accumulated before the cool-down elapses…
        reg.mark(series::OOM, 0, 1.0);
        reg.mark(series::OOM, 0, 9.0);
        assert_eq!(reg.trim_count(series::OOM, 0, 10.0 - 2.0), 1);
        r.step_to(10.0).unwrap();
        assert!(r.accepting(), "respawn cool-down elapsed");
        // …are forgotten at the next harvest: a respawned replica
        // starts with a clean pressure history
        r.harvest(10.0, &mut reg);
        assert_eq!(reg.count_since(series::OOM, 0, 0.0), 0);
    }

    #[test]
    fn warming_replica_serves_only_after_warmup() {
        let mut r = build_sim_replica(0, &meta(),
                                      &ReplicaSpec::heterogeneous(0), 5);
        r.state = ReplicaState::Warming { until: 8.0 };
        r.spawned_at = Some(0.0);
        assert!(!r.accepting(), "warming replicas take no routes");
        assert!(r.live(), "warming replicas are in the working set");
        assert_eq!(r.state.name(), "warming");
        r.step_to(4.0).unwrap();
        assert!(!r.accepting());
        r.step_to(8.0).unwrap();
        assert!(r.accepting(), "warm-up elapsed");
    }

    #[test]
    fn failed_replica_leaves_the_working_set() {
        let mut r = build_sim_replica(0, &meta(),
                                      &ReplicaSpec::heterogeneous(0), 5);
        r.state = ReplicaState::Failed;
        assert!(!r.accepting(), "failed replicas take no routes");
        assert!(!r.live(), "failed replicas leave the working set");
        assert_eq!(r.state.name(), "failed");
        // unlike a drain, stepping never resurrects it
        r.step_to(100.0).unwrap();
        assert_eq!(r.state, ReplicaState::Failed);
    }

    #[test]
    fn absorbed_marks_are_harvested() {
        let mut reg = Registry::new();
        let mut r = build_sim_replica(0, &meta(),
                                      &ReplicaSpec::heterogeneous(0), 5);
        // fake two absorbed spikes on the engine between steps
        r.engine.metrics.absorbed_spikes = 2;
        r.step_to(3.0).unwrap();
        r.harvest(3.0, &mut reg);
        assert_eq!(reg.count_since(series::ABSORBED, 0, 0.0), 2);
        assert_eq!(reg.count_since(series::ABSORBED, 0, 3.5), 0);
        r.engine.metrics.absorbed_spikes = 3;
        r.step_to(5.0).unwrap();
        r.harvest(5.0, &mut reg);
        assert_eq!(reg.count_since(series::ABSORBED, 0, 4.0), 1);
    }

    #[test]
    fn headroom_tracks_monitor_and_footprint() {
        let r = build_sim_replica(1, &meta(),
                                  &ReplicaSpec::heterogeneous(0), 5);
        let cap = r.engine.monitor.cfg.capacity;
        let used = r.engine.bytes_used();
        assert!(used > 0);
        // at t=0 the seeded process may or may not hold memory, but the
        // identity headroom = avail - used must hold
        let avail = r.engine.monitor.available_at(0.0);
        assert_eq!(r.kv_headroom(0.0), avail.saturating_sub(used));
        assert!(avail <= cap);
        assert!((r.mask_utility() - 1.0).abs() < 1e-12, "fresh mask dense");
    }

    #[test]
    fn specs_are_heterogeneous() {
        let m = meta();
        let a = build_sim_replica(0, &m, &ReplicaSpec::heterogeneous(0), 9);
        let b = build_sim_replica(1, &m, &ReplicaSpec::heterogeneous(1), 9);
        assert_ne!(a.engine.monitor.cfg.capacity,
                   b.engine.monitor.cfg.capacity);
    }
}

//! Fleet-level measurement: per-replica summaries plus aggregate tail
//! latencies, OOM/respawn counts, and the routing histogram — printable
//! and serializable to JSON via the in-tree `util::json` writer.

use crate::memory::mib;
use crate::server::metrics::ServeReport;
use crate::util::json::Json;

/// One replica's slice of a fleet run.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub id: usize,
    /// Lifecycle state at the end of the run.
    pub state: String,
    pub capacity_bytes: usize,
    /// Requests the router dispatched here.
    pub routed: u64,
    pub respawns: u64,
    /// Sequences shipped out of / delivered into this replica by the
    /// fleet's migration pass.
    pub migrations_out: u64,
    pub migrations_in: u64,
    pub serve: ServeReport,
}

/// Aggregate results of one fleet trace replay.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub policy: String,
    pub sim_secs: f64,
    /// Arrivals handed to the router (routed + dropped).
    pub total_requests: u64,
    pub completed: usize,
    /// Permanent admission rejections, summed over replicas.
    pub rejected: u64,
    /// Local evict-and-requeue casualties (OOM evictions), summed over
    /// replicas — the number migration exists to shrink.
    pub evictions: u64,
    /// Arrivals the router could not place (no accepting replica).
    pub dropped: u64,
    /// True OOM events (pressure even the min-viable mask couldn't
    /// absorb), summed over replicas.
    pub oom_events: u64,
    /// Memory spikes absorbed purely by mask-shrinking (no work shed,
    /// no OOM charged), summed over replicas.
    pub absorbed_spikes: u64,
    pub respawns: u64,
    /// Replicas added / retired by the autoscaler.
    pub spawns: u64,
    pub retires: u64,
    /// Cross-replica sequence migrations completed, and the payload
    /// bytes they moved over the modeled interconnect.
    pub migrations: u64,
    pub migration_bytes: u64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub throughput_rps: f64,
    /// Routing histogram: decisions per replica index.
    pub routing: Vec<u64>,
    pub replicas: Vec<ReplicaReport>,
}

/// JSON number that is always valid JSON (NaN/inf → null).
fn num(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

impl FleetReport {
    pub fn print(&self) {
        println!("── fleet report: router={} ({} replicas, {:.0}s sim)",
                 self.policy, self.replicas.len(), self.sim_secs);
        println!("   requests {} | completed {} | rejected {} | evicted \
                  {} | dropped {}", self.total_requests, self.completed,
                 self.rejected, self.evictions, self.dropped);
        println!("   OOM events {} | absorbed spikes {} | respawns {} | \
                  throughput {:.2} req/s",
                 self.oom_events, self.absorbed_spikes, self.respawns,
                 self.throughput_rps);
        if self.spawns + self.retires + self.migrations > 0 {
            println!("   elastic: spawned {} | retired {} | migrated {} \
                      ({:.1} MiB moved)",
                     self.spawns, self.retires, self.migrations,
                     mib(self.migration_bytes as usize));
        }
        println!("   latency p50/p99  {:.3}s / {:.3}s   ttft p50/p99  \
                  {:.3}s / {:.3}s",
                 self.p50_latency, self.p99_latency, self.p50_ttft,
                 self.p99_ttft);
        println!("   routing histogram: {:?}", self.routing);
        println!("   {:<4} {:>10} {:>7} {:>9} {:>6} {:>5} {:>9} {:>9}  \
                  state",
                 "id", "cap(MiB)", "routed", "completed", "OOMs", "resp",
                 "p50 lat", "p99 lat");
        for r in &self.replicas {
            println!("   {:<4} {:>10.1} {:>7} {:>9} {:>6} {:>5} {:>8.3}s \
                      {:>8.3}s  {}",
                     r.id, mib(r.capacity_bytes), r.routed,
                     r.serve.completed, r.serve.oom_events, r.respawns,
                     zero_nan(r.serve.p50_latency),
                     zero_nan(r.serve.p99_latency), r.state);
        }
    }

    /// The acceptance-surface JSON: per-replica and aggregate p50/p99
    /// latency + TTFT, OOM counts, and the routing histogram.
    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                Json::object(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("state", Json::Str(r.state.clone())),
                    ("capacity_bytes", Json::Num(r.capacity_bytes as f64)),
                    ("routed", Json::Num(r.routed as f64)),
                    ("respawns", Json::Num(r.respawns as f64)),
                    ("migrations_out",
                     Json::Num(r.migrations_out as f64)),
                    ("migrations_in", Json::Num(r.migrations_in as f64)),
                    ("completed", Json::Num(r.serve.completed as f64)),
                    ("rejected", Json::Num(r.serve.rejected as f64)),
                    ("evictions", Json::Num(r.serve.evictions as f64)),
                    ("oom_events", Json::Num(r.serve.oom_events as f64)),
                    ("absorbed_spikes",
                     Json::Num(r.serve.absorbed_spikes as f64)),
                    ("mask_switches",
                     Json::Num(r.serve.mask_switches as f64)),
                    ("p50_latency", num(r.serve.p50_latency)),
                    ("p99_latency", num(r.serve.p99_latency)),
                    ("p50_ttft", num(r.serve.p50_ttft)),
                    ("p99_ttft", num(r.serve.p99_ttft)),
                    ("throughput_rps", num(r.serve.throughput_rps)),
                ])
            })
            .collect();
        Json::object(vec![
            ("router", Json::Str(self.policy.clone())),
            ("sim_secs", num(self.sim_secs)),
            ("total_requests", Json::Num(self.total_requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("oom_events", Json::Num(self.oom_events as f64)),
            ("absorbed_spikes",
             Json::Num(self.absorbed_spikes as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("spawns", Json::Num(self.spawns as f64)),
            ("retires", Json::Num(self.retires as f64)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("migration_bytes",
             Json::Num(self.migration_bytes as f64)),
            ("mean_latency", num(self.mean_latency)),
            ("p50_latency", num(self.p50_latency)),
            ("p99_latency", num(self.p99_latency)),
            ("p50_ttft", num(self.p50_ttft)),
            ("p99_ttft", num(self.p99_ttft)),
            ("throughput_rps", num(self.throughput_rps)),
            ("routing_histogram",
             Json::Arr(self.routing.iter()
                       .map(|&c| Json::Num(c as f64)).collect())),
            ("replicas", Json::Arr(replicas)),
        ])
    }
}

/// Display policy for percentiles over an empty sample: print 0.0
/// (shared with the fleet experiment's table).
pub(crate) fn zero_nan(x: f64) -> f64 {
    if x.is_finite() { x } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::metrics::Metrics;

    #[test]
    fn json_is_parseable_even_with_empty_replicas() {
        let empty = Metrics::default().report(1.0); // NaN percentiles
        let report = FleetReport {
            policy: "rap-aware".into(),
            sim_secs: 1.0,
            total_requests: 0,
            completed: 0,
            rejected: 0,
            evictions: 0,
            dropped: 0,
            oom_events: 0,
            absorbed_spikes: 0,
            respawns: 0,
            spawns: 0,
            retires: 0,
            migrations: 0,
            migration_bytes: 0,
            mean_latency: f64::NAN,
            p50_latency: f64::NAN,
            p99_latency: f64::NAN,
            p50_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            throughput_rps: 0.0,
            routing: vec![0, 0],
            replicas: vec![ReplicaReport {
                id: 0,
                state: "serving".into(),
                capacity_bytes: 1 << 20,
                routed: 0,
                respawns: 0,
                migrations_out: 0,
                migrations_in: 0,
                serve: empty,
            }],
        };
        let s = report.to_json().pretty();
        let parsed = Json::parse(&s).expect("fleet JSON must parse");
        assert_eq!(parsed.get("router").unwrap().str().unwrap(),
                   "rap-aware");
        assert_eq!(parsed.get("p50_latency").unwrap(), &Json::Null);
        assert_eq!(parsed.get("routing_histogram").unwrap()
                   .usize_vec().unwrap(), vec![0, 0]);
        assert_eq!(parsed.get("replicas").unwrap().arr().unwrap().len(),
                   1);
    }
}

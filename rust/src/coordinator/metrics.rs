//! Fleet-level measurement: per-replica summaries plus aggregate tail
//! latencies, OOM/respawn counts, per-tenant sections (deadline
//! hit-rates, quota utilization), and the routing histogram — printable
//! and serializable to JSON via the in-tree `util::json` writer.

use crate::memory::mib;
use crate::server::metrics::{ServeReport, TenantCounts};
use crate::util::json::Json;

/// One replica's slice of a fleet run.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub id: usize,
    /// Lifecycle state at the end of the run.
    pub state: String,
    pub capacity_bytes: usize,
    /// Requests the router dispatched here.
    pub routed: u64,
    pub respawns: u64,
    /// Sequences shipped out of / delivered into this replica by the
    /// fleet's migration pass.
    pub migrations_out: u64,
    pub migrations_in: u64,
    /// Injected failures this replica absorbed (crashes include expired
    /// reclaim grace windows), and checkpointed sequences delivered
    /// *into* it by crash recovery.
    pub crashes: u64,
    pub restored_in: u64,
    pub serve: ServeReport,
}

/// The failure-injection and recovery ledger of one fleet run. All
/// zeros (and absent rates) on runs without a fault plan.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Scheduled fault events that fired.
    pub failures_injected: u64,
    /// Replica crashes (outright, plus reclaims whose grace expired).
    pub crashes: u64,
    /// Spot reclaims that began draining.
    pub reclaims: u64,
    /// Sequences whose decode progress was destroyed: uncheckpointed
    /// in-flight work on a crashed replica, plus restores that could
    /// not land anywhere.
    pub seq_lost: u64,
    /// Checkpointed sequences successfully restored onto a peer.
    pub seq_restored: u64,
    /// Checkpoint cycles that shipped anything, and the interconnect
    /// bytes they charged (live-KV deltas only), summed over replicas.
    pub checkpoints_taken: u64,
    pub checkpoint_bytes: u64,
    /// Transfer landings deferred by an interconnect partition, and
    /// moves abandoned after the retry budget ran out.
    pub transfer_retries: u64,
    pub transfer_failures: u64,
    /// p99 TTFT over the requests a fault displaced (`None` when none
    /// completed — serialized as `null`, never a NaN sentinel).
    pub recovery_p99_ttft: Option<f64>,
    /// Of the SLO-carrying requests a fault displaced, the fraction
    /// that still finished inside their deadline (`None` when none).
    pub chaos_deadline_hit_rate: Option<f64>,
}

impl Default for ChaosReport {
    fn default() -> Self {
        ChaosReport {
            failures_injected: 0,
            crashes: 0,
            reclaims: 0,
            seq_lost: 0,
            seq_restored: 0,
            checkpoints_taken: 0,
            checkpoint_bytes: 0,
            transfer_retries: 0,
            transfer_failures: 0,
            recovery_p99_ttft: None,
            chaos_deadline_hit_rate: None,
        }
    }
}

/// One tenant's slice of a fleet run: the merged outcome ledger across
/// replicas and the ingress, fleet-wide TTFT tails, and — under the
/// tenant-fair router — the quota and its observed high-water mark.
#[derive(Clone, Debug)]
pub struct FleetTenantReport {
    pub tenant: String,
    pub counts: TenantCounts,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// The tenant's KV-byte quota (`None` when unlimited or when the
    /// router carries no quota table).
    pub quota_bytes: Option<u64>,
    /// High-water mark of the tenant's committed KV bytes at dispatch.
    pub quota_peak_bytes: u64,
}

impl FleetTenantReport {
    /// See `TenantCounts::deadline_hit_rate`.
    pub fn deadline_hit_rate(&self) -> f64 {
        self.counts.deadline_hit_rate()
    }

    /// Peak quota utilization in [0, 1]-ish (NaN without a quota; > 1
    /// would mean the cap was breached — the fairness proptest holds it
    /// ≤ 1).
    pub fn quota_utilization(&self) -> f64 {
        match self.quota_bytes {
            Some(q) if q > 0 => self.quota_peak_bytes as f64 / q as f64,
            _ => f64::NAN,
        }
    }
}

/// Aggregate results of one fleet trace replay.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub policy: String,
    pub sim_secs: f64,
    /// Requests submitted at the fleet ingress — every arrival, whether
    /// it was routed, backlogged, dropped, or rejected at the front
    /// door. The conservation total: completed + rejected + cancelled +
    /// deadline_missed + dropped + still-pending.
    pub total_requests: u64,
    pub completed: usize,
    /// Permanent admission rejections, summed over replicas.
    pub rejected: u64,
    /// Local evict-and-requeue casualties (OOM evictions), summed over
    /// replicas — the number migration exists to shrink.
    pub evictions: u64,
    /// Requests reclaimed via the lifecycle API (replica-held and
    /// ingress-held cancels).
    pub cancelled: u64,
    /// Terminal `DeadlineMissed` outcomes (late finishes, queue
    /// expiries, expired sheds), summed over replicas + ingress.
    pub deadline_missed: u64,
    /// Arrivals the router could not place (no accepting replica), or
    /// that the run ended still holding in a tenant backlog.
    pub dropped: u64,
    /// True OOM events (pressure even the min-viable mask couldn't
    /// absorb), summed over replicas.
    pub oom_events: u64,
    /// Memory spikes absorbed purely by mask-shrinking (no work shed,
    /// no OOM charged), summed over replicas.
    pub absorbed_spikes: u64,
    /// Absorptions that needed the KV axis: at least one resident cache
    /// was compressed to the floor policy (a subset of
    /// `absorbed_spikes`), summed over replicas.
    pub compressed_spikes: u64,
    /// KV bytes freed by in-place compression under pressure, summed
    /// over replicas.
    pub kv_bytes_reclaimed: u64,
    pub respawns: u64,
    /// Replicas added / retired by the autoscaler.
    pub spawns: u64,
    pub retires: u64,
    /// Cross-replica sequence migrations completed, and the payload
    /// bytes they moved over the modeled interconnect (live KV slices —
    /// prefill-bucket padding is never shipped).
    pub migrations: u64,
    pub migration_bytes: u64,
    /// What the same migrations would have cost under the
    /// pre-compression accounting (bucket-padded caches) — the
    /// compression win is `migration_bytes_padded - migration_bytes`.
    pub migration_bytes_padded: u64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub throughput_rps: f64,
    /// Routing histogram: decisions per replica index.
    pub routing: Vec<u64>,
    /// Backlog heads skipped defensively at dispatch (should stay 0;
    /// see `Fleet::dispatch_ingress`).
    pub ingress_skipped: u64,
    /// Failure-injection and recovery ledger (all zeros without a
    /// fault plan).
    pub chaos: ChaosReport,
    /// Per-tenant sections, sorted by tenant name (one "default" entry
    /// on undecorated trace replays).
    pub tenants: Vec<FleetTenantReport>,
    pub replicas: Vec<ReplicaReport>,
}

/// JSON number that is always valid JSON (NaN/inf → null).
fn num(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

/// JSON for an optional rate: absent → null (a typed `None`, not a NaN
/// smuggled through the serializer).
fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => num(v),
        None => Json::Null,
    }
}

impl FleetReport {
    pub fn print(&self) {
        println!("── fleet report: router={} ({} replicas, {:.0}s sim)",
                 self.policy, self.replicas.len(), self.sim_secs);
        println!("   requests {} | completed {} | rejected {} | evicted \
                  {} | dropped {}", self.total_requests, self.completed,
                 self.rejected, self.evictions, self.dropped);
        println!("   OOM events {} | absorbed spikes {} | respawns {} | \
                  throughput {:.2} req/s",
                 self.oom_events, self.absorbed_spikes, self.respawns,
                 self.throughput_rps);
        if self.compressed_spikes > 0 {
            println!("   kv compressions {} ({:.1} MiB reclaimed)",
                     self.compressed_spikes,
                     mib(self.kv_bytes_reclaimed as usize));
        }
        if self.cancelled + self.deadline_missed > 0 {
            println!("   cancelled {} | deadline missed {}",
                     self.cancelled, self.deadline_missed);
        }
        if self.spawns + self.retires + self.migrations > 0 {
            println!("   elastic: spawned {} | retired {} | migrated {} \
                      ({:.1} MiB moved, {:.1} MiB padded-equivalent)",
                     self.spawns, self.retires, self.migrations,
                     mib(self.migration_bytes as usize),
                     mib(self.migration_bytes_padded as usize));
        }
        if self.chaos.failures_injected > 0 {
            let c = &self.chaos;
            println!("   chaos: {} faults | crashes {} | reclaims {} | \
                      seq lost {} | restored {}",
                     c.failures_injected, c.crashes, c.reclaims,
                     c.seq_lost, c.seq_restored);
            println!("   recovery: checkpoints {} ({:.1} MiB) | \
                      retries {} | failed moves {} | p99 ttft {:.3}s | \
                      SLO hit-rate {:.1}%",
                     c.checkpoints_taken,
                     mib(c.checkpoint_bytes as usize),
                     c.transfer_retries, c.transfer_failures,
                     c.recovery_p99_ttft.unwrap_or(0.0),
                     100.0 * c.chaos_deadline_hit_rate.unwrap_or(0.0));
        }
        println!("   latency p50/p99  {:.3}s / {:.3}s   ttft p50/p99  \
                  {:.3}s / {:.3}s",
                 self.p50_latency, self.p99_latency, self.p50_ttft,
                 self.p99_ttft);
        println!("   routing histogram: {:?}", self.routing);
        self.print_tenants();
        println!("   {:<4} {:>10} {:>7} {:>9} {:>6} {:>5} {:>9} {:>9}  \
                  state",
                 "id", "cap(MiB)", "routed", "completed", "OOMs", "resp",
                 "p50 lat", "p99 lat");
        for r in &self.replicas {
            println!("   {:<4} {:>10.1} {:>7} {:>9} {:>6} {:>5} {:>8.3}s \
                      {:>8.3}s  {}",
                     r.id, mib(r.capacity_bytes), r.routed,
                     r.serve.completed, r.serve.oom_events, r.respawns,
                     zero_nan(r.serve.p50_latency),
                     zero_nan(r.serve.p99_latency), r.state);
        }
    }

    /// The per-tenant table (skipped when the run is single-tenant with
    /// no SLOs or quotas in play — the trace-replay default).
    pub fn print_tenants(&self) {
        let interesting = self.tenants.len() > 1
            || self.tenants.iter().any(|t| {
                t.counts.deadline_total > 0 || t.quota_bytes.is_some()
            });
        if !interesting {
            return;
        }
        println!("   {:<10} {:>9} {:>6} {:>7} {:>7} {:>7} {:>9} {:>9} \
                  {:>7}",
                 "tenant", "submitted", "done", "missed", "cancel",
                 "reject", "p99 ttft", "hit-rate", "quota%");
        for t in &self.tenants {
            let hr = if t.counts.deadline_total > 0 {
                format!("{:>8.1}%", 100.0 * t.deadline_hit_rate())
            } else {
                "        —".to_string()
            };
            let qu = if t.quota_bytes.is_some() {
                format!("{:>6.1}%", 100.0 * t.quota_utilization())
            } else {
                "      —".to_string()
            };
            println!("   {:<10} {:>9} {:>6} {:>7} {:>7} {:>7} {:>8.3}s \
                      {} {}",
                     t.tenant, t.counts.submitted, t.counts.finished,
                     t.counts.deadline_missed, t.counts.cancelled,
                     t.counts.rejected, zero_nan(t.p99_ttft), hr, qu);
        }
    }

    /// The acceptance-surface JSON: per-replica, per-tenant, and
    /// aggregate p50/p99 latency + TTFT, OOM counts, deadline hit-rates,
    /// and the routing histogram.
    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                Json::object(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("state", Json::Str(r.state.clone())),
                    ("capacity_bytes", Json::Num(r.capacity_bytes as f64)),
                    ("routed", Json::Num(r.routed as f64)),
                    ("respawns", Json::Num(r.respawns as f64)),
                    ("migrations_out",
                     Json::Num(r.migrations_out as f64)),
                    ("migrations_in", Json::Num(r.migrations_in as f64)),
                    ("crashes", Json::Num(r.crashes as f64)),
                    ("restored_in", Json::Num(r.restored_in as f64)),
                    ("completed", Json::Num(r.serve.completed as f64)),
                    ("rejected", Json::Num(r.serve.rejected as f64)),
                    ("evictions", Json::Num(r.serve.evictions as f64)),
                    ("cancelled", Json::Num(r.serve.cancelled as f64)),
                    ("oom_events", Json::Num(r.serve.oom_events as f64)),
                    ("absorbed_spikes",
                     Json::Num(r.serve.absorbed_spikes as f64)),
                    ("compressed_spikes",
                     Json::Num(r.serve.compressed_spikes as f64)),
                    ("kv_bytes_reclaimed",
                     Json::Num(r.serve.kv_bytes_reclaimed as f64)),
                    ("mask_switches",
                     Json::Num(r.serve.mask_switches as f64)),
                    ("deadline_missed",
                     Json::Num(r.serve.deadline_missed as f64)),
                    ("checkpoints_taken",
                     Json::Num(r.serve.checkpoints_taken as f64)),
                    ("checkpoint_bytes",
                     Json::Num(r.serve.checkpoint_bytes as f64)),
                    ("p50_latency", num(r.serve.p50_latency)),
                    ("p99_latency", num(r.serve.p99_latency)),
                    ("p50_ttft", num(r.serve.p50_ttft)),
                    ("p99_ttft", num(r.serve.p99_ttft)),
                    ("throughput_rps", num(r.serve.throughput_rps)),
                ])
            })
            .collect();
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::object(vec![
                    ("tenant", Json::Str(t.tenant.clone())),
                    ("submitted", Json::Num(t.counts.submitted as f64)),
                    ("finished", Json::Num(t.counts.finished as f64)),
                    ("deadline_missed",
                     Json::Num(t.counts.deadline_missed as f64)),
                    ("cancelled", Json::Num(t.counts.cancelled as f64)),
                    ("rejected", Json::Num(t.counts.rejected as f64)),
                    ("deadline_hits",
                     Json::Num(t.counts.deadline_hits as f64)),
                    ("deadline_total",
                     Json::Num(t.counts.deadline_total as f64)),
                    ("deadline_hit_rate", num(t.deadline_hit_rate())),
                    ("p50_ttft", num(t.p50_ttft)),
                    ("p99_ttft", num(t.p99_ttft)),
                    ("quota_bytes", match t.quota_bytes {
                        Some(q) => Json::Num(q as f64),
                        None => Json::Null,
                    }),
                    ("quota_peak_bytes",
                     Json::Num(t.quota_peak_bytes as f64)),
                    ("quota_utilization", num(t.quota_utilization())),
                ])
            })
            .collect();
        Json::object(vec![
            ("router", Json::Str(self.policy.clone())),
            ("sim_secs", num(self.sim_secs)),
            ("total_requests", Json::Num(self.total_requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("deadline_missed",
             Json::Num(self.deadline_missed as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("oom_events", Json::Num(self.oom_events as f64)),
            ("absorbed_spikes",
             Json::Num(self.absorbed_spikes as f64)),
            ("compressed_spikes",
             Json::Num(self.compressed_spikes as f64)),
            ("kv_bytes_reclaimed",
             Json::Num(self.kv_bytes_reclaimed as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            ("spawns", Json::Num(self.spawns as f64)),
            ("retires", Json::Num(self.retires as f64)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("migration_bytes",
             Json::Num(self.migration_bytes as f64)),
            ("migration_bytes_padded",
             Json::Num(self.migration_bytes_padded as f64)),
            ("mean_latency", num(self.mean_latency)),
            ("p50_latency", num(self.p50_latency)),
            ("p99_latency", num(self.p99_latency)),
            ("p50_ttft", num(self.p50_ttft)),
            ("p99_ttft", num(self.p99_ttft)),
            ("throughput_rps", num(self.throughput_rps)),
            ("routing_histogram",
             Json::Arr(self.routing.iter()
                       .map(|&c| Json::Num(c as f64)).collect())),
            ("ingress_skipped",
             Json::Num(self.ingress_skipped as f64)),
            ("chaos", Json::object(vec![
                ("failures_injected",
                 Json::Num(self.chaos.failures_injected as f64)),
                ("crashes", Json::Num(self.chaos.crashes as f64)),
                ("reclaims", Json::Num(self.chaos.reclaims as f64)),
                ("seq_lost", Json::Num(self.chaos.seq_lost as f64)),
                ("seq_restored",
                 Json::Num(self.chaos.seq_restored as f64)),
                ("checkpoints_taken",
                 Json::Num(self.chaos.checkpoints_taken as f64)),
                ("checkpoint_bytes",
                 Json::Num(self.chaos.checkpoint_bytes as f64)),
                ("transfer_retries",
                 Json::Num(self.chaos.transfer_retries as f64)),
                ("transfer_failures",
                 Json::Num(self.chaos.transfer_failures as f64)),
                ("recovery_p99_ttft",
                 opt_num(self.chaos.recovery_p99_ttft)),
                ("chaos_deadline_hit_rate",
                 opt_num(self.chaos.chaos_deadline_hit_rate)),
            ])),
            ("tenants", Json::Arr(tenants)),
            ("replicas", Json::Arr(replicas)),
        ])
    }
}

/// Display policy for percentiles over an empty sample: print 0.0
/// (shared with the fleet experiment's table).
pub(crate) fn zero_nan(x: f64) -> f64 {
    if x.is_finite() { x } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::metrics::Metrics;

    #[test]
    fn json_is_parseable_even_with_empty_replicas() {
        let empty = Metrics::default().report(1.0); // NaN percentiles
        let report = FleetReport {
            policy: "rap-aware".into(),
            sim_secs: 1.0,
            total_requests: 0,
            completed: 0,
            rejected: 0,
            evictions: 0,
            cancelled: 0,
            deadline_missed: 0,
            dropped: 0,
            oom_events: 0,
            absorbed_spikes: 0,
            compressed_spikes: 0,
            kv_bytes_reclaimed: 0,
            respawns: 0,
            spawns: 0,
            retires: 0,
            migrations: 0,
            migration_bytes: 0,
            migration_bytes_padded: 0,
            mean_latency: f64::NAN,
            p50_latency: f64::NAN,
            p99_latency: f64::NAN,
            p50_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            throughput_rps: 0.0,
            routing: vec![0, 0],
            ingress_skipped: 0,
            chaos: ChaosReport::default(),
            tenants: vec![FleetTenantReport {
                tenant: "default".into(),
                counts: TenantCounts::default(),
                p50_ttft: f64::NAN,
                p99_ttft: f64::NAN,
                quota_bytes: None,
                quota_peak_bytes: 0,
            }],
            replicas: vec![ReplicaReport {
                id: 0,
                state: "serving".into(),
                capacity_bytes: 1 << 20,
                routed: 0,
                respawns: 0,
                migrations_out: 0,
                migrations_in: 0,
                crashes: 0,
                restored_in: 0,
                serve: empty,
            }],
        };
        let s = report.to_json().pretty();
        let parsed = Json::parse(&s).expect("fleet JSON must parse");
        assert_eq!(parsed.get("router").unwrap().str().unwrap(),
                   "rap-aware");
        assert_eq!(parsed.get("p50_latency").unwrap(), &Json::Null);
        assert_eq!(parsed.get("routing_histogram").unwrap()
                   .usize_vec().unwrap(), vec![0, 0]);
        assert_eq!(parsed.get("replicas").unwrap().arr().unwrap().len(),
                   1);
        // the tenant section parses, with nulls where no data exists
        let tenants = parsed.get("tenants").unwrap().arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("tenant").unwrap().str().unwrap(),
                   "default");
        assert_eq!(tenants[0].get("deadline_hit_rate").unwrap(),
                   &Json::Null);
        assert_eq!(tenants[0].get("quota_bytes").unwrap(), &Json::Null);
        // the chaos section parses, with nulls for the empty rates
        let chaos = parsed.get("chaos").unwrap();
        assert_eq!(chaos.get("crashes").unwrap(), &Json::Num(0.0));
        assert_eq!(chaos.get("recovery_p99_ttft").unwrap(), &Json::Null);
        assert_eq!(chaos.get("chaos_deadline_hit_rate").unwrap(),
                   &Json::Null);
    }

    /// Counter-completeness audit: every ledger field the fleet keeps
    /// must survive into the serialized report — a counter that exists
    /// on the struct but not in the JSON is invisible to every consumer
    /// downstream of `--json`. The key lists are maintained by hand
    /// (no reflection); adding a field to `FleetReport`/`ChaosReport`/
    /// the replica entries means adding it here too.
    #[test]
    fn serialized_report_carries_every_counter() {
        let empty = Metrics::default().report(1.0);
        let report = FleetReport {
            policy: "rap-aware".into(),
            sim_secs: 1.0,
            total_requests: 0,
            completed: 0,
            rejected: 0,
            evictions: 0,
            cancelled: 0,
            deadline_missed: 0,
            dropped: 0,
            oom_events: 0,
            absorbed_spikes: 0,
            compressed_spikes: 0,
            kv_bytes_reclaimed: 0,
            respawns: 0,
            spawns: 0,
            retires: 0,
            migrations: 0,
            migration_bytes: 0,
            migration_bytes_padded: 0,
            mean_latency: f64::NAN,
            p50_latency: f64::NAN,
            p99_latency: f64::NAN,
            p50_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            throughput_rps: 0.0,
            routing: vec![0],
            ingress_skipped: 0,
            chaos: ChaosReport::default(),
            tenants: vec![],
            replicas: vec![ReplicaReport {
                id: 0,
                state: "serving".into(),
                capacity_bytes: 1 << 20,
                routed: 0,
                respawns: 0,
                migrations_out: 0,
                migrations_in: 0,
                crashes: 0,
                restored_in: 0,
                serve: empty,
            }],
        };
        let j = report.to_json();
        let top = [
            "router", "sim_secs", "total_requests", "completed",
            "rejected", "evictions", "cancelled", "deadline_missed",
            "dropped", "oom_events", "absorbed_spikes",
            "compressed_spikes", "kv_bytes_reclaimed", "respawns",
            "spawns", "retires", "migrations", "migration_bytes",
            "migration_bytes_padded", "mean_latency", "p50_latency",
            "p99_latency", "p50_ttft", "p99_ttft", "throughput_rps",
            "routing_histogram", "ingress_skipped", "chaos", "tenants",
            "replicas",
        ];
        for key in top {
            assert!(j.get(key).is_ok(), "report JSON lost `{key}`");
        }
        let chaos = j.get("chaos").unwrap();
        for key in ["failures_injected", "crashes", "reclaims",
                    "seq_lost", "seq_restored", "checkpoints_taken",
                    "checkpoint_bytes", "transfer_retries",
                    "transfer_failures", "recovery_p99_ttft",
                    "chaos_deadline_hit_rate"] {
            assert!(chaos.get(key).is_ok(),
                    "chaos section lost `{key}`");
        }
        let replica = &j.get("replicas").unwrap().arr().unwrap()[0];
        for key in ["id", "state", "capacity_bytes", "routed",
                    "respawns", "migrations_out", "migrations_in",
                    "crashes", "restored_in", "completed", "rejected",
                    "evictions", "cancelled", "oom_events",
                    "absorbed_spikes", "compressed_spikes",
                    "kv_bytes_reclaimed", "mask_switches",
                    "deadline_missed", "checkpoints_taken",
                    "checkpoint_bytes", "p50_latency", "p99_latency",
                    "p50_ttft", "p99_ttft", "throughput_rps"] {
            assert!(replica.get(key).is_ok(),
                    "replica section lost `{key}`");
        }
    }

    #[test]
    fn quota_utilization_math() {
        let t = FleetTenantReport {
            tenant: "noisy".into(),
            counts: TenantCounts::default(),
            p50_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            quota_bytes: Some(1000),
            quota_peak_bytes: 750,
        };
        assert!((t.quota_utilization() - 0.75).abs() < 1e-12);
        let unlimited = FleetTenantReport { quota_bytes: None, ..t };
        assert!(unlimited.quota_utilization().is_nan());
    }
}

//! Request routing across fleet replicas.
//!
//! Five policies, from memory-blind to fully tenant/RAP-aware:
//!
//!   * `RoundRobin`       — cyclic dispatch over accepting replicas (the
//!                          memory-blind baseline every LB starts with);
//!   * `LeastOutstanding` — classic least-loaded by queued + in-flight
//!                          requests;
//!   * `KvHeadroom`       — most free memory, judged elastically:
//!                          `Sys_avail(t)` minus the replica's
//!                          *min-viable* footprint (the memory outlook),
//!                          so a replica mid-mask-shrink does not look
//!                          full;
//!   * `RapAware`         — scores feasibility *for this request*: the
//!                          request's estimated KV bytes under the mask
//!                          each replica could shrink to (its min-viable
//!                          mask) against that replica's
//!                          elastic headroom, weighted by mask utility
//!                          (quality of the deployed model) and queue
//!                          depth. Infeasible replicas (headroom ≤ cost)
//!                          rank strictly below every feasible one, by
//!                          raw deficit — utility must NOT scale a
//!                          negative surplus, or the least-damaged
//!                          replica would get the smallest penalty and
//!                          the preference would invert (see
//!                          `prop_rap_router_never_prefers_infeasible`).
//!                          This is the fleet-level analogue of the
//!                          paper's (workload, Sys_avail) state vector.
//!   * `TenantFair`       — deficit-weighted dispatch over per-tenant
//!                          KV-byte quotas ([`TenantQuotas`]): each
//!                          tenant's in-flight KV bytes are capped at
//!                          its quota, overflow waits in a per-tenant
//!                          ingress backlog owned by the fleet
//!                          (`Fleet::dispatch_ingress`), and the tenant
//!                          deepest under its quota dispatches first.
//!                          *Within* a tenant, each released request is
//!                          placed by the same RAP-aware scoring as
//!                          `RapAware` ([`Router::place`]).
//!
//! The router also owns the routing histogram (decisions per replica)
//! reported by `FleetReport`, and — for `TenantFair` — the quota table.

use anyhow::{bail, Result};

use super::replica::Replica;
use crate::api::{SubmitRequest, TenantQuotas};
use crate::util::rng::Rng;

/// Replicas per routing cell in the two-tier sampling hierarchy. Small
/// enough that refreshing one dirty cell is a bounded scan; large
/// enough that a 1k-replica fleet has only ~32 cells.
const CELL_SIZE: usize = 32;

/// Aggregate elastic outlook for one cell, maintained lazily: the
/// fleet marks a cell dirty whenever a member replica's schedule is
/// recomputed ([`Router::note_dirty`]), and the sampler refreshes a
/// dirty cell only when it is actually sampled.
#[derive(Clone, Copy, Debug, Default)]
struct CellAgg {
    /// Members currently accepting new work.
    accepting: usize,
    /// Summed elastic headroom (bytes) across members at last refresh.
    headroom: u64,
}

/// Power-of-d-choices placement state: pick two cells, refresh their
/// aggregates if stale, then score `d` sampled members of the better
/// cell with the exact RAP-aware formula. Routing touches O(d + cell)
/// replicas instead of the whole roster.
struct Sampler {
    d: usize,
    rng: Rng,
    agg: Vec<CellAgg>,
    dirty: Vec<bool>,
}

/// NaN-safe argmax fold shared by every float-scored selection in the
/// coordinator (full placement, sampled placement, migration
/// targeting): a NaN score can never become — or displace — the best
/// candidate, and ties break toward the lowest index regardless of
/// visit order.
pub(crate) fn fold_best(best: &mut Option<(usize, f64)>, i: usize,
                        score: f64) {
    if score.is_nan() {
        return;
    }
    let better = best.map_or(true, |(bi, bs)| {
        score > bs || (score == bs && i < bi)
    });
    if better {
        *best = Some((i, score));
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastOutstanding,
    KvHeadroom,
    RapAware,
    TenantFair,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 5] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastOutstanding,
        RouterPolicy::KvHeadroom,
        RouterPolicy::RapAware,
        RouterPolicy::TenantFair,
    ];

    pub fn parse(s: &str) -> Result<RouterPolicy> {
        Ok(match s {
            "rr" | "round-robin" => RouterPolicy::RoundRobin,
            "least" | "least-outstanding" => RouterPolicy::LeastOutstanding,
            "kv" | "kv-headroom" => RouterPolicy::KvHeadroom,
            "rap" | "rap-aware" => RouterPolicy::RapAware,
            "tenant" | "tenant-fair" => RouterPolicy::TenantFair,
            _ => bail!("unknown router '{s}' (expected round-robin | \
                        least-outstanding | kv-headroom | rap-aware | \
                        tenant-fair)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastOutstanding => "least-outstanding",
            RouterPolicy::KvHeadroom => "kv-headroom",
            RouterPolicy::RapAware => "rap-aware",
            RouterPolicy::TenantFair => "tenant-fair",
        }
    }
}

pub struct Router {
    pub policy: RouterPolicy,
    /// Routing histogram: requests dispatched to each replica index.
    pub decisions: Vec<u64>,
    /// Per-tenant KV-byte quotas (consulted only by `TenantFair`;
    /// unlimited by default, so tenant-fair without quotas degrades to
    /// pure RAP-aware placement).
    pub quotas: TenantQuotas,
    rr_next: usize,
    sampler: Option<Sampler>,
}

impl Router {
    pub fn new(policy: RouterPolicy, n_replicas: usize) -> Router {
        Router { policy, decisions: vec![0; n_replicas],
                 quotas: TenantQuotas::unlimited(), rr_next: 0,
                 sampler: None }
    }

    /// Install a quota table (tenant-fair fleets).
    pub fn with_quotas(mut self, quotas: TenantQuotas) -> Router {
        self.quotas = quotas;
        self
    }

    /// Switch RAP-aware/tenant-fair placement to power-of-`d`-choices
    /// sampling over the cell hierarchy (`FleetConfig::sample_d`). The
    /// seeded RNG keeps sampled placement deterministic per run.
    pub fn enable_sampling(&mut self, d: usize, seed: u64) {
        let n_cells =
            self.decisions.len().div_ceil(CELL_SIZE).max(1);
        self.sampler = Some(Sampler {
            d: d.max(1),
            rng: Rng::new(seed),
            agg: vec![CellAgg::default(); n_cells],
            dirty: vec![true; n_cells],
        });
    }

    /// Mark `replica`'s cell stale. The fleet calls this from `wake`
    /// whenever a replica's schedule (and thus its accepting state or
    /// headroom outlook) may have changed; the cell aggregate is
    /// rebuilt the next time the sampler lands on it. No-op without
    /// sampling.
    pub fn note_dirty(&mut self, replica: usize) {
        let Some(s) = self.sampler.as_mut() else { return };
        let cell = replica / CELL_SIZE;
        if cell >= s.agg.len() {
            s.agg.resize(cell + 1, CellAgg::default());
            s.dirty.resize(cell + 1, true);
        }
        s.dirty[cell] = true;
    }

    /// RAP-aware placement: the best replica for `req` right now,
    /// without touching the histogram. `None` only when no replica is
    /// accepting (a sampled miss falls back to the full scan, so the
    /// contract holds with sampling on). The `RapAware` and
    /// `TenantFair` arms of [`Router::route`] delegate here; the
    /// fleet's tenant-fair dispatcher also calls it directly to price
    /// a backlogged head before committing quota. Takes `&mut self`
    /// for the sampler's RNG and lazy cell aggregates.
    pub fn place(&mut self, req: &SubmitRequest, replicas: &[Replica],
                 t: f64) -> Option<usize> {
        if self.sampler.is_some() {
            if let Some(pick) = self.place_sampled(req, replicas, t) {
                return Some(pick);
            }
            // The sampled cells held no accepting (or no sampled
            // accepting) replica. Fall back to the full scan so the
            // contract stays exact: `Some` iff any replica accepts.
        }
        self.place_full(req, replicas, t)
    }

    /// Exact RAP-aware score for one accepting replica: the shared
    /// arithmetic of `place_full` and the sampled final pass.
    fn rap_score(r: &Replica, req: &SubmitRequest, t: f64) -> f64 {
        let headroom = r.elastic_headroom(t) as f64;
        // like for like: elastic headroom vs the request's cost
        // under the mask this replica could shrink to
        let cost = r.engine.elastic_admission_cost(req) as f64;
        let surplus = headroom - cost;
        if surplus > 0.0 {
            // feasible: quality-weighted memory surplus, discounted
            // by queue depth — always > 0, so every feasible
            // replica outranks every infeasible one
            r.mask_utility() * surplus / (1.0 + r.outstanding() as f64)
        } else {
            // infeasible right now: rank by RAW deficit far below
            // all feasible scores (never scale a negative surplus
            // by utility — that inverts the preference),
            // least-underwater first
            surplus - 1e18
        }
    }

    /// Full-roster RAP-aware placement — the exact baseline and the
    /// fallback when sampling finds nothing.
    fn place_full(&self, req: &SubmitRequest, replicas: &[Replica],
                  t: f64) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in replicas.iter().enumerate() {
            if !r.accepting() {
                continue;
            }
            fold_best(&mut best, i, Router::rap_score(r, req, t));
        }
        best.map(|(i, _)| i)
    }

    /// Power-of-d-choices placement: sample two cells, refresh their
    /// aggregates if dirty, then score `d` random members of the
    /// better cell exactly. Returns `None` when the sampled slice of
    /// the fleet shows nothing accepting — callers fall back to the
    /// full scan to preserve the `Some`-iff-any-accepting contract.
    fn place_sampled(&mut self, req: &SubmitRequest,
                     replicas: &[Replica], t: f64) -> Option<usize> {
        let n = replicas.len();
        if n == 0 {
            return None;
        }
        let s = self.sampler.as_mut()?;
        let n_cells = n.div_ceil(CELL_SIZE);
        if s.agg.len() < n_cells {
            s.agg.resize(n_cells, CellAgg::default());
            s.dirty.resize(n_cells, true);
        }
        // two-choice over cells, refreshing dirty aggregates on touch
        let ca = s.rng.below(n_cells);
        let cb = s.rng.below(n_cells);
        for &c in &[ca, cb] {
            if s.dirty[c] {
                let lo = c * CELL_SIZE;
                let hi = (lo + CELL_SIZE).min(n);
                let mut agg = CellAgg::default();
                for r in &replicas[lo..hi] {
                    if r.accepting() {
                        agg.accepting += 1;
                        agg.headroom += r.elastic_headroom(t) as u64;
                    }
                }
                s.agg[c] = agg;
                s.dirty[c] = false;
            }
        }
        let pick_cell = |c: usize| -> Option<(usize, u64)> {
            (s.agg[c].accepting > 0).then(|| (c, s.agg[c].headroom))
        };
        let cell = match (pick_cell(ca), pick_cell(cb)) {
            (Some((c, ha)), Some((_, hb))) if ha >= hb => c,
            (Some(_), Some((c, _))) => c,
            (Some((c, _)), None) | (None, Some((c, _))) => c,
            (None, None) => return None,
        };
        // d samples (with replacement) inside the cell, scored with
        // the exact RAP formula; ties break toward the lowest index
        // regardless of sample order
        let lo = cell * CELL_SIZE;
        let len = (lo + CELL_SIZE).min(n) - lo;
        let mut best: Option<(usize, f64)> = None;
        for _ in 0..s.d {
            let i = lo + s.rng.below(len);
            let r = &replicas[i];
            if !r.accepting() {
                continue;
            }
            fold_best(&mut best, i, Router::rap_score(r, req, t));
        }
        best.map(|(i, _)| i)
    }

    /// Pick a replica index for `req` at sim time `t`, or `None` when no
    /// replica is accepting. Ties break toward the lowest index so every
    /// policy is deterministic.
    pub fn route(&mut self, req: &SubmitRequest, replicas: &[Replica],
                 t: f64) -> Option<usize> {
        // The RAP-aware policies go straight through `place` (possibly
        // sampled) — building a full accepting-index vec per request
        // is exactly the O(N) scan the sampler exists to avoid.
        if matches!(self.policy,
                    RouterPolicy::RapAware | RouterPolicy::TenantFair)
        {
            let pick = self.place(req, replicas, t)?;
            self.decisions[pick] += 1;
            return Some(pick);
        }
        let accepting: Vec<usize> = replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.accepting())
            .map(|(i, _)| i)
            .collect();
        if accepting.is_empty() {
            return None;
        }
        let pick = match self.policy {
            RouterPolicy::RoundRobin => {
                let n = replicas.len();
                let mut chosen = accepting[0];
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if replicas[i].accepting() {
                        chosen = i;
                        break;
                    }
                }
                self.rr_next = (chosen + 1) % n;
                chosen
            }
            RouterPolicy::LeastOutstanding => *accepting
                .iter()
                .min_by_key(|&&i| (replicas[i].outstanding(), i))
                // lint:allow(hot-path-panic): accepting non-empty
                .unwrap(),
            RouterPolicy::KvHeadroom => *accepting
                .iter()
                .max_by_key(|&&i| {
                    (replicas[i].elastic_headroom(t),
                     std::cmp::Reverse(i))
                })
                // lint:allow(hot-path-panic): accepting non-empty
                .unwrap(),
            // handled above, before the accepting-vec scan
            RouterPolicy::RapAware | RouterPolicy::TenantFair => {
                // lint:allow(hot-path-panic): both arms return early
                unreachable!("RAP-aware policies return early")
            }
        };
        self.decisions[pick] += 1;
        Some(pick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SubmitRequest;
    use crate::coordinator::replica::{build_sim_replica, ReplicaSpec,
                                      ReplicaState};
    use crate::model_meta::ModelMeta;
    use crate::server::memmon::MemoryMonitor;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("r", 4, 128, 8, 4, 512, 512, 256)
    }

    fn req(id: u64) -> SubmitRequest {
        SubmitRequest::new(12, 6).with_id(id)
    }

    fn quiet_spec() -> ReplicaSpec {
        ReplicaSpec { app_rate: 0.0, ..ReplicaSpec::heterogeneous(0) }
    }

    fn fleet_of(n: usize) -> Vec<Replica> {
        (0..n).map(|i| build_sim_replica(i, &meta(), &quiet_spec(), 3))
            .collect()
    }

    #[test]
    fn parse_accepts_aliases_and_rejects_junk() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(),
                   RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("rap-aware").unwrap(),
                   RouterPolicy::RapAware);
        assert_eq!(RouterPolicy::parse("kv").unwrap(),
                   RouterPolicy::KvHeadroom);
        assert_eq!(RouterPolicy::parse("tenant-fair").unwrap(),
                   RouterPolicy::TenantFair);
        assert_eq!(RouterPolicy::parse("tenant").unwrap(),
                   RouterPolicy::TenantFair);
        assert!(RouterPolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_skips_draining() {
        let mut reps = fleet_of(3);
        let mut router = Router::new(RouterPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6)
            .map(|i| router.route(&req(i), &reps, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        reps[1].state = ReplicaState::Draining;
        let picks: Vec<usize> = (0..4)
            .map(|i| router.route(&req(10 + i), &reps, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        assert_eq!(router.decisions, vec![4, 2, 4]);
    }

    #[test]
    fn least_outstanding_prefers_empty() {
        let mut reps = fleet_of(2);
        reps[0].submit(req(100), 0.0);
        reps[0].submit(req(101), 0.0);
        let mut router = Router::new(RouterPolicy::LeastOutstanding, 2);
        assert_eq!(router.route(&req(0), &reps, 0.0), Some(1));
    }

    #[test]
    fn memory_aware_policies_avoid_underwater_replica() {
        let mut reps = fleet_of(2);
        // drown replica 0: permanent interference leaves less than the
        // dense parameter footprint available
        let params = reps[0].engine.bytes_used();
        let cap = (params as f64 * 1.2) as usize;
        reps[0].engine.monitor =
            MemoryMonitor::walls(cap, &[(0.0, 1e12, cap - params / 2)]);
        assert_eq!(reps[0].kv_headroom(0.0), 0);
        for policy in [RouterPolicy::KvHeadroom, RouterPolicy::RapAware,
                       RouterPolicy::TenantFair] {
            let mut router = Router::new(policy, 2);
            for i in 0..8 {
                assert_eq!(router.route(&req(i), &reps, 0.0), Some(1),
                           "{:?}", policy);
            }
        }
    }

    /// Regression (ISSUE 4): with every replica infeasible, the naive
    /// `utility × (headroom − cost)` score prefers the *low-utility*
    /// replica (its utility shrinks the penalty), inverting the
    /// preference. The raw-deficit ranking must pick the
    /// least-underwater replica instead.
    #[test]
    fn rap_aware_ranks_infeasible_by_raw_deficit() {
        use crate::model_meta::BlockId;

        let mut reps = fleet_of(2);
        let r = req(0);
        // replica 0: low utility (3 of 4 FFN blocks gone — KV cost is
        // unaffected) and zero headroom → deficit == full cost
        for l in 0..3 {
            reps[0].engine.mask.drop_block(BlockId::Ffn(l));
        }
        let p0 = reps[0].engine.bytes_used();
        reps[0].engine.monitor =
            MemoryMonitor::walls(p0 * 2, &[(0.0, 1e12, p0)]);
        let cost = reps[0].engine.admission_cost(&r);
        assert_eq!(reps[0].kv_headroom(0.0), 0);
        // replica 1: dense, and underwater by only half the cost
        let p1 = reps[1].engine.bytes_used();
        let cap = p1 * 2;
        reps[1].engine.monitor = MemoryMonitor::walls(
            cap, &[(0.0, 1e12, cap - p1 - cost / 2)]);
        assert!(reps[1].kv_headroom(0.0) < cost);
        // sanity: the naive utility-scaled penalty really would invert
        let u0 = reps[0].mask_utility();
        assert!(u0 * cost as f64
                    < (cost - reps[1].kv_headroom(0.0)) as f64,
                "scenario no longer exercises the inversion");
        let mut router = Router::new(RouterPolicy::RapAware, 2);
        assert_eq!(router.route(&r, &reps, 0.0), Some(1),
                   "picked the deeper-underwater replica");
    }

    /// Regression (ISSUE 10): a NaN score must never win — or poison —
    /// a placement. `fold_best` is the single argmax that full
    /// placement, sampled placement, and migration targeting all go
    /// through; with the old `score > best` fold a first-seen NaN won
    /// and then repelled every finite challenger (`x > NaN` is false).
    #[test]
    fn nan_scores_cannot_win_placement() {
        let mut best = None;
        fold_best(&mut best, 0, f64::NAN);
        assert_eq!(best, None, "leading NaN became the best candidate");
        fold_best(&mut best, 1, -3.0);
        fold_best(&mut best, 2, f64::NAN);
        fold_best(&mut best, 3, 7.0);
        assert_eq!(best, Some((3, 7.0)));
        // ties break toward the lowest index regardless of visit order
        let mut tie = None;
        fold_best(&mut tie, 5, 1.0);
        fold_best(&mut tie, 2, 1.0);
        fold_best(&mut tie, 9, 1.0);
        assert_eq!(tie, Some((2, 1.0)));
        // all-NaN: no candidate at all rather than an arbitrary pick
        let mut none = None;
        for i in 0..4 {
            fold_best(&mut none, i, f64::NAN);
        }
        assert_eq!(none, None);
    }

    #[test]
    fn none_when_no_replica_accepting() {
        let mut reps = fleet_of(1);
        reps[0].state = ReplicaState::Draining;
        let mut router = Router::new(RouterPolicy::RapAware, 1);
        assert_eq!(router.route(&req(0), &reps, 0.0), None);
        // the stateless placer agrees
        assert_eq!(router.place(&req(0), &reps, 0.0), None);
    }

    /// With sampling on, `place` still returns `Some` iff any replica
    /// is accepting: a sampled miss must fall back to the full scan.
    #[test]
    fn sampled_place_preserves_some_iff_accepting() {
        let mut reps = fleet_of(70); // 3 cells (32 + 32 + 6)
        let mut router = Router::new(RouterPolicy::RapAware, 70);
        router.enable_sampling(2, 0xDEAD);
        for i in 0..64 {
            let pick = router.route(&req(i), &reps, 0.0)
                .expect("everything accepting");
            assert!(reps[pick].accepting());
        }
        // exactly one accepting replica, in the last (partial) cell:
        // sampling may miss it, the fallback must not
        for (i, r) in reps.iter_mut().enumerate() {
            if i != 69 {
                r.state = ReplicaState::Draining;
            }
        }
        for c in 0..3 {
            router.note_dirty(c * 32);
        }
        for i in 0..16 {
            assert_eq!(router.route(&req(100 + i), &reps, 0.0),
                       Some(69));
        }
        reps[69].state = ReplicaState::Draining;
        router.note_dirty(69);
        assert_eq!(router.route(&req(200), &reps, 0.0), None);
    }

    /// Same seed → same sampled pick sequence (the event-driven
    /// fleet's byte-identical reports depend on this).
    #[test]
    fn sampled_place_is_deterministic_per_seed() {
        let reps = fleet_of(70);
        let run = || {
            let mut router = Router::new(RouterPolicy::RapAware, 70);
            router.enable_sampling(2, 42);
            (0..64).map(|i| router.route(&req(i), &reps, 0.0))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    /// `place` is `route`'s RapAware arm without the histogram side
    /// effect — the tenant-fair dispatcher relies on the two agreeing.
    #[test]
    fn place_matches_rap_aware_route() {
        let reps = fleet_of(3);
        let mut router = Router::new(RouterPolicy::RapAware, 3);
        for i in 0..6 {
            let placed = router.place(&req(i), &reps, 1.0);
            let routed = router.route(&req(i), &reps, 1.0);
            assert_eq!(placed, routed);
        }
        assert_eq!(router.decisions.iter().sum::<u64>(), 6,
                   "place must not touch the histogram");
    }
}

//! Request routing across fleet replicas.
//!
//! Four policies, from memory-blind to fully RAP-aware:
//!
//!   * `RoundRobin`       — cyclic dispatch over accepting replicas (the
//!                          memory-blind baseline every LB starts with);
//!   * `LeastOutstanding` — classic least-loaded by queued + in-flight
//!                          requests;
//!   * `KvHeadroom`       — most free memory: `Sys_avail(t)` minus the
//!                          replica's current footprint;
//!   * `RapAware`         — scores feasibility *for this request*: the
//!                          request's estimated KV bytes under each
//!                          replica's current mask against that replica's
//!                          headroom, weighted by mask utility (quality
//!                          of the deployed model) and queue depth. This
//!                          is the fleet-level analogue of the paper's
//!                          (workload, Sys_avail) state vector.
//!
//! The router also owns the routing histogram (decisions per replica)
//! reported by `FleetReport`.

use anyhow::{bail, Result};

use super::replica::Replica;
use crate::workload::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastOutstanding,
    KvHeadroom,
    RapAware,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 4] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastOutstanding,
        RouterPolicy::KvHeadroom,
        RouterPolicy::RapAware,
    ];

    pub fn parse(s: &str) -> Result<RouterPolicy> {
        Ok(match s {
            "rr" | "round-robin" => RouterPolicy::RoundRobin,
            "least" | "least-outstanding" => RouterPolicy::LeastOutstanding,
            "kv" | "kv-headroom" => RouterPolicy::KvHeadroom,
            "rap" | "rap-aware" => RouterPolicy::RapAware,
            _ => bail!("unknown router '{s}' (expected round-robin | \
                        least-outstanding | kv-headroom | rap-aware)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastOutstanding => "least-outstanding",
            RouterPolicy::KvHeadroom => "kv-headroom",
            RouterPolicy::RapAware => "rap-aware",
        }
    }
}

pub struct Router {
    pub policy: RouterPolicy,
    /// Routing histogram: requests dispatched to each replica index.
    pub decisions: Vec<u64>,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy, n_replicas: usize) -> Router {
        Router { policy, decisions: vec![0; n_replicas], rr_next: 0 }
    }

    /// Pick a replica index for `req` at sim time `t`, or `None` when no
    /// replica is accepting. Ties break toward the lowest index so every
    /// policy is deterministic.
    pub fn route(&mut self, req: &Request, replicas: &[Replica], t: f64)
                 -> Option<usize> {
        let accepting: Vec<usize> = replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.accepting())
            .map(|(i, _)| i)
            .collect();
        if accepting.is_empty() {
            return None;
        }
        let pick = match self.policy {
            RouterPolicy::RoundRobin => {
                let n = replicas.len();
                let mut chosen = accepting[0];
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if replicas[i].accepting() {
                        chosen = i;
                        break;
                    }
                }
                self.rr_next = (chosen + 1) % n;
                chosen
            }
            RouterPolicy::LeastOutstanding => *accepting
                .iter()
                .min_by_key(|&&i| (replicas[i].outstanding(), i))
                .unwrap(),
            RouterPolicy::KvHeadroom => *accepting
                .iter()
                .max_by_key(|&&i| {
                    (replicas[i].kv_headroom(t), std::cmp::Reverse(i))
                })
                .unwrap(),
            RouterPolicy::RapAware => {
                let mut best: Option<(usize, f64)> = None;
                for &i in &accepting {
                    let r = &replicas[i];
                    let headroom = r.kv_headroom(t) as f64;
                    let cost = r.engine.admission_cost(req) as f64;
                    let score = if headroom > cost {
                        // feasible: quality-weighted memory surplus,
                        // discounted by queue depth
                        r.mask_utility() * (headroom - cost)
                            / (1.0 + r.outstanding() as f64)
                    } else {
                        // infeasible right now: rank far below every
                        // feasible replica, least-underwater first
                        (headroom - cost) - 1e18
                    };
                    if best.map_or(true, |(_, s)| score > s) {
                        best = Some((i, score));
                    }
                }
                best.unwrap().0
            }
        };
        self.decisions[pick] += 1;
        Some(pick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::replica::{build_sim_replica, ReplicaSpec,
                                      ReplicaState};
    use crate::model_meta::ModelMeta;
    use crate::server::memmon::{MemMonConfig, MemoryMonitor};

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("r", 4, 128, 8, 4, 512, 512, 256)
    }

    fn req(id: u64) -> Request {
        Request { id, arrival: 0.0, prompt_len: 12, gen_len: 6 }
    }

    fn quiet_spec() -> ReplicaSpec {
        ReplicaSpec { app_rate: 0.0, ..ReplicaSpec::heterogeneous(0) }
    }

    fn fleet_of(n: usize) -> Vec<Replica> {
        (0..n).map(|i| build_sim_replica(i, &meta(), &quiet_spec(), 3))
            .collect()
    }

    #[test]
    fn parse_accepts_aliases_and_rejects_junk() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(),
                   RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("rap-aware").unwrap(),
                   RouterPolicy::RapAware);
        assert_eq!(RouterPolicy::parse("kv").unwrap(),
                   RouterPolicy::KvHeadroom);
        assert!(RouterPolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_skips_draining() {
        let mut reps = fleet_of(3);
        let mut router = Router::new(RouterPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6)
            .map(|i| router.route(&req(i), &reps, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        reps[1].state = ReplicaState::Draining;
        let picks: Vec<usize> = (0..4)
            .map(|i| router.route(&req(10 + i), &reps, 0.0).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        assert_eq!(router.decisions, vec![4, 2, 4]);
    }

    #[test]
    fn least_outstanding_prefers_empty() {
        let mut reps = fleet_of(2);
        reps[0].enqueue(req(100));
        reps[0].enqueue(req(101));
        let mut router = Router::new(RouterPolicy::LeastOutstanding, 2);
        assert_eq!(router.route(&req(0), &reps, 0.0), Some(1));
    }

    #[test]
    fn memory_aware_policies_avoid_underwater_replica() {
        let mut reps = fleet_of(2);
        // drown replica 0: permanent interference leaves less than the
        // dense parameter footprint available
        let params = reps[0].engine.bytes_used();
        let cap = (params as f64 * 1.2) as usize;
        reps[0].engine.monitor = MemoryMonitor::with_spans(
            MemMonConfig::for_capacity(cap),
            &[(0.0, 1e12, cap - params / 2)]);
        assert_eq!(reps[0].kv_headroom(0.0), 0);
        for policy in [RouterPolicy::KvHeadroom, RouterPolicy::RapAware] {
            let mut router = Router::new(policy, 2);
            for i in 0..8 {
                assert_eq!(router.route(&req(i), &reps, 0.0), Some(1),
                           "{:?}", policy);
            }
        }
    }

    #[test]
    fn none_when_no_replica_accepting() {
        let mut reps = fleet_of(1);
        reps[0].state = ReplicaState::Draining;
        let mut router = Router::new(RouterPolicy::RapAware, 1);
        assert_eq!(router.route(&req(0), &reps, 0.0), None);
    }
}

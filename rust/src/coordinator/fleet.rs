//! The fleet event loop: one shared simulated clock driving N externally
//! stepped engines, a router in front, and a drain/respawn maintenance
//! pass for replicas under sustained OOM pressure.
//!
//! Time model: the fleet advances in events — the next trace arrival or
//! the next maintenance tick, whichever comes first. Every replica is
//! stepped to that time (`Replica::step_to`), then due arrivals are
//! routed. Individual engines may overshoot the barrier by at most one
//! compute step (documented on `Engine::step_to`); latency accounting
//! uses true arrival times, so the skew never leaks into metrics.

use anyhow::Result;

use super::metrics::{FleetReport, ReplicaReport};
use super::replica::{build_sim_replica, Replica, ReplicaSpec,
                     ReplicaState};
use super::router::{Router, RouterPolicy};
use crate::model_meta::ModelMeta;
use crate::util::stats::{mean, percentile};
use crate::workload::{Request, TraceConfig, TraceGenerator};

#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Drain a Serving replica when it sees at least this many OOM
    /// events within `oom_window_secs` (usize::MAX disables draining).
    pub oom_threshold: usize,
    pub oom_window_secs: f64,
    /// Offline cool-down after a drain completes.
    pub respawn_secs: f64,
    /// Maintenance cadence (drain/respawn checks between arrivals).
    pub tick_secs: f64,
    /// Hard stop for one `run_trace` call (sim seconds).
    pub max_sim_secs: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            oom_threshold: 8,
            oom_window_secs: 20.0,
            respawn_secs: 8.0,
            tick_secs: 0.5,
            max_sim_secs: 3600.0,
        }
    }
}

pub struct Fleet {
    pub cfg: FleetConfig,
    pub replicas: Vec<Replica>,
    pub router: Router,
    /// The shared simulated clock.
    pub clock: f64,
    /// Arrivals no accepting replica could take.
    pub dropped: u64,
}

impl Fleet {
    pub fn new(replicas: Vec<Replica>, router: Router, cfg: FleetConfig)
               -> Fleet {
        assert_eq!(router.decisions.len(), replicas.len(),
                   "router sized for a different fleet");
        Fleet { cfg, replicas, router, clock: 0.0, dropped: 0 }
    }

    fn all_idle(&self) -> bool {
        self.replicas.iter().all(|r| r.engine.idle())
    }

    /// Step every replica to `t`, then run the drain/respawn pass.
    fn step_all(&mut self, t: f64) -> Result<()> {
        for r in &mut self.replicas {
            r.step_to(t)?;
        }
        self.maintain(t);
        Ok(())
    }

    /// Lifecycle maintenance: drain replicas under sustained pressure
    /// (never the last serving one), move drained-empty replicas into
    /// their respawn cool-down. Respawn completion happens inside
    /// `Replica::step_to`.
    fn maintain(&mut self, t: f64) {
        let mut serving = self
            .replicas
            .iter()
            .filter(|r| r.accepting())
            .count();
        let window = self.cfg.oom_window_secs;
        let threshold = self.cfg.oom_threshold;
        for r in &mut self.replicas {
            match r.state {
                ReplicaState::Serving => {
                    if threshold != usize::MAX
                        && serving > 1
                        && r.recent_ooms(t, window) >= threshold
                    {
                        r.state = ReplicaState::Draining;
                        serving -= 1;
                    }
                }
                ReplicaState::Draining => {
                    if r.engine.idle() {
                        r.state = ReplicaState::Respawning {
                            until: t + self.cfg.respawn_secs,
                        };
                        r.respawns += 1;
                    }
                }
                ReplicaState::Respawning { .. } => {}
            }
        }
    }

    /// Replay a trace across the fleet and report. Arrivals are routed
    /// at their arrival time; the run ends when all work has drained (or
    /// at `max_sim_secs`).
    pub fn run_trace(&mut self, mut requests: Vec<Request>)
                     -> Result<FleetReport> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        // relative to where the shared clock already is, so a Fleet can
        // replay several traces back to back (mirrors Engine::run_trace)
        let deadline = self.clock + self.cfg.max_sim_secs;
        let mut next = 0usize;
        while self.clock < deadline {
            let mut target = self.clock + self.cfg.tick_secs;
            if next < requests.len() {
                target = target.min(requests[next].arrival);
            }
            target = target.min(deadline).max(self.clock + 1e-9);
            self.step_all(target)?;
            self.clock = target;
            while next < requests.len()
                && requests[next].arrival <= self.clock
            {
                let req = requests[next].clone();
                next += 1;
                match self.router.route(&req, &self.replicas, self.clock) {
                    Some(i) => self.replicas[i].enqueue(req),
                    None => self.dropped += 1,
                }
            }
            if next >= requests.len() && self.all_idle() {
                break;
            }
        }
        // Arrivals past the deadline were never offered to the router;
        // count them as dropped so the report's accounting invariant
        // (routing-histogram sum + dropped == trace length) holds even
        // on a truncated run.
        self.dropped += (requests.len() - next) as u64;
        Ok(self.report())
    }

    /// Snapshot the fleet's metrics (callable after `run_trace`).
    pub fn report(&self) -> FleetReport {
        let wall = self.clock.max(1e-9);
        let mut lats = Vec::new();
        let mut ttfts = Vec::new();
        let mut completed = 0usize;
        let mut rejected = 0u64;
        let mut oom_events = 0u64;
        let mut respawns = 0u64;
        let mut replicas = Vec::with_capacity(self.replicas.len());
        for r in &self.replicas {
            for rec in &r.engine.metrics.completed {
                lats.push(rec.latency());
                ttfts.push(rec.ttft());
            }
            completed += r.engine.metrics.completed.len();
            rejected += r.engine.metrics.rejected;
            oom_events += r.engine.metrics.oom_events;
            respawns += r.respawns;
            replicas.push(ReplicaReport {
                id: r.id,
                state: r.state.name().to_string(),
                capacity_bytes: r.engine.monitor.cfg.capacity,
                routed: r.routed,
                respawns: r.respawns,
                serve: r.engine.metrics.report(wall),
            });
        }
        let routed: u64 = self.router.decisions.iter().sum();
        FleetReport {
            policy: self.router.policy.name().to_string(),
            sim_secs: self.clock,
            total_requests: routed + self.dropped,
            completed,
            rejected,
            dropped: self.dropped,
            oom_events,
            respawns,
            mean_latency: mean(&lats),
            p50_latency: percentile(&lats, 50.0),
            p99_latency: percentile(&lats, 99.0),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            throughput_rps: completed as f64 / wall,
            routing: self.router.decisions.clone(),
            replicas,
        }
    }
}

/// The model every default sim replica serves: small enough that fleet
/// sweeps are instant, large enough (max_seq 256) that the default trace
/// config's prompt buckets + generations fit a sequence.
pub fn default_sim_meta() -> ModelMeta {
    ModelMeta::synthetic("fleet-sim", 4, 128, 8, 4, 512, 512, 256)
}

/// N heterogeneous sim replicas (capacity / interference / device speed
/// from `ReplicaSpec::heterogeneous`) behind a router. Deterministic per
/// seed.
pub fn default_sim_fleet(n_replicas: usize, seed: u64,
                         policy: RouterPolicy) -> Fleet {
    let meta = default_sim_meta();
    let replicas: Vec<Replica> = (0..n_replicas)
        .map(|i| build_sim_replica(i, &meta,
                                   &ReplicaSpec::heterogeneous(i), seed))
        .collect();
    let router = Router::new(policy, n_replicas);
    Fleet::new(replicas, router, FleetConfig::default())
}

/// A diurnal + bursty trace sized for `default_sim_meta` (generation cap
/// keeps prefill-bucket + generated tokens within max_seq).
pub fn default_fleet_trace(seed: u64, secs: f64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 2.0,
            day_secs: secs.max(60.0),
            bursts_per_day: (secs / 60.0).ceil().max(1.0),
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed,
    );
    gen.generate(0.0, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_serves_a_trace_and_reports() {
        let mut fleet = default_sim_fleet(3, 9, RouterPolicy::RapAware);
        let reqs = default_fleet_trace(9, 30.0);
        let n = reqs.len() as u64;
        assert!(n > 0);
        let report = fleet.run_trace(reqs).unwrap();
        assert_eq!(report.total_requests, n);
        assert_eq!(report.routing.iter().sum::<u64>() + report.dropped, n);
        assert!(report.completed > 0, "nothing completed");
        assert_eq!(report.replicas.len(), 3);
        assert!(report.sim_secs > 0.0);
        // every arrival is accounted for: finished, rejected somewhere,
        // or dropped at the router
        assert!(report.completed as u64 + report.rejected + report.dropped
                >= n);
    }

    #[test]
    fn fleet_runs_are_deterministic_per_seed() {
        let run = |seed| {
            let mut fleet =
                default_sim_fleet(2, seed, RouterPolicy::KvHeadroom);
            fleet.run_trace(default_fleet_trace(seed, 20.0)).unwrap()
        };
        let a = run(4);
        let b = run(4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.oom_events, b.oom_events);
        assert_eq!(a.routing, b.routing);
        assert_eq!(a.sim_secs, b.sim_secs);
        let c = run(5);
        assert!(a.routing != c.routing || a.completed != c.completed
                || a.sim_secs != c.sim_secs,
                "different seeds should differ somewhere");
    }

    #[test]
    fn drain_and_respawn_cycle_under_forced_pressure() {
        use crate::server::memmon::{MemMonConfig, MemoryMonitor};

        let mut fleet = default_sim_fleet(2, 3, RouterPolicy::RoundRobin);
        fleet.cfg.oom_threshold = 2;
        fleet.cfg.respawn_secs = 4.0;
        // replica 0 permanently underwater → every routed request OOMs
        let params = fleet.replicas[0].engine.bytes_used();
        let cap = (params as f64 * 1.1) as usize;
        fleet.replicas[0].engine.monitor = MemoryMonitor::with_spans(
            MemMonConfig::for_capacity(cap), &[(0.0, 1e12, cap)]);
        let reqs: Vec<Request> = (0..24)
            .map(|i| Request { id: i, arrival: i as f64 * 0.25,
                               prompt_len: 12, gen_len: 4 })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        assert!(report.respawns >= 1,
                "pressured replica never respawned: {report:?}");
        // the healthy replica kept serving throughout
        assert!(report.replicas[1].serve.completed > 0);
    }
}

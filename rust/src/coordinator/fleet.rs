//! The fleet event loop: one shared simulated clock driving N externally
//! stepped engines, the typed request ingress in front
//! (`Fleet::submit` / `poll` / `cancel` — see `crate::api`), and a
//! maintenance pass that keeps the fleet healthy — drain/respawn for
//! replicas under sustained OOM pressure, cross-replica migration of
//! in-flight sequences (`FleetConfig::migrate`), and autoscaling
//! (`FleetConfig::autoscale`, with an optional warm-up cost on spawn).
//!
//! Ingress model: every request enters as a typed
//! [`SubmitRequest`] through [`Fleet::submit`]. Trace replay is a thin
//! adapter over this ([`Fleet::run_trace`] maps the trace through
//! `api::from_trace` and drives [`Fleet::run_requests`]), so the router,
//! the engines, and the autoscaler all see one ingress path. Under the
//! `tenant-fair` router, arrivals land in a per-tenant ingress backlog
//! and are released against per-tenant KV-byte quotas
//! (`Fleet::dispatch_ingress`); every other policy dispatches on
//! arrival, exactly as before.
//!
//! Time model: the fleet advances in events — the next trace arrival or
//! the next maintenance tick, whichever comes first. At each such
//! barrier the *due* replicas are stepped (`Replica::step_to`), then
//! due arrivals are routed. Under the default event-driven scheduler
//! (`FleetConfig::event_driven`) the due set is every replica holding
//! work plus every queued lifecycle wake-up (warm-up / respawn
//! completion) drained from a priority queue in (time, replica id,
//! seq) order — idle replicas are skipped entirely and their engine
//! clocks jumped forward lazily when work next reaches them, which is
//! a pure clock jump for an idle engine, so seeded reports are
//! byte-identical to the lockstep sweep (`event_driven: false`).
//! Individual engines may overshoot the barrier by at most one
//! compute step (documented on `Engine::step_to`); latency accounting
//! uses true arrival times, so the skew never leaks into metrics.
//!
//! Migration model: when interference collapses a replica's
//! `Sys_avail(t)` headroom, its engine parks victims (chosen by expired
//! deadline, then priority class, then KV bytes × remaining decode —
//! see `EvictionMode::Park`) instead of evicting them, and the fleet
//! ships each parked state to the peer with the most *elastic*
//! headroom, charging the sim backend's modeled transfer cost
//! (`Runtime::transfer_cost`) for the *live* KV slice (prompt +
//! generated rows under the export mask — prefill-bucket padding is
//! never shipped; `migration_bytes_padded` keeps the pre-compression
//! number for comparison) before the payload lands. Queued work on a
//! collapsed replica is rebalanced the same way before the engines
//! step, so requests are not burned by a pressure wall they never had a
//! chance against. When no peer can take a victim, the fleet falls back
//! to the classic local requeue (and charges the eviction).
//!
//! Pressure is judged *mask-elastically* (`FleetConfig::
//! elastic_accounting`, on by default): a collapse exists only when not
//! even the replica's min-viable mask fits `Sys_avail(t)` (see
//! `server::outlook::MemoryOutlook`). An interference spike the RAP
//! controller can absorb by shrinking therefore triggers no queue
//! rebalancing, no migration, and — because the engine charges it to
//! `absorbed_spikes` instead of `oom_events` — no OOM-driven
//! autoscaling. The `absorbable_spike_fleet` scenario pins this down.
//!
//! Failure model (`Fleet::with_fault_plan`): a seeded, deterministic
//! [`FaultPlan`] can crash replicas (all resident KV lost), degrade or
//! fully partition the interconnect, and reclaim spot capacity with a
//! grace window. Engines checkpoint live KV deltas periodically
//! (`FleetConfig::checkpoint_period_secs`); on a crash, checkpointed
//! sequences restore onto peers, uncheckpointed in-flight work re-enters
//! admission at the head of its priority class, and every displaced
//! request keeps a full `Outcome` lifecycle — never silently dropped,
//! never double-completed. Deliveries that hit a partition retry with
//! bounded backoff, then fall back to a local requeue. The autoscaler
//! sees crashes and reclaims as a distinct capacity-loss signal that
//! bypasses its hold (but not its cooldown). The `chaos_storm_fleet`
//! scenario pins the whole path down.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap,
                       VecDeque};
use std::rc::Rc;

use anyhow::Result;

use super::autoscaler::{Autoscaler, FleetSignals, ScaleDecision};
use super::metrics::{ChaosReport, FleetReport, FleetTenantReport,
                     ReplicaReport};
use super::replica::{build_sim_replica, Replica, ReplicaSpec,
                     ReplicaState};
use super::router::{Router, RouterPolicy};
use crate::api::{self, Outcome, PriorityClass, RequestHandle,
                 RequestStatus, SubmitRequest, Tenant, TenantQuotas};
use crate::model_meta::ModelMeta;
use crate::runtime::{FaultEvent, FaultPlan};
use crate::server::engine::{EvictionMode, SeqState};
use crate::server::metrics::TenantCounts;
use crate::telemetry::registry::{series, FLEET};
use crate::telemetry::{Bus, EventKind, Recorder, Registry,
                       SignalSnapshot};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};
use crate::workload::{Request, TraceConfig, TraceGenerator};

pub use super::autoscaler::AutoscaleConfig;

#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Drain a Serving replica when it sees at least this many OOM
    /// events within `oom_window_secs` (usize::MAX disables draining).
    pub oom_threshold: usize,
    pub oom_window_secs: f64,
    /// Offline cool-down after a drain completes.
    pub respawn_secs: f64,
    /// Maintenance cadence (drain/respawn checks between arrivals).
    pub tick_secs: f64,
    /// Hard stop for one `run_trace` call (sim seconds).
    pub max_sim_secs: f64,
    /// Migrate in-flight sequences off pressured replicas instead of
    /// evicting them locally (engines switch to `EvictionMode::Park`).
    pub migrate: bool,
    /// Spawn/retire replicas from fleet-level load signals. `None`
    /// keeps the fixed-size drain/respawn-only fleet.
    pub autoscale: Option<AutoscaleConfig>,
    /// Warm-up cost on autoscale spawn: a spawned replica spends this
    /// long in `ReplicaState::Warming` (loading weights, building
    /// caches) before it accepts routes. 0.0 — the legacy behavior —
    /// means spawned replicas serve instantly.
    pub warmup_secs: f64,
    /// Mask-elastic memory accounting (`server::outlook`): every
    /// pressure decision — engine OOMs, queue rebalancing, migration
    /// targeting, router headroom — is judged against the min-viable
    /// footprint instead of the current-mask footprint, so spikes the
    /// RAP controllers can absorb by shrinking stop triggering phantom
    /// migrations and spawns. Copied onto every replica engine. Off
    /// reproduces the pre-outlook (current-mask) behavior for
    /// comparison runs.
    pub elastic_accounting: bool,
    /// The KV leg of the joint lattice (`EngineConfig::kv_elastic`,
    /// PR-9): under pressure, engines may compress resident KV caches
    /// down to the controller's floor policy before shedding work, and
    /// every elastic-headroom consumer prices placements against the
    /// joint (mask × KV policy) `min_viable`. Requires
    /// `elastic_accounting`; off restores mask-only elasticity for
    /// comparison runs.
    pub kv_elastic: bool,
    /// Periodic crash-recovery checkpointing on every replica engine
    /// (`EngineConfig::checkpoint_period_secs`): each period an engine
    /// snapshots the live-KV *delta* of its active sequences into
    /// portable `SeqState`s, paying the modeled interconnect cost — and
    /// a crashed replica then restores that work onto peers instead of
    /// losing it. `None` (the default) runs checkpoint-free.
    pub checkpoint_period_secs: Option<f64>,
    /// Event-driven stepping (the default): each barrier advances only
    /// the replicas holding work plus the ones whose next lifecycle
    /// event (warm-up / respawn completion) is due, found through the
    /// fleet's event queue — idle replicas cost nothing. Seeded runs
    /// are byte-identical either way (`tests/event_fleet.rs` pins every
    /// scenario family); `false` restores the full lockstep sweep as
    /// the comparison baseline.
    pub event_driven: bool,
    /// Power-of-d-choices placement for the RAP-aware scorers: sample
    /// `d` replicas from the better of two routing cells (≤ 32 replicas
    /// each, ranked by aggregate elastic headroom) instead of scanning
    /// the full roster per request. `None` (the default) keeps the
    /// exact full-scan placement — sampling changes *which* accepting
    /// replica wins, so the seeded small-fleet scenarios leave it off
    /// and the scale bench turns it on.
    pub sample_d: Option<usize>,
}

impl FleetConfig {
    /// The engine-level eviction mode this fleet config implies.
    fn eviction_mode(&self) -> EvictionMode {
        if self.migrate {
            EvictionMode::Park
        } else {
            EvictionMode::Requeue
        }
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            oom_threshold: 8,
            oom_window_secs: 20.0,
            respawn_secs: 8.0,
            tick_secs: 0.5,
            max_sim_secs: 3600.0,
            migrate: false,
            autoscale: None,
            warmup_secs: 0.0,
            elastic_accounting: true,
            kv_elastic: true,
            checkpoint_period_secs: None,
            event_driven: true,
            sample_d: None,
        }
    }
}

/// Deliveries that hit a full interconnect partition retry this many
/// times (backing off `RETRY_BACKOFF_SECS` × attempt) before the move
/// is abandoned and the sequence requeues at its source.
const MAX_TRANSFER_RETRIES: u32 = 3;
const RETRY_BACKOFF_SECS: f64 = 0.5;

/// One sequence state in flight between replicas.
struct Transfer {
    state: SeqState,
    src: usize,
    dest: usize,
    /// Sim time the payload lands (dispatch + modeled transfer cost).
    arrive_at: f64,
    /// Delivery attempts burned against `MAX_TRANSFER_RETRIES` (bumped
    /// each time a partition blocks the landing).
    attempts: u32,
    /// A crash-recovery restore rather than a migration: lands in the
    /// restore counters, and falling back to a local requeue loses the
    /// checkpointed progress (`seq_lost`).
    is_restore: bool,
}

/// A terminal outcome decided at the fleet ingress itself (dropped at
/// the router, stranded in or cancelled from the backlog, cancelled in
/// flight) — merged into the per-tenant report.
struct IngressEvent {
    tenant: Tenant,
    outcome: Outcome,
    /// The request carried an SLO (rejections with one count as
    /// deadline misses in the hit-rate denominator).
    had_deadline: bool,
    /// Whether the request had already reached a replica (and was
    /// therefore already counted as submitted there) — true only for
    /// cancels of in-flight transfers.
    reached_replica: bool,
}

/// Where a live request currently sits — the O(1) `poll` / `cancel`
/// index. Terminal requests keep the location of their last holder
/// (that replica's metrics own the outcome record), and ids the fleet
/// rejected at the ingress are answered by `ingress_outcomes` before
/// this index is consulted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Location {
    /// Held in the tenant-fair ingress backlog.
    Backlog,
    /// In flight between replicas (migration or crash restore).
    Transfer,
    /// Queued, active, parked, or terminal on this replica.
    Replica(usize),
}

pub struct Fleet {
    pub cfg: FleetConfig,
    pub replicas: Vec<Replica>,
    pub router: Router,
    /// The shared simulated clock.
    pub clock: f64,
    /// Arrivals no accepting replica could take.
    pub dropped: u64,
    /// Sequence states currently in flight between replicas.
    transfers: Vec<Transfer>,
    /// Completed migrations and the payload bytes they moved (live KV
    /// slices — see `SeqState::transfer_bytes`).
    pub migrations: u64,
    pub migration_bytes: u64,
    /// What the same migrations would have cost under the
    /// pre-compression accounting (bucket-padded caches); serialized in
    /// [`FleetReport`] so the compression win is auditable per run.
    pub migration_bytes_padded: u64,
    /// Replicas added by the autoscaler.
    pub spawns: u64,
    /// Replicas retired by the autoscaler.
    pub retires: u64,
    autoscaler: Option<Autoscaler>,
    /// Replica factory for autoscale spawns (id → fresh replica).
    spawner: Option<Box<dyn Fn(usize) -> Replica>>,
    /// Per-tenant ingress backlog (tenant-fair router only): arrivals
    /// held at the front door until their tenant is under quota.
    backlog: BTreeMap<Tenant, VecDeque<SubmitRequest>>,
    /// High-water mark of each tenant's committed KV bytes (projected,
    /// at dispatch time) — the quota-utilization report.
    tenant_peak: BTreeMap<Tenant, u64>,
    /// Terminal outcomes decided at the ingress itself (dropped at the
    /// router, cancelled from the backlog / in flight) — per tenant,
    /// merged into the per-tenant report.
    ingress_terminal: Vec<IngressEvent>,
    /// Outcome per request id for ingress-terminal requests (the
    /// lifecycle API's lookup for ids no replica ever saw).
    ingress_outcomes: HashMap<u64, Outcome>,
    /// Backlog heads skipped because their queue vanished between
    /// scoring and dispatch (defensive — see `dispatch_ingress`).
    pub ingress_skipped: u64,
    /// The injected failure schedule (empty unless
    /// [`Fleet::with_fault_plan`] installed one) and the cursor of the
    /// next unfired event.
    fault_plan: FaultPlan,
    next_fault: usize,
    /// Reclaimed replicas racing their grace window: (index, doom
    /// deadline). Swept every step; a replica still live past its
    /// deadline crashes with whatever it failed to drain.
    doomed: Vec<(usize, f64)>,
    /// Chaos ledger (see [`ChaosReport`]).
    pub failures_injected: u64,
    pub crashes: u64,
    pub reclaims: u64,
    /// Sequences whose decode progress was destroyed: uncheckpointed
    /// actives on a crashed replica, plus restores that could not land.
    pub seq_lost: u64,
    /// Checkpointed sequences successfully restored onto a peer.
    pub seq_restored: u64,
    pub transfer_retries: u64,
    pub transfer_failures: u64,
    /// Every request a fault displaced, and whether it carried an SLO —
    /// keys the recovery-latency and chaos hit-rate report (BTreeMap so
    /// report iteration is deterministic).
    chaos_ids: BTreeMap<u64, bool>,
    /// The metrics registry — always present (never gated on telemetry)
    /// because the autoscaler's windowed signals live in its series:
    /// OOM/absorbed/TTFT marks harvested from each replica, and the
    /// capacity-loss marks pushed by crash/reclaim handling under the
    /// fleet-level key `FLEET`.
    pub registry: Registry,
    /// Fleet-level event bus handle (disabled unless
    /// [`Fleet::enable_telemetry`] attached a recorder).
    bus: Bus,
    /// The shared recorder behind `bus` and every engine's bus.
    recorder: Option<Rc<RefCell<Recorder>>>,
    /// Sample every counter/gauge into the registry timeline at this
    /// sim-time period (`None` disables sampling).
    metrics_period: Option<f64>,
    last_sample_at: f64,
    /// Requests submitted at the fleet ingress (`offer`), plus arrivals
    /// rejected before ever being offered (non-finite or past the run
    /// deadline) — the conservation total `FleetReport::total_requests`
    /// reports.
    pub submitted: u64,
    /// id → current holder (see [`Location`]); maintained exactly-once
    /// across route, migrate, crash-restore, and cancel so `poll` is
    /// O(1) at 1k replicas.
    locations: HashMap<u64, Location>,
    // -- event-driven scheduler (`FleetConfig::event_driven`) ----------
    /// Replicas that must be stepped at every barrier: engine holds
    /// work (active, waiting, or parked) or the replica is draining.
    hot: BTreeSet<usize>,
    /// Pending finite wake-ups as `Reverse((time bits, replica, seq))`:
    /// warm-up and respawn completions. `f64::to_bits` is order-
    /// preserving for the non-negative sim times stored here, and the
    /// (time, replica, seq) tuple is the deterministic tie-break.
    events: BinaryHeap<Reverse<(u64, usize, u64)>>,
    /// Latest schedule generation per replica; heap entries with a
    /// stale seq are ignored when popped.
    sched_seq: Vec<u64>,
    next_seq: u64,
    /// Serving replicas with un-expired OOM marks: `maintain` must keep
    /// judging them even after they go idle, until the marks age out.
    oom_watch: BTreeSet<usize>,
    /// Mirror of `all_idle`'s per-replica scan (`!idle || parked > 0`),
    /// maintained by `wake` so the idle check is O(1).
    engaged: Vec<bool>,
    engaged_count: usize,
    /// The previous `step_all` barrier, and the clock every engine
    /// would hold under lockstep at the current point of the phase
    /// order (pre-step phases see the previous barrier, post-step
    /// phases the current one). `sync_engine` jumps stale idle engines
    /// to `engine_clock` before handing them work.
    last_barrier: f64,
    engine_clock: f64,
}

impl Fleet {
    pub fn new(mut replicas: Vec<Replica>, mut router: Router,
               cfg: FleetConfig) -> Fleet {
        assert_eq!(router.decisions.len(), replicas.len(),
                   "router sized for a different fleet");
        for r in &mut replicas {
            r.engine.cfg.eviction = cfg.eviction_mode();
            r.engine.cfg.elastic_accounting = cfg.elastic_accounting;
            r.engine.cfg.kv_elastic = cfg.kv_elastic;
            r.engine.cfg.checkpoint_period_secs =
                cfg.checkpoint_period_secs;
        }
        if let Some(d) = cfg.sample_d {
            router.enable_sampling(d, 0x5EED_CE11);
        }
        let n = replicas.len();
        let mut fleet = Fleet {
            autoscaler: cfg.autoscale.map(Autoscaler::new),
            cfg,
            replicas,
            router,
            clock: 0.0,
            dropped: 0,
            transfers: Vec::new(),
            migrations: 0,
            migration_bytes: 0,
            migration_bytes_padded: 0,
            spawns: 0,
            retires: 0,
            spawner: None,
            backlog: BTreeMap::new(),
            tenant_peak: BTreeMap::new(),
            ingress_terminal: Vec::new(),
            ingress_outcomes: HashMap::new(),
            ingress_skipped: 0,
            fault_plan: FaultPlan::default(),
            next_fault: 0,
            doomed: Vec::new(),
            failures_injected: 0,
            crashes: 0,
            reclaims: 0,
            seq_lost: 0,
            seq_restored: 0,
            transfer_retries: 0,
            transfer_failures: 0,
            chaos_ids: BTreeMap::new(),
            registry: Registry::new(),
            bus: Bus::disabled(),
            recorder: None,
            metrics_period: None,
            last_sample_at: 0.0,
            submitted: 0,
            locations: HashMap::new(),
            hot: BTreeSet::new(),
            events: BinaryHeap::new(),
            sched_seq: vec![0; n],
            next_seq: 0,
            oom_watch: BTreeSet::new(),
            engaged: vec![false; n],
            engaged_count: 0,
            last_barrier: 0.0,
            engine_clock: 0.0,
        };
        for i in 0..n {
            fleet.wake(i);
        }
        fleet
    }

    /// Attach a shared flight recorder: the fleet and every engine —
    /// including later autoscale spawns — emit lifecycle events through
    /// it. Purely additive: events carry sim time only, so seeded
    /// reports are byte-identical with telemetry on or off.
    pub fn enable_telemetry(&mut self) {
        let rec = Rc::new(RefCell::new(Recorder::default()));
        self.bus = Bus::attached(&rec, None);
        for r in &mut self.replicas {
            r.engine.bus = Bus::attached(&rec, Some(r.id));
        }
        self.recorder = Some(rec);
    }

    pub fn telemetry_enabled(&self) -> bool {
        self.recorder.is_some()
    }

    /// Sample the registry's counters and gauges into its JSON timeline
    /// every `period_secs` of sim time.
    pub fn enable_metrics_sampling(&mut self, period_secs: f64) {
        assert!(period_secs > 0.0 && period_secs.is_finite(),
                "metrics period must be positive");
        self.metrics_period = Some(period_secs);
    }

    /// Export the recorded event stream as a Chrome/Perfetto trace
    /// (`None` when telemetry was never enabled).
    pub fn trace_json(&self) -> Option<Json> {
        let rec = self.recorder.as_ref()?;
        let rec = rec.borrow();
        Some(crate::telemetry::trace::chrome_trace(
            &rec.events,
            &rec.dumps,
            self.clock,
            vec![("source", Json::Str("rap fleet".into())),
                 ("replicas",
                  Json::Num(self.replicas.len() as f64))],
        ))
    }

    /// Install a failure schedule. Crash and reclaim events fire as the
    /// shared clock passes them; degradation and partition windows are
    /// consulted lazily when transfers are priced and delivered; any
    /// pressure cliffs are folded into replica 0's memory monitor here
    /// (interference is a per-device phenomenon, and the plan's
    /// pressure events name no replica).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Fleet {
        use crate::server::memmon::MemoryMonitor;

        let has_pressure = plan
            .events
            .iter()
            .any(|e| matches!(e, FaultEvent::Pressure { .. }));
        if has_pressure && !self.replicas.is_empty() {
            let cap = self.replicas[0].engine.monitor.cfg.capacity;
            self.replicas[0].engine.monitor =
                MemoryMonitor::with_faults(cap, &plan);
        }
        self.fault_plan = plan;
        self.next_fault = 0;
        self
    }

    /// Install a replica factory so autoscale-up can add capacity. The
    /// closure receives the new replica's id (ids never repeat —
    /// retired replicas stay in the roster).
    pub fn with_spawner(mut self,
                        f: impl Fn(usize) -> Replica + 'static) -> Fleet {
        self.spawner = Some(Box::new(f));
        self
    }

    /// Replace the autoscaler configuration (scenario tests toggle the
    /// early-warning flags on a prebuilt fleet).
    pub fn with_autoscale(mut self, cfg: AutoscaleConfig) -> Fleet {
        self.cfg.autoscale = Some(cfg);
        self.autoscaler = Some(Autoscaler::new(cfg));
        self
    }

    fn all_idle(&self) -> bool {
        if !self.transfers.is_empty()
            || !self.backlog.values().all(|q| q.is_empty())
        {
            return false;
        }
        if self.cfg.event_driven {
            let idle = self.engaged_count == 0;
            debug_assert_eq!(
                idle,
                self.replicas.iter().all(|r| {
                    r.engine.idle() && r.engine.parked_len() == 0
                }),
                "engaged ledger drifted from the roster scan"
            );
            idle
        } else {
            self.replicas.iter().all(|r| {
                r.engine.idle() && r.engine.parked_len() == 0
            })
        }
    }

    /// Re-index one replica in the event scheduler after anything that
    /// could change its next wake-up: always-due (`hot`) while its
    /// engine holds work or it is draining, a finite heap entry for a
    /// warm-up / respawn completion, nothing while idle. Also maintains
    /// the `engaged` mirror of `all_idle`'s roster scan and dirties the
    /// router's cell aggregate. Cheap and safe to call redundantly, in
    /// both stepping modes.
    fn wake(&mut self, i: usize) {
        let at = self.replicas[i].next_event_at();
        self.next_seq += 1;
        self.sched_seq[i] = self.next_seq;
        if at == f64::NEG_INFINITY {
            self.hot.insert(i);
        } else {
            self.hot.remove(&i);
            if at.is_finite() {
                self.events
                    .push(Reverse((at.to_bits(), i, self.next_seq)));
            }
        }
        let engaged = {
            let e = &self.replicas[i].engine;
            !e.idle() || e.parked_len() > 0
        };
        if engaged != self.engaged[i] {
            self.engaged[i] = engaged;
            if engaged {
                self.engaged_count += 1;
            } else {
                self.engaged_count -= 1;
            }
        }
        self.router.note_dirty(i);
    }

    /// Event-driven mode leaves idle replicas un-stepped, so an idle
    /// engine's clock can lag the fleet's. Before handing such a
    /// replica new work (or cancelling into it), jump it to the clock
    /// every engine would hold under lockstep at this point of the
    /// phase order (`engine_clock`); on an idle engine this is a pure
    /// clock jump, and on an already-current engine a no-op, so seeded
    /// behavior stays byte-identical to the lockstep sweep.
    fn sync_engine(&mut self, i: usize) {
        if !self.cfg.event_driven {
            return;
        }
        let t = self.engine_clock;
        if self.replicas[i].engine.sim_time() >= t {
            return;
        }
        self.replicas[i]
            .step_to(t)
            // lint:allow(hot-path-panic): forward jump on an idle
            // engine cannot fail; a silent skip would desync clocks
            .expect("idle engine clock jump cannot fail");
        self.replicas[i].harvest(t, &mut self.registry);
    }

    /// The replicas a barrier at `t` must step: every hot replica plus
    /// every valid wake-up due by `t`, in ascending id order (the same
    /// order the lockstep sweep visits them).
    fn due_replicas(&mut self, t: f64) -> Vec<usize> {
        let mut due: Vec<usize> = self.hot.iter().copied().collect();
        while let Some(&Reverse((bits, i, seq))) = self.events.peek() {
            if f64::from_bits(bits) > t {
                break;
            }
            self.events.pop();
            if self.sched_seq[i] == seq {
                due.push(i);
            }
        }
        due.sort_unstable();
        due.dedup();
        #[cfg(debug_assertions)]
        for (i, r) in self.replicas.iter().enumerate() {
            debug_assert!(
                (r.engine.idle() && r.engine.parked_len() == 0)
                    || self.hot.contains(&i),
                "replica {i} holds work but is not scheduled hot"
            );
        }
        due
    }

    /// Step the fleet to barrier `t`, then run the maintenance passes:
    /// migration (queue rebalance before the step, parked pickup and
    /// transfer delivery after), drain/respawn, autoscaling, and the
    /// tenant-fair ingress drain (capacity freed by completions admits
    /// backlogged tenants). Dispatches on `FleetConfig::event_driven`;
    /// both paths run the same phases in the same order and produce
    /// byte-identical seeded reports.
    fn step_all(&mut self, t: f64) -> Result<()> {
        if self.cfg.event_driven {
            self.step_all_event(t)
        } else {
            self.step_all_lockstep(t)
        }
    }

    /// The original full sweep: every replica steps at every barrier.
    fn step_all_lockstep(&mut self, t: f64) -> Result<()> {
        self.apply_faults(t)?;
        if self.cfg.migrate {
            self.rebalance_queued(t);
        }
        for r in &mut self.replicas {
            r.step_to(t)?;
            r.harvest(t, &mut self.registry);
        }
        self.engine_clock = t;
        if self.cfg.migrate {
            self.dispatch_parked(t);
        }
        self.deliver_transfers(t)?;
        self.maintain(t);
        self.autoscale(t);
        self.dispatch_ingress(t);
        self.sample_metrics(t);
        self.last_barrier = t;
        Ok(())
    }

    /// Event-driven barrier: only the due set steps. Idle replicas are
    /// left on stale clocks and jumped forward (`sync_engine`) the
    /// moment anything hands them work — a pure clock jump, since an
    /// idle engine does nothing in between.
    fn step_all_event(&mut self, t: f64) -> Result<()> {
        // A firing fault (or a pending doom sweep) mutates arbitrary
        // replicas mid-phase; sync the whole roster to the previous
        // barrier and run a full sweep so the handlers observe exactly
        // the lockstep state. Faults are rare, so this costs nothing.
        let fault_active = !self.doomed.is_empty()
            || (self.next_fault < self.fault_plan.events.len()
                && self.fault_plan.events[self.next_fault].start()
                    <= t);
        if fault_active {
            for i in 0..self.replicas.len() {
                self.sync_engine(i);
            }
        }
        self.apply_faults(t)?;
        if self.cfg.migrate {
            self.rebalance_queued(t);
        }
        let due: Vec<usize> = if fault_active {
            (0..self.replicas.len()).collect()
        } else {
            self.due_replicas(t)
        };
        for &i in &due {
            self.replicas[i].step_to(t)?;
            self.replicas[i].harvest(t, &mut self.registry);
        }
        self.engine_clock = t;
        let threshold = self.cfg.oom_threshold;
        for &i in &due {
            self.wake(i);
            if threshold != usize::MAX
                && self.replicas[i].accepting()
                && self.registry.count_since(
                    series::OOM,
                    self.replicas[i].id,
                    t - self.cfg.oom_window_secs,
                ) > 0
            {
                self.oom_watch.insert(i);
            }
        }
        if self.cfg.migrate {
            self.dispatch_parked(t);
        }
        self.deliver_transfers(t)?;
        self.maintain(t);
        self.autoscale(t);
        self.dispatch_ingress(t);
        self.sample_metrics(t);
        self.last_barrier = t;
        Ok(())
    }

    /// Push the fleet's serving-state ledgers onto the registry's
    /// counter/gauge surface. Pure reads of fleet state; the registry's
    /// counters are write-only from the control plane's point of view.
    pub fn publish_metrics(&mut self) {
        let mut completed = 0u64;
        let mut rejected = 0u64;
        let mut ooms = 0u64;
        let mut absorbed = 0u64;
        let mut compressed = 0u64;
        let mut kv_reclaimed = 0u64;
        let mut evictions = 0u64;
        let mut cancelled = 0u64;
        let mut deadline_missed = 0u64;
        let mut checkpoints = 0u64;
        let mut checkpoint_bytes = 0u64;
        let mut outstanding = 0usize;
        let mut serving = 0usize;
        for r in &self.replicas {
            let m = &r.engine.metrics;
            completed += m.completed.len() as u64;
            rejected += m.rejected;
            ooms += m.oom_events;
            absorbed += m.absorbed_spikes;
            compressed += m.compressed_spikes;
            kv_reclaimed += m.kv_bytes_reclaimed;
            evictions += m.evictions;
            cancelled += m.cancelled;
            deadline_missed += m.deadline_missed;
            checkpoints += m.checkpoints_taken;
            checkpoint_bytes += m.checkpoint_bytes;
            outstanding += r.outstanding();
            serving += r.accepting() as usize;
        }
        let reg = &mut self.registry;
        reg.set_counter("rap_requests_completed_total", completed);
        reg.set_counter("rap_requests_rejected_total", rejected);
        reg.set_counter("rap_requests_dropped_total", self.dropped);
        reg.set_counter("rap_requests_cancelled_total", cancelled);
        reg.set_counter("rap_deadline_missed_total", deadline_missed);
        reg.set_counter("rap_oom_events_total", ooms);
        reg.set_counter("rap_absorbed_spikes_total", absorbed);
        reg.set_counter("rap_compressed_spikes_total", compressed);
        reg.set_counter("rap_kv_bytes_reclaimed_total", kv_reclaimed);
        reg.set_counter("rap_evictions_total", evictions);
        reg.set_counter("rap_checkpoints_total", checkpoints);
        reg.set_counter("rap_checkpoint_bytes_total", checkpoint_bytes);
        reg.set_counter("rap_migrations_total", self.migrations);
        reg.set_counter("rap_migration_bytes_total",
                        self.migration_bytes);
        reg.set_counter("rap_transfer_retries_total",
                        self.transfer_retries);
        reg.set_counter("rap_spawns_total", self.spawns);
        reg.set_counter("rap_retires_total", self.retires);
        reg.set_counter("rap_crashes_total", self.crashes);
        reg.set_counter("rap_reclaims_total", self.reclaims);
        reg.set_counter("rap_seq_restored_total", self.seq_restored);
        reg.set_counter("rap_seq_lost_total", self.seq_lost);
        reg.set_gauge("rap_replicas_serving", serving as f64);
        reg.set_gauge("rap_outstanding", outstanding as f64);
        let p99 = reg.histogram("rap_ttft_seconds")
            .map(|h| h.quantile(99.0))
            .unwrap_or(f64::NAN);
        reg.set_gauge("rap_p99_ttft_seconds", p99);
    }

    /// Timeline sampling tick: refresh counters/gauges and snapshot
    /// them, at most once per `metrics_period` of sim time. Reads only
    /// — never perturbs a seeded run.
    fn sample_metrics(&mut self, t: f64) {
        let Some(period) = self.metrics_period else { return };
        if self.registry.samples() > 0
            && t < self.last_sample_at + period
        {
            return;
        }
        self.last_sample_at = t;
        self.publish_metrics();
        self.registry.sample(t);
    }

    // ---- the request lifecycle (the one ingress path) -----------------

    /// Submit one typed request at the fleet's current clock. The
    /// returned handle keys [`Fleet::poll`] / [`Fleet::cancel`].
    pub fn submit(&mut self, req: SubmitRequest) -> RequestHandle {
        let t = self.clock;
        self.submit_at(req, t)
    }

    /// Advance the fleet to sim time `t` — replicas, migration,
    /// drain/respawn, autoscaling, and the tenant-fair ingress drain —
    /// the manual driving primitive between [`Fleet::submit`] and
    /// [`Fleet::poll`] for callers that don't replay a prepared batch
    /// through [`Fleet::run_requests`]. Times before the current clock
    /// are clamped (the clock never runs backwards).
    pub fn step(&mut self, t: f64) -> Result<()> {
        let target = t.max(self.clock);
        self.step_all(target)?;
        self.clock = target;
        Ok(())
    }

    fn submit_at(&mut self, req: SubmitRequest, t: f64) -> RequestHandle {
        let handle = RequestHandle { id: req.id };
        self.offer(req, t);
        handle
    }

    /// Route one arrival: straight to a replica for every classic
    /// policy; into the per-tenant ingress backlog (then an immediate
    /// quota-gated drain) under `tenant-fair`.
    fn offer(&mut self, req: SubmitRequest, t: f64) {
        self.submitted += 1;
        self.bus.emit(t, Some(req.id), Some(&req.tenant),
                      || EventKind::Submit);
        if self.router.policy == RouterPolicy::TenantFair {
            self.locations.insert(req.id, Location::Backlog);
            self.backlog
                .entry(req.tenant.clone())
                .or_default()
                .push_back(req);
            self.dispatch_ingress(t);
            return;
        }
        match self.router.route(&req, &self.replicas, t) {
            Some(i) => {
                self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                    EventKind::Route {
                        dest: i,
                        policy: self.router.policy.name().to_string(),
                    }
                });
                self.locations.insert(req.id, Location::Replica(i));
                self.sync_engine(i);
                self.replicas[i].submit(req, t);
                self.wake(i);
            }
            None => {
                self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                    EventKind::Reject { reason: "no-accepting-replica" }
                });
                self.bus.flight_dump(t, "terminal rejection at ingress");
                self.note_ingress_terminal(&req, Outcome::Rejected,
                                           false);
                self.dropped += 1;
            }
        }
    }

    /// Lifecycle state of a submitted request: ingress-terminal,
    /// backlogged, in flight between replicas, or wherever its replica
    /// says it is. `None` for ids the fleet has never seen. O(1): one
    /// lookup in the location index, never a fleet scan.
    pub fn poll(&self, h: RequestHandle) -> Option<RequestStatus> {
        if let Some(&o) = self.ingress_outcomes.get(&h.id) {
            return Some(RequestStatus::Finished(o));
        }
        match self.locations.get(&h.id) {
            Some(Location::Backlog) => Some(RequestStatus::Queued),
            Some(Location::Transfer) => Some(RequestStatus::Migrating),
            Some(&Location::Replica(i)) => {
                self.replicas[i].engine.status(h.id)
            }
            None => None,
        }
    }

    /// The pre-index full scan (backlog → transfers → every replica) —
    /// kept as the oracle the exactly-once proptest holds the location
    /// index to.
    pub fn poll_scan(&self, h: RequestHandle) -> Option<RequestStatus> {
        if let Some(&o) = self.ingress_outcomes.get(&h.id) {
            return Some(RequestStatus::Finished(o));
        }
        if self
            .backlog
            .values()
            .any(|q| q.iter().any(|r| r.id == h.id))
        {
            return Some(RequestStatus::Queued);
        }
        if self.transfers.iter().any(|tr| tr.state.id() == h.id) {
            return Some(RequestStatus::Migrating);
        }
        for r in &self.replicas {
            if let Some(s) = r.engine.status(h.id) {
                return Some(s);
            }
        }
        None
    }

    /// Reclaim a request wherever it currently lives: ingress backlog,
    /// in flight between replicas, or on a replica (queued or
    /// mid-decode — its KV is freed). Books `Outcome::Cancelled`.
    /// Returns false when no live copy of `h` exists. The location
    /// index narrows the search to the one holder — no fleet scan.
    pub fn cancel(&mut self, h: RequestHandle) -> Result<bool> {
        if self.ingress_outcomes.contains_key(&h.id) {
            return Ok(false); // already terminal at the ingress
        }
        match self.locations.get(&h.id).copied() {
            Some(Location::Backlog) => {
                let mut from_backlog: Option<SubmitRequest> = None;
                for q in self.backlog.values_mut() {
                    if let Some(i) =
                        q.iter().position(|r| r.id == h.id)
                    {
                        // the position is fresh, but degrade rather
                        // than panic if the slot is somehow gone
                        from_backlog = q.remove(i);
                        break;
                    }
                }
                let Some(req) = from_backlog else {
                    return Ok(false);
                };
                self.note_ingress_terminal(&req, Outcome::Cancelled,
                                           false);
                Ok(true)
            }
            Some(Location::Transfer) => {
                let Some(i) = self
                    .transfers
                    .iter()
                    .position(|tr| tr.state.id() == h.id)
                else {
                    return Ok(false);
                };
                let tr = self.transfers.remove(i);
                self.note_ingress_terminal(tr.state.request(),
                                           Outcome::Cancelled, true);
                Ok(true)
            }
            Some(Location::Replica(i)) => {
                self.sync_engine(i);
                let hit = self.replicas[i].engine.cancel(h.id)?;
                self.wake(i);
                Ok(hit)
            }
            None => Ok(false),
        }
    }

    fn note_ingress_terminal(&mut self, req: &SubmitRequest,
                             outcome: Outcome, reached_replica: bool) {
        self.ingress_outcomes.insert(req.id, outcome);
        self.ingress_terminal.push(IngressEvent {
            tenant: req.tenant.clone(),
            outcome,
            had_deadline: req.slo_deadline.is_some(),
            reached_replica,
        });
    }

    // ---- tenant-fair ingress ------------------------------------------

    /// Each tenant's committed KV bytes: the projected full-length cost
    /// (under the holding replica's current mask) of everything queued,
    /// active, parked, or in flight for that tenant. This is what the
    /// quota caps. Served from each engine's incrementally-maintained
    /// committed-token ledger (`Engine::committed_kv_bytes`) — pricing
    /// is exactly linear in committed tokens, so the ledger reproduces
    /// the old per-request rescan to the byte; the rescan survives as
    /// the `debug_assertions` oracle below (and the quota proptest's).
    fn tenant_kv_usage(&self) -> BTreeMap<Tenant, u64> {
        let mut usage: BTreeMap<Tenant, u64> = BTreeMap::new();
        for r in &self.replicas {
            if !r.live() {
                continue;
            }
            r.engine.committed_kv_bytes(&mut usage);
        }
        for tr in &self.transfers {
            let req = tr.state.request();
            *usage.entry(req.tenant.clone()).or_insert(0) +=
                self.replicas[tr.dest].engine.admission_cost(req) as u64;
        }
        debug_assert_eq!(usage, self.tenant_kv_usage_rescan(),
                         "committed-byte ledger drifted from the \
                          full rescan");
        usage
    }

    /// The full waiting/active/parked rescan the ledger replaced — the
    /// independent oracle `tenant_kv_usage` is held to under
    /// `debug_assertions`, and the quota proptest's reference.
    pub fn tenant_kv_usage_rescan(&self) -> BTreeMap<Tenant, u64> {
        let mut usage: BTreeMap<Tenant, u64> = BTreeMap::new();
        for r in &self.replicas {
            if !r.live() {
                continue;
            }
            let e = &r.engine;
            for req in e.batcher.waiting.iter() {
                *usage.entry(req.tenant.clone()).or_insert(0) +=
                    e.admission_cost(req) as u64;
            }
            for s in e.batcher.active.iter() {
                *usage.entry(s.req.tenant.clone()).or_insert(0) +=
                    e.admission_cost(&s.req) as u64;
            }
            for st in e.parked_states() {
                *usage.entry(st.request().tenant.clone()).or_insert(0) +=
                    e.admission_cost(st.request()) as u64;
            }
        }
        for tr in &self.transfers {
            let req = tr.state.request();
            *usage.entry(req.tenant.clone()).or_insert(0) +=
                self.replicas[tr.dest].engine.admission_cost(req) as u64;
        }
        usage
    }

    /// Deficit-weighted drain of the per-tenant backlogs: while any
    /// head-of-backlog fits its tenant's quota, dispatch the one whose
    /// tenant is deepest under quota (largest remaining fraction; ties
    /// break toward the lexicographically first tenant), placing it by
    /// RAP-aware scoring. The quota is a hard cap on committed KV
    /// bytes, so one tenant's flood queues at the front door instead of
    /// burying the replicas — a tenant whose head is over quota simply
    /// waits for its own completions to free bytes. No-op for every
    /// non-tenant-fair policy.
    fn dispatch_ingress(&mut self, t: f64) {
        if self.router.policy != RouterPolicy::TenantFair {
            return;
        }
        if self.backlog.values().all(|q| q.is_empty()) {
            return;
        }
        // One full-fleet usage scan per drain; each dispatch then folds
        // its own projected cost in, which is exactly what a rescan
        // would see (the request now sits queued on `dest`, priced at
        // `dest`'s admission cost).
        let mut usage = self.tenant_kv_usage();
        loop {
            // (remaining-quota fraction, tenant, placement, cost):
            // placement is decided here and reused for the dispatch, so
            // each released head is scored against the fleet once
            let mut pick: Option<(f64, Tenant, usize, u64)> = None;
            for (name, q) in &self.backlog {
                let Some(head) = q.front() else {
                    continue;
                };
                // price the head on the replica it would land on
                let Some(dest) =
                    self.router.place(head, &self.replicas, t)
                else {
                    // no accepting replica at all: nothing can dispatch
                    return;
                };
                let cost =
                    self.replicas[dest].engine.admission_cost(head)
                        as u64;
                let used = usage.get(name).copied().unwrap_or(0);
                let quota = self.router.quotas.bytes_for(name.as_ref());
                if used.saturating_add(cost) > quota {
                    continue; // over quota: this tenant waits
                }
                let frac =
                    1.0 - used as f64 / quota.max(1) as f64;
                if pick.as_ref().map_or(true, |(f, ..)| frac > *f) {
                    pick = Some((frac, name.clone(), dest, cost));
                }
            }
            let Some((_, name, dest, cost)) = pick else {
                break; // every backlogged tenant is at its cap
            };
            // The scored head should still be there — but if the queue
            // vanished between scoring and dispatch, skip the pick and
            // rescore rather than bring the whole fleet down.
            let Some(req) = self
                .backlog
                .get_mut(&name)
                .and_then(|q| q.pop_front())
            else {
                self.ingress_skipped += 1;
                self.backlog.remove(&name);
                continue;
            };
            let used =
                usage.entry(name.clone()).or_insert(0);
            *used += cost;
            let peak = self.tenant_peak.entry(name).or_insert(0);
            if *used > *peak {
                *peak = *used;
            }
            self.router.decisions[dest] += 1;
            self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                EventKind::Route {
                    dest,
                    policy: self.router.policy.name().to_string(),
                }
            });
            self.locations.insert(req.id, Location::Replica(dest));
            self.sync_engine(dest);
            self.replicas[dest].submit(req, t);
            self.wake(dest);
        }
    }

    // ---- failure injection & recovery ---------------------------------

    /// Fire every scheduled fault whose start time the clock has
    /// passed, then sweep reclaim grace deadlines. Runs at the head of
    /// `step_all`, so a fault lands *before* the replicas step over it.
    /// Degrade / Partition windows need no action here — the
    /// interconnect model (`link_transfer_cost`, `deliver_transfers`)
    /// consults the plan lazily — and Pressure cliffs were folded into
    /// the memory monitor by [`Fleet::with_fault_plan`].
    fn apply_faults(&mut self, t: f64) -> Result<()> {
        while self.next_fault < self.fault_plan.events.len()
            && self.fault_plan.events[self.next_fault].start() <= t
        {
            let ev = self.fault_plan.events[self.next_fault];
            self.next_fault += 1;
            self.failures_injected += 1;
            self.bus.emit(t, None, None, || EventKind::FaultInjected {
                fault: ev.describe(),
            });
            match ev {
                FaultEvent::Crash { replica, .. } => {
                    self.crash_replica(replica, t);
                }
                FaultEvent::Reclaim { at, replica, grace_secs } => {
                    self.reclaim_replica(replica, at + grace_secs, t)?;
                }
                FaultEvent::Degrade { .. }
                | FaultEvent::Partition { .. }
                | FaultEvent::Pressure { .. } => {}
            }
        }
        let doomed = std::mem::take(&mut self.doomed);
        for (i, deadline) in doomed {
            if t >= deadline {
                // grace expired with work still on board: the reclaim
                // becomes a crash (crash_replica no-ops if the drain
                // finished and the replica already retired)
                self.crash_replica(i, t);
            } else {
                self.doomed.push((i, deadline));
            }
        }
        Ok(())
    }

    /// Abrupt loss of one replica: every resident KV byte, queue slot,
    /// and parked state is destroyed. Checkpointed sequences restore
    /// onto peers, where they re-enter admission and resume mid-decode
    /// on dispatch (losing only the tokens decoded since their last
    /// snapshot); uncheckpointed in-flight work re-enters admission at
    /// the head of its priority class on the least-loaded peer (its
    /// decode progress is gone, but the request is never silently
    /// dropped); queued work requeues normally. With no accepting peer
    /// left the displaced requests are booked `Rejected` — terminal and
    /// visible, never a double completion.
    fn crash_replica(&mut self, idx: usize, t: f64) {
        if idx >= self.replicas.len() || !self.replicas[idx].live() {
            return;
        }
        self.crashes += 1;
        self.replicas[idx].crashes += 1;
        self.replicas[idx].state = ReplicaState::Failed;
        self.registry.mark(series::CAPACITY_LOSS, FLEET, t);
        // emit through the dying replica's own bus so the death carries
        // its replica stamp in the control-plane track
        self.replicas[idx].engine.bus.emit(t, None, None, || {
            EventKind::Crash { disposition: "replica-failed" }
        });
        if self.bus.enabled() {
            self.bus.flight_dump(t, &format!("crash: replica {idx}"));
        }
        let (ckpts, lost, queued) =
            self.replicas[idx].engine.crash_dump();
        self.wake(idx);
        for state in ckpts {
            let req = state.request();
            self.chaos_ids.insert(req.id, req.slo_deadline.is_some());
            self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                EventKind::Crash { disposition: "checkpointed" }
            });
            self.send_restore(idx, state, t);
        }
        for req in lost {
            self.chaos_ids.insert(req.id, req.slo_deadline.is_some());
            self.seq_lost += 1;
            self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                EventKind::Crash { disposition: "lost" }
            });
            match self.least_loaded_peer(idx) {
                Some(peer) => {
                    self.locations
                        .insert(req.id, Location::Replica(peer));
                    self.replicas[peer].engine.adopt_front(req);
                    self.wake(peer);
                }
                None => self.reject_displaced(idx, &req, t),
            }
        }
        for req in queued {
            self.chaos_ids.insert(req.id, req.slo_deadline.is_some());
            self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
                EventKind::Crash { disposition: "requeued" }
            });
            match self.least_loaded_peer(idx) {
                Some(peer) => {
                    self.locations
                        .insert(req.id, Location::Replica(peer));
                    self.replicas[peer].engine.adopt(req);
                    self.wake(peer);
                }
                None => self.reject_displaced(idx, &req, t),
            }
        }
    }

    /// Spot reclaim with a grace window: the replica stops accepting
    /// routes and immediately evacuates everything it holds — queued
    /// work and exported in-flight sequences ship to peers over the
    /// interconnect — then retires cleanly once drained (`maintain`
    /// sees `retiring`). If the grace deadline passes first, whatever
    /// is left crashes with it (the doom sweep in `apply_faults`).
    fn reclaim_replica(&mut self, idx: usize, deadline: f64, t: f64)
                       -> Result<()> {
        if idx >= self.replicas.len()
            || !self.replicas[idx].live()
            || self.replicas[idx].retiring
        {
            return Ok(());
        }
        self.reclaims += 1;
        self.registry.mark(series::CAPACITY_LOSS, FLEET, t);
        self.replicas[idx].retiring = true;
        self.replicas[idx].state = ReplicaState::Draining;
        self.wake(idx);
        self.doomed.push((idx, deadline));
        let queued = self.replicas[idx].engine.take_waiting();
        for req in queued {
            self.chaos_ids.insert(req.id, req.slo_deadline.is_some());
            // a queued request that is really an un-resumed restore
            // evacuates as its snapshot — decode progress in hand
            match self.replicas[idx].engine.take_resumable(req.id) {
                Some(state) => self.send_state(idx, state, t),
                None => self.send_state(idx, SeqState::Queued(req), t),
            }
        }
        let active_ids: Vec<u64> = self.replicas[idx]
            .engine
            .batcher
            .active
            .iter()
            .map(|s| s.req.id)
            .collect();
        for id in active_ids {
            if let Some(state) =
                self.replicas[idx].engine.export_sequence(id)?
            {
                let req = state.request();
                self.chaos_ids
                    .insert(req.id, req.slo_deadline.is_some());
                self.send_state(idx, state, t);
            }
        }
        self.wake(idx);
        Ok(())
    }

    /// Ship one checkpointed state off a failed replica to the best
    /// peer over the (possibly degraded) interconnect. With no viable
    /// peer the checkpoint is useless: the sequence's progress is lost
    /// and the request falls back to a plain requeue.
    fn send_restore(&mut self, src: usize, state: SeqState, t: f64) {
        let bytes = state.transfer_bytes();
        match self.pick_target(src, &state, t) {
            Some(dest) => {
                let cost = self.link_transfer_cost(src, bytes, t);
                self.locations
                    .insert(state.id(), Location::Transfer);
                self.transfers.push(Transfer {
                    state,
                    src,
                    dest,
                    arrive_at: t + cost,
                    attempts: 0,
                    is_restore: true,
                });
            }
            None => {
                self.seq_lost += 1;
                self.requeue_local(src, state, t);
            }
        }
    }

    /// Modeled transfer duration from `src` at `t`, scaled by any
    /// active interconnect degradation. A full partition does not block
    /// dispatch — the payload goes out and `deliver_transfers` retries
    /// the landing until the partition heals or the retry budget runs
    /// out.
    fn link_transfer_cost(&self, src: usize, bytes: usize, t: f64)
                          -> f64 {
        let base = self.replicas[src].engine.rt.transfer_cost(bytes);
        match self.fault_plan.link_factor(t) {
            Some(f) => base * f,
            None => base,
        }
    }

    /// The accepting replica with the fewest outstanding requests, ties
    /// toward the lowest index — where a crashed replica's displaced
    /// queue re-enters admission.
    fn least_loaded_peer(&self, src: usize) -> Option<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(i, r)| *i != src && r.accepting())
            .min_by_key(|(i, r)| (r.outstanding(), *i))
            .map(|(i, _)| i)
    }

    /// Terminal fallback for a displaced request when no replica can
    /// take it: booked `Rejected` on the replica that lost it, so the
    /// lifecycle stays intact (poll sees a terminal outcome) and the
    /// per-tenant ledger counts the miss.
    fn reject_displaced(&mut self, src: usize, req: &SubmitRequest,
                        t: f64) {
        self.locations.insert(req.id, Location::Replica(src));
        let m = &mut self.replicas[src].engine.metrics;
        m.rejected += 1;
        m.note_terminal(req, Outcome::Rejected);
        self.bus.emit(t, Some(req.id), Some(&req.tenant), || {
            EventKind::Reject { reason: "displaced-no-peer" }
        });
        self.bus.flight_dump(t, "terminal rejection of displaced work");
    }

    // ---- migration ----------------------------------------------------

    /// A replica that cannot host queued work even under its
    /// *min-viable* mask (a true collapse, not a spike its controller
    /// will absorb by shrinking) is about to shed in-flight work; move
    /// its admission queue to peers with headroom before the engines
    /// step, so the queue isn't burned by head-of-line rejections
    /// against a pressure wall. Gating on the outlook instead of the
    /// current-mask footprint is what stops an absorbable interference
    /// spike from rerouting the whole queue for nothing (with
    /// `elastic_accounting` off the outlook is rigid and this reduces
    /// to the old `bytes_used > Sys_avail` test).
    fn rebalance_queued(&mut self, t: f64) {
        // only a replica with queued work can collapse, and queued work
        // makes it hot — the hot set is a complete candidate list
        let candidates: Vec<usize> = if self.cfg.event_driven {
            self.hot.iter().copied().collect()
        } else {
            (0..self.replicas.len()).collect()
        };
        for src in candidates {
            let collapsed = {
                let r = &self.replicas[src];
                r.live()
                    && !r.engine.batcher.waiting.is_empty()
                    && r.engine
                        .outlook()
                        .true_oom(r.engine.monitor.available_at(t))
            };
            if !collapsed {
                continue;
            }
            let reqs = self.replicas[src].engine.take_waiting();
            for req in reqs {
                match self.replicas[src].engine.take_resumable(req.id) {
                    Some(state) => self.send_state(src, state, t),
                    None => self.send_state(src, SeqState::Queued(req), t),
                }
            }
            self.wake(src);
        }
    }

    /// Collect the sequences each engine parked under memory pressure
    /// during this step and ship them out.
    fn dispatch_parked(&mut self, t: f64) {
        // parked work keeps a replica hot, so the hot set is complete
        let candidates: Vec<usize> = if self.cfg.event_driven {
            self.hot.iter().copied().collect()
        } else {
            (0..self.replicas.len()).collect()
        };
        for src in candidates {
            if self.replicas[src].engine.parked_len() == 0 {
                continue;
            }
            let parked = self.replicas[src].engine.take_parked();
            for state in parked {
                self.send_state(src, state, t);
            }
            self.wake(src);
        }
    }

    /// Per-destination load already committed but not yet landed:
    /// (pending transfer count, projected full-length KV bytes of each
    /// pending sequence at its destination). Folding this into the
    /// target score stops one maintenance pass from herding every
    /// refugee onto the same peer before any of them arrive.
    fn pending_per_dest(&self) -> (Vec<usize>, Vec<usize>) {
        let mut count = vec![0usize; self.replicas.len()];
        let mut bytes = vec![0usize; self.replicas.len()];
        for tr in &self.transfers {
            count[tr.dest] += 1;
            bytes[tr.dest] += self.replicas[tr.dest]
                .engine
                .elastic_admission_cost(tr.state.request());
        }
        (count, bytes)
    }

    fn pick_target(&self, src: usize, state: &SeqState, t: f64)
                   -> Option<usize> {
        let (count, bytes) = self.pending_per_dest();
        migration_target(&self.replicas, src, state, t, &count, &bytes)
    }

    /// Ship one sequence state from `src` to the best destination, or
    /// hand it back to `src` (a local requeue — the classic eviction)
    /// when no peer can take it. The interconnect is charged for the
    /// live KV slice only (`SeqState::transfer_bytes`).
    fn send_state(&mut self, src: usize, state: SeqState, t: f64) {
        let bytes = state.transfer_bytes();
        match self.pick_target(src, &state, t) {
            Some(dest) => {
                let cost = self.link_transfer_cost(src, bytes, t);
                self.locations
                    .insert(state.id(), Location::Transfer);
                self.transfers.push(Transfer {
                    state,
                    src,
                    dest,
                    arrive_at: t + cost,
                    attempts: 0,
                    is_restore: false,
                });
            }
            None => self.requeue_local(src, state, t),
        }
    }

    /// No destination: fall back to the classic local eviction — the
    /// request restarts from its prompt (any KV is dropped) and the
    /// eviction is charged to `src`'s metrics. If `src` itself went
    /// offline while the move was in flight (drained, retiring), the
    /// request joins the first accepting replica's queue instead:
    /// offline replicas must never be handed new work.
    fn requeue_local(&mut self, src: usize, state: SeqState, t: f64) {
        let home = if self.replicas[src].accepting() {
            src
        } else {
            self.replicas
                .iter()
                .position(|r| r.accepting())
                .unwrap_or(src)
        };
        // Nowhere alive to requeue: the source itself failed and no
        // peer accepts. The request must still reach a terminal state —
        // book it rejected rather than parking it on a dead engine
        // nothing will ever step again.
        if !self.replicas[home].live() {
            let req = state.request().clone();
            if matches!(state, SeqState::Active { .. }) {
                self.replicas[src].engine.metrics.evictions += 1;
            }
            self.reject_displaced(src, &req, t);
            return;
        }
        self.sync_engine(home);
        match state {
            SeqState::Queued(req) => {
                self.locations
                    .insert(req.id, Location::Replica(home));
                self.replicas[home].engine.adopt(req);
            }
            SeqState::Active { req, .. } => {
                self.replicas[src].engine.metrics.evictions += 1;
                self.locations
                    .insert(req.id, Location::Replica(home));
                self.replicas[home].engine.adopt_front(req);
            }
        }
        self.wake(home);
    }

    /// Land transfers whose payload has arrived. A destination that
    /// stopped accepting while the payload was in flight is re-resolved
    /// (the state already left its source, so it waits one tick); when
    /// no peer can take it at all, the move is abandoned and the
    /// sequence requeues at its source — it must never be lost or spin
    /// in flight until the deadline.
    fn deliver_transfers(&mut self, t: f64) -> Result<()> {
        let pending = std::mem::take(&mut self.transfers);
        for tr in pending {
            if tr.arrive_at > t {
                self.transfers.push(tr);
                continue;
            }
            // A partitioned interconnect fails the landing. The payload
            // is still in hand: back off and retry a bounded number of
            // times, then abandon the move and requeue at the source —
            // a sequence must never spin in flight forever.
            if self.fault_plan.link_factor(t).is_none() {
                if tr.attempts < MAX_TRANSFER_RETRIES {
                    self.transfer_retries += 1;
                    let backoff = RETRY_BACKOFF_SECS
                        * (tr.attempts + 1) as f64;
                    self.transfers.push(Transfer {
                        attempts: tr.attempts + 1,
                        arrive_at: t + backoff,
                        ..tr
                    });
                } else {
                    self.transfer_failures += 1;
                    if tr.is_restore {
                        self.seq_lost += 1;
                    }
                    self.requeue_local(tr.src, tr.state, t);
                }
                continue;
            }
            if !self.replicas[tr.dest].accepting() {
                match self.pick_target(tr.src, &tr.state, t) {
                    Some(dest) => self.transfers.push(Transfer {
                        dest,
                        arrive_at: t + self.cfg.tick_secs,
                        ..tr
                    }),
                    None => {
                        // No peer — but if the source itself recovered
                        // while the payload was in flight, re-import
                        // there losslessly (no interconnect charge for
                        // coming home) instead of dropping the KV.
                        let src = &self.replicas[tr.src];
                        let src_ok = src.accepting()
                            && src.elastic_headroom(t)
                                > src.engine.elastic_admission_cost(
                                    tr.state.request())
                            && src.engine.can_import(&tr.state);
                        if src_ok {
                            self.sync_engine(tr.src);
                            self.locations.insert(
                                tr.state.id(),
                                Location::Replica(tr.src),
                            );
                            self.replicas[tr.src]
                                .engine
                                .import_sequence(tr.state)?;
                            self.wake(tr.src);
                        } else {
                            if tr.is_restore {
                                self.seq_lost += 1;
                            }
                            self.requeue_local(tr.src, tr.state, t);
                        }
                    }
                }
                continue;
            }
            self.sync_engine(tr.dest);
            if self.replicas[tr.dest].engine.can_import(&tr.state) {
                let bytes = tr.state.transfer_bytes() as u64;
                let padded = tr.state.padded_transfer_bytes() as u64;
                let req = tr.state.request();
                if tr.is_restore {
                    self.bus.emit(t, Some(req.id), Some(&req.tenant),
                                  || EventKind::Restore {
                                      dest: tr.dest,
                                  });
                } else {
                    self.bus.emit(t, Some(req.id), Some(&req.tenant),
                                  || EventKind::Migrate {
                        src: tr.src,
                        dest: tr.dest,
                        bytes,
                        state: match tr.state {
                            SeqState::Active { .. } => "active",
                            SeqState::Queued(_) => "queued",
                        },
                    });
                }
                if tr.is_restore {
                    // A crash restore is recovery, not load balancing:
                    // it lands in its own books — and it re-enters
                    // ADMISSION at the head of its priority class (the
                    // snapshot held aside, KV re-attached on dispatch)
                    // rather than seizing a decode slot ahead of
                    // queued higher-priority work.
                    self.locations.insert(tr.state.id(),
                                          Location::Replica(tr.dest));
                    self.replicas[tr.dest].engine.resume_import(tr.state)?;
                    self.seq_restored += 1;
                    self.replicas[tr.dest].restored_in += 1;
                    self.wake(tr.dest);
                    continue;
                }
                self.locations.insert(tr.state.id(),
                                      Location::Replica(tr.dest));
                self.replicas[tr.dest].engine.import_sequence(tr.state)?;
                // counted on delivery (not dispatch), so abandoned
                // moves never desynchronize the in/out/aggregate
                // counters
                self.replicas[tr.src].migrations_out += 1;
                self.replicas[tr.dest].migrations_in += 1;
                self.migrations += 1;
                self.migration_bytes += bytes;
                self.migration_bytes_padded += padded;
                self.wake(tr.dest);
            } else {
                // Shape mismatch across heterogeneous models: the
                // payload is useless there — the sequence restarts from
                // its prompt. A lossy move is an eviction, not a
                // migration, in the books (and a lossy restore is a
                // lost sequence).
                if tr.is_restore {
                    self.seq_lost += 1;
                }
                let req = tr.state.request().clone();
                self.replicas[tr.src].engine.metrics.evictions += 1;
                self.locations
                    .insert(req.id, Location::Replica(tr.dest));
                self.replicas[tr.dest].engine.adopt(req);
                self.wake(tr.dest);
            }
        }
        Ok(())
    }

    // ---- lifecycle ----------------------------------------------------

    /// Lifecycle maintenance: drain replicas under sustained pressure
    /// (never the last serving one), and move drained-empty replicas on
    /// to their next state — a respawn cool-down, or `Retired` when the
    /// autoscaler flagged them. Respawn and warm-up completion happen
    /// inside `Replica::step_to`. Event-driven mode judges only the
    /// hot replicas plus the OOM watch set (idle Serving replicas whose
    /// marks have not aged out yet) — any replica that could transition
    /// is in one of the two.
    fn maintain(&mut self, t: f64) {
        let candidates: Vec<usize> = if self.cfg.event_driven {
            let mut c: Vec<usize> = self
                .hot
                .iter()
                .chain(self.oom_watch.iter())
                .copied()
                .collect();
            c.sort_unstable();
            c.dedup();
            c
        } else {
            (0..self.replicas.len()).collect()
        };
        if candidates.is_empty() {
            return;
        }
        let window = self.cfg.oom_window_secs;
        let threshold = self.cfg.oom_threshold;
        // the "never the last serving replica" gate needs the roster-
        // wide count; skipped entirely when draining is disabled
        let mut serving = if threshold == usize::MAX {
            0
        } else {
            self.replicas.iter().filter(|r| r.accepting()).count()
        };
        for i in candidates {
            match self.replicas[i].state {
                ReplicaState::Serving => {
                    // trim only behind the same gates the lockstep
                    // sweep used, so the mark-expiry schedule is
                    // identical in both modes
                    if threshold == usize::MAX || serving <= 1 {
                        continue;
                    }
                    // same destructive window the replicas' private
                    // mark lists kept: drop marks older than the
                    // horizon, count the rest
                    let marks = self.registry.trim_count(
                        series::OOM,
                        self.replicas[i].id,
                        t - window,
                    );
                    if marks == 0 {
                        self.oom_watch.remove(&i);
                    }
                    if marks >= threshold {
                        self.replicas[i].state =
                            ReplicaState::Draining;
                        serving -= 1;
                        self.wake(i);
                    }
                }
                ReplicaState::Draining => {
                    let r = &mut self.replicas[i];
                    if r.engine.idle() && r.engine.parked_len() == 0 {
                        if r.retiring {
                            r.state = ReplicaState::Retired;
                        } else {
                            r.state = ReplicaState::Respawning {
                                until: t + self.cfg.respawn_secs,
                            };
                            r.respawns += 1;
                        }
                        self.wake(i);
                    }
                }
                ReplicaState::Warming { .. }
                | ReplicaState::Respawning { .. }
                | ReplicaState::Retired
                | ReplicaState::Failed => {}
            }
        }
    }

    // ---- autoscaling --------------------------------------------------

    /// Fleet-level load signals over the trailing `window` seconds.
    /// Quota-held ingress backlog is not counted (see
    /// [`FleetSignals::outstanding`]): new replicas cannot admit work
    /// the fleet-wide KV quota is holding back, so counting it would
    /// scale the fleet for demand no capacity can serve.
    fn signals(&mut self, t: f64, window: f64) -> FleetSignals {
        let serving =
            self.replicas.iter().filter(|r| r.accepting()).count();
        let outstanding: usize = self
            .replicas
            .iter()
            .filter(|r| r.live())
            .map(|r| r.outstanding())
            .sum();
        let mut per_tenant: BTreeMap<Tenant, usize> = BTreeMap::new();
        for r in self.replicas.iter().filter(|r| r.live()) {
            r.outstanding_by_tenant(&mut per_tenant);
        }
        let max_tenant_outstanding =
            per_tenant.values().copied().max().unwrap_or(0);
        let t0 = t - window;
        let mut ttfts = Vec::new();
        let mut recent_ooms = 0usize;
        let mut recent_absorbed = 0usize;
        for r in &self.replicas {
            recent_ooms +=
                self.registry.count_since(series::OOM, r.id, t0);
            recent_absorbed +=
                self.registry.count_since(series::ABSORBED, r.id, t0);
            self.registry
                .values_since(series::TTFT, r.id, t0, &mut ttfts);
        }
        let capacity_losses =
            self.registry.trim_count(series::CAPACITY_LOSS, FLEET, t0);
        FleetSignals {
            serving,
            outstanding,
            max_tenant_outstanding,
            p99_ttft: percentile(&ttfts, 99.0),
            recent_ooms,
            recent_absorbed,
            capacity_losses,
        }
    }

    fn autoscale(&mut self, t: f64) {
        let Some(mut scaler) = self.autoscaler.take() else {
            return;
        };
        // signal collection scans completion records — skip it entirely
        // between the scaler's evaluation ticks
        if !scaler.due(t) {
            self.autoscaler = Some(scaler);
            return;
        }
        let signals = self.signals(t, scaler.cfg.signal_window_secs);
        let decision = scaler.decide(t, &signals);
        let (applied, victim) = match decision {
            ScaleDecision::Up => (self.spawn_replica(t), None),
            ScaleDecision::Down => {
                let v = self.retire_replica();
                (v.is_some(), v)
            }
            ScaleDecision::Hold => (false, None),
        };
        if applied {
            scaler.note_action(t);
            // audit trail: which windowed signal pulled the trigger,
            // and what every signal read at decision time
            let trigger = scaler.explain(&signals, decision);
            let snap = SignalSnapshot {
                serving: signals.serving,
                outstanding: signals.outstanding,
                p99_ttft: signals.p99_ttft,
                recent_ooms: signals.recent_ooms,
                recent_absorbed: signals.recent_absorbed,
                capacity_losses: signals.capacity_losses,
            };
            match decision {
                ScaleDecision::Up => {
                    let new_replica = self.replicas.len() - 1;
                    self.bus.emit(t, None, None, || {
                        EventKind::AutoscaleSpawn {
                            new_replica,
                            trigger,
                            signals: snap,
                        }
                    });
                }
                ScaleDecision::Down => {
                    // lint:allow(hot-path-panic): Down is only applied
                    // when a victim was chosen two lines up
                    let victim = victim.expect("applied retire");
                    self.bus.emit(t, None, None, || {
                        EventKind::AutoscaleRetire {
                            victim,
                            trigger,
                            signals: snap,
                        }
                    });
                }
                ScaleDecision::Hold => {}
            }
        }
        self.autoscaler = Some(scaler);
    }

    /// Add a replica via the installed spawner. Returns false when no
    /// spawner is installed — the fleet then simply cannot scale up —
    /// or when the replicas that will eventually serve again (serving,
    /// warming, pressure-draining, or respawning) already fill
    /// `max_replicas`: the scaler's own bound only sees the *currently
    /// accepting* count, which dips while a drained replica cools down.
    /// With `FleetConfig::warmup_secs` set, the new replica enters
    /// through `Warming` and accepts no routes until the warm-up
    /// elapses.
    fn spawn_replica(&mut self, t: f64) -> bool {
        let Some(spawner) = &self.spawner else {
            return false;
        };
        if let Some(auto) = &self.cfg.autoscale {
            let returning = self
                .replicas
                .iter()
                .filter(|r| r.live() && !r.retiring)
                .count();
            if returning >= auto.max_replicas {
                return false;
            }
        }
        let id = self.replicas.len();
        let mut r = spawner(id);
        r.id = id;
        r.engine.cfg.eviction = self.cfg.eviction_mode();
        r.engine.cfg.elastic_accounting = self.cfg.elastic_accounting;
        r.engine.cfg.kv_elastic = self.cfg.kv_elastic;
        r.engine.cfg.checkpoint_period_secs =
            self.cfg.checkpoint_period_secs;
        r.spawned_at = Some(t);
        if let Some(rec) = &self.recorder {
            r.engine.bus = Bus::attached(rec, Some(id));
        }
        if self.cfg.warmup_secs > 0.0 {
            r.state = ReplicaState::Warming {
                until: t + self.cfg.warmup_secs,
            };
        }
        self.replicas.push(r);
        self.router.decisions.push(0);
        self.sched_seq.push(0);
        self.engaged.push(false);
        self.wake(id);
        self.spawns += 1;
        true
    }

    /// Begin retiring the least-loaded serving replica: it stops
    /// accepting work, drains, and parks as `Retired`. Ties break
    /// toward the highest id so the original fleet core is the last to
    /// go. Returns the victim's id, or `None` when only one serving
    /// replica remains.
    fn retire_replica(&mut self) -> Option<usize> {
        let serving =
            self.replicas.iter().filter(|r| r.accepting()).count();
        if serving <= 1 {
            return None;
        }
        let pick = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.accepting())
            .min_by_key(|(i, r)| (r.outstanding(), std::cmp::Reverse(*i)))
            .map(|(i, _)| i);
        let i = pick?;
        self.replicas[i].retiring = true;
        self.replicas[i].state = ReplicaState::Draining;
        self.wake(i);
        self.retires += 1;
        Some(i)
    }

    // ---- the event loop -----------------------------------------------

    /// Serve a batch of typed requests across the fleet and report.
    /// Arrivals are submitted at their arrival time; the run ends when
    /// all work has drained — in-flight transfers and ingress backlogs
    /// included — or at `max_sim_secs`. This is the native entry point;
    /// [`Fleet::run_trace`] adapts a workload trace onto it.
    pub fn run_requests(&mut self, requests: Vec<SubmitRequest>)
                        -> Result<FleetReport> {
        // A non-finite arrival can neither be ordered nor served:
        // reject it at the front door (terminal, visible in the tenant
        // ledger) instead of letting it poison the sort.
        let (mut requests, bad): (Vec<_>, Vec<_>) = requests
            .into_iter()
            .partition(|r| r.has_finite_arrival());
        for req in bad {
            self.submitted += 1;
            self.note_ingress_terminal(&req, Outcome::Rejected, false);
            self.dropped += 1;
        }
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        // relative to where the shared clock already is, so a Fleet can
        // replay several traces back to back (mirrors Engine::run_requests)
        let deadline = self.clock + self.cfg.max_sim_secs;
        let mut next = 0usize;
        while self.clock < deadline {
            let mut target = self.clock + self.cfg.tick_secs;
            if next < requests.len() {
                target = target.min(requests[next].arrival);
            }
            target = target.min(deadline).max(self.clock + 1e-9);
            self.step_all(target)?;
            self.clock = target;
            while next < requests.len()
                && requests[next].arrival <= self.clock
            {
                let req = requests[next].clone();
                next += 1;
                let t = self.clock;
                self.submit_at(req, t);
            }
            if next >= requests.len() && self.all_idle() {
                break;
            }
        }
        // Arrivals past the deadline were never offered to the router;
        // count them as dropped — and give each a terminal ingress
        // outcome — so the report's accounting invariant (submitted ==
        // terminal outcomes + pending) holds even on a truncated run.
        // Backlogged requests the run never released are terminal too:
        // rejected at the front door.
        for req in &requests[next..] {
            self.submitted += 1;
            self.note_ingress_terminal(req, Outcome::Rejected, false);
        }
        self.dropped += (requests.len() - next) as u64;
        let stranded: Vec<SubmitRequest> = self
            .backlog
            .values_mut()
            .flat_map(|q| q.drain(..))
            .collect();
        for req in stranded {
            self.note_ingress_terminal(&req, Outcome::Rejected, false);
            self.dropped += 1;
        }
        Ok(self.report())
    }

    /// Replay a workload trace across the fleet — the legacy front
    /// door, now a thin adapter over [`Fleet::run_requests`]: a trace
    /// is just an iterator of default-tenancy `SubmitRequest`s
    /// (`api::from_trace`), so replay and the typed API share one
    /// ingress path.
    pub fn run_trace(&mut self, requests: Vec<Request>)
                     -> Result<FleetReport> {
        self.run_requests(api::from_trace(requests).collect())
    }

    /// Snapshot the fleet's metrics (callable after `run_requests`).
    pub fn report(&self) -> FleetReport {
        let wall = self.clock.max(1e-9);
        let mut lats = Vec::new();
        let mut ttfts = Vec::new();
        let mut completed = 0usize;
        let mut rejected = 0u64;
        let mut evictions = 0u64;
        let mut cancelled = 0u64;
        let mut deadline_missed = 0u64;
        let mut oom_events = 0u64;
        let mut absorbed_spikes = 0u64;
        let mut compressed_spikes = 0u64;
        let mut kv_bytes_reclaimed = 0u64;
        let mut respawns = 0u64;
        let mut checkpoints_taken = 0u64;
        let mut checkpoint_bytes = 0u64;
        let mut chaos_ttfts = Vec::new();
        let mut replicas = Vec::with_capacity(self.replicas.len());
        let mut tenant_counts: BTreeMap<Tenant, TenantCounts> =
            BTreeMap::new();
        let mut tenant_ttfts: BTreeMap<Tenant, Vec<f64>> =
            BTreeMap::new();
        for r in &self.replicas {
            for rec in &r.engine.metrics.completed {
                lats.push(rec.latency());
                ttfts.push(rec.ttft());
                if self.chaos_ids.contains_key(&rec.id) {
                    chaos_ttfts.push(rec.ttft());
                }
                tenant_ttfts
                    .entry(rec.tenant.clone())
                    .or_default()
                    .push(rec.ttft());
            }
            for (name, c) in &r.engine.metrics.tenants {
                tenant_counts.entry(name.clone()).or_default().merge(c);
            }
            completed += r.engine.metrics.completed.len();
            rejected += r.engine.metrics.rejected;
            evictions += r.engine.metrics.evictions;
            cancelled += r.engine.metrics.cancelled;
            deadline_missed += r.engine.metrics.deadline_missed;
            oom_events += r.engine.metrics.oom_events;
            absorbed_spikes += r.engine.metrics.absorbed_spikes;
            compressed_spikes += r.engine.metrics.compressed_spikes;
            kv_bytes_reclaimed += r.engine.metrics.kv_bytes_reclaimed;
            respawns += r.respawns;
            checkpoints_taken += r.engine.metrics.checkpoints_taken;
            checkpoint_bytes += r.engine.metrics.checkpoint_bytes;
            replicas.push(ReplicaReport {
                id: r.id,
                state: r.state.name().to_string(),
                capacity_bytes: r.engine.monitor.cfg.capacity,
                routed: r.routed,
                respawns: r.respawns,
                migrations_in: r.migrations_in,
                migrations_out: r.migrations_out,
                crashes: r.crashes,
                restored_in: r.restored_in,
                serve: r.engine.metrics.report(wall),
            });
        }
        for ev in &self.ingress_terminal {
            let c = tenant_counts.entry(ev.tenant.clone()).or_default();
            // an ingress-terminal request was submitted to the fleet
            // but never reached a replica's ledger (except a cancelled
            // in-flight transfer, already counted at its source)
            if !ev.reached_replica {
                c.submitted += 1;
            }
            c.book(ev.outcome, ev.had_deadline);
            match ev.outcome {
                Outcome::Cancelled => cancelled += 1,
                Outcome::DeadlineMissed => deadline_missed += 1,
                _ => {}
            }
        }
        let quotas_on = self.router.policy == RouterPolicy::TenantFair
            && self.router.quotas.any_finite();
        let tenants: Vec<FleetTenantReport> = tenant_counts
            .iter()
            .map(|(name, c)| {
                let tt: &[f64] = tenant_ttfts
                    .get(name)
                    .map(|v| v.as_slice())
                    .unwrap_or(&[]);
                let qb = self.router.quotas.bytes_for(name.as_ref());
                FleetTenantReport {
                    tenant: name.to_string(),
                    counts: *c,
                    p50_ttft: percentile(tt, 50.0),
                    p99_ttft: percentile(tt, 99.0),
                    quota_bytes: (quotas_on && qb != u64::MAX)
                        .then_some(qb),
                    quota_peak_bytes: self
                        .tenant_peak
                        .get(name)
                        .copied()
                        .unwrap_or(0),
                }
            })
            .collect();
        // Chaos recovery quality: over the SLO-carrying requests a
        // fault displaced, how many still finished inside their
        // deadline (cancels and still-unfinished ids don't count
        // against the rate; `None` when no fault touched one).
        let mut chaos_hit = 0u64;
        let mut chaos_total = 0u64;
        for (&id, &had_deadline) in &self.chaos_ids {
            if !had_deadline {
                continue;
            }
            match self.outcome_of(id) {
                Some(Outcome::Done) => {
                    chaos_hit += 1;
                    chaos_total += 1;
                }
                Some(Outcome::DeadlineMissed)
                | Some(Outcome::Rejected) => chaos_total += 1,
                _ => {}
            }
        }
        let chaos = ChaosReport {
            failures_injected: self.failures_injected,
            crashes: self.crashes,
            reclaims: self.reclaims,
            seq_lost: self.seq_lost,
            seq_restored: self.seq_restored,
            checkpoints_taken,
            checkpoint_bytes,
            transfer_retries: self.transfer_retries,
            transfer_failures: self.transfer_failures,
            recovery_p99_ttft: (!chaos_ttfts.is_empty())
                .then(|| percentile(&chaos_ttfts, 99.0)),
            chaos_deadline_hit_rate: (chaos_total > 0)
                .then(|| chaos_hit as f64 / chaos_total as f64),
        };
        FleetReport {
            policy: self.router.policy.name().to_string(),
            sim_secs: self.clock,
            total_requests: self.submitted,
            completed,
            rejected,
            evictions,
            cancelled,
            deadline_missed,
            dropped: self.dropped,
            oom_events,
            absorbed_spikes,
            compressed_spikes,
            kv_bytes_reclaimed,
            respawns,
            spawns: self.spawns,
            retires: self.retires,
            migrations: self.migrations,
            migration_bytes: self.migration_bytes,
            migration_bytes_padded: self.migration_bytes_padded,
            mean_latency: mean(&lats),
            p50_latency: percentile(&lats, 50.0),
            p99_latency: percentile(&lats, 99.0),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            throughput_rps: completed as f64 / wall,
            routing: self.router.decisions.clone(),
            ingress_skipped: self.ingress_skipped,
            chaos,
            tenants,
            replicas,
        }
    }

    /// Terminal outcome of `id`, wherever it was booked — the ingress
    /// ledger first, then the replicas in index order.
    fn outcome_of(&self, id: u64) -> Option<Outcome> {
        if let Some(&o) = self.ingress_outcomes.get(&id) {
            return Some(o);
        }
        self.replicas
            .iter()
            .find_map(|r| r.engine.metrics.outcome(id))
    }
}

/// Destination scoring for one migrating sequence — the rap-aware
/// router's shape, applied to migration: *elastic* memory surplus
/// (`Sys_avail(t)` minus the peer's min-viable footprint — a peer
/// mid-mask-shrink is not "full") after taking the sequence's projected
/// full-length cache, discounted by queue depth. Requiring positive
/// surplus keeps migration memory-safe; the queue discount stops a
/// pressure wall from herding every refugee onto the single roomiest
/// replica (one deep queue is how tail latency dies). `pending_count` /
/// `pending_bytes` are per-replica in-flight transfer loads (see
/// `Fleet::pending_per_dest`), charged as if already landed so a burst
/// of sends inside one maintenance pass spreads out. Ties break toward
/// the lowest index, so migration is deterministic.
pub fn migration_target(replicas: &[Replica], src: usize,
                        state: &SeqState, t: f64,
                        pending_count: &[usize],
                        pending_bytes: &[usize]) -> Option<usize> {
    let req = state.request();
    let mut best: Option<(usize, f64)> = None;
    for (i, r) in replicas.iter().enumerate() {
        if i == src || !r.accepting() {
            continue;
        }
        let headroom =
            r.elastic_headroom(t).saturating_sub(pending_bytes[i]);
        // like for like: elastic headroom vs the cost under the mask
        // the peer would shrink to (current-mask cost would leave
        // phantom infeasibility on dense adaptive peers)
        let need = r.engine.elastic_admission_cost(req);
        if headroom <= need {
            continue;
        }
        let score = (headroom - need) as f64
            / (1.0 + (r.outstanding() + pending_count[i]) as f64);
        super::router::fold_best(&mut best, i, score);
    }
    best.map(|(i, _)| i)
}

/// The model every default sim replica serves: small enough that fleet
/// sweeps are instant, large enough (max_seq 256) that the default trace
/// config's prompt buckets + generations fit a sequence.
pub fn default_sim_meta() -> ModelMeta {
    ModelMeta::synthetic("fleet-sim", 4, 128, 8, 4, 512, 512, 256)
}

/// N heterogeneous sim replicas (capacity / interference / device speed
/// from `ReplicaSpec::heterogeneous`) behind a router. Deterministic per
/// seed.
pub fn default_sim_fleet(n_replicas: usize, seed: u64,
                         policy: RouterPolicy) -> Fleet {
    default_sim_fleet_with(n_replicas, seed, policy,
                           FleetConfig::default())
}

/// As [`default_sim_fleet`], with an explicit fleet config (set
/// `migrate` / `autoscale` for elastic serving). The installed spawner
/// reuses the same heterogeneous palette, so autoscaled fleets stay
/// deterministic per seed.
pub fn default_sim_fleet_with(n_replicas: usize, seed: u64,
                              policy: RouterPolicy, cfg: FleetConfig)
                              -> Fleet {
    let meta = default_sim_meta();
    let replicas: Vec<Replica> = (0..n_replicas)
        .map(|i| build_sim_replica(i, &meta,
                                   &ReplicaSpec::heterogeneous(i), seed))
        .collect();
    let router = Router::new(policy, n_replicas);
    Fleet::new(replicas, router, cfg).with_spawner(move |id| {
        build_sim_replica(id, &meta, &ReplicaSpec::heterogeneous(id),
                          seed)
    })
}

/// A fleet of `n` identical replicas built from one spec — scenario
/// tests and the elastic experiment use this to control device speed
/// and memory exactly. The spawner clones the same spec.
pub fn uniform_sim_fleet(n: usize, seed: u64, policy: RouterPolicy,
                         cfg: FleetConfig, spec: ReplicaSpec) -> Fleet {
    let meta = default_sim_meta();
    let replicas: Vec<Replica> = (0..n)
        .map(|i| build_sim_replica(i, &meta, &spec, seed))
        .collect();
    let router = Router::new(policy, n);
    Fleet::new(replicas, router, cfg).with_spawner(move |id| {
        build_sim_replica(id, &meta, &spec, seed)
    })
}

/// Equal-share quota table: each of `n` tenants gets 1/n of the
/// fleet's aggregate KV headroom at t = 0 (capacity minus the current
/// footprint). `serve-fleet --router tenant-fair --tenants n` uses
/// this as its default quota.
pub fn equal_share_quotas(fleet: &Fleet, n: usize) -> TenantQuotas {
    let total: usize = fleet
        .replicas
        .iter()
        .map(|r| {
            r.engine
                .monitor
                .cfg
                .capacity
                .saturating_sub(r.engine.bytes_used())
        })
        .sum();
    TenantQuotas::unlimited()
        .with_default((total / n.max(1)) as u64)
}

/// A diurnal + bursty trace sized for `default_sim_meta` (generation cap
/// keeps prefill-bucket + generated tokens within max_seq).
pub fn default_fleet_trace(seed: u64, secs: f64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 2.0,
            day_secs: secs.max(60.0),
            bursts_per_day: (secs / 60.0).ceil().max(1.0),
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed,
    );
    gen.generate(0.0, secs)
}

// ---- scenario traces (elastic-fleet harness) --------------------------

/// Constant-rate stages back to back (bursts and diurnal swing off),
/// ids reassigned to stay unique across stage boundaries.
fn staged_trace(seed: u64, stages: &[(f64, f64)]) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::new();
    let mut t0 = 0.0;
    for (k, &(secs, rate)) in stages.iter().enumerate() {
        let mut gen = TraceGenerator::new(
            TraceConfig {
                base_rate: rate,
                diurnal_amp: 0.0,
                bursts_per_day: 0.0,
                day_secs: secs.max(1.0),
                gen_max: 48,
                ..TraceConfig::default()
            },
            seed.wrapping_add(7919 * (k as u64 + 1)),
        );
        let mut reqs = gen.generate(0.0, secs);
        for r in &mut reqs {
            r.arrival += t0;
        }
        out.extend(reqs);
        t0 += secs;
    }
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Ramp-up: the arrival rate staircases 0.5 → 6 req/s across `secs`.
pub fn ramp_up_trace(seed: u64, secs: f64) -> Vec<Request> {
    let s = secs / 4.0;
    staged_trace(seed, &[(s, 0.5), (s, 1.5), (s, 3.0), (s, 6.0)])
}

/// Drain-down: the ramp in reverse.
pub fn drain_down_trace(seed: u64, secs: f64) -> Vec<Request> {
    let s = secs / 4.0;
    staged_trace(seed, &[(s, 6.0), (s, 3.0), (s, 1.5), (s, 0.5)])
}

/// Length of the elastic demo scenario (`elastic_demo_fleet` +
/// `elastic_demo_trace`).
pub const ELASTIC_DEMO_SECS: f64 = 120.0;

/// The elastic-serving demo scenario shared by `tests/elastic_fleet.rs`
/// and `rap experiment fleet --elastic`: two slow static-dense replicas
/// behind the least-outstanding router, hit by a burst storm while a
/// periodic interference wall (10 s every 25 s) leaves replica 0 less
/// than the dense parameter footprint — exactly the squeeze migration
/// and autoscaling exist for. `elastic = false` is the fixed-size
/// drain/respawn baseline; `true` turns on migration plus a
/// burst-reactive autoscaler (short hold/cooldown — a storm is over
/// before the conservative defaults would act). Everything else
/// (replicas, trace, router, thresholds) is identical, and
/// deterministic per seed.
pub fn elastic_demo_fleet(seed: u64, elastic: bool) -> Fleet {
    use crate::server::memmon::MemoryMonitor;

    let spec = ReplicaSpec {
        // ~1 req/s per replica at this model size: the storm's bursts
        // overload the pair, and sequences live long enough for the
        // walls to catch them mid-decode
        flops_per_sec: 1.0e8,
        app_rate: 0.0, // interference is the explicit wall below
        adaptive: false, // static dense: isolate fleet mechanics
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: elastic,
        autoscale: if elastic {
            Some(AutoscaleConfig {
                min_replicas: 2,
                max_replicas: 8,
                hold_secs: 2.0,
                cooldown_secs: 5.0,
                eval_every_secs: 0.5,
                signal_window_secs: 10.0,
                high_p99_ttft_secs: 4.0,
                ..AutoscaleConfig::default()
            })
        } else {
            None
        },
        max_sim_secs: ELASTIC_DEMO_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed,
                                      RouterPolicy::LeastOutstanding,
                                      cfg, spec);
    // Replica 0: 4× params capacity, so between walls it serves its
    // share of in-flight work. Each wall leaves only half the dense
    // parameter footprint available: whatever is mid-decode there must
    // move or die.
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = params * 4;
    let walls: Vec<(f64, f64, usize)> = (0..4)
        .map(|k| (15.0 + 25.0 * k as f64, 25.0 + 25.0 * k as f64,
                  cap - params / 2))
        .collect();
    fleet.replicas[0].engine.monitor = MemoryMonitor::walls(cap, &walls);
    fleet
}

/// The burst-storm trace `elastic_demo_fleet` is squeezed with.
pub fn elastic_demo_trace(seed: u64) -> Vec<Request> {
    burst_storm_trace(seed, ELASTIC_DEMO_SECS)
}

/// Length of the absorbable-spike scenario's arrival window
/// (`absorbable_spike_fleet` + `absorbable_spike_trace`); the
/// interference wall begins the moment arrivals end.
pub const ABSORBABLE_SPIKE_SECS: f64 = 20.0;

/// The ISSUE-4 acceptance scenario: an interference spike that RAP's
/// controllers can *fully absorb* by mask-shrinking, aimed at a fleet
/// whose every pressure reflex (queue rebalancing, migration, OOM-driven
/// autoscaling) is armed.
///
/// Two adaptive (GsiGreedy) replicas behind the least-outstanding
/// router; an arrival burst piles queues up, and the moment arrivals
/// end a 12 s interference wall lands on replica 0, sized so that
/// `min_viable < Sys_avail(t) < current(dense)` — the absorbable band.
/// Migration is on and the autoscaler is configured so only the OOM
/// signal can trigger a spawn (queue/TTFT watermarks parked out of
/// reach, `high_oom_events: 1`): every spawn or migration in this
/// scenario is by construction *phantom* pressure, and because no
/// arrivals remain, none of it can help — the current-mask fleet dumps
/// replica 0's whole queue onto its peer (concentrating the burst
/// behind one replica) and spawns capacity nothing will ever be routed
/// to, while the mask-elastic fleet shrinks replica 0's mask (which
/// also makes it proportionally *faster*) and serves everything in
/// place. The replicas' controller period is stretched to 30 s so that
/// during the wall only pressure-forced decisions move the mask — the
/// booked OOM/absorbed outcome is then deterministic, not a race
/// against the periodic re-decide.
///
/// `mask_elastic = true` (the fix) judges pressure against the memory
/// outlook: the spike is absorbed, and migrations and spawns must both
/// be zero. `mask_elastic = false` reproduces the current-mask
/// accounting: the same spike reroutes the queue and spawns a replica.
/// Everything else is identical and deterministic per seed.
pub fn absorbable_spike_fleet(seed: u64, mask_elastic: bool) -> Fleet {
    use crate::server::memmon::MemoryMonitor;

    let spec = ReplicaSpec {
        // slow enough (~1 req/s per replica) that the burst builds a
        // real queue for phantom pressure to reroute
        flops_per_sec: 1.0e8,
        app_rate: 0.0, // interference is the explicit wall below
        adaptive: true, // the whole point: masks that can shrink
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: true,
        // no drain/respawn: isolate the outlook's effect
        oom_threshold: usize::MAX,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 2,
            max_replicas: 4,
            // only the OOM signal can fire: queue/TTFT watermarks are
            // unreachable, and the low watermark never retires
            high_queue_per_replica: 1e12,
            low_queue_per_replica: 0.0,
            high_p99_ttft_secs: 1e12,
            high_oom_events: 1,
            hold_secs: 1.0,
            cooldown_secs: 10.0,
            eval_every_secs: 0.5,
            signal_window_secs: 10.0,
            ..AutoscaleConfig::default()
        }),
        elastic_accounting: mask_elastic,
        max_sim_secs: ABSORBABLE_SPIKE_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed,
                                      RouterPolicy::LeastOutstanding,
                                      cfg, spec);
    for r in &mut fleet.replicas {
        r.engine.cfg.controller_period = 30.0;
    }
    // The wall is sized into the absorbable band: it leaves 0.72× the
    // dense parameter footprint available — under the dense footprint
    // (pressure under the current mask) but well over the min-viable
    // one (≈0.3× params + the shrunken KV), so the controller alone
    // can always absorb it.
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = fleet.replicas[0].engine.monitor.cfg.capacity;
    let avail = (params as f64 * 0.72) as usize;
    fleet.replicas[0].engine.monitor = MemoryMonitor::walls(
        cap, &[(ABSORBABLE_SPIKE_SECS, ABSORBABLE_SPIKE_SECS + 12.0,
                cap - avail)]);
    fleet
}

/// The trace `absorbable_spike_fleet` serves: a steady base load ending
/// in a dense 3 s arrival burst straight into the wall, so both
/// replicas carry deep queues and live decodes when the interference
/// lands. Generations are long (`gen_mu` 3.0, ~27-token median) so the
/// wall reliably catches mid-decode work.
pub fn absorbable_spike_trace(seed: u64) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::new();
    let mut t0 = 0.0;
    for (k, &(secs, rate)) in [(17.0, 1.2), (3.0, 6.0)].iter()
        .enumerate()
    {
        let mut gen = TraceGenerator::new(
            TraceConfig {
                base_rate: rate,
                diurnal_amp: 0.0,
                bursts_per_day: 0.0,
                day_secs: secs.max(1.0),
                gen_mu: 3.0,
                gen_max: 48,
                ..TraceConfig::default()
            },
            seed.wrapping_add(7919 * (k as u64 + 1)),
        );
        let mut reqs = gen.generate(0.0, secs);
        for r in &mut reqs {
            r.arrival += t0;
        }
        out.extend(reqs);
        t0 += secs;
    }
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Length of the long-context storm's arrival window plus decode tail
/// (`longctx_storm_fleet` + `longctx_storm_trace`). The interference
/// wall lands at [`LONGCTX_WALL_AT`], *inside* the cohort's decode
/// phase.
pub const LONGCTX_STORM_SECS: f64 = 20.0;
/// When the interference wall lands on replica 0 (mid-decode).
pub const LONGCTX_WALL_AT: f64 = 16.5;
/// How long the wall holds.
pub const LONGCTX_WALL_SECS: f64 = 12.0;
/// Wall height: the fraction of the dense parameter footprint left
/// available. Sized into the *joint-only* band — see
/// [`longctx_storm_fleet`].
pub const LONGCTX_AVAIL_FRAC: f64 = 0.62;
/// Replica speed for the scenario: fast enough that the whole storm
/// cohort prefills before the wall, slow enough that its decodes are
/// still resident when the wall lands.
pub const LONGCTX_FLOPS: f64 = 6.0e8;

/// The PR-9 acceptance scenario: a long-context storm that mask-only
/// elasticity *cannot* absorb but the joint (mask × KV policy) lattice
/// can — by compressing resident caches to the KV floor instead of
/// shedding work.
///
/// Two adaptive replicas behind the least-outstanding router. A dense
/// ~1 s storm of long-prompt/long-generation requests arrives at
/// t ≈ 13 s; every request prefills before the wall, so when the wall
/// lands at [`LONGCTX_WALL_AT`] each replica under it holds a *closed
/// cohort* of 5–7 mid-decode residents and an empty queue. The wall is
/// sized (via [`LONGCTX_AVAIL_FRAC`]) so that at the first pressure
/// instant even the min-viable mask fits the live KV — both arms
/// absorb by mask-shrinking alone. Then the cohort keeps decoding:
/// resident KV grows under a mask that cannot shrink further (the
/// controller's decision grid already sits at the min-viable level in
/// this budget band), and the live footprint crosses `Sys_avail`
/// again.
///
/// At that second pressure instant the two lattices diverge:
///   * `kv_elastic = false` (mask-only): `min_viable` prices resident
///     KV at full length — the floor itself no longer fits, so this is
///     a *true OOM*: work is shed, the queue migrates, the OOM-armed
///     autoscaler spawns a replica that nothing will ever be routed
///     to.
///   * `kv_elastic = true` (joint): the outlook prices residents at
///     the KV floor, so the spike is still absorbable — pressure
///     compresses residents to the floor (window+sink eviction),
///     books `compressed_spikes`/`kv_bytes_reclaimed`, and sheds
///     nothing: zero migrations, zero spawns, zero OOMs, at
///     equal-or-better p99 TTFT.
///
/// Both arms run mask-elastic accounting (`elastic_accounting: true`);
/// only the KV leg differs. Deterministic per seed; seeds 42, 10 and
/// 100 are pinned by `tests/longctx_fleet.rs` and the CI smoke.
pub fn longctx_storm_fleet(seed: u64, kv_elastic: bool) -> Fleet {
    use crate::server::memmon::MemoryMonitor;

    let spec = ReplicaSpec {
        flops_per_sec: LONGCTX_FLOPS,
        app_rate: 0.0, // interference is the explicit wall below
        adaptive: true,
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: true,
        // no drain/respawn: isolate the joint lattice's effect
        oom_threshold: usize::MAX,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 2,
            max_replicas: 4,
            // only the OOM signal can fire (as in the absorbable-spike
            // scenario): every spawn here is shed pressure
            high_queue_per_replica: 1e12,
            low_queue_per_replica: 0.0,
            high_p99_ttft_secs: 1e12,
            high_oom_events: 1,
            hold_secs: 1.0,
            cooldown_secs: 10.0,
            eval_every_secs: 0.5,
            signal_window_secs: 10.0,
            ..AutoscaleConfig::default()
        }),
        elastic_accounting: true,
        kv_elastic,
        max_sim_secs: LONGCTX_STORM_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed,
                                      RouterPolicy::LeastOutstanding,
                                      cfg, spec);
    for r in &mut fleet.replicas {
        r.engine.cfg.controller_period = 30.0;
    }
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = fleet.replicas[0].engine.monitor.cfg.capacity;
    let avail = (params as f64 * LONGCTX_AVAIL_FRAC) as usize;
    fleet.replicas[0].engine.monitor = MemoryMonitor::walls(
        cap, &[(LONGCTX_WALL_AT, LONGCTX_WALL_AT + LONGCTX_WALL_SECS,
                cap - avail)]);
    fleet
}

/// The trace `longctx_storm_fleet` serves: a sparse warm-up followed by
/// a ~1 s storm of long-context requests. Prompts blow past the largest
/// prefill bucket (128), so every resident cache sits far above the KV
/// floor cap; generations are long (96–128 tokens) so resident KV keeps
/// growing under the wall. Hand-rolled (not `TraceGenerator`): the
/// joint-only pressure band depends on the cohort's length profile, so
/// the draws are pinned here exactly.
pub fn longctx_storm_trace(seed: u64) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::new();
    let mut t0 = 0.0;
    for (k, &(secs, rate)) in [(13.0, 0.25), (1.1, 18.0)].iter()
        .enumerate()
    {
        let mut rng = Rng::new(
            seed.wrapping_add(7919u64.wrapping_mul(k as u64 + 1)));
        let mut t = 0.0;
        loop {
            t += rng.exponential(rate);
            if t >= secs {
                break;
            }
            let prompt = 144 + rng.below(81);
            let gen = 96 + rng.below(33);
            out.push(Request { id: 0, arrival: t0 + t,
                               prompt_len: prompt, gen_len: gen });
        }
        t0 += secs;
    }
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Burst storm: a calm baseline punctured by dense burst episodes.
pub fn burst_storm_trace(seed: u64, secs: f64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 1.0,
            diurnal_amp: 0.0,
            day_secs: secs.max(60.0),
            bursts_per_day: (secs / 25.0).ceil().max(2.0),
            burst_mult: 8.0,
            burst_secs: 6.0,
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed,
    );
    let mut reqs = gen.generate(0.0, secs);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i as u64;
    }
    reqs
}

// ---- multi-tenant scenario (ISSUE 5) ----------------------------------

/// Arrival window of the tenant-storm scenario.
pub const TENANT_STORM_SECS: f64 = 40.0;

/// The latency-sensitive tenant's completion SLO (seconds after
/// arrival).
pub const TENANT_STORM_SLO_SECS: f64 = 2.5;

/// The ISSUE-5 acceptance scenario's trace: one noisy tenant flooding
/// low-priority long-decode requests over a latency-sensitive tenant's
/// steady stream.
///
///   * `latency` — Interactive, ~1.2 req/s for the whole window, short
///     prompts (≤ 24 tokens) and generations (≤ 8 tokens), every
///     request carrying a `TENANT_STORM_SLO_SECS` completion deadline;
///   * `noisy`   — Batch, no deadline, an 8 req/s flood of long decodes
///     (median ~33 tokens, prompts ≤ 32) from t = 5 s to t = 25 s.
///
/// Prompt caps keep single prefills small relative to the SLO, so the
/// comparison measures queueing discipline, not prefill-size luck.
/// Ids are assigned in arrival order; deterministic per seed.
pub fn tenant_storm_trace(seed: u64) -> Vec<SubmitRequest> {
    let mut out: Vec<SubmitRequest> = Vec::new();
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 1.2,
            diurnal_amp: 0.0,
            bursts_per_day: 0.0,
            day_secs: TENANT_STORM_SECS,
            prompt_max: 24,
            gen_mu: 1.6,
            gen_sigma: 0.4,
            gen_max: 8,
            ..TraceConfig::default()
        },
        seed.wrapping_add(7919),
    );
    for r in gen.generate(0.0, TENANT_STORM_SECS) {
        out.push(SubmitRequest::from_trace(&r)
            .with_tenant("latency")
            .with_priority(PriorityClass::Interactive)
            .with_deadline(r.arrival + TENANT_STORM_SLO_SECS));
    }
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 8.0,
            diurnal_amp: 0.0,
            bursts_per_day: 0.0,
            day_secs: 20.0,
            prompt_max: 32,
            gen_mu: 3.5,
            gen_sigma: 0.3,
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed.wrapping_add(15838),
    );
    for r in gen.generate(0.0, 20.0) {
        out.push(SubmitRequest::from_trace(&r)
            .with_tenant("noisy")
            .with_priority(PriorityClass::Batch)
            .with_arrival(r.arrival + 5.0));
    }
    out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// The FCFS-baseline decoration of [`tenant_storm_trace`]: identical
/// arrivals, lengths, tenants, and deadlines (so hit-rates stay
/// measurable), but every priority flattened to `Normal` — the legacy
/// trace-replay front door carried no urgency, so its queues were pure
/// FCFS. Pair it with any non-tenant-fair router (which also turns
/// deadline *enforcement* off — see [`tenant_storm_fleet`]).
pub fn tenant_storm_fcfs_trace(seed: u64) -> Vec<SubmitRequest> {
    let mut reqs = tenant_storm_trace(seed);
    for r in &mut reqs {
        r.priority = PriorityClass::Normal;
    }
    reqs
}

/// The fleet `tenant_storm_trace` is aimed at: two identical slow
/// static-dense replicas (so the outcome is a property of the ingress,
/// not of controller adaptivity), no drain/respawn, no autoscaling.
/// Under `RouterPolicy::TenantFair` the noisy tenant gets a KV-byte
/// quota of 4 worst-case requests fleet-wide (the latency tenant is
/// uncapped), so its flood queues at the front door. Under any other
/// policy the fleet models the *legacy* front door the API replaces:
/// dispatch on arrival, and deadlines measured but never enforced
/// (`EngineConfig::enforce_deadlines = false`) — pair with
/// [`tenant_storm_fcfs_trace`] for the full FCFS baseline.
/// Deterministic per seed.
pub fn tenant_storm_fleet(seed: u64, policy: RouterPolicy) -> Fleet {
    let spec = ReplicaSpec {
        // ~1 req/s per replica: the flood genuinely overloads the pair
        flops_per_sec: 1.0e8,
        app_rate: 0.0,   // no interference: isolate the ingress effect
        adaptive: false, // static dense: no mask motion in the way
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        oom_threshold: usize::MAX, // no drain/respawn
        max_sim_secs: TENANT_STORM_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed, policy, cfg, spec);
    if policy == RouterPolicy::TenantFair {
        // a worst-case noisy request: the capped prompt bucket (32)
        // plus the generation cap (48)
        let worst =
            fleet.replicas[0].engine.kv_bytes_for_len(32 + 48) as u64;
        fleet.router.quotas = TenantQuotas::unlimited()
            .with_quota("noisy", 4 * worst);
    } else {
        for r in &mut fleet.replicas {
            r.engine.cfg.enforce_deadlines = false;
        }
    }
    fleet
}

// ---- chaos scenario (ISSUE 6) -----------------------------------------

/// Arrival window of the chaos-storm scenario (the fault plan below is
/// laid out inside it).
pub const CHAOS_STORM_SECS: f64 = 40.0;

/// The chaos-storm latency tenant's completion SLO (seconds after
/// arrival). Long-decode requests under a deadline a few times their
/// service time: loose enough that an undisturbed request usually
/// makes it, tight enough that losing a crashed request's decode
/// progress usually costs the deadline.
pub const CHAOS_STORM_SLO_SECS: f64 = 7.0;

/// The fixed fault schedule the chaos-storm scenario injects: the
/// interconnect degrades 3× from t = 10 and fully partitions over
/// [16, 19); replica 1 crashes outright at t = 14 (mid-flood, queues
/// deep, long decodes live — the worst moment); and replica 2 is
/// spot-reclaimed at t = 24 with a 5 s grace window to drain through
/// the migration path.
pub fn chaos_storm_plan() -> FaultPlan {
    FaultPlan::new(vec![
        FaultEvent::Degrade { from: 10.0, until: 20.0, factor: 3.0 },
        FaultEvent::Crash { at: 14.0, replica: 1 },
        FaultEvent::Partition { from: 16.0, until: 19.0 },
        FaultEvent::Reclaim { at: 24.0, replica: 2, grace_secs: 5.0 },
    ])
}

/// The chaos-storm arrivals — the tenant-storm *shape* retuned so
/// crash-destroyed progress is what decides deadlines:
///
///   * `latency` — Interactive, ~0.8 req/s across the window, short
///     prompts but LONG decodes (median ~49 tokens, cap 64), each
///     request under a `CHAOS_STORM_SLO_SECS` completion deadline.
///     These sequences are resident for seconds, so the crash lands on
///     *their* decode progress, and whether a checkpoint preserved it
///     shows up directly in the deadline hit-rate.
///   * `noisy`   — Batch, no deadline, a 5 req/s long-decode flood
///     from t = 5 s to t = 25 s that keeps queues deep and decode
///     slots contended through every fault in the plan.
///
/// Ids are assigned in arrival order; deterministic per seed.
pub fn chaos_storm_trace(seed: u64) -> Vec<SubmitRequest> {
    let mut out: Vec<SubmitRequest> = Vec::new();
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 0.8,
            diurnal_amp: 0.0,
            bursts_per_day: 0.0,
            day_secs: CHAOS_STORM_SECS,
            prompt_max: 24,
            gen_mu: 3.9,
            gen_sigma: 0.15,
            gen_max: 64,
            ..TraceConfig::default()
        },
        seed.wrapping_add(7919),
    );
    for r in gen.generate(0.0, CHAOS_STORM_SECS) {
        out.push(SubmitRequest::from_trace(&r)
            .with_tenant("latency")
            .with_priority(PriorityClass::Interactive)
            .with_deadline(r.arrival + CHAOS_STORM_SLO_SECS));
    }
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 5.0,
            diurnal_amp: 0.0,
            bursts_per_day: 0.0,
            day_secs: 20.0,
            prompt_max: 32,
            gen_mu: 3.5,
            gen_sigma: 0.3,
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed.wrapping_add(15838),
    );
    for r in gen.generate(0.0, 20.0) {
        out.push(SubmitRequest::from_trace(&r)
            .with_tenant("noisy")
            .with_priority(PriorityClass::Batch)
            .with_arrival(r.arrival + 5.0));
    }
    out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// The ISSUE-6 acceptance scenario: three identical slow static-dense
/// replicas behind the least-outstanding router, serving
/// [`chaos_storm_trace`] while [`chaos_storm_plan`] tears pieces out of
/// the fleet. Migration is on; the autoscaler can act on the
/// capacity-loss signal only (every load watermark is parked out of
/// reach), so each spawn in this scenario is a crash/reclaim
/// replacement by construction. `checkpointed = true` turns on 1 s
/// periodic KV checkpointing — the crash then restores checkpointed
/// sequences onto peers, where they re-enter admission and resume
/// mid-decode; `false` is the checkpoint-free baseline that loses
/// every in-flight sequence on the crashed replica. Everything else is
/// identical, and deterministic per seed.
pub fn chaos_storm_fleet(seed: u64, checkpointed: bool) -> Fleet {
    let spec = ReplicaSpec {
        // ~2 req/s per replica: the flood genuinely overloads the trio,
        // so the crash catches deep queues and live decodes
        flops_per_sec: 2.0e8,
        app_rate: 0.0,   // faults are the explicit plan above
        adaptive: false, // static dense: isolate recovery mechanics
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: true,
        oom_threshold: usize::MAX, // no pressure-drains in the way
        checkpoint_period_secs: if checkpointed {
            Some(1.0)
        } else {
            None
        },
        autoscale: Some(AutoscaleConfig {
            min_replicas: 3,
            max_replicas: 4,
            // only the capacity-loss signal can fire
            high_queue_per_replica: 1e12,
            low_queue_per_replica: 0.0,
            high_p99_ttft_secs: 1e12,
            high_oom_events: usize::MAX,
            hold_secs: 1.0,
            cooldown_secs: 5.0,
            eval_every_secs: 0.5,
            signal_window_secs: 10.0,
            ..AutoscaleConfig::default()
        }),
        warmup_secs: 1.0,
        max_sim_secs: CHAOS_STORM_SECS + 3600.0,
        ..FleetConfig::default()
    };
    uniform_sim_fleet(3, seed, RouterPolicy::LeastOutstanding, cfg,
                      spec)
        .with_fault_plan(chaos_storm_plan())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_serves_a_trace_and_reports() {
        let mut fleet = default_sim_fleet(3, 9, RouterPolicy::RapAware);
        let reqs = default_fleet_trace(9, 30.0);
        let n = reqs.len() as u64;
        assert!(n > 0);
        let report = fleet.run_trace(reqs).unwrap();
        assert_eq!(report.total_requests, n);
        assert_eq!(report.routing.iter().sum::<u64>() + report.dropped, n);
        assert!(report.completed > 0, "nothing completed");
        assert_eq!(report.replicas.len(), 3);
        assert!(report.sim_secs > 0.0);
        // every arrival is accounted for: finished, rejected somewhere,
        // or dropped at the router
        assert!(report.completed as u64 + report.rejected + report.dropped
                >= n);
        // a fixed fleet never scales or migrates
        assert_eq!(report.spawns + report.retires + report.migrations, 0);
        // trace replay is default tenancy: one tenant, no deadlines
        assert_eq!(report.tenants.len(), 1);
        assert_eq!(report.tenants[0].tenant, crate::api::DEFAULT_TENANT);
        assert_eq!(report.cancelled, 0);
        assert_eq!(report.deadline_missed, 0);
    }

    #[test]
    fn fleet_runs_are_deterministic_per_seed() {
        let run = |seed| {
            let mut fleet =
                default_sim_fleet(2, seed, RouterPolicy::KvHeadroom);
            fleet.run_trace(default_fleet_trace(seed, 20.0)).unwrap()
        };
        let a = run(4);
        let b = run(4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.oom_events, b.oom_events);
        assert_eq!(a.routing, b.routing);
        assert_eq!(a.sim_secs, b.sim_secs);
        let c = run(5);
        assert!(a.routing != c.routing || a.completed != c.completed
                || a.sim_secs != c.sim_secs,
                "different seeds should differ somewhere");
    }

    #[test]
    fn drain_and_respawn_cycle_under_forced_pressure() {
        use crate::server::memmon::MemoryMonitor;

        let mut fleet = default_sim_fleet(2, 3, RouterPolicy::RoundRobin);
        fleet.cfg.oom_threshold = 2;
        fleet.cfg.respawn_secs = 4.0;
        // replica 0 permanently underwater → every routed request OOMs
        let params = fleet.replicas[0].engine.bytes_used();
        let cap = (params as f64 * 1.1) as usize;
        fleet.replicas[0].engine.monitor =
            MemoryMonitor::walls(cap, &[(0.0, 1e12, cap)]);
        let reqs: Vec<Request> = (0..24)
            .map(|i| Request { id: i, arrival: i as f64 * 0.25,
                               prompt_len: 12, gen_len: 4 })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        assert!(report.respawns >= 1,
                "pressured replica never respawned: {report:?}");
        // the healthy replica kept serving throughout
        assert!(report.replicas[1].serve.completed > 0);
    }

    #[test]
    fn scenario_traces_are_deterministic_and_distinct() {
        let builders: [fn(u64, f64) -> Vec<Request>; 3] =
            [ramp_up_trace, drain_down_trace, burst_storm_trace];
        for build in builders {
            let a = build(5, 80.0);
            let b = build(5, 80.0);
            assert!(!a.is_empty());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert!((x.arrival - y.arrival).abs() < 1e-12);
                assert_eq!(x.prompt_len, y.prompt_len);
                assert_eq!(x.gen_len, y.gen_len);
            }
            // ids unique and arrivals ordered within [0, secs)
            let mut prev = 0.0;
            for (i, r) in a.iter().enumerate() {
                assert_eq!(r.id, i as u64);
                assert!(r.arrival >= prev - 1e-12);
                prev = r.arrival;
                assert!(r.arrival < 80.0 + 1e-9);
            }
            let c = build(6, 80.0);
            assert_ne!(a.len(), 0);
            let same = a.len() == c.len()
                && a.iter().zip(&c).all(|(x, y)| {
                    (x.arrival - y.arrival).abs() < 1e-12
                });
            assert!(!same, "different seeds produced the same trace");
        }
        // the ramp's back half is denser than its front half
        let ramp = ramp_up_trace(5, 80.0);
        let front =
            ramp.iter().filter(|r| r.arrival < 40.0).count();
        let back = ramp.len() - front;
        assert!(back > 2 * front,
                "ramp-up not ramping: {front} then {back}");
    }

    #[test]
    fn tenant_storm_trace_is_deterministic_and_two_sided() {
        let a = tenant_storm_trace(42);
        let b = tenant_storm_trace(42);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.slo_deadline, y.slo_deadline);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let latency: Vec<&SubmitRequest> =
            a.iter().filter(|r| r.tenant.as_ref() == "latency").collect();
        let noisy: Vec<&SubmitRequest> =
            a.iter().filter(|r| r.tenant.as_ref() == "noisy").collect();
        assert!(latency.len() >= 20, "thin latency stream: {}",
                latency.len());
        // the flood really is a flood: several times the steady stream
        assert!(noisy.len() >= 2 * latency.len(),
                "{} noisy vs {} latency", noisy.len(), latency.len());
        for r in &latency {
            assert_eq!(r.priority, PriorityClass::Interactive);
            assert_eq!(r.slo_deadline,
                       Some(r.arrival + TENANT_STORM_SLO_SECS));
            assert!(r.max_new_tokens <= 8);
        }
        for r in &noisy {
            assert_eq!(r.priority, PriorityClass::Batch);
            assert_eq!(r.slo_deadline, None);
            assert!(r.arrival >= 5.0 && r.arrival <= 25.0 + 1e-9);
        }
        // ids are arrival-ordered and unique
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        let c = tenant_storm_trace(43);
        assert!(a.len() != c.len()
                || a.iter().zip(&c).any(|(x, y)| {
                    (x.arrival - y.arrival).abs() > 1e-12
                }),
                "different seeds produced the same storm");
    }

    #[test]
    fn spawned_replicas_join_routing_and_reports() {
        // Force a spawn mechanically: autoscaler with a hair-trigger
        // queue watermark and a fleet whose two replicas are buried by
        // an arrival wave on slow devices.
        let spec = ReplicaSpec {
            flops_per_sec: 2.0e7,
            app_rate: 0.0,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            autoscale: Some(AutoscaleConfig {
                min_replicas: 2,
                max_replicas: 4,
                high_queue_per_replica: 2.0,
                hold_secs: 1.0,
                cooldown_secs: 5.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        };
        let mut fleet =
            uniform_sim_fleet(2, 11, RouterPolicy::LeastOutstanding,
                              cfg, spec);
        let reqs: Vec<Request> = (0..40)
            .map(|i| Request { id: i, arrival: 0.1 * i as f64,
                               prompt_len: 16, gen_len: 24 })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        assert!(report.spawns >= 1, "overload never spawned: {report:?}");
        assert!(report.replicas.len() > 2);
        assert_eq!(report.routing.len(), report.replicas.len());
        // a spawned replica actually served traffic
        let extra_completed: usize = report.replicas[2..]
            .iter()
            .map(|r| r.serve.completed)
            .sum();
        assert!(extra_completed > 0,
                "spawned replicas never served: {report:?}");
        assert_eq!(report.completed, 40);
    }

    #[test]
    fn retire_parks_the_least_loaded_replica() {
        let spec = ReplicaSpec {
            app_rate: 0.0,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            autoscale: Some(AutoscaleConfig {
                min_replicas: 1,
                max_replicas: 4,
                hold_secs: 1.0,
                cooldown_secs: 3.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        };
        let mut fleet = uniform_sim_fleet(3, 7, RouterPolicy::RoundRobin,
                                          cfg, spec);
        // a tiny trace, then a long idle tail: the scaler must shed the
        // excess capacity down to min_replicas and no further
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request { id: i, arrival: 0.2 * i as f64,
                               prompt_len: 12, gen_len: 4 })
            .collect();
        fleet.run_trace(reqs).unwrap();
        // idle tail: drive the clock so the scaler can act
        for k in 1..=120 {
            fleet.step_all(fleet.clock + 0.5 * k as f64).unwrap();
        }
        let retired = fleet
            .replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Retired)
            .count();
        let serving = fleet
            .replicas
            .iter()
            .filter(|r| r.accepting())
            .count();
        assert!(retired >= 1, "idle fleet never retired");
        assert!(serving >= 1, "retired below min_replicas");
        assert_eq!(fleet.retires as usize, retired);
    }

    fn chaos_test_fleet(plan: FaultPlan, cfg: FleetConfig) -> Fleet {
        let spec = ReplicaSpec {
            flops_per_sec: 1.0e8,
            app_rate: 0.0,
            adaptive: false,
            capacity_mult: 2.5,
            ..ReplicaSpec::heterogeneous(0)
        };
        uniform_sim_fleet(2, 9, RouterPolicy::LeastOutstanding, cfg,
                          spec)
            .with_fault_plan(plan)
    }

    fn chaos_test_reqs(n: u64) -> Vec<SubmitRequest> {
        (0..n)
            .map(|i| SubmitRequest::new(16, 24)
                .with_id(i)
                .with_arrival(0.05 * i as f64))
            .collect()
    }

    /// A mid-run crash destroys a replica's resident work, but every
    /// displaced request still reaches exactly one terminal outcome —
    /// nothing is silently dropped, nothing double-completes.
    #[test]
    fn crash_displaces_work_without_losing_requests() {
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            ..FleetConfig::default()
        };
        let plan = FaultPlan::new(vec![FaultEvent::Crash {
            at: 2.0,
            replica: 1,
        }]);
        let mut fleet = chaos_test_fleet(plan, cfg);
        let report = fleet.run_requests(chaos_test_reqs(24)).unwrap();
        assert_eq!(report.chaos.crashes, 1);
        assert_eq!(report.chaos.failures_injected, 1);
        // checkpoint-free: the crash's in-flight work lost its progress
        assert!(report.chaos.seq_lost > 0,
                "crash caught no live work: {report:?}");
        assert_eq!(report.chaos.seq_restored, 0);
        assert_eq!(fleet.replicas[1].state, ReplicaState::Failed);
        assert_eq!(fleet.replicas[1].crashes, 1);
        for id in 0..24u64 {
            match fleet.poll(RequestHandle { id }) {
                Some(RequestStatus::Finished(_)) => {}
                other => panic!("request {id} not terminal: {other:?}"),
            }
        }
    }

    /// With periodic checkpointing on, the same crash restores
    /// snapshotted sequences onto the surviving peer instead of losing
    /// them all.
    #[test]
    fn checkpointed_crash_restores_onto_peers() {
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            checkpoint_period_secs: Some(0.25),
            ..FleetConfig::default()
        };
        let plan = FaultPlan::new(vec![FaultEvent::Crash {
            at: 4.0,
            replica: 1,
        }]);
        let mut fleet = chaos_test_fleet(plan, cfg);
        let report = fleet.run_requests(chaos_test_reqs(24)).unwrap();
        assert_eq!(report.chaos.crashes, 1);
        assert!(report.chaos.checkpoints_taken > 0,
                "no checkpoint cycles ran: {report:?}");
        assert!(report.chaos.checkpoint_bytes > 0);
        assert!(report.chaos.seq_restored > 0,
                "nothing restored from checkpoints: {report:?}");
        assert_eq!(fleet.replicas[0].restored_in,
                   report.chaos.seq_restored);
        for id in 0..24u64 {
            match fleet.poll(RequestHandle { id }) {
                Some(RequestStatus::Finished(_)) => {}
                other => panic!("request {id} not terminal: {other:?}"),
            }
        }
    }

    /// A spot reclaim with a generous grace window evacuates everything
    /// through the migration path and retires cleanly: no crash, no
    /// lost sequence, every request completed.
    #[test]
    fn generous_grace_reclaim_drains_losslessly() {
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            ..FleetConfig::default()
        };
        let plan = FaultPlan::new(vec![FaultEvent::Reclaim {
            at: 1.0,
            replica: 1,
            grace_secs: 500.0,
        }]);
        let mut fleet = chaos_test_fleet(plan, cfg);
        let report = fleet.run_requests(chaos_test_reqs(24)).unwrap();
        assert_eq!(report.chaos.reclaims, 1);
        assert_eq!(report.chaos.crashes, 0,
                   "grace expired despite 500 s window: {report:?}");
        assert_eq!(report.chaos.seq_lost, 0);
        assert_eq!(report.completed, 24, "lossy reclaim: {report:?}");
        assert_eq!(fleet.replicas[1].state, ReplicaState::Retired);
    }

    /// A crash feeds the autoscaler's capacity-loss signal: the fleet
    /// spawns a replacement without waiting out the hold, even though
    /// every load watermark is unreachable.
    #[test]
    fn crash_triggers_replacement_spawn() {
        let cfg = FleetConfig {
            migrate: true,
            oom_threshold: usize::MAX,
            autoscale: Some(AutoscaleConfig {
                min_replicas: 2,
                max_replicas: 3,
                high_queue_per_replica: 1e12,
                low_queue_per_replica: 0.0,
                high_p99_ttft_secs: 1e12,
                high_oom_events: usize::MAX,
                hold_secs: 30.0, // far longer than the run
                cooldown_secs: 2.0,
                eval_every_secs: 0.5,
                signal_window_secs: 10.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        };
        let plan = FaultPlan::new(vec![FaultEvent::Crash {
            at: 2.0,
            replica: 1,
        }]);
        let mut fleet = chaos_test_fleet(plan, cfg);
        let report = fleet.run_requests(chaos_test_reqs(24)).unwrap();
        assert!(report.spawns >= 1,
                "capacity loss never spawned a replacement: {report:?}");
        assert_eq!(report.replicas.len(), 3);
        assert_eq!(report.chaos.crashes, 1);
    }

    /// Non-finite arrivals are rejected at the fleet's front door —
    /// terminal, counted, and kept out of the arrival sort.
    #[test]
    fn non_finite_arrivals_are_rejected_at_ingress() {
        let mut fleet = chaos_test_fleet(FaultPlan::default(),
                                         FleetConfig::default());
        let mut reqs = chaos_test_reqs(4);
        reqs.push(SubmitRequest::new(16, 8)
            .with_id(100)
            .with_arrival(f64::NAN));
        reqs.push(SubmitRequest::new(16, 8)
            .with_id(101)
            .with_arrival(f64::INFINITY));
        let report = fleet.run_requests(reqs).unwrap();
        assert_eq!(report.completed, 4);
        assert_eq!(report.dropped, 2);
        for id in [100u64, 101] {
            assert_eq!(fleet.poll(RequestHandle { id }),
                       Some(RequestStatus::Finished(Outcome::Rejected)),
                       "bad arrival {id} not terminal");
        }
    }

    /// The chaos-storm scenario is deterministic per seed: two builds
    /// serve the same trace to byte-identical reports.
    #[test]
    fn chaos_storm_is_deterministic_per_seed() {
        let run = |seed| {
            let mut fleet = chaos_storm_fleet(seed, true);
            fleet.run_requests(chaos_storm_trace(seed))
                .unwrap()
                .to_json()
                .pretty()
        };
        assert_eq!(run(7), run(7), "same seed diverged");
        assert_ne!(run(7), run(8), "different seeds identical");
    }

    /// The fleet-level lifecycle API: submit → poll → cancel, including
    /// a cancel that reaches into a replica's queue.
    #[test]
    fn fleet_submit_poll_cancel() {
        let spec = ReplicaSpec {
            app_rate: 0.0,
            ..ReplicaSpec::heterogeneous(0)
        };
        let mut fleet =
            uniform_sim_fleet(2, 5, RouterPolicy::LeastOutstanding,
                              FleetConfig::default(), spec);
        let h = fleet.submit(SubmitRequest::new(12, 6).with_id(900));
        assert_eq!(fleet.poll(h), Some(RequestStatus::Queued));
        assert!(fleet.cancel(h).unwrap());
        assert_eq!(fleet.poll(h),
                   Some(RequestStatus::Finished(Outcome::Cancelled)));
        assert!(!fleet.cancel(h).unwrap(), "already terminal");
        // a request served to completion polls as Done
        let h2 = fleet.submit(SubmitRequest::new(12, 6).with_id(901));
        for k in 1..=40 {
            fleet.step_all(fleet.clock + 0.5 * k as f64).unwrap();
            fleet.clock += 0.5 * k as f64;
            if fleet.poll(h2)
                == Some(RequestStatus::Finished(Outcome::Done))
            {
                break;
            }
        }
        assert_eq!(fleet.poll(h2),
                   Some(RequestStatus::Finished(Outcome::Done)));
        assert_eq!(fleet.poll(RequestHandle { id: 12345 }), None);
        let report = fleet.report();
        assert_eq!(report.cancelled, 1);
        assert_eq!(report.completed, 1);
    }
}

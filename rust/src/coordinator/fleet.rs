//! The fleet event loop: one shared simulated clock driving N externally
//! stepped engines, a router in front, and a maintenance pass that keeps
//! the fleet healthy — drain/respawn for replicas under sustained OOM
//! pressure, cross-replica migration of in-flight sequences
//! (`FleetConfig::migrate`), and autoscaling (`FleetConfig::autoscale`).
//!
//! Time model: the fleet advances in events — the next trace arrival or
//! the next maintenance tick, whichever comes first. Every replica is
//! stepped to that time (`Replica::step_to`), then due arrivals are
//! routed. Individual engines may overshoot the barrier by at most one
//! compute step (documented on `Engine::step_to`); latency accounting
//! uses true arrival times, so the skew never leaks into metrics.
//!
//! Migration model: when interference collapses a replica's
//! `Sys_avail(t)` headroom, its engine parks victims (chosen by KV bytes
//! × remaining decode — see `EvictionMode::Park`) instead of evicting
//! them, and the fleet ships each parked state to the peer with the most
//! *elastic* headroom, charging the sim backend's modeled transfer cost
//! (`Runtime::transfer_cost`) before the payload lands. Queued work on a
//! collapsed replica is rebalanced the same way before the engines step,
//! so requests are not burned by a pressure wall they never had a chance
//! against. When no peer can take a victim, the fleet falls back to the
//! classic local requeue (and charges the eviction).
//!
//! Pressure is judged *mask-elastically* (`FleetConfig::
//! elastic_accounting`, on by default): a collapse exists only when not
//! even the replica's min-viable mask fits `Sys_avail(t)` (see
//! `server::outlook::MemoryOutlook`). An interference spike the RAP
//! controller can absorb by shrinking therefore triggers no queue
//! rebalancing, no migration, and — because the engine charges it to
//! `absorbed_spikes` instead of `oom_events` — no OOM-driven
//! autoscaling. The `absorbable_spike_fleet` scenario pins this down.

use anyhow::Result;

use super::autoscaler::{Autoscaler, FleetSignals, ScaleDecision};
use super::metrics::{FleetReport, ReplicaReport};
use super::replica::{build_sim_replica, Replica, ReplicaSpec,
                     ReplicaState};
use super::router::{Router, RouterPolicy};
use crate::model_meta::ModelMeta;
use crate::server::engine::{EvictionMode, SeqState};
use crate::util::stats::{mean, percentile};
use crate::workload::{Request, TraceConfig, TraceGenerator};

pub use super::autoscaler::AutoscaleConfig;

#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Drain a Serving replica when it sees at least this many OOM
    /// events within `oom_window_secs` (usize::MAX disables draining).
    pub oom_threshold: usize,
    pub oom_window_secs: f64,
    /// Offline cool-down after a drain completes.
    pub respawn_secs: f64,
    /// Maintenance cadence (drain/respawn checks between arrivals).
    pub tick_secs: f64,
    /// Hard stop for one `run_trace` call (sim seconds).
    pub max_sim_secs: f64,
    /// Migrate in-flight sequences off pressured replicas instead of
    /// evicting them locally (engines switch to `EvictionMode::Park`).
    pub migrate: bool,
    /// Spawn/retire replicas from fleet-level load signals. `None`
    /// keeps the fixed-size drain/respawn-only fleet.
    pub autoscale: Option<AutoscaleConfig>,
    /// Mask-elastic memory accounting (`server::outlook`): every
    /// pressure decision — engine OOMs, queue rebalancing, migration
    /// targeting, router headroom — is judged against the min-viable
    /// footprint instead of the current-mask footprint, so spikes the
    /// RAP controllers can absorb by shrinking stop triggering phantom
    /// migrations and spawns. Copied onto every replica engine. Off
    /// reproduces the pre-outlook (current-mask) behavior for
    /// comparison runs.
    pub elastic_accounting: bool,
}

impl FleetConfig {
    /// The engine-level eviction mode this fleet config implies.
    fn eviction_mode(&self) -> EvictionMode {
        if self.migrate {
            EvictionMode::Park
        } else {
            EvictionMode::Requeue
        }
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            oom_threshold: 8,
            oom_window_secs: 20.0,
            respawn_secs: 8.0,
            tick_secs: 0.5,
            max_sim_secs: 3600.0,
            migrate: false,
            autoscale: None,
            elastic_accounting: true,
        }
    }
}

/// One sequence state in flight between replicas.
struct Transfer {
    state: SeqState,
    src: usize,
    dest: usize,
    /// Sim time the payload lands (dispatch + modeled transfer cost).
    arrive_at: f64,
}

pub struct Fleet {
    pub cfg: FleetConfig,
    pub replicas: Vec<Replica>,
    pub router: Router,
    /// The shared simulated clock.
    pub clock: f64,
    /// Arrivals no accepting replica could take.
    pub dropped: u64,
    /// Sequence states currently in flight between replicas.
    transfers: Vec<Transfer>,
    /// Completed migrations and the payload bytes they moved.
    pub migrations: u64,
    pub migration_bytes: u64,
    /// Replicas added by the autoscaler.
    pub spawns: u64,
    /// Replicas retired by the autoscaler.
    pub retires: u64,
    autoscaler: Option<Autoscaler>,
    /// Replica factory for autoscale spawns (id → fresh replica).
    spawner: Option<Box<dyn Fn(usize) -> Replica>>,
}

impl Fleet {
    pub fn new(mut replicas: Vec<Replica>, router: Router,
               cfg: FleetConfig) -> Fleet {
        assert_eq!(router.decisions.len(), replicas.len(),
                   "router sized for a different fleet");
        for r in &mut replicas {
            r.engine.cfg.eviction = cfg.eviction_mode();
            r.engine.cfg.elastic_accounting = cfg.elastic_accounting;
        }
        Fleet {
            autoscaler: cfg.autoscale.map(Autoscaler::new),
            cfg,
            replicas,
            router,
            clock: 0.0,
            dropped: 0,
            transfers: Vec::new(),
            migrations: 0,
            migration_bytes: 0,
            spawns: 0,
            retires: 0,
            spawner: None,
        }
    }

    /// Install a replica factory so autoscale-up can add capacity. The
    /// closure receives the new replica's id (ids never repeat —
    /// retired replicas stay in the roster).
    pub fn with_spawner(mut self,
                        f: impl Fn(usize) -> Replica + 'static) -> Fleet {
        self.spawner = Some(Box::new(f));
        self
    }

    fn all_idle(&self) -> bool {
        self.transfers.is_empty()
            && self.replicas.iter().all(|r| {
                r.engine.idle() && r.engine.parked_len() == 0
            })
    }

    /// Step every replica to `t`, then run the maintenance passes:
    /// migration (queue rebalance before the step, parked pickup and
    /// transfer delivery after), drain/respawn, and autoscaling.
    fn step_all(&mut self, t: f64) -> Result<()> {
        if self.cfg.migrate {
            self.rebalance_queued(t);
        }
        for r in &mut self.replicas {
            r.step_to(t)?;
        }
        if self.cfg.migrate {
            self.dispatch_parked(t);
        }
        self.deliver_transfers(t)?;
        self.maintain(t);
        self.autoscale(t);
        Ok(())
    }

    // ---- migration ----------------------------------------------------

    /// A replica that cannot host queued work even under its
    /// *min-viable* mask (a true collapse, not a spike its controller
    /// will absorb by shrinking) is about to shed in-flight work; move
    /// its admission queue to peers with headroom before the engines
    /// step, so the queue isn't burned by head-of-line rejections
    /// against a pressure wall. Gating on the outlook instead of the
    /// current-mask footprint is what stops an absorbable interference
    /// spike from rerouting the whole queue for nothing (with
    /// `elastic_accounting` off the outlook is rigid and this reduces
    /// to the old `bytes_used > Sys_avail` test).
    fn rebalance_queued(&mut self, t: f64) {
        for src in 0..self.replicas.len() {
            let collapsed = {
                let r = &self.replicas[src];
                r.live()
                    && !r.engine.batcher.waiting.is_empty()
                    && r.engine
                        .outlook()
                        .true_oom(r.engine.monitor.available_at(t))
            };
            if !collapsed {
                continue;
            }
            let reqs = self.replicas[src].engine.take_waiting();
            for req in reqs {
                self.send_state(src, SeqState::Queued(req), t);
            }
        }
    }

    /// Collect the sequences each engine parked under memory pressure
    /// during this step and ship them out.
    fn dispatch_parked(&mut self, t: f64) {
        for src in 0..self.replicas.len() {
            if self.replicas[src].engine.parked_len() == 0 {
                continue;
            }
            let parked = self.replicas[src].engine.take_parked();
            for state in parked {
                self.send_state(src, state, t);
            }
        }
    }

    /// Per-destination load already committed but not yet landed:
    /// (pending transfer count, projected full-length KV bytes of each
    /// pending sequence at its destination). Folding this into the
    /// target score stops one maintenance pass from herding every
    /// refugee onto the same peer before any of them arrive.
    fn pending_per_dest(&self) -> (Vec<usize>, Vec<usize>) {
        let mut count = vec![0usize; self.replicas.len()];
        let mut bytes = vec![0usize; self.replicas.len()];
        for tr in &self.transfers {
            count[tr.dest] += 1;
            bytes[tr.dest] += self.replicas[tr.dest]
                .engine
                .elastic_admission_cost(tr.state.request());
        }
        (count, bytes)
    }

    fn pick_target(&self, src: usize, state: &SeqState, t: f64)
                   -> Option<usize> {
        let (count, bytes) = self.pending_per_dest();
        migration_target(&self.replicas, src, state, t, &count, &bytes)
    }

    /// Ship one sequence state from `src` to the best destination, or
    /// hand it back to `src` (a local requeue — the classic eviction)
    /// when no peer can take it.
    fn send_state(&mut self, src: usize, state: SeqState, t: f64) {
        let bytes = state.transfer_bytes();
        match self.pick_target(src, &state, t) {
            Some(dest) => {
                let cost =
                    self.replicas[src].engine.rt.transfer_cost(bytes);
                self.transfers.push(Transfer {
                    state,
                    src,
                    dest,
                    arrive_at: t + cost,
                });
            }
            None => self.requeue_local(src, state),
        }
    }

    /// No destination: fall back to the classic local eviction — the
    /// request restarts from its prompt (any KV is dropped) and the
    /// eviction is charged to `src`'s metrics. If `src` itself went
    /// offline while the move was in flight (drained, retiring), the
    /// request joins the first accepting replica's queue instead:
    /// offline replicas must never be handed new work.
    fn requeue_local(&mut self, src: usize, state: SeqState) {
        let home = if self.replicas[src].accepting() {
            src
        } else {
            self.replicas
                .iter()
                .position(|r| r.accepting())
                .unwrap_or(src)
        };
        match state {
            SeqState::Queued(req) => {
                self.replicas[home].engine.batcher.waiting.push_back(req);
            }
            SeqState::Active { req, .. } => {
                self.replicas[src].engine.metrics.evictions += 1;
                self.replicas[home].engine.batcher.waiting.push_front(req);
            }
        }
    }

    /// Land transfers whose payload has arrived. A destination that
    /// stopped accepting while the payload was in flight is re-resolved
    /// (the state already left its source, so it waits one tick); when
    /// no peer can take it at all, the move is abandoned and the
    /// sequence requeues at its source — it must never be lost or spin
    /// in flight until the deadline.
    fn deliver_transfers(&mut self, t: f64) -> Result<()> {
        let pending = std::mem::take(&mut self.transfers);
        for tr in pending {
            if tr.arrive_at > t {
                self.transfers.push(tr);
                continue;
            }
            if !self.replicas[tr.dest].accepting() {
                match self.pick_target(tr.src, &tr.state, t) {
                    Some(dest) => self.transfers.push(Transfer {
                        dest,
                        arrive_at: t + self.cfg.tick_secs,
                        ..tr
                    }),
                    None => {
                        // No peer — but if the source itself recovered
                        // while the payload was in flight, re-import
                        // there losslessly (no interconnect charge for
                        // coming home) instead of dropping the KV.
                        let src = &self.replicas[tr.src];
                        let src_ok = src.accepting()
                            && src.elastic_headroom(t)
                                > src.engine.elastic_admission_cost(
                                    tr.state.request())
                            && src.engine.can_import(&tr.state);
                        if src_ok {
                            self.replicas[tr.src]
                                .engine
                                .import_sequence(tr.state)?;
                        } else {
                            self.requeue_local(tr.src, tr.state);
                        }
                    }
                }
                continue;
            }
            if self.replicas[tr.dest].engine.can_import(&tr.state) {
                let bytes = tr.state.transfer_bytes() as u64;
                self.replicas[tr.dest].engine.import_sequence(tr.state)?;
                // counted on delivery (not dispatch), so abandoned
                // moves never desynchronize the in/out/aggregate
                // counters
                self.replicas[tr.src].migrations_out += 1;
                self.replicas[tr.dest].migrations_in += 1;
                self.migrations += 1;
                self.migration_bytes += bytes;
            } else {
                // Shape mismatch across heterogeneous models: the
                // payload is useless there — the sequence restarts from
                // its prompt. A lossy move is an eviction, not a
                // migration, in the books.
                let req = tr.state.request().clone();
                self.replicas[tr.src].engine.metrics.evictions += 1;
                self.replicas[tr.dest].engine.enqueue(req);
            }
        }
        Ok(())
    }

    // ---- lifecycle ----------------------------------------------------

    /// Lifecycle maintenance: drain replicas under sustained pressure
    /// (never the last serving one), and move drained-empty replicas on
    /// to their next state — a respawn cool-down, or `Retired` when the
    /// autoscaler flagged them. Respawn completion happens inside
    /// `Replica::step_to`.
    fn maintain(&mut self, t: f64) {
        let mut serving = self
            .replicas
            .iter()
            .filter(|r| r.accepting())
            .count();
        let window = self.cfg.oom_window_secs;
        let threshold = self.cfg.oom_threshold;
        for r in &mut self.replicas {
            match r.state {
                ReplicaState::Serving => {
                    if threshold != usize::MAX
                        && serving > 1
                        && r.recent_ooms(t, window) >= threshold
                    {
                        r.state = ReplicaState::Draining;
                        serving -= 1;
                    }
                }
                ReplicaState::Draining => {
                    if r.engine.idle() && r.engine.parked_len() == 0 {
                        if r.retiring {
                            r.state = ReplicaState::Retired;
                        } else {
                            r.state = ReplicaState::Respawning {
                                until: t + self.cfg.respawn_secs,
                            };
                            r.respawns += 1;
                        }
                    }
                }
                ReplicaState::Respawning { .. }
                | ReplicaState::Retired => {}
            }
        }
    }

    // ---- autoscaling --------------------------------------------------

    /// Fleet-level load signals over the trailing `window` seconds.
    fn signals(&mut self, t: f64, window: f64) -> FleetSignals {
        let serving =
            self.replicas.iter().filter(|r| r.accepting()).count();
        let outstanding: usize = self
            .replicas
            .iter()
            .filter(|r| r.live())
            .map(|r| r.outstanding())
            .sum();
        let t0 = t - window;
        let mut ttfts = Vec::new();
        let mut recent_ooms = 0usize;
        for r in &mut self.replicas {
            recent_ooms += r.ooms_since(t0);
            r.recent_ttfts(t0, &mut ttfts);
        }
        FleetSignals {
            serving,
            outstanding,
            p99_ttft: percentile(&ttfts, 99.0),
            recent_ooms,
        }
    }

    fn autoscale(&mut self, t: f64) {
        let Some(mut scaler) = self.autoscaler.take() else {
            return;
        };
        // signal collection scans completion records — skip it entirely
        // between the scaler's evaluation ticks
        if !scaler.due(t) {
            self.autoscaler = Some(scaler);
            return;
        }
        let signals = self.signals(t, scaler.cfg.signal_window_secs);
        let applied = match scaler.decide(t, &signals) {
            ScaleDecision::Up => self.spawn_replica(),
            ScaleDecision::Down => self.retire_replica(),
            ScaleDecision::Hold => false,
        };
        if applied {
            scaler.note_action(t);
        }
        self.autoscaler = Some(scaler);
    }

    /// Add a replica via the installed spawner. Returns false when no
    /// spawner is installed — the fleet then simply cannot scale up —
    /// or when the replicas that will eventually serve again (serving,
    /// pressure-draining, or respawning) already fill `max_replicas`:
    /// the scaler's own bound only sees the *currently accepting*
    /// count, which dips while a drained replica cools down.
    fn spawn_replica(&mut self) -> bool {
        let Some(spawner) = &self.spawner else {
            return false;
        };
        if let Some(auto) = &self.cfg.autoscale {
            let returning = self
                .replicas
                .iter()
                .filter(|r| r.live() && !r.retiring)
                .count();
            if returning >= auto.max_replicas {
                return false;
            }
        }
        let id = self.replicas.len();
        let mut r = spawner(id);
        r.id = id;
        r.engine.cfg.eviction = self.cfg.eviction_mode();
        r.engine.cfg.elastic_accounting = self.cfg.elastic_accounting;
        self.replicas.push(r);
        self.router.decisions.push(0);
        self.spawns += 1;
        true
    }

    /// Begin retiring the least-loaded serving replica: it stops
    /// accepting work, drains, and parks as `Retired`. Ties break
    /// toward the highest id so the original fleet core is the last to
    /// go. Returns false when only one serving replica remains.
    fn retire_replica(&mut self) -> bool {
        let serving =
            self.replicas.iter().filter(|r| r.accepting()).count();
        if serving <= 1 {
            return false;
        }
        let pick = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.accepting())
            .min_by_key(|(i, r)| (r.outstanding(), std::cmp::Reverse(*i)))
            .map(|(i, _)| i);
        let Some(i) = pick else {
            return false;
        };
        self.replicas[i].retiring = true;
        self.replicas[i].state = ReplicaState::Draining;
        self.retires += 1;
        true
    }

    // ---- the event loop -----------------------------------------------

    /// Replay a trace across the fleet and report. Arrivals are routed
    /// at their arrival time; the run ends when all work has drained —
    /// in-flight transfers included — or at `max_sim_secs`.
    pub fn run_trace(&mut self, mut requests: Vec<Request>)
                     -> Result<FleetReport> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        // relative to where the shared clock already is, so a Fleet can
        // replay several traces back to back (mirrors Engine::run_trace)
        let deadline = self.clock + self.cfg.max_sim_secs;
        let mut next = 0usize;
        while self.clock < deadline {
            let mut target = self.clock + self.cfg.tick_secs;
            if next < requests.len() {
                target = target.min(requests[next].arrival);
            }
            target = target.min(deadline).max(self.clock + 1e-9);
            self.step_all(target)?;
            self.clock = target;
            while next < requests.len()
                && requests[next].arrival <= self.clock
            {
                let req = requests[next].clone();
                next += 1;
                match self.router.route(&req, &self.replicas, self.clock) {
                    Some(i) => self.replicas[i].enqueue(req),
                    None => self.dropped += 1,
                }
            }
            if next >= requests.len() && self.all_idle() {
                break;
            }
        }
        // Arrivals past the deadline were never offered to the router;
        // count them as dropped so the report's accounting invariant
        // (routing-histogram sum + dropped == trace length) holds even
        // on a truncated run.
        self.dropped += (requests.len() - next) as u64;
        Ok(self.report())
    }

    /// Snapshot the fleet's metrics (callable after `run_trace`).
    pub fn report(&self) -> FleetReport {
        let wall = self.clock.max(1e-9);
        let mut lats = Vec::new();
        let mut ttfts = Vec::new();
        let mut completed = 0usize;
        let mut rejected = 0u64;
        let mut evictions = 0u64;
        let mut oom_events = 0u64;
        let mut absorbed_spikes = 0u64;
        let mut respawns = 0u64;
        let mut replicas = Vec::with_capacity(self.replicas.len());
        for r in &self.replicas {
            for rec in &r.engine.metrics.completed {
                lats.push(rec.latency());
                ttfts.push(rec.ttft());
            }
            completed += r.engine.metrics.completed.len();
            rejected += r.engine.metrics.rejected;
            evictions += r.engine.metrics.evictions;
            oom_events += r.engine.metrics.oom_events;
            absorbed_spikes += r.engine.metrics.absorbed_spikes;
            respawns += r.respawns;
            replicas.push(ReplicaReport {
                id: r.id,
                state: r.state.name().to_string(),
                capacity_bytes: r.engine.monitor.cfg.capacity,
                routed: r.routed,
                respawns: r.respawns,
                migrations_in: r.migrations_in,
                migrations_out: r.migrations_out,
                serve: r.engine.metrics.report(wall),
            });
        }
        let routed: u64 = self.router.decisions.iter().sum();
        FleetReport {
            policy: self.router.policy.name().to_string(),
            sim_secs: self.clock,
            total_requests: routed + self.dropped,
            completed,
            rejected,
            evictions,
            dropped: self.dropped,
            oom_events,
            absorbed_spikes,
            respawns,
            spawns: self.spawns,
            retires: self.retires,
            migrations: self.migrations,
            migration_bytes: self.migration_bytes,
            mean_latency: mean(&lats),
            p50_latency: percentile(&lats, 50.0),
            p99_latency: percentile(&lats, 99.0),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            throughput_rps: completed as f64 / wall,
            routing: self.router.decisions.clone(),
            replicas,
        }
    }
}

/// Destination scoring for one migrating sequence — the rap-aware
/// router's shape, applied to migration: *elastic* memory surplus
/// (`Sys_avail(t)` minus the peer's min-viable footprint — a peer
/// mid-mask-shrink is not "full") after taking the sequence's projected
/// full-length cache, discounted by queue depth. Requiring positive
/// surplus keeps migration memory-safe; the queue discount stops a
/// pressure wall from herding every refugee onto the single roomiest
/// replica (one deep queue is how tail latency dies). `pending_count` /
/// `pending_bytes` are per-replica in-flight transfer loads (see
/// `Fleet::pending_per_dest`), charged as if already landed so a burst
/// of sends inside one maintenance pass spreads out. Ties break toward
/// the lowest index, so migration is deterministic.
pub fn migration_target(replicas: &[Replica], src: usize,
                        state: &SeqState, t: f64,
                        pending_count: &[usize],
                        pending_bytes: &[usize]) -> Option<usize> {
    let req = state.request();
    let mut best: Option<(usize, f64)> = None;
    for (i, r) in replicas.iter().enumerate() {
        if i == src || !r.accepting() {
            continue;
        }
        let headroom =
            r.elastic_headroom(t).saturating_sub(pending_bytes[i]);
        // like for like: elastic headroom vs the cost under the mask
        // the peer would shrink to (current-mask cost would leave
        // phantom infeasibility on dense adaptive peers)
        let need = r.engine.elastic_admission_cost(req);
        if headroom <= need {
            continue;
        }
        let score = (headroom - need) as f64
            / (1.0 + (r.outstanding() + pending_count[i]) as f64);
        if best.map_or(true, |(_, s)| score > s) {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// The model every default sim replica serves: small enough that fleet
/// sweeps are instant, large enough (max_seq 256) that the default trace
/// config's prompt buckets + generations fit a sequence.
pub fn default_sim_meta() -> ModelMeta {
    ModelMeta::synthetic("fleet-sim", 4, 128, 8, 4, 512, 512, 256)
}

/// N heterogeneous sim replicas (capacity / interference / device speed
/// from `ReplicaSpec::heterogeneous`) behind a router. Deterministic per
/// seed.
pub fn default_sim_fleet(n_replicas: usize, seed: u64,
                         policy: RouterPolicy) -> Fleet {
    default_sim_fleet_with(n_replicas, seed, policy,
                           FleetConfig::default())
}

/// As [`default_sim_fleet`], with an explicit fleet config (set
/// `migrate` / `autoscale` for elastic serving). The installed spawner
/// reuses the same heterogeneous palette, so autoscaled fleets stay
/// deterministic per seed.
pub fn default_sim_fleet_with(n_replicas: usize, seed: u64,
                              policy: RouterPolicy, cfg: FleetConfig)
                              -> Fleet {
    let meta = default_sim_meta();
    let replicas: Vec<Replica> = (0..n_replicas)
        .map(|i| build_sim_replica(i, &meta,
                                   &ReplicaSpec::heterogeneous(i), seed))
        .collect();
    let router = Router::new(policy, n_replicas);
    Fleet::new(replicas, router, cfg).with_spawner(move |id| {
        build_sim_replica(id, &meta, &ReplicaSpec::heterogeneous(id),
                          seed)
    })
}

/// A fleet of `n` identical replicas built from one spec — scenario
/// tests and the elastic experiment use this to control device speed
/// and memory exactly. The spawner clones the same spec.
pub fn uniform_sim_fleet(n: usize, seed: u64, policy: RouterPolicy,
                         cfg: FleetConfig, spec: ReplicaSpec) -> Fleet {
    let meta = default_sim_meta();
    let replicas: Vec<Replica> = (0..n)
        .map(|i| build_sim_replica(i, &meta, &spec, seed))
        .collect();
    let router = Router::new(policy, n);
    Fleet::new(replicas, router, cfg).with_spawner(move |id| {
        build_sim_replica(id, &meta, &spec, seed)
    })
}

/// A diurnal + bursty trace sized for `default_sim_meta` (generation cap
/// keeps prefill-bucket + generated tokens within max_seq).
pub fn default_fleet_trace(seed: u64, secs: f64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 2.0,
            day_secs: secs.max(60.0),
            bursts_per_day: (secs / 60.0).ceil().max(1.0),
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed,
    );
    gen.generate(0.0, secs)
}

// ---- scenario traces (elastic-fleet harness) --------------------------

/// Constant-rate stages back to back (bursts and diurnal swing off),
/// ids reassigned to stay unique across stage boundaries.
fn staged_trace(seed: u64, stages: &[(f64, f64)]) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::new();
    let mut t0 = 0.0;
    for (k, &(secs, rate)) in stages.iter().enumerate() {
        let mut gen = TraceGenerator::new(
            TraceConfig {
                base_rate: rate,
                diurnal_amp: 0.0,
                bursts_per_day: 0.0,
                day_secs: secs.max(1.0),
                gen_max: 48,
                ..TraceConfig::default()
            },
            seed.wrapping_add(7919 * (k as u64 + 1)),
        );
        let mut reqs = gen.generate(0.0, secs);
        for r in &mut reqs {
            r.arrival += t0;
        }
        out.extend(reqs);
        t0 += secs;
    }
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Ramp-up: the arrival rate staircases 0.5 → 6 req/s across `secs`.
pub fn ramp_up_trace(seed: u64, secs: f64) -> Vec<Request> {
    let s = secs / 4.0;
    staged_trace(seed, &[(s, 0.5), (s, 1.5), (s, 3.0), (s, 6.0)])
}

/// Drain-down: the ramp in reverse.
pub fn drain_down_trace(seed: u64, secs: f64) -> Vec<Request> {
    let s = secs / 4.0;
    staged_trace(seed, &[(s, 6.0), (s, 3.0), (s, 1.5), (s, 0.5)])
}

/// Length of the elastic demo scenario (`elastic_demo_fleet` +
/// `elastic_demo_trace`).
pub const ELASTIC_DEMO_SECS: f64 = 120.0;

/// The elastic-serving demo scenario shared by `tests/elastic_fleet.rs`
/// and `rap experiment fleet --elastic`: two slow static-dense replicas
/// behind the least-outstanding router, hit by a burst storm while a
/// periodic interference wall (10 s every 25 s) leaves replica 0 less
/// than the dense parameter footprint — exactly the squeeze migration
/// and autoscaling exist for. `elastic = false` is the fixed-size
/// drain/respawn baseline; `true` turns on migration plus a
/// burst-reactive autoscaler (short hold/cooldown — a storm is over
/// before the conservative defaults would act). Everything else
/// (replicas, trace, router, thresholds) is identical, and
/// deterministic per seed.
pub fn elastic_demo_fleet(seed: u64, elastic: bool) -> Fleet {
    use crate::server::memmon::MemoryMonitor;

    let spec = ReplicaSpec {
        // ~1 req/s per replica at this model size: the storm's bursts
        // overload the pair, and sequences live long enough for the
        // walls to catch them mid-decode
        flops_per_sec: 1.0e8,
        app_rate: 0.0, // interference is the explicit wall below
        adaptive: false, // static dense: isolate fleet mechanics
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: elastic,
        autoscale: if elastic {
            Some(AutoscaleConfig {
                min_replicas: 2,
                max_replicas: 8,
                hold_secs: 2.0,
                cooldown_secs: 5.0,
                eval_every_secs: 0.5,
                signal_window_secs: 10.0,
                high_p99_ttft_secs: 4.0,
                ..AutoscaleConfig::default()
            })
        } else {
            None
        },
        max_sim_secs: ELASTIC_DEMO_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed,
                                      RouterPolicy::LeastOutstanding,
                                      cfg, spec);
    // Replica 0: 4× params capacity, so between walls it serves its
    // share of in-flight work. Each wall leaves only half the dense
    // parameter footprint available: whatever is mid-decode there must
    // move or die.
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = params * 4;
    let walls: Vec<(f64, f64, usize)> = (0..4)
        .map(|k| (15.0 + 25.0 * k as f64, 25.0 + 25.0 * k as f64,
                  cap - params / 2))
        .collect();
    fleet.replicas[0].engine.monitor = MemoryMonitor::walls(cap, &walls);
    fleet
}

/// The burst-storm trace `elastic_demo_fleet` is squeezed with.
pub fn elastic_demo_trace(seed: u64) -> Vec<Request> {
    burst_storm_trace(seed, ELASTIC_DEMO_SECS)
}

/// Length of the absorbable-spike scenario's arrival window
/// (`absorbable_spike_fleet` + `absorbable_spike_trace`); the
/// interference wall begins the moment arrivals end.
pub const ABSORBABLE_SPIKE_SECS: f64 = 20.0;

/// The ISSUE-4 acceptance scenario: an interference spike that RAP's
/// controllers can *fully absorb* by mask-shrinking, aimed at a fleet
/// whose every pressure reflex (queue rebalancing, migration, OOM-driven
/// autoscaling) is armed.
///
/// Two adaptive (GsiGreedy) replicas behind the least-outstanding
/// router; an arrival burst piles queues up, and the moment arrivals
/// end a 12 s interference wall lands on replica 0, sized so that
/// `min_viable < Sys_avail(t) < current(dense)` — the absorbable band.
/// Migration is on and the autoscaler is configured so only the OOM
/// signal can trigger a spawn (queue/TTFT watermarks parked out of
/// reach, `high_oom_events: 1`): every spawn or migration in this
/// scenario is by construction *phantom* pressure, and because no
/// arrivals remain, none of it can help — the current-mask fleet dumps
/// replica 0's whole queue onto its peer (concentrating the burst
/// behind one replica) and spawns capacity nothing will ever be routed
/// to, while the mask-elastic fleet shrinks replica 0's mask (which
/// also makes it proportionally *faster*) and serves everything in
/// place. The replicas' controller period is stretched to 30 s so that
/// during the wall only pressure-forced decisions move the mask — the
/// booked OOM/absorbed outcome is then deterministic, not a race
/// against the periodic re-decide.
///
/// `mask_elastic = true` (the fix) judges pressure against the memory
/// outlook: the spike is absorbed, and migrations and spawns must both
/// be zero. `mask_elastic = false` reproduces the current-mask
/// accounting: the same spike reroutes the queue and spawns a replica.
/// Everything else is identical and deterministic per seed.
pub fn absorbable_spike_fleet(seed: u64, mask_elastic: bool) -> Fleet {
    use crate::server::memmon::MemoryMonitor;

    let spec = ReplicaSpec {
        // slow enough (~1 req/s per replica) that the burst builds a
        // real queue for phantom pressure to reroute
        flops_per_sec: 1.0e8,
        app_rate: 0.0, // interference is the explicit wall below
        adaptive: true, // the whole point: masks that can shrink
        capacity_mult: 2.5,
        ..ReplicaSpec::heterogeneous(0)
    };
    let cfg = FleetConfig {
        migrate: true,
        // no drain/respawn: isolate the outlook's effect
        oom_threshold: usize::MAX,
        autoscale: Some(AutoscaleConfig {
            min_replicas: 2,
            max_replicas: 4,
            // only the OOM signal can fire: queue/TTFT watermarks are
            // unreachable, and the low watermark never retires
            high_queue_per_replica: 1e12,
            low_queue_per_replica: 0.0,
            high_p99_ttft_secs: 1e12,
            high_oom_events: 1,
            hold_secs: 1.0,
            cooldown_secs: 10.0,
            eval_every_secs: 0.5,
            signal_window_secs: 10.0,
            ..AutoscaleConfig::default()
        }),
        elastic_accounting: mask_elastic,
        max_sim_secs: ABSORBABLE_SPIKE_SECS + 3600.0,
        ..FleetConfig::default()
    };
    let mut fleet = uniform_sim_fleet(2, seed,
                                      RouterPolicy::LeastOutstanding,
                                      cfg, spec);
    for r in &mut fleet.replicas {
        r.engine.cfg.controller_period = 30.0;
    }
    // The wall is sized into the absorbable band: it leaves 0.72× the
    // dense parameter footprint available — under the dense footprint
    // (pressure under the current mask) but well over the min-viable
    // one (≈0.3× params + the shrunken KV), so the controller alone
    // can always absorb it.
    let params = fleet.replicas[0].engine.bytes_used();
    let cap = fleet.replicas[0].engine.monitor.cfg.capacity;
    let avail = (params as f64 * 0.72) as usize;
    fleet.replicas[0].engine.monitor = MemoryMonitor::walls(
        cap, &[(ABSORBABLE_SPIKE_SECS, ABSORBABLE_SPIKE_SECS + 12.0,
                cap - avail)]);
    fleet
}

/// The trace `absorbable_spike_fleet` serves: a steady base load ending
/// in a dense 3 s arrival burst straight into the wall, so both
/// replicas carry deep queues and live decodes when the interference
/// lands. Generations are long (`gen_mu` 3.0, ~27-token median) so the
/// wall reliably catches mid-decode work.
pub fn absorbable_spike_trace(seed: u64) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::new();
    let mut t0 = 0.0;
    for (k, &(secs, rate)) in [(17.0, 1.2), (3.0, 6.0)].iter()
        .enumerate()
    {
        let mut gen = TraceGenerator::new(
            TraceConfig {
                base_rate: rate,
                diurnal_amp: 0.0,
                bursts_per_day: 0.0,
                day_secs: secs.max(1.0),
                gen_mu: 3.0,
                gen_max: 48,
                ..TraceConfig::default()
            },
            seed.wrapping_add(7919 * (k as u64 + 1)),
        );
        let mut reqs = gen.generate(0.0, secs);
        for r in &mut reqs {
            r.arrival += t0;
        }
        out.extend(reqs);
        t0 += secs;
    }
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Burst storm: a calm baseline punctured by dense burst episodes.
pub fn burst_storm_trace(seed: u64, secs: f64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        TraceConfig {
            base_rate: 1.0,
            diurnal_amp: 0.0,
            day_secs: secs.max(60.0),
            bursts_per_day: (secs / 25.0).ceil().max(2.0),
            burst_mult: 8.0,
            burst_secs: 6.0,
            gen_max: 48,
            ..TraceConfig::default()
        },
        seed,
    );
    let mut reqs = gen.generate(0.0, secs);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i as u64;
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_serves_a_trace_and_reports() {
        let mut fleet = default_sim_fleet(3, 9, RouterPolicy::RapAware);
        let reqs = default_fleet_trace(9, 30.0);
        let n = reqs.len() as u64;
        assert!(n > 0);
        let report = fleet.run_trace(reqs).unwrap();
        assert_eq!(report.total_requests, n);
        assert_eq!(report.routing.iter().sum::<u64>() + report.dropped, n);
        assert!(report.completed > 0, "nothing completed");
        assert_eq!(report.replicas.len(), 3);
        assert!(report.sim_secs > 0.0);
        // every arrival is accounted for: finished, rejected somewhere,
        // or dropped at the router
        assert!(report.completed as u64 + report.rejected + report.dropped
                >= n);
        // a fixed fleet never scales or migrates
        assert_eq!(report.spawns + report.retires + report.migrations, 0);
    }

    #[test]
    fn fleet_runs_are_deterministic_per_seed() {
        let run = |seed| {
            let mut fleet =
                default_sim_fleet(2, seed, RouterPolicy::KvHeadroom);
            fleet.run_trace(default_fleet_trace(seed, 20.0)).unwrap()
        };
        let a = run(4);
        let b = run(4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.oom_events, b.oom_events);
        assert_eq!(a.routing, b.routing);
        assert_eq!(a.sim_secs, b.sim_secs);
        let c = run(5);
        assert!(a.routing != c.routing || a.completed != c.completed
                || a.sim_secs != c.sim_secs,
                "different seeds should differ somewhere");
    }

    #[test]
    fn drain_and_respawn_cycle_under_forced_pressure() {
        use crate::server::memmon::MemoryMonitor;

        let mut fleet = default_sim_fleet(2, 3, RouterPolicy::RoundRobin);
        fleet.cfg.oom_threshold = 2;
        fleet.cfg.respawn_secs = 4.0;
        // replica 0 permanently underwater → every routed request OOMs
        let params = fleet.replicas[0].engine.bytes_used();
        let cap = (params as f64 * 1.1) as usize;
        fleet.replicas[0].engine.monitor =
            MemoryMonitor::walls(cap, &[(0.0, 1e12, cap)]);
        let reqs: Vec<Request> = (0..24)
            .map(|i| Request { id: i, arrival: i as f64 * 0.25,
                               prompt_len: 12, gen_len: 4 })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        assert!(report.respawns >= 1,
                "pressured replica never respawned: {report:?}");
        // the healthy replica kept serving throughout
        assert!(report.replicas[1].serve.completed > 0);
    }

    #[test]
    fn scenario_traces_are_deterministic_and_distinct() {
        let builders: [fn(u64, f64) -> Vec<Request>; 3] =
            [ramp_up_trace, drain_down_trace, burst_storm_trace];
        for build in builders {
            let a = build(5, 80.0);
            let b = build(5, 80.0);
            assert!(!a.is_empty());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert!((x.arrival - y.arrival).abs() < 1e-12);
                assert_eq!(x.prompt_len, y.prompt_len);
                assert_eq!(x.gen_len, y.gen_len);
            }
            // ids unique and arrivals ordered within [0, secs)
            let mut prev = 0.0;
            for (i, r) in a.iter().enumerate() {
                assert_eq!(r.id, i as u64);
                assert!(r.arrival >= prev - 1e-12);
                prev = r.arrival;
                assert!(r.arrival < 80.0 + 1e-9);
            }
            let c = build(6, 80.0);
            assert_ne!(a.len(), 0);
            let same = a.len() == c.len()
                && a.iter().zip(&c).all(|(x, y)| {
                    (x.arrival - y.arrival).abs() < 1e-12
                });
            assert!(!same, "different seeds produced the same trace");
        }
        // the ramp's back half is denser than its front half
        let ramp = ramp_up_trace(5, 80.0);
        let front =
            ramp.iter().filter(|r| r.arrival < 40.0).count();
        let back = ramp.len() - front;
        assert!(back > 2 * front,
                "ramp-up not ramping: {front} then {back}");
    }

    #[test]
    fn spawned_replicas_join_routing_and_reports() {
        // Force a spawn mechanically: autoscaler with a hair-trigger
        // queue watermark and a fleet whose two replicas are buried by
        // an arrival wave on slow devices.
        let spec = ReplicaSpec {
            flops_per_sec: 2.0e7,
            app_rate: 0.0,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            autoscale: Some(AutoscaleConfig {
                min_replicas: 2,
                max_replicas: 4,
                high_queue_per_replica: 2.0,
                hold_secs: 1.0,
                cooldown_secs: 5.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        };
        let mut fleet =
            uniform_sim_fleet(2, 11, RouterPolicy::LeastOutstanding,
                              cfg, spec);
        let reqs: Vec<Request> = (0..40)
            .map(|i| Request { id: i, arrival: 0.1 * i as f64,
                               prompt_len: 16, gen_len: 24 })
            .collect();
        let report = fleet.run_trace(reqs).unwrap();
        assert!(report.spawns >= 1, "overload never spawned: {report:?}");
        assert!(report.replicas.len() > 2);
        assert_eq!(report.routing.len(), report.replicas.len());
        // a spawned replica actually served traffic
        let extra_completed: usize = report.replicas[2..]
            .iter()
            .map(|r| r.serve.completed)
            .sum();
        assert!(extra_completed > 0,
                "spawned replicas never served: {report:?}");
        assert_eq!(report.completed, 40);
    }

    #[test]
    fn retire_parks_the_least_loaded_replica() {
        let spec = ReplicaSpec {
            app_rate: 0.0,
            ..ReplicaSpec::heterogeneous(0)
        };
        let cfg = FleetConfig {
            autoscale: Some(AutoscaleConfig {
                min_replicas: 1,
                max_replicas: 4,
                hold_secs: 1.0,
                cooldown_secs: 3.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        };
        let mut fleet = uniform_sim_fleet(3, 7, RouterPolicy::RoundRobin,
                                          cfg, spec);
        // a tiny trace, then a long idle tail: the scaler must shed the
        // excess capacity down to min_replicas and no further
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request { id: i, arrival: 0.2 * i as f64,
                               prompt_len: 12, gen_len: 4 })
            .collect();
        fleet.run_trace(reqs).unwrap();
        // idle tail: drive the clock so the scaler can act
        for k in 1..=120 {
            fleet.step_all(fleet.clock + 0.5 * k as f64).unwrap();
        }
        let retired = fleet
            .replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Retired)
            .count();
        let serving = fleet
            .replicas
            .iter()
            .filter(|r| r.accepting())
            .count();
        assert!(retired >= 1, "idle fleet never retired");
        assert!(serving >= 1, "retired below min_replicas");
        assert_eq!(fleet.retires as usize, retired);
    }
}

//! Fleet autoscaler: spawn/retire replicas from fleet-level load
//! signals, with hysteresis and a cooldown so noisy signals cannot make
//! the fleet thrash.
//!
//! Signals (trailing `signal_window_secs`, evaluated every
//! `eval_every_secs` of sim time):
//!
//!   * queue pressure — outstanding requests per serving replica;
//!   * tenant queue pressure — the *single worst tenant's* outstanding
//!     requests per serving replica (`high_tenant_queue_per_replica`,
//!     off by default). The fleet average can look calm while one
//!     tenant's backlog burns its SLOs; this signal lets the scaler see
//!     that skew;
//!   * p99 TTFT       — tail time-to-first-token of recently finished
//!                      requests (queueing and memory stalls surface
//!                      here first);
//!   * OOM rate       — interference-driven memory casualties across
//!                      the fleet. Under mask-elastic accounting (the
//!                      default — see `server::outlook`) engines charge
//!                      only *true* OOMs here: a spike a replica's RAP
//!                      controller absorbs by mask-shrinking lands in
//!                      `absorbed_spikes` instead, so the fleet no
//!                      longer spawns capacity for pressure the masks
//!                      already soaked up;
//!   * absorbed-spike rate (early warning, `scale_on_absorption`, off
//!     by default) — sustained mask absorption means the controllers
//!     are soaking up pressure at a quality cost, and the next spike
//!     may land below `min_viable`: scale *after* sustained absorption,
//!     *before* true OOMs.
//!
//! Policy: scale UP when any signal has stayed above its high watermark
//! for `hold_secs`; scale DOWN when every signal has stayed below its
//! low watermark for `hold_secs`. After any applied action the scaler is
//! quiet for `cooldown_secs`.
//!
//! Anti-oscillation is layered three ways: the asymmetric watermarks
//! (high ≫ low) form the hysteresis band, the hold requirement filters
//! one-tick spikes, and the cooldown bounds the action rate — over any
//! run, spawns + retires ≤ sim_secs / cooldown_secs + 1, which is the
//! bound the elastic-fleet scenario tests assert.

#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Never retire below this many serving replicas.
    pub min_replicas: usize,
    /// Never spawn above this many serving replicas.
    pub max_replicas: usize,
    /// Scale up above this many outstanding requests per serving
    /// replica (keep above the engine's max decode batch of 8 — a full
    /// batch in flight with an empty queue is healthy, not pressure)…
    pub high_queue_per_replica: f64,
    /// …and down below this many (the hysteresis band between the two
    /// watermarks is what prevents flapping).
    pub low_queue_per_replica: f64,
    /// Scale up when any single tenant's outstanding requests per
    /// serving replica exceed this (`INFINITY` — the default — disables
    /// the signal; single-tenant runs then behave exactly as before).
    pub high_tenant_queue_per_replica: f64,
    /// Scale up when the windowed p99 TTFT exceeds this (sim seconds).
    pub high_p99_ttft_secs: f64,
    /// Scale up when the fleet saw at least this many OOM events in the
    /// signal window.
    pub high_oom_events: usize,
    /// Early warning (PR-4 follow-up): treat sustained mask absorption
    /// as scale-up pressure — the fleet adds capacity *before* spikes
    /// start landing below the min-viable floor. Off by default: the
    /// absorbable-spike scenario's "zero spawns" contract holds unless
    /// a deployment opts in.
    pub scale_on_absorption: bool,
    /// With `scale_on_absorption`: absorbed spikes in the signal window
    /// that count as high pressure.
    pub high_absorbed_spikes: usize,
    /// How long a signal must persist before acting.
    pub hold_secs: f64,
    /// Quiet period after any applied spawn/retire.
    pub cooldown_secs: f64,
    /// Signal evaluation cadence.
    pub eval_every_secs: f64,
    /// Signal look-back window. Keep ≤ the fleet's `oom_window_secs`:
    /// the replicas' OOM pressure marks are trimmed to that horizon.
    pub signal_window_secs: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 8,
            high_queue_per_replica: 9.0,
            low_queue_per_replica: 1.0,
            high_tenant_queue_per_replica: f64::INFINITY,
            high_p99_ttft_secs: 8.0,
            high_oom_events: 6,
            scale_on_absorption: false,
            high_absorbed_spikes: 4,
            hold_secs: 4.0,
            cooldown_secs: 20.0,
            eval_every_secs: 1.0,
            signal_window_secs: 15.0,
        }
    }
}

/// One evaluation's worth of fleet-level signals.
#[derive(Clone, Copy, Debug)]
pub struct FleetSignals {
    /// Replicas currently accepting routed work.
    pub serving: usize,
    /// Queued + in-flight requests across live replicas. Quota-held
    /// tenant-fair backlog is deliberately NOT counted: the quota is a
    /// fleet-wide byte cap, so spawning replicas cannot admit that
    /// overflow — it is not capacity-addressable demand.
    pub outstanding: usize,
    /// The single worst tenant's outstanding requests (same
    /// replica-side accounting). Equals `outstanding` on single-tenant
    /// runs.
    pub max_tenant_outstanding: usize,
    /// p99 TTFT of requests finished inside the signal window (NaN when
    /// none finished — NaN compares false, so it never trips a
    /// watermark).
    pub p99_ttft: f64,
    /// True OOM events observed inside the signal window (mask-absorbed
    /// spikes are not OOMs and never reach this signal).
    pub recent_ooms: usize,
    /// Mask-absorbed spikes inside the signal window (the early-warning
    /// signal; only consulted when `scale_on_absorption` is set).
    pub recent_absorbed: usize,
    /// Abrupt capacity losses (replica crashes, spot reclaims) inside
    /// the signal window. Unlike queue/TTFT pressure this is a *known*
    /// deficit, not a noisy inference — the scaler replaces the lost
    /// replica without waiting out `hold_secs` (the cooldown still
    /// applies, so a cascading failure cannot spawn-storm).
    pub capacity_losses: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

#[derive(Clone, Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    /// Sim time the high (resp. low) condition became continuously true.
    high_since: Option<f64>,
    low_since: Option<f64>,
    last_eval_at: f64,
    last_action_at: f64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler {
            cfg,
            high_since: None,
            low_since: None,
            last_eval_at: f64::NEG_INFINITY,
            last_action_at: f64::NEG_INFINITY,
        }
    }

    /// Whether `t` is an evaluation tick — callers can skip computing
    /// signals entirely between ticks (`decide` would discard them).
    pub fn due(&self, t: f64) -> bool {
        t - self.last_eval_at >= self.cfg.eval_every_secs
    }

    /// Evaluate the signals at sim time `t`. Returns `Hold` between
    /// evaluation ticks and while cooling down; bounds (`min_replicas`,
    /// `max_replicas`) are enforced here against `signals.serving`. The
    /// caller applies the decision and reports success via
    /// [`Autoscaler::note_action`], which starts the cooldown.
    pub fn decide(&mut self, t: f64, s: &FleetSignals) -> ScaleDecision {
        if !self.due(t) {
            return ScaleDecision::Hold;
        }
        self.last_eval_at = t;
        let per = s.outstanding as f64 / s.serving.max(1) as f64;
        let tenant_per = s.max_tenant_outstanding as f64
            / s.serving.max(1) as f64;
        let absorbed_high = self.cfg.scale_on_absorption
            && s.recent_absorbed >= self.cfg.high_absorbed_spikes;
        let tenant_high =
            tenant_per > self.cfg.high_tenant_queue_per_replica;
        let high = per > self.cfg.high_queue_per_replica
            || tenant_high
            || s.p99_ttft > self.cfg.high_p99_ttft_secs
            || s.recent_ooms >= self.cfg.high_oom_events
            || absorbed_high;
        // every high signal also vetoes low — high and low being true
        // simultaneously would let the bounds turn sustained pressure
        // into spawn/retire flapping at max_replicas
        let low = per < self.cfg.low_queue_per_replica
            && !tenant_high
            && !(s.p99_ttft > self.cfg.high_p99_ttft_secs)
            && s.recent_ooms == 0
            && !absorbed_high
            && s.capacity_losses == 0;
        self.high_since = if high { self.high_since.or(Some(t)) }
                          else { None };
        self.low_since = if low { self.low_since.or(Some(t)) }
                         else { None };
        if t - self.last_action_at < self.cfg.cooldown_secs {
            return ScaleDecision::Hold;
        }
        // A crash or reclaim is a step change in capacity, not a signal
        // to be smoothed: replace immediately (bypassing the hold — the
        // hold exists to filter noise, and this is not noise), bounded
        // by max_replicas and the cooldown above.
        if s.capacity_losses > 0 && s.serving < self.cfg.max_replicas {
            return ScaleDecision::Up;
        }
        if high
            && s.serving < self.cfg.max_replicas
            && self.high_since
                .map_or(false, |s0| t - s0 >= self.cfg.hold_secs)
        {
            return ScaleDecision::Up;
        }
        if low
            && s.serving > self.cfg.min_replicas
            && self.low_since
                .map_or(false, |s0| t - s0 >= self.cfg.hold_secs)
        {
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }

    /// The fleet applied a scale action at `t`: start the cooldown and
    /// reset the hysteresis holds.
    pub fn note_action(&mut self, t: f64) {
        self.last_action_at = t;
        self.high_since = None;
        self.low_since = None;
    }

    /// Name the signal that motivated `decision` given `s` — the
    /// decision-audit label on autoscale telemetry events. Mirrors
    /// `decide`'s precedence: capacity loss first (it bypasses the
    /// hold), then the high watermarks in evaluation order.
    pub fn explain(&self, s: &FleetSignals, decision: ScaleDecision)
                   -> &'static str {
        match decision {
            ScaleDecision::Down => "idle",
            ScaleDecision::Hold => "hold",
            ScaleDecision::Up => {
                let per =
                    s.outstanding as f64 / s.serving.max(1) as f64;
                let tenant_per = s.max_tenant_outstanding as f64
                    / s.serving.max(1) as f64;
                if s.capacity_losses > 0 {
                    "capacity-loss"
                } else if per > self.cfg.high_queue_per_replica {
                    "queue-depth"
                } else if tenant_per
                    > self.cfg.high_tenant_queue_per_replica
                {
                    "tenant-queue"
                } else if s.p99_ttft > self.cfg.high_p99_ttft_secs {
                    "p99-ttft"
                } else if s.recent_ooms >= self.cfg.high_oom_events {
                    "oom-rate"
                } else if self.cfg.scale_on_absorption
                    && s.recent_absorbed
                        >= self.cfg.high_absorbed_spikes
                {
                    "absorbed-spikes"
                } else {
                    "pressure"
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            hold_secs: 3.0,
            cooldown_secs: 10.0,
            eval_every_secs: 1.0,
            ..AutoscaleConfig::default()
        }
    }

    fn signals(serving: usize, outstanding: usize) -> FleetSignals {
        FleetSignals { serving, outstanding,
                       max_tenant_outstanding: outstanding,
                       p99_ttft: f64::NAN, recent_ooms: 0,
                       recent_absorbed: 0, capacity_losses: 0 }
    }

    fn overloaded(serving: usize) -> FleetSignals {
        signals(serving, serving * 50)
    }

    fn idle_signals(serving: usize) -> FleetSignals {
        signals(serving, 0)
    }

    #[test]
    fn sustained_high_scales_up_after_hold_not_before() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(0.0, &overloaded(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(1.0, &overloaded(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(2.0, &overloaded(2)), ScaleDecision::Hold);
        // 3 s of sustained pressure → up
        assert_eq!(a.decide(3.0, &overloaded(2)), ScaleDecision::Up);
    }

    #[test]
    fn one_tick_spike_does_not_scale() {
        let mut a = Autoscaler::new(cfg());
        a.decide(0.0, &overloaded(2));
        a.decide(1.0, &overloaded(2));
        // the spike clears: hold resets
        assert_eq!(a.decide(2.0, &idle_signals(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(3.0, &overloaded(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(4.0, &overloaded(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(5.0, &overloaded(2)), ScaleDecision::Hold);
        assert_eq!(a.decide(6.0, &overloaded(2)), ScaleDecision::Up);
    }

    #[test]
    fn cooldown_bounds_the_action_rate() {
        let mut a = Autoscaler::new(cfg());
        let mut ups = 0;
        let mut t = 0.0;
        while t < 100.0 {
            if a.decide(t, &overloaded(2)) == ScaleDecision::Up {
                ups += 1;
                a.note_action(t);
            }
            t += 1.0;
        }
        // cooldown 10 s + 3 s hold re-arm → at most one action per 13 s
        assert!(ups >= 2, "never scaled under constant overload");
        assert!(ups <= 100 / 10 + 1, "cooldown failed to bound actions: \
                 {ups}");
    }

    #[test]
    fn respects_replica_bounds() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            let d = a.decide(t as f64, &overloaded(4)); // at max
            assert_eq!(d, ScaleDecision::Hold, "scaled past max");
        }
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            let d = a.decide(t as f64, &idle_signals(1)); // at min
            assert_eq!(d, ScaleDecision::Hold, "retired past min");
        }
    }

    #[test]
    fn sustained_low_scales_down() {
        let mut a = Autoscaler::new(cfg());
        a.decide(0.0, &idle_signals(3));
        a.decide(1.0, &idle_signals(3));
        a.decide(2.0, &idle_signals(3));
        assert_eq!(a.decide(3.0, &idle_signals(3)), ScaleDecision::Down);
        a.note_action(3.0);
        // cooling down: no flapping even though the signal is still low
        assert_eq!(a.decide(4.0, &idle_signals(2)), ScaleDecision::Hold);
    }

    #[test]
    fn oom_pressure_alone_triggers_up() {
        let mut a = Autoscaler::new(cfg());
        let s = FleetSignals { recent_ooms: 50, ..idle_signals(2) };
        a.decide(0.0, &s);
        a.decide(1.0, &s);
        a.decide(2.0, &s);
        assert_eq!(a.decide(3.0, &s), ScaleDecision::Up);
    }

    /// One tenant's backlog trips the per-tenant watermark even though
    /// the fleet-average queue looks calm; with the watermark at its
    /// infinite default, the identical signals hold.
    #[test]
    fn skewed_tenant_queue_triggers_up_when_armed() {
        let mut armed = Autoscaler::new(AutoscaleConfig {
            high_tenant_queue_per_replica: 10.0,
            ..cfg()
        });
        // fleet-average 6/replica (below 9), worst tenant 24/replica
        let s = FleetSignals { serving: 2, outstanding: 12,
                               max_tenant_outstanding: 48,
                               p99_ttft: f64::NAN, recent_ooms: 0,
                               recent_absorbed: 0, capacity_losses: 0 };
        armed.decide(0.0, &s);
        armed.decide(1.0, &s);
        armed.decide(2.0, &s);
        assert_eq!(armed.decide(3.0, &s), ScaleDecision::Up);
        // default (INFINITY): the same skew never trips
        let mut unarmed = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(unarmed.decide(t as f64, &s), ScaleDecision::Hold);
        }
    }

    /// A capacity loss (crash / spot reclaim) replaces the lost replica
    /// on the very first evaluation — no hold — but the cooldown still
    /// bounds the spawn rate, and an idle window with a loss never
    /// scales down.
    #[test]
    fn capacity_loss_bypasses_hold_but_not_cooldown() {
        let mut a = Autoscaler::new(cfg());
        let lost = FleetSignals { capacity_losses: 1,
                                  ..idle_signals(2) };
        // immediate — queue/TTFT pressure would need 3 s of hold
        assert_eq!(a.decide(0.0, &lost), ScaleDecision::Up);
        a.note_action(0.0);
        // cooling down: a second loss in the window must wait
        assert_eq!(a.decide(1.0, &lost), ScaleDecision::Hold);
        // at max_replicas the loss cannot spawn
        let mut b = Autoscaler::new(cfg());
        let at_max = FleetSignals { capacity_losses: 1,
                                    ..idle_signals(4) };
        assert_eq!(b.decide(0.0, &at_max), ScaleDecision::Hold);
        // and a loss in the window vetoes scale-down even when idle
        let mut c = Autoscaler::new(cfg());
        let calm_loss = FleetSignals { capacity_losses: 1,
                                       ..idle_signals(4) };
        for t in 0..8 {
            assert_eq!(c.decide(t as f64, &calm_loss),
                       ScaleDecision::Hold, "scaled down past a loss");
        }
    }

    /// `explain` attributes an applied decision to the signal that
    /// fired it, in `decide`'s own precedence order.
    #[test]
    fn explain_names_the_firing_signal() {
        let a = Autoscaler::new(cfg());
        let lost = FleetSignals { capacity_losses: 1,
                                  ..idle_signals(2) };
        assert_eq!(a.explain(&lost, ScaleDecision::Up),
                   "capacity-loss");
        assert_eq!(a.explain(&overloaded(2), ScaleDecision::Up),
                   "queue-depth");
        let ooming = FleetSignals { recent_ooms: 50,
                                    ..idle_signals(2) };
        assert_eq!(a.explain(&ooming, ScaleDecision::Up), "oom-rate");
        let slow = FleetSignals { p99_ttft: 30.0, ..idle_signals(2) };
        assert_eq!(a.explain(&slow, ScaleDecision::Up), "p99-ttft");
        // capacity loss outranks a simultaneous queue signal, exactly
        // as it does in `decide`
        let both = FleetSignals { capacity_losses: 1,
                                  ..overloaded(2) };
        assert_eq!(a.explain(&both, ScaleDecision::Up),
                   "capacity-loss");
        assert_eq!(a.explain(&idle_signals(3), ScaleDecision::Down),
                   "idle");
        assert_eq!(a.explain(&idle_signals(3), ScaleDecision::Hold),
                   "hold");
    }

    /// The PR-4 follow-up: sustained mask absorption scales up — but
    /// only when a deployment opts in, and it also vetoes scale-down
    /// while absorbing.
    #[test]
    fn absorption_early_warning_is_gated_by_the_flag() {
        let absorbing = FleetSignals { recent_absorbed: 5,
                                       ..idle_signals(2) };
        // flag off (default): absorption is invisible — and since the
        // queue is idle, the scaler would rather scale DOWN
        let mut off = Autoscaler::new(cfg());
        off.decide(0.0, &absorbing);
        off.decide(1.0, &absorbing);
        off.decide(2.0, &absorbing);
        assert_eq!(off.decide(3.0, &absorbing), ScaleDecision::Down);
        // flag on: the same window is high pressure
        let mut on = Autoscaler::new(AutoscaleConfig {
            scale_on_absorption: true,
            high_absorbed_spikes: 4,
            ..cfg()
        });
        on.decide(0.0, &absorbing);
        on.decide(1.0, &absorbing);
        on.decide(2.0, &absorbing);
        assert_eq!(on.decide(3.0, &absorbing), ScaleDecision::Up);
        // below the absorbed watermark the flag changes nothing
        let calm = FleetSignals { recent_absorbed: 3,
                                  ..idle_signals(2) };
        let mut on2 = Autoscaler::new(AutoscaleConfig {
            scale_on_absorption: true,
            high_absorbed_spikes: 4,
            ..cfg()
        });
        on2.decide(0.0, &calm);
        on2.decide(1.0, &calm);
        on2.decide(2.0, &calm);
        assert_eq!(on2.decide(3.0, &calm), ScaleDecision::Down);
    }
}

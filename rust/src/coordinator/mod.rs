//! Fleet coordinator — the paper's L3 coordination contribution scaled
//! out: many serving replicas, one shared simulated clock, and a
//! memory-aware request router between them.
//!
//! The single-engine story (paper Fig 5) is that pruning must react to
//! *runtime* memory variation on one device. At fleet scale the same
//! signal becomes a *placement* problem: replicas differ in capacity,
//! co-tenant interference, device speed, and — because each runs its own
//! RAP controller — in the quality of the mask currently deployed. A
//! request is cheap on a replica with KV headroom and an unpruned mask,
//! and expensive (or fatal) on one that interference has pushed under
//! water.
//!
//! ## Mask-elastic memory accounting (`server::outlook::MemoryOutlook`)
//!
//! Because each replica's footprint is *elastic*, a single
//! `bytes_used()` number misrepresents it. Every pressure decision in
//! this module therefore reads the replica's memory outlook — the
//! footprint at three points of the reachable mask lattice:
//!
//!   * `min_viable` — bytes under the cheapest mask the controller may
//!     deploy for the observed workload (the GSI-greedy prefix down to
//!     the controller's retained-parameter floor; for a static
//!     deployment this equals `current`);
//!   * `current`    — bytes under the mask deployed right now;
//!   * `dense`      — bytes under the full mask (the re-growth ceiling).
//!
//! `Sys_avail(t)` between `min_viable` and `current` is the *absorbable
//! band*: the controller shrinks, nothing is shed, no OOM is charged
//! (engines count `absorbed_spikes` instead). Only `Sys_avail(t) <
//! min_viable` is a true OOM. Consequently: `Fleet::rebalance_queued`
//! reroutes a queue only off truly collapsed replicas, migration
//! targets and the memory-aware routers score peers by *elastic*
//! headroom (`Sys_avail − min_viable`), and the autoscaler's OOM-rate
//! signal — fed from engine `oom_events` — no longer spawns replicas
//! for pressure the masks absorb. `FleetConfig::elastic_accounting`
//! (default on) gates all of it; off reproduces the current-mask
//! accounting for comparison, and `fleet::absorbable_spike_fleet` is
//! the seeded scenario holding the distinction to zero phantom
//! migrations and spawns.
//!
//! Module map:
//!   * [`replica`] — one serving [`crate::server::engine::Engine`] plus
//!     its lifecycle (`Serving` → `Draining` → `Respawning`/`Retired`)
//!     and OOM-pressure bookkeeping. Engines are *externally stepped*
//!     via `Engine::step_to`, which is what lets N of them share a
//!     clock.
//!   * [`router`] — pluggable dispatch policies: round-robin,
//!     least-outstanding, KV-headroom-aware, and RAP-aware (scores each
//!     replica by `Sys_avail(t)` headroom against the request's
//!     estimated KV cost under that replica's *current mask*, weighted
//!     by mask utility and queue depth).
//!   * [`fleet`] — the event loop: admit trace arrivals, route, step all
//!     replicas to the shared clock, drain replicas under sustained OOM
//!     pressure and respawn them after a cool-down. With
//!     `FleetConfig::migrate`, in-flight sequences move off pressured
//!     replicas (KV intact, transfer cost charged) instead of being
//!     evicted; with `FleetConfig::autoscale`, the fleet spawns and
//!     retires replicas from aggregate load signals.
//!   * [`autoscaler`] — the spawn/retire policy: queue depth, windowed
//!     p99 TTFT, and OOM rate, behind hysteresis watermarks, a
//!     persistence hold, and a cooldown.
//!   * [`metrics`] — `FleetReport`: per-replica and aggregate p50/p99
//!     TTFT + latency, OOM/eviction/respawn counts, migration and
//!     spawn/retire totals, and the routing histogram, printable and
//!     serializable to JSON.
//!
//! Everything is seeded and deterministic: replicas run the sim runtime
//! backend (`rap::runtime::sim`) by default, so fleet experiments replay
//! bit-identically — `rap serve-fleet --replicas 4 --router rap` is the
//! CLI entry point, `experiments::fleet` the policy comparison.

pub mod autoscaler;
pub mod fleet;
pub mod metrics;
pub mod replica;
pub mod router;

pub use autoscaler::{AutoscaleConfig, Autoscaler, FleetSignals,
                     ScaleDecision};
pub use fleet::{Fleet, FleetConfig};
pub use metrics::{FleetReport, ReplicaReport};
pub use replica::{Replica, ReplicaSpec, ReplicaState};
pub use router::{Router, RouterPolicy};

//! Fleet coordinator — the paper's L3 coordination contribution scaled
//! out: many serving replicas, one shared simulated clock, and a
//! memory-aware request router between them.
//!
//! The single-engine story (paper Fig 5) is that pruning must react to
//! *runtime* memory variation on one device. At fleet scale the same
//! signal becomes a *placement* problem: replicas differ in capacity,
//! co-tenant interference, device speed, and — because each runs its own
//! RAP controller — in the quality of the mask currently deployed. A
//! request is cheap on a replica with KV headroom and an unpruned mask,
//! and expensive (or fatal) on one that interference has pushed under
//! water.
//!
//! ## Mask-elastic memory accounting (`server::outlook::MemoryOutlook`)
//!
//! Because each replica's footprint is *elastic*, a single
//! `bytes_used()` number misrepresents it. Every pressure decision in
//! this module therefore reads the replica's memory outlook — the
//! footprint at three points of the reachable mask lattice:
//!
//!   * `min_viable` — bytes under the cheapest mask the controller may
//!     deploy for the observed workload (the GSI-greedy prefix down to
//!     the controller's retained-parameter floor; for a static
//!     deployment this equals `current`);
//!   * `current`    — bytes under the mask deployed right now;
//!   * `dense`      — bytes under the full mask (the re-growth ceiling).
//!
//! `Sys_avail(t)` between `min_viable` and `current` is the *absorbable
//! band*: the controller shrinks, nothing is shed, no OOM is charged
//! (engines count `absorbed_spikes` instead). Only `Sys_avail(t) <
//! min_viable` is a true OOM. Consequently: `Fleet::rebalance_queued`
//! reroutes a queue only off truly collapsed replicas, migration
//! targets and the memory-aware routers score peers by *elastic*
//! headroom (`Sys_avail − min_viable`), and the autoscaler's OOM-rate
//! signal — fed from engine `oom_events` — no longer spawns replicas
//! for pressure the masks absorb. `FleetConfig::elastic_accounting`
//! (default on) gates all of it; off reproduces the current-mask
//! accounting for comparison, and `fleet::absorbable_spike_fleet` is
//! the seeded scenario holding the distinction to zero phantom
//! migrations and spawns.
//!
//! ## Typed ingress (`crate::api`)
//!
//! Work enters the fleet exclusively as `api::SubmitRequest`s through
//! `Fleet::submit` (trace replay is a thin adapter — `Fleet::run_trace`
//! maps the trace through `api::from_trace`). The request's tenant,
//! priority class, and SLO deadline thread through every decision
//! layer: the `tenant-fair` router caps each tenant's committed KV
//! bytes at its quota (overflow waits in a per-tenant ingress backlog),
//! engines queue by priority and pick pressure victims
//! expired-deadline-first / lowest-class-first, and the autoscaler
//! reads a per-tenant outstanding signal. `Fleet::poll` / `cancel`
//! complete the lifecycle; `FleetReport::tenants` carries per-tenant
//! TTFT tails, deadline hit-rates, and quota utilization.
//!
//! Module map:
//!   * [`replica`] — one serving [`crate::server::engine::Engine`] plus
//!     its lifecycle (`Serving` → `Draining` → `Respawning`/`Retired`,
//!     with autoscaler spawns optionally entering through `Warming`
//!     while the warm-up cost elapses) and OOM/absorbed-spike pressure
//!     bookkeeping. Engines are *externally stepped* via
//!     `Engine::step_to`, which is what lets N of them share a clock.
//!   * [`router`] — pluggable dispatch policies: round-robin,
//!     least-outstanding, KV-headroom-aware, RAP-aware (scores each
//!     replica by `Sys_avail(t)` headroom against the request's
//!     estimated KV cost under that replica's *current mask*, weighted
//!     by mask utility and queue depth), and tenant-fair
//!     (quota-gated dispatch, RAP-aware placement within a tenant).
//!   * [`fleet`] — the event loop: admit typed arrivals, route, step
//!     all replicas to the shared clock, drain replicas under sustained
//!     OOM pressure and respawn them after a cool-down. With
//!     `FleetConfig::migrate`, in-flight sequences move off pressured
//!     replicas (live-slice KV intact, transfer cost charged) instead
//!     of being evicted; with `FleetConfig::autoscale`, the fleet
//!     spawns and retires replicas from aggregate load signals,
//!     charging `FleetConfig::warmup_secs` before a spawn serves.
//!   * [`autoscaler`] — the spawn/retire policy: queue depth (fleet-
//!     and worst-tenant), windowed p99 TTFT, OOM rate, and (opt-in) the
//!     absorbed-spike early warning, behind hysteresis watermarks, a
//!     persistence hold, and a cooldown.
//!   * [`metrics`] — `FleetReport`: per-replica, per-tenant, and
//!     aggregate p50/p99 TTFT + latency, OOM/eviction/respawn counts,
//!     migration and spawn/retire totals, the chaos/recovery ledger
//!     (`ChaosReport`), and the routing histogram, printable and
//!     serializable to JSON.
//!
//! ## Failure injection & recovery (`Fleet::with_fault_plan`)
//!
//! A seeded [`crate::runtime::FaultPlan`] can crash replicas, degrade
//! or partition the interconnect, and reclaim spot capacity with a
//! grace window. Engines checkpoint live-KV deltas periodically
//! (`FleetConfig::checkpoint_period_secs`); a crash restores
//! checkpointed sequences onto peers, re-enters uncheckpointed work at
//! the head of its priority class, and feeds the autoscaler a
//! capacity-loss signal that bypasses its hold. `fleet::
//! chaos_storm_fleet` is the seeded acceptance scenario.
//!
//! Everything is seeded and deterministic: replicas run the sim runtime
//! backend (`rap::runtime::sim`) by default, so fleet experiments replay
//! bit-identically — `rap serve-fleet --replicas 4 --router rap` is the
//! CLI entry point, `experiments::fleet` the policy comparison, and
//! `rap experiment fleet --tenants` the multi-tenant acceptance
//! scenario.

pub mod autoscaler;
pub mod fleet;
pub mod metrics;
pub mod replica;
pub mod router;

pub use autoscaler::{AutoscaleConfig, Autoscaler, FleetSignals,
                     ScaleDecision};
pub use fleet::{Fleet, FleetConfig};
pub use metrics::{ChaosReport, FleetReport, FleetTenantReport,
                  ReplicaReport};
pub use replica::{Replica, ReplicaSpec, ReplicaState};
pub use router::{Router, RouterPolicy};

//! Fleet coordinator — the paper's L3 coordination contribution scaled
//! out: many serving replicas, one shared simulated clock, and a
//! memory-aware request router between them.
//!
//! The single-engine story (paper Fig 5) is that pruning must react to
//! *runtime* memory variation on one device. At fleet scale the same
//! signal becomes a *placement* problem: replicas differ in capacity,
//! co-tenant interference, device speed, and — because each runs its own
//! RAP controller — in the quality of the mask currently deployed. A
//! request is cheap on a replica with KV headroom and an unpruned mask,
//! and expensive (or fatal) on one that interference has pushed under
//! water.
//!
//! Module map:
//!   * [`replica`] — one serving [`crate::server::engine::Engine`] plus
//!     its lifecycle (`Serving` → `Draining` → `Respawning`/`Retired`)
//!     and OOM-pressure bookkeeping. Engines are *externally stepped*
//!     via `Engine::step_to`, which is what lets N of them share a
//!     clock.
//!   * [`router`] — pluggable dispatch policies: round-robin,
//!     least-outstanding, KV-headroom-aware, and RAP-aware (scores each
//!     replica by `Sys_avail(t)` headroom against the request's
//!     estimated KV cost under that replica's *current mask*, weighted
//!     by mask utility and queue depth).
//!   * [`fleet`] — the event loop: admit trace arrivals, route, step all
//!     replicas to the shared clock, drain replicas under sustained OOM
//!     pressure and respawn them after a cool-down. With
//!     `FleetConfig::migrate`, in-flight sequences move off pressured
//!     replicas (KV intact, transfer cost charged) instead of being
//!     evicted; with `FleetConfig::autoscale`, the fleet spawns and
//!     retires replicas from aggregate load signals.
//!   * [`autoscaler`] — the spawn/retire policy: queue depth, windowed
//!     p99 TTFT, and OOM rate, behind hysteresis watermarks, a
//!     persistence hold, and a cooldown.
//!   * [`metrics`] — `FleetReport`: per-replica and aggregate p50/p99
//!     TTFT + latency, OOM/eviction/respawn counts, migration and
//!     spawn/retire totals, and the routing histogram, printable and
//!     serializable to JSON.
//!
//! Everything is seeded and deterministic: replicas run the sim runtime
//! backend (`rap::runtime::sim`) by default, so fleet experiments replay
//! bit-identically — `rap serve-fleet --replicas 4 --router rap` is the
//! CLI entry point, `experiments::fleet` the policy comparison.

pub mod autoscaler;
pub mod fleet;
pub mod metrics;
pub mod replica;
pub mod router;

pub use autoscaler::{AutoscaleConfig, Autoscaler, FleetSignals,
                     ScaleDecision};
pub use fleet::{Fleet, FleetConfig};
pub use metrics::{FleetReport, ReplicaReport};
pub use replica::{Replica, ReplicaSpec, ReplicaState};
pub use router::{Router, RouterPolicy};

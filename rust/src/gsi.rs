//! Greedy Sequential Importance (paper §4.1, Algorithm 1).
//!
//! Iteratively removes the block whose exclusion degrades perplexity the
//! least, *re-scoring every remaining block after each removal* — the
//! recalibration that one-shot methods skip and that Figure 6 shows they
//! pay for. Importance(b | mask) = NLL(mask \ b) − NLL(mask).
//!
//! Cost control: scores are memoized on the pruned-set key, which matters
//! enormously for DQN training (Alg 2 recomputes the importance vector
//! after every action, and exploration revisits prefixes constantly — the
//! memo turns O(episodes · steps · 2N) model evaluations into roughly the
//! number of *distinct* masks visited).

use std::collections::HashMap;

use anyhow::Result;

use crate::mask::PruneMask;
use crate::model_meta::BlockId;
use crate::runtime::{NllEvaluator, Runtime};

/// Outcome of a full greedy pass (Algorithm 1).
#[derive(Clone, Debug)]
pub struct GsiResult {
    pub base_nll: f64,
    /// Removal order, least-damaging first.
    pub order: Vec<BlockId>,
    /// NLL after each removal (same indexing as `order`).
    pub nll_after: Vec<f64>,
}

impl GsiResult {
    pub fn ppl_after(&self) -> Vec<f64> {
        self.nll_after.iter().map(|x| x.exp()).collect()
    }
}

pub struct GsiEngine<'a, E: NllEvaluator> {
    eval: &'a mut E,
    memo: HashMap<u64, f64>,
}

impl<'a, E: NllEvaluator> GsiEngine<'a, E> {
    pub fn new(eval: &'a mut E) -> Self {
        GsiEngine { eval, memo: HashMap::new() }
    }

    /// Resume with a previously extracted memo (lets a serving controller
    /// keep GSI scores warm across transient engine instances).
    pub fn with_memo(eval: &'a mut E, memo: HashMap<u64, f64>) -> Self {
        GsiEngine { eval, memo }
    }

    /// Hand the memo back to the caller for reuse.
    pub fn take_memo(self) -> HashMap<u64, f64> {
        self.memo
    }

    /// Memoized NLL under a mask.
    pub fn nll(&mut self, mask: &PruneMask) -> Result<f64> {
        let key = mask.key();
        if let Some(&v) = self.memo.get(&key) {
            return Ok(v);
        }
        let v = self.eval.eval_nll(mask)?;
        self.memo.insert(key, v);
        Ok(v)
    }

    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// Importance of every *remaining* block given the current mask:
    /// ΔNLL when that block is additionally removed. Removed blocks get
    /// importance 0. This is the recomputed score vector the RL state
    /// carries (s_t^Model).
    pub fn importance(&mut self, mask: &PruneMask) -> Result<Vec<f64>> {
        let n_layers = self.eval.meta().n_layers;
        let base = self.nll(mask)?;
        let mut out = vec![0.0; 2 * n_layers];
        for i in 0..2 * n_layers {
            let b = BlockId::from_index(i, n_layers);
            if mask.block_dropped(b) {
                continue;
            }
            let cand = mask.with_block_dropped(b);
            out[i] = self.nll(&cand)? - base;
        }
        Ok(out)
    }

    /// One greedy step: the remaining block with minimal damage.
    pub fn least_important(&mut self, mask: &PruneMask)
                           -> Result<Option<(BlockId, f64)>> {
        let n_layers = self.eval.meta().n_layers;
        let base = self.nll(mask)?;
        let mut best: Option<(BlockId, f64)> = None;
        for i in 0..2 * n_layers {
            let b = BlockId::from_index(i, n_layers);
            if mask.block_dropped(b) {
                continue;
            }
            let nll = self.nll(&mask.with_block_dropped(b))?;
            let damage = nll - base;
            if best.map_or(true, |(_, d)| damage < d) {
                best = Some((b, damage));
            }
        }
        Ok(best)
    }

    /// Algorithm 1: prune greedily until `stop(mask)` returns true (e.g.
    /// a parameter-ratio or memory-budget predicate), re-scoring after
    /// every removal.
    pub fn greedy<F: FnMut(&PruneMask) -> bool>(
        &mut self, start: &PruneMask, mut stop: F) -> Result<GsiResult> {
        let base_nll = self.nll(start)?;
        let mut mask = start.clone();
        let mut order = Vec::new();
        let mut nll_after = Vec::new();
        while !stop(&mask) {
            let Some((b, _)) = self.least_important(&mask)? else {
                break; // nothing left to prune
            };
            mask.drop_block(b);
            order.push(b);
            nll_after.push(self.nll(&mask)?);
        }
        Ok(GsiResult { base_nll, order, nll_after })
    }

    /// One-shot variant (the RAP⁻GSI ablation): score all blocks once on
    /// the *dense* model and return them sorted ascending by damage —
    /// no recalibration between removals.
    pub fn one_shot_order(&mut self, start: &PruneMask)
                          -> Result<Vec<(BlockId, f64)>> {
        let n_layers = self.eval.meta().n_layers;
        let imp = self.importance(start)?;
        let mut pairs: Vec<(BlockId, f64)> = (0..2 * n_layers)
            .filter(|&i| {
                !start.block_dropped(BlockId::from_index(i, n_layers))
            })
            .map(|i| (BlockId::from_index(i, n_layers), imp[i]))
            .collect();
        pairs.sort_by(|a, b| a.1.total_cmp(&b.1));
        Ok(pairs)
    }
}

/// Binds a `Runtime` + calibration batch (alpaca-sim) into an
/// `NllEvaluator` so GSI / the RL env can score masks on the real model.
pub struct CalibratedEvaluator {
    pub rt: Runtime,
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seqlen: usize,
}

impl CalibratedEvaluator {
    /// Use the first `batch`×`seqlen` window of the GSI calibration split.
    pub fn new(rt: Runtime, corpus: &crate::corpus::Corpus, batch: usize,
               seqlen: usize) -> Result<Self> {
        let tokens = corpus
            .batches(crate::corpus::Split::Alpaca, batch, seqlen, 1, 0)?
            .remove(0);
        Ok(CalibratedEvaluator { rt, tokens, batch, seqlen })
    }
}

impl NllEvaluator for CalibratedEvaluator {
    fn meta(&self) -> &crate::model_meta::ModelMeta {
        self.rt.meta()
    }

    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64> {
        self.rt.mean_nll(self.batch, self.seqlen, &self.tokens, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;
    use crate::runtime::SyntheticEvaluator;

    fn synth(damage: Vec<f64>, synergy: f64) -> SyntheticEvaluator {
        let n_layers = damage.len() / 2;
        let meta = ModelMeta::synthetic("t", n_layers, 64, 4, 2, 96, 128,
                                        64);
        SyntheticEvaluator::new(meta, 2.0, damage, synergy)
    }

    #[test]
    fn importance_matches_damage_when_additive() {
        let mut ev = synth(vec![0.5, 0.1, 0.9, 0.2, 0.8, 0.3], 0.0);
        let meta = ev.meta.clone();
        let mut gsi = GsiEngine::new(&mut ev);
        let full = PruneMask::full(&meta);
        let imp = gsi.importance(&full).unwrap();
        for (i, d) in [0.5, 0.1, 0.9, 0.2, 0.8, 0.3].iter().enumerate() {
            assert!((imp[i] - d).abs() < 1e-12);
        }
    }

    #[test]
    fn greedy_removes_in_ascending_damage_order_when_additive() {
        let mut ev = synth(vec![0.5, 0.1, 0.9, 0.2, 0.8, 0.3], 0.0);
        let meta = ev.meta.clone();
        let mut gsi = GsiEngine::new(&mut ev);
        let full = PruneMask::full(&meta);
        let mut count = 0;
        let res = gsi
            .greedy(&full, |_| {
                count += 1;
                count > 4 // remove 4 blocks
            })
            .unwrap();
        let idx: Vec<usize> =
            res.order.iter().map(|b| b.index(3)).collect();
        assert_eq!(idx, vec![1, 3, 5, 0]); // damages .1 .2 .3 .5
        // nll_after is cumulative
        assert!((res.nll_after[3] - (2.0 + 0.1 + 0.2 + 0.3 + 0.5)).abs()
                < 1e-9);
    }

    #[test]
    fn greedy_diverges_from_one_shot_under_interactions() {
        // Strong synergy: killing both blocks of a layer is catastrophic.
        // One-shot ignores this; greedy (recalibrated) avoids it.
        let mut ev = synth(vec![0.10, 0.11, 0.30, 0.12, 0.13, 0.31], 5.0);
        let meta = ev.meta.clone();
        let mut gsi = GsiEngine::new(&mut ev);
        let full = PruneMask::full(&meta);
        let os = gsi.one_shot_order(&full).unwrap();
        // one-shot's first four picks: indices 0,1,3,4 (damage .10-.13)
        // which includes BOTH blocks of layer 0 (idx 0=MHA0, 3=FFN0) and
        // layer 1 (idx 1=MHA1, 4=FFN1) → would pay synergy.
        let os_first4: Vec<usize> =
            os.iter().take(4).map(|(b, _)| b.index(3)).collect();
        assert_eq!(os_first4, vec![0, 1, 3, 4]);
        // greedy with recalibration refuses the 4th synergy-triggering cut
        let mut n = 0;
        let g = gsi
            .greedy(&full, |_| {
                n += 1;
                n > 4
            })
            .unwrap();
        let final_nll = *g.nll_after.last().unwrap();
        // one-shot's 4 picks: 2.0+.10+.11+.12+.13+2*5.0 = 12.46
        // greedy must end strictly lower
        assert!(final_nll < 12.0, "greedy nll {final_nll}");
    }

    #[test]
    fn memoization_caches_masks() {
        let mut ev = synth(vec![0.1; 6], 0.0);
        let meta = ev.meta.clone();
        {
            let mut gsi = GsiEngine::new(&mut ev);
            let full = PruneMask::full(&meta);
            gsi.importance(&full).unwrap();
            let first = gsi.memo_len();
            gsi.importance(&full).unwrap(); // fully cached
            assert_eq!(gsi.memo_len(), first);
        }
        assert_eq!(ev.evals as usize, 7); // 1 base + 6 candidates
    }

    #[test]
    fn stop_predicate_on_param_budget() {
        let mut ev = synth(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6], 0.0);
        let meta = ev.meta.clone();
        let mut gsi = GsiEngine::new(&mut ev);
        let full = PruneMask::full(&meta);
        let res = gsi
            .greedy(&full, |m| m.param_fraction(&meta) <= 0.7)
            .unwrap();
        assert!(!res.order.is_empty());
        let mut m = PruneMask::full(&meta);
        for b in &res.order {
            m.drop_block(*b);
        }
        assert!(m.param_fraction(&meta) <= 0.7);
    }
}

//! Evaluation harness: perplexity (WikiText2-sim / PTB-sim) and the
//! commonsense-sim MCQ suite — the measurement side of Table 1/2/3.
//!
//! Protocol mirrors the paper's LM-Eval-Harness usage: zero-shot MCQ
//! scored by summed log-likelihood of each candidate ending given the
//! context (all endings in a task share a length, so sum and mean rank
//! identically), perplexity as exp(mean NLL) over held-out streams.

pub mod mcq;

use anyhow::Result;

use crate::corpus::{Corpus, Split};
use crate::mask::PruneMask;
use crate::runtime::Runtime;

/// Perplexity of a split under a mask. Uses `n_batches` windows of the
/// (batch, seqlen) score bucket.
pub fn perplexity(rt: &mut Runtime, corpus: &Corpus, split: Split,
                  mask: &PruneMask, batch: usize, seqlen: usize,
                  n_batches: usize) -> Result<f64> {
    let mut total_nll = 0.0f64;
    let mut total_cnt = 0.0f64;
    let ones = vec![1.0f32; batch * seqlen];
    for tokens in corpus.batches(split, batch, seqlen, n_batches, 0)? {
        let (nll, cnt) = rt.score(batch, seqlen, &tokens, &ones, mask)?;
        total_nll += nll.iter().map(|&x| x as f64).sum::<f64>();
        total_cnt += cnt.iter().map(|&x| x as f64).sum::<f64>();
    }
    Ok((total_nll / total_cnt.max(1.0)).exp())
}

/// A full Table-1-style evaluation row for one (scheme, mask).
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub scheme: String,
    pub wikitext2_ppl: f64,
    pub ptb_ppl: f64,
    /// (task name, accuracy %) in canonical task order.
    pub task_acc: Vec<(String, f64)>,
}

impl EvalRow {
    pub fn avg_acc(&self) -> f64 {
        if self.task_acc.is_empty() {
            return f64::NAN;
        }
        self.task_acc.iter().map(|(_, a)| a).sum::<f64>()
            / self.task_acc.len() as f64
    }

    pub fn header() -> String {
        format!(
            "{:<22} {:>10} {:>10} | {:>6} {:>6} {:>6} {:>6} {:>6} {:>6} \
             {:>6} {:>6}",
            "Scheme", "WikiT2", "PTB", "BoolQ", "PIQA", "WinoG", "HellaS",
            "ARC-e", "ARC-c", "OBQA", "Avg")
    }

    pub fn row(&self) -> String {
        let mut s = format!("{:<22} {:>10} {:>10} |", self.scheme,
                            fmt_ppl(self.wikitext2_ppl),
                            fmt_ppl(self.ptb_ppl));
        for (_, a) in &self.task_acc {
            s.push_str(&format!(" {:>6.2}", a));
        }
        s.push_str(&format!(" {:>6.2}", self.avg_acc()));
        s
    }
}

pub fn fmt_ppl(p: f64) -> String {
    if p < 1000.0 {
        format!("{p:.2}")
    } else {
        format!("{p:.0}")
    }
}

/// Evaluate perplexity on both held-out splits plus the 7-task MCQ suite.
pub fn full_eval(rt: &mut Runtime, corpus: &Corpus, mask: &PruneMask,
                 scheme: &str, n_ppl_batches: usize,
                 questions_per_task: usize, seed: u64) -> Result<EvalRow> {
    let t = rt.meta().max_seq.min(128);
    let wiki = perplexity(rt, corpus, Split::Wiki, mask, 4, t,
                          n_ppl_batches)?;
    let ptb = perplexity(rt, corpus, Split::Ptb, mask, 4, t,
                         n_ppl_batches)?;
    let mut task_acc = Vec::new();
    for task in mcq::all_tasks() {
        let acc = mcq::accuracy(rt, corpus, &task, mask,
                                questions_per_task, seed)?;
        task_acc.push((task.name.to_string(), acc * 100.0));
    }
    Ok(EvalRow { scheme: scheme.to_string(), wikitext2_ppl: wiki,
                 ptb_ppl: ptb, task_acc })
}

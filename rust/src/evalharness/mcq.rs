//! Commonsense-sim: seven synthetic multiple-choice tasks standing in for
//! BoolQ / PIQA / WinoGrande / HellaSwag / ARC-e / ARC-c / OpenBookQA
//! (DESIGN.md §6 documents the substitution).
//!
//! Construction: a question is a context sampled from the training chain,
//! a *correct* ending sampled from the true generative process continuing
//! that context, and distractor endings drawn from a task-specific source
//! (uniform noise, continuations of a different context, or the shifted
//! PTB chain). Tasks differ in context length, ending length, choice count
//! and distractor hardness, giving the spread of difficulty the paper's
//! suite has. Scoring = argmax of summed ending log-likelihood, computed
//! with one `score_b4_t64` call per question (choices = batch rows).

use anyhow::Result;

use crate::corpus::{Corpus, MarkovChain};
use crate::mask::PruneMask;
use crate::runtime::Runtime;
use crate::server::kv::KvPolicy;
use crate::util::rng::Rng;

/// Where distractor endings come from (hardness order: Uniform <
/// ShiftedChain < WrongContext).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistractorKind {
    /// i.i.d. uniform tokens — easiest to reject.
    Uniform,
    /// continuation under the PTB (noise-interpolated) chain.
    ShiftedChain,
    /// true-process continuation of a *different* context — hardest.
    WrongContext,
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub ctx_len: usize,
    pub end_len: usize,
    pub n_choices: usize,
    pub distractors: DistractorKind,
    /// Per-task seed offset so tasks draw disjoint question streams.
    pub seed_offset: u64,
}

/// The canonical 7-task suite (paper Table 1 column order).
pub fn all_tasks() -> Vec<TaskSpec> {
    vec![
        TaskSpec { name: "boolq-sim", ctx_len: 24, end_len: 4,
                   n_choices: 2, distractors: DistractorKind::ShiftedChain,
                   seed_offset: 11 },
        TaskSpec { name: "piqa-sim", ctx_len: 16, end_len: 6,
                   n_choices: 2, distractors: DistractorKind::WrongContext,
                   seed_offset: 22 },
        TaskSpec { name: "winogrande-sim", ctx_len: 20, end_len: 2,
                   n_choices: 2, distractors: DistractorKind::Uniform,
                   seed_offset: 33 },
        TaskSpec { name: "hellaswag-sim", ctx_len: 32, end_len: 8,
                   n_choices: 4, distractors: DistractorKind::WrongContext,
                   seed_offset: 44 },
        TaskSpec { name: "arc-e-sim", ctx_len: 12, end_len: 4,
                   n_choices: 4, distractors: DistractorKind::Uniform,
                   seed_offset: 55 },
        TaskSpec { name: "arc-c-sim", ctx_len: 12, end_len: 4,
                   n_choices: 4, distractors: DistractorKind::WrongContext,
                   seed_offset: 66 },
        TaskSpec { name: "obqa-sim", ctx_len: 8, end_len: 6,
                   n_choices: 4, distractors: DistractorKind::ShiftedChain,
                   seed_offset: 77 },
    ]
}

/// One generated question.
#[derive(Clone, Debug)]
pub struct Question {
    pub context: Vec<u16>,
    /// endings[0] is correct; presentation order is shuffled at scoring.
    pub endings: Vec<Vec<u16>>,
}

/// Continue `ctx` for `n` tokens under the true process.
fn continue_seq(chain: &MarkovChain, ctx: &[u16], n: usize, rng: &mut Rng)
                -> Vec<u16> {
    let mut hist = ctx.to_vec();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t = chain.next_token(&hist, rng);
        hist.push(t);
        out.push(t);
    }
    out
}

pub fn generate_question(corpus: &Corpus, task: &TaskSpec, rng: &mut Rng)
                         -> Question {
    let chain = &corpus.chain;
    let context = chain.sample(task.ctx_len, rng);
    let correct = continue_seq(chain, &context, task.end_len, rng);
    let mut endings = vec![correct];
    while endings.len() < task.n_choices {
        let d = match task.distractors {
            DistractorKind::Uniform => (0..task.end_len)
                .map(|_| rng.below(chain.vocab) as u16)
                .collect(),
            DistractorKind::ShiftedChain => {
                continue_seq(&corpus.chain_ptb, &context, task.end_len, rng)
            }
            DistractorKind::WrongContext => {
                let other = chain.sample(task.ctx_len, rng);
                continue_seq(chain, &other, task.end_len, rng)
            }
        };
        // A distractor identical to the correct ending would make the
        // question unanswerable; resample (cheap, rare).
        if d != endings[0] {
            endings.push(d);
        }
    }
    Question { context, endings }
}

/// Sequence/bucket constants: all tasks fit the (4, 64) score bucket.
pub const MCQ_BATCH: usize = 4;
pub const MCQ_SEQLEN: usize = 64;

/// Score one question: returns the index of the highest-likelihood ending.
pub fn score_question(rt: &mut Runtime, q: &Question, mask: &PruneMask)
                      -> Result<usize> {
    let n = q.endings.len();
    assert!(n <= MCQ_BATCH);
    let ctx_len = q.context.len();
    let end_len = q.endings[0].len();
    assert!(ctx_len + end_len <= MCQ_SEQLEN);
    let mut tokens = vec![0i32; MCQ_BATCH * MCQ_SEQLEN];
    let mut lmask = vec![0.0f32; MCQ_BATCH * MCQ_SEQLEN];
    for (row, ending) in q.endings.iter().enumerate() {
        let base = row * MCQ_SEQLEN;
        for (i, &t) in q.context.iter().enumerate() {
            tokens[base + i] = t as i32;
        }
        for (i, &t) in ending.iter().enumerate() {
            tokens[base + ctx_len + i] = t as i32;
            lmask[base + ctx_len + i] = 1.0;
        }
    }
    let (nll, _cnt) = rt.score(MCQ_BATCH, MCQ_SEQLEN, &tokens, &lmask,
                               mask)?;
    let mut best = 0usize;
    for i in 1..n {
        if nll[i] < nll[best] {
            best = i;
        }
    }
    Ok(best)
}

/// Accuracy over `n_questions` fresh questions (deterministic in `seed`).
pub fn accuracy(rt: &mut Runtime, corpus: &Corpus, task: &TaskSpec,
                mask: &PruneMask, n_questions: usize, seed: u64)
                -> Result<f64> {
    let mut rng = Rng::new(seed.wrapping_add(task.seed_offset));
    let mut correct = 0usize;
    for _ in 0..n_questions {
        let q = generate_question(corpus, task, &mut rng);
        // endings[0] is correct by construction; score_question returns
        // the argmax row.
        if score_question(rt, &q, mask)? == 0 {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_questions as f64)
}

/// Chance-level accuracy (the floor a destroyed model decays to).
pub fn chance(task: &TaskSpec) -> f64 {
    1.0 / task.n_choices as f64
}

// ---- KV-policy accuracy oracle (PR-9) ---------------------------------
//
// `accuracy` above measures the *mask* axis: the runtime's NLL moves
// with pruned weights, but its attention is always over the full cache,
// so it cannot see token eviction. The KV axis needs a scorer whose
// answer depends on *which context tokens survive compression* — that
// is exactly the true generative process (the Markov chain with its
// copy mechanism), conditioned on the retained positions only. The
// oracle is the model an ideal network would converge to, so the
// accuracy delta it reports under a policy is the *information-
// theoretic* cost of that policy's eviction, independent of this
// particular synthetic runtime.

/// The long-context member of the suite: the only task whose context
/// (56 tokens) exceeds the default KV floor cap (52), so the floor
/// policy genuinely evicts mid-context tokens here. The suite's other
/// tasks fit under the cap and are untouched by compression.
pub fn longctx_task() -> TaskSpec {
    TaskSpec { name: "longctx-sim", ctx_len: 56, end_len: 8,
               n_choices: 4, distractors: DistractorKind::WrongContext,
               seed_offset: 88 }
}

/// Accuracy tolerance for the compression floor: the joint lattice's
/// claim is that pressure compression is *quality-neutral*, because the
/// floor's `recent` window (48) keeps every copy source (lag 4) that
/// ending positions can reference. `policy_accuracy` under the default
/// floor must sit within this epsilon of dense — in fact it is exactly
/// equal; the epsilon only absorbs a future corpus re-pin.
pub const MCQ_EPSILON: f64 = 0.01;

/// Is context position `i` (of `ctx_len`) still resident after
/// compressing under `policy`? `WindowSink` keeps the first `sink` and
/// last `recent` positions; `Dense`/`HeadDrop` are token-complete
/// (HeadDrop thins kv groups, not tokens — the oracle reads content,
/// so group thinning is invisible to it).
pub fn token_retained(policy: KvPolicy, i: usize, ctx_len: usize)
                      -> bool {
    match policy {
        KvPolicy::Dense | KvPolicy::HeadDrop { .. } => true,
        KvPolicy::WindowSink { sink, recent } => {
            i < sink || i + recent >= ctx_len
        }
    }
}

/// Log-likelihood of `ending` under the true chain, conditioned on the
/// *retained* context only. Evicted positions are unknown to the
/// scorer: where the chain's copy mechanism points at one (distance
/// `copy_lag` behind the predicted position), the copy term is
/// marginalized to uniform over the vocabulary; a hidden current token
/// likewise marginalizes the transition row. Ending tokens are
/// appended after compression, so they are always visible.
fn oracle_ending_loglik(chain: &MarkovChain, ctx: &[u16],
                        policy: KvPolicy, ending: &[u16]) -> f64 {
    let v = chain.vocab as f64;
    let ctx_len = ctx.len();
    let visible =
        |i: usize| i >= ctx_len || token_retained(policy, i, ctx_len);
    let mut hist: Vec<u16> = ctx.to_vec();
    let mut ll = 0.0f64;
    for &tok in ending {
        let pos = hist.len();
        let has_copy = pos >= chain.copy_lag;
        let chain_w = if has_copy { 1.0 - chain.copy_p } else { 1.0 };
        let mut p = if visible(pos - 1) {
            chain.row(hist[pos - 1] as usize)[tok as usize] as f64
                * chain_w
        } else {
            chain_w / v
        };
        if has_copy {
            let s = pos - chain.copy_lag;
            if visible(s) {
                if hist[s] == tok {
                    p += chain.copy_p;
                }
            } else {
                p += chain.copy_p / v;
            }
        }
        ll += p.max(1e-12).ln();
        hist.push(tok);
    }
    ll
}

/// Score one question under a KV policy: argmax of the oracle ending
/// log-likelihood over the retained context. Ties break toward the
/// lower index, mirroring `score_question`.
pub fn oracle_score_question(corpus: &Corpus, q: &Question,
                             policy: KvPolicy) -> usize {
    let mut best = 0usize;
    let mut best_ll =
        oracle_ending_loglik(&corpus.chain, &q.context, policy,
                             &q.endings[0]);
    for (i, e) in q.endings.iter().enumerate().skip(1) {
        let ll = oracle_ending_loglik(&corpus.chain, &q.context, policy,
                                      e);
        if ll > best_ll {
            best = i;
            best_ll = ll;
        }
    }
    best
}

/// Oracle accuracy over `n_questions` fresh questions under a KV
/// policy (deterministic in `seed`; the question stream is identical
/// to `accuracy`'s for the same task + seed).
pub fn policy_accuracy(corpus: &Corpus, task: &TaskSpec,
                       policy: KvPolicy, n_questions: usize, seed: u64)
                       -> f64 {
    let mut rng = Rng::new(seed.wrapping_add(task.seed_offset));
    let mut correct = 0usize;
    for _ in 0..n_questions {
        let q = generate_question(corpus, task, &mut rng);
        if oracle_score_question(corpus, &q, policy) == 0 {
            correct += 1;
        }
    }
    correct as f64 / n_questions as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::MarkovChain;

    fn toy_corpus() -> Corpus {
        // deterministic 8-token cycle chain; ptb = uniform-ish
        let v = 8;
        let mut trans = vec![0.0f32; v * v];
        for t in 0..v {
            trans[t * v + (t + 1) % v] = 1.0;
        }
        let chain = MarkovChain::new(v, trans, 0.0, 4).unwrap();
        let uni = MarkovChain::new(v, vec![1.0 / v as f32; v * v], 0.0, 4)
            .unwrap();
        Corpus {
            chain,
            chain_ptb: uni,
            train: vec![0; 1024],
            wiki: vec![0; 1024],
            ptb: vec![0; 1024],
            alpaca: vec![0; 1024],
        }
    }

    #[test]
    fn question_shapes() {
        let c = toy_corpus();
        let mut rng = Rng::new(1);
        for task in all_tasks() {
            let q = generate_question(&c, &task, &mut rng);
            assert_eq!(q.context.len(), task.ctx_len);
            assert_eq!(q.endings.len(), task.n_choices);
            for e in &q.endings {
                assert_eq!(e.len(), task.end_len);
            }
            assert!(task.ctx_len + task.end_len <= MCQ_SEQLEN);
            assert!(task.n_choices <= MCQ_BATCH);
        }
    }

    #[test]
    fn correct_ending_follows_chain() {
        let c = toy_corpus();
        let mut rng = Rng::new(2);
        let task = &all_tasks()[0];
        let q = generate_question(&c, task, &mut rng);
        // deterministic cycle: correct ending continues ctx
        let mut expect = *q.context.last().unwrap();
        for &t in &q.endings[0] {
            expect = (expect + 1) % 8;
            assert_eq!(t, expect);
        }
    }

    #[test]
    fn distractors_differ_from_correct() {
        let c = toy_corpus();
        let mut rng = Rng::new(3);
        for task in all_tasks() {
            for _ in 0..20 {
                let q = generate_question(&c, &task, &mut rng);
                for d in &q.endings[1..] {
                    assert_ne!(*d, q.endings[0]);
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let c = toy_corpus();
        let task = &all_tasks()[3];
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let q1 = generate_question(&c, task, &mut r1);
        let q2 = generate_question(&c, task, &mut r2);
        assert_eq!(q1.context, q2.context);
        assert_eq!(q1.endings, q2.endings);
    }

    #[test]
    fn chance_levels() {
        let tasks = all_tasks();
        assert_eq!(chance(&tasks[0]), 0.5);
        assert_eq!(chance(&tasks[3]), 0.25);
    }

    #[test]
    fn longctx_task_exceeds_floor_cap_and_fits_bucket() {
        let t = longctx_task();
        let floor = crate::server::controller::default_kv_floor();
        assert!(t.ctx_len > floor.token_cap(),
                "longctx context must force real eviction");
        assert!(t.ctx_len + t.end_len <= MCQ_SEQLEN);
        assert!(t.n_choices <= MCQ_BATCH);
    }

    #[test]
    fn token_retained_window_geometry() {
        let p = KvPolicy::WindowSink { sink: 4, recent: 48 };
        // ctx 56: positions 0-3 (sink) and 8-55 (recent) survive
        for i in 0..56 {
            assert_eq!(token_retained(p, i, 56), i < 4 || i >= 8,
                       "position {i}");
        }
        assert!(token_retained(KvPolicy::Dense, 30, 56));
        assert!(token_retained(KvPolicy::HeadDrop { keep_groups: 1 },
                               30, 56));
    }

    #[test]
    fn floor_policy_accuracy_matches_dense_exactly() {
        // The default floor keeps every copy source an ending position
        // can reference (recent 48 >= lag 4), so the oracle's
        // conditionals — and therefore every argmax — are identical
        // to dense, even on the long-context task where the floor
        // genuinely evicts mid-context tokens.
        let c = Corpus::synthetic(64, 7);
        let floor = crate::server::controller::default_kv_floor();
        let mut tasks = all_tasks();
        tasks.push(longctx_task());
        for t in &tasks {
            let dense =
                policy_accuracy(&c, t, KvPolicy::Dense, 40, 42);
            let compressed = policy_accuracy(&c, t, floor, 40, 42);
            assert_eq!(dense, compressed, "task {}", t.name);
            assert!((dense - compressed).abs() <= MCQ_EPSILON);
        }
    }

    #[test]
    fn oracle_beats_chance_on_longctx() {
        let c = Corpus::synthetic(64, 7);
        let t = longctx_task();
        let acc = policy_accuracy(&c, &t, KvPolicy::Dense, 60, 42);
        assert!(acc > chance(&t) + 0.1,
                "oracle should beat chance: {acc}");
    }

    #[test]
    fn evicting_the_copy_source_flips_the_argmax() {
        // Handcrafted corpus where the answer *is* the copy evidence:
        // a deterministic cycle chain with a strong copy mechanism
        // (p=0.6, lag 4). The correct ending's first token copies
        // ctx[len-4]; the distractor follows the cycle instead.
        //   visible source:  p(copy tok) = 0.6      > p(cycle tok) = 0.4
        //   evicted source:  p(copy tok) = 0.6/v    < p(cycle tok) = 0.4 + 0.6/v
        // so a window too small to hold the source (recent 2 < lag 4)
        // must flip the argmax — the teeth behind MCQ_EPSILON.
        let v = 8;
        let mut trans = vec![0.0f32; v * v];
        for t in 0..v {
            trans[t * v + (t + 1) % v] = 1.0;
        }
        let chain = MarkovChain::new(v, trans.clone(), 0.6, 4).unwrap();
        let uni =
            MarkovChain::new(v, vec![1.0 / v as f32; v * v], 0.0, 4)
                .unwrap();
        let corpus = Corpus { chain, chain_ptb: uni,
                              train: vec![0; 64], wiki: vec![0; 64],
                              ptb: vec![0; 64], alpaca: vec![0; 64] };
        // context: 0 1 2 3 4 5 6 7; copy source for the next position
        // is ctx[4] = 4, the cycle successor of ctx[7] = 7 is 0.
        let context: Vec<u16> = (0..8).map(|x| x as u16).collect();
        let q = Question {
            context,
            endings: vec![vec![4u16], vec![0u16]],
        };
        let dense = oracle_score_question(&corpus, &q, KvPolicy::Dense);
        assert_eq!(dense, 0, "with the source visible, copy wins");
        let tight = KvPolicy::WindowSink { sink: 0, recent: 2 };
        let flipped = oracle_score_question(&corpus, &q, tight);
        assert_eq!(flipped, 1,
                   "with the source evicted, the cycle token wins");
    }
}

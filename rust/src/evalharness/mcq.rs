//! Commonsense-sim: seven synthetic multiple-choice tasks standing in for
//! BoolQ / PIQA / WinoGrande / HellaSwag / ARC-e / ARC-c / OpenBookQA
//! (DESIGN.md §6 documents the substitution).
//!
//! Construction: a question is a context sampled from the training chain,
//! a *correct* ending sampled from the true generative process continuing
//! that context, and distractor endings drawn from a task-specific source
//! (uniform noise, continuations of a different context, or the shifted
//! PTB chain). Tasks differ in context length, ending length, choice count
//! and distractor hardness, giving the spread of difficulty the paper's
//! suite has. Scoring = argmax of summed ending log-likelihood, computed
//! with one `score_b4_t64` call per question (choices = batch rows).

use anyhow::Result;

use crate::corpus::{Corpus, MarkovChain};
use crate::mask::PruneMask;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Where distractor endings come from (hardness order: Uniform <
/// ShiftedChain < WrongContext).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistractorKind {
    /// i.i.d. uniform tokens — easiest to reject.
    Uniform,
    /// continuation under the PTB (noise-interpolated) chain.
    ShiftedChain,
    /// true-process continuation of a *different* context — hardest.
    WrongContext,
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub ctx_len: usize,
    pub end_len: usize,
    pub n_choices: usize,
    pub distractors: DistractorKind,
    /// Per-task seed offset so tasks draw disjoint question streams.
    pub seed_offset: u64,
}

/// The canonical 7-task suite (paper Table 1 column order).
pub fn all_tasks() -> Vec<TaskSpec> {
    vec![
        TaskSpec { name: "boolq-sim", ctx_len: 24, end_len: 4,
                   n_choices: 2, distractors: DistractorKind::ShiftedChain,
                   seed_offset: 11 },
        TaskSpec { name: "piqa-sim", ctx_len: 16, end_len: 6,
                   n_choices: 2, distractors: DistractorKind::WrongContext,
                   seed_offset: 22 },
        TaskSpec { name: "winogrande-sim", ctx_len: 20, end_len: 2,
                   n_choices: 2, distractors: DistractorKind::Uniform,
                   seed_offset: 33 },
        TaskSpec { name: "hellaswag-sim", ctx_len: 32, end_len: 8,
                   n_choices: 4, distractors: DistractorKind::WrongContext,
                   seed_offset: 44 },
        TaskSpec { name: "arc-e-sim", ctx_len: 12, end_len: 4,
                   n_choices: 4, distractors: DistractorKind::Uniform,
                   seed_offset: 55 },
        TaskSpec { name: "arc-c-sim", ctx_len: 12, end_len: 4,
                   n_choices: 4, distractors: DistractorKind::WrongContext,
                   seed_offset: 66 },
        TaskSpec { name: "obqa-sim", ctx_len: 8, end_len: 6,
                   n_choices: 4, distractors: DistractorKind::ShiftedChain,
                   seed_offset: 77 },
    ]
}

/// One generated question.
#[derive(Clone, Debug)]
pub struct Question {
    pub context: Vec<u16>,
    /// endings[0] is correct; presentation order is shuffled at scoring.
    pub endings: Vec<Vec<u16>>,
}

/// Continue `ctx` for `n` tokens under the true process.
fn continue_seq(chain: &MarkovChain, ctx: &[u16], n: usize, rng: &mut Rng)
                -> Vec<u16> {
    let mut hist = ctx.to_vec();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t = chain.next_token(&hist, rng);
        hist.push(t);
        out.push(t);
    }
    out
}

pub fn generate_question(corpus: &Corpus, task: &TaskSpec, rng: &mut Rng)
                         -> Question {
    let chain = &corpus.chain;
    let context = chain.sample(task.ctx_len, rng);
    let correct = continue_seq(chain, &context, task.end_len, rng);
    let mut endings = vec![correct];
    while endings.len() < task.n_choices {
        let d = match task.distractors {
            DistractorKind::Uniform => (0..task.end_len)
                .map(|_| rng.below(chain.vocab) as u16)
                .collect(),
            DistractorKind::ShiftedChain => {
                continue_seq(&corpus.chain_ptb, &context, task.end_len, rng)
            }
            DistractorKind::WrongContext => {
                let other = chain.sample(task.ctx_len, rng);
                continue_seq(chain, &other, task.end_len, rng)
            }
        };
        // A distractor identical to the correct ending would make the
        // question unanswerable; resample (cheap, rare).
        if d != endings[0] {
            endings.push(d);
        }
    }
    Question { context, endings }
}

/// Sequence/bucket constants: all tasks fit the (4, 64) score bucket.
pub const MCQ_BATCH: usize = 4;
pub const MCQ_SEQLEN: usize = 64;

/// Score one question: returns the index of the highest-likelihood ending.
pub fn score_question(rt: &mut Runtime, q: &Question, mask: &PruneMask)
                      -> Result<usize> {
    let n = q.endings.len();
    assert!(n <= MCQ_BATCH);
    let ctx_len = q.context.len();
    let end_len = q.endings[0].len();
    assert!(ctx_len + end_len <= MCQ_SEQLEN);
    let mut tokens = vec![0i32; MCQ_BATCH * MCQ_SEQLEN];
    let mut lmask = vec![0.0f32; MCQ_BATCH * MCQ_SEQLEN];
    for (row, ending) in q.endings.iter().enumerate() {
        let base = row * MCQ_SEQLEN;
        for (i, &t) in q.context.iter().enumerate() {
            tokens[base + i] = t as i32;
        }
        for (i, &t) in ending.iter().enumerate() {
            tokens[base + ctx_len + i] = t as i32;
            lmask[base + ctx_len + i] = 1.0;
        }
    }
    let (nll, _cnt) = rt.score(MCQ_BATCH, MCQ_SEQLEN, &tokens, &lmask,
                               mask)?;
    let mut best = 0usize;
    for i in 1..n {
        if nll[i] < nll[best] {
            best = i;
        }
    }
    Ok(best)
}

/// Accuracy over `n_questions` fresh questions (deterministic in `seed`).
pub fn accuracy(rt: &mut Runtime, corpus: &Corpus, task: &TaskSpec,
                mask: &PruneMask, n_questions: usize, seed: u64)
                -> Result<f64> {
    let mut rng = Rng::new(seed.wrapping_add(task.seed_offset));
    let mut correct = 0usize;
    for _ in 0..n_questions {
        let q = generate_question(corpus, task, &mut rng);
        // endings[0] is correct by construction; score_question returns
        // the argmax row.
        if score_question(rt, &q, mask)? == 0 {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_questions as f64)
}

/// Chance-level accuracy (the floor a destroyed model decays to).
pub fn chance(task: &TaskSpec) -> f64 {
    1.0 / task.n_choices as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::MarkovChain;

    fn toy_corpus() -> Corpus {
        // deterministic 8-token cycle chain; ptb = uniform-ish
        let v = 8;
        let mut trans = vec![0.0f32; v * v];
        for t in 0..v {
            trans[t * v + (t + 1) % v] = 1.0;
        }
        let chain = MarkovChain::new(v, trans, 0.0, 4).unwrap();
        let uni = MarkovChain::new(v, vec![1.0 / v as f32; v * v], 0.0, 4)
            .unwrap();
        Corpus {
            chain,
            chain_ptb: uni,
            train: vec![0; 1024],
            wiki: vec![0; 1024],
            ptb: vec![0; 1024],
            alpaca: vec![0; 1024],
        }
    }

    #[test]
    fn question_shapes() {
        let c = toy_corpus();
        let mut rng = Rng::new(1);
        for task in all_tasks() {
            let q = generate_question(&c, &task, &mut rng);
            assert_eq!(q.context.len(), task.ctx_len);
            assert_eq!(q.endings.len(), task.n_choices);
            for e in &q.endings {
                assert_eq!(e.len(), task.end_len);
            }
            assert!(task.ctx_len + task.end_len <= MCQ_SEQLEN);
            assert!(task.n_choices <= MCQ_BATCH);
        }
    }

    #[test]
    fn correct_ending_follows_chain() {
        let c = toy_corpus();
        let mut rng = Rng::new(2);
        let task = &all_tasks()[0];
        let q = generate_question(&c, task, &mut rng);
        // deterministic cycle: correct ending continues ctx
        let mut expect = *q.context.last().unwrap();
        for &t in &q.endings[0] {
            expect = (expect + 1) % 8;
            assert_eq!(t, expect);
        }
    }

    #[test]
    fn distractors_differ_from_correct() {
        let c = toy_corpus();
        let mut rng = Rng::new(3);
        for task in all_tasks() {
            for _ in 0..20 {
                let q = generate_question(&c, &task, &mut rng);
                for d in &q.endings[1..] {
                    assert_ne!(*d, q.endings[0]);
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let c = toy_corpus();
        let task = &all_tasks()[3];
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let q1 = generate_question(&c, task, &mut r1);
        let q2 = generate_question(&c, task, &mut r2);
        assert_eq!(q1.context, q2.context);
        assert_eq!(q1.endings, q2.endings);
    }

    #[test]
    fn chance_levels() {
        let tasks = all_tasks();
        assert_eq!(chance(&tasks[0]), 0.5);
        assert_eq!(chance(&tasks[3]), 0.25);
    }
}

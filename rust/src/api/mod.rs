//! First-class request API — the serving front door.
//!
//! RAP's premise is that compression strategy must adapt to the
//! "heterogeneous KV-cache demands arising from diverse user requests",
//! but a pre-baked workload trace carries none of that diversity: no
//! tenant, no urgency, no deadline. This module is the typed ingress
//! that replaces trace replay as the way work enters the serving stack:
//! a [`SubmitRequest`] carries *who* is asking ([`Tenant`]), *how
//! urgent* it is ([`PriorityClass`]), and *by when* it must finish
//! (`slo_deadline`), and every decision layer — engine admission,
//! pressure-victim selection, the fleet router, the autoscaler — reads
//! those fields. Trace replay still exists, but only as a thin adapter
//! ([`from_trace`]): a trace is just an iterator of `SubmitRequest`s
//! with default tenancy, so there is exactly one ingress path.
//!
//! ## Request lifecycle
//!
//! A submitted request moves through this state machine (surfaced by
//! `Engine::status` / `Fleet::poll` as [`RequestStatus`]):
//!
//! ```text
//!   submit ──► Queued ──► Running ──► Finished(Done)
//!                │  ▲         │   └──► Finished(DeadlineMissed)   (late)
//!                │  └─────────┤                                  (evict/requeue)
//!                │       Migrating                (parked / in flight
//!                │            │                    between replicas)
//!                │            └─────► Queued | Running  (landed on a peer)
//!                ├──► Finished(Rejected)        (admission control /
//!                │                               no accepting replica)
//!                ├──► Finished(DeadlineMissed)  (expired in queue, or
//!                │                               shed after its deadline)
//!                └──► Finished(Cancelled)       (cancel() — any
//!                                                non-terminal state)
//! ```
//!
//! Terminal outcomes ([`Outcome`]):
//!
//!   * `Done` — all `max_new_tokens` generated, within the deadline
//!     when one was set;
//!   * `Rejected` — admission control permanently rejected it (or no
//!     replica was accepting / the run ended with it still backlogged);
//!   * `DeadlineMissed` — it finished after `slo_deadline`, expired in
//!     the queue, or was shed under pressure after its deadline passed
//!     (expired work is terminated rather than requeued: re-running a
//!     request that already missed its SLO only burns capacity);
//!   * `Cancelled` — [`cancel`](crate::server::engine::Engine::cancel)
//!     reclaimed it; any KV it held is freed.
//!
//! ## Priority and deadlines in the decision layers
//!
//!   * the batcher's admission queue is priority-ordered (stable FCFS
//!     within a class);
//!   * pressure victims are chosen expired-deadline-first, then lowest
//!     class, then largest KV × remaining decode — and admission may
//!     preempt strictly-lower-class in-flight work to fit a higher
//!     class, never the reverse;
//!   * the `tenant-fair` router holds each tenant's overflow in a
//!     per-tenant ingress backlog against a KV-byte quota
//!     ([`TenantQuotas`]), dispatching deepest-under-quota first and
//!     placing each released request by RAP-aware scoring;
//!   * the autoscaler reads a per-tenant outstanding-requests signal so
//!     one tenant's backlog can trigger scale-up even when the fleet
//!     average looks calm.
//!
//! With every field at its default (tenant `"default"`, `Normal`
//! priority, no deadline) the whole stack behaves exactly like the
//! trace-replay path it replaced — seeded scenarios reproduce
//! byte-identically.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::workload::Request as TraceRequest;

/// Tenant identity: a cheap-to-clone interned name. Ordering (and
/// therefore every per-tenant report and quota table) is by name, so
/// multi-tenant output is deterministic.
pub type Tenant = Arc<str>;

/// The tenant every undecorated request belongs to (trace replay,
/// defaults).
pub const DEFAULT_TENANT: &str = "default";

/// Intern a tenant name.
pub fn tenant(name: &str) -> Tenant {
    Arc::from(name)
}

/// Urgency class. Ordered: `Batch < Normal < Interactive` — a higher
/// class is never evicted to admit a lower one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord,
         Hash)]
pub enum PriorityClass {
    /// Throughput-oriented background work (first to be shed).
    Batch,
    /// The default class — exactly the pre-API behavior.
    #[default]
    Normal,
    /// Latency-sensitive traffic (last to be shed, first in queue).
    Interactive,
}

impl PriorityClass {
    pub fn parse(s: &str) -> Result<PriorityClass> {
        Ok(match s {
            "batch" => PriorityClass::Batch,
            "normal" => PriorityClass::Normal,
            "interactive" => PriorityClass::Interactive,
            _ => bail!("unknown priority '{s}' (expected batch | normal \
                        | interactive)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PriorityClass::Batch => "batch",
            PriorityClass::Normal => "normal",
            PriorityClass::Interactive => "interactive",
        }
    }
}

/// One typed request — the only way work enters `Engine` or `Fleet`.
///
/// `prompt_len` stands in for the prompt itself: the serving stack is
/// driven by shape (the sim backend derives deterministic prompt tokens
/// from `id`), so the API carries the token count rather than token
/// text.
#[derive(Clone, Debug)]
pub struct SubmitRequest {
    /// Unique per run; the handle key. Assigned by the submitter (the
    /// trace adapter keeps trace ids).
    pub id: u64,
    /// Submission time in sim seconds.
    pub arrival: f64,
    pub tenant: Tenant,
    pub priority: PriorityClass,
    /// Absolute sim-time completion deadline (`None` = no SLO).
    pub slo_deadline: Option<f64>,
    pub prompt_len: usize,
    /// Generation cap; the sim completes a request exactly here.
    pub max_new_tokens: usize,
}

impl SubmitRequest {
    /// A default-tenancy request: tenant `"default"`, `Normal`
    /// priority, no deadline, id 0, arrival 0.0.
    pub fn new(prompt_len: usize, max_new_tokens: usize) -> SubmitRequest {
        SubmitRequest {
            id: 0,
            arrival: 0.0,
            tenant: tenant(DEFAULT_TENANT),
            priority: PriorityClass::Normal,
            slo_deadline: None,
            prompt_len,
            max_new_tokens,
        }
    }

    pub fn with_id(mut self, id: u64) -> SubmitRequest {
        self.id = id;
        self
    }

    pub fn with_arrival(mut self, arrival: f64) -> SubmitRequest {
        self.arrival = arrival;
        self
    }

    pub fn with_tenant(mut self, name: &str) -> SubmitRequest {
        self.tenant = tenant(name);
        self
    }

    pub fn with_priority(mut self, p: PriorityClass) -> SubmitRequest {
        self.priority = p;
        self
    }

    /// Set an absolute completion deadline (sim seconds).
    pub fn with_deadline(mut self, at: f64) -> SubmitRequest {
        self.slo_deadline = Some(at);
        self
    }

    /// The one trace→API conversion: default tenancy, the trace's id,
    /// arrival, and lengths.
    pub fn from_trace(r: &TraceRequest) -> SubmitRequest {
        SubmitRequest::new(r.prompt_len, r.gen_len)
            .with_id(r.id)
            .with_arrival(r.arrival)
    }

    /// Whether the deadline has already passed at sim time `now`.
    pub fn expired(&self, now: f64) -> bool {
        self.slo_deadline.map_or(false, |d| now > d)
    }

    /// Whether finishing at `at` honors the SLO (vacuously true without
    /// one).
    pub fn deadline_hit(&self, at: f64) -> bool {
        self.slo_deadline.map_or(true, |d| at <= d)
    }

    /// Ingress validity gate: a NaN or infinite arrival (a malformed
    /// trace, a broken client clock) is rejected at the boundary with
    /// [`Outcome::Rejected`] — it must never reach an arrival sort or
    /// the admission loop, where non-finite times panic or wedge.
    pub fn has_finite_arrival(&self) -> bool {
        self.arrival.is_finite()
    }
}

/// Opaque ticket returned by `submit`; feed it to `poll` / `cancel`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RequestHandle {
    pub id: u64,
}

/// Terminal result of one request (see the module docs for the state
/// machine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Done,
    Rejected,
    DeadlineMissed,
    Cancelled,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Done => "done",
            Outcome::Rejected => "rejected",
            Outcome::DeadlineMissed => "deadline-missed",
            Outcome::Cancelled => "cancelled",
        }
    }
}

/// Observable lifecycle state (`Engine::status` / `Fleet::poll`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestStatus {
    /// Admitted but not yet prefilled (replica queue or ingress
    /// backlog).
    Queued,
    /// Mid-decode on some replica.
    Running,
    /// Parked for migration or in flight between replicas.
    Migrating,
    Finished(Outcome),
}

/// The trace-replay adapter — the single legacy ingress, now just an
/// iterator of default-tenancy [`SubmitRequest`]s.
pub fn from_trace<I>(trace: I) -> impl Iterator<Item = SubmitRequest>
where
    I: IntoIterator<Item = TraceRequest>,
{
    trace.into_iter().map(|r| SubmitRequest::from_trace(&r))
}

/// Spread a trace across `tenants` synthetic tenants (`t0`, `t1`, …,
/// round-robin by request id) and attach a relative completion SLO of
/// `slo` seconds after arrival. `tenants <= 1` keeps the default
/// tenant. The CLI's `--tenants` / `--slo` flags are this function.
pub fn decorate_trace(trace: Vec<TraceRequest>, tenants: usize,
                      slo: Option<f64>) -> Vec<SubmitRequest> {
    let names: Vec<Tenant> = if tenants <= 1 {
        vec![tenant(DEFAULT_TENANT)]
    } else {
        (0..tenants).map(|i| tenant(&format!("t{i}"))).collect()
    };
    trace
        .into_iter()
        .map(|r| {
            let mut s = SubmitRequest::from_trace(&r);
            s.tenant = names[(r.id as usize) % names.len()].clone();
            if let Some(rel) = slo {
                s.slo_deadline = Some(r.arrival + rel);
            }
            s
        })
        .collect()
}

/// Per-tenant KV-byte quotas for the tenant-fair router: the cap on a
/// tenant's projected in-flight KV bytes across the fleet (queued +
/// active + migrating). The quota is a hard cap — a tenant's overflow
/// waits in the ingress backlog regardless of idle capacity (borrowing
/// idle share is a ROADMAP follow-up) — so it must exceed the largest
/// single request's projected KV bytes or that tenant can never
/// dispatch.
#[derive(Clone, Debug)]
pub struct TenantQuotas {
    /// Quota for tenants with no explicit entry.
    pub default_bytes: u64,
    overrides: Vec<(Tenant, u64)>,
}

impl TenantQuotas {
    /// No caps at all: tenant-fair degrades to pure RAP-aware placement.
    pub fn unlimited() -> TenantQuotas {
        TenantQuotas { default_bytes: u64::MAX, overrides: Vec::new() }
    }

    pub fn with_default(mut self, bytes: u64) -> TenantQuotas {
        self.default_bytes = bytes;
        self
    }

    /// Set (or replace) one tenant's quota.
    pub fn with_quota(mut self, name: &str, bytes: u64) -> TenantQuotas {
        if let Some(e) =
            self.overrides.iter_mut().find(|(t, _)| t.as_ref() == name)
        {
            e.1 = bytes;
        } else {
            self.overrides.push((tenant(name), bytes));
        }
        self
    }

    pub fn bytes_for(&self, name: &str) -> u64 {
        self.overrides
            .iter()
            .find(|(t, _)| t.as_ref() == name)
            .map(|(_, b)| *b)
            .unwrap_or(self.default_bytes)
    }

    /// Whether any finite quota is configured (reports only print the
    /// quota columns when one is).
    pub fn any_finite(&self) -> bool {
        self.default_bytes != u64::MAX
            || self.overrides.iter().any(|(_, b)| *b != u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_classes_are_ordered() {
        assert!(PriorityClass::Batch < PriorityClass::Normal);
        assert!(PriorityClass::Normal < PriorityClass::Interactive);
        assert_eq!(PriorityClass::default(), PriorityClass::Normal);
        assert_eq!(PriorityClass::parse("interactive").unwrap(),
                   PriorityClass::Interactive);
        assert!(PriorityClass::parse("urgent").is_err());
    }

    #[test]
    fn trace_adapter_preserves_identity_and_defaults() {
        let trace = vec![
            TraceRequest { id: 3, arrival: 1.5, prompt_len: 12,
                           gen_len: 6 },
            TraceRequest { id: 4, arrival: 2.0, prompt_len: 30,
                           gen_len: 8 },
        ];
        let subs: Vec<SubmitRequest> = from_trace(trace).collect();
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].id, 3);
        assert_eq!(subs[0].arrival, 1.5);
        assert_eq!(subs[0].prompt_len, 12);
        assert_eq!(subs[0].max_new_tokens, 6);
        assert_eq!(subs[0].tenant.as_ref(), DEFAULT_TENANT);
        assert_eq!(subs[0].priority, PriorityClass::Normal);
        assert_eq!(subs[0].slo_deadline, None);
        assert_eq!(subs[1].id, 4);
    }

    #[test]
    fn deadlines_and_expiry() {
        let r = SubmitRequest::new(8, 4).with_deadline(10.0);
        assert!(!r.expired(10.0));
        assert!(r.expired(10.1));
        assert!(r.deadline_hit(10.0));
        assert!(!r.deadline_hit(10.1));
        let n = SubmitRequest::new(8, 4);
        assert!(!n.expired(1e9));
        assert!(n.deadline_hit(1e9));
    }

    #[test]
    fn decorate_assigns_tenants_and_slo() {
        let trace: Vec<TraceRequest> = (0..6)
            .map(|id| TraceRequest { id, arrival: id as f64,
                                     prompt_len: 8, gen_len: 4 })
            .collect();
        let subs = decorate_trace(trace, 3, Some(2.5));
        assert_eq!(subs[0].tenant.as_ref(), "t0");
        assert_eq!(subs[1].tenant.as_ref(), "t1");
        assert_eq!(subs[2].tenant.as_ref(), "t2");
        assert_eq!(subs[3].tenant.as_ref(), "t0");
        assert_eq!(subs[4].slo_deadline, Some(4.0 + 2.5));
        let plain = decorate_trace(
            vec![TraceRequest { id: 0, arrival: 0.0, prompt_len: 8,
                                gen_len: 4 }],
            1, None);
        assert_eq!(plain[0].tenant.as_ref(), DEFAULT_TENANT);
        assert_eq!(plain[0].slo_deadline, None);
    }

    #[test]
    fn quota_lookup_and_overrides() {
        let q = TenantQuotas::unlimited()
            .with_default(1000)
            .with_quota("noisy", 64)
            .with_quota("noisy", 128); // replace, not duplicate
        assert_eq!(q.bytes_for("noisy"), 128);
        assert_eq!(q.bytes_for("anyone-else"), 1000);
        assert!(q.any_finite());
        assert!(!TenantQuotas::unlimited().any_finite());
    }
}

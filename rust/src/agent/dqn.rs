//! Masked DQN (paper Appendix A.3/A.4): ε-greedy over valid actions,
//! replay buffer, target network with soft updates, TD(0) targets with a
//! masked max.

use anyhow::Result;

use super::env::PruneEnv;
use super::mlp::{AdamMlp, Mlp};
use super::replay::{ReplayBuffer, Transition};
use crate::memory::Workload;
use crate::runtime::NllEvaluator;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub hidden: usize,
    pub gamma: f32,
    pub lr: f32,
    pub tau: f32,
    pub eps_start: f64,
    pub eps_end: f64,
    /// Episodes over which ε decays linearly.
    pub eps_decay_episodes: usize,
    pub replay_cap: usize,
    pub batch_size: usize,
    /// Gradient steps per environment step.
    pub train_per_step: usize,
    pub episodes: usize,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig {
            hidden: 128,
            gamma: 0.99,
            lr: 1e-3,
            tau: 0.05,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_episodes: 80,
            replay_cap: 20_000,
            batch_size: 32,
            train_per_step: 1,
            episodes: 150,
        }
    }
}

/// Episode-level training record (Fig 9's reward curves).
#[derive(Clone, Debug)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub steps: usize,
    pub epsilon: f64,
    pub fit: bool,
}

pub struct DqnAgent {
    pub q: Mlp,
    pub target: Mlp,
    pub cfg: DqnConfig,
}

impl DqnAgent {
    pub fn new(state_dim: usize, n_actions: usize, cfg: DqnConfig,
               rng: &mut Rng) -> DqnAgent {
        let q = Mlp::new(state_dim, cfg.hidden, n_actions, rng);
        let target = q.clone();
        DqnAgent { q, target, cfg }
    }

    pub fn n_params(&self) -> usize {
        self.q.n_params()
    }

    /// Greedy argmax over valid actions.
    pub fn act_greedy(&self, state: &[f32], valid: &[bool]) -> usize {
        let qs = self.q.forward(state);
        argmax_masked(&qs, valid)
    }

    fn act_eps(&self, state: &[f32], valid: &[bool], eps: f64,
               rng: &mut Rng) -> usize {
        if rng.chance(eps) {
            let idx: Vec<usize> = valid
                .iter()
                .enumerate()
                .filter(|(_, &v)| v)
                .map(|(i, _)| i)
                .collect();
            idx[rng.below(idx.len())]
        } else {
            self.act_greedy(state, valid)
        }
    }

    /// Algorithm 2: train over episodes whose (workload, budget) are
    /// drawn by `sampler`. Returns the per-episode log.
    pub fn train<E: NllEvaluator, S>(
        &mut self, env: &mut PruneEnv<E>, mut sampler: S, seed: u64)
        -> Result<Vec<EpisodeLog>>
    where
        S: FnMut(&mut Rng) -> (Workload, f64),
    {
        let mut rng = Rng::new(seed);
        let mut replay = ReplayBuffer::new(self.cfg.replay_cap);
        let mut opt = AdamMlp::new(&self.q, self.cfg.lr);
        let mut logs = Vec::with_capacity(self.cfg.episodes);

        for ep in 0..self.cfg.episodes {
            let frac = (ep as f64
                / self.cfg.eps_decay_episodes.max(1) as f64)
                .min(1.0);
            let eps = self.cfg.eps_start
                + (self.cfg.eps_end - self.cfg.eps_start) * frac;
            let (w, budget) = sampler(&mut rng);
            let mut state = env.reset(w, budget)?;
            let mut total_reward = 0.0f64;
            let mut steps = 0usize;
            loop {
                let valid = env.valid_actions();
                if !valid.iter().any(|&v| v) {
                    break; // fully pruned and still over budget
                }
                let action = self.act_eps(&state, &valid, eps, &mut rng);
                let res = env.step(action)?;
                total_reward += res.reward as f64;
                steps += 1;
                replay.push(Transition {
                    state: state.clone(),
                    action,
                    reward: res.reward,
                    next_state: res.state.clone(),
                    done: res.done,
                    next_valid: env.valid_actions(),
                });
                state = res.state;

                if replay.len() >= self.cfg.batch_size {
                    for _ in 0..self.cfg.train_per_step {
                        self.train_batch(&mut opt, &replay, &mut rng);
                    }
                    self.target.soft_update_from(&self.q, self.cfg.tau);
                }
                if res.done {
                    break;
                }
            }
            logs.push(EpisodeLog { episode: ep, reward: total_reward,
                                   steps, epsilon: eps, fit: env.fits() });
        }
        Ok(logs)
    }

    fn train_batch(&mut self, opt: &mut AdamMlp, replay: &ReplayBuffer,
                   rng: &mut Rng) {
        let batch = replay.sample(self.cfg.batch_size, rng);
        opt.zero_grad();
        for t in &batch {
            let y = if t.done {
                t.reward
            } else {
                let qs = self.target.forward(&t.next_state);
                let max_q = qs
                    .iter()
                    .zip(&t.next_valid)
                    .filter(|(_, &v)| v)
                    .map(|(&q, _)| q)
                    .fold(f32::NEG_INFINITY, f32::max);
                let max_q = if max_q.is_finite() { max_q } else { 0.0 };
                t.reward + self.cfg.gamma * max_q
            };
            opt.accumulate(&self.q, &t.state, t.action, y);
        }
        opt.step(&mut self.q, batch.len());
    }

    // -- persistence (simple f32-binary format) ---------------------------

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut bytes = Vec::new();
        for dim in [self.q.n_in, self.q.n_hidden, self.q.n_out] {
            bytes.extend((dim as u32).to_le_bytes());
        }
        for part in [&self.q.w1, &self.q.b1, &self.q.w2, &self.q.b2] {
            for v in part.iter() {
                bytes.extend(v.to_le_bytes());
            }
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path, cfg: DqnConfig)
                -> Result<DqnAgent> {
        let bytes = std::fs::read(path)?;
        let rd = |i: usize| {
            u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap())
                as usize
        };
        let (n_in, n_hidden, n_out) = (rd(0), rd(1), rd(2));
        let mut rng = Rng::new(0);
        let mut agent = DqnAgent::new(n_in, n_out, cfg, &mut rng);
        agent.q.n_hidden = n_hidden;
        let mut off = 12usize;
        let mut read_part = |len: usize| {
            let out: Vec<f32> = bytes[off..off + len * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += len * 4;
            out
        };
        agent.q.w1 = read_part(n_hidden * n_in);
        agent.q.b1 = read_part(n_hidden);
        agent.q.w2 = read_part(n_out * n_hidden);
        agent.q.b2 = read_part(n_out);
        agent.target = agent.q.clone();
        Ok(agent)
    }
}

fn argmax_masked(qs: &[f32], valid: &[bool]) -> usize {
    let mut best = usize::MAX;
    let mut best_q = f32::NEG_INFINITY;
    for (i, (&q, &v)) in qs.iter().zip(valid).enumerate() {
        if v && q > best_q {
            best_q = q;
            best = i;
        }
    }
    assert!(best != usize::MAX, "no valid action");
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::env::EnvConfig;
    use crate::model_meta::ModelMeta;
    use crate::runtime::SyntheticEvaluator;

    fn quick_cfg() -> DqnConfig {
        DqnConfig { episodes: 160, eps_decay_episodes: 80, hidden: 32,
                    batch_size: 16, ..DqnConfig::default() }
    }

    #[test]
    fn argmax_respects_mask() {
        let qs = [5.0f32, 9.0, 1.0];
        assert_eq!(argmax_masked(&qs, &[true, true, true]), 1);
        assert_eq!(argmax_masked(&qs, &[true, false, true]), 0);
        assert_eq!(argmax_masked(&qs, &[false, false, true]), 2);
    }

    #[test]
    fn trained_policy_beats_random_on_final_mask_quality() {
        let meta = ModelMeta::synthetic("t", 3, 64, 4, 2, 96, 128, 64);
        // Asymmetric damage so there IS a right answer to learn: the
        // cheap blocks are MHA0 (0.05) and FFN0 (0.06).
        let damage = vec![0.05, 0.9, 0.9, 0.06, 0.9, 0.9];
        let mut ev = SyntheticEvaluator::new(meta.clone(), 2.0,
                                             damage.clone(), 0.0);
        let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
        let mut rng = Rng::new(7);
        let (sd, na) = (env.state_dim(), env.n_actions());
        let mut agent = DqnAgent::new(sd, na, quick_cfg(), &mut rng);
        let w = Workload::new(4, 32);
        let logs =
            agent.train(&mut env, |_r| (w, 0.75), 7).unwrap();
        // every episode must end within budget
        assert!(logs.iter().all(|l| l.fit));

        // Greedy rollout (Algorithm 3): total damage of dropped blocks
        // must beat the random-drop expectation.
        let mask =
            crate::agent::online_prune(&agent, &mut env, w, 0.75).unwrap();
        let dmg = |m: &crate::mask::PruneMask| -> f64 {
            m.dropped_blocks()
                .iter()
                .map(|b| damage[b.index(3)])
                .sum()
        };
        let learned = dmg(&mask);
        // random baseline: average over 50 random fit-seeking masks
        let mem = crate::memory::MemoryModel::new(&meta);
        let budget = mem.budget_bytes(w, 0.75);
        let mut total = 0.0;
        for s in 0..50u64 {
            let mut r = Rng::new(1000 + s);
            let mut order = meta.all_blocks();
            r.shuffle(&mut order);
            let mut m = crate::mask::PruneMask::full(&meta);
            for b in order {
                if mem.fits(&m, w, budget) {
                    break;
                }
                m.drop_block(b);
            }
            total += dmg(&m);
        }
        let random_avg = total / 50.0;
        assert!(learned <= random_avg,
                "learned damage {learned} vs random {random_avg}");
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let agent = DqnAgent::new(6, 4, quick_cfg(), &mut rng);
        let dir = std::env::temp_dir().join("rap_dqn_test.bin");
        agent.save(&dir).unwrap();
        let loaded = DqnAgent::load(&dir, quick_cfg()).unwrap();
        let x = vec![0.3f32; 6];
        assert_eq!(agent.q.forward(&x), loaded.q.forward(&x));
        let _ = std::fs::remove_file(dir);
    }
}


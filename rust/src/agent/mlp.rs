//! The paper's controller network: a 2-layer MLP (~18K parameters on
//! Llama2-7B's 64-block action space), hand-rolled with Adam — small
//! enough that a from-scratch implementation is both faster than any
//! framework round-trip and trivially auditable.

use crate::util::rng::Rng;

/// Fully-connected ReLU MLP: in → hidden (ReLU) → out (linear).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    pub w1: Vec<f32>, // [hidden, in]
    pub b1: Vec<f32>,
    pub w2: Vec<f32>, // [out, hidden]
    pub b2: Vec<f32>,
}

impl Mlp {
    pub fn new(n_in: usize, n_hidden: usize, n_out: usize, rng: &mut Rng)
               -> Mlp {
        let he = |fan_in: usize, rng: &mut Rng| {
            let s = (2.0 / fan_in as f64).sqrt();
            (rng.normal() * s) as f32
        };
        Mlp {
            n_in,
            n_hidden,
            n_out,
            w1: (0..n_hidden * n_in).map(|_| he(n_in, rng)).collect(),
            b1: vec![0.0; n_hidden],
            w2: (0..n_out * n_hidden).map(|_| he(n_hidden, rng)).collect(),
            b2: vec![0.0; n_out],
        }
    }

    pub fn n_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
    }

    /// Forward pass; writes hidden activations into `h` (len n_hidden)
    /// and returns the outputs.
    pub fn forward_with_hidden(&self, x: &[f32], h: &mut [f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.n_in);
        for j in 0..self.n_hidden {
            let row = &self.w1[j * self.n_in..(j + 1) * self.n_in];
            let mut s = self.b1[j];
            for (w, xi) in row.iter().zip(x) {
                s += w * xi;
            }
            h[j] = s.max(0.0);
        }
        let mut out = vec![0.0f32; self.n_out];
        for (k, o) in out.iter_mut().enumerate() {
            let row = &self.w2[k * self.n_hidden..(k + 1) * self.n_hidden];
            let mut s = self.b2[k];
            for (w, hj) in row.iter().zip(h.iter()) {
                s += w * hj;
            }
            *o = s;
        }
        out
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut h = vec![0.0f32; self.n_hidden];
        self.forward_with_hidden(x, &mut h)
    }

    /// Soft update toward `src`: θ ← τ·src + (1−τ)·θ (target network).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        let blend = |dst: &mut [f32], s: &[f32]| {
            for (d, s) in dst.iter_mut().zip(s) {
                *d = tau * s + (1.0 - tau) * *d;
            }
        };
        blend(&mut self.w1, &src.w1);
        blend(&mut self.b1, &src.b1);
        blend(&mut self.w2, &src.w2);
        blend(&mut self.b2, &src.b2);
    }
}

/// Adam state + gradient accumulators sized for one `Mlp`.
pub struct AdamMlp {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
    grad: Vec<f32>,
}

impl AdamMlp {
    pub fn new(net: &Mlp, lr: f32) -> AdamMlp {
        let n = net.n_params();
        AdamMlp { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0,
                  m: vec![0.0; n], v: vec![0.0; n], grad: vec![0.0; n] }
    }

    pub fn zero_grad(&mut self) {
        self.grad.fill(0.0);
    }

    /// Accumulate the gradient of 0.5·(Q(s)[a] − y)² for one sample.
    /// Returns the TD error (Q − y).
    pub fn accumulate(&mut self, net: &Mlp, x: &[f32], action: usize,
                      y: f32) -> f32 {
        let mut h = vec![0.0f32; net.n_hidden];
        let out = net.forward_with_hidden(x, &mut h);
        let err = out[action] - y;

        // Gradients. Output layer: only row `action` sees gradient.
        let (g_w1, rest) = self.grad.split_at_mut(net.w1.len());
        let (g_b1, rest) = rest.split_at_mut(net.b1.len());
        let (g_w2, g_b2) = rest.split_at_mut(net.w2.len());

        let w2_row = &net.w2[action * net.n_hidden..(action + 1)
            * net.n_hidden];
        g_b2[action] += err;
        for j in 0..net.n_hidden {
            g_w2[action * net.n_hidden + j] += err * h[j];
        }
        // Hidden layer: dL/dh_j = err * w2[action, j], ReLU-gated.
        for j in 0..net.n_hidden {
            if h[j] <= 0.0 {
                continue;
            }
            let dh = err * w2_row[j];
            g_b1[j] += dh;
            let row = &mut g_w1[j * net.n_in..(j + 1) * net.n_in];
            for (g, xi) in row.iter_mut().zip(x) {
                *g += dh * xi;
            }
        }
        err
    }

    /// Apply the accumulated gradients (divided by `batch`) with Adam.
    pub fn step(&mut self, net: &mut Mlp, batch: usize) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let scale = 1.0 / batch.max(1) as f32;
        let params: [&mut [f32]; 4] = [&mut net.w1, &mut net.b1,
                                       &mut net.w2, &mut net.b2];
        let mut off = 0usize;
        for p in params {
            for (i, w) in p.iter_mut().enumerate() {
                let g = self.grad[off + i] * scale;
                let m = &mut self.m[off + i];
                let v = &mut self.v[off + i];
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                let mh = *m / bc1;
                let vh = *v / bc2;
                *w -= self.lr * mh / (vh.sqrt() + self.eps);
            }
            off += p.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_relu() {
        let mut rng = Rng::new(1);
        let net = Mlp::new(3, 8, 2, &mut rng);
        let out = net.forward(&[0.1, -0.2, 0.3]);
        assert_eq!(out.len(), 2);
        assert_eq!(net.n_params(), 3 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn can_regress_a_simple_function() {
        // Fit Q(x)[a] = target for two actions: y0 = 2x0, y1 = -x1 + 1.
        let mut rng = Rng::new(2);
        let mut net = Mlp::new(2, 32, 2, &mut rng);
        let mut opt = AdamMlp::new(&net, 1e-2);
        for _ in 0..2000 {
            opt.zero_grad();
            let mut n = 0;
            for _ in 0..16 {
                let x = [rng.f32() * 2.0 - 1.0, rng.f32() * 2.0 - 1.0];
                let a = rng.below(2);
                let y = if a == 0 { 2.0 * x[0] } else { -x[1] + 1.0 };
                opt.accumulate(&net, &x, a, y);
                n += 1;
            }
            opt.step(&mut net, n);
        }
        let mut max_err = 0.0f32;
        for _ in 0..100 {
            let x = [rng.f32() * 2.0 - 1.0, rng.f32() * 2.0 - 1.0];
            let out = net.forward(&x);
            max_err = max_err.max((out[0] - 2.0 * x[0]).abs());
            max_err = max_err.max((out[1] - (-x[1] + 1.0)).abs());
        }
        assert!(max_err < 0.2, "max_err={max_err}");
    }

    #[test]
    fn soft_update_converges_to_source() {
        let mut rng = Rng::new(3);
        let src = Mlp::new(2, 4, 2, &mut rng);
        let mut tgt = Mlp::new(2, 4, 2, &mut rng);
        for _ in 0..200 {
            tgt.soft_update_from(&src, 0.1);
        }
        for (a, b) in tgt.w1.iter().zip(&src.w1) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::new(4);
        let net = Mlp::new(3, 5, 2, &mut rng);
        let x = [0.3f32, -0.7, 0.9];
        let (a, y) = (1usize, 0.25f32);
        let mut opt = AdamMlp::new(&net, 1e-3);
        opt.zero_grad();
        opt.accumulate(&net, &x, a, y);
        // finite-difference check on a few w1 entries
        let loss = |n: &Mlp| {
            let q = n.forward(&x)[a];
            0.5 * (q - y) * (q - y)
        };
        for &idx in &[0usize, 4, 7, 14] {
            let mut plus = net.clone();
            plus.w1[idx] += 1e-3;
            let mut minus = net.clone();
            minus.w1[idx] -= 1e-3;
            let fd = (loss(&plus) - loss(&minus)) / 2e-3;
            let an = opt.grad[idx];
            assert!((fd - an).abs() < 1e-2,
                    "idx {idx}: fd {fd} vs analytic {an}");
        }
    }
}

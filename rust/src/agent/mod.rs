//! The RL controller (paper §4.2 + Appendix A): pruning MDP environment,
//! hand-rolled 2-layer MLP Q-network, replay buffer, masked DQN training
//! (Algorithm 2) and online execution (Algorithm 3).

pub mod dqn;
pub mod env;
pub mod mlp;
pub mod replay;

use anyhow::Result;

use crate::mask::PruneMask;
use crate::memory::Workload;
use crate::runtime::NllEvaluator;

/// Algorithm 3: online execution. Run the trained agent greedily from the
/// dense model until the budget is met (or STOP). Returns the mask.
pub fn online_prune<E: NllEvaluator>(
    agent: &dqn::DqnAgent, env: &mut env::PruneEnv<E>, workload: Workload,
    budget_fraction: f64) -> Result<PruneMask> {
    let mut state = env.reset(workload, budget_fraction)?;
    let horizon = env.n_actions() - 1;
    for _ in 0..horizon {
        if env.fits() {
            break;
        }
        let valid = env.valid_actions();
        if !valid.iter().any(|&v| v) {
            break;
        }
        let action = agent.act_greedy(&state, &valid);
        if action == 0 {
            break; // STOP
        }
        state = env.step(action)?.state;
    }
    Ok(env.mask.clone())
}

//! Experience replay buffer for the masked DQN (paper Appendix A.3).

use crate::util::rng::Rng;

/// One transition (s, a, r, s', done, valid-mask of s').
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
    /// Valid actions in s' (needed for the masked max in the TD target).
    pub next_valid: Vec<bool>,
}

/// Fixed-capacity ring buffer with uniform sampling.
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    cap: usize,
    next: usize,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> ReplayBuffer {
        ReplayBuffer { buf: Vec::with_capacity(cap), cap, next: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
        }
        self.next = (self.next + 1) % self.cap;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Sample `n` transitions uniformly with replacement.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng)
                      -> Vec<&'a Transition> {
        (0..n).map(|_| &self.buf[rng.below(self.buf.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f32) -> Transition {
        Transition { state: vec![r], action: 0, reward: r,
                     next_state: vec![r], done: false,
                     next_valid: vec![true] }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        let rewards: Vec<f32> = rb.buf.iter().map(|x| x.reward).collect();
        // slots: [3, 4, 2]
        assert!(rewards.contains(&2.0));
        assert!(rewards.contains(&3.0));
        assert!(rewards.contains(&4.0));
        assert!(!rewards.contains(&0.0));
    }

    #[test]
    fn sampling_is_uniformish() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..10 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for s in rb.sample(10_000, &mut rng) {
            counts[s.reward as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 700 && c < 1300, "{counts:?}");
        }
    }
}

//! The pruning MDP (paper Appendix A.1): sequential single-block removal
//! with a memory-budget termination condition and the Eq. 2 reward.
//!
//! State  s_t = (s^Req, s^Model, s^Sys):
//!   [ bs/16, sql/max_seq,
//!     GSI importance of all 2N blocks (recomputed after every removal,
//!     normalized by the dense model's max importance),
//!     Sys_avail / dense_peak, Sys_req / dense_peak ]
//! Action a_t ∈ {0 = STOP, 1..2N = remove block a−1}, with an action mask
//! (already-removed blocks invalid; STOP invalid while over budget).
//! Reward Eq. 2: R_t = Σ_i kept_i · (α·R_ppl_i − β·R_mem_i).

use anyhow::Result;

use crate::gsi::GsiEngine;
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::model_meta::BlockId;
use crate::runtime::NllEvaluator;

#[derive(Clone, Copy, Debug)]
pub struct EnvConfig {
    /// Accuracy weight α (paper default 1.0).
    pub alpha: f64,
    /// Memory-penalty weight β (paper default 0.3).
    pub beta: f64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig { alpha: 1.0, beta: 0.3 }
    }
}

pub struct StepResult {
    pub state: Vec<f32>,
    pub reward: f32,
    pub done: bool,
}

pub struct PruneEnv<'a, E: NllEvaluator> {
    pub gsi: GsiEngine<'a, E>,
    pub mem: MemoryModel,
    pub cfg: EnvConfig,
    n_layers: usize,
    max_seq: usize,
    // episode state
    pub workload: Workload,
    pub budget_bytes: usize,
    pub mask: PruneMask,
    importance: Vec<f64>,
    imp_scale: f64,
    dense_peak: usize,
    steps: usize,
}

impl<'a, E: NllEvaluator> PruneEnv<'a, E> {
    pub fn new(eval: &'a mut E, cfg: EnvConfig) -> PruneEnv<'a, E> {
        Self::with_memo(eval, cfg, std::collections::HashMap::new())
    }

    /// Build with a pre-warmed GSI memo (serving controllers reuse their
    /// memo across decisions).
    pub fn with_memo(eval: &'a mut E, cfg: EnvConfig,
                     memo: std::collections::HashMap<u64, f64>)
                     -> PruneEnv<'a, E> {
        let meta = eval.meta().clone();
        PruneEnv {
            gsi: GsiEngine::with_memo(eval, memo),
            mem: MemoryModel::new(&meta),
            cfg,
            n_layers: meta.n_layers,
            max_seq: meta.max_seq,
            workload: Workload::new(1, 1),
            budget_bytes: usize::MAX,
            mask: PruneMask::full(&meta),
            importance: Vec::new(),
            imp_scale: 1.0,
            dense_peak: 1,
            steps: 0,
        }
    }

    /// Extract the GSI memo for reuse by the caller.
    pub fn take_memo(self) -> std::collections::HashMap<u64, f64> {
        self.gsi.take_memo()
    }

    pub fn n_actions(&self) -> usize {
        2 * self.n_layers + 1
    }

    pub fn state_dim(&self) -> usize {
        2 * self.n_layers + 4
    }

    /// Begin an episode for a workload and a *relative* budget fraction.
    pub fn reset(&mut self, workload: Workload, budget_fraction: f64)
                 -> Result<Vec<f32>> {
        let meta = self.mem.meta().clone();
        self.workload = workload;
        self.dense_peak = self.mem.dense_peak_bytes(workload).max(1);
        self.budget_bytes =
            (self.dense_peak as f64 * budget_fraction) as usize;
        self.mask = PruneMask::full(&meta);
        self.importance = self.gsi.importance(&self.mask)?;
        self.imp_scale = self
            .importance
            .iter()
            .cloned()
            .fold(f64::MIN_POSITIVE, f64::max);
        self.steps = 0;
        Ok(self.state())
    }

    pub fn fits(&self) -> bool {
        self.mem.peak_bytes(&self.mask, self.workload) <= self.budget_bytes
    }

    pub fn state(&self) -> Vec<f32> {
        let mut s = Vec::with_capacity(self.state_dim());
        s.push(self.workload.batch as f32 / 16.0);
        s.push(self.workload.seqlen as f32 / self.max_seq as f32);
        for &imp in &self.importance {
            s.push((imp / self.imp_scale).clamp(-2.0, 2.0) as f32);
        }
        s.push(self.budget_bytes as f32 / self.dense_peak as f32);
        let req = self.mem.peak_bytes(&self.mask, self.workload);
        s.push(req as f32 / self.dense_peak as f32);
        s
    }

    /// Action mask: STOP (0) only when within budget; block removals only
    /// for blocks still present.
    pub fn valid_actions(&self) -> Vec<bool> {
        let mut v = vec![false; self.n_actions()];
        v[0] = self.fits();
        for i in 0..2 * self.n_layers {
            let b = BlockId::from_index(i, self.n_layers);
            v[i + 1] = !self.mask.block_dropped(b);
        }
        v
    }

    /// Eq. 2 over the current mask.
    pub fn reward(&self) -> f32 {
        let mut r = 0.0f64;
        for i in 0..2 * self.n_layers {
            let b = BlockId::from_index(i, self.n_layers);
            if self.mask.block_dropped(b) {
                continue;
            }
            let r_ppl = (self.importance[i] / self.imp_scale).clamp(-2.0,
                                                                    2.0);
            let r_mem = self.mem.block_bytes(&self.mask, self.workload, b)
                as f64
                / self.dense_peak as f64;
            r += self.cfg.alpha * r_ppl - self.cfg.beta * r_mem;
        }
        // Normalize by block count so reward scale is model-size free.
        (r / (2 * self.n_layers) as f64) as f32
    }

    pub fn step(&mut self, action: usize) -> Result<StepResult> {
        self.steps += 1;
        let horizon = 2 * self.n_layers;
        if action == 0 {
            // STOP (only legal when within budget).
            return Ok(StepResult { state: self.state(),
                                   reward: self.reward(), done: true });
        }
        let b = BlockId::from_index(action - 1, self.n_layers);
        debug_assert!(!self.mask.block_dropped(b), "invalid action");
        self.mask.drop_block(b);
        // GSI recalibration (Alg 2 line 10).
        self.importance = self.gsi.importance(&self.mask)?;
        let done = self.fits() || self.steps >= horizon;
        Ok(StepResult { state: self.state(), reward: self.reward(), done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;
    use crate::runtime::SyntheticEvaluator;

    fn env_for(damage: Vec<f64>) -> SyntheticEvaluator {
        let n_layers = damage.len() / 2;
        let meta = ModelMeta::synthetic("t", n_layers, 64, 4, 2, 96, 128,
                                        64);
        SyntheticEvaluator::new(meta, 2.0, damage, 0.0)
    }

    #[test]
    fn reset_gives_dense_state() {
        let mut ev = env_for(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
        let s = env.reset(Workload::new(4, 32), 0.8).unwrap();
        assert_eq!(s.len(), env.state_dim());
        assert!(!env.fits()); // 80% budget: dense can't fit
        let v = env.valid_actions();
        assert!(!v[0]); // STOP masked while over budget
        assert!(v[1..].iter().all(|&x| x));
    }

    #[test]
    fn stepping_prunes_until_fit() {
        let mut ev = env_for(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
        env.reset(Workload::new(4, 32), 0.8).unwrap();
        let mut done = false;
        let mut taken = 0;
        while !done {
            // always remove the first valid block
            let v = env.valid_actions();
            let a = (1..v.len()).find(|&i| v[i]).unwrap();
            let r = env.step(a).unwrap();
            done = r.done;
            taken += 1;
            assert!(taken <= 6);
        }
        assert!(env.fits());
    }

    #[test]
    fn stop_is_terminal_and_legal_when_fitting() {
        let mut ev = env_for(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let mut env = PruneEnv::new(&mut ev, EnvConfig::default());
        env.reset(Workload::new(1, 4), 1.1).unwrap(); // generous budget
        assert!(env.fits());
        assert!(env.valid_actions()[0]);
        let r = env.step(0).unwrap();
        assert!(r.done);
    }

    #[test]
    fn reward_decreases_when_dropping_important_blocks_is_kept() {
        // Keeping everything yields the max Σ importance; dropping the
        // *most* important block reduces the kept-importance sum more
        // than dropping the least important one.
        let mut ev = env_for(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.9]);
        let mut env = PruneEnv::new(&mut ev, EnvConfig { alpha: 1.0,
                                                         beta: 0.0 });
        env.reset(Workload::new(4, 32), 0.5).unwrap();
        let r_keep_all = env.reward();
        let r_drop_least = env.step(1).unwrap().reward; // damage 0.1
        env.reset(Workload::new(4, 32), 0.5).unwrap();
        let r_drop_most = env.step(6).unwrap().reward; // damage 0.9
        assert!(r_drop_least > r_drop_most,
                "{r_drop_least} !> {r_drop_most}");
        assert!(r_keep_all >= r_drop_least);
    }

    #[test]
    fn beta_penalizes_memory_hungry_masks() {
        let mut ev = env_for(vec![0.5; 6]);
        let mut env = PruneEnv::new(&mut ev, EnvConfig { alpha: 0.0,
                                                         beta: 1.0 });
        env.reset(Workload::new(4, 32), 0.5).unwrap();
        let dense_reward = env.reward();
        // removing blocks shrinks the memory penalty → reward rises
        let after = env.step(1).unwrap().reward;
        assert!(after > dense_reward, "{after} !> {dense_reward}");
    }
}

//! Pruning masks: the runtime representation of a compression decision.
//!
//! A `PruneMask` holds the two gate tensors fed to every compiled entry
//! point (`head_gate [L, H]`, `ffn_gate [L, F]`). Block-level pruning (the
//! paper's action space) zeroes whole rows; channel-level baselines
//! (LLMPruner-sim, SliceGPT-sim) zero subsets. All memory accounting in
//! `memory.rs` is derived from the mask, so a mask IS the single source of
//! truth for "what is pruned".

use crate::model_meta::{BlockId, ModelMeta};

#[derive(Clone, Debug, PartialEq)]
pub struct PruneMask {
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    /// Row-major [L, H] multiplier (1.0 = keep).
    pub head_gate: Vec<f32>,
    /// Row-major [L, F] multiplier.
    pub ffn_gate: Vec<f32>,
}

impl PruneMask {
    /// Dense model: everything kept.
    pub fn full(meta: &ModelMeta) -> PruneMask {
        PruneMask {
            n_layers: meta.n_layers,
            n_heads: meta.n_heads,
            n_kv_heads: meta.n_kv_heads,
            d_ff: meta.d_ff,
            head_gate: vec![1.0; meta.n_layers * meta.n_heads],
            ffn_gate: vec![1.0; meta.n_layers * meta.d_ff],
        }
    }

    // -- block-level ops (the paper's 2N action space) ----------------------

    pub fn drop_block(&mut self, b: BlockId) {
        match b {
            BlockId::Mha(l) => self.set_mha_row(l, 0.0),
            BlockId::Ffn(l) => self.set_ffn_row(l, 0.0),
        }
    }

    pub fn restore_block(&mut self, b: BlockId) {
        match b {
            BlockId::Mha(l) => self.set_mha_row(l, 1.0),
            BlockId::Ffn(l) => self.set_ffn_row(l, 1.0),
        }
    }

    pub fn with_block_dropped(&self, b: BlockId) -> PruneMask {
        let mut m = self.clone();
        m.drop_block(b);
        m
    }

    fn set_mha_row(&mut self, l: usize, v: f32) {
        let h = self.n_heads;
        self.head_gate[l * h..(l + 1) * h].fill(v);
    }

    fn set_ffn_row(&mut self, l: usize, v: f32) {
        let f = self.d_ff;
        self.ffn_gate[l * f..(l + 1) * f].fill(v);
    }

    /// A block counts as dropped when every gate in its row is zero.
    pub fn block_dropped(&self, b: BlockId) -> bool {
        match b {
            BlockId::Mha(l) => self.active_heads(l) == 0,
            BlockId::Ffn(l) => self.active_ffn_channels(l) == 0,
        }
    }

    pub fn dropped_blocks(&self) -> Vec<BlockId> {
        let mut out = Vec::new();
        for l in 0..self.n_layers {
            if self.block_dropped(BlockId::Mha(l)) {
                out.push(BlockId::Mha(l));
            }
        }
        for l in 0..self.n_layers {
            if self.block_dropped(BlockId::Ffn(l)) {
                out.push(BlockId::Ffn(l));
            }
        }
        out
    }

    // -- channel-level ops (baselines) --------------------------------------

    pub fn set_head(&mut self, l: usize, h: usize, keep: bool) {
        self.head_gate[l * self.n_heads + h] = if keep { 1.0 } else { 0.0 };
    }

    pub fn head(&self, l: usize, h: usize) -> bool {
        self.head_gate[l * self.n_heads + h] != 0.0
    }

    pub fn set_ffn_channel(&mut self, l: usize, c: usize, keep: bool) {
        self.ffn_gate[l * self.d_ff + c] = if keep { 1.0 } else { 0.0 };
    }

    pub fn ffn_channel(&self, l: usize, c: usize) -> bool {
        self.ffn_gate[l * self.d_ff + c] != 0.0
    }

    // -- aggregate queries (feed the memory model) ---------------------------

    pub fn active_heads(&self, l: usize) -> usize {
        let h = self.n_heads;
        self.head_gate[l * h..(l + 1) * h]
            .iter()
            .filter(|&&g| g != 0.0)
            .count()
    }

    pub fn active_ffn_channels(&self, l: usize) -> usize {
        let f = self.d_ff;
        self.ffn_gate[l * f..(l + 1) * f]
            .iter()
            .filter(|&&g| g != 0.0)
            .count()
    }

    /// KV groups with at least one live query head — these are the kv
    /// heads whose cache rows must actually be stored.
    pub fn active_kv_groups(&self, l: usize) -> usize {
        let group = self.n_heads / self.n_kv_heads;
        (0..self.n_kv_heads)
            .filter(|&g| {
                (0..group).any(|j| self.head(l, g * group + j))
            })
            .count()
    }

    /// Fraction of prunable-block parameters retained (Table 4 metric).
    pub fn param_fraction(&self, meta: &ModelMeta) -> f64 {
        let mut kept = meta.base_params() as f64;
        for l in 0..self.n_layers {
            kept += self.layer_param_bytes_scalar(meta, l);
        }
        kept / meta.total_params() as f64
    }

    /// Parameters retained in layer `l` (scalar count, not bytes).
    pub fn layer_param_bytes_scalar(&self, meta: &ModelMeta, l: usize)
                                    -> f64 {
        let d = meta.d_model as f64;
        let dh = meta.head_dim() as f64;
        let qh = self.active_heads(l) as f64;
        let kvg = self.active_kv_groups(l) as f64;
        let fc = self.active_ffn_channels(l) as f64;
        let mut p = 0.0;
        if qh > 0.0 {
            p += qh * 2.0 * d * dh;        // wq + wo slices
            p += kvg * 2.0 * d * dh;       // wk + wv slices
            p += d;                        // attn norm
        }
        if fc > 0.0 {
            p += fc * 3.0 * d;             // w_gate/w_up cols + w_down rows
            p += d;                        // ffn norm
        }
        p
    }

    /// Stable 64-bit key for memoization (GSI caches per pruned-set).
    pub fn key(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut feed = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        for (i, &g) in self.head_gate.iter().enumerate() {
            if g == 0.0 {
                feed(i as u64 + 1);
            }
        }
        feed(u64::MAX);
        for (i, &g) in self.ffn_gate.iter().enumerate() {
            if g == 0.0 {
                feed(i as u64 + 1);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("t", 4, 64, 4, 2, 96, 128, 64)
    }

    #[test]
    fn full_mask_keeps_everything() {
        let m = meta();
        let mask = PruneMask::full(&m);
        assert_eq!(mask.dropped_blocks(), vec![]);
        assert!((mask.param_fraction(&m) - 1.0).abs() < 1e-12);
        for l in 0..4 {
            assert_eq!(mask.active_heads(l), 4);
            assert_eq!(mask.active_kv_groups(l), 2);
            assert_eq!(mask.active_ffn_channels(l), 96);
        }
    }

    #[test]
    fn drop_and_restore_block() {
        let m = meta();
        let mut mask = PruneMask::full(&m);
        mask.drop_block(BlockId::Mha(1));
        mask.drop_block(BlockId::Ffn(3));
        assert!(mask.block_dropped(BlockId::Mha(1)));
        assert!(mask.block_dropped(BlockId::Ffn(3)));
        assert_eq!(mask.dropped_blocks().len(), 2);
        assert_eq!(mask.active_kv_groups(1), 0);
        mask.restore_block(BlockId::Mha(1));
        assert!(!mask.block_dropped(BlockId::Mha(1)));
        assert_eq!(mask.dropped_blocks().len(), 1);
    }

    #[test]
    fn kv_groups_follow_query_heads() {
        let m = meta();
        let mut mask = PruneMask::full(&m);
        // group size = 2: heads {0,1} -> group0, {2,3} -> group1
        mask.set_head(0, 0, false);
        assert_eq!(mask.active_kv_groups(0), 2); // head 1 keeps group 0
        mask.set_head(0, 1, false);
        assert_eq!(mask.active_kv_groups(0), 1);
        assert!(!mask.block_dropped(BlockId::Mha(0)));
        mask.set_head(0, 2, false);
        mask.set_head(0, 3, false);
        assert_eq!(mask.active_kv_groups(0), 0);
        assert!(mask.block_dropped(BlockId::Mha(0)));
    }

    #[test]
    fn param_fraction_decreases_monotonically() {
        let m = meta();
        let mut mask = PruneMask::full(&m);
        let mut prev = mask.param_fraction(&m);
        for b in m.all_blocks() {
            mask.drop_block(b);
            let f = mask.param_fraction(&m);
            assert!(f < prev, "{b}: {f} !< {prev}");
            prev = f;
        }
        // everything dropped → only base params remain
        assert!((prev - m.base_params() as f64 / m.total_params() as f64)
            .abs() < 1e-12);
    }

    #[test]
    fn keys_distinguish_masks() {
        let m = meta();
        let full = PruneMask::full(&m);
        let a = full.with_block_dropped(BlockId::Mha(0));
        let b = full.with_block_dropped(BlockId::Ffn(0));
        let c = full.with_block_dropped(BlockId::Mha(0));
        assert_ne!(full.key(), a.key());
        assert_ne!(a.key(), b.key());
        assert_eq!(a.key(), c.key());
    }
}

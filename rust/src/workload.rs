//! Workload generation: the Azure-LLM-inference-trace substitute
//! (paper Fig. 2 / Takeaway 1 — DynamoLLM-style diurnal + bursty traffic).
//!
//! The generator reproduces the trace *statistics* the paper leans on:
//!   * arrival rate follows a diurnal (sinusoidal) profile with
//!     superimposed Poisson burst episodes (5–10× rate spikes);
//!   * prompt lengths are log-normal (heavy right tail: a mix of short
//!     conversational turns and long-form inputs);
//!   * generation lengths are geometric-ish (log-normal, shorter).
//! Everything is seeded and deterministic.

use crate::util::rng::Rng;

/// One trace event: an arrival with sampled lengths. This is *not* the
/// serving stack's request type any more — engines and fleets consume
/// `crate::api::SubmitRequest` (tenant, priority class, SLO deadline),
/// and a trace enters serving only through the `api::from_trace`
/// adapter, which wraps each event in default tenancy.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/sec) at the diurnal baseline.
    pub base_rate: f64,
    /// Diurnal amplitude as a fraction of base (0..1).
    pub diurnal_amp: f64,
    /// Simulated day length in seconds (compressed day).
    pub day_secs: f64,
    /// Burst episodes per day (Poisson).
    pub bursts_per_day: f64,
    /// Burst rate multiplier and duration.
    pub burst_mult: f64,
    pub burst_secs: f64,
    /// Log-normal prompt-length parameters (of ln tokens).
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub prompt_max: usize,
    /// Log-normal generation-length parameters.
    pub gen_mu: f64,
    pub gen_sigma: f64,
    pub gen_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            base_rate: 2.0,
            diurnal_amp: 0.6,
            day_secs: 600.0,
            bursts_per_day: 6.0,
            burst_mult: 6.0,
            burst_secs: 15.0,
            prompt_mu: 3.1,   // median ~22 tokens
            prompt_sigma: 0.8,
            prompt_max: 120,
            gen_mu: 2.3,      // median ~10 tokens
            gen_sigma: 0.6,
            gen_max: 64,
        }
    }
}

pub struct TraceGenerator {
    pub cfg: TraceConfig,
    rng: Rng,
    bursts: Vec<(f64, f64)>, // (start, end)
    next_id: u64,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, seed: u64) -> TraceGenerator {
        let mut rng = Rng::new(seed);
        // Pre-draw burst episodes across one day.
        let n = rng.poisson(cfg.bursts_per_day);
        let mut bursts = Vec::with_capacity(n);
        for _ in 0..n {
            let s = rng.f64() * cfg.day_secs;
            bursts.push((s, s + cfg.burst_secs));
        }
        bursts.sort_by(|a, b| a.0.total_cmp(&b.0));
        TraceGenerator { cfg, rng, bursts, next_id: 0 }
    }

    /// Instantaneous arrival rate at time t (requests/sec).
    pub fn rate_at(&self, t: f64) -> f64 {
        let c = &self.cfg;
        let phase = 2.0 * std::f64::consts::PI * (t % c.day_secs)
            / c.day_secs;
        // trough at t=0, peak mid-day
        let diurnal = c.base_rate * (1.0 - c.diurnal_amp * phase.cos());
        let burst = self
            .bursts
            .iter()
            .any(|&(s, e)| t >= s && t < e);
        if burst { diurnal * c.burst_mult } else { diurnal }
    }

    fn sample_len(&mut self, mu: f64, sigma: f64, max: usize) -> usize {
        let v = self.rng.lognormal(mu, sigma).round() as usize;
        v.clamp(2, max)
    }

    /// Generate all requests arriving in [t0, t1) (thinned Poisson).
    pub fn generate(&mut self, t0: f64, t1: f64) -> Vec<Request> {
        let mut out = Vec::new();
        // upper bound on rate for thinning
        let max_rate = self.cfg.base_rate * (1.0 + self.cfg.diurnal_amp)
            * self.cfg.burst_mult;
        let mut t = t0;
        loop {
            t += self.rng.exponential(max_rate);
            if t >= t1 {
                break;
            }
            if self.rng.f64() < self.rate_at(t) / max_rate {
                let prompt_len = self.sample_len(self.cfg.prompt_mu,
                                                 self.cfg.prompt_sigma,
                                                 self.cfg.prompt_max);
                let gen_len = self.sample_len(self.cfg.gen_mu,
                                              self.cfg.gen_sigma,
                                              self.cfg.gen_max);
                out.push(Request { id: self.next_id, arrival: t,
                                   prompt_len, gen_len });
                self.next_id += 1;
            }
        }
        out
    }

    /// Whole-day trace (for Fig 2 / Fig 5 style analyses).
    pub fn generate_day(&mut self) -> Vec<Request> {
        let day = self.cfg.day_secs;
        self.generate(0.0, day)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let mut a = TraceGenerator::new(TraceConfig::default(), 5);
        let mut b = TraceGenerator::new(TraceConfig::default(), 5);
        let ra = a.generate(0.0, 50.0);
        let rb = b.generate(0.0, 50.0);
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_are_ordered_and_bounded() {
        let mut g = TraceGenerator::new(TraceConfig::default(), 1);
        let reqs = g.generate_day();
        assert!(!reqs.is_empty());
        let mut prev = 0.0;
        for r in &reqs {
            assert!(r.arrival >= prev);
            prev = r.arrival;
            assert!(r.prompt_len >= 2
                    && r.prompt_len <= g.cfg.prompt_max);
            assert!(r.gen_len >= 2 && r.gen_len <= g.cfg.gen_max);
        }
    }

    #[test]
    fn diurnal_rate_varies() {
        let g = TraceGenerator::new(TraceConfig {
            bursts_per_day: 0.0,
            ..TraceConfig::default()
        }, 2);
        let trough = g.rate_at(0.0);
        let peak = g.rate_at(g.cfg.day_secs / 2.0);
        assert!(peak > trough * 2.0, "peak {peak} trough {trough}");
    }

    #[test]
    fn bursts_raise_rate() {
        let g = TraceGenerator::new(TraceConfig::default(), 3);
        if let Some(&(s, _)) = g.bursts.first() {
            let in_burst = g.rate_at(s + 0.1);
            let outside = g.rate_at((s + g.cfg.burst_secs + 60.0)
                                    % g.cfg.day_secs);
            assert!(in_burst > outside * 2.0);
        }
    }

    /// Full-field determinism: same seed → identical ids, arrivals,
    /// prompt AND generation lengths; different seed → a different trace.
    #[test]
    fn trace_fully_deterministic_in_seed() {
        let mut a = TraceGenerator::new(TraceConfig::default(), 21);
        let mut b = TraceGenerator::new(TraceConfig::default(), 21);
        let ra = a.generate(0.0, 120.0);
        let rb = b.generate(0.0, 120.0);
        assert!(!ra.is_empty());
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.gen_len, y.gen_len);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
        let mut c = TraceGenerator::new(TraceConfig::default(), 22);
        let rc = c.generate(0.0, 120.0);
        let same = ra.len() == rc.len()
            && ra.iter().zip(&rc).all(|(x, y)| {
                (x.arrival - y.arrival).abs() < 1e-12
            });
        assert!(!same, "different seeds produced an identical trace");
    }

    /// The burst-episode multiplier must be visible in the *generated
    /// arrivals*, not just in `rate_at`: the empirical rate inside a
    /// burst window is several times the rate in a burst-free window.
    #[test]
    fn burst_multiplier_observed_in_arrivals() {
        let cfg = TraceConfig {
            base_rate: 4.0,
            diurnal_amp: 0.0, // flat baseline isolates the burst effect
            bursts_per_day: 10.0,
            burst_mult: 8.0,
            burst_secs: 30.0,
            ..TraceConfig::default()
        };
        let mut g = TraceGenerator::new(cfg, 11);
        assert!(!g.bursts.is_empty(), "seed drew no burst episodes");
        let (bs, be) = g.bursts[0];
        let bursts = g.bursts.clone();
        let day = g.cfg.day_secs;
        let reqs = g.generate(0.0, day);
        // a same-length window overlapping no burst episode
        let mut quiet = None;
        let mut t0 = 0.0;
        while t0 + 30.0 < day {
            if bursts.iter().all(|&(s, e)| t0 + 30.0 <= s || e <= t0) {
                quiet = Some(t0);
                break;
            }
            t0 += 1.0;
        }
        let q0 = quiet.expect("no burst-free window in the day");
        let in_burst = reqs.iter()
            .filter(|r| r.arrival >= bs && r.arrival < be)
            .count();
        let in_quiet = reqs.iter()
            .filter(|r| r.arrival >= q0 && r.arrival < q0 + 30.0)
            .count();
        assert!(in_quiet > 0, "empty quiet window");
        assert!(in_burst as f64 > 3.0 * in_quiet as f64,
                "burst {in_burst} vs quiet {in_quiet}: multiplier not \
                 observed");
    }

    /// The log-normal length caps must bind even when the distribution's
    /// median is far above them.
    #[test]
    fn length_caps_respected_under_extreme_params() {
        let cfg = TraceConfig {
            prompt_mu: 7.0, // median e^7 ≈ 1096 ≫ cap
            prompt_max: 50,
            gen_mu: 6.0,
            gen_max: 24,
            ..TraceConfig::default()
        };
        let mut g = TraceGenerator::new(cfg, 12);
        let reqs = g.generate(0.0, 300.0);
        assert!(!reqs.is_empty());
        for r in &reqs {
            assert!(r.prompt_len >= 2 && r.prompt_len <= 50,
                    "prompt {}", r.prompt_len);
            assert!(r.gen_len >= 2 && r.gen_len <= 24, "gen {}", r.gen_len);
        }
        // with the median far above the cap, the cap must actually bind
        assert_eq!(reqs.iter().map(|r| r.prompt_len).max().unwrap(), 50);
        assert_eq!(reqs.iter().map(|r| r.gen_len).max().unwrap(), 24);
    }

    #[test]
    fn prompt_lengths_heavy_tailed() {
        let mut g = TraceGenerator::new(TraceConfig::default(), 4);
        let reqs = g.generate(0.0, 400.0);
        let lens: Vec<f64> =
            reqs.iter().map(|r| r.prompt_len as f64).collect();
        let mean = crate::util::stats::mean(&lens);
        let p95 = crate::util::stats::percentile(&lens, 95.0);
        assert!(p95 > mean * 2.0, "p95 {p95} mean {mean}");
    }
}

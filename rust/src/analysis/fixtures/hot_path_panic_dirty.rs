//! Lint fixture — DIRTY on purpose, never compiled (not in the module
//! tree). Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 2 unjustified
//! `hot-path-panic` findings — and ZERO when re-scanned under
//! `agent/fixture.rs`, pinning the rule's scope.

pub fn pop_badly(&mut self) -> u64 {
    // plain violation: one empty queue takes the replica down
    let head = self.queue.pop_front().unwrap();
    head
}

pub fn meta_badly(&self, id: u64) -> &SeqMeta {
    // suppression WITHOUT a justification — still a finding
    // lint:allow(hot-path-panic)
    self.meta.get(&id).expect("meta for live sequence")
}

pub fn pop_fine(&mut self) -> Option<u64> {
    // the compliant form: degrade, don't panic; must NOT fire
    self.queue.pop_front()
}

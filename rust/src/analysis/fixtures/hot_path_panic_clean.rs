//! Lint fixture — CLEAN, never compiled (not in the module tree).
//! Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 1 *justified*
//! `hot-path-panic` finding and 0 unjustified ones.

pub fn pop_checked(&mut self) -> u64 {
    debug_assert!(!self.queue.is_empty(), "caller checks non-empty");
    // lint:allow(hot-path-panic): the is_empty guard one line up makes
    // this provably unreachable; a silent default would hide the bug
    self.queue.pop_front().unwrap()
}

pub fn pop_fine(&mut self) -> Option<u64> {
    // the compliant form; must NOT fire
    self.queue.pop_front()
}

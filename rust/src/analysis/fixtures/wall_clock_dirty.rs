//! Lint fixture — DIRTY on purpose, never compiled (not in the module
//! tree; the tree scan skips `analysis/fixtures/`). Scanned by
//! `tests/lint.rs` under the virtual path `server/fixture.rs` and
//! expected to yield exactly 2 unjustified `wall-clock` findings.

pub fn step_badly(&mut self) -> f64 {
    // plain violation: the sim step reads the host clock
    let t0 = std::time::Instant::now();
    self.advance();
    t0.elapsed().as_secs_f64()
}

pub fn stamp_badly(&mut self) -> u64 {
    // suppression WITHOUT a justification — still counts as a
    // finding; the directive below must not silence it.
    // lint:allow(wall-clock)
    let stamp = std::time::SystemTime::now();
    fingerprint(stamp)
}

//! Lint fixture — CLEAN, never compiled (not in the module tree).
//! Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 1 *justified*
//! `raw-rng` finding and 0 unjustified ones.

pub fn salted_probe(&self) -> u64 {
    // lint:allow(raw-rng): hashing fallback only — the salt never
    // reaches sampling, routing, or any serialized output
    let state = RandomState::new();
    probe_with(state, self.key)
}

pub fn draw_fine(&mut self) -> f64 {
    // the compliant form: the seeded crate rng; must NOT fire
    self.rng.f64()
}

//! Lint fixture — DIRTY on purpose, never compiled (not in the module
//! tree). Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 2 unjustified
//! `float-ordering` findings.

pub fn rank_badly(xs: &mut [f64]) {
    // plain violation: NaN placement becomes incidental
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));
}

pub fn pick_badly(xs: &[f64]) -> Option<f64> {
    // suppression WITHOUT a justification — still a finding
    // lint:allow(float-ordering)
    xs.iter()
        .cloned()
        .max_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Less))
}

pub fn rank_fine(xs: &mut [f64]) {
    // the compliant form; must NOT fire
    xs.sort_by(|a, b| a.total_cmp(b));
}

//! Lint fixture — CLEAN, never compiled (not in the module tree).
//! Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 1 *justified*
//! `wall-clock` finding and 0 unjustified ones.

pub fn measured_on_purpose(&mut self) -> f64 {
    // lint:allow(wall-clock): this path meters real host latency for
    // the operator report; nothing simulated reads the value
    let t0 = std::time::Instant::now();
    self.advance();
    t0.elapsed().as_secs_f64()
}

pub fn sim_clock_path(&self) -> f64 {
    // the compliant form: simulated time comes from the event loop
    self.clock.now_sim_secs()
}

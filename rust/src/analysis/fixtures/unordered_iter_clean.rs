//! Lint fixture — CLEAN, never compiled (not in the module tree).
//! Scanned by `tests/lint.rs` under the virtual path
//! `coordinator/fixture.rs` and expected to yield exactly 1
//! *justified* `unordered-iter` finding and 0 unjustified ones.

use std::collections::HashMap;

pub struct Scratch {
    staging: HashMap<u64, u64>,
    emitted: std::collections::BTreeMap<u64, u64>,
}

impl Scratch {
    pub fn total(&self) -> u64 {
        // lint:allow(unordered-iter): a sum is order-independent, so
        // hash order cannot reach the result
        self.staging.values().sum()
    }

    pub fn export(&self) -> Vec<(u64, u64)> {
        // BTreeMap iteration is key-ordered; must NOT fire
        self.emitted.iter().map(|(k, v)| (*k, *v)).collect()
    }
}

//! Lint fixture — DIRTY on purpose, never compiled (not in the module
//! tree). Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 2 unjustified
//! `raw-rng` findings.

pub fn jitter_badly(&mut self) -> f64 {
    // plain violation: host entropy breaks run-to-run determinism
    let r: f64 = rand::random();
    r * self.scale
}

pub fn reseed_badly(&mut self) {
    // suppression WITHOUT a justification — still a finding
    // lint:allow(raw-rng)
    self.rng = StdRng::from_entropy();
}

pub fn jitter_fine(&mut self) -> f64 {
    // the compliant form: the seeded crate rng; must NOT fire
    self.rng.f64() * self.scale
}

//! Lint fixture — CLEAN, never compiled (not in the module tree).
//! Scanned by `tests/lint.rs` under the virtual path
//! `server/fixture.rs` and expected to yield exactly 1 *justified*
//! `float-ordering` finding and 0 unjustified ones.

pub fn probe_sentinel(probe: f64, sentinel: f64) -> bool {
    // lint:allow(float-ordering): None-on-NaN is the point here — the
    // caller treats an unordered probe as "sentinel absent"
    probe.partial_cmp(&sentinel).is_none()
}

pub fn rank_fine(xs: &mut [f64]) {
    // the compliant form; must NOT fire
    xs.sort_by(|a, b| a.total_cmp(b));
}

//! Lint fixture — DIRTY on purpose, never compiled (not in the module
//! tree). Scanned by `tests/lint.rs` under the virtual path
//! `coordinator/fixture.rs` and expected to yield exactly 2
//! unjustified `unordered-iter` findings — and ZERO when re-scanned
//! under `agent/fixture.rs`, pinning the rule's scope.

use std::collections::HashMap;

pub struct TenantBooks {
    by_tenant: HashMap<u64, f64>,
    ordered: Vec<(u64, f64)>,
}

impl TenantBooks {
    pub fn report_badly(&self) -> Vec<String> {
        let mut lines = Vec::new();
        // plain violation: hash order reaches a serialized report
        for (t, v) in &self.by_tenant {
            lines.push(format!("{t}: {v}"));
        }
        lines
    }

    pub fn drain_badly(&mut self) -> f64 {
        // suppression WITHOUT a justification — still a finding
        // lint:allow(unordered-iter)
        let total: f64 = self.by_tenant.values().sum();
        total
    }

    pub fn walk_fine(&self) -> f64 {
        // a Vec walk is deterministic; must NOT fire
        self.ordered.iter().map(|(_, v)| v).sum()
    }
}

//! `rap lint` — a determinism & invariant static-analysis pass over
//! the crate's own source.
//!
//! The serving stack's contracts are source-level, not just
//! behavioral: simulated time must never read the host clock, report
//! and telemetry walks must never follow hash order, float selections
//! must use a total order, the serving/coordination hot path must not
//! panic, and all randomness must flow through `util::rng`. Each is
//! easy to hold in review and easy to lose in a refactor — so this
//! module scans the tree and enforces them mechanically (`rap lint`,
//! gated in CI at zero unjustified findings).
//!
//! The scanner is deliberately a light line/token pass, not a `syn`
//! parse (no new dependencies in the offline image): comments, string
//! and char literals are blanked column-for-column, `#[cfg(test)]`
//! regions are skipped by brace tracking, and rule tokens are matched
//! against what remains. That trades a sliver of precision for zero
//! dependencies and total transparency — every rule below documents
//! its over/under-approximation.
//!
//! Escape hatch: a finding on a line covered by
//! `// lint:allow(<rule>): <why>` is *justified* and does not gate.
//! The justification text is REQUIRED — an allow without one still
//! counts as unjustified (the whole point is an auditable reason at
//! the site). The directive covers its own line when trailing, or the
//! next code-bearing line when standing alone.
//!
//! A Python mirror of this scanner lives at
//! `.claude/skills/verify/lint_port.py` for toolchain-less
//! pre-verification. If you change a rule here, change it there too.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

/// One lint rule: its gate name and a one-line contract statement.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

/// The rule catalog (what `rap lint` enforces, in evaluation order).
pub const RULES: [RuleInfo; 5] = [
    RuleInfo {
        name: "wall-clock",
        summary: "host wall-clock (Instant::now / SystemTime) only in \
                  util::bench and the serve-report wall module",
    },
    RuleInfo {
        name: "unordered-iter",
        summary: "no iteration over hash-ordered containers in modules \
                  that serialize reports, emit telemetry, or pick \
                  victims/routes",
    },
    RuleInfo {
        name: "float-ordering",
        summary: "float sorts/selections must use total_cmp, never \
                  the partial order (NaN-dependent)",
    },
    RuleInfo {
        name: "hot-path-panic",
        summary: "no unwrap/expect/panic family in server/ and \
                  coordinator/ non-test code",
    },
    RuleInfo {
        name: "raw-rng",
        summary: "randomness only through util::rng in non-test code",
    },
];

const WALL_CLOCK_TOKENS: [&str; 2] = ["Instant::now", "SystemTime"];
const WALL_CLOCK_EXEMPT: [&str; 2] =
    ["util/bench.rs", "server/metrics.rs"];
const ITER_TOKENS: [&str; 10] = [
    ".iter()", ".iter_mut()", ".keys()", ".values()", ".values_mut()",
    ".drain(", ".into_iter()", ".into_keys()", ".into_values()",
    ".retain(",
];
const UNORDERED_SCOPE: [&str; 3] =
    ["server/", "coordinator/", "telemetry/"];
const PANIC_TOKENS: [&str; 6] = [
    ".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(",
    "unimplemented!(",
];
const PANIC_SCOPE: [&str; 2] = ["server/", "coordinator/"];
const RNG_TOKENS: [&str; 7] = [
    "rand::", "thread_rng", "from_entropy", "getrandom", "SeedableRng",
    "RandomState", "rand_core",
];
const RNG_EXEMPT: [&str; 1] = ["util/rng.rs"];

/// One scanner hit: where, which rule, and whether a justification
/// covers it.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    /// Path normalized to the crate-source-relative form the scopes
    /// use (`server/engine.rs`, …).
    pub file: String,
    /// 1-indexed.
    pub line: usize,
    pub message: String,
    /// The raw source line, trimmed.
    pub snippet: String,
    /// `Some` only for an allow directive WITH a justification text.
    pub justification: Option<String>,
}

impl Finding {
    pub fn is_justified(&self) -> bool {
        self.justification.is_some()
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Does `pat` (ASCII) start at char index `i` of `ch`?
fn at(ch: &[char], i: usize, pat: &str) -> bool {
    pat.chars()
        .enumerate()
        .all(|(k, p)| ch.get(i + k) == Some(&p))
}

/// First char index >= `from` where `pat` starts, if any.
fn find_from(ch: &[char], from: usize, pat: &str) -> Option<usize> {
    let plen = pat.chars().count();
    if plen == 0 {
        return Some(from);
    }
    (from..ch.len().saturating_sub(plen - 1).max(from))
        .find(|&i| at(ch, i, pat))
}

/// Blank comments and literal contents, preserving columns. `block`
/// carries nested block-comment depth across lines. Returns (code,
/// comment-text).
fn strip_line(line: &str, block: &mut usize) -> (String, String) {
    let ch: Vec<char> = line.chars().collect();
    let n = ch.len();
    let mut out = String::with_capacity(n);
    let mut comment = String::new();
    let mut i = 0usize;
    while i < n {
        let c = ch[i];
        if *block > 0 {
            if at(&ch, i, "*/") {
                *block -= 1;
                i += 2;
                out.push_str("  ");
                continue;
            }
            if at(&ch, i, "/*") {
                *block += 1;
                i += 2;
                out.push_str("  ");
                continue;
            }
            comment.push(c);
            out.push(' ');
            i += 1;
            continue;
        }
        if at(&ch, i, "//") {
            comment.extend(&ch[i..]);
            out.extend(std::iter::repeat(' ').take(n - i));
            break;
        }
        if at(&ch, i, "/*") {
            *block += 1;
            out.push_str("  ");
            i += 2;
            continue;
        }
        let raw_head = c == 'r'
            && i + 1 < n
            && (ch[i + 1] == '"' || ch[i + 1] == '#')
            && (i == 0 || !ident_char(ch[i - 1]));
        if c == '"' || raw_head {
            if c == 'r' {
                // raw string: r"..." or r#"..."# with any hash count
                let mut j = i + 1;
                let mut hashes = 0usize;
                while j < n && ch[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j >= n || ch[j] != '"' {
                    out.push(c);
                    i += 1;
                    continue;
                }
                let close: String = std::iter::once('"')
                    .chain(std::iter::repeat('#').take(hashes))
                    .collect();
                let end = find_from(&ch, j + 1, &close)
                    .map(|k| k + 1 + hashes)
                    .unwrap_or(n);
                out.extend(std::iter::repeat(' ').take(end - i));
                i = end;
                continue;
            }
            // plain string literal; blank its contents
            let mut j = i + 1;
            while j < n {
                if ch[j] == '\\' {
                    j += 2;
                    continue;
                }
                if ch[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let j = j.min(n);
            out.push('"');
            out.extend(
                std::iter::repeat(' ').take((j - i).saturating_sub(2)),
            );
            if j - i >= 2 {
                out.push('"');
            }
            i = j;
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime: '\\x' escapes, then 'c' forms;
            // anything else (a lifetime) passes through untouched
            if i + 1 < n && ch[i + 1] == '\\' {
                if let Some(j) = find_from(&ch, i + 2, "'") {
                    out.extend(std::iter::repeat(' ').take(j + 1 - i));
                    i = j + 1;
                    continue;
                }
            }
            if i + 2 < n && ch[i + 2] == '\'' {
                out.push_str("   ");
                i += 3;
                continue;
            }
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    (out, comment)
}

/// Every `lint:allow(<rule>)` directive in one comment, with its
/// justification text (the `: <why>` tail) when present.
fn parse_allow(comment: &str) -> Vec<(String, Option<String>)> {
    let mut out = Vec::new();
    let mut idx = 0usize;
    const HEAD: &str = "lint:allow(";
    while let Some(k) = comment[idx..].find(HEAD).map(|k| k + idx) {
        let open = k + HEAD.len();
        let Some(j) = comment[open..].find(')').map(|j| j + open)
        else {
            return out;
        };
        let rule = comment[open..j].trim().to_string();
        let mut just = None;
        if let Some(text) = comment[j + 1..].trim_start().strip_prefix(':')
        {
            let text = text.trim();
            if !text.is_empty() {
                just = Some(text.to_string());
            }
        }
        out.push((rule, just));
        idx = j + 1;
    }
    out
}

/// Byte offsets where `token` occurs in `code`. Tokens that begin with
/// an identifier char require a non-identifier char before the match
/// (so `MyInstant::now` does not fire); dot-led tokens attach to any
/// receiver by construction.
fn token_hits(code: &str, token: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let need_boundary =
        token.as_bytes().first().is_some_and(|&b| is_ident(b));
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(k) = code[start..].find(token).map(|k| k + start) {
        if !need_boundary || k == 0 || !is_ident(bytes[k - 1]) {
            hits.push(k);
        }
        start = k + 1;
    }
    hits
}

/// The identifier immediately left of byte position `pos`, or "".
fn ident_before(code: &str, pos: usize) -> &str {
    let bytes = code.as_bytes();
    let mut j = pos;
    while j > 0 && is_ident(bytes[j - 1]) {
        j -= 1;
    }
    &code[j..pos]
}

/// Names declared (or bound) as `HashMap`/`HashSet` anywhere in the
/// file: `name: HashMap<...>` fields/params and `name = HashSet::…`
/// bindings. File-global on purpose — a cheap over-approximation that
/// beats missing a renamed field.
fn hash_names(code_lines: &[String]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for code in code_lines {
        for marker in ["HashMap", "HashSet"] {
            for k in token_hits(code, marker) {
                let mut before = code[..k].trim_end();
                if let Some(p) = before.strip_suffix("std::collections::")
                {
                    before = p.trim_end();
                }
                if let Some(p) = before.strip_suffix("collections::") {
                    before = p.trim_end();
                }
                loop {
                    if let Some(p) = before.strip_suffix('&') {
                        before = p.trim_end();
                    } else if let Some(p) = before.strip_suffix("mut") {
                        before = p.trim_end();
                    } else {
                        break;
                    }
                }
                let tail = if let Some(p) = before.strip_suffix(':') {
                    p
                } else if let Some(p) = before.strip_suffix('=') {
                    p
                } else {
                    continue;
                };
                let tail = tail.trim_end();
                let name = ident_before(tail, tail.len());
                if !name.is_empty()
                    && !name.starts_with(|c: char| c.is_ascii_digit())
                {
                    names.insert(name.to_string());
                }
            }
        }
    }
    names
}

/// The bare identifier a `for … in <expr>` loop walks, if the
/// expression IS a bare identifier (after `&`/`mut`/`self.`).
fn for_loop_target(code: &str) -> Option<String> {
    let k = code.find("for ")?;
    let j = code[k..].find(" in ").map(|j| j + k)?;
    let mut expr = &code[j + 4..];
    if let Some(b) = expr.find('{') {
        expr = &expr[..b];
    }
    let mut expr = expr.trim();
    while let Some(p) = expr.strip_prefix('&') {
        expr = p.trim_start();
    }
    if let Some(p) = expr.strip_prefix("mut ") {
        expr = p.trim_start();
    }
    if let Some(p) = expr.strip_prefix("self.") {
        expr = p;
    }
    if !expr.is_empty() && expr.bytes().all(is_ident) {
        Some(expr.to_string())
    } else {
        None
    }
}

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

fn braces(code: &str) -> i64 {
    code.bytes().filter(|&b| b == b'{').count() as i64
        - code.bytes().filter(|&b| b == b'}').count() as i64
}

/// Scan one file's source. `rel` is normalized to the text after the
/// last `src/` so scopes match however the path was produced.
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    let rel = match rel.rfind("src/") {
        Some(k) => rel[k + 4..].to_string(),
        None => rel,
    };

    let raw_lines: Vec<&str> = src.split('\n').collect();
    let nlines = raw_lines.len();
    let mut block = 0usize;
    let mut code_lines: Vec<String> = Vec::with_capacity(nlines);
    let mut comments: Vec<String> = Vec::with_capacity(nlines);
    for line in &raw_lines {
        let (code, comment) = strip_line(line, &mut block);
        code_lines.push(code);
        comments.push(comment);
    }

    // Test-region marking: an armed `#[cfg(test)]` attaches to the
    // next `mod`/`fn` item and the braced region it opens.
    let mut is_test = vec![false; nlines];
    let whole_file_test = rel.starts_with("tests/");
    let mut arming = false;
    let mut depth: i64 = 0;
    let mut region = false;
    for (idx, code) in code_lines.iter().enumerate() {
        if region {
            is_test[idx] = true;
            depth += braces(code);
            if depth <= 0 {
                region = false;
            }
            continue;
        }
        if code.contains("#[cfg(test)]") {
            arming = true;
            is_test[idx] = true;
            continue;
        }
        if arming {
            is_test[idx] = true;
            if (code.contains("mod ") || code.contains("fn "))
                && code.contains('{')
            {
                depth = braces(code);
                region = depth > 0;
                if !region {
                    arming = false;
                }
            }
        }
    }
    if whole_file_test {
        is_test.iter_mut().for_each(|t| *t = true);
    }

    // Allow directives: trailing covers its own line; standalone
    // comment lines accumulate onto the next code-bearing line.
    let mut allows: Vec<Vec<(String, Option<String>)>> =
        vec![Vec::new(); nlines];
    let mut pending: Vec<(String, Option<String>)> = Vec::new();
    for idx in 0..nlines {
        let own = parse_allow(&comments[idx]);
        if code_lines[idx].trim().is_empty() {
            pending.extend(own);
        } else {
            allows[idx] = std::mem::take(&mut pending);
            allows[idx].extend(own);
        }
    }

    let names = hash_names(&code_lines);

    let mut findings: Vec<Finding> = Vec::new();
    let mut emit = |rule: &'static str, idx: usize, message: &str| {
        let mut just = None;
        let mut suppressed = false;
        for (r, j) in &allows[idx] {
            if r == rule {
                suppressed = true;
                if j.is_some() {
                    just = j.clone();
                }
            }
        }
        let message = if suppressed && just.is_none() {
            format!(
                "{message} (suppression present but lacks a \
                 justification — `lint:allow({rule}): <why>`)"
            )
        } else {
            message.to_string()
        };
        findings.push(Finding {
            rule,
            file: rel.clone(),
            line: idx + 1,
            message,
            snippet: raw_lines[idx].trim().to_string(),
            justification: just,
        });
    };

    for (idx, code) in code_lines.iter().enumerate() {
        if is_test[idx] {
            continue;
        }
        // wall-clock: reading the host clock anywhere but the metered
        // exemptions silently couples simulated behavior to the host.
        if !WALL_CLOCK_EXEMPT.contains(&rel.as_str())
            && WALL_CLOCK_TOKENS
                .iter()
                .any(|t| !token_hits(code, t).is_empty())
        {
            emit(
                "wall-clock",
                idx,
                "host wall-clock outside util::bench / \
                 ServeReport::wall",
            );
        }
        // unordered-iter: walking a hash-ordered container where the
        // result reaches a report, telemetry, or a serving decision.
        if in_scope(&rel, &UNORDERED_SCOPE) && !names.is_empty() {
            let mut hit = ITER_TOKENS.iter().any(|t| {
                token_hits(code, t)
                    .into_iter()
                    .any(|k| names.contains(ident_before(code, k)))
            });
            if let Some(tgt) = for_loop_target(code) {
                hit = hit || names.contains(&tgt);
            }
            if hit {
                emit(
                    "unordered-iter",
                    idx,
                    "iteration over a hash-ordered container in a \
                     report/telemetry/decision module",
                );
            }
        }
        // float-ordering: partial_cmp makes NaN ordering incidental.
        if !token_hits(code, "partial_cmp").is_empty() {
            emit(
                "float-ordering",
                idx,
                "partial_cmp is not a total order over floats; use \
                 total_cmp",
            );
        }
        // hot-path-panic: a panic in serving/coordination code takes
        // the whole replica down with the one bad sequence.
        if in_scope(&rel, &PANIC_SCOPE)
            && PANIC_TOKENS
                .iter()
                .any(|t| !token_hits(code, t).is_empty())
        {
            emit(
                "hot-path-panic",
                idx,
                "panic path in serving/coordination code",
            );
        }
        // raw-rng: any entropy source but the seeded util::rng breaks
        // run-to-run determinism.
        if !RNG_EXEMPT.contains(&rel.as_str())
            && RNG_TOKENS
                .iter()
                .any(|t| !token_hits(code, t).is_empty())
        {
            emit(
                "raw-rng",
                idx,
                "randomness outside util::rng breaks seeded \
                 determinism",
            );
        }
    }
    findings
}

/// Recursively gather `.rs` files under `dir`, skipping build output,
/// vendored crates, and the lint fixtures (they are dirty on purpose).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    let dir_name =
        dir.file_name().and_then(|s| s.to_str()).unwrap_or("");
    for p in entries {
        let name =
            p.file_name().and_then(|s| s.to_str()).unwrap_or("");
        if p.is_dir() {
            if name == "target"
                || name == "vendor"
                || (name == "fixtures" && dir_name == "analysis")
            {
                continue;
            }
            collect_rs(&p, out)?;
        } else if name.ends_with(".rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan a file, or every `.rs` file under a directory. Findings come
/// back sorted by (file, line, rule).
pub fn scan_path(path: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    if path.is_dir() {
        collect_rs(path, &mut files)?;
    } else {
        files.push(path.to_path_buf());
    }
    files.sort();
    let mut out = Vec::new();
    for p in &files {
        let src = std::fs::read_to_string(p)
            .map_err(|e| anyhow!("reading {}: {e}", p.display()))?;
        out.extend(scan_source(&p.to_string_lossy(), &src));
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(out)
}

/// The crate's own `src/` tree — what `rap lint` scans by default and
/// what the self-scan test holds clean.
pub fn default_src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_all(src: &str) -> Vec<(String, String)> {
        let mut block = 0;
        src.split('\n')
            .map(|l| strip_line(l, &mut block))
            .collect()
    }

    #[test]
    fn strings_comments_and_chars_are_blanked() {
        let out = strip_all(
            "let a = \"Instant::now\"; // Instant::now here\n\
             let b = 'x'; let lt: &'static str = \"\";\n\
             /* SystemTime\n\
             still SystemTime */ let c = 1;",
        );
        assert!(!out[0].0.contains("Instant"));
        assert!(out[0].1.contains("Instant::now here"));
        assert!(out[1].0.contains("'static"), "lifetime survives");
        assert!(!out[1].0.contains("'x'"), "char literal blanked");
        assert!(!out[2].0.contains("SystemTime"));
        assert!(out[3].0.contains("let c = 1"));
    }

    #[test]
    fn raw_strings_blank_to_the_matching_close() {
        let out = strip_all("let s = r#\"unwrap() \"quoted\"\"#; x();");
        assert!(!out[0].0.contains("unwrap"));
        assert!(out[0].0.contains("x();"));
    }

    #[test]
    fn columns_survive_stripping() {
        let (code, _) =
            strip_line("let t = \"pad\"; t.partial_cmp(&u);", &mut 0);
        let k = code.find("partial_cmp").unwrap();
        assert_eq!(ident_before(&code[..k + 11], k), "");
        assert_eq!(
            "let t = \"pad\"; t.partial_cmp(&u);".len(),
            code.len()
        );
    }

    #[test]
    fn allow_parsing_requires_text_for_justification() {
        let a = parse_allow("// lint:allow(wall-clock): bench timing");
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].0, "wall-clock");
        assert_eq!(a[0].1.as_deref(), Some("bench timing"));
        let b = parse_allow("// lint:allow(raw-rng)");
        assert_eq!(b[0].1, None);
        let c = parse_allow("// lint:allow(raw-rng):   ");
        assert_eq!(c[0].1, None, "blank justification is none");
    }

    #[test]
    fn token_boundaries_respect_identifiers() {
        assert!(token_hits("MyInstant::now()", "Instant::now")
            .is_empty());
        assert_eq!(
            token_hits("Instant::now()", "Instant::now").len(),
            1
        );
        // dot-led tokens attach to any receiver
        assert_eq!(token_hits("x.unwrap()", ".unwrap()").len(), 1);
    }

    #[test]
    fn hash_names_sees_fields_params_and_bindings() {
        let lines: Vec<String> = [
            "    seqs: HashMap<u64, SeqCache>,",
            "    let mut live = HashSet::new();",
            "    ordered: BTreeMap<u64, u64>,",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let names = hash_names(&lines);
        assert!(names.contains("seqs"));
        assert!(names.contains("live"));
        assert!(!names.contains("ordered"));
    }

    #[test]
    fn for_loop_targets_extract_bare_idents() {
        assert_eq!(
            for_loop_target("for s in self.seqs {").as_deref(),
            Some("seqs")
        );
        assert_eq!(
            for_loop_target("for x in &mut table {").as_deref(),
            Some("table")
        );
        assert_eq!(for_loop_target("for x in 0..n {"), None);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { y.unwrap(); }\n\
                   }\n";
        let fs = scan_source("server/demo.rs", src);
        let panics: Vec<_> = fs
            .iter()
            .filter(|f| f.rule == "hot-path-panic")
            .collect();
        assert_eq!(panics.len(), 1);
        assert_eq!(panics[0].line, 1);
    }
}

//! # RAP — Runtime-Adaptive Pruning for LLM Inference
//!
//! Rust coordinator (L3) of the three-layer reproduction of *"Runtime
//! Adaptive Pruning for LLM Inference"*: a reinforcement-learning
//! controller that, per request mix and memory budget, decides which
//! transformer MHA/FFN blocks to prune so that parameters + KV cache fit
//! the instantaneous budget with minimal perplexity damage.
//!
//! Layer map (see DESIGN.md):
//!   * L1 (Pallas kernels) + L2 (JAX model) live in `python/compile/` and
//!     are AOT-lowered to HLO text under `artifacts/` at build time;
//!   * L3 (this crate) loads those artifacts via PJRT (`runtime`), owns
//!     the paper's contribution (`gsi`, `agent`, `pruning`), the
//!     serving stack (`server`, `workload`) behind the typed
//!     tenant/SLO-aware request ingress (`api`), the multi-replica
//!     fleet coordinator with memory-aware routing (`coordinator`), the
//!     flight-recorder observability layer (`telemetry`), and
//!     regenerates every table and figure (`experiments`). The
//!     source-level determinism contracts all of that relies on are
//!     enforced mechanically by the in-tree lint pass (`analysis`,
//!     surfaced as `rap lint`).

pub mod agent;
pub mod analysis;
pub mod api;
pub mod coordinator;
pub mod corpus;
pub mod evalharness;
pub mod experiments;
pub mod gsi;
pub mod mask;
pub mod memory;
pub mod model_meta;
pub mod pruning;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts location: `$RAP_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("RAP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

//! Corpus access: the synthetic Markov+copy language (WikiText2/PTB/Alpaca
//! substitutes) produced by `python/compile/corpus.py`.
//!
//! Rust reads the exported chain matrix so it can (a) evaluate perplexity
//! on the pre-sampled splits and (b) *generate* fresh data deterministically
//! — MCQ endings for the commonsense-sim suite, prompts for the serving
//! workload — with exactly the distribution the model was trained on.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// The generative process: sparse Markov chain + copy rule.
#[derive(Clone)]
pub struct MarkovChain {
    pub vocab: usize,
    /// Row-major [V, V] row-stochastic transitions.
    pub trans: Vec<f32>,
    /// Cumulative rows for O(log V) inverse-CDF sampling.
    cdf: Vec<f32>,
    pub copy_p: f64,
    pub copy_lag: usize,
}

impl MarkovChain {
    pub fn new(vocab: usize, trans: Vec<f32>, copy_p: f64, copy_lag: usize)
               -> Result<MarkovChain> {
        if trans.len() != vocab * vocab {
            bail!("chain matrix has {} entries, wanted {}", trans.len(),
                  vocab * vocab);
        }
        let mut cdf = trans.clone();
        for row in cdf.chunks_exact_mut(vocab) {
            let mut acc = 0.0f32;
            for x in row.iter_mut() {
                acc += *x;
                *x = acc;
            }
        }
        Ok(MarkovChain { vocab, trans, cdf, copy_p, copy_lag })
    }

    pub fn row(&self, tok: usize) -> &[f32] {
        &self.trans[tok * self.vocab..(tok + 1) * self.vocab]
    }

    /// Sample the next token given the history so far.
    pub fn next_token(&self, history: &[u16], rng: &mut Rng) -> u16 {
        let cur = *history.last().expect("empty history") as usize;
        if history.len() >= self.copy_lag && rng.chance(self.copy_p) {
            return history[history.len() - self.copy_lag];
        }
        let row = &self.cdf[cur * self.vocab..(cur + 1) * self.vocab];
        let u = rng.f32();
        // binary search the cdf row
        match row.binary_search_by(|x| x.total_cmp(&u)) {
            Ok(i) | Err(i) => i.min(self.vocab - 1) as u16,
        }
    }

    /// Sample a fresh sequence of length `n`.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = Vec::with_capacity(n);
        out.push(rng.below(self.vocab) as u16);
        while out.len() < n {
            let t = self.next_token(&out, rng);
            out.push(t);
        }
        out
    }

    /// True predictive distribution p(next | context) — the oracle used
    /// to construct MCQ correct answers and by sanity tests.
    pub fn next_dist(&self, context: &[u16]) -> Vec<f64> {
        let cur = *context.last().expect("empty context") as usize;
        let has_copy = context.len() >= self.copy_lag;
        let chain_w = if has_copy { 1.0 - self.copy_p } else { 1.0 };
        let mut dist: Vec<f64> = self
            .row(cur)
            .iter()
            .map(|&p| p as f64 * chain_w)
            .collect();
        if has_copy {
            dist[context[context.len() - self.copy_lag] as usize] +=
                self.copy_p;
        }
        dist
    }
}

/// The full corpus: chain(s) + pre-sampled token splits.
pub struct Corpus {
    pub chain: MarkovChain,
    pub chain_ptb: MarkovChain,
    pub train: Vec<u16>,
    pub wiki: Vec<u16>,
    pub ptb: Vec<u16>,
    pub alpaca: Vec<u16>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Wiki,
    Ptb,
    Alpaca,
}

impl Split {
    pub fn name(&self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Wiki => "wikitext2-sim",
            Split::Ptb => "ptb-sim",
            Split::Alpaca => "alpaca-sim",
        }
    }
}

impl Corpus {
    /// Regenerate the corpus in-process — the artifact-free fallback
    /// behind `experiments::common::setup`. Same Markov+copy family as
    /// python/compile/corpus.py: ~20 Zipf-weighted successors per
    /// token, copy probability 0.35 at lag 4, and a 0.35-uniform-noise
    /// interpolated chain for the shifted ptb-sim split. Deterministic
    /// per (vocab, seed); splits are sized for every in-tree consumer
    /// (≥ 4096 tokens each).
    pub fn synthetic(vocab: usize, seed: u64) -> Corpus {
        const COPY_P: f64 = 0.35;
        const COPY_LAG: usize = 4;
        const PTB_NOISE: f32 = 0.35;
        let mut rng = Rng::new(seed ^ 0xC0_52_05);
        let chain = MarkovChain::new(
            vocab, synthetic_chain(vocab, &mut rng), COPY_P, COPY_LAG)
            .expect("synthetic chain is square by construction");
        let uni = 1.0 / vocab as f32;
        let shifted: Vec<f32> = chain
            .trans
            .iter()
            .map(|&p| (1.0 - PTB_NOISE) * p + PTB_NOISE * uni)
            .collect();
        let chain_ptb =
            MarkovChain::new(vocab, shifted, COPY_P, COPY_LAG)
                .expect("shifted chain is square by construction");
        let sample = |c: &MarkovChain, n: usize, salt: u64| {
            let mut r = Rng::new(seed ^ salt);
            c.sample(n, &mut r)
        };
        let train = sample(&chain, 8192, 0x7A1);
        let wiki = sample(&chain, 4096, 0x7A2);
        let ptb = sample(&chain_ptb, 4096, 0x7A3);
        let alpaca = sample(&chain, 4096, 0x7A4);
        Corpus { chain, chain_ptb, train, wiki, ptb, alpaca }
    }

    pub fn load(corpus_dir: &Path) -> Result<Corpus> {
        let meta = Json::parse_file(&corpus_dir.join("meta.json"))?;
        let vocab = meta.get("vocab")?.usize()?;
        let copy_p = meta.get("copy_p")?.num()?;
        let copy_lag = meta.get("copy_lag")?.usize()?;
        let chain = MarkovChain::new(
            vocab, read_f32(&corpus_dir.join("chain.bin"))?, copy_p,
            copy_lag)?;
        let chain_ptb = MarkovChain::new(
            vocab, read_f32(&corpus_dir.join("chain_ptb.bin"))?, copy_p,
            copy_lag)?;
        Ok(Corpus {
            chain,
            chain_ptb,
            train: read_u16(&corpus_dir.join("train.bin"))?,
            wiki: read_u16(&corpus_dir.join("wiki.bin"))?,
            ptb: read_u16(&corpus_dir.join("ptb.bin"))?,
            alpaca: read_u16(&corpus_dir.join("alpaca.bin"))?,
        })
    }

    pub fn split(&self, s: Split) -> &[u16] {
        match s {
            Split::Train => &self.train,
            Split::Wiki => &self.wiki,
            Split::Ptb => &self.ptb,
            Split::Alpaca => &self.alpaca,
        }
    }

    /// Deterministic non-overlapping [batch, seqlen] windows from a split,
    /// as i32 (the score entry's token dtype). `n_batches` batches are
    /// taken starting at `offset` windows in.
    pub fn batches(&self, s: Split, batch: usize, seqlen: usize,
                   n_batches: usize, offset: usize) -> Result<Vec<Vec<i32>>> {
        let toks = self.split(s);
        let need = (offset + n_batches * batch) * seqlen;
        if need > toks.len() {
            bail!("split {} too small: need {} tokens, have {}", s.name(),
                  need, toks.len());
        }
        let mut out = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut flat = Vec::with_capacity(batch * seqlen);
            for i in 0..batch {
                let start = (offset + b * batch + i) * seqlen;
                flat.extend(
                    toks[start..start + seqlen].iter().map(|&t| t as i32));
            }
            out.push(flat);
        }
        Ok(out)
    }
}

/// Row-stochastic [V, V] transition matrix with ~20 preferred
/// successors per token, Zipf-weighted (mirrors corpus.py's
/// `build_chain`, modulo the PRNG).
fn synthetic_chain(vocab: usize, rng: &mut Rng) -> Vec<f32> {
    let branch = 20.min(vocab);
    let mut trans = vec![0.0f32; vocab * vocab];
    for t in 0..vocab {
        let succ = rng.choose_k(vocab, branch);
        let row = &mut trans[t * vocab..(t + 1) * vocab];
        for x in row.iter_mut() {
            *x = 1e-4;
        }
        for (k, &s) in succ.iter().enumerate() {
            row[s] += 1.0 / (k + 1) as f32;
        }
        let sum: f32 = row.iter().sum();
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    trans
}

fn read_u16(path: &Path) -> Result<Vec<u16>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 2 != 0 {
        bail!("{}: odd byte count", path.display());
    }
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: byte count not multiple of 4", path.display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 4-token toy chain: 0→1, 1→2, 2→3, 3→0 (deterministic).
    fn toy(copy_p: f64) -> MarkovChain {
        let v = 4;
        let mut trans = vec![0.0f32; v * v];
        for t in 0..v {
            trans[t * v + (t + 1) % v] = 1.0;
        }
        MarkovChain::new(v, trans, copy_p, 2).unwrap()
    }

    #[test]
    fn deterministic_chain_cycles() {
        let c = toy(0.0);
        let mut rng = Rng::new(1);
        let seq = c.sample(9, &mut rng);
        for w in seq.windows(2) {
            assert_eq!(w[1], (w[0] + 1) % 4);
        }
    }

    #[test]
    fn rows_are_stochastic_after_cdf() {
        let c = toy(0.3);
        for t in 0..4 {
            let s: f32 = c.row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn copy_rule_fires() {
        let c = toy(1.0); // always copy from lag 2
        let mut rng = Rng::new(2);
        let mut seq = vec![3u16, 1u16];
        for _ in 0..6 {
            let t = c.next_token(&seq, &mut rng);
            seq.push(t);
        }
        // with lag 2 and always-copy: sequence alternates 3,1,3,1,...
        for (i, &t) in seq.iter().enumerate() {
            assert_eq!(t, if i % 2 == 0 { 3 } else { 1 });
        }
    }

    #[test]
    fn next_dist_sums_to_one_and_matches_copy() {
        let c = toy(0.4);
        let ctx = vec![0u16, 1u16, 2u16];
        let d = c.next_dist(&ctx);
        let sum: f64 = d.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // copy target is ctx[len-2] = 1; chain target from 2 is 3.
        assert!((d[1] - 0.4).abs() < 1e-6);
        assert!((d[3] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn sampled_frequencies_match_dist() {
        let c = toy(0.25);
        let mut rng = Rng::new(3);
        let ctx = vec![2u16, 0u16]; // copy target 2, chain target 1
        let mut counts = [0usize; 4];
        let n = 20_000;
        for _ in 0..n {
            counts[c.next_token(&ctx, &mut rng) as usize] += 1;
        }
        let f2 = counts[2] as f64 / n as f64;
        let f1 = counts[1] as f64 / n as f64;
        assert!((f2 - 0.25).abs() < 0.02, "copy freq {f2}");
        assert!((f1 - 0.75).abs() < 0.02, "chain freq {f1}");
    }

    #[test]
    fn shape_validation() {
        assert!(MarkovChain::new(4, vec![0.0; 15], 0.1, 2).is_err());
    }

    #[test]
    fn synthetic_corpus_is_usable_and_deterministic() {
        let c = Corpus::synthetic(64, 7);
        // rows stochastic
        for t in 0..64 {
            let s: f32 = c.chain.row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {t} sums to {s}");
            let s: f32 = c.chain_ptb.row(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "ptb row {t} sums to {s}");
        }
        // splits big enough for the perplexity harness's largest ask
        let batches = c.batches(Split::Wiki, 4, 128, 6, 0).unwrap();
        assert_eq!(batches.len(), 6);
        assert!(batches[0].iter().all(|&t| (t as usize) < 64));
        // deterministic per seed, different across seeds
        let c2 = Corpus::synthetic(64, 7);
        assert_eq!(c.wiki, c2.wiki);
        assert_eq!(c.chain.trans, c2.chain.trans);
        let c3 = Corpus::synthetic(64, 8);
        assert_ne!(c.wiki, c3.wiki);
        // the shifted split really is shifted
        assert_ne!(c.chain.trans, c.chain_ptb.trans);
    }
}

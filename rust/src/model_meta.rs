//! Model architecture metadata, loaded from `artifacts/<model>/manifest.json`.
//!
//! Mirrors `python/compile/model.py::ModelConfig` plus the parameter table
//! (name/shape/offset into `weights.bin`) and the entry-point descriptors
//! (input/output shapes per compiled HLO). Everything downstream — the
//! memory model, the mask arithmetic, the runtime literal construction —
//! is derived from this single source of truth.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of an entry input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

/// One tensor in `weights.bin`.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// One input or output of a compiled entry point.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled HLO entry point (e.g. `score_b4_t128`).
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The architecture constants (paper notation: N layers, each with one MHA
/// and one FFN block → 2N prunable blocks).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub params: Vec<ParamSpec>,
    pub entries: Vec<EntrySpec>,
    pub dir: PathBuf,
}

/// Identifier of a prunable transformer block. The paper's action space is
/// exactly these 2N blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BlockId {
    Mha(usize),
    Ffn(usize),
}

impl BlockId {
    /// Flat index in [0, 2N): MHA blocks first, then FFN blocks.
    pub fn index(&self, n_layers: usize) -> usize {
        match *self {
            BlockId::Mha(l) => l,
            BlockId::Ffn(l) => n_layers + l,
        }
    }

    pub fn from_index(i: usize, n_layers: usize) -> BlockId {
        if i < n_layers {
            BlockId::Mha(i)
        } else {
            BlockId::Ffn(i - n_layers)
        }
    }

    pub fn layer(&self) -> usize {
        match *self {
            BlockId::Mha(l) | BlockId::Ffn(l) => l,
        }
    }

    pub fn is_mha(&self) -> bool {
        matches!(self, BlockId::Mha(_))
    }
}

impl std::fmt::Display for BlockId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BlockId::Mha(l) => write!(f, "MHA{l}"),
            BlockId::Ffn(l) => write!(f, "FFN{l}"),
        }
    }
}

impl ModelMeta {
    /// Load from `artifacts/<model>/manifest.json`.
    pub fn load(model_dir: &Path) -> Result<ModelMeta> {
        let manifest = Json::parse_file(&model_dir.join("manifest.json"))
            .context("loading manifest")?;
        let m = manifest.get("model")?;
        let params = manifest
            .get("params")?
            .arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                    offset: p.get("offset")?.usize()?,
                    nbytes: p.get("nbytes")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entries = Vec::new();
        for (name, e) in manifest.get("entries")?.obj()? {
            let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)?
                    .arr()?
                    .iter()
                    .map(|t| {
                        Ok(TensorSpec {
                            name: t.get("name")?.str()?.to_string(),
                            shape: t.get("shape")?.usize_vec()?,
                            dtype: DType::parse(t.get("dtype")?.str()?)?,
                        })
                    })
                    .collect()
            };
            entries.push(EntrySpec {
                name: name.clone(),
                file: e.get("file")?.str()?.to_string(),
                inputs: tensors("inputs")?,
                outputs: tensors("outputs")?,
            });
        }
        Ok(ModelMeta {
            name: m.get("name")?.str()?.to_string(),
            vocab: m.get("vocab")?.usize()?,
            d_model: m.get("d_model")?.usize()?,
            n_layers: m.get("n_layers")?.usize()?,
            n_heads: m.get("n_heads")?.usize()?,
            n_kv_heads: m.get("n_kv_heads")?.usize()?,
            d_ff: m.get("d_ff")?.usize()?,
            max_seq: m.get("max_seq")?.usize()?,
            params,
            entries,
            dir: model_dir.to_path_buf(),
        })
    }

    /// Synthetic metadata for unit tests and analytic sweeps (no
    /// artifacts needed).
    pub fn synthetic(name: &str, n_layers: usize, d_model: usize,
                     n_heads: usize, n_kv_heads: usize, d_ff: usize,
                     vocab: usize, max_seq: usize) -> ModelMeta {
        ModelMeta {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            max_seq,
            params: Vec::new(),
            entries: Vec::new(),
            dir: PathBuf::new(),
        }
    }

    /// Llama2-7B-shaped metadata — used by the analytic memory-model
    /// figures (Fig 3) to reproduce the paper's own numbers.
    pub fn llama2_7b() -> ModelMeta {
        ModelMeta::synthetic("llama2-7b", 32, 4096, 32, 32, 11008, 32000,
                             4096)
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Total prunable blocks (paper: 2N).
    pub fn n_blocks(&self) -> usize {
        2 * self.n_layers
    }

    pub fn all_blocks(&self) -> Vec<BlockId> {
        (0..self.n_blocks())
            .map(|i| BlockId::from_index(i, self.n_layers))
            .collect()
    }

    /// Parameters in one full MHA block (wq + wk + wv + wo + norm).
    pub fn mha_block_params(&self) -> usize {
        let d = self.d_model;
        let qo = d * self.n_heads * self.head_dim() * 2;
        let kv = d * self.n_kv_heads * self.head_dim() * 2;
        qo + kv + d
    }

    /// Parameters in one full FFN block (w_gate + w_up + w_down + norm).
    pub fn ffn_block_params(&self) -> usize {
        3 * self.d_model * self.d_ff + self.d_model
    }

    /// Parameters outside any prunable block (embedding + final norm).
    pub fn base_params(&self) -> usize {
        self.vocab * self.d_model + self.d_model
    }

    pub fn total_params(&self) -> usize {
        self.base_params()
            + self.n_layers
                * (self.mha_block_params() + self.ffn_block_params())
    }

    /// Per-query-head parameters (wq + wo slices).
    pub fn per_head_params(&self) -> usize {
        2 * self.d_model * self.head_dim()
    }

    /// Per-kv-group parameters (wk + wv slices, shared by `group_size`
    /// query heads).
    pub fn per_kv_group_params(&self) -> usize {
        2 * self.d_model * self.head_dim()
    }

    /// Per-FFN-channel parameters (one column of w_gate/w_up, one row of
    /// w_down).
    pub fn per_ffn_channel_params(&self) -> usize {
        3 * self.d_model
    }

    /// KV-cache bytes for ONE token in ONE layer with `kv_heads` active
    /// kv heads (×2 for keys and values; f32 storage).
    pub fn kv_bytes_per_token_layer(&self, kv_heads: usize) -> usize {
        2 * kv_heads * self.head_dim() * BYTES_PER_SCALAR
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("entry '{name}' not in manifest \
                 (available: {:?})",
                self.entries.iter().map(|e| &e.name).collect::<Vec<_>>()))
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }
}

/// f32 everywhere in this build (the paper uses bf16=2; the *ratios* that
/// drive every result are byte-size independent).
pub const BYTES_PER_SCALAR: usize = 4;

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        // rap-small shape
        ModelMeta::synthetic("t", 12, 256, 8, 8, 1024, 512, 256)
    }

    #[test]
    fn block_indexing_roundtrip() {
        let m = meta();
        for i in 0..m.n_blocks() {
            let b = BlockId::from_index(i, m.n_layers);
            assert_eq!(b.index(m.n_layers), i);
        }
        assert_eq!(BlockId::Mha(3).layer(), 3);
        assert!(BlockId::Mha(0).is_mha());
        assert!(!BlockId::Ffn(0).is_mha());
    }

    #[test]
    fn param_counts_match_hand_calc() {
        let m = meta();
        // wq/wo: 256*256 each; wk/wv: 256*256 each (MHA); + norm 256
        assert_eq!(m.mha_block_params(), 4 * 256 * 256 + 256);
        assert_eq!(m.ffn_block_params(), 3 * 256 * 1024 + 256);
        assert_eq!(m.base_params(), 512 * 256 + 256);
        let total = m.total_params();
        assert!(total > 12_000_000 && total < 14_000_000, "{total}");
    }

    #[test]
    fn gqa_param_counts() {
        let m = ModelMeta::synthetic("q", 8, 256, 8, 2, 768, 512, 256);
        // wq/wo: 256*256 each; wk/wv: 256*64 each
        assert_eq!(m.mha_block_params(),
                   2 * 256 * 256 + 2 * 256 * 64 + 256);
        assert_eq!(m.group_size(), 4);
    }

    #[test]
    fn llama2_7b_is_7b() {
        let m = ModelMeta::llama2_7b();
        let total = m.total_params();
        assert!(total > 6_400_000_000 && total < 6_900_000_000, "{total}");
        // paper §2.1: FFN ≈ 2× attention parameters
        let r = m.ffn_block_params() as f64 / m.mha_block_params() as f64;
        assert!(r > 1.8 && r < 2.2, "ffn/mha ratio {r}");
    }

    #[test]
    fn kv_bytes_match_paper_formula() {
        let m = ModelMeta::llama2_7b();
        // paper: 2 * n_heads * d_head per token per layer (scalars)
        let per = m.kv_bytes_per_token_layer(m.n_kv_heads);
        assert_eq!(per, 2 * 32 * 128 * BYTES_PER_SCALAR);
    }
}

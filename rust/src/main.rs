//! `rap` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   experiment <id>   regenerate a paper table/figure
//!                     (fig2|fig3|fig4|fig5|fig6|fig9|fig10|fig11|
//!                      table1|table2|table3|table4|fleet|all)
//!   train-agent       train + save the DQN controller for a model
//!   serve             replay a synthetic trace through the serving
//!                     engine; --tenants N spreads it across N synthetic
//!                     tenants and --slo S attaches an S-second
//!                     completion deadline to every request (per-tenant
//!                     deadline hit-rates in the report)
//!   serve-fleet       replay a trace across N heterogeneous replicas
//!                     behind a pluggable router; emits a JSON
//!                     FleetReport. --autoscale spawns/retires replicas
//!                     from load (--warmup charges a warm-up cost before
//!                     a spawn serves), --migrate moves in-flight
//!                     sequences off pressured replicas instead of
//!                     evicting them, --router tenant-fair + --tenants N
//!                     caps each tenant's in-flight KV bytes at an
//!                     equal share, --fault-plan <seed> injects a seeded
//!                     failure schedule and --checkpoint <secs> turns on
//!                     periodic KV checkpointing for crash recovery
//!   gsi               run Greedy Sequential Importance on a model
//!   trace             summarize/validate a flight-recorder trace file
//!                     written by --trace (serve / serve-fleet /
//!                     experiment fleet --chaos)
//!   bench             fleet serving throughput with telemetry off vs
//!                     on, written to BENCH_fleet.json
//!   lint              determinism & invariant static analysis over
//!                     the crate's own source (wall-clock, hash-order
//!                     iteration, partial_cmp, hot-path panics, raw
//!                     rng); nonzero exit on any unjustified finding
//!
//! Common flags: --model <name> --seed <n> --quick

use anyhow::{bail, Context, Result};
use rap::api;
use rap::coordinator::fleet::{default_fleet_trace,
                              default_sim_fleet_with,
                              equal_share_quotas, AutoscaleConfig,
                              FleetConfig};
use rap::coordinator::router::RouterPolicy;
use rap::experiments::{bench, figures, fleet, rl, tables};
use rap::runtime::FaultPlan;
use rap::telemetry::trace;
use rap::util::cli::Args;
use rap::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let model = args.str_or("model", "rap-small");
    let seed = args.u64_or("seed", 42)?;
    let quick = args.bool("quick");
    match cmd {
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            run_experiment(id, &model, seed, quick, &args)
        }
        "train-agent" => {
            let episodes = args.usize_or(
                "episodes", if quick { 40 } else { 120 })?;
            rl::train_agent(&model, episodes, seed)?;
            Ok(())
        }
        "gsi" => {
            let n = args.usize_or("remove", 8)?;
            figures::fig6(&model, n)
        }
        "serve" => {
            let secs = args.f64_or("secs", 120.0)?;
            let tenants = args.usize_or("tenants", 1)?;
            let slo = match args.get("slo") {
                Some(v) => Some(v.parse::<f64>()?),
                None => None,
            };
            figures::fig5_with(seed, secs, tenants, slo,
                               args.get("trace").map(|s| s.as_str()))
        }
        "serve-fleet" => serve_fleet(seed, &args),
        "trace" => run_trace_tool(&args),
        "lint" => run_lint(&args),
        "bench" => {
            let what = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("fleet");
            if what != "fleet" {
                bail!("unknown bench target '{what}' (try: fleet)");
            }
            if args.bool("scale") {
                let points: Vec<usize> = match args.get("points") {
                    Some(s) => s
                        .split(',')
                        .map(|p| p.trim().parse::<usize>())
                        .collect::<Result<_, _>>()
                        .context("--points takes a comma-separated \
                                  list of replica counts")?,
                    None => vec![4, 64, 256, 1024],
                };
                if points.is_empty() || points.contains(&0) {
                    bail!("--points needs at least one nonzero \
                           replica count");
                }
                bench::bench_scale(seed,
                                   args.get("json").map(|s| s.as_str()),
                                   &points)
            } else {
                bench::bench_fleet(seed,
                                   args.get("json").map(|s| s.as_str()))
            }
        }
        // ("--help" never reaches here: Args::parse turns --x into a
        // flag, leaving cmd at its "help" default)
        "help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            // Unknown commands must fail loudly with a nonzero exit —
            // and never be silently absorbed by the help path.
            print_help();
            bail!("unknown command '{other}'")
        }
    }
}

/// `rap lint [--json [<path>]] [paths…]`: the determinism & invariant
/// static-analysis pass over the crate's own `src/` tree (or the given
/// files/directories). Prints one line per finding — `FIND` for
/// unjustified, `ALLOW` for sites suppressed with a justified
/// `// lint:allow(<rule>): <why>` — and exits nonzero if any
/// unjustified finding remains. Bare `--json` prints the machine
/// report to stdout instead; `--json <path>` writes it to `<path>`
/// (CI uploads that file as the failure artifact).
fn run_lint(args: &Args) -> Result<()> {
    use rap::analysis::{default_src_root, scan_path, Finding, RULES};
    let targets: Vec<std::path::PathBuf> = if args.positional.len() > 1 {
        args.positional[1..]
            .iter()
            .map(std::path::PathBuf::from)
            .collect()
    } else {
        vec![default_src_root()]
    };
    let mut findings: Vec<Finding> = Vec::new();
    for t in &targets {
        findings.extend(scan_path(t)?);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    let unjustified =
        findings.iter().filter(|f| f.justification.is_none()).count();

    let json_mode = args.get("json");
    if json_mode.is_some() {
        let doc = Json::object(vec![
            ("rules", Json::Arr(RULES.iter().map(|r| {
                Json::object(vec![
                    ("name", Json::Str(r.name.to_string())),
                    ("summary", Json::Str(r.summary.to_string())),
                ])
            }).collect())),
            ("findings", Json::Arr(findings.iter().map(|f| {
                Json::object(vec![
                    ("rule", Json::Str(f.rule.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                    ("snippet", Json::Str(f.snippet.clone())),
                    ("justification", match &f.justification {
                        Some(j) => Json::Str(j.clone()),
                        None => Json::Null,
                    }),
                ])
            }).collect())),
            ("total", Json::Num(findings.len() as f64)),
            ("justified",
             Json::Num((findings.len() - unjustified) as f64)),
            ("unjustified", Json::Num(unjustified as f64)),
        ]);
        match json_mode {
            // bare `--json` parses as the flag value "true"
            Some("true") | None => println!("{}", doc.pretty()),
            Some(path) => {
                std::fs::write(path, doc.pretty())?;
                println!("lint JSON written to {path}");
            }
        }
    }
    if json_mode != Some("true") {
        for f in &findings {
            match &f.justification {
                Some(why) => println!(
                    "ALLOW {}:{} [{}] {} — {}",
                    f.file, f.line, f.rule, f.snippet, why),
                None => println!(
                    "FIND  {}:{} [{}] {}\n      {}",
                    f.file, f.line, f.rule, f.snippet, f.message),
            }
        }
        println!("{} findings, {} justified, {} unjustified",
                 findings.len(), findings.len() - unjustified,
                 unjustified);
    }
    if unjustified > 0 {
        bail!("lint: {unjustified} unjustified finding(s) — fix them \
               or add `// lint:allow(<rule>): <why>` with a real \
               justification");
    }
    println!("lint clean");
    Ok(())
}

/// `rap trace summarize <file> [--request <id>]` reconstructs one
/// request's life story from a flight-recorder trace (no id: the most
/// eventful — in a chaos run, the crash-disturbed — request);
/// `rap trace validate <file>` checks the structural invariants
/// (monotonic timestamps, balanced begin/end spans, no orphan ids).
fn run_trace_tool(args: &Args) -> Result<()> {
    let action = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("summarize");
    let path = args
        .positional
        .get(2)
        .context("usage: rap trace summarize|validate <file>")?;
    let doc = Json::parse_file(std::path::Path::new(path))?;
    match action {
        "summarize" => {
            let want = match args.get("request") {
                Some(v) => Some(v.parse::<u64>()?),
                None => None,
            };
            print!("{}", trace::summarize(&doc, want)?);
            Ok(())
        }
        "validate" => {
            let stats = trace::validate(&doc)?;
            println!("trace OK: {} trace events ({} spans, {} \
                      instants), {} requests, {} audit events",
                     stats.trace_events, stats.spans, stats.instants,
                     stats.requests, stats.audit_events);
            Ok(())
        }
        other => bail!("unknown trace action '{other}' \
                        (try: summarize, validate)"),
    }
}

/// `rap serve-fleet --replicas 4 --router rap --secs 120 [--json path]
/// [--autoscale [--min-replicas N] [--max-replicas N] [--warmup S]]
/// [--migrate] [--tenants N] [--slo S] [--fault-plan SEED]
/// [--checkpoint S]`:
/// one seeded trace across N heterogeneous sim replicas, with the fleet
/// report printed and emitted as JSON (stdout, or `--json <path>`).
/// `--tenants` spreads the trace across N synthetic tenants (and, under
/// `--router tenant-fair`, gives each an equal KV-byte quota); `--slo`
/// attaches a relative completion deadline to every request.
/// `--fault-plan <seed>` injects a seeded failure schedule (crashes,
/// link degradation/partitions, spot reclaims, memory pressure) drawn
/// over the arrival window; `--checkpoint <secs>` turns on periodic KV
/// checkpointing so crashes restore in-flight sequences onto peers.
/// Observability: `--trace <path>` writes the Chrome/Perfetto flight
/// recording, `--metrics <path>` the Prometheus text exposition,
/// `--metrics-json <path>` the sampled time-series (period:
/// `--metrics-period <secs>`, default 5).
fn serve_fleet(seed: u64, args: &Args) -> Result<()> {
    let replicas = args.usize_or("replicas", 4)?;
    if replicas == 0 {
        bail!("--replicas must be at least 1");
    }
    let secs = args.f64_or("secs", 120.0)?;
    let policy = RouterPolicy::parse(&args.str_or("router", "rap"))?;
    let tenants = args.usize_or("tenants", 1)?;
    let slo = match args.get("slo") {
        Some(v) => Some(v.parse::<f64>()?),
        None => None,
    };
    let autoscale = if args.bool("autoscale") {
        Some(AutoscaleConfig {
            min_replicas: args.usize_or("min-replicas", 1)?.max(1),
            max_replicas: args
                .usize_or("max-replicas", (replicas * 2).max(2))?,
            ..AutoscaleConfig::default()
        })
    } else {
        None
    };
    let checkpoint = match args.get("checkpoint") {
        Some(v) => {
            let period = v.parse::<f64>()?;
            if !period.is_finite() || period <= 0.0 {
                bail!("--checkpoint must be a positive number of seconds");
            }
            Some(period)
        }
        None => None,
    };
    let cfg = FleetConfig {
        // never truncate the requested trace: arrivals span `secs`,
        // plus a generous drain window
        max_sim_secs: secs + 3600.0,
        migrate: args.bool("migrate"),
        autoscale,
        warmup_secs: args.f64_or("warmup", 0.0)?,
        checkpoint_period_secs: checkpoint,
        ..FleetConfig::default()
    };
    let mut fleet = default_sim_fleet_with(replicas, seed, policy, cfg);
    if let Some(v) = args.get("fault-plan") {
        let fault_seed = v.parse::<u64>()?;
        fleet = fleet
            .with_fault_plan(FaultPlan::seeded(fault_seed, secs, replicas));
    }
    if policy == RouterPolicy::TenantFair && tenants > 1 {
        fleet.router.quotas = equal_share_quotas(&fleet, tenants);
    }
    let trace_path = args.get("trace");
    let metrics_path = args.get("metrics");
    let metrics_json_path = args.get("metrics-json");
    if trace_path.is_some() {
        fleet.enable_telemetry();
    }
    if metrics_path.is_some() || metrics_json_path.is_some() {
        let period = args.f64_or("metrics-period", 5.0)?;
        if !period.is_finite() || period <= 0.0 {
            bail!("--metrics-period must be a positive number of \
                   seconds");
        }
        fleet.enable_metrics_sampling(period);
    }
    let reqs = default_fleet_trace(seed, secs);
    println!("serve-fleet: {} requests over {secs:.0}s across {replicas} \
              replicas (router={}, seed={seed}, tenants={tenants}, \
              autoscale={}, migrate={}, fault_plan={}, checkpoint={:?})",
             reqs.len(), policy.name(), cfg.autoscale.is_some(),
             cfg.migrate, args.get("fault-plan").is_some(), checkpoint);
    let subs = api::decorate_trace(reqs, tenants, slo);
    let report = fleet.run_requests(subs)?;
    report.print();
    let json = report.to_json().pretty();
    match args.get("json") {
        Some(path) => {
            std::fs::write(path, &json)?;
            println!("fleet report JSON written to {path}");
        }
        None => println!("{json}"),
    }
    if let (Some(path), Some(trace)) = (trace_path, fleet.trace_json())
    {
        std::fs::write(path, trace.pretty())?;
        println!("trace written to {path}");
    }
    if metrics_path.is_some() || metrics_json_path.is_some() {
        // refresh the counters one last time so the exposition reflects
        // the fully drained run, not the last in-run sample
        fleet.publish_metrics();
        if let Some(path) = metrics_path {
            std::fs::write(path, fleet.registry.prometheus())?;
            println!("metrics exposition written to {path}");
        }
        if let Some(path) = metrics_json_path {
            std::fs::write(path,
                           fleet.registry.timeline_json().pretty())?;
            println!("metrics time-series written to {path}");
        }
    }
    Ok(())
}

fn run_experiment(id: &str, model: &str, seed: u64, quick: bool,
                  args: &Args) -> Result<()> {
    let episodes = args.usize_or("episodes",
                                 if quick { 30 } else { 80 })?;
    match id {
        "fig2" => figures::fig2(seed),
        "fig3" => figures::fig3(),
        "fig4" | "fig12" => figures::fig4(model),
        "fig5" => figures::fig5(seed, args.f64_or("secs",
                                                  if quick { 60.0 }
                                                  else { 180.0 })?),
        "fig6" => figures::fig6(model, args.usize_or("remove", 6)?),
        "fig9" => rl::fig9(model, episodes),
        "fig10" => rl::fig10(model, episodes.min(40)),
        "fig11" => rl::fig11(model),
        "table1" => tables::table1(model, seed, quick).map(|_| ()),
        "table2" | "fig8" => tables::table2(model, seed, quick),
        "table3" => tables::table1("qwen-sim", seed, quick).map(|_| ()),
        "table4" => tables::table4(seed),
        "tables" => tables::all_tables(seed, quick),
        "fleet" => {
            if args.bool("elastic") {
                // fixed scenario (2 replicas, 120 s) so the acceptance
                // inequality stays reproducible; only --seed varies it
                fleet::fleet_elastic(seed)
            } else if args.bool("absorbable") {
                // fixed scenario (2 replicas, one absorbable wall):
                // current-mask vs mask-elastic accounting
                fleet::fleet_absorbable(seed)
            } else if args.bool("longctx") {
                // fixed scenario (2 replicas, one joint-only wall):
                // mask-only vs joint (mask × KV policy) elasticity;
                // --report writes the acceptance JSON
                fleet::fleet_longctx(seed,
                                     args.get("report")
                                         .map(|s| s.as_str()))
            } else if args.bool("tenants") {
                // fixed scenario (2 replicas, two tenants, one flood):
                // FCFS vs tenant-fair ingress
                fleet::fleet_tenants(seed)
            } else if args.bool("chaos") {
                // fixed scenario (3 replicas, one fault plan):
                // checkpointed vs checkpoint-free recovery; --trace
                // flight-records the checkpointed run
                fleet::fleet_chaos(seed,
                                   args.get("trace")
                                       .map(|s| s.as_str()))
            } else {
                fleet::fleet_compare(
                    seed,
                    args.f64_or("secs",
                                if quick { 45.0 } else { 120.0 })?,
                    args.usize_or("replicas", 4)?)
            }
        }
        "all" => {
            figures::fig2(seed)?;
            figures::fig3()?;
            figures::fig4(model)?;
            figures::fig5(seed, if quick { 60.0 } else { 180.0 })?;
            figures::fig6(model, 6)?;
            tables::all_tables(seed, quick)?;
            rl::fig9(model, episodes)?;
            rl::fig10(model, episodes.min(40))?;
            rl::fig11(model)
        }
        _ => bail!("unknown experiment '{id}'"),
    }
}

fn print_help() {
    println!("rap — Runtime-Adaptive Pruning for LLM inference");
    println!();
    println!("USAGE: rap <command> [flags]");
    println!();
    println!("COMMANDS:");
    println!("  experiment <id>  fig2..fig12, table1..table4, fleet, all");
    println!("                   fleet takes --elastic: fixed fleet vs \
              autoscale+migration");
    println!("                   fleet takes --absorbable: current-mask \
              vs mask-elastic accounting");
    println!("                   fleet takes --tenants: FCFS vs \
              tenant-fair ingress on a two-tenant storm");
    println!("                   fleet takes --longctx: mask-only vs \
              joint (mask x KV policy) elasticity");
    println!("                    on a long-context storm \
              [--report <path> writes the acceptance JSON]");
    println!("                   fleet takes --chaos: checkpointed vs \
              checkpoint-free recovery under one fault plan");
    println!("  train-agent      --model <m> --episodes <n> --seed <s>");
    println!("  serve            --secs <n> --seed <s> [--tenants <n>] \
              [--slo <secs>]");
    println!("                   (--tenants spreads the trace across n \
              synthetic tenants;");
    println!("                    --slo attaches a completion deadline \
              — per-tenant hit-rates in the report)");
    println!("  serve-fleet      --replicas <n> --router \
              rr|least|kv|rap|tenant  --secs <n> [--json <path>]");
    println!("                   [--autoscale [--min-replicas <n>] \
              [--max-replicas <n>] [--warmup <secs>]]");
    println!("                   [--migrate]  (move in-flight sequences \
              off pressured replicas)");
    println!("                   [--tenants <n>] [--slo <secs>]  \
              (tenant-fair: equal KV quotas per tenant)");
    println!("                   [--fault-plan <seed>] [--checkpoint \
              <secs>]  (seeded failure injection; periodic KV");
    println!("                    checkpoints restore crashed work onto \
              peers)");
    println!("                   [--trace <path>]  (Chrome/Perfetto \
              flight recording — also on serve and");
    println!("                    experiment fleet --chaos)");
    println!("                   [--metrics <path>] [--metrics-json \
              <path>] [--metrics-period <secs>]");
    println!("                    (Prometheus exposition / sampled \
              time-series of the fleet registry)");
    println!("  trace            summarize|validate <file> \
              [--request <id>]");
    println!("  bench            fleet [--json <path>]  (storm-scenario \
              throughput, telemetry off vs on)");
    println!("                   fleet --scale [--points 4,64,256,1024] \
              [--json <path>]");
    println!("                    (replica-count sweep: event-driven \
              1M-request storm vs a truncated");
    println!("                     lockstep baseline, wall-normalized \
              req/s + RSS to BENCH_scale.json)");
    println!("  gsi              --model <m> --remove <n>");
    println!("  lint             [--json [<path>]] [paths...]  \
              (determinism & invariant static analysis");
    println!("                    over the crate's own source: \
              wall-clock, hash-order iteration, float ordering,");
    println!("                    hot-path panics, raw rng — nonzero \
              exit on any unjustified finding;");
    println!("                    suppress with `// lint:allow(<rule>): \
              <why>` — the why is required)");
    println!();
    println!("FLAGS: --model rap-small|qwen-sim|rap-tiny  --seed N  \
              --quick");
}

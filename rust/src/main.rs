//! `rap` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   experiment <id>   regenerate a paper table/figure
//!                     (fig2|fig3|fig4|fig5|fig6|fig9|fig10|fig11|
//!                      table1|table2|table3|table4|all)
//!   train-agent       train + save the DQN controller for a model
//!   serve             replay a synthetic trace through the serving engine
//!   gsi               run Greedy Sequential Importance on a model
//!
//! Common flags: --model <name> --seed <n> --quick

use anyhow::{bail, Result};
use rap::experiments::{figures, rl, tables};
use rap::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let model = args.str_or("model", "rap-small");
    let seed = args.u64_or("seed", 42)?;
    let quick = args.bool("quick");
    match cmd {
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            run_experiment(id, &model, seed, quick, &args)
        }
        "train-agent" => {
            let episodes = args.usize_or(
                "episodes", if quick { 40 } else { 120 })?;
            rl::train_agent(&model, episodes, seed)?;
            Ok(())
        }
        "gsi" => {
            let n = args.usize_or("remove", 8)?;
            figures::fig6(&model, n)
        }
        "serve" => {
            let secs = args.f64_or("secs", 120.0)?;
            figures::fig5(seed, secs)
        }
        "help" | _ => {
            print_help();
            if cmd != "help" {
                bail!("unknown command '{cmd}'");
            }
            Ok(())
        }
    }
}

fn run_experiment(id: &str, model: &str, seed: u64, quick: bool,
                  args: &Args) -> Result<()> {
    let episodes = args.usize_or("episodes",
                                 if quick { 30 } else { 80 })?;
    match id {
        "fig2" => figures::fig2(seed),
        "fig3" => figures::fig3(),
        "fig4" | "fig12" => figures::fig4(model),
        "fig5" => figures::fig5(seed, args.f64_or("secs",
                                                  if quick { 60.0 }
                                                  else { 180.0 })?),
        "fig6" => figures::fig6(model, args.usize_or("remove", 6)?),
        "fig9" => rl::fig9(model, episodes),
        "fig10" => rl::fig10(model, episodes.min(40)),
        "fig11" => rl::fig11(model),
        "table1" => tables::table1(model, seed, quick).map(|_| ()),
        "table2" | "fig8" => tables::table2(model, seed, quick),
        "table3" => tables::table1("qwen-sim", seed, quick).map(|_| ()),
        "table4" => tables::table4(seed),
        "tables" => tables::all_tables(seed, quick),
        "all" => {
            figures::fig2(seed)?;
            figures::fig3()?;
            figures::fig4(model)?;
            figures::fig5(seed, if quick { 60.0 } else { 180.0 })?;
            figures::fig6(model, 6)?;
            tables::all_tables(seed, quick)?;
            rl::fig9(model, episodes)?;
            rl::fig10(model, episodes.min(40))?;
            rl::fig11(model)
        }
        _ => bail!("unknown experiment '{id}'"),
    }
}

fn print_help() {
    println!("rap — Runtime-Adaptive Pruning for LLM inference");
    println!();
    println!("USAGE: rap <command> [flags]");
    println!();
    println!("COMMANDS:");
    println!("  experiment <id>  fig2..fig12, table1..table4, all");
    println!("  train-agent      --model <m> --episodes <n> --seed <s>");
    println!("  serve            --secs <n> --seed <s>");
    println!("  gsi              --model <m> --remove <n>");
    println!();
    println!("FLAGS: --model rap-small|qwen-sim|rap-tiny  --seed N  \
              --quick");
}

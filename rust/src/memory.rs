//! The paper's memory model (Appendix A.2, Eq. 3–4).
//!
//!   Mem_param(M)            = b_prec · Σ_B #params(B)
//!   Mem_KV(M, bs, sql)      = b_prec · 2 · Σ_ℓ n_kv,ℓ · d_head · bs · sql
//!   Mem_peak                = Mem_param + Mem_KV
//!
//! All budget arithmetic in the paper ("80% memory budget" = 0.8 ×
//! peak(dense, workload)) goes through this module, as does the serving
//! runtime's admission control.

use crate::mask::PruneMask;
use crate::model_meta::{ModelMeta, BYTES_PER_SCALAR};

/// A (batch size, sequence length) request shape — the workload half of
/// the paper's state vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Workload {
    pub batch: usize,
    pub seqlen: usize,
}

impl Workload {
    pub fn new(batch: usize, seqlen: usize) -> Workload {
        Workload { batch, seqlen }
    }
}

/// Breakdown of a peak-memory estimate (drives Fig 3's pies).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemBreakdown {
    pub ffn_param_bytes: usize,
    pub mha_param_bytes: usize,
    pub base_param_bytes: usize,
    pub kv_bytes: usize,
}

impl MemBreakdown {
    pub fn param_bytes(&self) -> usize {
        self.ffn_param_bytes + self.mha_param_bytes + self.base_param_bytes
    }

    pub fn total(&self) -> usize {
        self.param_bytes() + self.kv_bytes
    }
}

#[derive(Clone, Debug)]
pub struct MemoryModel {
    meta: ModelMeta,
}

impl MemoryModel {
    pub fn new(meta: &ModelMeta) -> MemoryModel {
        MemoryModel { meta: meta.clone() }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Eq. 3 restricted to the blocks the mask keeps.
    pub fn param_bytes(&self, mask: &PruneMask) -> usize {
        self.breakdown(mask, Workload::new(0, 0)).param_bytes()
    }

    /// Eq. 4: KV bytes for a workload under a mask. A layer whose MHA
    /// block is gone stores nothing; with GQA only kv groups that still
    /// serve a live query head are stored.
    pub fn kv_bytes(&self, mask: &PruneMask, w: Workload) -> usize {
        let mut total = 0usize;
        for l in 0..self.meta.n_layers {
            let kvh = mask.active_kv_groups(l);
            total += self.meta.kv_bytes_per_token_layer(kvh)
                * w.batch
                * w.seqlen;
        }
        total
    }

    /// Eq. 3 + Eq. 4 with the FFN/MHA/base/KV split.
    pub fn breakdown(&self, mask: &PruneMask, w: Workload) -> MemBreakdown {
        let m = &self.meta;
        let d = m.d_model;
        let dh = m.head_dim();
        let mut ffn = 0usize;
        let mut mha = 0usize;
        for l in 0..m.n_layers {
            let qh = mask.active_heads(l);
            let kvg = mask.active_kv_groups(l);
            if qh > 0 {
                mha += (qh * 2 * d * dh + kvg * 2 * d * dh + d)
                    * BYTES_PER_SCALAR;
            }
            let fc = mask.active_ffn_channels(l);
            if fc > 0 {
                ffn += (fc * 3 * d + d) * BYTES_PER_SCALAR;
            }
        }
        MemBreakdown {
            ffn_param_bytes: ffn,
            mha_param_bytes: mha,
            base_param_bytes: m.base_params() * BYTES_PER_SCALAR,
            kv_bytes: self.kv_bytes(mask, w),
        }
    }

    /// Mem_peak(M, bs, sql).
    pub fn peak_bytes(&self, mask: &PruneMask, w: Workload) -> usize {
        self.param_bytes(mask) + self.kv_bytes(mask, w)
    }

    /// Peak of the *dense* model — the reference the paper's "X% budget"
    /// is defined against.
    pub fn dense_peak_bytes(&self, w: Workload) -> usize {
        self.peak_bytes(&PruneMask::full(&self.meta), w)
    }

    /// Absolute byte budget for a relative budget (e.g. 0.8).
    pub fn budget_bytes(&self, w: Workload, fraction: f64) -> usize {
        (self.dense_peak_bytes(w) as f64 * fraction) as usize
    }

    /// Does the mask fit the budget for this workload?
    pub fn fits(&self, mask: &PruneMask, w: Workload, budget_bytes: usize)
                -> bool {
        self.peak_bytes(mask, w) <= budget_bytes
    }

    /// Bytes freed by dropping `b` from `mask` (0 if already dropped) —
    /// the R_mem term of the paper's reward (Eq. 2).
    pub fn block_bytes(&self, mask: &PruneMask, w: Workload,
                       b: crate::model_meta::BlockId) -> usize {
        if mask.block_dropped(b) {
            return 0;
        }
        let after = mask.with_block_dropped(b);
        self.peak_bytes(mask, w) - self.peak_bytes(&after, w)
    }
}

pub fn gib(bytes: usize) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

pub fn mib(bytes: usize) -> f64 {
    bytes as f64 / (1u64 << 20) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::BlockId;

    fn mm() -> MemoryModel {
        MemoryModel::new(&ModelMeta::synthetic("t", 4, 64, 4, 2, 96, 128,
                                               64))
    }

    #[test]
    fn dense_param_bytes_match_total() {
        let mm = mm();
        let mask = PruneMask::full(mm.meta());
        assert_eq!(mm.param_bytes(&mask),
                   mm.meta().total_params() * BYTES_PER_SCALAR);
    }

    #[test]
    fn kv_scales_linearly_with_batch_and_seq() {
        let mm = mm();
        let mask = PruneMask::full(mm.meta());
        let a = mm.kv_bytes(&mask, Workload::new(1, 16));
        let b = mm.kv_bytes(&mask, Workload::new(2, 16));
        let c = mm.kv_bytes(&mask, Workload::new(1, 32));
        assert_eq!(b, 2 * a);
        assert_eq!(c, 2 * a);
        assert!(a > 0);
    }

    #[test]
    fn dropping_mha_frees_params_and_kv() {
        let mm = mm();
        let w = Workload::new(4, 64);
        let full = PruneMask::full(mm.meta());
        let pruned = full.with_block_dropped(BlockId::Mha(2));
        assert!(mm.param_bytes(&pruned) < mm.param_bytes(&full));
        assert!(mm.kv_bytes(&pruned, w) < mm.kv_bytes(&full, w));
        // exactly one layer's kv disappears
        let per_layer = mm.meta().kv_bytes_per_token_layer(2) * 4 * 64;
        assert_eq!(mm.kv_bytes(&full, w) - mm.kv_bytes(&pruned, w),
                   per_layer);
    }

    #[test]
    fn dropping_ffn_frees_params_only() {
        let mm = mm();
        let w = Workload::new(4, 64);
        let full = PruneMask::full(mm.meta());
        let pruned = full.with_block_dropped(BlockId::Ffn(1));
        assert!(mm.param_bytes(&pruned) < mm.param_bytes(&full));
        assert_eq!(mm.kv_bytes(&pruned, w), mm.kv_bytes(&full, w));
    }

    #[test]
    fn budget_and_fits() {
        let mm = mm();
        let w = Workload::new(8, 64);
        let full = PruneMask::full(mm.meta());
        let budget = mm.budget_bytes(w, 0.8);
        assert!(!mm.fits(&full, w, budget));
        // drop everything → must fit
        let mut empty = full.clone();
        for b in mm.meta().all_blocks() {
            empty.drop_block(b);
        }
        assert!(mm.fits(&empty, w, budget));
    }

    #[test]
    fn block_bytes_is_peak_delta() {
        let mm = mm();
        let w = Workload::new(2, 32);
        let full = PruneMask::full(mm.meta());
        for b in mm.meta().all_blocks() {
            let freed = mm.block_bytes(&full, w, b);
            let after = full.with_block_dropped(b);
            assert_eq!(freed,
                       mm.peak_bytes(&full, w) - mm.peak_bytes(&after, w));
            assert!(freed > 0);
        }
    }

    #[test]
    fn paper_regime_shift_param_to_kv() {
        // Fig 3's qualitative claim on the Llama2-7B shape: small
        // workloads are parameter-dominated, large ones KV-dominated.
        let mm = MemoryModel::new(&ModelMeta::llama2_7b());
        let mask = PruneMask::full(mm.meta());
        let small = mm.breakdown(&mask, Workload::new(1, 128));
        assert!(small.param_bytes() > small.kv_bytes);
        let large = mm.breakdown(&mask, Workload::new(16, 4096));
        assert!(large.kv_bytes > large.param_bytes());
        // paper's headline number: 32 GB of KV at batch=16, 4k tokens, bf16.
        let kv_bf16 = large.kv_bytes / 2; // we store f32, paper uses bf16
        let gib_v = kv_bf16 as f64 / (1u64 << 30) as f64;
        assert!(gib_v > 28.0 && gib_v < 36.0, "kv={gib_v} GiB");
    }
}

//! Pruning schemes: RAP and every baseline from the paper's Table 1/2,
//! all evaluated under *identical memory budgets* (the paper's headline
//! evaluation protocol — §5.1 argues pruning ratio is a misleading proxy).
//!
//! Each scheme produces a `PruneMask` for a (workload, budget) pair:
//!   * `Dense`        — no pruning (the 100% row)
//!   * `LlmPrunerSim` — gradient/saliency-style structured pruning at
//!                      head/channel granularity (activation-norm saliency,
//!                      first/last layers protected, like LLM-Pruner)
//!   * `SliceGptSim`  — uniform width slicing, shallow→deep schedule
//!                      (PCA-free emulation of SliceGPT, DESIGN.md §6)
//!   * `ShortGpt`     — whole-layer removal by cosine-similarity redundancy
//!   * `MhaDrop`      — attention-block removal by cosine redundancy
//!   * `FfnSkip`      — FFN-block skipping by cosine redundancy
//!   * `RandomDrop`   — the RAP⁻RL ablation (uniform random blocks)
//!   * `OneShot`      — the RAP⁻GSI ablation (static one-shot PPL scores)
//!   * `RapGreedy`    — GSI with recalibration, greedy until budget met
//!   * RAP proper = GSI + trained DQN, via `agent::online_prune`.

use anyhow::Result;

use crate::gsi::GsiEngine;
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::model_meta::{BlockId, ModelMeta};
use crate::runtime::{NllEvaluator, ProbeStats};
use crate::util::rng::Rng;

/// Everything a static scheme needs to decide a mask.
pub struct PruneContext<'a> {
    pub mem: &'a MemoryModel,
    pub probe: &'a ProbeStats,
    pub workload: Workload,
    pub budget_bytes: usize,
    pub seed: u64,
}

impl PruneContext<'_> {
    pub fn meta(&self) -> &ModelMeta {
        self.mem.meta()
    }

    pub fn fits(&self, mask: &PruneMask) -> bool {
        self.mem.fits(mask, self.workload, self.budget_bytes)
    }
}

/// Identifier for table output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Dense,
    LlmPrunerSim,
    SliceGptSim,
    ShortGpt,
    MhaDrop,
    FfnSkip,
    RandomDrop,
    OneShot,
    RapGreedy,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Dense => "Dense",
            Scheme::LlmPrunerSim => "LLMPruner-sim",
            Scheme::SliceGptSim => "SliceGPT-sim",
            Scheme::ShortGpt => "ShortGPT",
            Scheme::MhaDrop => "MHA-Drop",
            Scheme::FfnSkip => "FFN-Skip",
            Scheme::RandomDrop => "Random-Drop (RAP-RL)",
            Scheme::OneShot => "One-Shot (RAP-GSI)",
            Scheme::RapGreedy => "RAP",
        }
    }

    /// The Table-1 baseline set (probe-driven; no model evals needed).
    pub fn baselines() -> Vec<Scheme> {
        vec![Scheme::LlmPrunerSim, Scheme::SliceGptSim, Scheme::ShortGpt,
             Scheme::MhaDrop, Scheme::FfnSkip]
    }
}

/// Drop blocks in the given order until the budget is met (or the order
/// is exhausted). Returns the mask and how many blocks were dropped.
pub fn drop_until_fits(ctx: &PruneContext, order: &[BlockId])
                       -> (PruneMask, usize) {
    let mut mask = PruneMask::full(ctx.meta());
    let mut dropped = 0;
    for &b in order {
        if ctx.fits(&mask) {
            break;
        }
        mask.drop_block(b);
        dropped += 1;
    }
    (mask, dropped)
}

/// Build a mask for a static scheme.
pub fn build_mask(scheme: Scheme, ctx: &PruneContext) -> Result<PruneMask> {
    match scheme {
        Scheme::Dense => Ok(PruneMask::full(ctx.meta())),
        Scheme::LlmPrunerSim => llm_pruner_sim(ctx),
        Scheme::SliceGptSim => slice_gpt_sim(ctx),
        Scheme::ShortGpt => Ok(short_gpt(ctx)),
        Scheme::MhaDrop => Ok(mha_drop(ctx)),
        Scheme::FfnSkip => Ok(ffn_skip(ctx)),
        Scheme::RandomDrop => Ok(random_drop(ctx)),
        Scheme::OneShot | Scheme::RapGreedy => {
            anyhow::bail!("{:?} needs an evaluator — use build_mask_eval",
                          scheme)
        }
    }
}

/// Build a mask for an evaluator-driven scheme (one-shot / GSI-greedy).
pub fn build_mask_eval<E: NllEvaluator>(
    scheme: Scheme, ctx: &PruneContext, gsi: &mut GsiEngine<E>)
    -> Result<PruneMask> {
    let full = PruneMask::full(ctx.meta());
    match scheme {
        Scheme::OneShot => {
            let order: Vec<BlockId> = gsi
                .one_shot_order(&full)?
                .into_iter()
                .map(|(b, _)| b)
                .collect();
            Ok(drop_until_fits(ctx, &order).0)
        }
        Scheme::RapGreedy => {
            let res = gsi.greedy(&full, |m| {
                ctx.mem.fits(m, ctx.workload, ctx.budget_bytes)
            })?;
            let mut mask = full;
            for b in res.order {
                mask.drop_block(b);
            }
            Ok(mask)
        }
        _ => build_mask(scheme, ctx),
    }
}

// ---------------------------------------------------------------------
// Probe-driven baselines
// ---------------------------------------------------------------------

/// LLM-Pruner-style: head/channel units ranked by activation-norm
/// saliency × parameter cost; first and last layers protected (the
/// original's "coupled structure" rule keeps model ends intact).
fn llm_pruner_sim(ctx: &PruneContext) -> Result<PruneMask> {
    let m = ctx.meta();
    let mut mask = PruneMask::full(m);
    #[derive(Clone, Copy)]
    enum Unit {
        Head(usize, usize),
        Chan(usize, usize),
    }
    let mut units: Vec<(f64, Unit)> = Vec::new();
    for l in 1..m.n_layers.saturating_sub(1) {
        for h in 0..m.n_heads {
            let sal = ctx.probe.head_norm[l * m.n_heads + h] as f64;
            units.push((sal, Unit::Head(l, h)));
        }
        for c in 0..m.d_ff {
            let sal = ctx.probe.chan_norm[l * m.d_ff + c] as f64;
            units.push((sal, Unit::Chan(l, c)));
        }
    }
    units.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (_, u) in units {
        if ctx.fits(&mask) {
            break;
        }
        match u {
            Unit::Head(l, h) => mask.set_head(l, h, false),
            Unit::Chan(l, c) => mask.set_ffn_channel(l, c, false),
        }
    }
    Ok(mask)
}

/// SliceGPT-style: uniform width reduction with a shallow→deep ramp
/// (deeper layers sliced harder, as PCA energy concentrates). The global
/// slice scale is binary-searched to hit the budget.
fn slice_gpt_sim(ctx: &PruneContext) -> Result<PruneMask> {
    let m = ctx.meta();
    let build = |scale: f64| -> PruneMask {
        let mut mask = PruneMask::full(m);
        for l in 0..m.n_layers {
            let depth = (l + 1) as f64 / m.n_layers as f64;
            let frac = (scale * (0.5 + 0.5 * depth)).min(0.95);
            // prune the lowest-norm heads/channels in this layer
            let nh = (frac * m.n_heads as f64) as usize;
            let nc = (frac * m.d_ff as f64) as usize;
            let mut hs: Vec<usize> = (0..m.n_heads).collect();
            hs.sort_by(|&a, &b| {
                ctx.probe.head_norm[l * m.n_heads + a]
                    .total_cmp(&ctx.probe.head_norm[l * m.n_heads + b])
            });
            for &h in hs.iter().take(nh) {
                mask.set_head(l, h, false);
            }
            let mut cs: Vec<usize> = (0..m.d_ff).collect();
            cs.sort_by(|&a, &b| {
                ctx.probe.chan_norm[l * m.d_ff + a]
                    .total_cmp(&ctx.probe.chan_norm[l * m.d_ff + b])
            });
            for &c in cs.iter().take(nc) {
                mask.set_ffn_channel(l, c, false);
            }
        }
        mask
    };
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        if ctx.fits(&build(mid)) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(build(hi))
}

/// ShortGPT: remove whole layers (MHA+FFN together) in descending
/// input/output cosine similarity (most redundant first).
fn short_gpt(ctx: &PruneContext) -> PruneMask {
    let m = ctx.meta();
    let mut layers: Vec<usize> = (0..m.n_layers).collect();
    let redundancy = |l: usize| {
        (ctx.probe.attn_cos[l] + ctx.probe.ffn_cos[l]) as f64
    };
    layers.sort_by(|&a, &b| {
        redundancy(b).total_cmp(&redundancy(a))
    });
    let order: Vec<BlockId> = layers
        .into_iter()
        .flat_map(|l| [BlockId::Mha(l), BlockId::Ffn(l)])
        .collect();
    drop_until_fits(ctx, &order).0
}

/// MHA-Drop: attention blocks only, by cosine redundancy.
fn mha_drop(ctx: &PruneContext) -> PruneMask {
    let m = ctx.meta();
    let mut layers: Vec<usize> = (0..m.n_layers).collect();
    layers.sort_by(|&a, &b| {
        ctx.probe.attn_cos[b].total_cmp(&ctx.probe.attn_cos[a])
    });
    let order: Vec<BlockId> =
        layers.into_iter().map(BlockId::Mha).collect();
    drop_until_fits(ctx, &order).0
}

/// FFN-Skip: feed-forward blocks only, by cosine redundancy (the
/// input-adaptive part is the probe being computed on the live batch).
fn ffn_skip(ctx: &PruneContext) -> PruneMask {
    let m = ctx.meta();
    let mut layers: Vec<usize> = (0..m.n_layers).collect();
    layers.sort_by(|&a, &b| {
        ctx.probe.ffn_cos[b].total_cmp(&ctx.probe.ffn_cos[a])
    });
    let order: Vec<BlockId> =
        layers.into_iter().map(BlockId::Ffn).collect();
    drop_until_fits(ctx, &order).0
}

/// Random-Drop (RAP⁻RL ablation): uniformly random blocks until fit.
fn random_drop(ctx: &PruneContext) -> PruneMask {
    let m = ctx.meta();
    let mut rng = Rng::new(ctx.seed);
    let mut order: Vec<BlockId> = m.all_blocks();
    rng.shuffle(&mut order);
    drop_until_fits(ctx, &order).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;

    fn setup() -> (ModelMeta, MemoryModel, ProbeStats) {
        let meta = ModelMeta::synthetic("t", 6, 64, 4, 2, 96, 128, 64);
        let mem = MemoryModel::new(&meta);
        // synthetic probe: deeper layers more redundant; head/channel
        // norms rising with index
        let mut probe = ProbeStats {
            attn_cos: (0..6).map(|l| 0.5 + 0.08 * l as f32).collect(),
            ffn_cos: (0..6).map(|l| 0.4 + 0.09 * l as f32).collect(),
            head_norm: vec![0.0; 6 * 4],
            chan_norm: vec![0.0; 6 * 96],
        };
        for l in 0..6 {
            for h in 0..4 {
                probe.head_norm[l * 4 + h] = (h + 1) as f32;
            }
            for c in 0..96 {
                probe.chan_norm[l * 96 + c] = (c + 1) as f32;
            }
        }
        (meta, mem, probe)
    }

    fn ctx<'a>(mem: &'a MemoryModel, probe: &'a ProbeStats, frac: f64)
               -> PruneContext<'a> {
        let w = Workload::new(8, 64);
        let budget = mem.budget_bytes(w, frac);
        PruneContext { mem, probe, workload: w, budget_bytes: budget,
                       seed: 42 }
    }

    #[test]
    fn all_schemes_meet_the_budget() {
        let (_meta, mem, probe) = setup();
        for frac in [0.8, 0.6] {
            let c = ctx(&mem, &probe, frac);
            for s in [Scheme::LlmPrunerSim, Scheme::SliceGptSim,
                      Scheme::ShortGpt, Scheme::RandomDrop] {
                let mask = build_mask(s, &c).unwrap();
                assert!(c.fits(&mask), "{} at {frac}", s.name());
            }
        }
    }

    #[test]
    fn ffn_skip_cannot_fix_a_kv_bottleneck() {
        // A core paper claim (§2.2): parameter-only pruning fails when
        // the KV cache dominates — FFN-Skip frees no KV rows, so under a
        // tight budget at a KV-heavy workload it exhausts all FFN blocks
        // and still violates the budget.
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.6);
        let mask = build_mask(Scheme::FfnSkip, &c).unwrap();
        // all FFN blocks gone...
        assert_eq!(mask.dropped_blocks().len(), 6);
        // ...and the budget is still not met.
        assert!(!c.fits(&mask));
        // MHA-Drop, which frees KV, does meet the same budget.
        let mask2 = build_mask(Scheme::MhaDrop, &c).unwrap();
        assert!(c.fits(&mask2));
    }

    #[test]
    fn mha_drop_frees_kv_first() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.8);
        let mask = build_mask(Scheme::MhaDrop, &c).unwrap();
        // only MHA blocks removed
        for b in mask.dropped_blocks() {
            assert!(b.is_mha());
        }
        // most redundant layer (5) dropped first
        assert!(mask.block_dropped(BlockId::Mha(5)));
    }

    #[test]
    fn ffn_skip_only_touches_ffn() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.8);
        let mask = build_mask(Scheme::FfnSkip, &c).unwrap();
        assert!(!mask.dropped_blocks().is_empty());
        for b in mask.dropped_blocks() {
            assert!(!b.is_mha());
        }
    }

    #[test]
    fn short_gpt_removes_whole_layers() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.6);
        let mask = build_mask(Scheme::ShortGpt, &c).unwrap();
        // each fully-dropped layer has both of its blocks gone, except
        // possibly the last (partial) layer in the drop order
        let dropped = mask.dropped_blocks();
        let mha: Vec<usize> = dropped.iter().filter(|b| b.is_mha())
            .map(|b| b.layer()).collect();
        let ffn: Vec<usize> = dropped.iter().filter(|b| !b.is_mha())
            .map(|b| b.layer()).collect();
        assert!((mha.len() as i64 - ffn.len() as i64).abs() <= 1);
    }

    #[test]
    fn llm_pruner_protects_first_and_last_layer() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.7);
        let mask = build_mask(Scheme::LlmPrunerSim, &c).unwrap();
        assert_eq!(mask.active_heads(0), 4);
        assert_eq!(mask.active_ffn_channels(0), 96);
        assert_eq!(mask.active_heads(5), 4);
        assert_eq!(mask.active_ffn_channels(5), 96);
        assert!(c.fits(&mask));
    }

    #[test]
    fn slice_gpt_slices_deeper_layers_harder() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.6);
        let mask = build_mask(Scheme::SliceGptSim, &c).unwrap();
        assert!(c.fits(&mask));
        let shallow = mask.active_ffn_channels(0);
        let deep = mask.active_ffn_channels(5);
        assert!(deep <= shallow, "deep {deep} shallow {shallow}");
    }

    #[test]
    fn random_drop_is_seed_deterministic() {
        let (_meta, mem, probe) = setup();
        let c = ctx(&mem, &probe, 0.6);
        let a = build_mask(Scheme::RandomDrop, &c).unwrap();
        let b = build_mask(Scheme::RandomDrop, &c).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn eval_schemes_meet_budget_on_synthetic_model() {
        use crate::runtime::SyntheticEvaluator;
        let (meta, mem, probe) = setup();
        let damage: Vec<f64> =
            (0..12).map(|i| 0.05 + 0.01 * i as f64).collect();
        let mut ev = SyntheticEvaluator::new(meta, 2.0, damage, 0.0);
        let mut gsi = GsiEngine::new(&mut ev);
        let c = ctx(&mem, &probe, 0.6);
        for s in [Scheme::OneShot, Scheme::RapGreedy] {
            let mask = build_mask_eval(s, &c, &mut gsi).unwrap();
            assert!(c.fits(&mask), "{}", s.name());
        }
    }
}

//! The RAP controller: maps (observed workload, instantaneous memory) to a
//! pruning mask at serving time (paper Algorithm 3 embedded in a server).
//!
//! Policies:
//!   * `Policy::Static`  — a fixed mask chosen at startup (how every
//!     baseline scheme deploys);
//!   * `Policy::GsiGreedy` — recalibrated greedy pruning to the current
//!     budget (RAP without the RL agent's learned trade-offs);
//!   * `Policy::Dqn`     — the trained agent steps the pruning MDP
//!     (Algorithm 3) against the live (workload, budget) state.
//!
//! Decisions are cached on a (budget%, batch, seqlen) grid: the paper's
//! "negligible controller overhead" claim holds because a policy step is
//! an MLP rollout plus GSI lookups that are memoized across decisions.

use std::collections::HashMap;

use anyhow::Result;

use crate::agent::dqn::DqnAgent;
use crate::agent::env::{EnvConfig, PruneEnv};
use crate::gsi::GsiEngine;
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::server::kv::KvPolicy;
use crate::model_meta::ModelMeta;
use crate::runtime::{NllEvaluator, Runtime};

/// NllEvaluator over a borrowed runtime + fixed calibration batch.
pub struct BorrowedEvaluator<'a> {
    pub rt: &'a mut Runtime,
    pub tokens: &'a [i32],
    pub batch: usize,
    pub seqlen: usize,
}

impl NllEvaluator for BorrowedEvaluator<'_> {
    fn meta(&self) -> &ModelMeta {
        self.rt.meta()
    }

    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64> {
        self.rt.mean_nll(self.batch, self.seqlen, self.tokens, mask)
    }
}

pub enum Policy {
    /// Fixed mask (baselines / dense).
    Static(PruneMask),
    /// GSI-greedy to the live budget.
    GsiGreedy,
    /// Trained DQN (Algorithm 3).
    Dqn(Box<DqnAgent>),
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static(_) => "static",
            Policy::GsiGreedy => "rap-gsi-greedy",
            Policy::Dqn(_) => "rap-dqn",
        }
    }
}

/// Default floor on the retained-parameter fraction for
/// [`Controller::min_viable_mask`]: the controller will not report a
/// mask below this quality as reachable, even though the raw action
/// space could prune further. Keeping the floor above what `decide`
/// might actually deploy makes mask-elastic accounting conservative:
/// `min_viable` never *under*-estimates the cheapest real footprint.
pub const DEFAULT_MIN_MASK_FRACTION: f64 = 0.3;

/// Default KV compression floor: the most aggressive per-sequence
/// policy pressure may deploy (the KV leg of the joint `min_viable`).
/// Window+sink token eviction; the window is kept comfortably above the
/// synthetic corpus's copy lag so the evalharness MCQ accuracy stays at
/// the dense level (see `evalharness::mcq::policy_accuracy`).
pub const DEFAULT_KV_SINK: usize = 4;
pub const DEFAULT_KV_RECENT: usize = 48;

/// The default KV floor policy (see [`DEFAULT_KV_SINK`] /
/// [`DEFAULT_KV_RECENT`]).
pub fn default_kv_floor() -> KvPolicy {
    KvPolicy::WindowSink { sink: DEFAULT_KV_SINK,
                           recent: DEFAULT_KV_RECENT }
}

pub struct Controller {
    pub policy: Policy,
    mem: MemoryModel,
    /// Calibration batch for GSI at decision time (b=1 bucket: cheap).
    calib_tokens: Vec<i32>,
    calib_batch: usize,
    calib_seqlen: usize,
    /// Floor on the retained-parameter fraction of the min-viable mask.
    min_mask_fraction: f64,
    /// Compression floor on the KV axis: the most aggressive
    /// per-sequence policy pressure may deploy. `None` disables the KV
    /// leg of the joint lattice (mask-only elasticity, the pre-PR-9
    /// behavior).
    kv_floor: Option<KvPolicy>,
    /// Persistent GSI memo shared across decisions.
    memo: HashMap<u64, f64>,
    /// Decision cache keyed by (budget%, batch, seqlen-bucket).
    cache: HashMap<(u32, usize, usize), PruneMask>,
    /// Cached min-viable mask. A single slot: today's floor predicate
    /// reads neither the workload nor the budget, so the answer is the
    /// same for every query (a workload-conditioned floor — see the
    /// ROADMAP follow-up — would turn this into a keyed cache). Cleared
    /// by [`Controller::invalidate_outlook`] /
    /// [`Controller::with_min_mask_fraction`].
    floor_cache: Option<PruneMask>,
    pub decisions: u64,
    pub cache_hits: u64,
}

impl Controller {
    pub fn new(policy: Policy, mem: MemoryModel, calib_tokens: Vec<i32>,
               calib_seqlen: usize) -> Controller {
        Controller { policy, mem, calib_tokens, calib_batch: 1,
                     calib_seqlen,
                     min_mask_fraction: DEFAULT_MIN_MASK_FRACTION,
                     kv_floor: Some(default_kv_floor()),
                     memo: HashMap::new(),
                     cache: HashMap::new(),
                     floor_cache: None,
                     decisions: 0, cache_hits: 0 }
    }

    /// Use a different compiled score bucket for calibration (models
    /// without the (1, 128) bucket, e.g. rap-tiny's (4, 64)).
    pub fn with_calib_bucket(mut self, batch: usize, seqlen: usize)
                             -> Controller {
        self.calib_batch = batch;
        self.calib_seqlen = seqlen;
        self
    }

    /// Override the retained-parameter floor used by
    /// [`Controller::min_viable_mask`].
    pub fn with_min_mask_fraction(mut self, f: f64) -> Controller {
        self.min_mask_fraction = f.clamp(0.0, 1.0);
        self.floor_cache = None;
        self
    }

    /// Override (or clear) the KV compression floor.
    pub fn with_kv_floor(mut self, floor: Option<KvPolicy>)
                         -> Controller {
        self.kv_floor = floor;
        self
    }

    /// The KV compression floor pressure may deploy, if any.
    pub fn kv_floor(&self) -> Option<KvPolicy> {
        self.kv_floor
    }

    /// Whether this controller can actually move the mask at runtime.
    pub fn adaptive(&self) -> bool {
        !matches!(self.policy, Policy::Static(_))
    }

    /// Drop cached min-viable masks (call if the mask space or the
    /// importance landscape changes — today neither does at runtime,
    /// but the invalidation point is part of the outlook contract).
    pub fn invalidate_outlook(&mut self) {
        self.floor_cache = None;
    }

    /// The cheapest mask this controller is allowed to reach for the
    /// observed workload: the GSI-greedy removal prefix (least-damaging
    /// blocks first, recalibrated after every removal — the same
    /// machinery `decide` walks) taken down to — and never past — the
    /// retained-parameter floor: the removal that would cross below it
    /// is not applied, so the reported mask's quality is always at
    /// least the floor. For a static policy the mask cannot move, so
    /// the deployed mask itself is returned. Cached (the floor
    /// predicate reads neither workload nor budget; the workload
    /// parameter is the seam for a learned, workload-conditioned
    /// floor); NLL evaluations share the decision memo, so a cache
    /// miss is a handful of memoized lookups, not a fresh calibration.
    pub fn min_viable_mask(&mut self, rt: &mut Runtime,
                           _workload: Workload) -> Result<PruneMask> {
        if let Policy::Static(m) = &self.policy {
            return Ok(m.clone());
        }
        if let Some(m) = &self.floor_cache {
            return Ok(m.clone());
        }
        let mut ev = BorrowedEvaluator { rt, tokens: &self.calib_tokens,
                                         batch: self.calib_batch,
                                         seqlen: self.calib_seqlen };
        let memo = std::mem::take(&mut self.memo);
        let mut gsi = GsiEngine::with_memo(&mut ev, memo);
        let meta = self.mem.meta().clone();
        let floor = self.min_mask_fraction;
        let res = gsi.greedy(&PruneMask::full(&meta), |m| {
            m.param_fraction(&meta) <= floor
        })?;
        self.memo = gsi.take_memo();
        // The greedy stop fires at the first mask AT OR BELOW the
        // floor; with block granularity that final removal overshoots.
        // Keep the deepest mask that still honors the floor.
        let mut mask = PruneMask::full(&meta);
        for b in res.order {
            let cand = mask.with_block_dropped(b);
            if cand.param_fraction(&meta) < floor {
                break;
            }
            mask = cand;
        }
        self.floor_cache = Some(mask.clone());
        Ok(mask)
    }

    /// Decide a mask for the observed workload and available memory.
    pub fn decide(&mut self, rt: &mut Runtime, workload: Workload,
                  avail_bytes: usize) -> Result<PruneMask> {
        self.decisions += 1;
        if let Policy::Static(m) = &self.policy {
            return Ok(m.clone());
        }
        let dense_peak = self.mem.dense_peak_bytes(workload).max(1);
        let frac = (avail_bytes as f64 / dense_peak as f64).min(1.5);
        // bucket to 5% so the cache is effective
        let key = ((frac * 20.0).floor() as u32,
                   workload.batch, workload.seqlen);
        if let Some(m) = self.cache.get(&key) {
            self.cache_hits += 1;
            return Ok(m.clone());
        }
        let mut ev = BorrowedEvaluator { rt, tokens: &self.calib_tokens,
                                         batch: self.calib_batch,
                                         seqlen: self.calib_seqlen };
        let memo = std::mem::take(&mut self.memo);
        let mask = match &self.policy {
            // lint:allow(hot-path-panic): static masks return earlier
            Policy::Static(_) => unreachable!(),
            Policy::GsiGreedy => {
                let mut gsi = GsiEngine::with_memo(&mut ev, memo);
                let mem = self.mem.clone();
                let res = gsi.greedy(&PruneMask::full(mem.meta()), |m| {
                    mem.peak_bytes(m, workload) <= avail_bytes
                })?;
                let mut mask = PruneMask::full(mem.meta());
                for b in res.order {
                    mask.drop_block(b);
                }
                self.memo = gsi.take_memo();
                mask
            }
            Policy::Dqn(agent) => {
                let mut env = PruneEnv::with_memo(
                    &mut ev, EnvConfig::default(), memo);
                let mask = crate::agent::online_prune(
                    agent, &mut env, workload, frac.min(1.0))?;
                self.memo = env.take_memo();
                mask
            }
        };
        self.cache.insert(key, mask.clone());
        Ok(mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parts() -> (Runtime, MemoryModel) {
        let meta = ModelMeta::synthetic("c", 4, 128, 8, 4, 512, 512, 256);
        let rt = Runtime::synthetic(meta.clone(), 5);
        (rt, MemoryModel::new(&meta))
    }

    #[test]
    fn static_min_viable_is_the_deployed_mask() {
        let (mut rt, mem) = parts();
        let mask = PruneMask::full(mem.meta());
        let mut c = Controller::new(Policy::Static(mask.clone()), mem,
                                    vec![0; 128], 128)
            .with_calib_bucket(1, 128);
        assert!(!c.adaptive());
        let mv = c.min_viable_mask(&mut rt, Workload::new(1, 64)).unwrap();
        assert_eq!(mv, mask);
    }

    #[test]
    fn adaptive_min_viable_reaches_the_floor_and_caches() {
        let (mut rt, mem) = parts();
        let meta = mem.meta().clone();
        let mut c = Controller::new(Policy::GsiGreedy, mem,
                                    vec![0; 128], 128)
            .with_calib_bucket(1, 128)
            .with_min_mask_fraction(0.3);
        assert!(c.adaptive());
        let w = Workload::new(4, 64);
        let mv = c.min_viable_mask(&mut rt, w).unwrap();
        // pruned down toward — but never past — the floor
        let frac = mv.param_fraction(&meta);
        assert!(frac >= 0.3, "floor undershot: {frac}");
        assert!(frac < 0.55, "barely pruned: {frac}");
        // whole blocks only (the controller's action space)
        for l in 0..meta.n_layers {
            let h = mv.active_heads(l);
            assert!(h == 0 || h == meta.n_heads);
            let f = mv.active_ffn_channels(l);
            assert!(f == 0 || f == meta.d_ff);
        }
        // cached: same workload bucket returns the same mask
        let again = c.min_viable_mask(&mut rt, w).unwrap();
        assert_eq!(mv, again);
        // invalidation clears the cache without changing the answer
        c.invalidate_outlook();
        let third = c.min_viable_mask(&mut rt, w).unwrap();
        assert_eq!(mv, third);
    }

    #[test]
    fn kv_floor_defaults_on_and_can_be_cleared() {
        let (_rt, mem) = parts();
        let c = Controller::new(Policy::GsiGreedy, mem,
                                vec![0; 128], 128);
        assert_eq!(c.kv_floor(), Some(default_kv_floor()));
        assert_eq!(default_kv_floor().token_cap(),
                   DEFAULT_KV_SINK + DEFAULT_KV_RECENT);
        let c = c.with_kv_floor(None);
        assert_eq!(c.kv_floor(), None);
    }

    #[test]
    fn min_viable_is_cheaper_than_dense() {
        let (mut rt, mem) = parts();
        let meta = mem.meta().clone();
        let mut c = Controller::new(Policy::GsiGreedy, mem.clone(),
                                    vec![0; 128], 128)
            .with_calib_bucket(1, 128);
        let mv = c.min_viable_mask(&mut rt, Workload::new(1, 32)).unwrap();
        let w = Workload::new(1, 32);
        assert!(mem.peak_bytes(&mv, w)
                    < mem.peak_bytes(&PruneMask::full(&meta), w));
    }
}

//! The RAP controller: maps (observed workload, instantaneous memory) to a
//! pruning mask at serving time (paper Algorithm 3 embedded in a server).
//!
//! Policies:
//!   * `Policy::Static`  — a fixed mask chosen at startup (how every
//!     baseline scheme deploys);
//!   * `Policy::GsiGreedy` — recalibrated greedy pruning to the current
//!     budget (RAP without the RL agent's learned trade-offs);
//!   * `Policy::Dqn`     — the trained agent steps the pruning MDP
//!     (Algorithm 3) against the live (workload, budget) state.
//!
//! Decisions are cached on a (budget%, batch, seqlen) grid: the paper's
//! "negligible controller overhead" claim holds because a policy step is
//! an MLP rollout plus GSI lookups that are memoized across decisions.

use std::collections::HashMap;

use anyhow::Result;

use crate::agent::dqn::DqnAgent;
use crate::agent::env::{EnvConfig, PruneEnv};
use crate::gsi::GsiEngine;
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::model_meta::ModelMeta;
use crate::runtime::{NllEvaluator, Runtime};

/// NllEvaluator over a borrowed runtime + fixed calibration batch.
pub struct BorrowedEvaluator<'a> {
    pub rt: &'a mut Runtime,
    pub tokens: &'a [i32],
    pub batch: usize,
    pub seqlen: usize,
}

impl NllEvaluator for BorrowedEvaluator<'_> {
    fn meta(&self) -> &ModelMeta {
        self.rt.meta()
    }

    fn eval_nll(&mut self, mask: &PruneMask) -> Result<f64> {
        self.rt.mean_nll(self.batch, self.seqlen, self.tokens, mask)
    }
}

pub enum Policy {
    /// Fixed mask (baselines / dense).
    Static(PruneMask),
    /// GSI-greedy to the live budget.
    GsiGreedy,
    /// Trained DQN (Algorithm 3).
    Dqn(Box<DqnAgent>),
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static(_) => "static",
            Policy::GsiGreedy => "rap-gsi-greedy",
            Policy::Dqn(_) => "rap-dqn",
        }
    }
}

pub struct Controller {
    pub policy: Policy,
    mem: MemoryModel,
    /// Calibration batch for GSI at decision time (b=1 bucket: cheap).
    calib_tokens: Vec<i32>,
    calib_batch: usize,
    calib_seqlen: usize,
    /// Persistent GSI memo shared across decisions.
    memo: HashMap<u64, f64>,
    /// Decision cache keyed by (budget%, batch, seqlen-bucket).
    cache: HashMap<(u32, usize, usize), PruneMask>,
    pub decisions: u64,
    pub cache_hits: u64,
}

impl Controller {
    pub fn new(policy: Policy, mem: MemoryModel, calib_tokens: Vec<i32>,
               calib_seqlen: usize) -> Controller {
        Controller { policy, mem, calib_tokens, calib_batch: 1,
                     calib_seqlen, memo: HashMap::new(),
                     cache: HashMap::new(), decisions: 0, cache_hits: 0 }
    }

    /// Use a different compiled score bucket for calibration (models
    /// without the (1, 128) bucket, e.g. rap-tiny's (4, 64)).
    pub fn with_calib_bucket(mut self, batch: usize, seqlen: usize)
                             -> Controller {
        self.calib_batch = batch;
        self.calib_seqlen = seqlen;
        self
    }

    /// Decide a mask for the observed workload and available memory.
    pub fn decide(&mut self, rt: &mut Runtime, workload: Workload,
                  avail_bytes: usize) -> Result<PruneMask> {
        self.decisions += 1;
        if let Policy::Static(m) = &self.policy {
            return Ok(m.clone());
        }
        let dense_peak = self.mem.dense_peak_bytes(workload).max(1);
        let frac = (avail_bytes as f64 / dense_peak as f64).min(1.5);
        // bucket to 5% so the cache is effective
        let key = ((frac * 20.0).floor() as u32,
                   workload.batch, workload.seqlen);
        if let Some(m) = self.cache.get(&key) {
            self.cache_hits += 1;
            return Ok(m.clone());
        }
        let mut ev = BorrowedEvaluator { rt, tokens: &self.calib_tokens,
                                         batch: self.calib_batch,
                                         seqlen: self.calib_seqlen };
        let memo = std::mem::take(&mut self.memo);
        let mask = match &self.policy {
            Policy::Static(_) => unreachable!(),
            Policy::GsiGreedy => {
                let mut gsi = GsiEngine::with_memo(&mut ev, memo);
                let mem = self.mem.clone();
                let res = gsi.greedy(&PruneMask::full(mem.meta()), |m| {
                    mem.peak_bytes(m, workload) <= avail_bytes
                })?;
                let mut mask = PruneMask::full(mem.meta());
                for b in res.order {
                    mask.drop_block(b);
                }
                self.memo = gsi.take_memo();
                mask
            }
            Policy::Dqn(agent) => {
                let mut env = PruneEnv::with_memo(
                    &mut ev, EnvConfig::default(), memo);
                let mask = crate::agent::online_prune(
                    agent, &mut env, workload, frac.min(1.0))?;
                self.memo = env.take_memo();
                mask
            }
        };
        self.cache.insert(key, mask.clone());
        Ok(mask)
    }
}

//! Serving metrics: per-request latency, throughput, memory trace, OOM
//! events — the measurement layer behind Fig 5 and the end-to-end example.

use crate::util::stats::{mean, percentile};

#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: f64,
    pub first_token_at: f64,
    pub finished_at: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

impl RequestRecord {
    pub fn latency(&self) -> f64 {
        self.finished_at - self.arrival
    }

    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MemSample {
    pub t: f64,
    pub used: usize,
    pub available: usize,
    pub param_bytes: usize,
    pub kv_bytes: usize,
}

#[derive(Default)]
pub struct Metrics {
    pub completed: Vec<RequestRecord>,
    pub mem_trace: Vec<MemSample>,
    /// True OOMs only: pressure that not even the min-viable mask could
    /// absorb (under mask-elastic accounting; with it disabled, any
    /// pressure under the current mask counts, as before).
    pub oom_events: u64,
    /// Memory spikes absorbed purely by mask-shrinking — pressure under
    /// the current mask that fit within the min-viable footprint, so no
    /// work was shed and no OOM was charged.
    pub absorbed_spikes: u64,
    /// Head-of-line requests permanently rejected (admission control).
    pub rejected: u64,
    /// In-flight sequences evicted and requeued locally under memory
    /// pressure (they restart from their prompt). Parked-for-migration
    /// victims are NOT counted here — migration is what avoids these.
    pub evictions: u64,
    pub decode_steps: u64,
    pub prefills: u64,
    pub tokens_generated: u64,
    pub mask_switches: u64,
    /// Host wall-clock seconds spent in controller decisions
    /// (accumulated from `std::time::Instant` — nondeterministic; see
    /// `ServeReport::wall`).
    pub controller_secs: f64,
    pub exec_secs: f64,
}

impl Metrics {
    pub fn report(&self, wall_secs: f64) -> ServeReport {
        let lats: Vec<f64> =
            self.completed.iter().map(|r| r.latency()).collect();
        let ttfts: Vec<f64> =
            self.completed.iter().map(|r| r.ttft()).collect();
        ServeReport {
            completed: self.completed.len(),
            oom_events: self.oom_events,
            absorbed_spikes: self.absorbed_spikes,
            rejected: self.rejected,
            evictions: self.evictions,
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            tokens_generated: self.tokens_generated,
            mask_switches: self.mask_switches,
            mean_latency: mean(&lats),
            p50_latency: percentile(&lats, 50.0),
            p95_latency: percentile(&lats, 95.0),
            p99_latency: percentile(&lats, 99.0),
            mean_ttft: mean(&ttfts),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            throughput_rps: self.completed.len() as f64 / wall_secs,
            throughput_tps: self.tokens_generated as f64 / wall_secs,
            wall: WallClockStats { controller_secs: self.controller_secs },
            exec_secs: self.exec_secs,
        }
    }
}

/// Host wall-clock measurements. These are real seconds on the machine
/// running the simulation — nondeterministic across runs by nature — so
/// they live in their own section that is NEVER serialized into report
/// JSON (the byte-identical-per-seed determinism contract; guarded by
/// `fleet_report_json_excludes_wall_clock_fields` in
/// `tests/elastic_fleet.rs`). Print freely; serialize never.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallClockStats {
    /// Seconds spent inside controller decisions (`std::time::Instant`
    /// around `Controller::decide` — the paper's "<1% overhead" path).
    pub controller_secs: f64,
}

/// Aggregated serving results.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    /// True OOM events (see `Metrics::oom_events`).
    pub oom_events: u64,
    /// Pressure spikes absorbed by mask-shrinking alone.
    pub absorbed_spikes: u64,
    /// Permanent admission rejections.
    pub rejected: u64,
    /// Local evict-and-requeue events (see `Metrics::evictions`).
    pub evictions: u64,
    pub decode_steps: u64,
    pub prefills: u64,
    pub tokens_generated: u64,
    pub mask_switches: u64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub throughput_rps: f64,
    pub throughput_tps: f64,
    /// Wall-clock section — never serialized (see [`WallClockStats`]).
    pub wall: WallClockStats,
    /// Modeled (sim backend) or measured (PJRT) compute seconds. On the
    /// sim backend this is deterministic per seed.
    pub exec_secs: f64,
}

impl ServeReport {
    pub fn print(&self, label: &str) {
        println!("── serve report: {label}");
        println!("   completed        {:>10}", self.completed);
        println!("   rejected         {:>10}", self.rejected);
        println!("   evictions        {:>10}", self.evictions);
        println!("   OOM events       {:>10}", self.oom_events);
        println!("   absorbed spikes  {:>10}", self.absorbed_spikes);
        println!("   prefills         {:>10}", self.prefills);
        println!("   decode steps     {:>10}", self.decode_steps);
        println!("   tokens generated {:>10}", self.tokens_generated);
        println!("   mask switches    {:>10}", self.mask_switches);
        println!("   latency mean/p50/p95/p99  {:.3}s / {:.3}s / {:.3}s \
                  / {:.3}s",
                 self.mean_latency, self.p50_latency, self.p95_latency,
                 self.p99_latency);
        println!("   ttft mean/p50/p99  {:.3}s / {:.3}s / {:.3}s",
                 self.mean_ttft, self.p50_ttft, self.p99_ttft);
        println!("   throughput       {:>7.2} req/s  {:>8.1} tok/s",
                 self.throughput_rps, self.throughput_tps);
        println!("   controller time  {:>9.3}s   exec time {:>9.3}s",
                 self.wall.controller_secs, self.exec_secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounting() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.completed.push(RequestRecord {
                id: i,
                arrival: i as f64,
                first_token_at: i as f64 + 0.5,
                finished_at: i as f64 + 1.0 + i as f64 * 0.1,
                prompt_len: 8,
                gen_len: 4,
            });
            m.tokens_generated += 4;
        }
        let r = m.report(10.0);
        assert_eq!(r.completed, 10);
        assert!((r.throughput_rps - 1.0).abs() < 1e-9);
        assert!((r.throughput_tps - 4.0).abs() < 1e-9);
        assert!(r.p95_latency >= r.p50_latency);
        assert!(r.p99_latency >= r.p95_latency);
        assert!((r.mean_ttft - 0.5).abs() < 1e-9);
        assert!((r.p50_ttft - 0.5).abs() < 1e-9);
        assert!(r.p99_ttft >= r.p50_ttft);
    }
}

//! Serving metrics: per-request latency, throughput, memory trace, OOM
//! events, and — since the request API — per-tenant outcome ledgers
//! (deadline hit-rates, cancellations) behind Fig 5 and the end-to-end
//! example.

use std::collections::{BTreeMap, HashMap};

use crate::api::{Outcome, PriorityClass, SubmitRequest, Tenant};
use crate::util::stats::{mean, percentile};

#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub tenant: Tenant,
    pub priority: PriorityClass,
    /// Absolute completion deadline, when the request carried one.
    pub deadline: Option<f64>,
    pub arrival: f64,
    pub first_token_at: f64,
    pub finished_at: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

impl RequestRecord {
    pub fn latency(&self) -> f64 {
        self.finished_at - self.arrival
    }

    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MemSample {
    pub t: f64,
    pub used: usize,
    pub available: usize,
    pub param_bytes: usize,
    pub kv_bytes: usize,
}

/// One tenant's slice of the outcome ledger. `finished` counts in-SLO
/// completions only; a late finish lands in `deadline_missed` (its
/// latency record still exists for the TTFT percentiles).
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantCounts {
    /// Requests submitted to this engine for the tenant.
    pub submitted: u64,
    /// Terminal `Done` (finished, SLO honored or absent).
    pub finished: u64,
    /// Terminal `DeadlineMissed` (finished late, expired in queue, or
    /// shed after expiry).
    pub deadline_missed: u64,
    pub cancelled: u64,
    pub rejected: u64,
    /// Of the terminal requests that carried a deadline, how many hit
    /// it.
    pub deadline_hits: u64,
    pub deadline_total: u64,
}

impl TenantCounts {
    /// Fraction of deadline-carrying terminal requests that hit their
    /// deadline (NaN when none carried one). Cancels are excluded from
    /// the denominator (user-initiated, not a serving failure);
    /// rejections with a deadline count as misses.
    pub fn deadline_hit_rate(&self) -> f64 {
        self.deadline_hits as f64 / self.deadline_total as f64
    }

    /// Book one terminal outcome — the single home of the ledger's
    /// outcome and hit-rate-denominator rules (used by engine-level
    /// `Metrics::note_terminal` and the fleet's ingress-terminal
    /// merge).
    pub fn book(&mut self, outcome: Outcome, had_deadline: bool) {
        let hd = had_deadline as u64;
        match outcome {
            Outcome::Done => {
                self.finished += 1;
                self.deadline_total += hd;
                self.deadline_hits += hd;
            }
            Outcome::DeadlineMissed => {
                self.deadline_missed += 1;
                self.deadline_total += hd;
            }
            Outcome::Cancelled => self.cancelled += 1,
            Outcome::Rejected => {
                self.rejected += 1;
                self.deadline_total += hd;
            }
        }
    }

    pub fn merge(&mut self, o: &TenantCounts) {
        self.submitted += o.submitted;
        self.finished += o.finished;
        self.deadline_missed += o.deadline_missed;
        self.cancelled += o.cancelled;
        self.rejected += o.rejected;
        self.deadline_hits += o.deadline_hits;
        self.deadline_total += o.deadline_total;
    }
}

#[derive(Default)]
pub struct Metrics {
    pub completed: Vec<RequestRecord>,
    pub mem_trace: Vec<MemSample>,
    /// True OOMs only: pressure that not even the min-viable mask could
    /// absorb (under mask-elastic accounting; with it disabled, any
    /// pressure under the current mask counts, as before).
    pub oom_events: u64,
    /// Memory spikes absorbed purely by mask-shrinking — pressure under
    /// the current mask that fit within the min-viable footprint, so no
    /// work was shed and no OOM was charged.
    pub absorbed_spikes: u64,
    /// Pressure spikes where absorbing required engaging the KV axis:
    /// at least one resident cache was compressed to the floor policy
    /// (a subset of the absorption events; mask-only spikes don't
    /// count here).
    pub compressed_spikes: u64,
    /// KV bytes freed by in-place compression under pressure.
    pub kv_bytes_reclaimed: u64,
    /// Head-of-line requests permanently rejected (admission control).
    pub rejected: u64,
    /// In-flight sequences evicted and requeued locally under memory
    /// pressure (they restart from their prompt). Parked-for-migration
    /// victims are NOT counted here — migration is what avoids these.
    pub evictions: u64,
    /// Requests reclaimed through the lifecycle API's `cancel`.
    pub cancelled: u64,
    /// Terminal `DeadlineMissed` outcomes (late finishes + expired work
    /// shed or purged).
    pub deadline_missed: u64,
    pub decode_steps: u64,
    pub prefills: u64,
    pub tokens_generated: u64,
    pub mask_switches: u64,
    /// Crash-recovery checkpoint cycles that shipped anything
    /// (`EngineConfig::checkpoint_period_secs`).
    pub checkpoints_taken: u64,
    /// Interconnect bytes charged to checkpointing (deltas only).
    pub checkpoint_bytes: u64,
    /// Host wall-clock seconds spent in controller decisions
    /// (accumulated from `std::time::Instant` — nondeterministic; see
    /// `ServeReport::wall`).
    pub controller_secs: f64,
    pub exec_secs: f64,
    /// Per-tenant outcome ledger (deterministic name order).
    pub tenants: BTreeMap<Tenant, TenantCounts>,
    /// Terminal outcome per request id — the lifecycle API's lookup.
    outcomes: HashMap<u64, Outcome>,
}

impl Metrics {
    /// Terminal outcome of a request this engine finished, if any.
    pub fn outcome(&self, id: u64) -> Option<Outcome> {
        self.outcomes.get(&id).copied()
    }

    /// Book a submission (the `submit` entry point calls this once per
    /// request, at the engine it is first dispatched to).
    pub fn note_submitted(&mut self, req: &SubmitRequest) {
        self.tenants.entry(req.tenant.clone()).or_default().submitted +=
            1;
    }

    /// Book a terminal outcome: the lifecycle map plus the per-tenant
    /// ledger. Deadline totals count every terminal request that
    /// carried a deadline except cancels (user-initiated, not a
    /// serving failure); only `Done` ones count as hits — a rejected
    /// SLO-carrying request is a miss, not a statistical
    /// disappearance.
    pub fn note_terminal(&mut self, req: &SubmitRequest,
                         outcome: Outcome) {
        self.outcomes.insert(req.id, outcome);
        match outcome {
            Outcome::DeadlineMissed => self.deadline_missed += 1,
            Outcome::Cancelled => self.cancelled += 1,
            _ => {}
        }
        self.tenants
            .entry(req.tenant.clone())
            .or_default()
            .book(outcome, req.slo_deadline.is_some());
    }

    pub fn report(&self, wall_secs: f64) -> ServeReport {
        let lats: Vec<f64> =
            self.completed.iter().map(|r| r.latency()).collect();
        let ttfts: Vec<f64> =
            self.completed.iter().map(|r| r.ttft()).collect();
        let tenants = self
            .tenants
            .iter()
            .map(|(name, c)| {
                let tt: Vec<f64> = self
                    .completed
                    .iter()
                    .filter(|r| r.tenant == *name)
                    .map(|r| r.ttft())
                    .collect();
                TenantReport {
                    tenant: name.to_string(),
                    counts: *c,
                    p50_ttft: percentile(&tt, 50.0),
                    p99_ttft: percentile(&tt, 99.0),
                }
            })
            .collect();
        ServeReport {
            completed: self.completed.len(),
            oom_events: self.oom_events,
            absorbed_spikes: self.absorbed_spikes,
            compressed_spikes: self.compressed_spikes,
            kv_bytes_reclaimed: self.kv_bytes_reclaimed,
            rejected: self.rejected,
            evictions: self.evictions,
            cancelled: self.cancelled,
            deadline_missed: self.deadline_missed,
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            tokens_generated: self.tokens_generated,
            mask_switches: self.mask_switches,
            checkpoints_taken: self.checkpoints_taken,
            checkpoint_bytes: self.checkpoint_bytes,
            mean_latency: mean(&lats),
            p50_latency: percentile(&lats, 50.0),
            p95_latency: percentile(&lats, 95.0),
            p99_latency: percentile(&lats, 99.0),
            mean_ttft: mean(&ttfts),
            p50_ttft: percentile(&ttfts, 50.0),
            p99_ttft: percentile(&ttfts, 99.0),
            throughput_rps: self.completed.len() as f64 / wall_secs,
            throughput_tps: self.tokens_generated as f64 / wall_secs,
            wall: WallClockStats { controller_secs: self.controller_secs },
            exec_secs: self.exec_secs,
            tenants,
        }
    }
}

/// Host wall-clock measurements. These are real seconds on the machine
/// running the simulation — nondeterministic across runs by nature — so
/// they live in their own section that is NEVER serialized into report
/// JSON (the byte-identical-per-seed determinism contract; guarded by
/// `fleet_report_json_excludes_wall_clock_fields` in
/// `tests/elastic_fleet.rs`). Print freely; serialize never.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallClockStats {
    /// Seconds spent inside controller decisions (`std::time::Instant`
    /// around `Controller::decide` — the paper's "<1% overhead" path).
    pub controller_secs: f64,
}

/// One tenant's section of a [`ServeReport`].
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub tenant: String,
    pub counts: TenantCounts,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
}

impl TenantReport {
    /// See [`TenantCounts::deadline_hit_rate`].
    pub fn deadline_hit_rate(&self) -> f64 {
        self.counts.deadline_hit_rate()
    }
}

/// Aggregated serving results.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    /// True OOM events (see `Metrics::oom_events`).
    pub oom_events: u64,
    /// Pressure spikes absorbed by mask-shrinking alone.
    pub absorbed_spikes: u64,
    /// Absorptions that also compressed resident KV (see
    /// `Metrics::compressed_spikes`).
    pub compressed_spikes: u64,
    /// KV bytes freed by in-place compression under pressure.
    pub kv_bytes_reclaimed: u64,
    /// Permanent admission rejections.
    pub rejected: u64,
    /// Local evict-and-requeue events (see `Metrics::evictions`).
    pub evictions: u64,
    /// Requests reclaimed via the lifecycle API.
    pub cancelled: u64,
    /// Terminal `DeadlineMissed` outcomes.
    pub deadline_missed: u64,
    pub decode_steps: u64,
    pub prefills: u64,
    pub tokens_generated: u64,
    pub mask_switches: u64,
    /// Crash-recovery checkpoint cycles that shipped anything (see
    /// `Metrics::checkpoints_taken`).
    pub checkpoints_taken: u64,
    /// Interconnect bytes charged to checkpointing (deltas only).
    pub checkpoint_bytes: u64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub throughput_rps: f64,
    pub throughput_tps: f64,
    /// Wall-clock section — never serialized (see [`WallClockStats`]).
    pub wall: WallClockStats,
    /// Modeled (sim backend) or measured (PJRT) compute seconds. On the
    /// sim backend this is deterministic per seed.
    pub exec_secs: f64,
    /// Per-tenant sections, sorted by tenant name. A default-tenancy
    /// run has exactly one ("default").
    pub tenants: Vec<TenantReport>,
}

impl ServeReport {
    pub fn print(&self, label: &str) {
        println!("── serve report: {label}");
        println!("   completed        {:>10}", self.completed);
        println!("   rejected         {:>10}", self.rejected);
        println!("   evictions        {:>10}", self.evictions);
        println!("   cancelled        {:>10}", self.cancelled);
        println!("   deadline missed  {:>10}", self.deadline_missed);
        println!("   OOM events       {:>10}", self.oom_events);
        println!("   absorbed spikes  {:>10}", self.absorbed_spikes);
        if self.compressed_spikes > 0 {
            println!("   kv compressions  {:>10}   ({} bytes reclaimed)",
                     self.compressed_spikes, self.kv_bytes_reclaimed);
        }
        println!("   prefills         {:>10}", self.prefills);
        println!("   decode steps     {:>10}", self.decode_steps);
        println!("   tokens generated {:>10}", self.tokens_generated);
        println!("   mask switches    {:>10}", self.mask_switches);
        if self.checkpoints_taken > 0 {
            println!("   checkpoints      {:>10}   ({} bytes)",
                     self.checkpoints_taken, self.checkpoint_bytes);
        }
        println!("   latency mean/p50/p95/p99  {:.3}s / {:.3}s / {:.3}s \
                  / {:.3}s",
                 self.mean_latency, self.p50_latency, self.p95_latency,
                 self.p99_latency);
        println!("   ttft mean/p50/p99  {:.3}s / {:.3}s / {:.3}s",
                 self.mean_ttft, self.p50_ttft, self.p99_ttft);
        println!("   throughput       {:>7.2} req/s  {:>8.1} tok/s",
                 self.throughput_rps, self.throughput_tps);
        println!("   controller time  {:>9.3}s   exec time {:>9.3}s",
                 self.wall.controller_secs, self.exec_secs);
        self.print_tenants();
    }

    /// The per-tenant table, printed only when there is tenancy worth
    /// showing (more than one tenant, or any SLO in play).
    pub fn print_tenants(&self) {
        let interesting = self.tenants.len() > 1
            || self.tenants.iter().any(|t| t.counts.deadline_total > 0);
        if !interesting {
            return;
        }
        println!("   {:<14} {:>9} {:>8} {:>8} {:>8} {:>8} {:>9} {:>9}",
                 "tenant", "submitted", "done", "missed", "cancel",
                 "reject", "hit-rate", "p99 ttft");
        for t in &self.tenants {
            let hr = if t.counts.deadline_total > 0 {
                format!("{:>8.1}%", 100.0 * t.deadline_hit_rate())
            } else {
                "       —".to_string()
            };
            let p99 = if t.p99_ttft.is_finite() {
                format!("{:>8.3}s", t.p99_ttft)
            } else {
                "       —".to_string()
            };
            println!("   {:<14} {:>9} {:>8} {:>8} {:>8} {:>8} {} {}",
                     t.tenant, t.counts.submitted, t.counts.finished,
                     t.counts.deadline_missed, t.counts.cancelled,
                     t.counts.rejected, hr, p99);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{tenant, Outcome, SubmitRequest};

    fn record(id: u64, tenant_name: &str, arrival: f64)
              -> RequestRecord {
        RequestRecord {
            id,
            tenant: tenant(tenant_name),
            priority: PriorityClass::Normal,
            deadline: None,
            arrival,
            first_token_at: arrival + 0.5,
            finished_at: arrival + 1.0,
            prompt_len: 8,
            gen_len: 4,
        }
    }

    #[test]
    fn latency_accounting() {
        let mut m = Metrics::default();
        for i in 0..10 {
            let mut r = record(i, "default", i as f64);
            r.finished_at = i as f64 + 1.0 + i as f64 * 0.1;
            m.completed.push(r);
            m.tokens_generated += 4;
        }
        let r = m.report(10.0);
        assert_eq!(r.completed, 10);
        assert!((r.throughput_rps - 1.0).abs() < 1e-9);
        assert!((r.throughput_tps - 4.0).abs() < 1e-9);
        assert!(r.p95_latency >= r.p50_latency);
        assert!(r.p99_latency >= r.p95_latency);
        assert!((r.mean_ttft - 0.5).abs() < 1e-9);
        assert!((r.p50_ttft - 0.5).abs() < 1e-9);
        assert!(r.p99_ttft >= r.p50_ttft);
    }

    #[test]
    fn tenant_ledger_tracks_outcomes_and_hit_rate() {
        let mut m = Metrics::default();
        let hit = SubmitRequest::new(8, 4)
            .with_id(1)
            .with_tenant("a")
            .with_deadline(10.0);
        let miss = SubmitRequest::new(8, 4)
            .with_id(2)
            .with_tenant("a")
            .with_deadline(1.0);
        let free = SubmitRequest::new(8, 4).with_id(3).with_tenant("b");
        for r in [&hit, &miss, &free] {
            m.note_submitted(r);
        }
        m.note_terminal(&hit, Outcome::Done);
        m.note_terminal(&miss, Outcome::DeadlineMissed);
        m.note_terminal(&free, Outcome::Cancelled);
        assert_eq!(m.outcome(1), Some(Outcome::Done));
        assert_eq!(m.outcome(2), Some(Outcome::DeadlineMissed));
        assert_eq!(m.outcome(3), Some(Outcome::Cancelled));
        assert_eq!(m.outcome(99), None);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.deadline_missed, 1);
        m.completed.push(record(1, "a", 0.0));
        let rep = m.report(1.0);
        assert_eq!(rep.tenants.len(), 2);
        let a = &rep.tenants[0];
        assert_eq!(a.tenant, "a");
        assert_eq!(a.counts.submitted, 2);
        assert_eq!(a.counts.finished, 1);
        assert_eq!(a.counts.deadline_missed, 1);
        assert_eq!(a.counts.deadline_total, 2);
        assert_eq!(a.counts.deadline_hits, 1);
        assert!((a.deadline_hit_rate() - 0.5).abs() < 1e-12);
        let b = &rep.tenants[1];
        assert_eq!(b.tenant, "b");
        assert_eq!(b.counts.cancelled, 1);
        // tenants without a deadline never divide by zero into a panic
        assert!(b.deadline_hit_rate().is_nan());
    }

    #[test]
    fn tenant_counts_merge() {
        let mut a = TenantCounts { submitted: 2, finished: 1,
                                   deadline_missed: 1, cancelled: 0,
                                   rejected: 0, deadline_hits: 1,
                                   deadline_total: 2 };
        let b = TenantCounts { submitted: 3, finished: 3,
                               deadline_missed: 0, cancelled: 1,
                               rejected: 1, deadline_hits: 2,
                               deadline_total: 2 };
        a.merge(&b);
        assert_eq!(a.submitted, 5);
        assert_eq!(a.finished, 4);
        assert_eq!(a.deadline_hits, 3);
        assert_eq!(a.deadline_total, 4);
        assert_eq!(a.cancelled, 1);
        assert_eq!(a.rejected, 1);
    }
}

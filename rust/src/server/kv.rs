//! KV-cache manager: owns per-sequence caches, splices them into decode
//! batches, and does the mask-aware memory accounting (only layers whose
//! MHA block survives — and within GQA only live kv groups — count,
//! exactly like the paper's Eq. 4).
//!
//! Since PR-9 each sequence also carries a [`KvPolicy`] — the second
//! elasticity axis next to the param mask. Compression rewrites the
//! cache in place and breaks the old `total_tokens × per-token-bytes`
//! linearity, so byte accounting aggregates per-(policy-class) totals
//! incrementally: `bytes_used` stays O(layers · policy classes) and
//! never sweeps sequences.
//!
//! Layouts (flattened f32, row-major):
//!   per-sequence cache: [L, Hkv, S, Dh]   (from `prefill`, B axis removed)
//!   decode batch cache: [L, B, Hkv, S, Dh] (what `decode_b{B}` consumes)

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::mask::PruneMask;
use crate::model_meta::{ModelMeta, BYTES_PER_SCALAR};

/// Per-sequence KV compression policy. `Ord` so policy classes live in
/// a `BTreeMap` and every per-class walk is deterministic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KvPolicy {
    /// Full cache — every kv group, every token.
    Dense,
    /// Head-adaptive eviction à la FastGen (arXiv 2310.01801): keep the
    /// first `keep_groups` kv groups per layer, zero the rest.
    HeadDrop { keep_groups: usize },
    /// Window + attention-sink token eviction (arXiv 2509.03136): keep
    /// the first `sink` tokens and the last `recent`, drop the middle.
    WindowSink { sink: usize, recent: usize },
}

impl KvPolicy {
    /// Max tokens a sequence bills under this policy right after
    /// compression (it may grow past the cap again until the next
    /// `compress`). `usize::MAX` == uncapped.
    pub fn token_cap(&self) -> usize {
        match self {
            KvPolicy::Dense | KvPolicy::HeadDrop { .. } => usize::MAX,
            KvPolicy::WindowSink { sink, recent } => sink + recent,
        }
    }

    /// Max kv groups per layer this policy keeps materialized.
    pub fn group_cap(&self) -> usize {
        match self {
            KvPolicy::Dense | KvPolicy::WindowSink { .. } => usize::MAX,
            KvPolicy::HeadDrop { keep_groups } => *keep_groups,
        }
    }

    /// Physical length after compressing a cache of `len` tokens.
    pub fn compressed_len(&self, len: usize) -> usize {
        len.min(self.token_cap())
    }
}

/// One sequence's cached state.
#[derive(Clone, Debug)]
pub struct SeqCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Tokens currently materialized in the cache (== next write pos).
    pub len: usize,
    /// Compression policy the cache currently satisfies.
    pub policy: KvPolicy,
}

/// Incrementally-maintained totals for one policy class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct ClassTotals {
    seqs: usize,
    /// Σ len over the class's sequences.
    tokens: usize,
    /// Σ min(len, floor token cap) — the class's share of the
    /// compression floor projection.
    floor_tokens: usize,
}

pub struct KvManager {
    meta: ModelMeta,
    /// Keyed by sequence id; a `BTreeMap` so every whole-map walk
    /// (floor re-derivation, audit rescans) visits sequences in id
    /// order — hash order must never reach accounting or telemetry.
    seqs: BTreeMap<u64, SeqCache>,
    /// Running total of cached tokens across live sequences (kept in
    /// step by insert/remove/bump_lens/compress) — the dense-ceiling
    /// accounting is O(layers), it sits on the engine's pressure path
    /// and every router's scoring path.
    total_tokens: usize,
    /// Per-policy-class totals, maintained incrementally so
    /// `bytes_used`/`floor_bytes` are O(layers · classes), never
    /// O(sequences).
    classes: BTreeMap<KvPolicy, ClassTotals>,
    /// The compression floor: the most aggressive policy pressure may
    /// deploy. `floor_bytes` prices every resident sequence as if
    /// compressed down to it. `None` == no compression floor (rigid KV).
    floor: Option<KvPolicy>,
    /// High-water mark of bytes held (for reports).
    pub peak_bytes_seen: usize,
}

impl KvManager {
    pub fn new(meta: &ModelMeta) -> KvManager {
        KvManager { meta: meta.clone(), seqs: BTreeMap::new(),
                    total_tokens: 0, classes: BTreeMap::new(),
                    floor: None, peak_bytes_seen: 0 }
    }

    pub fn seq_elems(&self) -> usize {
        let m = &self.meta;
        m.n_layers * m.n_kv_heads * m.max_seq * m.head_dim()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_len(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    pub fn policy_of(&self, id: u64) -> Option<KvPolicy> {
        self.seqs.get(&id).map(|s| s.policy)
    }

    /// Total cached tokens across live sequences (post-compression
    /// physical lengths). Scales the dense ceiling: with every group
    /// restored and no token eviction, bytes would be this total times
    /// the dense per-token bytes.
    pub fn total_tokens(&self) -> usize {
        self.total_tokens
    }

    /// The deployed compression floor, if any.
    pub fn floor(&self) -> Option<KvPolicy> {
        self.floor
    }

    fn floor_token_cap(&self) -> usize {
        self.floor.map(|f| f.token_cap()).unwrap_or(usize::MAX)
    }

    fn floor_group_cap(&self) -> usize {
        self.floor.map(|f| f.group_cap()).unwrap_or(usize::MAX)
    }

    /// Install (or clear) the compression floor. Changing the floor
    /// re-derives every class's floor-token projection — O(sequences),
    /// but only on a floor change, never on the accounting hot path.
    pub fn set_floor(&mut self, floor: Option<KvPolicy>) {
        if self.floor == floor {
            return;
        }
        self.floor = floor;
        let cap = self.floor_token_cap();
        for t in self.classes.values_mut() {
            t.floor_tokens = 0;
        }
        for s in self.seqs.values() {
            if let Some(t) = self.classes.get_mut(&s.policy) {
                t.floor_tokens += s.len.min(cap);
            }
        }
        self.debug_audit();
    }

    fn class_add(&mut self, policy: KvPolicy, len: usize) {
        let cap = self.floor_token_cap();
        let t = self.classes.entry(policy).or_default();
        t.seqs += 1;
        t.tokens += len;
        t.floor_tokens += len.min(cap);
        self.total_tokens += len;
    }

    fn class_remove(&mut self, policy: KvPolicy, len: usize) {
        let cap = self.floor_token_cap();
        let t = self.classes.get_mut(&policy)
            // lint:allow(hot-path-panic): class books invariant — every
            // resident sequence's policy has a class entry (audited)
            .expect("class_remove: unknown policy class");
        t.seqs -= 1;
        t.tokens -= len;
        t.floor_tokens -= len.min(cap);
        if t.seqs == 0 {
            self.classes.remove(&policy);
        }
        self.total_tokens -= len;
    }

    /// Admit a sequence with its prefill-produced cache
    /// (`[L, 1, Hkv, S, Dh]` == `[L, Hkv, S, Dh]` flattened). New
    /// sequences enter dense; `compress` moves them between classes.
    pub fn insert(&mut self, id: u64, k: Vec<f32>, v: Vec<f32>,
                  prompt_len: usize, mask: &PruneMask) -> Result<()> {
        if k.len() != self.seq_elems() || v.len() != self.seq_elems() {
            bail!("cache size mismatch: got {}, want {}", k.len(),
                  self.seq_elems());
        }
        if let Some(old) = self.seqs.insert(
            id,
            SeqCache { k, v, len: prompt_len, policy: KvPolicy::Dense },
        ) {
            self.class_remove(old.policy, old.len);
        }
        self.class_add(KvPolicy::Dense, prompt_len);
        self.note_usage(mask);
        self.debug_audit();
        Ok(())
    }

    /// Borrow one sequence's cache without removing it — the periodic
    /// checkpoint path snapshots live caches in place.
    pub fn get(&self, id: u64) -> Option<&SeqCache> {
        self.seqs.get(&id)
    }

    pub fn remove(&mut self, id: u64) -> Option<SeqCache> {
        let removed = self.seqs.remove(&id);
        if let Some(s) = &removed {
            self.class_remove(s.policy, s.len);
        }
        self.debug_audit();
        removed
    }

    /// Per-token KV bytes under `mask` with at most `group_cap` kv
    /// groups per layer materialized.
    fn per_token_bytes_capped(&self, mask: &PruneMask,
                              group_cap: usize) -> usize {
        let dh = self.meta.head_dim();
        let mut per_token = 0usize;
        for l in 0..self.meta.n_layers {
            per_token += 2 * mask.active_kv_groups(l).min(group_cap)
                * dh * BYTES_PER_SCALAR;
        }
        per_token
    }

    /// Per-token KV bytes a sequence under `policy` pays under `mask`.
    pub fn per_token_bytes(&self, mask: &PruneMask,
                           policy: KvPolicy) -> usize {
        self.per_token_bytes_capped(mask, policy.group_cap())
    }

    /// Logical KV bytes for the *active* sequences under `mask`:
    /// Σ_class (class tokens) × (class per-token bytes under the
    /// mask). With every sequence dense this reduces exactly to the
    /// pre-compression `total_tokens × per-token-bytes` formula.
    pub fn bytes_used(&self, mask: &PruneMask) -> usize {
        self.classes
            .iter()
            .map(|(p, t)| {
                t.tokens * self.per_token_bytes_capped(mask, p.group_cap())
            })
            .sum()
    }

    /// Logical KV bytes under `mask` if every resident sequence were
    /// compressed down to the floor policy — the KV leg of the joint
    /// `min_viable`. Equals `bytes_used` when no floor is installed.
    /// O(layers · classes), maintained incrementally.
    pub fn floor_bytes(&self, mask: &PruneMask) -> usize {
        let fg = self.floor_group_cap();
        self.classes
            .iter()
            .map(|(p, t)| {
                t.floor_tokens
                    * self.per_token_bytes_capped(mask,
                                                  p.group_cap().min(fg))
            })
            .sum()
    }

    /// Dense ceiling: bytes the resident tokens would cost with no
    /// pruning and no compression-restricted groups.
    pub fn dense_bytes(&self) -> usize {
        self.total_tokens * self.meta.n_layers
            * self.meta.kv_bytes_per_token_layer(self.meta.n_kv_heads)
    }

    /// Bytes `compress(id, policy)` would reclaim under `mask`, without
    /// touching the cache. Zero for unknown ids.
    pub fn reclaim_estimate(&self, id: u64, policy: KvPolicy,
                            mask: &PruneMask) -> usize {
        let Some(s) = self.seqs.get(&id) else { return 0 };
        let before = s.len * self.per_token_bytes(mask, s.policy);
        let new_len = policy.compressed_len(s.len);
        let new_groups = s.policy.group_cap().min(policy.group_cap());
        let after =
            new_len * self.per_token_bytes_capped(mask, new_groups);
        before.saturating_sub(after)
    }

    /// Compress one sequence in place to `policy`, rewriting the cache
    /// and its byte accounting. Compression composes: a `WindowSink`
    /// pass over a `HeadDrop`'d sequence keeps the dropped groups
    /// dropped (the resulting class carries the tighter of both caps).
    /// Idempotent — re-applying a policy a sequence already satisfies
    /// changes nothing.
    pub fn compress(&mut self, id: u64, policy: KvPolicy) -> Result<()> {
        let m = self.meta.clone();
        let Some(s) = self.seqs.get_mut(&id) else {
            bail!("compress: unknown seq {id}");
        };
        let old_len = s.len;
        let old_policy = s.policy;
        let dh = m.head_dim();
        let row = m.max_seq * dh;

        // Token eviction: keep [0, sink) and the trailing `recent`
        // rows, compacted to [sink, sink + recent).
        let new_len = policy.compressed_len(old_len);
        if new_len < old_len {
            let KvPolicy::WindowSink { sink, recent } = policy else {
                // lint:allow(hot-path-panic): only WindowSink has a
                // finite token_cap, so new_len < old_len implies it
                unreachable!("only WindowSink caps tokens");
            };
            let keep_from = old_len - recent;
            for l in 0..m.n_layers {
                for h in 0..m.n_kv_heads {
                    let base = (l * m.n_kv_heads + h) * row;
                    for buf in [&mut s.k, &mut s.v] {
                        buf.copy_within(
                            base + keep_from * dh..base + old_len * dh,
                            base + sink * dh,
                        );
                        for x in &mut buf
                            [base + new_len * dh..base + old_len * dh]
                        {
                            *x = 0.0;
                        }
                    }
                }
            }
        }

        // Head-adaptive eviction: zero every kv group past the cap.
        let new_groups =
            old_policy.group_cap().min(policy.group_cap());
        if new_groups < m.n_kv_heads
            && new_groups < old_policy.group_cap()
        {
            for l in 0..m.n_layers {
                for h in new_groups..m.n_kv_heads {
                    let base = (l * m.n_kv_heads + h) * row;
                    for buf in [&mut s.k, &mut s.v] {
                        for x in &mut buf[base..base + row] {
                            *x = 0.0;
                        }
                    }
                }
            }
        }

        // The sequence's class carries the tighter of (old, new) caps
        // so accounting never un-prices data that is already gone.
        let new_policy = if new_groups < policy.group_cap() {
            KvPolicy::HeadDrop { keep_groups: new_groups }
        } else {
            policy
        };
        s.len = new_len;
        s.policy = new_policy;
        self.class_remove(old_policy, old_len);
        self.class_add(new_policy, new_len);
        self.debug_audit();
        Ok(())
    }

    fn note_usage(&mut self, mask: &PruneMask) {
        let b = self.bytes_used(mask);
        if b > self.peak_bytes_seen {
            self.peak_bytes_seen = b;
        }
    }

    /// Exhaustive per-sequence rescan of the class totals — the oracle
    /// the incremental books must match after any interleaving of
    /// insert/compress/bump/evict. O(sequences); debug assertions and
    /// proptests only, never the serving path.
    fn rescan_classes(&self)
                      -> (BTreeMap<KvPolicy, ClassTotals>, usize) {
        let cap = self.floor_token_cap();
        let mut classes: BTreeMap<KvPolicy, ClassTotals> =
            BTreeMap::new();
        let mut total = 0usize;
        for s in self.seqs.values() {
            let t = classes.entry(s.policy).or_default();
            t.seqs += 1;
            t.tokens += s.len;
            t.floor_tokens += s.len.min(cap);
            total += s.len;
        }
        (classes, total)
    }

    /// Check the incremental accounting against the exhaustive rescan.
    pub fn audit(&self) -> Result<()> {
        let (classes, total) = self.rescan_classes();
        if classes != self.classes {
            bail!("kv class books diverged: incremental {:?} vs \
                   rescan {:?}",
                  self.classes, classes);
        }
        if total != self.total_tokens {
            bail!("kv total_tokens diverged: incremental {} vs \
                   rescan {}",
                  self.total_tokens, total);
        }
        Ok(())
    }

    fn debug_audit(&self) {
        #[cfg(debug_assertions)]
        if let Err(e) = self.audit() {
            // lint:allow(hot-path-panic): debug-build oracle check;
            // release builds compile this block away entirely
            panic!("{e}");
        }
    }

    /// Gather the per-seq caches of `ids` into a decode batch layout
    /// `[L, B, Hkv, S, Dh]` (B = ids.len()).
    pub fn gather(&self, ids: &[u64]) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.meta;
        let b = ids.len();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        let mut k = vec![0.0f32; m.n_layers * b * per_layer];
        let mut v = vec![0.0f32; m.n_layers * b * per_layer];
        for (bi, id) in ids.iter().enumerate() {
            let Some(s) = self.seqs.get(id) else {
                bail!("gather: unknown seq {id}");
            };
            for l in 0..m.n_layers {
                let src = l * per_layer..(l + 1) * per_layer;
                let dst = (l * b + bi) * per_layer;
                k[dst..dst + per_layer].copy_from_slice(&s.k[src.clone()]);
                v[dst..dst + per_layer].copy_from_slice(&s.v[src]);
            }
        }
        Ok((k, v))
    }

    /// Scatter an updated decode-batch cache back into the per-seq
    /// caches, and bump each sequence's length by one (the decode step
    /// wrote position `len`).
    pub fn scatter(&mut self, ids: &[u64], k: &[f32], v: &[f32],
                   mask: &PruneMask) -> Result<()> {
        self.scatter_cache(ids, k, v, false)?;
        self.bump_lens(ids, mask)
    }

    /// Copy a decode-batch cache back into per-seq storage WITHOUT
    /// touching lengths (used when a persistent batch is recomposed —
    /// see `engine::Engine`). With `skip_missing`, ids that were already
    /// retired are ignored.
    pub fn scatter_cache(&mut self, ids: &[u64], k: &[f32], v: &[f32],
                         skip_missing: bool) -> Result<()> {
        let m = &self.meta;
        let b = ids.len();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        if k.len() != m.n_layers * b * per_layer {
            bail!("scatter: bad batch cache size");
        }
        for (bi, id) in ids.iter().enumerate() {
            let Some(s) = self.seqs.get_mut(id) else {
                if skip_missing {
                    continue;
                }
                bail!("scatter: unknown seq {id}");
            };
            for l in 0..m.n_layers {
                let dst = l * per_layer..(l + 1) * per_layer;
                let src = (l * b + bi) * per_layer;
                s.k[dst.clone()].copy_from_slice(&k[src..src + per_layer]);
                s.v[dst].copy_from_slice(&v[src..src + per_layer]);
            }
        }
        Ok(())
    }

    /// Advance each sequence's materialized length by one decode step.
    pub fn bump_lens(&mut self, ids: &[u64], mask: &PruneMask)
                     -> Result<()> {
        let cap = self.floor_token_cap();
        for id in ids {
            let Some(s) = self.seqs.get_mut(id) else {
                bail!("bump_lens: unknown seq {id}");
            };
            s.len += 1;
            let policy = s.policy;
            let len = s.len;
            if len > self.meta.max_seq {
                bail!("sequence {id} overflowed max_seq");
            }
            let t = self.classes.get_mut(&policy)
                // lint:allow(hot-path-panic): class books invariant —
                // the sequence we just fetched pins its class entry
                .expect("bump_lens: unknown policy class");
            t.tokens += 1;
            if len <= cap {
                t.floor_tokens += 1;
            }
            self.total_tokens += 1;
        }
        self.note_usage(mask);
        self.debug_audit();
        Ok(())
    }

    /// Current write positions for a decode batch (pos input of decode).
    pub fn positions(&self, ids: &[u64]) -> Result<Vec<i32>> {
        ids.iter()
            .map(|id| {
                self.seqs
                    .get(id)
                    .map(|s| s.len as i32)
                    .ok_or_else(|| anyhow::anyhow!("unknown seq {id}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("t", 2, 16, 4, 2, 24, 32, 8)
    }

    fn filled_cache(meta: &ModelMeta, fill: f32) -> (Vec<f32>, Vec<f32>) {
        let n = meta.n_layers * meta.n_kv_heads * meta.max_seq
            * meta.head_dim();
        (vec![fill; n], vec![fill + 0.5; n])
    }

    #[test]
    fn insert_gather_roundtrip() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        let (k2, v2) = filled_cache(&m, 2.0);
        kv.insert(10, k1, v1, 3, &mask).unwrap();
        kv.insert(20, k2, v2, 5, &mask).unwrap();
        let (k, v) = kv.gather(&[10, 20]).unwrap();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        // layer 0, batch 0 = seq 10 (fill 1.0); batch 1 = seq 20 (2.0)
        assert_eq!(k[0], 1.0);
        assert_eq!(k[per_layer], 2.0);
        assert_eq!(v[0], 1.5);
        assert_eq!(v[per_layer], 2.5);
        assert_eq!(kv.positions(&[10, 20]).unwrap(), vec![3, 5]);
    }

    #[test]
    fn scatter_updates_and_advances() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        kv.insert(7, k1, v1, 2, &mask).unwrap();
        let (mut k, v) = kv.gather(&[7]).unwrap();
        k[5] = 42.0;
        kv.scatter(&[7], &k, &v, &mask).unwrap();
        assert_eq!(kv.seq_len(7), Some(3));
        let (k2, _) = kv.gather(&[7]).unwrap();
        assert_eq!(k2[5], 42.0);
    }

    #[test]
    fn bytes_follow_mask_and_length() {
        let m = meta();
        let full = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 0.0);
        kv.insert(1, k1, v1, 4, &full).unwrap();
        let dense = kv.bytes_used(&full);
        // 2 layers * 2 kv groups * dh=4 * len=4 * 2(k+v) * 4B
        assert_eq!(dense, 2 * (2 * 2 * 4 * 4) * 4);
        let mut pruned = full.clone();
        pruned.drop_block(crate::model_meta::BlockId::Mha(0));
        assert_eq!(kv.bytes_used(&pruned), dense / 2);
        // total_tokens × dense per-token bytes recovers bytes_used
        assert_eq!(kv.total_tokens(), 4);
        assert_eq!(kv.total_tokens() * m.n_layers
                       * m.kv_bytes_per_token_layer(m.n_kv_heads),
                   dense);
        assert_eq!(kv.dense_bytes(), dense);
    }

    fn tok_at(kv: &KvManager, id: u64, t: usize, dh: usize) -> f32 {
        kv.get(id).unwrap().k[t * dh]
    }

    #[test]
    fn window_sink_compacts_tokens_in_place() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let dh = m.head_dim();
        // distinct value per token position so the compaction is visible
        let mut k1 = vec![0.0f32; kv.seq_elems()];
        for l in 0..m.n_layers {
            for h in 0..m.n_kv_heads {
                for t in 0..m.max_seq {
                    let base =
                        ((l * m.n_kv_heads + h) * m.max_seq + t) * dh;
                    for d in 0..dh {
                        k1[base + d] = t as f32;
                    }
                }
            }
        }
        let v1 = k1.clone();
        kv.insert(5, k1, v1, 10, &mask).unwrap();
        let policy = KvPolicy::WindowSink { sink: 2, recent: 3 };
        kv.compress(5, policy).unwrap();
        assert_eq!(kv.seq_len(5), Some(5));
        assert_eq!(kv.policy_of(5), Some(policy));
        assert_eq!(kv.total_tokens(), 5);
        // sinks untouched, window compacted from tokens 7..10, tail 0
        assert_eq!(tok_at(&kv, 5, 0, dh), 0.0);
        assert_eq!(tok_at(&kv, 5, 1, dh), 1.0);
        assert_eq!(tok_at(&kv, 5, 2, dh), 7.0);
        assert_eq!(tok_at(&kv, 5, 3, dh), 8.0);
        assert_eq!(tok_at(&kv, 5, 4, dh), 9.0);
        assert_eq!(tok_at(&kv, 5, 5, dh), 0.0);
        // idempotent: re-applying the satisfied policy changes nothing
        kv.compress(5, policy).unwrap();
        assert_eq!(kv.seq_len(5), Some(5));
        assert_eq!(tok_at(&kv, 5, 2, dh), 7.0);
        kv.audit().unwrap();
    }

    #[test]
    fn head_drop_prices_only_kept_groups() {
        let m = meta();
        let full = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        kv.insert(1, k1, v1, 4, &full).unwrap();
        let dense = kv.bytes_used(&full);
        kv.compress(1, KvPolicy::HeadDrop { keep_groups: 1 }).unwrap();
        // 1 of 2 kv groups survives → half the bytes, same token count
        assert_eq!(kv.bytes_used(&full), dense / 2);
        assert_eq!(kv.total_tokens(), 4);
        // dropped group is physically zeroed
        let s = kv.get(1).unwrap();
        let row = m.max_seq * m.head_dim();
        assert!(s.k[row..2 * row].iter().all(|&x| x == 0.0));
        assert!(s.k[..row].iter().any(|&x| x != 0.0));
        kv.audit().unwrap();
    }

    #[test]
    fn compression_composes_with_the_tighter_caps() {
        let m = meta();
        let full = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        kv.insert(1, k1, v1, 12, &full).unwrap();
        kv.compress(1, KvPolicy::HeadDrop { keep_groups: 1 }).unwrap();
        kv.compress(1, KvPolicy::WindowSink { sink: 1, recent: 3 })
            .unwrap();
        // head cap survives the window pass: class keeps keep_groups=1
        assert_eq!(kv.policy_of(1),
                   Some(KvPolicy::HeadDrop { keep_groups: 1 }));
        assert_eq!(kv.seq_len(1), Some(4));
        let per_token_half = m.n_layers * m.kv_bytes_per_token_layer(1);
        assert_eq!(kv.bytes_used(&full), 4 * per_token_half);
        kv.audit().unwrap();
    }

    #[test]
    fn floor_bytes_projects_every_class_to_the_floor() {
        let m = meta();
        let full = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        let (k2, v2) = filled_cache(&m, 2.0);
        kv.insert(1, k1, v1, 12, &full).unwrap();
        kv.insert(2, k2, v2, 3, &full).unwrap();
        // no floor: floor_bytes == bytes_used
        assert_eq!(kv.floor_bytes(&full), kv.bytes_used(&full));
        let floor = KvPolicy::WindowSink { sink: 1, recent: 4 };
        kv.set_floor(Some(floor));
        let per_token = kv.per_token_bytes(&full, KvPolicy::Dense);
        // seq 1 caps at 5 tokens, seq 2 stays at 3
        assert_eq!(kv.floor_bytes(&full), (5 + 3) * per_token);
        assert_eq!(kv.bytes_used(&full), (12 + 3) * per_token);
        // bump past the cap: bytes grow, the floor projection doesn't
        kv.bump_lens(&[1], &full).unwrap();
        assert_eq!(kv.bytes_used(&full), (13 + 3) * per_token);
        assert_eq!(kv.floor_bytes(&full), (5 + 3) * per_token);
        // deploying the floor realizes the projection exactly
        kv.compress(1, floor).unwrap();
        kv.compress(2, floor).unwrap();
        assert_eq!(kv.bytes_used(&full), kv.floor_bytes(&full));
        kv.audit().unwrap();
    }

    #[test]
    fn gather_unknown_seq_fails() {
        let m = meta();
        let kv = KvManager::new(&m);
        assert!(kv.gather(&[99]).is_err());
    }

    #[test]
    fn overflow_detected() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 0.0);
        kv.insert(1, k1, v1, m.max_seq, &mask).unwrap();
        let (k, v) = kv.gather(&[1]).unwrap();
        assert!(kv.scatter(&[1], &k, &v, &mask).is_err());
    }
}

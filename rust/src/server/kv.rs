//! KV-cache manager: owns per-sequence caches, splices them into decode
//! batches, and does the mask-aware memory accounting (only layers whose
//! MHA block survives — and within GQA only live kv groups — count,
//! exactly like the paper's Eq. 4).
//!
//! Layouts (flattened f32, row-major):
//!   per-sequence cache: [L, Hkv, S, Dh]   (from `prefill`, B axis removed)
//!   decode batch cache: [L, B, Hkv, S, Dh] (what `decode_b{B}` consumes)

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::mask::PruneMask;
use crate::model_meta::{ModelMeta, BYTES_PER_SCALAR};

/// One sequence's cached state.
#[derive(Clone, Debug)]
pub struct SeqCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Tokens currently materialized in the cache (== next write pos).
    pub len: usize,
}

pub struct KvManager {
    meta: ModelMeta,
    seqs: HashMap<u64, SeqCache>,
    /// Running total of cached tokens across live sequences (kept in
    /// step by insert/remove/bump_lens), so the mask-aware byte
    /// accounting is O(layers) instead of O(sequences × layers) — it
    /// sits on the engine's pressure path and every router's scoring
    /// path.
    total_tokens: usize,
    /// High-water mark of bytes held (for reports).
    pub peak_bytes_seen: usize,
}

impl KvManager {
    pub fn new(meta: &ModelMeta) -> KvManager {
        KvManager { meta: meta.clone(), seqs: HashMap::new(),
                    total_tokens: 0, peak_bytes_seen: 0 }
    }

    pub fn seq_elems(&self) -> usize {
        let m = &self.meta;
        m.n_layers * m.n_kv_heads * m.max_seq * m.head_dim()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn seq_len(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Total cached tokens across live sequences. Because every layer
    /// stores the same `len` tokens per sequence, `bytes_used` under
    /// any block-level mask is this total times the mask's per-token
    /// bytes — which lets callers price alternative masks without a
    /// per-sequence sweep.
    pub fn total_tokens(&self) -> usize {
        self.total_tokens
    }

    /// Admit a sequence with its prefill-produced cache
    /// (`[L, 1, Hkv, S, Dh]` == `[L, Hkv, S, Dh]` flattened).
    pub fn insert(&mut self, id: u64, k: Vec<f32>, v: Vec<f32>,
                  prompt_len: usize, mask: &PruneMask) -> Result<()> {
        if k.len() != self.seq_elems() || v.len() != self.seq_elems() {
            bail!("cache size mismatch: got {}, want {}", k.len(),
                  self.seq_elems());
        }
        if let Some(old) =
            self.seqs.insert(id, SeqCache { k, v, len: prompt_len })
        {
            self.total_tokens -= old.len;
        }
        self.total_tokens += prompt_len;
        self.note_usage(mask);
        Ok(())
    }

    /// Borrow one sequence's cache without removing it — the periodic
    /// checkpoint path snapshots live caches in place.
    pub fn get(&self, id: u64) -> Option<&SeqCache> {
        self.seqs.get(&id)
    }

    pub fn remove(&mut self, id: u64) -> Option<SeqCache> {
        let removed = self.seqs.remove(&id);
        if let Some(s) = &removed {
            self.total_tokens -= s.len;
        }
        removed
    }

    /// Logical KV bytes for the *active* sequences under `mask`:
    /// Σ_seq Σ_layer 2 · kv_groups(l) · Dh · len(seq) · 4B — computed
    /// as (total tokens) × (per-token bytes under the mask), which is
    /// exactly equal because every layer stores the same `len` tokens
    /// per sequence.
    pub fn bytes_used(&self, mask: &PruneMask) -> usize {
        let dh = self.meta.head_dim();
        let mut per_token = 0usize;
        for l in 0..self.meta.n_layers {
            per_token +=
                2 * mask.active_kv_groups(l) * dh * BYTES_PER_SCALAR;
        }
        self.total_tokens * per_token
    }

    fn note_usage(&mut self, mask: &PruneMask) {
        let b = self.bytes_used(mask);
        if b > self.peak_bytes_seen {
            self.peak_bytes_seen = b;
        }
    }

    /// Gather the per-seq caches of `ids` into a decode batch layout
    /// `[L, B, Hkv, S, Dh]` (B = ids.len()).
    pub fn gather(&self, ids: &[u64]) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.meta;
        let b = ids.len();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        let mut k = vec![0.0f32; m.n_layers * b * per_layer];
        let mut v = vec![0.0f32; m.n_layers * b * per_layer];
        for (bi, id) in ids.iter().enumerate() {
            let Some(s) = self.seqs.get(id) else {
                bail!("gather: unknown seq {id}");
            };
            for l in 0..m.n_layers {
                let src = l * per_layer..(l + 1) * per_layer;
                let dst = (l * b + bi) * per_layer;
                k[dst..dst + per_layer].copy_from_slice(&s.k[src.clone()]);
                v[dst..dst + per_layer].copy_from_slice(&s.v[src]);
            }
        }
        Ok((k, v))
    }

    /// Scatter an updated decode-batch cache back into the per-seq
    /// caches, and bump each sequence's length by one (the decode step
    /// wrote position `len`).
    pub fn scatter(&mut self, ids: &[u64], k: &[f32], v: &[f32],
                   mask: &PruneMask) -> Result<()> {
        self.scatter_cache(ids, k, v, false)?;
        self.bump_lens(ids, mask)
    }

    /// Copy a decode-batch cache back into per-seq storage WITHOUT
    /// touching lengths (used when a persistent batch is recomposed —
    /// see `engine::Engine`). With `skip_missing`, ids that were already
    /// retired are ignored.
    pub fn scatter_cache(&mut self, ids: &[u64], k: &[f32], v: &[f32],
                         skip_missing: bool) -> Result<()> {
        let m = &self.meta;
        let b = ids.len();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        if k.len() != m.n_layers * b * per_layer {
            bail!("scatter: bad batch cache size");
        }
        for (bi, id) in ids.iter().enumerate() {
            let Some(s) = self.seqs.get_mut(id) else {
                if skip_missing {
                    continue;
                }
                bail!("scatter: unknown seq {id}");
            };
            for l in 0..m.n_layers {
                let dst = l * per_layer..(l + 1) * per_layer;
                let src = (l * b + bi) * per_layer;
                s.k[dst.clone()].copy_from_slice(&k[src..src + per_layer]);
                s.v[dst].copy_from_slice(&v[src..src + per_layer]);
            }
        }
        Ok(())
    }

    /// Advance each sequence's materialized length by one decode step.
    pub fn bump_lens(&mut self, ids: &[u64], mask: &PruneMask)
                     -> Result<()> {
        for id in ids {
            let Some(s) = self.seqs.get_mut(id) else {
                bail!("bump_lens: unknown seq {id}");
            };
            s.len += 1;
            self.total_tokens += 1;
            if s.len > self.meta.max_seq {
                bail!("sequence {id} overflowed max_seq");
            }
        }
        self.note_usage(mask);
        Ok(())
    }

    /// Current write positions for a decode batch (pos input of decode).
    pub fn positions(&self, ids: &[u64]) -> Result<Vec<i32>> {
        ids.iter()
            .map(|id| {
                self.seqs
                    .get(id)
                    .map(|s| s.len as i32)
                    .ok_or_else(|| anyhow::anyhow!("unknown seq {id}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("t", 2, 16, 4, 2, 24, 32, 8)
    }

    fn filled_cache(meta: &ModelMeta, fill: f32) -> (Vec<f32>, Vec<f32>) {
        let n = meta.n_layers * meta.n_kv_heads * meta.max_seq
            * meta.head_dim();
        (vec![fill; n], vec![fill + 0.5; n])
    }

    #[test]
    fn insert_gather_roundtrip() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        let (k2, v2) = filled_cache(&m, 2.0);
        kv.insert(10, k1, v1, 3, &mask).unwrap();
        kv.insert(20, k2, v2, 5, &mask).unwrap();
        let (k, v) = kv.gather(&[10, 20]).unwrap();
        let per_layer = m.n_kv_heads * m.max_seq * m.head_dim();
        // layer 0, batch 0 = seq 10 (fill 1.0); batch 1 = seq 20 (2.0)
        assert_eq!(k[0], 1.0);
        assert_eq!(k[per_layer], 2.0);
        assert_eq!(v[0], 1.5);
        assert_eq!(v[per_layer], 2.5);
        assert_eq!(kv.positions(&[10, 20]).unwrap(), vec![3, 5]);
    }

    #[test]
    fn scatter_updates_and_advances() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 1.0);
        kv.insert(7, k1, v1, 2, &mask).unwrap();
        let (mut k, v) = kv.gather(&[7]).unwrap();
        k[5] = 42.0;
        kv.scatter(&[7], &k, &v, &mask).unwrap();
        assert_eq!(kv.seq_len(7), Some(3));
        let (k2, _) = kv.gather(&[7]).unwrap();
        assert_eq!(k2[5], 42.0);
    }

    #[test]
    fn bytes_follow_mask_and_length() {
        let m = meta();
        let full = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 0.0);
        kv.insert(1, k1, v1, 4, &full).unwrap();
        let dense = kv.bytes_used(&full);
        // 2 layers * 2 kv groups * dh=4 * len=4 * 2(k+v) * 4B
        assert_eq!(dense, 2 * (2 * 2 * 4 * 4) * 4);
        let mut pruned = full.clone();
        pruned.drop_block(crate::model_meta::BlockId::Mha(0));
        assert_eq!(kv.bytes_used(&pruned), dense / 2);
        // total_tokens × dense per-token bytes recovers bytes_used
        assert_eq!(kv.total_tokens(), 4);
        assert_eq!(kv.total_tokens() * m.n_layers
                       * m.kv_bytes_per_token_layer(m.n_kv_heads),
                   dense);
    }

    #[test]
    fn gather_unknown_seq_fails() {
        let m = meta();
        let kv = KvManager::new(&m);
        assert!(kv.gather(&[99]).is_err());
    }

    #[test]
    fn overflow_detected() {
        let m = meta();
        let mask = PruneMask::full(&m);
        let mut kv = KvManager::new(&m);
        let (k1, v1) = filled_cache(&m, 0.0);
        kv.insert(1, k1, v1, m.max_seq, &mask).unwrap();
        let (k, v) = kv.gather(&[1]).unwrap();
        assert!(kv.scatter(&[1], &k, &v, &mask).is_err());
    }
}

//! Simulated device-memory monitor with co-running-application
//! interference (paper Takeaway 3 / Fig 5).
//!
//! The paper's serving node is an A40 whose free memory fluctuates 5–10×
//! because other tenants grab and release GPU memory. We model the
//! interference as a marked Poisson process: apps arrive at rate λ, hold
//! a log-normal amount of memory for an exponential duration. The
//! resulting `available(t)` curve is precomputed per seed so the whole
//! trace is deterministic and queryable at any t.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct MemMonConfig {
    /// Total device memory in bytes.
    pub capacity: usize,
    /// Co-running app arrivals per second.
    pub app_rate: f64,
    /// Mean hold duration (seconds).
    pub mean_hold_secs: f64,
    /// Log-normal parameters of per-app bytes (of ln bytes).
    pub size_mu: f64,
    pub size_sigma: f64,
    /// Horizon to precompute (seconds).
    pub horizon_secs: f64,
}

impl MemMonConfig {
    /// Sized for our substitute model: interference chunks are ~18% of
    /// capacity each, so a few concurrent apps force real choices.
    pub fn for_capacity(capacity: usize) -> MemMonConfig {
        MemMonConfig {
            capacity,
            app_rate: 0.05,
            mean_hold_secs: 40.0,
            size_mu: (capacity as f64 * 0.18).ln(),
            size_sigma: 0.5,
            horizon_secs: 1200.0,
        }
    }
}

/// One interference interval: [start, end) holding `bytes`.
#[derive(Clone, Copy, Debug)]
struct AppSpan {
    start: f64,
    end: f64,
    bytes: usize,
}

#[derive(Clone, Debug)]
pub struct MemoryMonitor {
    pub cfg: MemMonConfig,
    spans: Vec<AppSpan>,
}

impl MemoryMonitor {
    pub fn new(cfg: MemMonConfig, seed: u64) -> MemoryMonitor {
        let mut rng = Rng::new(seed);
        let mut spans = Vec::new();
        let mut t = 0.0;
        while t < cfg.horizon_secs {
            t += rng.exponential(cfg.app_rate);
            if t >= cfg.horizon_secs {
                break;
            }
            let hold = rng.exponential(1.0 / cfg.mean_hold_secs);
            let bytes = rng.lognormal(cfg.size_mu, cfg.size_sigma) as usize;
            spans.push(AppSpan { start: t, end: t + hold,
                                 bytes: bytes.min(cfg.capacity / 2) });
        }
        MemoryMonitor { cfg, spans }
    }

    /// A monitor with zero interference (fixed budget — the baseline the
    /// paper's static schemes implicitly assume).
    pub fn constant(capacity: usize) -> MemoryMonitor {
        MemoryMonitor { cfg: MemMonConfig::for_capacity(capacity),
                        spans: Vec::new() }
    }

    /// A monitor with an explicit interference schedule: `(start, end,
    /// bytes)` triples. Lets tests and fleet scenarios construct exact
    /// pressure patterns without depending on the seeded process.
    pub fn with_spans(cfg: MemMonConfig, spans: &[(f64, f64, usize)])
                      -> MemoryMonitor {
        let spans = spans
            .iter()
            .map(|&(start, end, bytes)| AppSpan { start, end, bytes })
            .collect();
        MemoryMonitor { cfg, spans }
    }

    /// Shorthand for the ubiquitous test/scenario monitor: a device of
    /// `capacity` bytes with an explicit schedule of interference walls
    /// (`with_spans` over `MemMonConfig::for_capacity`).
    pub fn walls(capacity: usize, spans: &[(f64, f64, usize)])
                 -> MemoryMonitor {
        MemoryMonitor::with_spans(MemMonConfig::for_capacity(capacity),
                                  spans)
    }

    /// A monitor driven by a fault plan's pressure events: each
    /// `FaultEvent::Pressure` becomes an interference wall holding its
    /// fraction of `capacity`, so engine-level tests inject the same
    /// `Sys_avail(t)` cliffs a chaos fleet sees — without a fleet.
    pub fn with_faults(capacity: usize,
                       plan: &crate::runtime::FaultPlan)
                       -> MemoryMonitor {
        MemoryMonitor::walls(capacity, &plan.pressure_spans(capacity))
    }

    /// Queries past the precomputed horizon wrap around into `[0,
    /// horizon)`: the interference process extends periodically instead
    /// of silently reporting an idle device forever (which would let a
    /// long-running engine believe it has full capacity).
    fn effective_t(&self, t: f64) -> f64 {
        let h = self.cfg.horizon_secs;
        if t < h || h <= 0.0 {
            t
        } else {
            t % h
        }
    }

    /// Bytes held by co-running apps at time t.
    pub fn interference_at(&self, t: f64) -> usize {
        let t = self.effective_t(t);
        self.spans
            .iter()
            .filter(|s| t >= s.start && t < s.end)
            .map(|s| s.bytes)
            .sum()
    }

    /// Memory available to the LLM at time t (Sys_avail in the paper's
    /// state vector).
    pub fn available_at(&self, t: f64) -> usize {
        self.cfg.capacity.saturating_sub(self.interference_at(t))
    }

    /// Sample the availability curve (Fig 5's blue line).
    pub fn curve(&self, t0: f64, t1: f64, dt: f64) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        let mut t = t0;
        while t < t1 {
            out.push((t, self.available_at(t)));
            t += dt;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mon(seed: u64) -> MemoryMonitor {
        MemoryMonitor::new(MemMonConfig::for_capacity(1 << 30), seed)
    }

    #[test]
    fn available_never_exceeds_capacity() {
        let m = mon(1);
        for (_, a) in m.curve(0.0, 600.0, 1.0) {
            assert!(a <= m.cfg.capacity);
        }
    }

    #[test]
    fn interference_actually_fluctuates() {
        let m = mon(2);
        let vals: Vec<usize> =
            m.curve(0.0, 1000.0, 1.0).iter().map(|&(_, a)| a).collect();
        let min = *vals.iter().min().unwrap();
        let max = *vals.iter().max().unwrap();
        assert!(max > min, "no fluctuation");
        // require a meaningful swing (paper: 5–10× headroom changes)
        assert!((max - min) as f64 > 0.25 * m.cfg.capacity as f64,
                "swing too small: {min}..{max}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = mon(3);
        let b = mon(3);
        assert_eq!(a.available_at(123.4), b.available_at(123.4));
    }

    #[test]
    fn constant_monitor_is_flat() {
        let m = MemoryMonitor::constant(1 << 28);
        assert_eq!(m.available_at(0.0), 1 << 28);
        assert_eq!(m.available_at(500.0), 1 << 28);
    }

    /// Regression: queries past `horizon_secs` must not silently report
    /// full capacity — the schedule extends periodically.
    #[test]
    fn interference_persists_past_horizon() {
        let m = mon(42);
        let h = m.cfg.horizon_secs;
        // find a moment with real interference inside the horizon
        let (t_star, _) = m
            .curve(0.0, h, 1.0)
            .into_iter()
            .min_by_key(|&(_, a)| a)
            .unwrap();
        assert!(m.interference_at(t_star) > 0, "seed produced no spans");
        // one and two full periods later, the schedule repeats exactly
        assert_eq!(m.interference_at(t_star + h),
                   m.interference_at(t_star));
        assert_eq!(m.interference_at(t_star + 2.0 * h),
                   m.interference_at(t_star));
        assert!(m.available_at(t_star + h) < m.cfg.capacity);
    }

    #[test]
    fn walls_shorthand_matches_with_spans() {
        let spans = [(10.0, 20.0, 300usize)];
        let a = MemoryMonitor::walls(1000, &spans);
        let b = MemoryMonitor::with_spans(MemMonConfig::for_capacity(1000),
                                          &spans);
        assert_eq!(a.cfg.capacity, 1000);
        for t in [0.0, 12.0, 25.0] {
            assert_eq!(a.available_at(t), b.available_at(t));
        }
    }

    /// Satellite: the fault plan's pressure cliffs flow through the
    /// walls mechanism — a `Pressure{frac}` event is a sudden
    /// `Sys_avail(t)` drop of exactly that fraction.
    #[test]
    fn fault_plan_drives_pressure_cliffs() {
        use crate::runtime::{FaultEvent, FaultPlan};
        let plan = FaultPlan::new(vec![
            FaultEvent::Pressure { from: 10.0, until: 20.0, frac: 0.6 },
            FaultEvent::Crash { at: 12.0, replica: 0 }, // not a wall
        ]);
        let m = MemoryMonitor::with_faults(1000, &plan);
        assert_eq!(m.available_at(5.0), 1000);
        assert_eq!(m.available_at(15.0), 400);
        assert_eq!(m.available_at(20.0), 1000);
    }

    #[test]
    fn explicit_spans_are_exact() {
        let cfg = MemMonConfig::for_capacity(1000);
        let m = MemoryMonitor::with_spans(cfg, &[(10.0, 20.0, 300),
                                                 (15.0, 30.0, 200)]);
        assert_eq!(m.available_at(5.0), 1000);
        assert_eq!(m.available_at(12.0), 700);
        assert_eq!(m.available_at(17.0), 500);
        assert_eq!(m.available_at(25.0), 800);
        assert_eq!(m.available_at(30.0), 1000);
    }
}

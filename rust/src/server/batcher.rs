//! Continuous batcher: admission queue + decode-batch composition.
//!
//! Policy (vLLM-style, adapted to fixed PJRT shape buckets):
//!   * prefill runs one sequence at a time at the smallest bucket that
//!     holds the prompt (prefill-prioritized when the decode batch has
//!     room — this is the "prefill/decode scheduler" role of L3);
//!   * decode batches the active sequences into the largest compiled
//!     bucket ≤ active count; membership changes only at step boundaries;
//!   * admission control rejects/queues work that would exceed the
//!     *memory-model* budget (Eq. 3+4) for the current mask.

use std::collections::VecDeque;

use crate::workload::Request;

/// Compiled shape buckets (must match aot.py's PREFILL_T / DECODE_B).
pub const PREFILL_BUCKETS: [usize; 4] = [16, 32, 64, 128];
pub const DECODE_BUCKETS: [usize; 4] = [1, 2, 4, 8];

/// Smallest prefill bucket that holds `prompt_len` tokens.
pub fn prefill_bucket(prompt_len: usize) -> usize {
    for b in PREFILL_BUCKETS {
        if prompt_len <= b {
            return b;
        }
    }
    *PREFILL_BUCKETS.last().unwrap()
}

/// Largest decode bucket ≤ n (0 if n == 0).
pub fn decode_bucket(n: usize) -> usize {
    let mut best = 0;
    for b in DECODE_BUCKETS {
        if b <= n {
            best = b;
        }
    }
    best
}

/// A sequence being served.
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub req: Request,
    /// Tokens generated so far.
    pub generated: usize,
    /// Last sampled token (next decode input).
    pub next_token: i32,
    /// When prefill finished (sim seconds).
    pub prefill_done_at: f64,
}

/// Waiting + active bookkeeping. The engine drives it; this struct owns
/// only the scheduling decisions so they are unit-testable.
#[derive(Default)]
pub struct Batcher {
    pub waiting: VecDeque<Request>,
    pub active: Vec<ActiveSeq>,
    /// Max concurrent decode sequences (largest decode bucket).
    pub max_active: usize,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher { waiting: VecDeque::new(), active: Vec::new(),
                  max_active: *DECODE_BUCKETS.last().unwrap() }
    }

    pub fn enqueue(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Should we run a prefill now? Yes when there is queue room in the
    /// active set.
    pub fn wants_prefill(&self) -> bool {
        !self.waiting.is_empty() && self.active.len() < self.max_active
    }

    pub fn pop_for_prefill(&mut self) -> Option<Request> {
        if self.active.len() >= self.max_active {
            return None;
        }
        self.waiting.pop_front()
    }

    pub fn push_active(&mut self, seq: ActiveSeq) {
        self.active.push(seq);
    }

    /// Compose the next decode batch: ids of up to `decode_bucket`
    /// sequences, oldest first (FCFS completion).
    pub fn decode_ids(&self) -> Vec<u64> {
        let n = decode_bucket(self.active.len());
        self.active.iter().take(n).map(|s| s.req.id).collect()
    }

    /// Remove and return finished sequences.
    pub fn retire_finished(&mut self) -> Vec<ActiveSeq> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated >= self.active[i].req.gen_len {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn seq_mut(&mut self, id: u64) -> Option<&mut ActiveSeq> {
        self.active.iter_mut().find(|s| s.req.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request { id, arrival: 0.0, prompt_len: prompt, gen_len: gen }
    }

    fn active(id: u64, gen_left: usize) -> ActiveSeq {
        ActiveSeq { req: req(id, 16, gen_left), generated: 0,
                    next_token: 0, prefill_done_at: 0.0 }
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(prefill_bucket(5), 16);
        assert_eq!(prefill_bucket(16), 16);
        assert_eq!(prefill_bucket(17), 32);
        assert_eq!(prefill_bucket(100), 128);
        assert_eq!(prefill_bucket(1000), 128); // clamped
        assert_eq!(decode_bucket(0), 0);
        assert_eq!(decode_bucket(1), 1);
        assert_eq!(decode_bucket(3), 2);
        assert_eq!(decode_bucket(7), 4);
        assert_eq!(decode_bucket(20), 8);
    }

    #[test]
    fn fcfs_prefill_order() {
        let mut b = Batcher::new();
        b.enqueue(req(1, 8, 4));
        b.enqueue(req(2, 8, 4));
        assert!(b.wants_prefill());
        assert_eq!(b.pop_for_prefill().unwrap().id, 1);
        assert_eq!(b.pop_for_prefill().unwrap().id, 2);
        assert!(!b.wants_prefill());
    }

    #[test]
    fn active_cap_blocks_prefill() {
        let mut b = Batcher::new();
        for i in 0..8 {
            b.push_active(active(i, 4));
        }
        b.enqueue(req(100, 8, 4));
        assert!(!b.wants_prefill());
        assert!(b.pop_for_prefill().is_none());
    }

    #[test]
    fn decode_batch_is_a_compiled_bucket() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.push_active(active(i, 4));
        }
        let ids = b.decode_ids();
        assert_eq!(ids.len(), 4);
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn retire_removes_done() {
        let mut b = Batcher::new();
        b.push_active(active(1, 0)); // gen_len 0 → done immediately
        b.push_active(active(2, 3));
        let done = b.retire_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].req.id, 1);
        assert_eq!(b.active.len(), 1);
    }
}

//! Continuous batcher: admission queue + decode-batch composition.
//!
//! Policy (vLLM-style, adapted to fixed PJRT shape buckets):
//!   * prefill runs one sequence at a time at the smallest bucket that
//!     holds the prompt (prefill-prioritized when the decode batch has
//!     room — this is the "prefill/decode scheduler" role of L3);
//!   * decode batches the active sequences into the largest compiled
//!     bucket ≤ active count; membership changes only at step boundaries;
//!   * admission control rejects/queues work that would exceed the
//!     *memory-model* budget (Eq. 3+4) for the current mask;
//!   * the admission queue is priority-ordered: a higher
//!     [`PriorityClass`] waits ahead of a lower one, stable FCFS within
//!     a class — with uniform priorities (the trace-replay default) this
//!     is exactly the old FCFS queue.

use std::collections::VecDeque;

use crate::api::SubmitRequest;

/// Compiled shape buckets (must match aot.py's PREFILL_T / DECODE_B).
pub const PREFILL_BUCKETS: [usize; 4] = [16, 32, 64, 128];
pub const DECODE_BUCKETS: [usize; 4] = [1, 2, 4, 8];

/// Smallest prefill bucket that holds `prompt_len` tokens.
pub fn prefill_bucket(prompt_len: usize) -> usize {
    for b in PREFILL_BUCKETS {
        if prompt_len <= b {
            return b;
        }
    }
    // lint:allow(hot-path-panic): const 4-element array is non-empty
    *PREFILL_BUCKETS.last().unwrap()
}

/// Largest decode bucket ≤ n (0 if n == 0).
pub fn decode_bucket(n: usize) -> usize {
    let mut best = 0;
    for b in DECODE_BUCKETS {
        if b <= n {
            best = b;
        }
    }
    best
}

/// A sequence being served.
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub req: SubmitRequest,
    /// Tokens generated so far.
    pub generated: usize,
    /// Last sampled token (next decode input).
    pub next_token: i32,
    /// When prefill finished (sim seconds).
    pub prefill_done_at: f64,
}

/// Waiting + active bookkeeping. The engine drives it; this struct owns
/// only the scheduling decisions so they are unit-testable.
#[derive(Default)]
pub struct Batcher {
    pub waiting: VecDeque<SubmitRequest>,
    pub active: Vec<ActiveSeq>,
    /// Max concurrent decode sequences (largest decode bucket).
    pub max_active: usize,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher { waiting: VecDeque::new(), active: Vec::new(),
                  // lint:allow(hot-path-panic): const array non-empty
                  max_active: *DECODE_BUCKETS.last().unwrap() }
    }

    /// Admit a new request: it waits behind everything of its own class
    /// and above, ahead of anything strictly lower.
    pub fn enqueue(&mut self, req: SubmitRequest) {
        let pos = self
            .waiting
            .iter()
            .position(|r| r.priority < req.priority)
            .unwrap_or(self.waiting.len());
        self.waiting.insert(pos, req);
    }

    /// Put an evicted-and-requeued request back at the *head* of its
    /// class (it was already admitted once): ahead of its equals, still
    /// behind any strictly higher class. With uniform priorities this
    /// is the classic `push_front`.
    pub fn requeue_front(&mut self, req: SubmitRequest) {
        let pos = self
            .waiting
            .iter()
            .position(|r| r.priority <= req.priority)
            .unwrap_or(self.waiting.len());
        self.waiting.insert(pos, req);
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Should we run a prefill now? Yes when there is queue room in the
    /// active set.
    pub fn wants_prefill(&self) -> bool {
        !self.waiting.is_empty() && self.active.len() < self.max_active
    }

    pub fn pop_for_prefill(&mut self) -> Option<SubmitRequest> {
        if self.active.len() >= self.max_active {
            return None;
        }
        self.waiting.pop_front()
    }

    pub fn push_active(&mut self, seq: ActiveSeq) {
        self.active.push(seq);
    }

    /// Compose the next decode batch: ids of up to `decode_bucket`
    /// sequences, oldest first (FCFS completion).
    pub fn decode_ids(&self) -> Vec<u64> {
        let n = decode_bucket(self.active.len());
        self.active.iter().take(n).map(|s| s.req.id).collect()
    }

    /// Remove and return finished sequences.
    pub fn retire_finished(&mut self) -> Vec<ActiveSeq> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].generated
                >= self.active[i].req.max_new_tokens
            {
                done.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        done
    }

    pub fn seq_mut(&mut self, id: u64) -> Option<&mut ActiveSeq> {
        self.active.iter_mut().find(|s| s.req.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::PriorityClass;

    fn req(id: u64, prompt: usize, gen: usize) -> SubmitRequest {
        SubmitRequest::new(prompt, gen).with_id(id)
    }

    fn active(id: u64, gen_left: usize) -> ActiveSeq {
        ActiveSeq { req: req(id, 16, gen_left), generated: 0,
                    next_token: 0, prefill_done_at: 0.0 }
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(prefill_bucket(5), 16);
        assert_eq!(prefill_bucket(16), 16);
        assert_eq!(prefill_bucket(17), 32);
        assert_eq!(prefill_bucket(100), 128);
        assert_eq!(prefill_bucket(1000), 128); // clamped
        assert_eq!(decode_bucket(0), 0);
        assert_eq!(decode_bucket(1), 1);
        assert_eq!(decode_bucket(3), 2);
        assert_eq!(decode_bucket(7), 4);
        assert_eq!(decode_bucket(20), 8);
    }

    #[test]
    fn fcfs_prefill_order() {
        let mut b = Batcher::new();
        b.enqueue(req(1, 8, 4));
        b.enqueue(req(2, 8, 4));
        assert!(b.wants_prefill());
        assert_eq!(b.pop_for_prefill().unwrap().id, 1);
        assert_eq!(b.pop_for_prefill().unwrap().id, 2);
        assert!(!b.wants_prefill());
    }

    /// Higher classes wait ahead of lower ones; FCFS within a class;
    /// `requeue_front` re-enters at the head of its own class.
    #[test]
    fn priority_orders_the_queue() {
        let mut b = Batcher::new();
        b.enqueue(req(1, 8, 4)); // Normal
        b.enqueue(req(2, 8, 4).with_priority(PriorityClass::Batch));
        b.enqueue(req(3, 8, 4).with_priority(PriorityClass::Interactive));
        b.enqueue(req(4, 8, 4)); // Normal, after 1
        b.enqueue(req(5, 8, 4).with_priority(PriorityClass::Interactive));
        let order: Vec<u64> = b.waiting.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![3, 5, 1, 4, 2]);
        // an evicted Normal re-enters ahead of queued Normals but still
        // behind Interactive work
        b.requeue_front(req(6, 8, 4));
        let order: Vec<u64> = b.waiting.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![3, 5, 6, 1, 4, 2]);
    }

    #[test]
    fn active_cap_blocks_prefill() {
        let mut b = Batcher::new();
        for i in 0..8 {
            b.push_active(active(i, 4));
        }
        b.enqueue(req(100, 8, 4));
        assert!(!b.wants_prefill());
        assert!(b.pop_for_prefill().is_none());
    }

    #[test]
    fn decode_batch_is_a_compiled_bucket() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.push_active(active(i, 4));
        }
        let ids = b.decode_ids();
        assert_eq!(ids.len(), 4);
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn retire_removes_done() {
        let mut b = Batcher::new();
        b.push_active(active(1, 0)); // max_new_tokens 0 → done immediately
        b.push_active(active(2, 3));
        let done = b.retire_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].req.id, 1);
        assert_eq!(b.active.len(), 1);
    }
}

//! The serving runtime (L3's coordination contribution): continuous
//! batcher, KV-cache manager, memory monitor with interference, the RAP
//! controller loop, mask-elastic memory accounting
//! ([`outlook::MemoryOutlook`]), and metrics — composed by
//! `engine::Engine`.

pub mod batcher;
pub mod controller;
pub mod engine;
pub mod kv;
pub mod memmon;
pub mod metrics;
pub mod outlook;

//! Mask-elastic memory accounting: a replica's footprint as a lattice.
//!
//! RAP's premise is that a replica's footprint is *elastic* — the
//! controller can shrink the FFN/attention masks to absorb a memory
//! spike before any work must be shed. A single `bytes_used()` number
//! (the footprint under the *current* mask) therefore under-describes
//! the replica: a spike that fits between the current footprint and the
//! cheapest reachable footprint is *absorbable*, and treating it as an
//! OOM produces phantom pressure — queues rerouted, replicas spawned,
//! KV migrated for nothing (ISSUE 4).
//!
//! [`MemoryOutlook`] reports the footprint at three points of the mask
//! lattice:
//!
//!   * `min_viable` — the footprint under the cheapest mask the
//!     controller is allowed to reach for the observed workload (the
//!     GSI-greedy prefix down to the controller's retained-parameter
//!     floor; for a static deployment the mask cannot move, so
//!     `min_viable == current`);
//!   * `current`    — the footprint under the mask deployed right now
//!     (what `Engine::bytes_used` has always reported);
//!   * `dense`      — the footprint this replica would have under the
//!     full mask (the ceiling the mask could grow back to).
//!
//! Pressure semantics follow directly: a spike with
//! `current > Sys_avail(t) >= min_viable` is **absorbable** (shrink the
//! mask, shed nothing, count no OOM); only `Sys_avail(t) < min_viable`
//! is a **true OOM**. Placement semantics likewise: a peer's capacity
//! to take on work is its *elastic* headroom `Sys_avail(t) - min_viable`,
//! not the headroom under whatever mask it happens to be wearing
//! mid-shrink.
//!
//! Since PR-9 the lattice is *joint*: `min_viable` minimizes over
//! (reachable mask) × (reachable KV policy per resident sequence) under
//! the controller's compression floor, so the absorbable band covers
//! spikes that mask-shrinking alone cannot reach. `kv_slack` reports the
//! KV-compression leg of that band on its own — the bytes per-sequence
//! compression could free *without* moving the mask — so pressure
//! consumers can tell the two elasticity axes apart.

/// A replica's memory footprint across the reachable (mask × KV-policy)
/// lattice, in bytes. Invariant (enforced at construction):
/// `min_viable <= current <= dense`, `kv_slack <= slack()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryOutlook {
    /// Footprint under the cheapest (mask, KV policy) point the
    /// controller may deploy: the floor mask priced with every resident
    /// sequence compressed down to the KV floor.
    pub min_viable: usize,
    /// Footprint under the currently deployed mask and policies.
    pub current: usize,
    /// Footprint under the full (dense) mask with no compression caps.
    pub dense: usize,
    /// Bytes KV compression alone could free at the *current* mask —
    /// the second elasticity axis, zero when KV elasticity is off.
    pub kv_slack: usize,
}

impl MemoryOutlook {
    pub fn new(min_viable: usize, current: usize, dense: usize)
               -> MemoryOutlook {
        // Clamp rather than panic: a mask already pruned below the
        // controller's floor makes the floor-mask footprint exceed the
        // current one, and staying put is always reachable.
        MemoryOutlook {
            min_viable: min_viable.min(current),
            current,
            dense: dense.max(current),
            kv_slack: 0,
        }
    }

    /// Attach the KV-compression leg of the elastic band (clamped into
    /// the lattice: compression can never free more than the full
    /// distance down to `min_viable`).
    pub fn with_kv_slack(mut self, kv_slack: usize) -> MemoryOutlook {
        self.kv_slack = kv_slack.min(self.slack());
        self
    }

    /// An outlook with no elasticity: all three points collapse onto
    /// the current footprint (static deployments, or mask-elastic
    /// accounting disabled).
    pub fn rigid(current: usize) -> MemoryOutlook {
        MemoryOutlook { min_viable: current, current, dense: current,
                        kv_slack: 0 }
    }

    /// Bytes the controller could free right now by shrinking the mask.
    pub fn slack(&self) -> usize {
        self.current - self.min_viable
    }

    /// Headroom under the current mask (the classic
    /// `Sys_avail - bytes_used`).
    pub fn headroom(&self, avail: usize) -> usize {
        avail.saturating_sub(self.current)
    }

    /// Headroom the mask lattice can reach: `Sys_avail - min_viable`.
    /// This is what placement decisions (routing, migration targets)
    /// should score — a replica mid-shrink is not "full".
    pub fn elastic_headroom(&self, avail: usize) -> usize {
        avail.saturating_sub(self.min_viable)
    }

    /// The current mask is over `avail` (some reaction is needed).
    pub fn pressured(&self, avail: usize) -> bool {
        self.current > avail
    }

    /// Even the cheapest reachable mask fits `avail`: any pressure at
    /// this level is absorbable by mask-shrinking alone.
    pub fn viable(&self, avail: usize) -> bool {
        self.min_viable <= avail
    }

    /// A true OOM: pressured AND not absorbable.
    pub fn true_oom(&self, avail: usize) -> bool {
        self.pressured(avail) && !self.viable(avail)
    }

    /// The spike needs more than the mask axis alone can free: only
    /// reachable by deploying KV compression (or not at all).
    pub fn needs_kv_axis(&self, avail: usize) -> bool {
        self.pressured(avail)
            && self.current.saturating_sub(avail)
                > self.slack().saturating_sub(self.kv_slack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_invariant_is_enforced() {
        let o = MemoryOutlook::new(100, 80, 60);
        assert!(o.min_viable <= o.current);
        assert!(o.current <= o.dense);
        assert_eq!(o.min_viable, 80);
        assert_eq!(o.dense, 80);
    }

    #[test]
    fn rigid_has_no_slack() {
        let o = MemoryOutlook::rigid(42);
        assert_eq!(o.slack(), 0);
        assert_eq!(o.elastic_headroom(100), o.headroom(100));
        // rigid pressure is always a true OOM
        assert!(o.true_oom(41));
        assert!(!o.true_oom(42));
    }

    #[test]
    fn absorbable_band_is_not_an_oom() {
        let o = MemoryOutlook::new(30, 100, 120);
        assert_eq!(o.slack(), 70);
        // above current: no pressure at all
        assert!(!o.pressured(100));
        // in (min_viable, current): pressured but absorbable
        assert!(o.pressured(60));
        assert!(o.viable(60));
        assert!(!o.true_oom(60));
        assert_eq!(o.elastic_headroom(60), 30);
        assert_eq!(o.headroom(60), 0);
        // below min_viable: a true OOM
        assert!(o.true_oom(29));
        assert_eq!(o.elastic_headroom(29), 0);
    }

    #[test]
    fn kv_slack_splits_the_elastic_band() {
        let o = MemoryOutlook::new(30, 100, 120).with_kv_slack(40);
        assert_eq!(o.kv_slack, 40);
        // mask axis alone frees slack - kv_slack = 30 bytes: a spike
        // down to avail=70 is mask-absorbable, below that the KV axis
        // must engage
        assert!(!o.needs_kv_axis(70));
        assert!(o.needs_kv_axis(69));
        assert!(o.needs_kv_axis(30));
        // the joint floor still bounds absorbability
        assert!(!o.true_oom(30));
        assert!(o.true_oom(29));
        // kv_slack clamps into the lattice
        let c = MemoryOutlook::new(90, 100, 120).with_kv_slack(40);
        assert_eq!(c.kv_slack, c.slack());
        // rigid outlooks carry no kv slack
        assert_eq!(MemoryOutlook::rigid(42).kv_slack, 0);
    }
}

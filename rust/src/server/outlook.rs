//! Mask-elastic memory accounting: a replica's footprint as a lattice.
//!
//! RAP's premise is that a replica's footprint is *elastic* — the
//! controller can shrink the FFN/attention masks to absorb a memory
//! spike before any work must be shed. A single `bytes_used()` number
//! (the footprint under the *current* mask) therefore under-describes
//! the replica: a spike that fits between the current footprint and the
//! cheapest reachable footprint is *absorbable*, and treating it as an
//! OOM produces phantom pressure — queues rerouted, replicas spawned,
//! KV migrated for nothing (ISSUE 4).
//!
//! [`MemoryOutlook`] reports the footprint at three points of the mask
//! lattice:
//!
//!   * `min_viable` — the footprint under the cheapest mask the
//!     controller is allowed to reach for the observed workload (the
//!     GSI-greedy prefix down to the controller's retained-parameter
//!     floor; for a static deployment the mask cannot move, so
//!     `min_viable == current`);
//!   * `current`    — the footprint under the mask deployed right now
//!     (what `Engine::bytes_used` has always reported);
//!   * `dense`      — the footprint this replica would have under the
//!     full mask (the ceiling the mask could grow back to).
//!
//! Pressure semantics follow directly: a spike with
//! `current > Sys_avail(t) >= min_viable` is **absorbable** (shrink the
//! mask, shed nothing, count no OOM); only `Sys_avail(t) < min_viable`
//! is a **true OOM**. Placement semantics likewise: a peer's capacity
//! to take on work is its *elastic* headroom `Sys_avail(t) - min_viable`,
//! not the headroom under whatever mask it happens to be wearing
//! mid-shrink.

/// A replica's memory footprint across the reachable mask lattice, in
/// bytes. Invariant (enforced at construction): `min_viable <= current
/// <= dense`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryOutlook {
    /// Footprint under the cheapest mask the controller may deploy.
    pub min_viable: usize,
    /// Footprint under the currently deployed mask.
    pub current: usize,
    /// Footprint under the full (dense) mask.
    pub dense: usize,
}

impl MemoryOutlook {
    pub fn new(min_viable: usize, current: usize, dense: usize)
               -> MemoryOutlook {
        // Clamp rather than panic: a mask already pruned below the
        // controller's floor makes the floor-mask footprint exceed the
        // current one, and staying put is always reachable.
        MemoryOutlook {
            min_viable: min_viable.min(current),
            current,
            dense: dense.max(current),
        }
    }

    /// An outlook with no elasticity: all three points collapse onto
    /// the current footprint (static deployments, or mask-elastic
    /// accounting disabled).
    pub fn rigid(current: usize) -> MemoryOutlook {
        MemoryOutlook { min_viable: current, current, dense: current }
    }

    /// Bytes the controller could free right now by shrinking the mask.
    pub fn slack(&self) -> usize {
        self.current - self.min_viable
    }

    /// Headroom under the current mask (the classic
    /// `Sys_avail - bytes_used`).
    pub fn headroom(&self, avail: usize) -> usize {
        avail.saturating_sub(self.current)
    }

    /// Headroom the mask lattice can reach: `Sys_avail - min_viable`.
    /// This is what placement decisions (routing, migration targets)
    /// should score — a replica mid-shrink is not "full".
    pub fn elastic_headroom(&self, avail: usize) -> usize {
        avail.saturating_sub(self.min_viable)
    }

    /// The current mask is over `avail` (some reaction is needed).
    pub fn pressured(&self, avail: usize) -> bool {
        self.current > avail
    }

    /// Even the cheapest reachable mask fits `avail`: any pressure at
    /// this level is absorbable by mask-shrinking alone.
    pub fn viable(&self, avail: usize) -> bool {
        self.min_viable <= avail
    }

    /// A true OOM: pressured AND not absorbable.
    pub fn true_oom(&self, avail: usize) -> bool {
        self.pressured(avail) && !self.viable(avail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_invariant_is_enforced() {
        let o = MemoryOutlook::new(100, 80, 60);
        assert!(o.min_viable <= o.current);
        assert!(o.current <= o.dense);
        assert_eq!(o.min_viable, 80);
        assert_eq!(o.dense, 80);
    }

    #[test]
    fn rigid_has_no_slack() {
        let o = MemoryOutlook::rigid(42);
        assert_eq!(o.slack(), 0);
        assert_eq!(o.elastic_headroom(100), o.headroom(100));
        // rigid pressure is always a true OOM
        assert!(o.true_oom(41));
        assert!(!o.true_oom(42));
    }

    #[test]
    fn absorbable_band_is_not_an_oom() {
        let o = MemoryOutlook::new(30, 100, 120);
        assert_eq!(o.slack(), 70);
        // above current: no pressure at all
        assert!(!o.pressured(100));
        // in (min_viable, current): pressured but absorbable
        assert!(o.pressured(60));
        assert!(o.viable(60));
        assert!(!o.true_oom(60));
        assert_eq!(o.elastic_headroom(60), 30);
        assert_eq!(o.headroom(60), 0);
        // below min_viable: a true OOM
        assert!(o.true_oom(29));
        assert_eq!(o.elastic_headroom(29), 0);
    }
}

//! The serving engine: a continuous-batching event loop over the model
//! runtime, with RAP's controller in the loop.
//!
//! Ingress model: work enters exclusively as typed
//! [`SubmitRequest`]s through [`Engine::submit`], which returns a
//! [`RequestHandle`] for the lifecycle API ([`Engine::status`] /
//! [`Engine::cancel`]; terminal [`Outcome`]s are kept in the metrics
//! ledger). Trace replay is a thin adapter over this —
//! [`Engine::run_trace`] maps the trace through `api::from_trace` and
//! drives [`Engine::run_requests`] — so there is exactly one ingress
//! path.
//!
//! Time model: the engine advances a *simulated* clock fed by the trace's
//! arrival times; compute steps advance the clock by their duration —
//! measured wall-clock on the PJRT backend, the modeled cost on the sim
//! backend (`Runtime::last_cost`) — times `time_scale`. This lets a
//! 10-minute "day" of traffic replay in however long the actual math
//! takes while keeping latency accounting coherent.
//!
//! Stepping model: the engine no longer owns its run loop. The primitive
//! is [`Engine::step_to`], which advances the clock to a target time
//! doing work along the way; [`Engine::run_requests`] is a thin driver
//! over `submit` + `step_to`, and the fleet coordinator drives many
//! engines against one shared clock the same way.
//!
//! Per unit of work:
//!   1. controller: observe (active workload, Sys_avail(t)) and re-decide
//!      the mask when the situation changed (cached decisions make this
//!      the paper's "<1% overhead" path);
//!   2. pressure handling: if interference spiked over our *current*
//!      footprint, consult the [`MemoryOutlook`] — when even the
//!      min-viable mask fits `Sys_avail(t)` the spike is absorbable:
//!      shrink the mask, shed nothing, charge `absorbed_spikes`. Only
//!      when `Sys_avail(t)` dips below the min-viable footprint is an
//!      OOM counted and work shed per [`EvictionMode`]. Victims are
//!      picked expired-deadline-first, then lowest [`PriorityClass`],
//!      then largest KV bytes × remaining decode (the shed that frees
//!      the most memory per eviction) — with uniform priorities and no
//!      deadlines this is exactly the pre-API order. An expired victim
//!      is *terminated* (`Outcome::DeadlineMissed`), never requeued or
//!      migrated. With `EngineConfig::elastic_accounting` off, any
//!      pressure under the current mask counts as an OOM (the
//!      pre-outlook behavior, kept for comparison runs);
//!   3. run one prefill (if queue room + memory headroom) or one decode
//!      step over the gathered batch; sample tokens; retire finished.
//!      Admission is priority-aware: a memory-blocked head of queue may
//!      preempt strictly-lower-class in-flight work, never the reverse.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Result};

use super::batcher::{decode_bucket, prefill_bucket, ActiveSeq, Batcher};
use super::controller::Controller;
use super::kv::{KvManager, KvPolicy};
use super::memmon::MemoryMonitor;
use super::metrics::{MemSample, Metrics, RequestRecord, ServeReport};
use super::outlook::MemoryOutlook;
use crate::api::{Outcome, RequestHandle, RequestStatus, SubmitRequest};
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::runtime::Runtime;
use crate::telemetry::{Bus, EventKind};

/// How the engine sheds in-flight work when interference pushes its
/// footprint over `Sys_avail(t)`.
/// Both modes pick victims the same way — expired deadlines first, then
/// the lowest priority class, then by KV bytes × remaining decode, the
/// sequence whose removal frees the most memory for the longest
/// remaining run (`Engine::pressure_victim`) — so a requeueing engine
/// sheds with the fewest evictions, exactly like a parking one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionMode {
    /// Evict the victim and requeue it locally — it restarts from its
    /// prompt (the single-node policy).
    #[default]
    Requeue,
    /// Export the victim's full state (KV included) into the parked
    /// stash for an external coordinator to migrate to a peer replica.
    /// Only meaningful when something drains the stash
    /// (`take_parked`): a standalone engine should use `Requeue`.
    Park,
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Simulated seconds per real compute second.
    pub time_scale: f64,
    /// Memory-trace sampling period (sim seconds).
    pub sample_every: f64,
    /// Re-run the controller at most this often (sim seconds).
    pub controller_period: f64,
    /// Safety factor on admission (fraction of available memory usable).
    pub admission_headroom: f64,
    /// Hard stop (sim seconds) even if work remains.
    pub max_sim_secs: f64,
    /// What to do with in-flight sequences under memory pressure.
    pub eviction: EvictionMode,
    /// Act on SLO deadlines (the default): expired queued requests are
    /// purged as `DeadlineMissed` without burning a prefill, and
    /// expired pressure victims are terminated rather than requeued.
    /// Off = measure-only: deadlines still classify terminal outcomes
    /// (hit-rates stay reportable) but never change scheduling — the
    /// legacy trace-replay front door, kept for baseline comparisons
    /// (`fleet::tenant_storm_fleet`'s FCFS side).
    pub enforce_deadlines: bool,
    /// Mask-elastic memory accounting: judge pressure against the
    /// [`MemoryOutlook`]'s `min_viable` footprint instead of the
    /// current-mask footprint. On (the default), a spike the controller
    /// can absorb by shrinking sheds no work and counts no OOM; off
    /// reproduces the pre-outlook behavior (every current-mask
    /// transgression is an OOM) for comparison runs.
    pub elastic_accounting: bool,
    /// The KV leg of the joint lattice: price `min_viable` with every
    /// resident sequence compressed to the controller's KV floor, and
    /// let `handle_memory_pressure` deploy per-sequence compression
    /// between shrink-mask and shed-work. Off reproduces PR-4's
    /// mask-only elasticity (requires `elastic_accounting`; inert
    /// without it).
    pub kv_elastic: bool,
    /// Periodically snapshot every active sequence into the portable
    /// [`SeqState`] format (the crash-recovery checkpoint), charging
    /// the modeled interconnect cost for the KV delta since the last
    /// snapshot. `None` (the default) disables checkpointing — a crash
    /// then loses all in-flight decode progress.
    pub checkpoint_period_secs: Option<f64>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { time_scale: 1.0, sample_every: 2.0,
                       controller_period: 5.0, admission_headroom: 0.95,
                       max_sim_secs: 1e9,
                       eviction: EvictionMode::Requeue,
                       enforce_deadlines: true,
                       elastic_accounting: true,
                       kv_elastic: true,
                       checkpoint_period_secs: None }
    }
}

/// Exported state of one sequence — everything a peer engine needs to
/// continue serving it. Produced by [`Engine::export_sequence`] and the
/// `Park` eviction mode; consumed by [`Engine::import_sequence`]. The
/// fleet coordinator moves these across replicas (charging the modeled
/// transfer cost for the payload).
#[derive(Clone, Debug)]
pub enum SeqState {
    /// Queued but unstarted: no KV yet, just the admission ticket.
    Queued(SubmitRequest),
    /// Mid-decode: the sequence's KV cache travels with it.
    Active {
        req: SubmitRequest,
        /// Tokens generated so far (prefill's first token included).
        generated: usize,
        /// Last sampled token (next decode input).
        next_token: i32,
        /// When prefill finished (shared-clock sim seconds) — preserved
        /// so TTFT accounting survives the move.
        prefill_done_at: f64,
        /// Tokens materialized in the cache (next write position).
        kv_len: usize,
        k: Vec<f32>,
        v: Vec<f32>,
        /// Logical KV bytes of the full (bucket-padded) cache under the
        /// exporting replica's mask at export time.
        kv_bytes: usize,
        /// Logical KV bytes of the live `prompt + generated` slice
        /// under the same mask *and the sequence's KV policy* — what a
        /// migration actually ships over the interconnect (the prefill
        /// bucket's padding rows carry no information and are re-padded
        /// on arrival; compressed-away tokens and dropped kv groups are
        /// gone and ship nothing).
        live_kv_bytes: usize,
        /// The sequence's KV compression policy, carried across
        /// migrate/checkpoint/restore so the importing engine restores
        /// the cache into the right accounting class.
        policy: KvPolicy,
    },
}

impl SeqState {
    pub fn id(&self) -> u64 {
        self.request().id
    }

    pub fn request(&self) -> &SubmitRequest {
        match self {
            SeqState::Queued(r) => r,
            SeqState::Active { req, .. } => req,
        }
    }

    /// Bytes a migration of this state must move over the interconnect:
    /// the *live* KV slice (`prompt + generated` tokens × the mask's
    /// active groups) plus the prompt token ids. Bucket padding is not
    /// shipped.
    pub fn transfer_bytes(&self) -> usize {
        let prompt = self.request().prompt_len * 4;
        match self {
            SeqState::Queued(_) => prompt,
            SeqState::Active { live_kv_bytes, .. } => {
                live_kv_bytes + prompt
            }
        }
    }

    /// What the pre-compression accounting charged: the bucket-padded
    /// cache. Kept so the strict-reduction regression (and the fleet's
    /// `migration_bytes_padded` counter) can compare without re-deriving
    /// masks.
    pub fn padded_transfer_bytes(&self) -> usize {
        let prompt = self.request().prompt_len * 4;
        match self {
            SeqState::Queued(_) => prompt,
            SeqState::Active { kv_bytes, .. } => kv_bytes + prompt,
        }
    }
}

/// Idle-but-blocked time increment: how far the clock creeps while the
/// engine waits for memory headroom with nothing runnable.
const BLOCKED_TICK: f64 = 0.05;

/// Persistent decode-batch state: while batch membership is unchanged,
/// the gathered caches stay resident here and per-step gather/scatter
/// (a ~85 ms memcpy at batch 8 — see EXPERIMENTS.md §Perf) is skipped.
struct BatchState {
    ids: Vec<u64>,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine {
    pub rt: Runtime,
    pub mem: MemoryModel,
    pub kv: KvManager,
    pub batcher: Batcher,
    pub monitor: MemoryMonitor,
    pub controller: Controller,
    pub cfg: EngineConfig,
    pub mask: PruneMask,
    pub metrics: Metrics,
    /// Telemetry event bus — disabled (and free) unless a recorder is
    /// attached. Every lifecycle transition this engine decides is
    /// emitted here; numerics never read from it (observer-effect
    /// guard: a seeded run's report is byte-identical on or off).
    pub bus: Bus,
    sim_time: f64,
    last_controller_at: f64,
    last_sample_at: f64,
    batch: Option<BatchState>,
    /// Victim states exported under `EvictionMode::Park`, awaiting
    /// pickup by the fleet coordinator.
    parked: Vec<SeqState>,
    /// Cheapest mask the controller may reach for the observed workload
    /// (refreshed by `run_controller`, cached here so `outlook()` works
    /// from `&self` — routers and fleet passes read it between steps).
    /// `None` until the controller has run once; the outlook then falls
    /// back to the current mask, which is always conservative.
    min_viable_mask: Option<PruneMask>,
    /// Dense (full-mask) parameter bytes — mask-independent, cached so
    /// the outlook's hot path never re-walks the full mask.
    dense_param_bytes: usize,
    /// Latest checkpoint per live sequence id (the crash-recovery
    /// stash, conceptually held off-device — a crash keeps it). Looked
    /// up by id only, never iterated into decisions or output.
    checkpoints: HashMap<u64, SeqState>,
    last_checkpoint_at: f64,
    /// Restored-but-not-yet-resumed snapshots, keyed by request id. A
    /// crash restore re-enters ADMISSION: the request waits at the
    /// head of its priority class while its snapshot is held aside
    /// here, and `try_prefill` re-attaches the KV in place of a
    /// prefill — recovered work queues like work instead of seizing a
    /// decode slot ahead of admitted higher-priority requests.
    resumable: HashMap<u64, SeqState>,
    /// Per-tenant committed KV *tokens*: the clamped full length
    /// (`min(prompt + max_new, max_seq)`) summed over every resident
    /// request (queued + active + parked). Maintained incrementally at
    /// every membership change so the fleet's tenant-fair quota check
    /// reads committed bytes without rescanning sequences.
    /// `kv_bytes_for_len` is exactly linear in length, so
    /// `tokens × per-token bytes` under the current mask equals the
    /// per-request rescan to the byte. A `BTreeMap` so the fleet-facing
    /// aggregation walk is tenant-ordered, never hash-ordered.
    committed_tokens: BTreeMap<crate::api::Tenant, u64>,
}

impl Engine {
    pub fn new(rt: Runtime, monitor: MemoryMonitor,
               controller: Controller, cfg: EngineConfig) -> Engine {
        let meta = rt.meta().clone();
        let mem = MemoryModel::new(&meta);
        let mask = PruneMask::full(&meta);
        let dense_param_bytes = mem.param_bytes(&mask);
        let mut engine = Engine {
            kv: KvManager::new(&meta),
            batcher: Batcher::new(),
            rt,
            mem,
            monitor,
            controller,
            cfg,
            mask,
            metrics: Metrics::default(),
            bus: Bus::disabled(),
            sim_time: 0.0,
            last_controller_at: f64::NEG_INFINITY,
            last_sample_at: f64::NEG_INFINITY,
            batch: None,
            parked: Vec::new(),
            min_viable_mask: None,
            dense_param_bytes,
            checkpoints: HashMap::new(),
            last_checkpoint_at: f64::NEG_INFINITY,
            resumable: HashMap::new(),
            committed_tokens: BTreeMap::new(),
        };
        engine.sync_kv_floor();
        engine
    }

    /// Whether the KV leg of the joint lattice is live: both elasticity
    /// gates on and a compression floor installed.
    fn kv_elastic_on(&self) -> bool {
        self.cfg.kv_elastic && self.cfg.elastic_accounting
            && self.kv.floor().is_some()
    }

    /// Keep the KV manager's floor in step with the config gates and
    /// the controller's floor policy (config fields are mutated after
    /// construction by the fleet's spawn path, so this re-syncs at
    /// every controller/pressure entry — a no-op when unchanged).
    fn sync_kv_floor(&mut self) {
        let floor =
            if self.cfg.kv_elastic && self.cfg.elastic_accounting {
                self.controller.kv_floor()
            } else {
                None
            };
        self.kv.set_floor(floor);
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// Nothing queued and nothing active.
    pub fn idle(&self) -> bool {
        self.batcher.active.is_empty() && self.batcher.waiting.is_empty()
    }

    /// Requests accepted but not yet finished (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.batcher.active.len() + self.batcher.waiting.len()
    }

    /// The lifecycle entry point: hand the engine one typed request. It
    /// is served on subsequent `step_to` calls (external admission —
    /// the fleet router dispatches through this too); the returned
    /// handle keys [`Engine::status`] / [`Engine::cancel`].
    pub fn submit(&mut self, req: SubmitRequest) -> RequestHandle {
        let handle = RequestHandle { id: req.id };
        self.bus.emit(self.sim_time, Some(req.id), Some(&req.tenant),
                      || EventKind::Submit);
        self.metrics.note_submitted(&req);
        self.ledger_add(&req);
        self.batcher.enqueue(req);
        handle
    }

    /// Re-enter a displaced request into admission (fleet requeue and
    /// crash-recovery paths). Unlike [`Engine::submit`] this is not a
    /// new submission — the submitted counter is untouched — but the
    /// request becomes resident here, so the committed-bytes ledger is
    /// charged.
    pub fn adopt(&mut self, req: SubmitRequest) {
        self.ledger_add(&req);
        self.batcher.enqueue(req);
    }

    /// As [`Engine::adopt`], but at the head of the request's priority
    /// class (evicted work keeps its place in line).
    pub fn adopt_front(&mut self, req: SubmitRequest) {
        self.ledger_add(&req);
        self.batcher.requeue_front(req);
    }

    /// Tokens a resident request commits: its KV at full clamped length.
    fn commit_tokens_of(&self, req: &SubmitRequest) -> u64 {
        (req.prompt_len + req.max_new_tokens)
            .min(self.rt.meta().max_seq) as u64
    }

    /// A request became resident (queued, active, or parked).
    fn ledger_add(&mut self, req: &SubmitRequest) {
        *self
            .committed_tokens
            .entry(req.tenant.clone())
            .or_insert(0) += self.commit_tokens_of(req);
    }

    /// A resident request left (terminal, exported, or drained).
    fn ledger_remove(&mut self, req: &SubmitRequest) {
        let n = self.commit_tokens_of(req);
        if let Some(v) = self.committed_tokens.get_mut(&req.tenant) {
            debug_assert!(*v >= n, "committed-token ledger underflow");
            *v = v.saturating_sub(n);
            if *v == 0 {
                self.committed_tokens.remove(&req.tenant);
            }
        } else {
            debug_assert!(false,
                          "committed-token ledger missing tenant {:?}",
                          req.tenant);
        }
    }

    /// Fold this engine's committed KV bytes per tenant into `acc`,
    /// priced under the *current* mask — byte-identical to summing
    /// [`Engine::admission_cost`] over every resident request, because
    /// `kv_bytes_for_len` is exactly linear in length. O(tenants held),
    /// not O(sequences held).
    pub fn committed_kv_bytes(
        &self, acc: &mut std::collections::BTreeMap<crate::api::Tenant,
                                                    u64>) {
        if self.committed_tokens.is_empty() {
            return;
        }
        let per_token = self.kv_bytes_for_len(1) as u64;
        for (tenant, tokens) in &self.committed_tokens {
            *acc.entry(tenant.clone()).or_insert(0) +=
                tokens * per_token;
        }
    }

    /// The rescan oracle for [`Engine::committed_kv_bytes`]: walk every
    /// resident request and sum admission costs (the pre-ledger
    /// accounting). Debug assertions and the quota proptest hold the
    /// two equal.
    pub fn committed_kv_bytes_rescan(
        &self, acc: &mut std::collections::BTreeMap<crate::api::Tenant,
                                                    u64>) {
        for req in self.batcher.waiting.iter() {
            *acc.entry(req.tenant.clone()).or_insert(0) +=
                self.admission_cost(req) as u64;
        }
        for s in self.batcher.active.iter() {
            *acc.entry(s.req.tenant.clone()).or_insert(0) +=
                self.admission_cost(&s.req) as u64;
        }
        for state in &self.parked {
            let req = state.request();
            *acc.entry(req.tenant.clone()).or_insert(0) +=
                self.admission_cost(req) as u64;
        }
    }

    /// Lifecycle state of a request this engine has seen: queued,
    /// mid-decode, parked for migration, or finished with a terminal
    /// [`Outcome`]. `None` for ids this engine does not hold (a fleet
    /// aggregates over replicas).
    pub fn status(&self, id: u64) -> Option<RequestStatus> {
        if let Some(o) = self.metrics.outcome(id) {
            return Some(RequestStatus::Finished(o));
        }
        if self.batcher.active.iter().any(|s| s.req.id == id) {
            return Some(RequestStatus::Running);
        }
        if self.batcher.waiting.iter().any(|r| r.id == id) {
            return Some(RequestStatus::Queued);
        }
        if self.parked.iter().any(|s| s.id() == id) {
            return Some(RequestStatus::Migrating);
        }
        None
    }

    /// Reclaim a request: queued, mid-decode (its KV is freed), or
    /// parked. Books `Outcome::Cancelled`. Returns false when the
    /// engine does not hold `id` live (already terminal, or elsewhere).
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        if let Some(i) =
            self.batcher.waiting.iter().position(|r| r.id == id)
        {
            // lint:allow(hot-path-panic): i came from position() on
            // the same deque one line up
            let req = self.batcher.waiting.remove(i).unwrap();
            self.drop_checkpoint(id);
            self.resumable.remove(&id);
            self.ledger_remove(&req);
            self.bus.emit(self.sim_time, Some(id), Some(&req.tenant),
                          || EventKind::Cancel);
            self.metrics.note_terminal(&req, Outcome::Cancelled);
            return Ok(true);
        }
        if let Some(i) =
            self.batcher.active.iter().position(|s| s.req.id == id)
        {
            self.flush_batch()?;
            let seq = self.batcher.active.remove(i);
            self.kv.remove(seq.req.id);
            self.drop_checkpoint(id);
            self.ledger_remove(&seq.req);
            self.bus.emit(self.sim_time, Some(id),
                          Some(&seq.req.tenant), || EventKind::Cancel);
            self.metrics.note_terminal(&seq.req, Outcome::Cancelled);
            return Ok(true);
        }
        if let Some(i) = self.parked.iter().position(|s| s.id() == id) {
            let state = self.parked.remove(i);
            self.drop_checkpoint(id);
            self.ledger_remove(state.request());
            self.bus.emit(self.sim_time, Some(id),
                          Some(&state.request().tenant),
                          || EventKind::Cancel);
            self.metrics.note_terminal(state.request(),
                                       Outcome::Cancelled);
            return Ok(true);
        }
        Ok(false)
    }

    /// Current model + KV footprint under the active mask.
    pub fn bytes_used(&self) -> usize {
        self.bytes_used_under(&self.mask)
    }

    /// Model + live-KV footprint this engine would have under an
    /// arbitrary mask (same per-layer accounting as `bytes_used`, with
    /// the live sequences' cached lengths).
    pub fn bytes_used_under(&self, mask: &PruneMask) -> usize {
        self.mem.param_bytes(mask) + self.kv.bytes_used(mask)
    }

    /// The elastic view of this engine's footprint: `{min_viable,
    /// current, dense}` bytes (see [`MemoryOutlook`]). With
    /// `kv_elastic` on, `min_viable` is the *joint* minimum — the floor
    /// mask priced with every resident sequence compressed to the KV
    /// floor — and `kv_slack` reports the compression-only leg at the
    /// current mask. With `elastic_accounting` off, or before the
    /// controller has produced a min-viable mask, the outlook is rigid
    /// at the current footprint — every consumer then degrades to the
    /// classic current-mask behavior.
    pub fn outlook(&self) -> MemoryOutlook {
        let current = self.bytes_used();
        if !self.cfg.elastic_accounting {
            return MemoryOutlook::rigid(current);
        }
        // Dense footprint without re-walking the full mask: every
        // layer caches the same tokens, so dense KV is just the token
        // total times the dense per-token bytes.
        let dense = self.dense_param_bytes + self.kv.dense_bytes();
        let kv_elastic = self.kv_elastic_on();
        let min_viable = match &self.min_viable_mask {
            Some(m) => {
                let kv = if kv_elastic {
                    self.kv.floor_bytes(m)
                } else {
                    self.kv.bytes_used(m)
                };
                self.mem.param_bytes(m) + kv
            }
            None => current,
        };
        let outlook = MemoryOutlook::new(min_viable, current, dense);
        if kv_elastic {
            outlook.with_kv_slack(
                self.kv
                    .bytes_used(&self.mask)
                    .saturating_sub(self.kv.floor_bytes(&self.mask)),
            )
        } else {
            outlook
        }
    }

    /// The workload descriptor the controller conditions on: current
    /// decode batch size and the longest projected sequence among active
    /// + queued work.
    fn observed_workload(&self) -> Workload {
        let batch = decode_bucket(self.batcher.active.len()).max(1);
        let longest = self
            .batcher
            .active
            .iter()
            .map(|s| s.req.prompt_len + s.req.max_new_tokens)
            .chain(self.batcher.waiting.iter()
                   .map(|r| r.prompt_len + r.max_new_tokens))
            .max()
            .unwrap_or(32);
        Workload::new(batch, longest.min(self.rt.meta().max_seq))
    }

    fn run_controller(&mut self, force: bool) -> Result<()> {
        // Cheap no-op when unchanged; re-checked here because fleet
        // spawn paths mutate the config gates after construction.
        self.sync_kv_floor();
        if !force
            && self.sim_time - self.last_controller_at
                < self.cfg.controller_period
        {
            return Ok(());
        }
        self.last_controller_at = self.sim_time;
        let avail = self.monitor.available_at(self.sim_time);
        let w = self.observed_workload();
        #[allow(clippy::disallowed_methods)]
        // lint:allow(wall-clock): controller_secs meters real host
        // time spent deciding; it never feeds simulated time
        let t0 = std::time::Instant::now();
        let new_mask = self.controller.decide(&mut self.rt, w, avail)?;
        // Keep the outlook's min-viable mask in step with the observed
        // workload (the controller caches per workload bucket, so this
        // is a lookup except on the first sight of a new bucket).
        if self.cfg.elastic_accounting {
            self.min_viable_mask =
                Some(self.controller.min_viable_mask(&mut self.rt, w)?);
        }
        self.metrics.controller_secs += t0.elapsed().as_secs_f64();
        if new_mask != self.mask {
            self.metrics.mask_switches += 1;
            self.mask = new_mask;
            self.emit_mask_deploy(w, avail, false);
        }
        Ok(())
    }

    /// Audit a mask deployment: the GSI decision inputs (workload
    /// bucket, `Sys_avail(t)`) and the [`MemoryOutlook`] lattice at
    /// decision time. Emission only — a pure read of engine state.
    fn emit_mask_deploy(&self, w: Workload, avail: usize, forced: bool) {
        self.bus.emit(self.sim_time, None, None, || {
            let ol = self.outlook();
            EventKind::MaskDeploy {
                batch: w.batch,
                seqlen: w.seqlen,
                avail: avail as u64,
                min_viable: ol.min_viable as u64,
                current: ol.current as u64,
                dense: ol.dense as u64,
                retained: self.mask.param_fraction(self.rt.meta()),
                forced,
            }
        });
    }

    fn sample_memory(&mut self) {
        if self.sim_time - self.last_sample_at < self.cfg.sample_every {
            return;
        }
        self.last_sample_at = self.sim_time;
        self.metrics.mem_trace.push(MemSample {
            t: self.sim_time,
            used: self.bytes_used(),
            available: self.monitor.available_at(self.sim_time),
            param_bytes: self.mem.param_bytes(&self.mask),
            kv_bytes: self.kv.bytes_used(&self.mask),
        });
    }

    /// Handle an interference spike. The outlook decides what kind of
    /// pressure this is: a spike the mask lattice can absorb
    /// (`min_viable <= Sys_avail(t) < current`) shrinks the mask and
    /// sheds nothing — charged to `absorbed_spikes`, not `oom_events`.
    /// Only a true OOM (`Sys_avail(t) < min_viable`) counts as one and
    /// sheds work per the eviction mode. With `elastic_accounting` off,
    /// every current-mask transgression is an OOM (the old behavior).
    fn handle_memory_pressure(&mut self) -> Result<()> {
        let avail = self.monitor.available_at(self.sim_time);
        if self.bytes_used() <= avail {
            return Ok(());
        }
        let absorbable = !self.outlook().true_oom(avail)
            && self.cfg.elastic_accounting;
        if !absorbable {
            self.metrics.oom_events += 1;
            self.emit_oom();
        }
        // Give the controller a chance to shrink the model first.
        self.run_controller(true)?;
        if absorbable {
            // The controller's cached decision grid can under-shoot —
            // its stop predicate prices the *projected* workload KV,
            // which may underestimate the live footprint. Pressure
            // overrides the grid: deploy the min-viable mask itself
            // rather than shedding work the mask space can absorb.
            if self.bytes_used() > avail {
                self.deploy_min_viable();
            }
            // The second elasticity axis: when the mask alone cannot
            // absorb, compress resident sequences down to the KV floor
            // — largest reclaim first — before any work is shed.
            if self.bytes_used() > avail {
                self.compress_under_pressure(avail)?;
            }
            if self.bytes_used()
                <= self.monitor.available_at(self.sim_time)
            {
                self.metrics.absorbed_spikes += 1;
                self.bus.emit(self.sim_time, None, None,
                              || EventKind::AbsorbedSpike);
                return Ok(());
            }
            // Even the joint (mask × KV-policy) floor did not fit (the
            // monitor moved, or the outlook was stale): this is a true
            // OOM after all.
            self.metrics.oom_events += 1;
            self.emit_oom();
        }
        self.flush_batch()?;
        while self.bytes_used()
            > self.monitor.available_at(self.sim_time)
            && !self.batcher.active.is_empty()
        {
            // Both modes shed the victim whose removal frees the most
            // memory for the longest remaining run (expired deadlines
            // and lower classes first), so Requeue frees memory with
            // the fewest evictions, exactly like Park.
            let Some(i) = self.pressure_victim() else {
                debug_assert!(false, "no victim with active non-empty");
                break;
            };
            let seq = self.batcher.active.remove(i);
            if self.cfg.enforce_deadlines && seq.req.expired(self.sim_time)
            {
                // Past-deadline work is terminated, not recycled:
                // requeueing or migrating a request that already missed
                // its SLO only burns capacity (the victim order prefers
                // exactly these).
                self.kv.remove(seq.req.id);
                self.drop_checkpoint(seq.req.id);
                self.ledger_remove(&seq.req);
                self.bus.emit(self.sim_time, Some(seq.req.id),
                              Some(&seq.req.tenant), || {
                    EventKind::DeadlineMiss { site: "pressure" }
                });
                self.metrics.note_terminal(&seq.req,
                                           Outcome::DeadlineMissed);
                continue;
            }
            match self.cfg.eviction {
                EvictionMode::Requeue => {
                    // The cache is dropped; the request restarts from
                    // its prompt — the checkpoint with it (one copy of
                    // the sequence's truth at a time).
                    self.kv.remove(seq.req.id);
                    self.drop_checkpoint(seq.req.id);
                    self.metrics.evictions += 1;
                    self.bus.emit(self.sim_time, Some(seq.req.id),
                                  Some(&seq.req.tenant), || {
                        EventKind::Evict { mode: "requeue" }
                    });
                    self.batcher.requeue_front(seq.req);
                }
                EvictionMode::Park => {
                    self.bus.emit(self.sim_time, Some(seq.req.id),
                                  Some(&seq.req.tenant), || {
                        EventKind::Evict { mode: "park" }
                    });
                    let state = self.export_active(seq)?;
                    self.parked.push(state);
                }
            }
        }
        Ok(())
    }

    /// The pressure path's compress step: rewrite resident caches down
    /// to the controller's KV floor, one sequence at a time in
    /// deterministic order (largest reclaim first, ties toward the
    /// lowest id), until the footprint fits `avail` or every resident
    /// sequence sits at the floor. Books `compressed_spikes` /
    /// `kv_bytes_reclaimed` when compression engaged. A no-op when the
    /// KV axis is off.
    fn compress_under_pressure(&mut self, avail: usize) -> Result<()> {
        if !self.kv_elastic_on() {
            return Ok(());
        }
        let Some(floor) = self.kv.floor() else {
            return Ok(());
        };
        // The persistent decode batch holds gathered cache copies; a
        // later scatter would resurrect the pre-compression rows.
        self.flush_batch()?;
        let mut candidates: Vec<(usize, u64)> = self
            .batcher
            .active
            .iter()
            .map(|s| {
                (self.kv.reclaim_estimate(s.req.id, floor, &self.mask),
                 s.req.id)
            })
            .filter(|(est, _)| *est > 0)
            .collect();
        candidates.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let before = self.kv.bytes_used(&self.mask);
        let mut compressed = 0u64;
        for (_, id) in candidates {
            if self.bytes_used() <= avail {
                break;
            }
            self.kv.compress(id, floor)?;
            compressed += 1;
        }
        if compressed > 0 {
            let reclaimed =
                before - self.kv.bytes_used(&self.mask);
            self.metrics.compressed_spikes += 1;
            self.metrics.kv_bytes_reclaimed += reclaimed as u64;
            self.bus.emit(self.sim_time, None, None, || {
                EventKind::KvCompress { seqs: compressed,
                                        bytes: reclaimed as u64 }
            });
        }
        Ok(())
    }

    /// True-OOM audit: the instant event plus a flight-recorder dump —
    /// an OOM is exactly the moment a postmortem wants the ring for.
    fn emit_oom(&self) {
        self.bus.emit(self.sim_time, None, None, || EventKind::Oom);
        if self.bus.enabled() {
            self.bus
                .flight_dump(self.sim_time, "true OOM under pressure");
        }
    }

    /// Index of the active sequence whose eviction/migration pays off
    /// most. Preference order: already past its deadline first (that
    /// work can no longer hit its SLO), then the lowest priority class,
    /// then the largest KV bytes × remaining-decode estimate (ties
    /// break toward the oldest). With uniform priorities and no
    /// deadlines this reduces exactly to the pre-API order. `None` when
    /// nothing is active.
    fn pressure_victim(&self) -> Option<usize> {
        self.victim_among(|_| true)
    }

    /// `pressure_victim` restricted to an eligibility predicate (the
    /// preemption path restricts to strictly-lower classes).
    fn victim_among(&self, eligible: impl Fn(&ActiveSeq) -> bool)
                    -> Option<usize> {
        use std::cmp::Reverse;

        // Victim preference key, compared lexicographically: expired
        // first, then lowest class (Reverse), then largest score.
        type VictimKey =
            (bool, Reverse<crate::api::PriorityClass>, usize);
        let mut best: Option<(usize, VictimKey)> = None;
        for (i, s) in self.batcher.active.iter().enumerate() {
            if !eligible(s) {
                continue;
            }
            let remaining =
                s.req.max_new_tokens.saturating_sub(s.generated).max(1);
            let score = self.resident_kv_bytes(s.req.id) * remaining;
            // measure-only mode must not let deadlines steer
            // scheduling, victim choice included
            let expired = self.cfg.enforce_deadlines
                && s.req.expired(self.sim_time);
            let key = (expired, Reverse(s.req.priority), score);
            if best.map_or(true, |(_, b)| key > b) {
                best = Some((i, key));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Logical KV bytes one *resident* sequence currently holds under
    /// the deployed mask and its own compression policy. Zero for ids
    /// without a cache.
    fn resident_kv_bytes(&self, id: u64) -> usize {
        match (self.kv.seq_len(id), self.kv.policy_of(id)) {
            (Some(len), Some(p)) => {
                len * self.kv.per_token_bytes(&self.mask, p)
            }
            _ => 0,
        }
    }

    /// Logical KV bytes of one sequence of `len` cached tokens under the
    /// current mask (the same per-layer accounting as
    /// `KvManager::bytes_used`).
    pub fn kv_bytes_for_len(&self, len: usize) -> usize {
        self.kv_bytes_for_len_under(&self.mask, len)
    }

    /// As [`Engine::kv_bytes_for_len`], under an arbitrary mask.
    pub fn kv_bytes_for_len_under(&self, mask: &PruneMask, len: usize)
                                  -> usize {
        let meta = self.rt.meta();
        let dh = meta.head_dim();
        let mut kv = 0usize;
        for l in 0..meta.n_layers {
            kv += 2 * mask.active_kv_groups(l) * dh * len
                * crate::model_meta::BYTES_PER_SCALAR;
        }
        kv
    }

    /// Projected bytes if we admit `req` (its KV at full length) under
    /// the current mask. Public so memory-aware routers can estimate a
    /// request's footprint on each candidate replica.
    pub fn admission_cost(&self, req: &SubmitRequest) -> usize {
        let full_len = (req.prompt_len + req.max_new_tokens)
            .min(self.rt.meta().max_seq);
        self.kv_bytes_for_len(full_len)
    }

    /// Deploy the min-viable mask directly — the pressure/admission
    /// override for when the controller's decision grid under-shoots.
    /// No-op when none is cached or it is already deployed.
    fn deploy_min_viable(&mut self) {
        if let Some(m) = self.min_viable_mask.clone() {
            if m != self.mask {
                self.metrics.mask_switches += 1;
                self.mask = m;
                if self.bus.enabled() {
                    let w = self.observed_workload();
                    let avail =
                        self.monitor.available_at(self.sim_time);
                    self.emit_mask_deploy(w, avail, true);
                }
            }
        }
    }

    /// Projected bytes to host `req` under the cheapest deployable mask
    /// — the placement counterpart of [`Engine::admission_cost`]: what
    /// the sequence costs a peer that shrinks as far as allowed, so
    /// feasibility checks against *elastic* headroom compare like with
    /// like. Equals `admission_cost` for static deployments, with
    /// mask-elastic accounting off, or before the controller has run.
    pub fn elastic_admission_cost(&self, req: &SubmitRequest) -> usize {
        let current = self.admission_cost(req);
        if !self.cfg.elastic_accounting {
            return current;
        }
        match &self.min_viable_mask {
            Some(m) => {
                let full_len = (req.prompt_len + req.max_new_tokens)
                    .min(self.rt.meta().max_seq);
                let mut cost =
                    self.kv_bytes_for_len_under(m, full_len);
                // joint-elastic pricing: the sequence could run
                // compressed to the KV floor (capped tokens, capped
                // groups) on top of the floor mask
                if let Some(floor) =
                    self.kv_elastic_on().then(|| self.kv.floor()).flatten()
                {
                    cost = cost.min(
                        full_len.min(floor.token_cap())
                            * self.kv.per_token_bytes(m, floor),
                    );
                }
                cost.min(current)
            }
            None => current,
        }
    }

    /// Could a min-viable deployment host `req` within `avail` even
    /// though the current mask cannot? (Admission's counterpart of the
    /// outlook: an empty-but-dense server should shrink, not reject.)
    fn min_viable_admits(&self, req: &SubmitRequest, avail: usize)
                         -> bool {
        let Some(m) = &self.min_viable_mask else {
            return false;
        };
        let full_len = (req.prompt_len + req.max_new_tokens)
            .min(self.rt.meta().max_seq);
        // Residents are priced at the joint floor (pressure can
        // compress them); the newcomer is priced at full length under
        // the floor mask — it is admitted dense, so a floored price
        // here would diverge from the actual admission check and stall.
        let resident = if self.kv_elastic_on() {
            self.kv.floor_bytes(m)
        } else {
            self.kv.bytes_used(m)
        };
        self.mem.param_bytes(m) + resident
            + self.kv_bytes_for_len_under(m, full_len)
            <= avail
    }

    // ---- sequence export / import (fleet migration) -------------------

    /// Package one active sequence (already removed from the batcher)
    /// into a portable state, pulling its cache out of the KV manager.
    fn export_active(&mut self, seq: ActiveSeq) -> Result<SeqState> {
        let cache = self.kv.remove(seq.req.id).ok_or_else(|| {
            anyhow::anyhow!("export: seq {} has no cache", seq.req.id)
        })?;
        let kv_bytes = self.kv_bytes_for_len(cache.len);
        // The live slice: prompt tokens + decode writes. `cache.len` is
        // bucket-padded by prefill; the padding rows carry no
        // information, so a migration ships (and is charged for) only
        // the live rows. A compressed cache may be shorter than the
        // prefill bucket, so the live slice caps at the physical length
        // — and is priced per the sequence's policy (dropped kv groups
        // ship nothing).
        let live_len = (seq.req.prompt_len
            + cache.len
                .saturating_sub(prefill_bucket(seq.req.prompt_len)))
            .min(cache.len);
        let live_kv_bytes =
            live_len * self.kv.per_token_bytes(&self.mask, cache.policy);
        Ok(SeqState::Active {
            req: seq.req,
            generated: seq.generated,
            next_token: seq.next_token,
            prefill_done_at: seq.prefill_done_at,
            kv_len: cache.len,
            policy: cache.policy,
            k: cache.k,
            v: cache.v,
            kv_bytes,
            live_kv_bytes,
        })
    }

    /// Remove one sequence — mid-decode or queued-but-unstarted — and
    /// return its portable state, flushing the persistent decode batch
    /// first so the exported cache is coherent. `None` when the engine
    /// doesn't hold `id`.
    pub fn export_sequence(&mut self, id: u64) -> Result<Option<SeqState>> {
        if let Some(i) =
            self.batcher.active.iter().position(|s| s.req.id == id)
        {
            self.flush_batch()?;
            let seq = self.batcher.active.remove(i);
            self.drop_checkpoint(id);
            self.ledger_remove(&seq.req);
            return Ok(Some(self.export_active(seq)?));
        }
        if let Some(i) =
            self.batcher.waiting.iter().position(|r| r.id == id)
        {
            // lint:allow(hot-path-panic): i came from position() on
            // the same deque one line up
            let req = self.batcher.waiting.remove(i).unwrap();
            self.drop_checkpoint(id);
            self.ledger_remove(&req);
            if let Some(state) = self.resumable.remove(&id) {
                // an un-resumed restore travels as its snapshot: the
                // recovered decode progress survives the move
                return Ok(Some(state));
            }
            return Ok(Some(SeqState::Queued(req)));
        }
        Ok(None)
    }

    /// Whether `state` can be installed here: no live id collision and
    /// (for active states) a cache shape matching this engine's model.
    pub fn can_import(&self, state: &SeqState) -> bool {
        let id = state.id();
        if self.kv.contains(id)
            || self.batcher.active.iter().any(|s| s.req.id == id)
            || self.batcher.waiting.iter().any(|r| r.id == id)
            || self.resumable.contains_key(&id)
        {
            return false;
        }
        match state {
            SeqState::Queued(_) => true,
            SeqState::Active { k, v, .. } => {
                k.len() == self.kv.seq_elems()
                    && v.len() == self.kv.seq_elems()
            }
        }
    }

    /// Install a sequence exported by a peer engine. Queued states join
    /// the admission queue; active states resume decoding with their KV
    /// intact (first token already served, so TTFT is unaffected by the
    /// move). Fails, leaving the engine untouched, on a live id
    /// collision or a cache whose shape doesn't match this model.
    pub fn import_sequence(&mut self, state: SeqState) -> Result<()> {
        if !self.can_import(&state) {
            bail!("import: sequence {} rejected (duplicate id or \
                   mismatched cache shape)", state.id());
        }
        match state {
            SeqState::Queued(req) => {
                self.ledger_add(&req);
                self.batcher.enqueue(req)
            }
            SeqState::Active { req, generated, next_token,
                               prefill_done_at, kv_len, policy, k, v,
                               .. } => {
                self.kv.insert(req.id, k, v, kv_len, &self.mask)?;
                // restore the sequence into its compression class —
                // the cache data is already compressed, this re-labels
                // the accounting (and is a data no-op)
                self.kv.compress(req.id, policy)?;
                self.ledger_add(&req);
                self.batcher.push_active(ActiveSeq {
                    req,
                    generated,
                    next_token,
                    prefill_done_at,
                });
            }
        }
        Ok(())
    }

    /// Land a restored checkpoint without seizing a decode slot: the
    /// request re-enters admission at the head of its priority class
    /// while its snapshot waits in the `resumable` stash; when
    /// admission pops the request, the sequence re-attaches its KV and
    /// resumes mid-decode with no re-prefill (its first token was
    /// served before the crash). Only active states resume — a queued
    /// state has no progress to hold aside and should just `submit`.
    /// Fails, leaving the engine untouched, on a live id collision or
    /// a mismatched cache shape (the restore is then worthless here).
    pub fn resume_import(&mut self, state: SeqState) -> Result<()> {
        if !self.can_import(&state) {
            bail!("resume: sequence {} rejected (duplicate id or \
                   mismatched cache shape)", state.id());
        }
        if !matches!(state, SeqState::Active { .. }) {
            bail!("resume: sequence {} has no decode progress to hold \
                   aside", state.id());
        }
        let req = state.request().clone();
        self.resumable.insert(req.id, state);
        self.ledger_add(&req);
        self.batcher.requeue_front(req);
        Ok(())
    }

    /// Detach and return the un-resumed restore snapshot for `id`, if
    /// one is pending. Evacuation paths (spot-reclaim drains, queue
    /// rebalancing) ship this state instead of the bare queued request
    /// so the restored decode progress survives the move.
    pub fn take_resumable(&mut self, id: u64) -> Option<SeqState> {
        self.resumable.remove(&id)
    }

    /// Drain the states parked by `EvictionMode::Park` (the fleet
    /// coordinator's pickup point).
    pub fn take_parked(&mut self) -> Vec<SeqState> {
        let out = std::mem::take(&mut self.parked);
        for state in &out {
            self.ledger_remove(state.request());
        }
        out
    }

    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// The parked states, without draining them (quota accounting and
    /// the lifecycle API read these).
    pub fn parked_states(&self) -> &[SeqState] {
        &self.parked
    }

    /// Drain the admission queue (fleet queue-rebalancing off a
    /// pressured replica).
    pub fn take_waiting(&mut self) -> Vec<SubmitRequest> {
        let out: Vec<SubmitRequest> =
            self.batcher.waiting.drain(..).collect();
        for req in &out {
            self.ledger_remove(req);
        }
        out
    }

    // ---- checkpoint / crash recovery ----------------------------------

    /// Live checkpoints currently held (tests and reports).
    pub fn checkpoint_len(&self) -> usize {
        self.checkpoints.len()
    }

    /// A sequence left this engine (finished, cancelled, rejected, or
    /// exported): its checkpoint is stale and must never restore.
    fn drop_checkpoint(&mut self, id: u64) {
        self.checkpoints.remove(&id);
    }

    /// Snapshot one active sequence into the portable [`SeqState`]
    /// format without disturbing it (the caller has flushed the batch).
    fn snapshot_active(&self, seq: &ActiveSeq) -> Option<SeqState> {
        let cache = self.kv.get(seq.req.id)?;
        let kv_bytes = self.kv_bytes_for_len(cache.len);
        let live_len = (seq.req.prompt_len
            + cache.len
                .saturating_sub(prefill_bucket(seq.req.prompt_len)))
            .min(cache.len);
        Some(SeqState::Active {
            req: seq.req.clone(),
            generated: seq.generated,
            next_token: seq.next_token,
            prefill_done_at: seq.prefill_done_at,
            kv_len: cache.len,
            policy: cache.policy,
            k: cache.k.clone(),
            v: cache.v.clone(),
            kv_bytes,
            live_kv_bytes: live_len
                * self.kv.per_token_bytes(&self.mask, cache.policy),
        })
    }

    /// Periodic crash-recovery checkpoint: when due, snapshot every
    /// active sequence whose live KV grew since its last snapshot and
    /// charge the modeled interconnect cost for the *delta* bytes (the
    /// padding-free slice that actually ships). A no-op unless
    /// `checkpoint_period_secs` is set and something changed.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let Some(period) = self.cfg.checkpoint_period_secs else {
            return Ok(());
        };
        if self.sim_time - self.last_checkpoint_at < period {
            return Ok(());
        }
        self.last_checkpoint_at = self.sim_time;
        if self.batcher.active.is_empty() {
            return Ok(());
        }
        self.flush_batch()?;
        let mut delta_bytes = 0usize;
        let mut snaps = Vec::new();
        for seq in &self.batcher.active {
            let Some(state) = self.snapshot_active(seq) else {
                continue;
            };
            let new_bytes = state.transfer_bytes();
            let old_bytes = self
                .checkpoints
                .get(&seq.req.id)
                .map(|s| s.transfer_bytes())
                .unwrap_or(0);
            // Re-snapshot on ANY size change: a compressed sequence
            // shrinks its live slice, and the stale (larger) snapshot
            // would otherwise be what a restore ships and re-prices.
            // Shrinks ride the stream for free — the delta charges
            // only growth.
            if new_bytes != old_bytes {
                let delta = new_bytes.saturating_sub(old_bytes);
                delta_bytes += delta;
                self.bus.emit(self.sim_time, Some(seq.req.id),
                              Some(&seq.req.tenant), || {
                    EventKind::Checkpoint { bytes: delta as u64 }
                });
                snaps.push(state);
            }
        }
        if snaps.is_empty() {
            return Ok(());
        }
        for state in snaps {
            self.checkpoints.insert(state.id(), state);
        }
        self.metrics.checkpoints_taken += 1;
        self.metrics.checkpoint_bytes += delta_bytes as u64;
        // Deltas ride an always-open replication stream: serving is
        // charged for the bytes (what makes the period a real knob)
        // but not a per-transfer setup latency — that would price a
        // periodic snapshot like a discrete migration.
        self.sim_time += self.rt.stream_cost(delta_bytes)
            * self.cfg.time_scale;
        Ok(())
    }

    /// Catastrophic loss of this engine (replica crash / expired spot
    /// grace): every resident cache, queued request, and parked state
    /// is destroyed. Returns what a coordinator needs to recover:
    /// `(checkpointed, lost, queued)` — sequences with a live
    /// checkpoint (restorable on a peer, losing only tokens decoded
    /// since the snapshot), in-flight work with *no* checkpoint (its
    /// decode progress is gone; the request must re-enter admission),
    /// and queued-but-unstarted requests (nothing lost but the queue
    /// slot). Terminal outcomes already booked are untouched; the
    /// engine is left empty and idle.
    pub fn crash_dump(&mut self)
                      -> (Vec<SeqState>, Vec<SubmitRequest>,
                          Vec<SubmitRequest>) {
        self.batch = None;
        self.committed_tokens.clear();
        let mut ckpts = Vec::new();
        let mut lost = Vec::new();
        let mut queued = Vec::new();
        let waiting: Vec<SubmitRequest> =
            self.batcher.waiting.drain(..).collect();
        for req in waiting {
            // an un-resumed restore is checkpoint-equivalent: its
            // snapshot is in hand, restorable again on a peer
            match self
                .resumable
                .remove(&req.id)
                .or_else(|| self.checkpoints.remove(&req.id))
            {
                Some(state) => ckpts.push(state),
                None => queued.push(req),
            }
        }
        self.resumable.clear();
        let active: Vec<ActiveSeq> =
            self.batcher.active.drain(..).collect();
        for seq in active {
            self.kv.remove(seq.req.id);
            match self.checkpoints.remove(&seq.req.id) {
                Some(state) => ckpts.push(state),
                None => lost.push(seq.req),
            }
        }
        let parked = std::mem::take(&mut self.parked);
        for state in parked {
            match self.checkpoints.remove(&state.id()) {
                Some(ckpt) => ckpts.push(ckpt),
                None => lost.push(state.request().clone()),
            }
        }
        self.checkpoints.clear();
        (ckpts, lost, queued)
    }

    /// Advance the clock by one unit of compute: modeled cost when the
    /// runtime provides one (sim backend), measured wall time otherwise.
    fn advance(&mut self, wall_secs: f64) {
        let dt = self.rt.last_cost().unwrap_or(wall_secs);
        self.metrics.exec_secs += dt;
        self.sim_time += dt * self.cfg.time_scale;
    }

    /// Terminate queued requests whose completion deadline has already
    /// passed: serving them cannot hit the SLO, so they are booked as
    /// `DeadlineMissed` without burning a prefill. A no-op when nothing
    /// carries a deadline (the trace-replay default).
    fn drop_expired_queued(&mut self) {
        if !self.cfg.enforce_deadlines {
            return;
        }
        while let Some(front) = self.batcher.waiting.front() {
            if !front.expired(self.sim_time) {
                break;
            }
            let Some(req) = self.batcher.waiting.pop_front() else {
                break;
            };
            self.drop_checkpoint(req.id);
            self.resumable.remove(&req.id);
            self.ledger_remove(&req);
            self.bus.emit(self.sim_time, Some(req.id),
                          Some(&req.tenant), || {
                EventKind::DeadlineMiss { site: "queue" }
            });
            self.metrics.note_terminal(&req, Outcome::DeadlineMissed);
        }
    }

    /// Evict strictly-lower-class in-flight work until `req` fits
    /// within `avail` (or no such victim remains) — priority-aware
    /// admission's preemption half: a higher class may displace a lower
    /// one, never the reverse, and with uniform priorities this is a
    /// no-op. Expired victims are terminated; the rest are shed per the
    /// eviction mode (requeued behind their class head, or parked for
    /// migration). Returns whether anything was shed.
    fn preempt_for(&mut self, req: &SubmitRequest, avail: usize)
                   -> Result<bool> {
        // Only start shedding if the eligible victims' KV can actually
        // cover the shortfall — otherwise the lower-class residents
        // would lose their decode progress and the head would stay
        // blocked anyway.
        let shortfall = (self.bytes_used() + self.admission_cost(req))
            .saturating_sub(avail);
        let reclaimable: usize = self
            .batcher
            .active
            .iter()
            .filter(|s| s.req.priority < req.priority)
            .map(|s| self.resident_kv_bytes(s.req.id))
            .sum();
        if reclaimable < shortfall {
            return Ok(false);
        }
        let mut shed = false;
        while self.bytes_used() + self.admission_cost(req) > avail {
            let Some(i) =
                self.victim_among(|s| s.req.priority < req.priority)
            else {
                break;
            };
            self.flush_batch()?;
            let seq = self.batcher.active.remove(i);
            if self.cfg.enforce_deadlines && seq.req.expired(self.sim_time)
            {
                self.kv.remove(seq.req.id);
                self.drop_checkpoint(seq.req.id);
                self.ledger_remove(&seq.req);
                self.bus.emit(self.sim_time, Some(seq.req.id),
                              Some(&seq.req.tenant), || {
                    EventKind::DeadlineMiss { site: "preempt" }
                });
                self.metrics.note_terminal(&seq.req,
                                           Outcome::DeadlineMissed);
            } else {
                self.bus.emit(self.sim_time, Some(seq.req.id),
                              Some(&seq.req.tenant), || {
                    EventKind::Preempt { for_request: req.id }
                });
                match self.cfg.eviction {
                    EvictionMode::Requeue => {
                        self.kv.remove(seq.req.id);
                        self.drop_checkpoint(seq.req.id);
                        self.metrics.evictions += 1;
                        self.batcher.requeue_front(seq.req);
                    }
                    EvictionMode::Park => {
                        let state = self.export_active(seq)?;
                        self.parked.push(state);
                    }
                }
            }
            shed = true;
        }
        Ok(shed)
    }

    fn try_prefill(&mut self) -> Result<bool> {
        if !self.batcher.wants_prefill() {
            return Ok(false);
        }
        self.drop_expired_queued();
        let avail = (self.monitor.available_at(self.sim_time) as f64
            * self.cfg.admission_headroom) as usize;
        let Some(req) = self.batcher.waiting.front().cloned() else {
            return Ok(false);
        };
        if self.bytes_used() + self.admission_cost(&req) > avail {
            // Head-of-line blocked on memory. A higher class may
            // preempt strictly-lower-class in-flight work to fit (the
            // freed memory admits it next pass); with uniform
            // priorities this path is inert. Preemption only frees
            // victims' KV — never parameters — so it can only help a
            // head that fits alongside the bare model; a doomed head
            // must fall through to the shrink/reject path below, not
            // evict every lower-class resident for nothing.
            if self.mem.param_bytes(&self.mask)
                + self.admission_cost(&req)
                <= avail
                && self.preempt_for(&req, avail)?
            {
                return Ok(false);
            }
            // If the system is idle and even an empty server can't host
            // it under the current mask, consult the outlook: when a
            // min-viable deployment *could* host it, force a controller
            // decision (the mask should shrink, not the queue) and
            // retry next tick; otherwise reject outright.
            if self.batcher.active.is_empty()
                && self.mem.param_bytes(&self.mask)
                    + self.admission_cost(&req) > avail
            {
                if self.cfg.elastic_accounting
                    && self.min_viable_admits(&req, avail)
                {
                    self.run_controller(true)?;
                    // The decision grid targets the raw `Sys_avail`,
                    // so its mask can land inside the
                    // (headroom-scaled, raw] gap and never admit — and
                    // a DQN policy's decision has no fit predicate at
                    // all. Mirror the pressure path: when the decided
                    // mask still cannot admit, deploy the min-viable
                    // mask directly; the next pass then admits by the
                    // `min_viable_admits` check above (no retry
                    // livelock).
                    if self.mem.param_bytes(&self.mask)
                        + self.admission_cost(&req) > avail
                    {
                        self.deploy_min_viable();
                    }
                    return Ok(false);
                }
                let Some(rejected) = self.batcher.waiting.pop_front()
                else {
                    return Ok(false);
                };
                self.drop_checkpoint(rejected.id);
                self.resumable.remove(&rejected.id);
                self.ledger_remove(&rejected);
                self.metrics.rejected += 1;
                self.bus.emit(self.sim_time, Some(rejected.id),
                              Some(&rejected.tenant), || {
                    EventKind::Reject { reason: "admission-no-fit" }
                });
                if self.bus.enabled() {
                    self.bus.flight_dump(
                        self.sim_time,
                        "terminal rejection at admission",
                    );
                }
                self.metrics.note_terminal(&rejected, Outcome::Rejected);
            }
            return Ok(false);
        }
        let Some(req) = self.batcher.pop_for_prefill() else {
            return Ok(false);
        };
        if let Some(SeqState::Active {
            req, generated, next_token, prefill_done_at, kv_len, policy,
            k, v, ..
        }) = self.resumable.remove(&req.id)
        {
            // A restored sequence waited its turn like any admission,
            // but resumes mid-decode: the snapshot's KV attaches in
            // place and no prefill is re-run — its first token was
            // served before the crash, so TTFT keeps the original
            // prefill time.
            self.bus.emit(self.sim_time, Some(req.id),
                          Some(&req.tenant), || EventKind::Resume);
            self.kv.insert(req.id, k, v, kv_len, &self.mask)?;
            self.kv.compress(req.id, policy)?;
            self.batcher.push_active(ActiveSeq {
                req,
                generated,
                next_token,
                prefill_done_at,
            });
            return Ok(true);
        }
        let bucket = prefill_bucket(req.prompt_len);
        // Trace prompts are clamped to the largest bucket.
        let plen = req.prompt_len.min(bucket);
        // Deterministic prompt tokens derived from the request id.
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ req.id);
        let mut tokens = vec![0i32; bucket];
        let vocab = self.rt.meta().vocab;
        for t in tokens.iter_mut().take(plen) {
            *t = rng.below(vocab) as i32;
        }
        #[allow(clippy::disallowed_methods)]
        // lint:allow(wall-clock): PJRT-only fallback — `advance`
        // prefers the runtime's own last_cost; sim runs never read t0
        let t0 = std::time::Instant::now();
        let (logits, k, v) = self.rt.prefill(bucket, &tokens, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.prefills += 1;

        let next_token = argmax(&logits) as i32;
        self.bus.emit(self.sim_time, Some(req.id), Some(&req.tenant),
                      || EventKind::Admit);
        self.kv.insert(req.id, k, v, bucket, &self.mask)?;
        self.batcher.push_active(ActiveSeq {
            req,
            generated: 1,
            next_token,
            prefill_done_at: self.sim_time,
        });
        self.metrics.tokens_generated += 1;
        Ok(true)
    }

    /// Write the persistent batch's caches back to per-seq storage (ids
    /// already retired are skipped — their cache no longer matters).
    fn flush_batch(&mut self) -> Result<()> {
        if let Some(bs) = self.batch.take() {
            self.kv.scatter_cache(&bs.ids, &bs.k, &bs.v, true)?;
        }
        Ok(())
    }

    fn decode_step(&mut self) -> Result<bool> {
        let ids = self.batcher.decode_ids();
        if ids.is_empty() {
            self.flush_batch()?;
            return Ok(false);
        }
        let b = ids.len();
        // Recompose the persistent batch only when membership changes.
        if self.batch.as_ref().map(|s| s.ids.as_slice())
            != Some(ids.as_slice())
        {
            self.flush_batch()?;
            let (k, v) = self.kv.gather(&ids)?;
            self.batch = Some(BatchState { ids: ids.clone(), k, v });
        }
        let pos = self.kv.positions(&ids)?;
        let tokens: Vec<i32> = ids
            .iter()
            // lint:allow(hot-path-panic): decode_ids() lists only
            // live active sequences, so seq_mut is always Some
            .map(|id| self.batcher.seq_mut(*id).unwrap().next_token)
            .collect();
        // lint:allow(hot-path-panic): recomposed two lines up when
        // absent — batch is Some for a non-empty id set
        let bs = self.batch.as_mut().unwrap();
        #[allow(clippy::disallowed_methods)]
        // lint:allow(wall-clock): PJRT-only fallback — `advance`
        // prefers the runtime's own last_cost; sim runs never read t0
        let t0 = std::time::Instant::now();
        let logits = self.rt.decode(b, &tokens, &pos, &mut bs.k,
                                    &mut bs.v, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.decode_steps += 1;
        self.kv.bump_lens(&ids, &self.mask)?;

        let vocab = self.rt.meta().vocab;
        for (bi, id) in ids.iter().enumerate() {
            let tok = argmax(&logits[bi * vocab..(bi + 1) * vocab]) as i32;
            // lint:allow(hot-path-panic): same decode_ids membership
            // as above; retire_finished runs only after this loop
            let seq = self.batcher.seq_mut(*id).unwrap();
            seq.next_token = tok;
            seq.generated += 1;
            self.metrics.tokens_generated += 1;
        }
        let finished = self.batcher.retire_finished();
        if !finished.is_empty() {
            // membership will change; keep survivors' caches coherent
            self.flush_batch()?;
        }
        for seq in finished {
            self.kv.remove(seq.req.id);
            self.drop_checkpoint(seq.req.id);
            self.ledger_remove(&seq.req);
            // A finish after the deadline is still served (the tokens
            // exist) but terminates as DeadlineMissed in the ledger.
            let outcome = if seq.req.deadline_hit(self.sim_time) {
                Outcome::Done
            } else {
                Outcome::DeadlineMissed
            };
            self.bus.emit(self.sim_time, Some(seq.req.id),
                          Some(&seq.req.tenant), || {
                EventKind::Finish { outcome: outcome.name() }
            });
            self.metrics.note_terminal(&seq.req, outcome);
            self.metrics.completed.push(RequestRecord {
                id: seq.req.id,
                tenant: seq.req.tenant.clone(),
                priority: seq.req.priority,
                deadline: seq.req.slo_deadline,
                arrival: seq.req.arrival,
                first_token_at: seq.prefill_done_at,
                finished_at: self.sim_time,
                prompt_len: seq.req.prompt_len,
                gen_len: seq.req.max_new_tokens,
            });
        }
        Ok(true)
    }

    /// Advance the simulated clock to `t`, doing work along the way.
    ///
    /// Invariants: on return `sim_time() >= t` (compute steps may
    /// overshoot the target by at most one step's duration); with no
    /// outstanding work the clock jumps straight to `t`. This is the
    /// primitive an external coordinator drives — many engines stepped
    /// to the same `t` share one coherent fleet clock.
    pub fn step_to(&mut self, t: f64) -> Result<()> {
        self.step_while_busy(t)?;
        if self.sim_time < t {
            self.sim_time = t;
        }
        Ok(())
    }

    /// Like `step_to`, but returns as soon as the engine runs out of
    /// work instead of jumping the clock to `t` — so a driver that only
    /// wants "work until done or `t`" (e.g. `run_requests` with a huge
    /// `max_sim_secs` backstop) keeps a truthful completion time.
    pub fn step_while_busy(&mut self, t: f64) -> Result<()> {
        while self.sim_time < t && !self.idle() {
            self.run_controller(false)?;
            self.maybe_checkpoint()?;
            self.handle_memory_pressure()?;
            self.sample_memory();
            if !self.try_prefill()? && !self.decode_step()? {
                // waiting on memory headroom; let time creep forward
                self.sim_time = (self.sim_time + BLOCKED_TICK).min(t);
            }
        }
        Ok(())
    }

    /// Serve a batch of typed requests to completion (or
    /// `max_sim_secs`): a thin arrival-admission driver over `submit` +
    /// `step_to` — the native front door.
    pub fn run_requests(&mut self, mut requests: Vec<SubmitRequest>)
                        -> Result<ServeReport> {
        // A malformed trace (NaN/∞ arrival) must not panic the sort or
        // wedge the admission loop: such requests are rejected at the
        // boundary, terminally, and everything else is served.
        for req in &requests {
            if !req.has_finite_arrival() {
                self.metrics.note_submitted(req);
                self.metrics.rejected += 1;
                self.metrics.note_terminal(req, Outcome::Rejected);
            }
        }
        requests.retain(|r| r.has_finite_arrival());
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let t_start = self.sim_time;
        let deadline = t_start + self.cfg.max_sim_secs;
        let mut next = 0usize;
        loop {
            // 1. admit arrivals whose time has come
            while next < requests.len()
                && requests[next].arrival <= self.sim_time
            {
                self.submit(requests[next].clone());
                next += 1;
            }
            if self.idle() {
                if next >= requests.len() {
                    break;
                }
                // jump to next arrival
                self.sim_time = requests[next].arrival;
                continue;
            }
            if self.sim_time >= deadline {
                break;
            }
            // 2. work until the next arrival (or the deadline). The
            // non-jumping variant keeps `sim_time` at the true
            // completion moment when the queue drains early — stepping
            // *to* a 1e9 backstop would wreck wall/throughput numbers.
            let target = if next < requests.len() {
                requests[next].arrival.min(deadline)
            } else {
                deadline
            };
            self.step_while_busy(target)?;
        }
        let wall = (self.sim_time - t_start).max(1e-9);
        Ok(self.metrics.report(wall))
    }

    /// Serve a whole workload trace — the legacy front door, now a thin
    /// adapter: a trace is just an iterator of default-tenancy
    /// [`SubmitRequest`]s (`api::from_trace`), so replay and the typed
    /// API share one ingress path.
    pub fn run_trace(&mut self, requests: Vec<crate::workload::Request>)
                     -> Result<ServeReport> {
        self.run_requests(crate::api::from_trace(requests).collect())
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::PriorityClass;
    use crate::model_meta::ModelMeta;
    use crate::server::controller::Policy;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    fn engine_with(capacity_mult: f64, adaptive: bool) -> Engine {
        let meta = ModelMeta::synthetic("e", 4, 128, 8, 4, 512, 512, 256);
        let rt = Runtime::synthetic(meta.clone(), 1);
        let mem = MemoryModel::new(&meta);
        let capacity = (mem.param_bytes(&PruneMask::full(&meta)) as f64
            * capacity_mult) as usize;
        let monitor = MemoryMonitor::constant(capacity);
        let policy = if adaptive {
            Policy::GsiGreedy
        } else {
            Policy::Static(PruneMask::full(&meta))
        };
        let controller = Controller::new(policy, mem, vec![0; 128], 128)
            .with_calib_bucket(1, 128);
        Engine::new(rt, monitor, controller, EngineConfig::default())
    }

    fn sim_engine(capacity_mult: f64) -> Engine {
        engine_with(capacity_mult, false)
    }

    fn req(id: u64, arrival: f64) -> SubmitRequest {
        SubmitRequest::new(12, 6).with_id(id).with_arrival(arrival)
    }

    fn long_req(id: u64, prompt: usize, gen: usize) -> SubmitRequest {
        SubmitRequest::new(prompt, gen).with_id(id)
    }

    #[test]
    fn step_to_jumps_when_idle() {
        let mut e = sim_engine(4.0);
        e.step_to(17.5).unwrap();
        assert_eq!(e.sim_time(), 17.5);
    }

    #[test]
    fn externally_stepped_engine_serves_requests() {
        let mut e = sim_engine(4.0);
        for i in 0..5 {
            e.submit(req(i, 0.0));
        }
        assert_eq!(e.outstanding(), 5);
        // step in small external increments, like a fleet would
        let mut t = 0.0;
        while !e.idle() && t < 300.0 {
            t += 0.5;
            e.step_to(t).unwrap();
            assert!(e.sim_time() >= t - 1e-9 || e.idle());
        }
        assert!(e.idle(), "work left after 300s");
        assert_eq!(e.metrics.completed.len(), 5);
        assert_eq!(e.metrics.oom_events, 0);
        // clock advanced by modeled compute, not wall time
        assert!(e.metrics.exec_secs > 0.0);
    }

    #[test]
    fn run_trace_matches_external_stepping() {
        use crate::workload::Request;

        let trace: Vec<Request> = (0..8)
            .map(|i| Request { id: i, arrival: i as f64 * 0.4,
                               prompt_len: 12, gen_len: 6 })
            .collect();
        let mut a = sim_engine(4.0);
        let ra = a.run_trace(trace.clone()).unwrap();
        let mut b = sim_engine(4.0);
        let mut next = 0usize;
        let mut t = 0.0;
        while next < trace.len() || !b.idle() {
            while next < trace.len() && trace[next].arrival <= t {
                b.submit(SubmitRequest::from_trace(&trace[next]));
                next += 1;
            }
            t += 0.2;
            b.step_to(t).unwrap();
            assert!(t < 1000.0, "diverged");
        }
        assert_eq!(ra.completed, 8);
        assert_eq!(b.metrics.completed.len(), 8);
        // same requests, same backend seed → same token counts
        assert_eq!(ra.tokens_generated, b.metrics.tokens_generated);
        // regression: the huge max_sim_secs backstop must not leak into
        // the clock or the report when the queue drains early
        assert!(a.sim_time() < 1e4, "clock jumped to the deadline");
        assert!(ra.throughput_rps > 1e-3,
                "wall time corrupted: {} req/s", ra.throughput_rps);
        // the trace adapter is the one ingress: every request got a
        // terminal outcome and landed in the default tenant's ledger
        assert_eq!(ra.tenants.len(), 1);
        assert_eq!(ra.tenants[0].tenant, crate::api::DEFAULT_TENANT);
        assert_eq!(ra.tenants[0].counts.submitted, 8);
        assert_eq!(ra.tenants[0].counts.finished, 8);
        for i in 0..8 {
            assert_eq!(a.metrics.outcome(i), Some(Outcome::Done));
        }
    }

    /// Step in tiny increments so at most one compute op runs per call
    /// (every op costs ≥ the sim backend's base overhead of 2e-4 s).
    fn step_until_tokens(e: &mut Engine, want: u64) {
        let mut t = e.sim_time();
        while e.metrics.tokens_generated < want {
            t += 1e-4;
            e.step_to(t).unwrap();
            assert!(t < 60.0, "never generated {want} tokens");
        }
    }

    #[test]
    fn export_import_roundtrip_queued() {
        let mut a = sim_engine(4.0);
        a.submit(req(7, 0.0));
        let st = a.export_sequence(7).unwrap().unwrap();
        assert!(matches!(st, SeqState::Queued(_)));
        assert_eq!(st.id(), 7);
        assert!(a.idle(), "export left state behind");
        assert!(a.export_sequence(7).unwrap().is_none());

        let mut b = sim_engine(4.0);
        b.import_sequence(st).unwrap();
        b.step_to(120.0).unwrap();
        assert_eq!(b.metrics.completed.len(), 1);
        assert_eq!(b.metrics.completed[0].id, 7);
    }

    #[test]
    fn export_import_roundtrip_mid_decode() {
        // control: the same request served by one engine end to end
        let mut control = sim_engine(4.0);
        control.submit(req(3, 0.0));
        control.step_to(120.0).unwrap();
        assert_eq!(control.metrics.completed.len(), 1);
        let total = control.metrics.tokens_generated;
        assert_eq!(total, 6, "max_new_tokens tokens in total");

        // serve the prefill + two decode steps, then export mid-decode
        let mut a = sim_engine(4.0);
        a.submit(req(3, 0.0));
        step_until_tokens(&mut a, 3);
        let st = a.export_sequence(3).unwrap().unwrap();
        let SeqState::Active { generated, kv_len, .. } = &st else {
            panic!("expected a mid-decode export");
        };
        assert_eq!(*generated, 3);
        // prefill bucket (16 for a 12-token prompt) + 2 decode writes
        assert_eq!(*kv_len, 18);
        assert!(st.transfer_bytes() > 0);
        // migration compression: the charged payload is the live
        // 12 + 2 = 14 rows, strictly less than the padded 18
        assert!(st.transfer_bytes() < st.padded_transfer_bytes(),
                "live {} vs padded {}", st.transfer_bytes(),
                st.padded_transfer_bytes());
        assert!(a.idle(), "export left state behind");

        // identical continuation on two fresh engines
        let mut b1 = sim_engine(4.0);
        let mut b2 = sim_engine(4.0);
        b1.import_sequence(st.clone()).unwrap();
        b2.import_sequence(st).unwrap();
        b1.step_to(120.0).unwrap();
        b2.step_to(120.0).unwrap();
        for e in [&b1, &b2] {
            assert_eq!(e.metrics.completed.len(), 1);
            assert_eq!(e.metrics.completed[0].id, 3);
        }
        assert_eq!(b1.metrics.tokens_generated,
                   b2.metrics.tokens_generated);
        assert_eq!(b1.metrics.exec_secs, b2.metrics.exec_secs);
        // no token generated twice or lost across the move
        assert_eq!(a.metrics.tokens_generated
                   + b1.metrics.tokens_generated, total);
    }

    /// Tentpole: a periodically-checkpointed engine survives a crash
    /// losing only the tokens decoded since the snapshot — the restored
    /// copy finishes exactly once on a peer.
    #[test]
    fn checkpoint_then_crash_restores_on_peer() {
        let mut a = sim_engine(4.0);
        a.cfg.checkpoint_period_secs = Some(1e-6); // every step
        a.batcher.max_active = 1; // keep the second request queued
        a.submit(req(3, 0.0));
        a.submit(req(4, 0.0));
        step_until_tokens(&mut a, 4);
        assert!(a.checkpoint_len() >= 1);
        assert!(a.metrics.checkpoints_taken >= 1);
        assert!(a.metrics.checkpoint_bytes > 0);

        let (ckpts, lost, queued) = a.crash_dump();
        assert_eq!(ckpts.len(), 1);
        assert!(lost.is_empty());
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].id, 4);
        assert!(a.idle() && a.checkpoint_len() == 0);

        // the snapshot trails the live sequence by ≥ 0 tokens
        let SeqState::Active { generated, .. } = &ckpts[0] else {
            panic!("checkpoint of a mid-decode seq must be Active");
        };
        assert!(*generated >= 1 && *generated <= 4);

        let mut b = sim_engine(4.0);
        b.import_sequence(ckpts.into_iter().next().unwrap()).unwrap();
        b.step_to(120.0).unwrap();
        assert_eq!(b.metrics.completed.len(), 1);
        assert_eq!(b.metrics.completed[0].id, 3);
        // exactly once: the crashed engine never completed it
        assert_eq!(a.metrics.completed.len(), 0);
    }

    /// Without checkpoints a crash destroys decode progress: the
    /// request comes back as a bare re-admission ticket, never a
    /// silently-dropped id.
    #[test]
    fn crash_dump_without_checkpoints_loses_progress() {
        let mut a = sim_engine(4.0);
        a.submit(req(3, 0.0));
        step_until_tokens(&mut a, 3);
        let (ckpts, lost, queued) = a.crash_dump();
        assert!(ckpts.is_empty());
        assert_eq!(lost.len(), 1);
        assert_eq!(lost[0].id, 3);
        assert!(queued.is_empty());
        assert!(a.idle());
        // the lifecycle survives: resubmitting serves it from scratch
        let mut b = sim_engine(4.0);
        b.submit(lost.into_iter().next().unwrap().with_arrival(0.0));
        b.step_to(120.0).unwrap();
        assert_eq!(b.metrics.completed.len(), 1);
    }

    /// Satellite: non-finite arrivals are rejected at the boundary
    /// (terminal `Rejected`), not panicked on in the arrival sort.
    #[test]
    fn non_finite_arrivals_are_rejected_not_panicked() {
        let mut e = sim_engine(4.0);
        let reqs = vec![req(1, 0.0),
                        req(2, f64::NAN),
                        req(3, f64::INFINITY),
                        req(4, 0.5)];
        let report = e.run_requests(reqs).unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.rejected, 2);
        assert_eq!(e.metrics.outcome(2), Some(Outcome::Rejected));
        assert_eq!(e.metrics.outcome(3), Some(Outcome::Rejected));
        assert_eq!(e.metrics.outcome(1), Some(Outcome::Done));
    }

    #[test]
    fn import_rejects_live_duplicates() {
        let mut e = sim_engine(4.0);
        e.import_sequence(SeqState::Queued(req(9, 0.0))).unwrap();
        assert!(e.import_sequence(SeqState::Queued(req(9, 0.0))).is_err());
        assert_eq!(e.outstanding(), 1);
    }

    #[test]
    fn park_mode_parks_instead_of_requeueing() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(4.0);
        e.cfg.eviction = EvictionMode::Park;
        e.submit(req(1, 0.0));
        step_until_tokens(&mut e, 2);
        // yank the headroom out: capacity == params, so any KV is over
        let cap = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(cap);
        let t = e.sim_time() + 0.5;
        e.step_to(t).unwrap();
        assert_eq!(e.parked_len(), 1);
        assert_eq!(e.metrics.evictions, 0, "park must not requeue");
        assert!(e.metrics.oom_events >= 1);
        let parked = e.take_parked();
        assert!(matches!(parked[0], SeqState::Active { .. }));
        assert_eq!(e.parked_len(), 0);
        assert!(e.idle());
    }

    #[test]
    fn requeue_mode_counts_evictions() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(4.0);
        e.submit(req(1, 0.0));
        step_until_tokens(&mut e, 2);
        let cap = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(cap);
        let t = e.sim_time() + 0.5;
        e.step_to(t).unwrap();
        assert!(e.metrics.evictions >= 1);
        assert_eq!(e.parked_len(), 0);
    }

    /// Regression (ISSUE 4): `Requeue` must pick victims exactly like
    /// `Park` — by KV bytes × remaining decode — so pressure is
    /// relieved with the fewest evictions. The old youngest-first pop
    /// would evict the small sequence here, find memory still over,
    /// and evict the big one too: two evictions where one suffices.
    #[test]
    fn requeue_frees_memory_with_fewest_evictions() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(8.0);
        // A: long prompt (128-token bucket), B: short (16-token bucket)
        e.submit(long_req(1, 100, 30));
        e.submit(long_req(2, 12, 30));
        step_until_tokens(&mut e, 4); // both prefilled + one decode step
        let len_a = e.kv.seq_len(1).unwrap();
        let len_b = e.kv.seq_len(2).unwrap();
        assert!(len_a > len_b, "{len_a} vs {len_b}");
        // Pressure sized so evicting A alone relieves it (params +
        // B's KV fits, with slack for B to decode to completion), but
        // evicting B alone would not (params + A's KV stays over).
        let params = e.mem.param_bytes(&e.mask);
        let avail = params + e.kv_bytes_for_len(len_b + 40);
        assert!(avail < params + e.kv_bytes_for_len(len_a));
        e.monitor = MemoryMonitor::constant(avail);
        // a tiny step: pressure handling plus at most one compute op,
        // so the post-eviction state is still observable
        e.step_to(e.sim_time() + 1e-4).unwrap();
        assert_eq!(e.metrics.evictions, 1,
                   "victim selection should free memory in one eviction");
        assert!(e.metrics.oom_events >= 1);
        // the big sequence was the victim; the small one kept serving
        assert_eq!(e.batcher.waiting.front().unwrap().id, 1);
        assert!(e.batcher.active.iter().any(|s| s.req.id == 2));
        // and the survivor runs to completion without further shedding
        e.step_to(e.sim_time() + 0.5).unwrap();
        assert!(e.metrics.completed.iter().any(|r| r.id == 2));
        assert_eq!(e.metrics.evictions, 1);
    }

    /// The tentpole at engine level: a spike inside the absorbable band
    /// (`min_viable <= Sys_avail < current`) shrinks the mask — no OOM,
    /// no eviction, no parked victim — and is charged to
    /// `absorbed_spikes`. With `elastic_accounting` off, the identical
    /// spike is booked as an OOM (the legacy behavior).
    #[test]
    fn absorbable_spike_shrinks_mask_instead_of_oom() {
        use crate::server::memmon::MemoryMonitor;

        for elastic in [true, false] {
            let mut e = engine_with(4.0, true);
            e.cfg.elastic_accounting = elastic;
            e.submit(req(1, 0.0));
            step_until_tokens(&mut e, 2);
            assert_eq!(e.metrics.oom_events, 0);
            let params =
                e.mem.param_bytes(&PruneMask::full(e.rt.meta()));
            // into the absorbable band: below the dense parameter
            // footprint, far above the min-viable one (~0.3×)
            e.monitor = MemoryMonitor::constant(
                (params as f64 * 0.72) as usize);
            let t = e.sim_time() + 0.5;
            e.step_to(t).unwrap();
            if elastic {
                assert_eq!(e.metrics.oom_events, 0,
                           "absorbable spike was booked as an OOM");
                assert!(e.metrics.absorbed_spikes >= 1);
                assert_eq!(e.metrics.evictions, 0);
                assert_eq!(e.parked_len(), 0);
                assert!(e.mask.param_fraction(e.rt.meta()) < 1.0,
                        "the mask never shrank");
                assert!(e.bytes_used()
                        <= e.monitor.available_at(e.sim_time()));
                // and the sequence still completes under the shrunken
                // mask
                e.step_to(t + 300.0).unwrap();
                assert_eq!(e.metrics.completed.len(), 1);
            } else {
                assert!(e.metrics.oom_events >= 1,
                        "legacy accounting must book the spike");
                assert_eq!(e.metrics.absorbed_spikes, 0);
            }
        }
    }

    /// Review-fix regression: an empty adaptive engine whose
    /// `Sys_avail` lands in the gap between the controller's decided
    /// mask (which targets the *raw* avail) and the admission check
    /// (scaled by `admission_headroom`) must deploy the min-viable
    /// mask and serve the head-of-line request — never spin forever
    /// neither admitting nor rejecting it.
    #[test]
    fn admission_gap_deploys_min_viable_instead_of_starving() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = engine_with(4.0, true);
        let params = e.mem.param_bytes(&PruneMask::full(e.rt.meta()));
        // 0.60× dense params sits inside such a gap window for this
        // seed (verified against the outlook_port.py scan): pre-fix
        // the request was neither admitted nor rejected
        e.monitor =
            MemoryMonitor::constant((params as f64 * 0.60) as usize);
        e.submit(req(1, 0.0));
        e.step_to(300.0).unwrap();
        assert_eq!(e.metrics.completed.len(), 1,
                   "request starved in the admission gap");
        assert_eq!(e.metrics.rejected, 0);
    }

    /// The outlook lattice from a live engine: rigid for static masks,
    /// `min_viable < current <= dense` once an adaptive controller has
    /// run.
    #[test]
    fn outlook_reports_the_mask_lattice() {
        let mut s = sim_engine(4.0);
        s.submit(req(1, 0.0));
        step_until_tokens(&mut s, 2);
        let o = s.outlook();
        assert_eq!(o.min_viable, o.current, "static mask cannot shrink");

        let mut a = engine_with(4.0, true);
        a.submit(req(1, 0.0));
        step_until_tokens(&mut a, 2);
        let o = a.outlook();
        assert!(o.min_viable < o.current,
                "adaptive outlook has slack: {o:?}");
        assert!(o.current <= o.dense);
        assert_eq!(o.current, a.bytes_used());
    }

    #[test]
    fn sim_backend_drives_virtual_time() {
        let mut e = sim_engine(4.0);
        e.submit(req(0, 0.0));
        #[allow(clippy::disallowed_methods)]
        let wall = std::time::Instant::now();
        e.step_to(1000.0).unwrap();
        // a single request's modeled compute is far below 1000 virtual
        // seconds, yet wall time must be tiny: virtual ≫ wall
        assert!(e.sim_time() >= 1000.0);
        assert!(wall.elapsed().as_secs_f64() < 30.0);
        assert_eq!(e.metrics.completed.len(), 1);
    }

    // ---- request-API lifecycle (ISSUE 5) ------------------------------

    #[test]
    fn submit_poll_cancel_lifecycle_queued() {
        let mut e = sim_engine(4.0);
        let h = e.submit(req(1, 0.0));
        assert_eq!(e.status(h.id), Some(RequestStatus::Queued));
        assert!(e.cancel(h.id).unwrap());
        assert_eq!(e.status(h.id),
                   Some(RequestStatus::Finished(Outcome::Cancelled)));
        assert!(e.idle());
        assert_eq!(e.kv.len(), 0);
        assert_eq!(e.metrics.cancelled, 1);
        // already terminal: nothing left to cancel
        assert!(!e.cancel(h.id).unwrap());
        // a later step must not resurrect it
        e.step_to(10.0).unwrap();
        assert_eq!(e.metrics.completed.len(), 0);
        assert_eq!(e.status(99), None);
    }

    #[test]
    fn cancel_mid_decode_frees_kv() {
        let mut e = sim_engine(4.0);
        let h = e.submit(req(2, 0.0));
        step_until_tokens(&mut e, 2);
        assert_eq!(e.status(h.id), Some(RequestStatus::Running));
        assert!(e.cancel(h.id).unwrap());
        assert!(e.idle());
        // the sequence's cache is gone: the footprint collapses back to
        // the bare model
        assert_eq!(e.kv.len(), 0);
        assert_eq!(e.bytes_used(), e.mem.param_bytes(&e.mask));
        assert_eq!(e.metrics.outcome(2), Some(Outcome::Cancelled));
        e.step_to(e.sim_time() + 1.0).unwrap();
        assert_eq!(e.metrics.completed.len(), 0);
    }

    #[test]
    fn deadline_outcomes_are_booked() {
        // an impossible deadline: served, but terminal DeadlineMissed
        let mut e = sim_engine(4.0);
        e.submit(req(1, 0.0).with_deadline(1e-6));
        e.step_to(120.0).unwrap();
        assert_eq!(e.metrics.completed.len(), 1);
        assert_eq!(e.metrics.outcome(1),
                   Some(Outcome::DeadlineMissed));
        assert_eq!(e.metrics.deadline_missed, 1);
        // a comfortable deadline is a hit
        let mut e = sim_engine(4.0);
        e.submit(req(2, 0.0).with_deadline(1e6));
        e.step_to(120.0).unwrap();
        assert_eq!(e.metrics.outcome(2), Some(Outcome::Done));
        let rep = e.metrics.report(1.0);
        assert_eq!(rep.tenants.len(), 1);
        assert_eq!(rep.tenants[0].counts.deadline_hits, 1);
        assert_eq!(rep.tenants[0].counts.deadline_total, 1);
    }

    /// Victim order under pressure: expired deadlines go first (and are
    /// terminated, not requeued), and lower classes go before higher
    /// ones.
    #[test]
    fn pressure_victims_prefer_expired_then_lowest_class() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(8.0);
        e.submit(long_req(1, 12, 30)
                     .with_priority(PriorityClass::Interactive));
        e.submit(long_req(2, 12, 30)
                     .with_priority(PriorityClass::Batch));
        e.submit(long_req(3, 12, 30)
                     .with_priority(PriorityClass::Interactive));
        step_until_tokens(&mut e, 5);
        assert_eq!(e.batcher.active.len(), 3);
        // mark 3 as already past its deadline (post-hoc, so queue-purge
        // timing can't interfere with the scenario)
        e.batcher.seq_mut(3).unwrap().req.slo_deadline =
            Some(e.sim_time() - 1.0);
        // wall: capacity == params → every sequence must be shed
        let cap = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(cap);
        e.step_to(e.sim_time() + 1e-4).unwrap();
        // the expired Interactive was terminated (no eviction charged);
        // the Batch and the live Interactive were requeued
        assert_eq!(e.metrics.outcome(3),
                   Some(Outcome::DeadlineMissed));
        assert_eq!(e.metrics.evictions, 2);
        assert!(e.batcher.active.is_empty());
        assert!(!e.batcher.waiting.iter().any(|r| r.id == 3),
                "expired victim must not be requeued");
    }

    /// Priority-aware admission: a memory-blocked Interactive head may
    /// preempt a resident Batch sequence; a Batch head must never
    /// displace a resident Interactive one.
    #[test]
    fn admission_preempts_only_lower_classes() {
        use crate::server::memmon::MemoryMonitor;

        // case 1: Interactive arrives, Batch resident → preempted
        let mut e = sim_engine(8.0);
        e.submit(long_req(1, 12, 30)
                     .with_priority(PriorityClass::Batch));
        step_until_tokens(&mut e, 2);
        let params = e.mem.param_bytes(&e.mask);
        let incoming = long_req(2, 12, 30)
            .with_priority(PriorityClass::Interactive);
        let need = e.admission_cost(&incoming);
        // capacity: hosts the incoming request on an otherwise-empty
        // server, but not alongside the resident sequence
        let cap = ((params + need) as f64 / 0.95) as usize + 16;
        let used = e.bytes_used();
        assert!(used + need > (cap as f64 * 0.95) as usize,
                "scenario must start memory-blocked");
        e.monitor = MemoryMonitor::constant(cap);
        e.submit(incoming);
        e.step_to(e.sim_time() + 2.0).unwrap();
        assert!(e.metrics.evictions >= 1,
                "the Batch resident was never preempted");
        assert!(e.metrics.completed.iter().any(|r| r.id == 2),
                "the Interactive request never got through");

        // case 2: the mirror image — Batch arrives, Interactive
        // resident → no preemption, ever
        let mut e = sim_engine(8.0);
        e.submit(long_req(1, 12, 30)
                     .with_priority(PriorityClass::Interactive));
        step_until_tokens(&mut e, 2);
        let params = e.mem.param_bytes(&e.mask);
        let incoming =
            long_req(2, 12, 30).with_priority(PriorityClass::Batch);
        let need = e.admission_cost(&incoming);
        let cap = ((params + need) as f64 / 0.95) as usize + 16;
        e.monitor = MemoryMonitor::constant(cap);
        e.submit(incoming);
        e.step_to(e.sim_time() + 300.0).unwrap();
        assert_eq!(e.metrics.evictions, 0,
                   "a Batch head displaced an Interactive resident");
        // both still finish — the Batch one simply waits its turn
        assert_eq!(e.metrics.completed.len(), 2);
        let pos = |id: u64| {
            e.metrics.completed.iter().position(|r| r.id == id).unwrap()
        };
        assert!(pos(1) < pos(2), "the Interactive resident finished \
                                  first");
    }

    /// Queued requests whose deadline already passed are purged as
    /// DeadlineMissed without burning a prefill.
    #[test]
    fn expired_queued_requests_are_purged() {
        let mut e = sim_engine(4.0);
        // a long-running resident keeps the engine busy past t = 2
        e.submit(long_req(1, 12, 40));
        // this one's deadline expires while it waits behind nothing —
        // give it an arrival-time deadline already in the past once the
        // clock moves: deadline 0 can never be hit after t > 0
        step_until_tokens(&mut e, 2);
        let dead = long_req(9, 12, 4).with_deadline(
            e.sim_time() - 1e-9);
        e.submit(dead);
        e.step_to(e.sim_time() + 60.0).unwrap();
        assert_eq!(e.metrics.outcome(9),
                   Some(Outcome::DeadlineMissed));
        assert_eq!(e.metrics.prefills, 1,
                   "the expired request burned a prefill");
        assert!(e.metrics.completed.iter().all(|r| r.id != 9));

        // measure-only mode (the legacy front door): the same expired
        // request is served to completion and merely *booked* as missed
        let mut e = sim_engine(4.0);
        e.cfg.enforce_deadlines = false;
        e.submit(long_req(1, 12, 40));
        step_until_tokens(&mut e, 2);
        let dead = long_req(9, 12, 4).with_deadline(
            e.sim_time() - 1e-9);
        e.submit(dead);
        e.step_to(e.sim_time() + 60.0).unwrap();
        assert_eq!(e.metrics.outcome(9),
                   Some(Outcome::DeadlineMissed));
        assert_eq!(e.metrics.prefills, 2, "measure-only must serve it");
        assert!(e.metrics.completed.iter().any(|r| r.id == 9));
    }

    // ---- joint (mask × KV policy) elasticity (PR-9) -------------------

    /// PR-9 tentpole at engine level: a spike the mask alone cannot
    /// absorb (static mask — zero mask slack) is absorbed by
    /// compressing resident KV down to the controller's floor policy —
    /// no OOM, no eviction — and booked to `compressed_spikes`. With
    /// the KV axis off, the identical spike sheds work.
    #[test]
    fn pressure_compresses_kv_before_shedding_work() {
        use crate::server::controller::default_kv_floor;
        use crate::server::memmon::MemoryMonitor;

        let floor_cap = default_kv_floor().token_cap(); // sink + recent
        for kv_elastic in [true, false] {
            let mut e = sim_engine(8.0);
            e.cfg.kv_elastic = kv_elastic;
            e.submit(long_req(1, 100, 40)); // 128-token prefill bucket
            step_until_tokens(&mut e, 3);
            let len = e.kv.seq_len(1).unwrap();
            assert!(len > floor_cap, "scenario needs compressible KV");
            // avail between the joint floor and the current footprint:
            // only the KV axis can absorb (the static mask cannot move)
            let params = e.mem.param_bytes(&e.mask);
            let avail = params + e.kv_bytes_for_len(floor_cap + 8);
            assert!(e.bytes_used() > avail);
            e.monitor = MemoryMonitor::constant(avail);
            e.step_to(e.sim_time() + 1e-4).unwrap();
            if kv_elastic {
                assert_eq!(e.metrics.oom_events, 0,
                           "KV-absorbable spike booked as an OOM");
                assert!(e.metrics.absorbed_spikes >= 1);
                assert_eq!(e.metrics.compressed_spikes, 1);
                assert!(e.metrics.kv_bytes_reclaimed > 0);
                assert_eq!(e.metrics.evictions, 0);
                assert_eq!(e.parked_len(), 0);
                assert_eq!(e.kv.seq_len(1), Some(floor_cap));
                assert_eq!(e.kv.policy_of(1), Some(default_kv_floor()));
                assert!(e.bytes_used() <= avail);
                e.kv.audit().unwrap();
                // the sequence still completes on its compressed cache
                e.step_to(e.sim_time() + 60.0).unwrap();
                assert!(e.metrics.completed.iter().any(|r| r.id == 1));
            } else {
                assert!(e.metrics.oom_events >= 1,
                        "mask-only accounting must shed");
                assert_eq!(e.metrics.compressed_spikes, 0);
                assert!(e.metrics.evictions >= 1);
            }
        }
    }

    /// Below the *joint* floor even compression cannot help: the spike
    /// is a true OOM and sheds work (and the compress step is never
    /// charged — true OOMs bypass the absorption path).
    #[test]
    fn pressure_below_the_joint_floor_is_a_true_oom() {
        use crate::server::controller::default_kv_floor;
        use crate::server::memmon::MemoryMonitor;

        let floor_cap = default_kv_floor().token_cap();
        let mut e = sim_engine(8.0);
        e.submit(long_req(1, 100, 40));
        step_until_tokens(&mut e, 3);
        let params = e.mem.param_bytes(&e.mask);
        let avail = params + e.kv_bytes_for_len(floor_cap / 2);
        e.monitor = MemoryMonitor::constant(avail);
        e.step_to(e.sim_time() + 1e-4).unwrap();
        assert!(e.metrics.oom_events >= 1);
        assert!(e.metrics.evictions >= 1);
        assert_eq!(e.metrics.absorbed_spikes, 0);
        assert_eq!(e.metrics.compressed_spikes, 0);
    }

    /// Satellite (a): a compressed sequence exports / checkpoints its
    /// *post-compression* slice — the migration payload shrinks with
    /// the cache, and the next checkpoint cycle re-snapshots the
    /// smaller state at zero delta cost instead of keeping the stale
    /// fat snapshot alive.
    #[test]
    fn compression_reprices_transfer_and_checkpoint_bytes() {
        use crate::server::controller::default_kv_floor;
        use crate::server::memmon::MemoryMonitor;

        let floor_cap = default_kv_floor().token_cap();
        let mut e = sim_engine(8.0);
        e.cfg.checkpoint_period_secs = Some(1.0);
        e.submit(long_req(1, 100, 40));
        step_until_tokens(&mut e, 3);
        // drive the checkpoint cycles by hand (same-module test): the
        // serving loop would interleave decode writes and muddy the
        // delta assertion
        e.flush_batch().unwrap();
        e.sim_time += 10.0;
        e.maybe_checkpoint().unwrap();
        let fat = e.checkpoints.get(&1).unwrap().transfer_bytes();
        let ckpt_bytes_before = e.metrics.checkpoint_bytes;
        let params = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(
            params + e.kv_bytes_for_len(floor_cap + 8));
        e.handle_memory_pressure().unwrap();
        assert_eq!(e.metrics.compressed_spikes, 1);
        assert_eq!(e.metrics.evictions, 0);
        // the next cycle re-snapshots the shrunken slice — replacing
        // the stale fat snapshot — at zero delta cost (shrinks are
        // free; only growth charges the stream)
        e.sim_time += 10.0;
        e.maybe_checkpoint().unwrap();
        let slim = e.checkpoints.get(&1).unwrap().transfer_bytes();
        assert!(slim < fat, "stale snapshot survived: {slim} vs {fat}");
        assert_eq!(e.metrics.checkpoint_bytes, ckpt_bytes_before);
        // an export ships the same compressed slice, and a peer
        // restores it into the right accounting class
        let st = e.export_sequence(1).unwrap().unwrap();
        let SeqState::Active { kv_len, policy, .. } = &st else {
            panic!("expected a mid-decode export");
        };
        assert_eq!(*kv_len, floor_cap);
        assert_eq!(*policy, default_kv_floor());
        assert_eq!(st.transfer_bytes(), slim);
        let mut b = sim_engine(8.0);
        b.import_sequence(st).unwrap();
        assert_eq!(b.kv.seq_len(1), Some(floor_cap));
        assert_eq!(b.kv.policy_of(1), Some(default_kv_floor()));
        b.kv.audit().unwrap();
        b.step_to(120.0).unwrap();
        assert_eq!(b.metrics.completed.len(), 1);
        assert_eq!(b.metrics.completed[0].id, 1);
    }

    /// Placement pricing reads the joint lattice: with the KV axis on,
    /// `elastic_admission_cost` prices a long request at the floor
    /// policy's capped tokens — strictly cheaper than the mask-only
    /// elastic price, which is itself no dearer than the current-mask
    /// price.
    #[test]
    fn elastic_admission_cost_prices_the_kv_floor() {
        let mut e = engine_with(4.0, true);
        e.submit(req(1, 0.0));
        step_until_tokens(&mut e, 2); // controller ran: floor mask cached
        let big = long_req(9, 200, 56); // clamps to max_seq = 256
        let joint = e.elastic_admission_cost(&big);
        e.cfg.kv_elastic = false;
        let mask_only = e.elastic_admission_cost(&big);
        assert!(joint < mask_only, "{joint} vs {mask_only}");
        assert!(mask_only <= e.admission_cost(&big));
        // the outlook exposes the same split: kv_slack > 0 only with
        // the KV axis on (the resident cache is tiny, so compression
        // frees nothing here — use a long resident instead)
        e.cfg.kv_elastic = true;
        let mut long = sim_engine(8.0);
        long.submit(long_req(2, 100, 40));
        step_until_tokens(&mut long, 3);
        let o = long.outlook();
        assert!(o.kv_slack > 0, "long resident must have KV slack");
        assert!(o.min_viable + o.kv_slack <= o.current);
        long.cfg.kv_elastic = false;
        assert_eq!(long.outlook().kv_slack, 0);
    }
}

//! The serving engine: a continuous-batching event loop over the model
//! runtime, with RAP's controller in the loop.
//!
//! Time model: the engine advances a *simulated* clock fed by the trace's
//! arrival times; compute steps advance the clock by their duration —
//! measured wall-clock on the PJRT backend, the modeled cost on the sim
//! backend (`Runtime::last_cost`) — times `time_scale`. This lets a
//! 10-minute "day" of traffic replay in however long the actual math
//! takes while keeping latency accounting coherent.
//!
//! Stepping model: the engine no longer owns its run loop. The primitive
//! is [`Engine::step_to`], which advances the clock to a target time
//! doing work along the way; [`Engine::run_trace`] is a thin driver over
//! `enqueue` + `step_to`, and the fleet coordinator drives many engines
//! against one shared clock the same way.
//!
//! Per unit of work:
//!   1. controller: observe (active workload, Sys_avail(t)) and re-decide
//!      the mask when the situation changed (cached decisions make this
//!      the paper's "<1% overhead" path);
//!   2. pressure handling: if interference spiked over our *current*
//!      footprint, consult the [`MemoryOutlook`] — when even the
//!      min-viable mask fits `Sys_avail(t)` the spike is absorbable:
//!      shrink the mask, shed nothing, charge `absorbed_spikes`. Only
//!      when `Sys_avail(t)` dips below the min-viable footprint is an
//!      OOM counted and work shed per [`EvictionMode`] (both modes pick
//!      victims by KV bytes × remaining decode — the shed that frees
//!      the most memory per eviction). With
//!      `EngineConfig::elastic_accounting` off, any pressure under the
//!      current mask counts as an OOM (the pre-outlook behavior, kept
//!      for comparison runs);
//!   3. run one prefill (if queue room + memory headroom) or one decode
//!      step over the gathered batch; sample tokens; retire finished.

use anyhow::{bail, Result};

use super::batcher::{decode_bucket, prefill_bucket, ActiveSeq, Batcher};
use super::controller::Controller;
use super::kv::KvManager;
use super::memmon::MemoryMonitor;
use super::metrics::{MemSample, Metrics, RequestRecord, ServeReport};
use super::outlook::MemoryOutlook;
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::runtime::Runtime;
use crate::workload::Request;

/// How the engine sheds in-flight work when interference pushes its
/// footprint over `Sys_avail(t)`.
/// Both modes pick victims the same way — by KV bytes × remaining
/// decode, the sequence whose removal frees the most memory for the
/// longest remaining run (`Engine::pressure_victim`) — so a requeueing
/// engine sheds with the fewest evictions, exactly like a parking one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionMode {
    /// Evict the victim and requeue it locally — it restarts from its
    /// prompt (the single-node policy).
    #[default]
    Requeue,
    /// Export the victim's full state (KV included) into the parked
    /// stash for an external coordinator to migrate to a peer replica.
    /// Only meaningful when something drains the stash
    /// (`take_parked`): a standalone engine should use `Requeue`.
    Park,
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Simulated seconds per real compute second.
    pub time_scale: f64,
    /// Memory-trace sampling period (sim seconds).
    pub sample_every: f64,
    /// Re-run the controller at most this often (sim seconds).
    pub controller_period: f64,
    /// Safety factor on admission (fraction of available memory usable).
    pub admission_headroom: f64,
    /// Hard stop (sim seconds) even if work remains.
    pub max_sim_secs: f64,
    /// What to do with in-flight sequences under memory pressure.
    pub eviction: EvictionMode,
    /// Mask-elastic memory accounting: judge pressure against the
    /// [`MemoryOutlook`]'s `min_viable` footprint instead of the
    /// current-mask footprint. On (the default), a spike the controller
    /// can absorb by shrinking sheds no work and counts no OOM; off
    /// reproduces the pre-outlook behavior (every current-mask
    /// transgression is an OOM) for comparison runs.
    pub elastic_accounting: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { time_scale: 1.0, sample_every: 2.0,
                       controller_period: 5.0, admission_headroom: 0.95,
                       max_sim_secs: 1e9,
                       eviction: EvictionMode::Requeue,
                       elastic_accounting: true }
    }
}

/// Exported state of one sequence — everything a peer engine needs to
/// continue serving it. Produced by [`Engine::export_sequence`] and the
/// `Park` eviction mode; consumed by [`Engine::import_sequence`]. The
/// fleet coordinator moves these across replicas (charging the modeled
/// transfer cost for the payload).
#[derive(Clone, Debug)]
pub enum SeqState {
    /// Queued but unstarted: no KV yet, just the admission ticket.
    Queued(Request),
    /// Mid-decode: the sequence's KV cache travels with it.
    Active {
        req: Request,
        /// Tokens generated so far (prefill's first token included).
        generated: usize,
        /// Last sampled token (next decode input).
        next_token: i32,
        /// When prefill finished (shared-clock sim seconds) — preserved
        /// so TTFT accounting survives the move.
        prefill_done_at: f64,
        /// Tokens materialized in the cache (next write position).
        kv_len: usize,
        k: Vec<f32>,
        v: Vec<f32>,
        /// Logical KV bytes under the exporting replica's mask at
        /// export time — the payload a migration must move.
        kv_bytes: usize,
    },
}

impl SeqState {
    pub fn id(&self) -> u64 {
        self.request().id
    }

    pub fn request(&self) -> &Request {
        match self {
            SeqState::Queued(r) => r,
            SeqState::Active { req, .. } => req,
        }
    }

    /// Bytes a migration of this state must move over the interconnect:
    /// the KV payload plus the prompt token ids.
    pub fn transfer_bytes(&self) -> usize {
        let prompt = self.request().prompt_len * 4;
        match self {
            SeqState::Queued(_) => prompt,
            SeqState::Active { kv_bytes, .. } => kv_bytes + prompt,
        }
    }
}

/// Idle-but-blocked time increment: how far the clock creeps while the
/// engine waits for memory headroom with nothing runnable.
const BLOCKED_TICK: f64 = 0.05;

/// Persistent decode-batch state: while batch membership is unchanged,
/// the gathered caches stay resident here and per-step gather/scatter
/// (a ~85 ms memcpy at batch 8 — see EXPERIMENTS.md §Perf) is skipped.
struct BatchState {
    ids: Vec<u64>,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine {
    pub rt: Runtime,
    pub mem: MemoryModel,
    pub kv: KvManager,
    pub batcher: Batcher,
    pub monitor: MemoryMonitor,
    pub controller: Controller,
    pub cfg: EngineConfig,
    pub mask: PruneMask,
    pub metrics: Metrics,
    sim_time: f64,
    last_controller_at: f64,
    last_sample_at: f64,
    batch: Option<BatchState>,
    /// Victim states exported under `EvictionMode::Park`, awaiting
    /// pickup by the fleet coordinator.
    parked: Vec<SeqState>,
    /// Cheapest mask the controller may reach for the observed workload
    /// (refreshed by `run_controller`, cached here so `outlook()` works
    /// from `&self` — routers and fleet passes read it between steps).
    /// `None` until the controller has run once; the outlook then falls
    /// back to the current mask, which is always conservative.
    min_viable_mask: Option<PruneMask>,
    /// Dense (full-mask) parameter bytes — mask-independent, cached so
    /// the outlook's hot path never re-walks the full mask.
    dense_param_bytes: usize,
}

impl Engine {
    pub fn new(rt: Runtime, monitor: MemoryMonitor,
               controller: Controller, cfg: EngineConfig) -> Engine {
        let meta = rt.meta().clone();
        let mem = MemoryModel::new(&meta);
        let mask = PruneMask::full(&meta);
        let dense_param_bytes = mem.param_bytes(&mask);
        Engine {
            kv: KvManager::new(&meta),
            batcher: Batcher::new(),
            rt,
            mem,
            monitor,
            controller,
            cfg,
            mask,
            metrics: Metrics::default(),
            sim_time: 0.0,
            last_controller_at: f64::NEG_INFINITY,
            last_sample_at: f64::NEG_INFINITY,
            batch: None,
            parked: Vec::new(),
            min_viable_mask: None,
            dense_param_bytes,
        }
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// Nothing queued and nothing active.
    pub fn idle(&self) -> bool {
        self.batcher.active.is_empty() && self.batcher.waiting.is_empty()
    }

    /// Requests accepted but not yet finished (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.batcher.active.len() + self.batcher.waiting.len()
    }

    /// Hand the engine a request; it is served on subsequent `step_to`
    /// calls (external admission — the fleet router's entry point).
    pub fn enqueue(&mut self, req: Request) {
        self.batcher.enqueue(req);
    }

    /// Current model + KV footprint under the active mask.
    pub fn bytes_used(&self) -> usize {
        self.bytes_used_under(&self.mask)
    }

    /// Model + live-KV footprint this engine would have under an
    /// arbitrary mask (same per-layer accounting as `bytes_used`, with
    /// the live sequences' cached lengths).
    pub fn bytes_used_under(&self, mask: &PruneMask) -> usize {
        self.mem.param_bytes(mask) + self.kv.bytes_used(mask)
    }

    /// The mask-elastic view of this engine's footprint: `{min_viable,
    /// current, dense}` bytes (see [`MemoryOutlook`]). With
    /// `elastic_accounting` off, or before the controller has produced
    /// a min-viable mask, the outlook is rigid at the current
    /// footprint — every consumer then degrades to the classic
    /// current-mask behavior.
    pub fn outlook(&self) -> MemoryOutlook {
        let current = self.bytes_used();
        if !self.cfg.elastic_accounting {
            return MemoryOutlook::rigid(current);
        }
        // Dense footprint without re-walking the full mask: every
        // layer caches the same tokens, so dense KV is just the token
        // total times the dense per-token bytes.
        let meta = self.rt.meta();
        let dense = self.dense_param_bytes
            + self.kv.total_tokens()
                * meta.n_layers
                * meta.kv_bytes_per_token_layer(meta.n_kv_heads);
        let min_viable = match &self.min_viable_mask {
            Some(m) => self.bytes_used_under(m),
            None => current,
        };
        MemoryOutlook::new(min_viable, current, dense)
    }

    /// The workload descriptor the controller conditions on: current
    /// decode batch size and the longest projected sequence among active
    /// + queued work.
    fn observed_workload(&self) -> Workload {
        let batch = decode_bucket(self.batcher.active.len()).max(1);
        let longest = self
            .batcher
            .active
            .iter()
            .map(|s| s.req.prompt_len + s.req.gen_len)
            .chain(self.batcher.waiting.iter()
                   .map(|r| r.prompt_len + r.gen_len))
            .max()
            .unwrap_or(32);
        Workload::new(batch, longest.min(self.rt.meta().max_seq))
    }

    fn run_controller(&mut self, force: bool) -> Result<()> {
        if !force
            && self.sim_time - self.last_controller_at
                < self.cfg.controller_period
        {
            return Ok(());
        }
        self.last_controller_at = self.sim_time;
        let avail = self.monitor.available_at(self.sim_time);
        let w = self.observed_workload();
        let t0 = std::time::Instant::now();
        let new_mask = self.controller.decide(&mut self.rt, w, avail)?;
        // Keep the outlook's min-viable mask in step with the observed
        // workload (the controller caches per workload bucket, so this
        // is a lookup except on the first sight of a new bucket).
        if self.cfg.elastic_accounting {
            self.min_viable_mask =
                Some(self.controller.min_viable_mask(&mut self.rt, w)?);
        }
        self.metrics.controller_secs += t0.elapsed().as_secs_f64();
        if new_mask != self.mask {
            self.metrics.mask_switches += 1;
            self.mask = new_mask;
        }
        Ok(())
    }

    fn sample_memory(&mut self) {
        if self.sim_time - self.last_sample_at < self.cfg.sample_every {
            return;
        }
        self.last_sample_at = self.sim_time;
        self.metrics.mem_trace.push(MemSample {
            t: self.sim_time,
            used: self.bytes_used(),
            available: self.monitor.available_at(self.sim_time),
            param_bytes: self.mem.param_bytes(&self.mask),
            kv_bytes: self.kv.bytes_used(&self.mask),
        });
    }

    /// Handle an interference spike. The outlook decides what kind of
    /// pressure this is: a spike the mask lattice can absorb
    /// (`min_viable <= Sys_avail(t) < current`) shrinks the mask and
    /// sheds nothing — charged to `absorbed_spikes`, not `oom_events`.
    /// Only a true OOM (`Sys_avail(t) < min_viable`) counts as one and
    /// sheds work per the eviction mode. With `elastic_accounting` off,
    /// every current-mask transgression is an OOM (the old behavior).
    fn handle_memory_pressure(&mut self) -> Result<()> {
        let avail = self.monitor.available_at(self.sim_time);
        if self.bytes_used() <= avail {
            return Ok(());
        }
        let absorbable = !self.outlook().true_oom(avail)
            && self.cfg.elastic_accounting;
        if !absorbable {
            self.metrics.oom_events += 1;
        }
        // Give the controller a chance to shrink the model first.
        self.run_controller(true)?;
        if absorbable {
            // The controller's cached decision grid can under-shoot —
            // its stop predicate prices the *projected* workload KV,
            // which may underestimate the live footprint. Pressure
            // overrides the grid: deploy the min-viable mask itself
            // rather than shedding work the mask space can absorb.
            if self.bytes_used() > avail {
                self.deploy_min_viable();
            }
            if self.bytes_used()
                <= self.monitor.available_at(self.sim_time)
            {
                self.metrics.absorbed_spikes += 1;
                return Ok(());
            }
            // Even the min-viable mask did not fit (the monitor moved,
            // or the outlook was stale): this is a true OOM after all.
            self.metrics.oom_events += 1;
        }
        self.flush_batch()?;
        while self.bytes_used()
            > self.monitor.available_at(self.sim_time)
            && !self.batcher.active.is_empty()
        {
            // Both modes shed the victim whose removal frees the most
            // memory for the longest remaining run, so Requeue frees
            // memory with the fewest evictions, exactly like Park.
            let i = self.pressure_victim().unwrap();
            let seq = self.batcher.active.remove(i);
            match self.cfg.eviction {
                EvictionMode::Requeue => {
                    // The cache is dropped; the request restarts from
                    // its prompt.
                    self.kv.remove(seq.req.id);
                    self.metrics.evictions += 1;
                    self.batcher.waiting.push_front(seq.req);
                }
                EvictionMode::Park => {
                    let state = self.export_active(seq)?;
                    self.parked.push(state);
                }
            }
        }
        Ok(())
    }

    /// Index of the active sequence whose eviction/migration pays off
    /// most: the one with the largest KV bytes × remaining-decode
    /// estimate (ties break toward the oldest). `None` when nothing is
    /// active.
    fn pressure_victim(&self) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, s) in self.batcher.active.iter().enumerate() {
            let len = self.kv.seq_len(s.req.id).unwrap_or(0);
            let remaining =
                s.req.gen_len.saturating_sub(s.generated).max(1);
            let score = self.kv_bytes_for_len(len) * remaining;
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((i, score));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Logical KV bytes of one sequence of `len` cached tokens under the
    /// current mask (the same per-layer accounting as
    /// `KvManager::bytes_used`).
    pub fn kv_bytes_for_len(&self, len: usize) -> usize {
        self.kv_bytes_for_len_under(&self.mask, len)
    }

    /// As [`Engine::kv_bytes_for_len`], under an arbitrary mask.
    pub fn kv_bytes_for_len_under(&self, mask: &PruneMask, len: usize)
                                  -> usize {
        let meta = self.rt.meta();
        let dh = meta.head_dim();
        let mut kv = 0usize;
        for l in 0..meta.n_layers {
            kv += 2 * mask.active_kv_groups(l) * dh * len
                * crate::model_meta::BYTES_PER_SCALAR;
        }
        kv
    }

    /// Projected bytes if we admit `req` (its KV at full length) under
    /// the current mask. Public so memory-aware routers can estimate a
    /// request's footprint on each candidate replica.
    pub fn admission_cost(&self, req: &Request) -> usize {
        let full_len =
            (req.prompt_len + req.gen_len).min(self.rt.meta().max_seq);
        self.kv_bytes_for_len(full_len)
    }

    /// Deploy the min-viable mask directly — the pressure/admission
    /// override for when the controller's decision grid under-shoots.
    /// No-op when none is cached or it is already deployed.
    fn deploy_min_viable(&mut self) {
        if let Some(m) = self.min_viable_mask.clone() {
            if m != self.mask {
                self.metrics.mask_switches += 1;
                self.mask = m;
            }
        }
    }

    /// Projected bytes to host `req` under the cheapest deployable mask
    /// — the placement counterpart of [`Engine::admission_cost`]: what
    /// the sequence costs a peer that shrinks as far as allowed, so
    /// feasibility checks against *elastic* headroom compare like with
    /// like. Equals `admission_cost` for static deployments, with
    /// mask-elastic accounting off, or before the controller has run.
    pub fn elastic_admission_cost(&self, req: &Request) -> usize {
        let current = self.admission_cost(req);
        if !self.cfg.elastic_accounting {
            return current;
        }
        match &self.min_viable_mask {
            Some(m) => {
                let full_len = (req.prompt_len + req.gen_len)
                    .min(self.rt.meta().max_seq);
                self.kv_bytes_for_len_under(m, full_len).min(current)
            }
            None => current,
        }
    }

    /// Could a min-viable deployment host `req` within `avail` even
    /// though the current mask cannot? (Admission's counterpart of the
    /// outlook: an empty-but-dense server should shrink, not reject.)
    fn min_viable_admits(&self, req: &Request, avail: usize) -> bool {
        let Some(m) = &self.min_viable_mask else {
            return false;
        };
        let full_len =
            (req.prompt_len + req.gen_len).min(self.rt.meta().max_seq);
        self.mem.param_bytes(m) + self.kv.bytes_used(m)
            + self.kv_bytes_for_len_under(m, full_len)
            <= avail
    }

    // ---- sequence export / import (fleet migration) -------------------

    /// Package one active sequence (already removed from the batcher)
    /// into a portable state, pulling its cache out of the KV manager.
    fn export_active(&mut self, seq: ActiveSeq) -> Result<SeqState> {
        let cache = self.kv.remove(seq.req.id).ok_or_else(|| {
            anyhow::anyhow!("export: seq {} has no cache", seq.req.id)
        })?;
        let kv_bytes = self.kv_bytes_for_len(cache.len);
        Ok(SeqState::Active {
            req: seq.req,
            generated: seq.generated,
            next_token: seq.next_token,
            prefill_done_at: seq.prefill_done_at,
            kv_len: cache.len,
            k: cache.k,
            v: cache.v,
            kv_bytes,
        })
    }

    /// Remove one sequence — mid-decode or queued-but-unstarted — and
    /// return its portable state, flushing the persistent decode batch
    /// first so the exported cache is coherent. `None` when the engine
    /// doesn't hold `id`.
    pub fn export_sequence(&mut self, id: u64) -> Result<Option<SeqState>> {
        if let Some(i) =
            self.batcher.active.iter().position(|s| s.req.id == id)
        {
            self.flush_batch()?;
            let seq = self.batcher.active.remove(i);
            return Ok(Some(self.export_active(seq)?));
        }
        if let Some(i) =
            self.batcher.waiting.iter().position(|r| r.id == id)
        {
            let req = self.batcher.waiting.remove(i).unwrap();
            return Ok(Some(SeqState::Queued(req)));
        }
        Ok(None)
    }

    /// Whether `state` can be installed here: no live id collision and
    /// (for active states) a cache shape matching this engine's model.
    pub fn can_import(&self, state: &SeqState) -> bool {
        let id = state.id();
        if self.kv.contains(id)
            || self.batcher.active.iter().any(|s| s.req.id == id)
            || self.batcher.waiting.iter().any(|r| r.id == id)
        {
            return false;
        }
        match state {
            SeqState::Queued(_) => true,
            SeqState::Active { k, v, .. } => {
                k.len() == self.kv.seq_elems()
                    && v.len() == self.kv.seq_elems()
            }
        }
    }

    /// Install a sequence exported by a peer engine. Queued states join
    /// the admission queue; active states resume decoding with their KV
    /// intact (first token already served, so TTFT is unaffected by the
    /// move). Fails, leaving the engine untouched, on a live id
    /// collision or a cache whose shape doesn't match this model.
    pub fn import_sequence(&mut self, state: SeqState) -> Result<()> {
        if !self.can_import(&state) {
            bail!("import: sequence {} rejected (duplicate id or \
                   mismatched cache shape)", state.id());
        }
        match state {
            SeqState::Queued(req) => self.batcher.enqueue(req),
            SeqState::Active { req, generated, next_token,
                               prefill_done_at, kv_len, k, v, .. } => {
                self.kv.insert(req.id, k, v, kv_len, &self.mask)?;
                self.batcher.push_active(ActiveSeq {
                    req,
                    generated,
                    next_token,
                    prefill_done_at,
                });
            }
        }
        Ok(())
    }

    /// Drain the states parked by `EvictionMode::Park` (the fleet
    /// coordinator's pickup point).
    pub fn take_parked(&mut self) -> Vec<SeqState> {
        std::mem::take(&mut self.parked)
    }

    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Drain the admission queue (fleet queue-rebalancing off a
    /// pressured replica).
    pub fn take_waiting(&mut self) -> Vec<Request> {
        self.batcher.waiting.drain(..).collect()
    }

    /// Advance the clock by one unit of compute: modeled cost when the
    /// runtime provides one (sim backend), measured wall time otherwise.
    fn advance(&mut self, wall_secs: f64) {
        let dt = self.rt.last_cost().unwrap_or(wall_secs);
        self.metrics.exec_secs += dt;
        self.sim_time += dt * self.cfg.time_scale;
    }

    fn try_prefill(&mut self) -> Result<bool> {
        if !self.batcher.wants_prefill() {
            return Ok(false);
        }
        let avail = (self.monitor.available_at(self.sim_time) as f64
            * self.cfg.admission_headroom) as usize;
        let Some(req) = self.batcher.waiting.front().cloned() else {
            return Ok(false);
        };
        if self.bytes_used() + self.admission_cost(&req) > avail {
            // Head-of-line blocked on memory. If the system is idle and
            // even an empty server can't host it under the current
            // mask, consult the outlook: when a min-viable deployment
            // *could* host it, force a controller decision (the mask
            // should shrink, not the queue) and retry next tick;
            // otherwise reject outright.
            if self.batcher.active.is_empty()
                && self.mem.param_bytes(&self.mask)
                    + self.admission_cost(&req) > avail
            {
                if self.cfg.elastic_accounting
                    && self.min_viable_admits(&req, avail)
                {
                    self.run_controller(true)?;
                    // The decision grid targets the raw `Sys_avail`,
                    // so its mask can land inside the
                    // (headroom-scaled, raw] gap and never admit — and
                    // a DQN policy's decision has no fit predicate at
                    // all. Mirror the pressure path: when the decided
                    // mask still cannot admit, deploy the min-viable
                    // mask directly; the next pass then admits by the
                    // `min_viable_admits` check above (no retry
                    // livelock).
                    if self.mem.param_bytes(&self.mask)
                        + self.admission_cost(&req) > avail
                    {
                        self.deploy_min_viable();
                    }
                    return Ok(false);
                }
                self.batcher.waiting.pop_front();
                self.metrics.rejected += 1;
            }
            return Ok(false);
        }
        let req = self.batcher.pop_for_prefill().unwrap();
        let bucket = prefill_bucket(req.prompt_len);
        // Trace prompts are clamped to the largest bucket.
        let plen = req.prompt_len.min(bucket);
        // Deterministic prompt tokens derived from the request id.
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ req.id);
        let mut tokens = vec![0i32; bucket];
        let vocab = self.rt.meta().vocab;
        for t in tokens.iter_mut().take(plen) {
            *t = rng.below(vocab) as i32;
        }
        let t0 = std::time::Instant::now();
        let (logits, k, v) = self.rt.prefill(bucket, &tokens, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.prefills += 1;

        let next_token = argmax(&logits) as i32;
        self.kv.insert(req.id, k, v, bucket, &self.mask)?;
        self.batcher.push_active(ActiveSeq {
            req,
            generated: 1,
            next_token,
            prefill_done_at: self.sim_time,
        });
        self.metrics.tokens_generated += 1;
        Ok(true)
    }

    /// Write the persistent batch's caches back to per-seq storage (ids
    /// already retired are skipped — their cache no longer matters).
    fn flush_batch(&mut self) -> Result<()> {
        if let Some(bs) = self.batch.take() {
            self.kv.scatter_cache(&bs.ids, &bs.k, &bs.v, true)?;
        }
        Ok(())
    }

    fn decode_step(&mut self) -> Result<bool> {
        let ids = self.batcher.decode_ids();
        if ids.is_empty() {
            self.flush_batch()?;
            return Ok(false);
        }
        let b = ids.len();
        // Recompose the persistent batch only when membership changes.
        if self.batch.as_ref().map(|s| s.ids.as_slice())
            != Some(ids.as_slice())
        {
            self.flush_batch()?;
            let (k, v) = self.kv.gather(&ids)?;
            self.batch = Some(BatchState { ids: ids.clone(), k, v });
        }
        let pos = self.kv.positions(&ids)?;
        let tokens: Vec<i32> = ids
            .iter()
            .map(|id| self.batcher.seq_mut(*id).unwrap().next_token)
            .collect();
        let bs = self.batch.as_mut().unwrap();
        let t0 = std::time::Instant::now();
        let logits = self.rt.decode(b, &tokens, &pos, &mut bs.k,
                                    &mut bs.v, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.decode_steps += 1;
        self.kv.bump_lens(&ids, &self.mask)?;

        let vocab = self.rt.meta().vocab;
        for (bi, id) in ids.iter().enumerate() {
            let tok = argmax(&logits[bi * vocab..(bi + 1) * vocab]) as i32;
            let seq = self.batcher.seq_mut(*id).unwrap();
            seq.next_token = tok;
            seq.generated += 1;
            self.metrics.tokens_generated += 1;
        }
        let finished = self.batcher.retire_finished();
        if !finished.is_empty() {
            // membership will change; keep survivors' caches coherent
            self.flush_batch()?;
        }
        for seq in finished {
            self.kv.remove(seq.req.id);
            self.metrics.completed.push(RequestRecord {
                id: seq.req.id,
                arrival: seq.req.arrival,
                first_token_at: seq.prefill_done_at,
                finished_at: self.sim_time,
                prompt_len: seq.req.prompt_len,
                gen_len: seq.req.gen_len,
            });
        }
        Ok(true)
    }

    /// Advance the simulated clock to `t`, doing work along the way.
    ///
    /// Invariants: on return `sim_time() >= t` (compute steps may
    /// overshoot the target by at most one step's duration); with no
    /// outstanding work the clock jumps straight to `t`. This is the
    /// primitive an external coordinator drives — many engines stepped
    /// to the same `t` share one coherent fleet clock.
    pub fn step_to(&mut self, t: f64) -> Result<()> {
        self.step_while_busy(t)?;
        if self.sim_time < t {
            self.sim_time = t;
        }
        Ok(())
    }

    /// Like `step_to`, but returns as soon as the engine runs out of
    /// work instead of jumping the clock to `t` — so a driver that only
    /// wants "work until done or `t`" (e.g. `run_trace` with a huge
    /// `max_sim_secs` backstop) keeps a truthful completion time.
    pub fn step_while_busy(&mut self, t: f64) -> Result<()> {
        while self.sim_time < t && !self.idle() {
            self.run_controller(false)?;
            self.handle_memory_pressure()?;
            self.sample_memory();
            if !self.try_prefill()? && !self.decode_step()? {
                // waiting on memory headroom; let time creep forward
                self.sim_time = (self.sim_time + BLOCKED_TICK).min(t);
            }
        }
        Ok(())
    }

    /// Serve a whole trace to completion (or `max_sim_secs`): a thin
    /// arrival-admission driver over `enqueue` + `step_to`.
    pub fn run_trace(&mut self, mut requests: Vec<Request>)
                     -> Result<ServeReport> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let t_start = self.sim_time;
        let deadline = t_start + self.cfg.max_sim_secs;
        let mut next = 0usize;
        loop {
            // 1. admit arrivals whose time has come
            while next < requests.len()
                && requests[next].arrival <= self.sim_time
            {
                self.enqueue(requests[next].clone());
                next += 1;
            }
            if self.idle() {
                if next >= requests.len() {
                    break;
                }
                // jump to next arrival
                self.sim_time = requests[next].arrival;
                continue;
            }
            if self.sim_time >= deadline {
                break;
            }
            // 2. work until the next arrival (or the deadline). The
            // non-jumping variant keeps `sim_time` at the true
            // completion moment when the queue drains early — stepping
            // *to* a 1e9 backstop would wreck wall/throughput numbers.
            let target = if next < requests.len() {
                requests[next].arrival.min(deadline)
            } else {
                deadline
            };
            self.step_while_busy(target)?;
        }
        let wall = (self.sim_time - t_start).max(1e-9);
        Ok(self.metrics.report(wall))
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;
    use crate::server::controller::Policy;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    fn engine_with(capacity_mult: f64, adaptive: bool) -> Engine {
        let meta = ModelMeta::synthetic("e", 4, 128, 8, 4, 512, 512, 256);
        let rt = Runtime::synthetic(meta.clone(), 1);
        let mem = MemoryModel::new(&meta);
        let capacity = (mem.param_bytes(&PruneMask::full(&meta)) as f64
            * capacity_mult) as usize;
        let monitor = MemoryMonitor::constant(capacity);
        let policy = if adaptive {
            Policy::GsiGreedy
        } else {
            Policy::Static(PruneMask::full(&meta))
        };
        let controller = Controller::new(policy, mem, vec![0; 128], 128)
            .with_calib_bucket(1, 128);
        Engine::new(rt, monitor, controller, EngineConfig::default())
    }

    fn sim_engine(capacity_mult: f64) -> Engine {
        engine_with(capacity_mult, false)
    }

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, prompt_len: 12, gen_len: 6 }
    }

    #[test]
    fn step_to_jumps_when_idle() {
        let mut e = sim_engine(4.0);
        e.step_to(17.5).unwrap();
        assert_eq!(e.sim_time(), 17.5);
    }

    #[test]
    fn externally_stepped_engine_serves_requests() {
        let mut e = sim_engine(4.0);
        for i in 0..5 {
            e.enqueue(req(i, 0.0));
        }
        assert_eq!(e.outstanding(), 5);
        // step in small external increments, like a fleet would
        let mut t = 0.0;
        while !e.idle() && t < 300.0 {
            t += 0.5;
            e.step_to(t).unwrap();
            assert!(e.sim_time() >= t - 1e-9 || e.idle());
        }
        assert!(e.idle(), "work left after 300s");
        assert_eq!(e.metrics.completed.len(), 5);
        assert_eq!(e.metrics.oom_events, 0);
        // clock advanced by modeled compute, not wall time
        assert!(e.metrics.exec_secs > 0.0);
    }

    #[test]
    fn run_trace_matches_external_stepping() {
        let trace: Vec<Request> = (0..8).map(|i| req(i, i as f64 * 0.4))
            .collect();
        let mut a = sim_engine(4.0);
        let ra = a.run_trace(trace.clone()).unwrap();
        let mut b = sim_engine(4.0);
        let mut next = 0usize;
        let mut t = 0.0;
        while next < trace.len() || !b.idle() {
            while next < trace.len() && trace[next].arrival <= t {
                b.enqueue(trace[next].clone());
                next += 1;
            }
            t += 0.2;
            b.step_to(t).unwrap();
            assert!(t < 1000.0, "diverged");
        }
        assert_eq!(ra.completed, 8);
        assert_eq!(b.metrics.completed.len(), 8);
        // same requests, same backend seed → same token counts
        assert_eq!(ra.tokens_generated, b.metrics.tokens_generated);
        // regression: the huge max_sim_secs backstop must not leak into
        // the clock or the report when the queue drains early
        assert!(a.sim_time() < 1e4, "clock jumped to the deadline");
        assert!(ra.throughput_rps > 1e-3,
                "wall time corrupted: {} req/s", ra.throughput_rps);
    }

    /// Step in tiny increments so at most one compute op runs per call
    /// (every op costs ≥ the sim backend's base overhead of 2e-4 s).
    fn step_until_tokens(e: &mut Engine, want: u64) {
        let mut t = e.sim_time();
        while e.metrics.tokens_generated < want {
            t += 1e-4;
            e.step_to(t).unwrap();
            assert!(t < 60.0, "never generated {want} tokens");
        }
    }

    #[test]
    fn export_import_roundtrip_queued() {
        let mut a = sim_engine(4.0);
        a.enqueue(req(7, 0.0));
        let st = a.export_sequence(7).unwrap().unwrap();
        assert!(matches!(st, SeqState::Queued(_)));
        assert_eq!(st.id(), 7);
        assert!(a.idle(), "export left state behind");
        assert!(a.export_sequence(7).unwrap().is_none());

        let mut b = sim_engine(4.0);
        b.import_sequence(st).unwrap();
        b.step_to(120.0).unwrap();
        assert_eq!(b.metrics.completed.len(), 1);
        assert_eq!(b.metrics.completed[0].id, 7);
    }

    #[test]
    fn export_import_roundtrip_mid_decode() {
        // control: the same request served by one engine end to end
        let mut control = sim_engine(4.0);
        control.enqueue(req(3, 0.0));
        control.step_to(120.0).unwrap();
        assert_eq!(control.metrics.completed.len(), 1);
        let total = control.metrics.tokens_generated;
        assert_eq!(total, 6, "gen_len tokens in total");

        // serve the prefill + two decode steps, then export mid-decode
        let mut a = sim_engine(4.0);
        a.enqueue(req(3, 0.0));
        step_until_tokens(&mut a, 3);
        let st = a.export_sequence(3).unwrap().unwrap();
        let SeqState::Active { generated, kv_len, .. } = &st else {
            panic!("expected a mid-decode export");
        };
        assert_eq!(*generated, 3);
        // prefill bucket (16 for a 12-token prompt) + 2 decode writes
        assert_eq!(*kv_len, 18);
        assert!(st.transfer_bytes() > 0);
        assert!(a.idle(), "export left state behind");

        // identical continuation on two fresh engines
        let mut b1 = sim_engine(4.0);
        let mut b2 = sim_engine(4.0);
        b1.import_sequence(st.clone()).unwrap();
        b2.import_sequence(st).unwrap();
        b1.step_to(120.0).unwrap();
        b2.step_to(120.0).unwrap();
        for e in [&b1, &b2] {
            assert_eq!(e.metrics.completed.len(), 1);
            assert_eq!(e.metrics.completed[0].id, 3);
        }
        assert_eq!(b1.metrics.tokens_generated,
                   b2.metrics.tokens_generated);
        assert_eq!(b1.metrics.exec_secs, b2.metrics.exec_secs);
        // no token generated twice or lost across the move
        assert_eq!(a.metrics.tokens_generated
                   + b1.metrics.tokens_generated, total);
    }

    #[test]
    fn import_rejects_live_duplicates() {
        let mut e = sim_engine(4.0);
        e.import_sequence(SeqState::Queued(req(9, 0.0))).unwrap();
        assert!(e.import_sequence(SeqState::Queued(req(9, 0.0))).is_err());
        assert_eq!(e.outstanding(), 1);
    }

    #[test]
    fn park_mode_parks_instead_of_requeueing() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(4.0);
        e.cfg.eviction = EvictionMode::Park;
        e.enqueue(req(1, 0.0));
        step_until_tokens(&mut e, 2);
        // yank the headroom out: capacity == params, so any KV is over
        let cap = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(cap);
        let t = e.sim_time() + 0.5;
        e.step_to(t).unwrap();
        assert_eq!(e.parked_len(), 1);
        assert_eq!(e.metrics.evictions, 0, "park must not requeue");
        assert!(e.metrics.oom_events >= 1);
        let parked = e.take_parked();
        assert!(matches!(parked[0], SeqState::Active { .. }));
        assert_eq!(e.parked_len(), 0);
        assert!(e.idle());
    }

    #[test]
    fn requeue_mode_counts_evictions() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(4.0);
        e.enqueue(req(1, 0.0));
        step_until_tokens(&mut e, 2);
        let cap = e.mem.param_bytes(&e.mask);
        e.monitor = MemoryMonitor::constant(cap);
        let t = e.sim_time() + 0.5;
        e.step_to(t).unwrap();
        assert!(e.metrics.evictions >= 1);
        assert_eq!(e.parked_len(), 0);
    }

    /// Regression (ISSUE 4): `Requeue` must pick victims exactly like
    /// `Park` — by KV bytes × remaining decode — so pressure is
    /// relieved with the fewest evictions. The old youngest-first pop
    /// would evict the small sequence here, find memory still over,
    /// and evict the big one too: two evictions where one suffices.
    #[test]
    fn requeue_frees_memory_with_fewest_evictions() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = sim_engine(8.0);
        // A: long prompt (128-token bucket), B: short (16-token bucket)
        e.enqueue(Request { id: 1, arrival: 0.0, prompt_len: 100,
                            gen_len: 30 });
        e.enqueue(Request { id: 2, arrival: 0.0, prompt_len: 12,
                            gen_len: 30 });
        step_until_tokens(&mut e, 4); // both prefilled + one decode step
        let len_a = e.kv.seq_len(1).unwrap();
        let len_b = e.kv.seq_len(2).unwrap();
        assert!(len_a > len_b, "{len_a} vs {len_b}");
        // Pressure sized so evicting A alone relieves it (params +
        // B's KV fits, with slack for B to decode to completion), but
        // evicting B alone would not (params + A's KV stays over).
        let params = e.mem.param_bytes(&e.mask);
        let avail = params + e.kv_bytes_for_len(len_b + 40);
        assert!(avail < params + e.kv_bytes_for_len(len_a));
        e.monitor = MemoryMonitor::constant(avail);
        // a tiny step: pressure handling plus at most one compute op,
        // so the post-eviction state is still observable
        e.step_to(e.sim_time() + 1e-4).unwrap();
        assert_eq!(e.metrics.evictions, 1,
                   "victim selection should free memory in one eviction");
        assert!(e.metrics.oom_events >= 1);
        // the big sequence was the victim; the small one kept serving
        assert_eq!(e.batcher.waiting.front().unwrap().id, 1);
        assert!(e.batcher.active.iter().any(|s| s.req.id == 2));
        // and the survivor runs to completion without further shedding
        e.step_to(e.sim_time() + 0.5).unwrap();
        assert!(e.metrics.completed.iter().any(|r| r.id == 2));
        assert_eq!(e.metrics.evictions, 1);
    }

    /// The tentpole at engine level: a spike inside the absorbable band
    /// (`min_viable <= Sys_avail < current`) shrinks the mask — no OOM,
    /// no eviction, no parked victim — and is charged to
    /// `absorbed_spikes`. With `elastic_accounting` off, the identical
    /// spike is booked as an OOM (the legacy behavior).
    #[test]
    fn absorbable_spike_shrinks_mask_instead_of_oom() {
        use crate::server::memmon::MemoryMonitor;

        for elastic in [true, false] {
            let mut e = engine_with(4.0, true);
            e.cfg.elastic_accounting = elastic;
            e.enqueue(req(1, 0.0));
            step_until_tokens(&mut e, 2);
            assert_eq!(e.metrics.oom_events, 0);
            let params =
                e.mem.param_bytes(&PruneMask::full(e.rt.meta()));
            // into the absorbable band: below the dense parameter
            // footprint, far above the min-viable one (~0.3×)
            e.monitor = MemoryMonitor::constant(
                (params as f64 * 0.72) as usize);
            let t = e.sim_time() + 0.5;
            e.step_to(t).unwrap();
            if elastic {
                assert_eq!(e.metrics.oom_events, 0,
                           "absorbable spike was booked as an OOM");
                assert!(e.metrics.absorbed_spikes >= 1);
                assert_eq!(e.metrics.evictions, 0);
                assert_eq!(e.parked_len(), 0);
                assert!(e.mask.param_fraction(e.rt.meta()) < 1.0,
                        "the mask never shrank");
                assert!(e.bytes_used()
                        <= e.monitor.available_at(e.sim_time()));
                // and the sequence still completes under the shrunken
                // mask
                e.step_to(t + 300.0).unwrap();
                assert_eq!(e.metrics.completed.len(), 1);
            } else {
                assert!(e.metrics.oom_events >= 1,
                        "legacy accounting must book the spike");
                assert_eq!(e.metrics.absorbed_spikes, 0);
            }
        }
    }

    /// Review-fix regression: an empty adaptive engine whose
    /// `Sys_avail` lands in the gap between the controller's decided
    /// mask (which targets the *raw* avail) and the admission check
    /// (scaled by `admission_headroom`) must deploy the min-viable
    /// mask and serve the head-of-line request — never spin forever
    /// neither admitting nor rejecting it.
    #[test]
    fn admission_gap_deploys_min_viable_instead_of_starving() {
        use crate::server::memmon::MemoryMonitor;

        let mut e = engine_with(4.0, true);
        let params = e.mem.param_bytes(&PruneMask::full(e.rt.meta()));
        // 0.60× dense params sits inside such a gap window for this
        // seed (verified against the outlook_port.py scan): pre-fix
        // the request was neither admitted nor rejected
        e.monitor =
            MemoryMonitor::constant((params as f64 * 0.60) as usize);
        e.enqueue(req(1, 0.0));
        e.step_to(300.0).unwrap();
        assert_eq!(e.metrics.completed.len(), 1,
                   "request starved in the admission gap");
        assert_eq!(e.metrics.rejected, 0);
    }

    /// The outlook lattice from a live engine: rigid for static masks,
    /// `min_viable < current <= dense` once an adaptive controller has
    /// run.
    #[test]
    fn outlook_reports_the_mask_lattice() {
        let mut s = sim_engine(4.0);
        s.enqueue(req(1, 0.0));
        step_until_tokens(&mut s, 2);
        let o = s.outlook();
        assert_eq!(o.min_viable, o.current, "static mask cannot shrink");

        let mut a = engine_with(4.0, true);
        a.enqueue(req(1, 0.0));
        step_until_tokens(&mut a, 2);
        let o = a.outlook();
        assert!(o.min_viable < o.current,
                "adaptive outlook has slack: {o:?}");
        assert!(o.current <= o.dense);
        assert_eq!(o.current, a.bytes_used());
    }

    #[test]
    fn sim_backend_drives_virtual_time() {
        let mut e = sim_engine(4.0);
        e.enqueue(req(0, 0.0));
        let wall = std::time::Instant::now();
        e.step_to(1000.0).unwrap();
        // a single request's modeled compute is far below 1000 virtual
        // seconds, yet wall time must be tiny: virtual ≫ wall
        assert!(e.sim_time() >= 1000.0);
        assert!(wall.elapsed().as_secs_f64() < 30.0);
        assert_eq!(e.metrics.completed.len(), 1);
    }
}

//! The serving engine: a continuous-batching event loop over the model
//! runtime, with RAP's controller in the loop.
//!
//! Time model: the engine advances a *simulated* clock fed by the trace's
//! arrival times; compute steps advance the clock by their duration —
//! measured wall-clock on the PJRT backend, the modeled cost on the sim
//! backend (`Runtime::last_cost`) — times `time_scale`. This lets a
//! 10-minute "day" of traffic replay in however long the actual math
//! takes while keeping latency accounting coherent.
//!
//! Stepping model: the engine no longer owns its run loop. The primitive
//! is [`Engine::step_to`], which advances the clock to a target time
//! doing work along the way; [`Engine::run_trace`] is a thin driver over
//! `enqueue` + `step_to`, and the fleet coordinator drives many engines
//! against one shared clock the same way.
//!
//! Per unit of work:
//!   1. controller: observe (active workload, Sys_avail(t)) and re-decide
//!      the mask when the situation changed (cached decisions make this
//!      the paper's "<1% overhead" path);
//!   2. OOM handling: if interference spiked over our current footprint,
//!      count an OOM event and — under a static policy — evict the
//!      youngest sequence (requeue); RAP instead shrinks the mask;
//!   3. run one prefill (if queue room + memory headroom) or one decode
//!      step over the gathered batch; sample tokens; retire finished.

use anyhow::Result;

use super::batcher::{decode_bucket, prefill_bucket, ActiveSeq, Batcher};
use super::controller::Controller;
use super::kv::KvManager;
use super::memmon::MemoryMonitor;
use super::metrics::{MemSample, Metrics, RequestRecord, ServeReport};
use crate::mask::PruneMask;
use crate::memory::{MemoryModel, Workload};
use crate::runtime::Runtime;
use crate::workload::Request;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Simulated seconds per real compute second.
    pub time_scale: f64,
    /// Memory-trace sampling period (sim seconds).
    pub sample_every: f64,
    /// Re-run the controller at most this often (sim seconds).
    pub controller_period: f64,
    /// Safety factor on admission (fraction of available memory usable).
    pub admission_headroom: f64,
    /// Hard stop (sim seconds) even if work remains.
    pub max_sim_secs: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { time_scale: 1.0, sample_every: 2.0,
                       controller_period: 5.0, admission_headroom: 0.95,
                       max_sim_secs: 1e9 }
    }
}

/// Idle-but-blocked time increment: how far the clock creeps while the
/// engine waits for memory headroom with nothing runnable.
const BLOCKED_TICK: f64 = 0.05;

/// Persistent decode-batch state: while batch membership is unchanged,
/// the gathered caches stay resident here and per-step gather/scatter
/// (a ~85 ms memcpy at batch 8 — see EXPERIMENTS.md §Perf) is skipped.
struct BatchState {
    ids: Vec<u64>,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct Engine {
    pub rt: Runtime,
    pub mem: MemoryModel,
    pub kv: KvManager,
    pub batcher: Batcher,
    pub monitor: MemoryMonitor,
    pub controller: Controller,
    pub cfg: EngineConfig,
    pub mask: PruneMask,
    pub metrics: Metrics,
    sim_time: f64,
    last_controller_at: f64,
    last_sample_at: f64,
    batch: Option<BatchState>,
}

impl Engine {
    pub fn new(rt: Runtime, monitor: MemoryMonitor,
               controller: Controller, cfg: EngineConfig) -> Engine {
        let meta = rt.meta().clone();
        let mem = MemoryModel::new(&meta);
        let mask = PruneMask::full(&meta);
        Engine {
            kv: KvManager::new(&meta),
            batcher: Batcher::new(),
            rt,
            mem,
            monitor,
            controller,
            cfg,
            mask,
            metrics: Metrics::default(),
            sim_time: 0.0,
            last_controller_at: f64::NEG_INFINITY,
            last_sample_at: f64::NEG_INFINITY,
            batch: None,
        }
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    /// Nothing queued and nothing active.
    pub fn idle(&self) -> bool {
        self.batcher.active.is_empty() && self.batcher.waiting.is_empty()
    }

    /// Requests accepted but not yet finished (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.batcher.active.len() + self.batcher.waiting.len()
    }

    /// Hand the engine a request; it is served on subsequent `step_to`
    /// calls (external admission — the fleet router's entry point).
    pub fn enqueue(&mut self, req: Request) {
        self.batcher.enqueue(req);
    }

    /// Current model + KV footprint under the active mask.
    pub fn bytes_used(&self) -> usize {
        self.mem.param_bytes(&self.mask) + self.kv.bytes_used(&self.mask)
    }

    /// The workload descriptor the controller conditions on: current
    /// decode batch size and the longest projected sequence among active
    /// + queued work.
    fn observed_workload(&self) -> Workload {
        let batch = decode_bucket(self.batcher.active.len()).max(1);
        let longest = self
            .batcher
            .active
            .iter()
            .map(|s| s.req.prompt_len + s.req.gen_len)
            .chain(self.batcher.waiting.iter()
                   .map(|r| r.prompt_len + r.gen_len))
            .max()
            .unwrap_or(32);
        Workload::new(batch, longest.min(self.rt.meta().max_seq))
    }

    fn run_controller(&mut self, force: bool) -> Result<()> {
        if !force
            && self.sim_time - self.last_controller_at
                < self.cfg.controller_period
        {
            return Ok(());
        }
        self.last_controller_at = self.sim_time;
        let avail = self.monitor.available_at(self.sim_time);
        let w = self.observed_workload();
        let t0 = std::time::Instant::now();
        let new_mask = self.controller.decide(&mut self.rt, w, avail)?;
        self.metrics.controller_secs += t0.elapsed().as_secs_f64();
        if new_mask != self.mask {
            self.metrics.mask_switches += 1;
            self.mask = new_mask;
        }
        Ok(())
    }

    fn sample_memory(&mut self) {
        if self.sim_time - self.last_sample_at < self.cfg.sample_every {
            return;
        }
        self.last_sample_at = self.sim_time;
        self.metrics.mem_trace.push(MemSample {
            t: self.sim_time,
            used: self.bytes_used(),
            available: self.monitor.available_at(self.sim_time),
            param_bytes: self.mem.param_bytes(&self.mask),
            kv_bytes: self.kv.bytes_used(&self.mask),
        });
    }

    /// Handle an interference spike: OOM if our footprint exceeds what's
    /// available. Static policies evict; adaptive policies re-decide.
    fn handle_memory_pressure(&mut self) -> Result<()> {
        let avail = self.monitor.available_at(self.sim_time);
        if self.bytes_used() <= avail {
            return Ok(());
        }
        self.metrics.oom_events += 1;
        // Give the controller a chance to shrink the model first.
        self.run_controller(true)?;
        self.flush_batch()?;
        while self.bytes_used()
            > self.monitor.available_at(self.sim_time)
            && !self.batcher.active.is_empty()
        {
            // Evict the youngest sequence and requeue it.
            let seq = self.batcher.active.pop().unwrap();
            self.kv.remove(seq.req.id);
            self.metrics.rejected += 1;
            self.batcher.waiting.push_front(seq.req);
        }
        Ok(())
    }

    /// Projected bytes if we admit `req` (its KV at full length) under
    /// the current mask. Public so memory-aware routers can estimate a
    /// request's footprint on each candidate replica.
    pub fn admission_cost(&self, req: &Request) -> usize {
        let meta = self.rt.meta();
        let dh = meta.head_dim();
        let full_len = (req.prompt_len + req.gen_len).min(meta.max_seq);
        let mut kv = 0usize;
        for l in 0..meta.n_layers {
            kv += 2 * self.mask.active_kv_groups(l) * dh * full_len
                * crate::model_meta::BYTES_PER_SCALAR;
        }
        kv
    }

    /// Advance the clock by one unit of compute: modeled cost when the
    /// runtime provides one (sim backend), measured wall time otherwise.
    fn advance(&mut self, wall_secs: f64) {
        let dt = self.rt.last_cost().unwrap_or(wall_secs);
        self.metrics.exec_secs += dt;
        self.sim_time += dt * self.cfg.time_scale;
    }

    fn try_prefill(&mut self) -> Result<bool> {
        if !self.batcher.wants_prefill() {
            return Ok(false);
        }
        let avail = (self.monitor.available_at(self.sim_time) as f64
            * self.cfg.admission_headroom) as usize;
        let Some(req) = self.batcher.waiting.front().cloned() else {
            return Ok(false);
        };
        if self.bytes_used() + self.admission_cost(&req) > avail {
            // Head-of-line blocked on memory. If the system is idle and
            // even an empty server can't host it, reject outright.
            if self.batcher.active.is_empty()
                && self.mem.param_bytes(&self.mask)
                    + self.admission_cost(&req) > avail
            {
                self.batcher.waiting.pop_front();
                self.metrics.rejected += 1;
            }
            return Ok(false);
        }
        let req = self.batcher.pop_for_prefill().unwrap();
        let bucket = prefill_bucket(req.prompt_len);
        // Trace prompts are clamped to the largest bucket.
        let plen = req.prompt_len.min(bucket);
        // Deterministic prompt tokens derived from the request id.
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ req.id);
        let mut tokens = vec![0i32; bucket];
        let vocab = self.rt.meta().vocab;
        for t in tokens.iter_mut().take(plen) {
            *t = rng.below(vocab) as i32;
        }
        let t0 = std::time::Instant::now();
        let (logits, k, v) = self.rt.prefill(bucket, &tokens, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.prefills += 1;

        let next_token = argmax(&logits) as i32;
        self.kv.insert(req.id, k, v, bucket, &self.mask)?;
        self.batcher.push_active(ActiveSeq {
            req,
            generated: 1,
            next_token,
            prefill_done_at: self.sim_time,
        });
        self.metrics.tokens_generated += 1;
        Ok(true)
    }

    /// Write the persistent batch's caches back to per-seq storage (ids
    /// already retired are skipped — their cache no longer matters).
    fn flush_batch(&mut self) -> Result<()> {
        if let Some(bs) = self.batch.take() {
            self.kv.scatter_cache(&bs.ids, &bs.k, &bs.v, true)?;
        }
        Ok(())
    }

    fn decode_step(&mut self) -> Result<bool> {
        let ids = self.batcher.decode_ids();
        if ids.is_empty() {
            self.flush_batch()?;
            return Ok(false);
        }
        let b = ids.len();
        // Recompose the persistent batch only when membership changes.
        if self.batch.as_ref().map(|s| s.ids.as_slice())
            != Some(ids.as_slice())
        {
            self.flush_batch()?;
            let (k, v) = self.kv.gather(&ids)?;
            self.batch = Some(BatchState { ids: ids.clone(), k, v });
        }
        let pos = self.kv.positions(&ids)?;
        let tokens: Vec<i32> = ids
            .iter()
            .map(|id| self.batcher.seq_mut(*id).unwrap().next_token)
            .collect();
        let bs = self.batch.as_mut().unwrap();
        let t0 = std::time::Instant::now();
        let logits = self.rt.decode(b, &tokens, &pos, &mut bs.k,
                                    &mut bs.v, &self.mask)?;
        self.advance(t0.elapsed().as_secs_f64());
        self.metrics.decode_steps += 1;
        self.kv.bump_lens(&ids, &self.mask)?;

        let vocab = self.rt.meta().vocab;
        for (bi, id) in ids.iter().enumerate() {
            let tok = argmax(&logits[bi * vocab..(bi + 1) * vocab]) as i32;
            let seq = self.batcher.seq_mut(*id).unwrap();
            seq.next_token = tok;
            seq.generated += 1;
            self.metrics.tokens_generated += 1;
        }
        let finished = self.batcher.retire_finished();
        if !finished.is_empty() {
            // membership will change; keep survivors' caches coherent
            self.flush_batch()?;
        }
        for seq in finished {
            self.kv.remove(seq.req.id);
            self.metrics.completed.push(RequestRecord {
                id: seq.req.id,
                arrival: seq.req.arrival,
                first_token_at: seq.prefill_done_at,
                finished_at: self.sim_time,
                prompt_len: seq.req.prompt_len,
                gen_len: seq.req.gen_len,
            });
        }
        Ok(true)
    }

    /// Advance the simulated clock to `t`, doing work along the way.
    ///
    /// Invariants: on return `sim_time() >= t` (compute steps may
    /// overshoot the target by at most one step's duration); with no
    /// outstanding work the clock jumps straight to `t`. This is the
    /// primitive an external coordinator drives — many engines stepped
    /// to the same `t` share one coherent fleet clock.
    pub fn step_to(&mut self, t: f64) -> Result<()> {
        self.step_while_busy(t)?;
        if self.sim_time < t {
            self.sim_time = t;
        }
        Ok(())
    }

    /// Like `step_to`, but returns as soon as the engine runs out of
    /// work instead of jumping the clock to `t` — so a driver that only
    /// wants "work until done or `t`" (e.g. `run_trace` with a huge
    /// `max_sim_secs` backstop) keeps a truthful completion time.
    pub fn step_while_busy(&mut self, t: f64) -> Result<()> {
        while self.sim_time < t && !self.idle() {
            self.run_controller(false)?;
            self.handle_memory_pressure()?;
            self.sample_memory();
            if !self.try_prefill()? && !self.decode_step()? {
                // waiting on memory headroom; let time creep forward
                self.sim_time = (self.sim_time + BLOCKED_TICK).min(t);
            }
        }
        Ok(())
    }

    /// Serve a whole trace to completion (or `max_sim_secs`): a thin
    /// arrival-admission driver over `enqueue` + `step_to`.
    pub fn run_trace(&mut self, mut requests: Vec<Request>)
                     -> Result<ServeReport> {
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let t_start = self.sim_time;
        let deadline = t_start + self.cfg.max_sim_secs;
        let mut next = 0usize;
        loop {
            // 1. admit arrivals whose time has come
            while next < requests.len()
                && requests[next].arrival <= self.sim_time
            {
                self.enqueue(requests[next].clone());
                next += 1;
            }
            if self.idle() {
                if next >= requests.len() {
                    break;
                }
                // jump to next arrival
                self.sim_time = requests[next].arrival;
                continue;
            }
            if self.sim_time >= deadline {
                break;
            }
            // 2. work until the next arrival (or the deadline). The
            // non-jumping variant keeps `sim_time` at the true
            // completion moment when the queue drains early — stepping
            // *to* a 1e9 backstop would wreck wall/throughput numbers.
            let target = if next < requests.len() {
                requests[next].arrival.min(deadline)
            } else {
                deadline
            };
            self.step_while_busy(target)?;
        }
        let wall = (self.sim_time - t_start).max(1e-9);
        Ok(self.metrics.report(wall))
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_meta::ModelMeta;
    use crate::server::controller::Policy;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    fn sim_engine(capacity_mult: f64) -> Engine {
        let meta = ModelMeta::synthetic("e", 4, 128, 8, 4, 512, 512, 256);
        let rt = Runtime::synthetic(meta.clone(), 1);
        let mem = MemoryModel::new(&meta);
        let capacity = (mem.param_bytes(&PruneMask::full(&meta)) as f64
            * capacity_mult) as usize;
        let monitor = MemoryMonitor::constant(capacity);
        let controller = Controller::new(
            Policy::Static(PruneMask::full(&meta)), mem, vec![0; 128], 128)
            .with_calib_bucket(1, 128);
        Engine::new(rt, monitor, controller, EngineConfig::default())
    }

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, prompt_len: 12, gen_len: 6 }
    }

    #[test]
    fn step_to_jumps_when_idle() {
        let mut e = sim_engine(4.0);
        e.step_to(17.5).unwrap();
        assert_eq!(e.sim_time(), 17.5);
    }

    #[test]
    fn externally_stepped_engine_serves_requests() {
        let mut e = sim_engine(4.0);
        for i in 0..5 {
            e.enqueue(req(i, 0.0));
        }
        assert_eq!(e.outstanding(), 5);
        // step in small external increments, like a fleet would
        let mut t = 0.0;
        while !e.idle() && t < 300.0 {
            t += 0.5;
            e.step_to(t).unwrap();
            assert!(e.sim_time() >= t - 1e-9 || e.idle());
        }
        assert!(e.idle(), "work left after 300s");
        assert_eq!(e.metrics.completed.len(), 5);
        assert_eq!(e.metrics.oom_events, 0);
        // clock advanced by modeled compute, not wall time
        assert!(e.metrics.exec_secs > 0.0);
    }

    #[test]
    fn run_trace_matches_external_stepping() {
        let trace: Vec<Request> = (0..8).map(|i| req(i, i as f64 * 0.4))
            .collect();
        let mut a = sim_engine(4.0);
        let ra = a.run_trace(trace.clone()).unwrap();
        let mut b = sim_engine(4.0);
        let mut next = 0usize;
        let mut t = 0.0;
        while next < trace.len() || !b.idle() {
            while next < trace.len() && trace[next].arrival <= t {
                b.enqueue(trace[next].clone());
                next += 1;
            }
            t += 0.2;
            b.step_to(t).unwrap();
            assert!(t < 1000.0, "diverged");
        }
        assert_eq!(ra.completed, 8);
        assert_eq!(b.metrics.completed.len(), 8);
        // same requests, same backend seed → same token counts
        assert_eq!(ra.tokens_generated, b.metrics.tokens_generated);
        // regression: the huge max_sim_secs backstop must not leak into
        // the clock or the report when the queue drains early
        assert!(a.sim_time() < 1e4, "clock jumped to the deadline");
        assert!(ra.throughput_rps > 1e-3,
                "wall time corrupted: {} req/s", ra.throughput_rps);
    }

    #[test]
    fn sim_backend_drives_virtual_time() {
        let mut e = sim_engine(4.0);
        e.enqueue(req(0, 0.0));
        let wall = std::time::Instant::now();
        e.step_to(1000.0).unwrap();
        // a single request's modeled compute is far below 1000 virtual
        // seconds, yet wall time must be tiny: virtual ≫ wall
        assert!(e.sim_time() >= 1000.0);
        assert!(wall.elapsed().as_secs_f64() < 30.0);
        assert_eq!(e.metrics.completed.len(), 1);
    }
}
